"""Serving benchmark: continuous batching vs the PR-4-era static loop.

Workload: a heavy-tailed request mix (a few long generations among many
short ones — the shape real traffic has) served at EQUAL batch width:

  static_loop   the PR-4-era ``examples/serve.py`` pattern: take the next
                ``slots`` requests, prefill them together, then run the
                per-token decode loop until the LONGEST request in the
                batch finishes (head-of-line blocking: finished rows keep
                burning decode FLOPs), repeat.
  engine        ``repro.serving.ServingEngine`` with ``slots`` decode
                slots: finished rows retire immediately and queued
                requests are admitted mid-flight, so every tick's batch
                is full of USEFUL work.

Throughput counts useful tokens only (tokens a request actually asked
for).  Also measured: the int-``pos`` dispatch tax the old loop paid
(one host->device transfer per token — on this jax it does NOT recompile,
the staging is the cost), and the engine's throughput-vs-slots curve.

PR-9 rows ride on top:

  engine_ticks{K}   steady-state decode tok/s at ``ticks_per_dispatch``
                    K in {1,2,4,8} on a dispatch-dominated config (tiny
                    per-tick compute), occupied slots, timed ``step()``
                    loop — the host-sync amortization the multi-tick
                    scan buys, isolated from admission noise.
  spec_*            end-to-end speculative decoding vs the target-only
                    engine on the same request set: the truncated-layer
                    draft (the target's own first layer; tail-damped
                    target weights stand in for the draft/target
                    agreement a distilled draft would have — measured
                    >1x, see docs/serving.md break-even) and an
                    adversarial random-weight draft (acceptance ~chance
                    — the rejection-cost floor).
  blocks_peak_*     shared-prefix block pool: peak blocks in use for a
                    same-prompt burst with dedup on, vs the dedup-off
                    control (the row VALUE is the shared peak, so
                    compare.py flags capacity regressions).

Rows carry arch/slots/backend/devices metadata into BENCH_<date>.json.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro import models
from repro.configs import ARCHS, reduced
from repro.kernels.common import KernelPolicy
from repro.models import transformer
from repro.serving import Request, ServingEngine

ARCH = "olmo-1b"
CAPACITY = 64
PROMPT = 8
TICKS = (1, 2, 4, 8)


def _cfg():
    # big enough that the per-tick compute (not python dispatch) is what
    # the schedulers are racing on
    cfg = reduced(ARCHS[ARCH], n_layers=2, d_model=256)
    return dataclasses.replace(cfg, kernels=KernelPolicy(attention="xla"))


def _tick_cfg():
    # the opposite regime: per-tick compute so small that the host
    # round-trip IS the serving hot path — the cost multi-tick (and the
    # single-dispatch spec round) exists to amortize
    cfg = reduced(ARCHS[ARCH], n_layers=1, d_model=128)
    return dataclasses.replace(cfg, vocab_size=256,
                               kernels=KernelPolicy(attention="xla"))


def _steady_tps(params, cfg, *, ticks, measure, reps, slots=4):
    """Steady-state decode throughput: slots stay occupied (budgets never
    expire inside the window), compiles + prefills land before t0, and
    only the dispatch cadence is inside the timed loop."""
    rng = np.random.default_rng(0)
    best = 0.0
    for _ in range(reps):
        eng = ServingEngine(params, cfg, slots=slots, capacity=128,
                            buckets=(PROMPT,), ticks_per_dispatch=ticks)
        for _ in range(slots):
            eng.submit(Request(
                prompt=rng.integers(0, cfg.vocab_size, size=PROMPT),
                max_new_tokens=10 ** 6))
        eng.step()                       # admit + prefill (+ compiles)
        eng.step()                       # one warm decode dispatch
        t0 = time.perf_counter()
        n = 0
        while n < measure:
            eng.step()
            n += ticks
        best = max(best, slots * n / (time.perf_counter() - t0))
    return best


def _requests(n, rng):
    """3:1 short:long mix — the heavy tail static batching trips over."""
    reqs = []
    for i in range(n):
        long = i % 4 == 0
        reqs.append(Request(
            prompt=rng.integers(0, 512, size=PROMPT),
            max_new_tokens=40 if long else 5))
    return reqs


@functools.lru_cache(maxsize=None)
def _loop_fns(cfg):
    """Compile the static loop's steps ONCE per config (a fresh closure
    per run would put the compile inside the measured wall)."""
    decode = jax.jit(
        lambda p, c, t, pos: transformer.decode_step(p, cfg, c, t, pos))

    def _greedy(p, c, t, pos):
        lg, c = transformer.decode_step(p, cfg, c, t, pos)
        return jnp.argmax(lg, -1).astype(jnp.int32), c

    prefill_j = jax.jit(lambda p, toks: transformer.forward(
        p, cfg, toks, return_cache=True,
        cache=transformer.init_decode_cache(cfg, toks.shape[0], CAPACITY)))
    return decode, jax.jit(_greedy), prefill_j


def _static_loop(params, cfg, reqs, batch, *, jit_prefill: bool):
    """The PR-4-era serving pattern: static batches, eager whole-batch
    prefill, one jitted decode driven by a python loop with an int
    ``pos``, every batch running until its LONGEST request finishes.
    ``jit_prefill=True`` is the strengthened variant (compiled prefill,
    device-scalar pos, argmax fused into the jitted step) that isolates
    the SCHEDULING gap from the per-token dispatch overhead the old
    example also paid."""
    decode, decode_g, prefill_j = _loop_fns(cfg)
    useful = 0
    t0 = time.perf_counter()
    for lo in range(0, len(reqs), batch):
        group = reqs[lo:lo + batch]
        toks = jnp.asarray(np.stack([r.prompt for r in group]))
        if jit_prefill:
            logits, _, cache = prefill_j(params, toks)
        else:
            logits, _, cache = transformer.forward(
                params, cfg, toks, return_cache=True,
                cache=transformer.init_decode_cache(cfg, len(group),
                                                    CAPACITY))
        cur = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        steps = max(r.max_new_tokens for r in group) - 1
        for t in range(PROMPT, PROMPT + steps):
            if jit_prefill:
                cur, cache = decode_g(params, cache, cur, jnp.int32(t))
            else:
                lg, cache = decode(params, cache, cur, t)
                cur = jnp.argmax(lg, -1).astype(jnp.int32)
        jax.block_until_ready(cur)
        useful += sum(r.max_new_tokens for r in group)
    return useful, time.perf_counter() - t0


def _engine_run(params, cfg, reqs, slots):
    eng = ServingEngine(params, cfg, slots=slots, capacity=CAPACITY,
                        buckets=(PROMPT,))
    t0 = time.perf_counter()
    results = eng.run(list(reqs))
    wall = time.perf_counter() - t0
    return results, sum(len(r.tokens) for r in results), wall


def main():
    fast = os.environ.get("REPRO_BENCH_FAST") == "1"
    cfg = _cfg()
    params = models.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    n_req = 8 if fast else 16
    slots = 4
    meta = dict(arch=cfg.name, backend="xla",
                devices=jax.device_count(), capacity=CAPACITY)
    reqs = _requests(n_req, rng)

    # warm every path (BOTH static variants use distinct jitted fns —
    # compiles must not land inside a measured wall)
    _static_loop(params, cfg, reqs[:slots], slots, jit_prefill=False)
    _static_loop(params, cfg, reqs[:slots], slots, jit_prefill=True)
    _engine_run(params, cfg, reqs[:slots], slots)

    useful, wall = _static_loop(params, cfg, reqs, slots, jit_prefill=False)
    base_tps = useful / wall
    emit("serving/static_loop_pr4", wall / useful * 1e6,
         f"tok/s={base_tps:.1f}", slots=slots, **meta)
    useful_d, wall_d = _static_loop(params, cfg, reqs, slots,
                                    jit_prefill=True)
    strong_tps = useful_d / wall_d
    emit("serving/static_loop_jit", wall_d / useful_d * 1e6,
         f"tok/s={strong_tps:.1f}", slots=slots, **meta)

    results, toks, ewall = _engine_run(params, cfg, reqs, slots)
    eng_tps = toks / ewall
    emit("serving/engine", ewall / toks * 1e6,
         f"tok/s={eng_tps:.1f};speedup={eng_tps / base_tps:.2f}x;"
         f"speedup_vs_jit={eng_tps / strong_tps:.2f}x",
         slots=slots, **meta)
    lats = sorted(r.latency for r in results)
    emit("serving/latency_p50", lats[len(lats) // 2] * 1e6,
         "per-request", slots=slots, **meta)
    emit("serving/latency_p99",
         lats[min(int(0.99 * len(lats)), len(lats) - 1)] * 1e6,
         "per-request", slots=slots, **meta)

    for s in ((2, 4) if fast else (1, 2, 4, 8)):
        _engine_run(params, cfg, reqs[:2], s)       # warm this slot count
        _, tk, w = _engine_run(params, cfg, _requests(n_req, rng), s)
        emit(f"serving/engine_slots{s}", w / tk * 1e6,
             f"tok/s={tk / w:.1f}", slots=s, **meta)

    if eng_tps < 2 * base_tps:
        print(f"# WARNING: engine speedup {eng_tps / base_tps:.2f}x < 2x "
              "over the static loop", flush=True)

    # ---- multi-tick dispatch cadence ---------------------------------
    tcfg = _tick_cfg()
    tparams = models.init(jax.random.PRNGKey(0), tcfg)
    tmeta = dict(arch=tcfg.name, backend="xla", devices=jax.device_count())
    measure, reps = (24, 2) if fast else (48, 3)
    k1_tps = None
    best_k4 = 0.0
    for k in TICKS:
        tps = _steady_tps(tparams, tcfg, ticks=k, measure=measure,
                          reps=reps)
        k1_tps = k1_tps or tps
        if k >= 4:
            best_k4 = max(best_k4, tps / k1_tps)
        emit(f"serving/engine_ticks{k}", 1e6 / tps,
             f"tok/s={tps:.1f};speedup_vs_k1={tps / k1_tps:.2f}x",
             slots=4, ticks=k, **tmeta)
    if best_k4 < 1.3:
        print(f"# WARNING: multi-tick K>=4 only {best_k4:.2f}x over K=1",
              flush=True)

    # ---- speculative decoding ----------------------------------------
    def _spec_reqs():
        r = np.random.default_rng(7)
        return [Request(prompt=r.integers(0, tcfg.vocab_size, size=PROMPT),
                        max_new_tokens=16 if fast else 32)
                for _ in range(8)]

    def _spec_run(**kw):
        eng = ServingEngine(tparams, tcfg, slots=4, capacity=CAPACITY,
                            buckets=(PROMPT,), **kw)
        eng.run(_spec_reqs()[:2])                       # warm compiles
        eng = ServingEngine(tparams, tcfg, slots=4, capacity=CAPACITY,
                            buckets=(PROMPT,), **kw)
        t0 = time.perf_counter()
        toks = sum(len(r.tokens) for r in eng.run(_spec_reqs()))
        return eng, toks / (time.perf_counter() - t0)

    _, plain_tps = _spec_run()
    dadv = models.init(jax.random.PRNGKey(9), tcfg)
    eng, tps = _spec_run(draft_params=dadv, draft_cfg=tcfg, spec_tokens=2)
    emit("serving/spec_adversarial_draft", 1e6 / tps,
         f"tok/s={tps:.1f};speedup={tps / plain_tps:.2f}x;"
         f"acceptance={eng.spec_accepted / eng.spec_proposed:.2f}",
         slots=4, draft_arch=tcfg.name, target_arch=tcfg.name,
         spec_tokens=2, **tmeta)

    # truncated-layer draft: the >1x configuration (docs/serving.md
    # break-even).  The draft is the target's OWN first layer (1/8 of
    # its per-tick cost); the target's later layers are damped toward
    # identity so draft and target argmax agree — random init has no
    # trained agreement, and damping stands in for the distillation a
    # real deployment buys.  What the row measures is the ENGINE
    # mechanics at that acceptance: one fused dispatch per ~(1+gamma*acc)
    # tokens vs one per token.
    from repro.serving.spec_decode import truncated_draft
    scfg = dataclasses.replace(
        reduced(ARCHS[ARCH], n_layers=8, d_model=128),
        vocab_size=256, kernels=KernelPolicy(attention="xla"))
    sparams = models.init(jax.random.PRNGKey(0), scfg)
    sparams = {**sparams, "blocks": tuple(
        jax.tree.map(lambda x: x.at[1:].multiply(0.05)
                     if (x.ndim >= 1 and x.shape[0] == 8) else x, bp)
        for bp in sparams["blocks"])}
    gamma = 8

    def _trunc_reqs():
        # steady decode is what spec accelerates: long generations, so
        # per-round savings dominate the prefill/compile share
        r = np.random.default_rng(7)
        return [Request(prompt=r.integers(0, scfg.vocab_size, size=PROMPT),
                        max_new_tokens=24 if fast else 48)
                for _ in range(8)]

    def _trunc_run(**kw):
        best, eng = 0.0, None
        for _ in range(2 if fast else 3):
            eng = ServingEngine(sparams, scfg, slots=4, capacity=CAPACITY,
                                buckets=(PROMPT,), **kw)
            t0 = time.perf_counter()
            toks = sum(len(r.tokens) for r in eng.run(_trunc_reqs()))
            best = max(best, toks / (time.perf_counter() - t0))
        return eng, best

    _, strunc_base = _trunc_run()
    dcfg, dparams = truncated_draft(scfg, sparams, 1)
    eng, tps = _trunc_run(draft_params=dparams, draft_cfg=dcfg,
                          spec_tokens=gamma)
    speedup = tps / strunc_base
    emit("serving/spec_truncated_draft", 1e6 / tps,
         f"tok/s={tps:.1f};speedup={speedup:.2f}x;"
         f"acceptance={eng.spec_accepted / eng.spec_proposed:.2f}",
         slots=4, draft_arch=dcfg.name, target_arch=scfg.name,
         spec_tokens=gamma, draft_layers=1, target_layers=8,
         agreement="tail-damped-0.05", **tmeta)
    if speedup <= 1.0:
        print(f"# WARNING: truncated-draft spec only {speedup:.2f}x",
              flush=True)

    # ---- shared-prefix block capacity --------------------------------
    burst_prompt = list(range(1, 40))           # 2 full 16-blocks + tail
    def _burst(dedup):                                      # noqa: E306
        eng = ServingEngine(tparams, tcfg, slots=4, capacity=CAPACITY,
                            buckets=(CAPACITY,), block_size=16,
                            prefix_dedup=dedup)
        eng.run([Request(prompt=burst_prompt, max_new_tokens=8)
                 for _ in range(4)])
        return eng.block_mgr
    shared, private = _burst(True), _burst(False)
    emit("serving/blocks_peak_shared", float(shared.peak),
         f"peak_private={private.peak};prefills_skipped="
         f"{shared.prefills_skipped};capacity_x="
         f"{private.peak / shared.peak:.2f}",
         slots=4, block_size=16, **tmeta)


if __name__ == "__main__":
    from benchmarks.common import write_bench_json
    main()
    write_bench_json(partial=True)
