"""Paper Table 1's conv-backend axis (cuda-convnet vs cuDNN R1/R2), on TPU
terms: XLA direct conv vs the Pallas im2col+MXU kernel (interpret mode on
CPU — correctness-equivalent, timing indicative only), plus the other
Pallas kernels vs their oracles, plus the LM-zoo backend sweep: full
training steps (fwd+bwd+update) per arch under ``KernelPolicy`` xla vs
pallas — the end-to-end form of the registry's promise that every model
family now trains on the Pallas path.  ``REPRO_BENCH_FAST=1`` trims the
LM sweep to one arch."""
from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels.common import KernelPolicy
from repro.kernels.conv2d import ops as conv_ops, ref as conv_ref
from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.rwkv6 import ref as wkv_ref
from repro.kernels.rwkv6.rwkv6 import wkv_pallas

# one arch per kernel family the policy switches: dense->flash,
# ssm->wkv6, hybrid->rglru(+local attention)
LM_ARCHS = ("olmo-1b", "rwkv6-7b", "recurrentgemma-9b")


def lm_backend_sweep():
    from repro import models
    from repro.configs import ARCHS, reduced
    from repro.core import (init_param_avg_state, make_param_avg_step,
                            reshape_for_replicas)
    from repro.optim import schedules
    from repro.optim.optimizers import sgd_momentum

    archs = LM_ARCHS[:1] if os.environ.get("REPRO_BENCH_FAST") == "1" \
        else LM_ARCHS
    rng = jax.random.PRNGKey(0)
    for arch in archs:
        base = reduced(ARCHS[arch], n_layers=1, d_model=128)
        batch = reshape_for_replicas({
            "tokens": jax.random.randint(rng, (2, 64), 0, base.vocab_size),
            "labels": jax.random.randint(rng, (2, 64), 0, base.vocab_size),
        }, 1)
        for backend in ("xla", "pallas"):
            cfg = dataclasses.replace(
                base, kernels=KernelPolicy(backend=backend))
            opt = sgd_momentum()
            state = init_param_avg_state(
                rng, lambda r: models.init(r, cfg), opt, 1)
            step = jax.jit(make_param_avg_step(
                lambda p, b: models.loss_fn(p, cfg, b), opt,
                schedules.constant(1e-2)))

            def run(state=state, batch=batch, step=step):
                new_state, loss = step(state, batch)
                return loss
            emit(f"lm/{arch}/{backend}", time_fn(run, warmup=1, iters=3),
                 f"train step end-to-end; policy backend={backend}"
                 + (" (interpret)" if backend == "pallas"
                    and jax.default_backend() != "tpu" else ""))


def main():
    ks = jax.random.split(jax.random.PRNGKey(0), 5)

    # conv1 of (reduced) AlexNet — both pallas pipelines vs the XLA direct
    # conv (Table 1's backend axis: cuda-convnet vs cuDNN R1/R2)
    x = jax.random.normal(ks[0], (8, 64, 64, 3))
    w = jax.random.normal(ks[1], (7, 7, 3, 16)) * 0.1
    f_xla = jax.jit(lambda x, w: conv_ref.conv2d_ref(x, w, 2, 0))
    emit("conv/xla_direct", time_fn(f_xla, x, w), "backend=lax.conv")
    f_fused = jax.jit(lambda x, w: conv_ops.conv2d_fused(
        x, w, stride=2, padding=0))
    emit("conv/pallas_fused", time_fn(f_fused, x, w),
         "backend=pallas implicit-GEMM (interpret on cpu)")
    f_pal = jax.jit(lambda x, w: conv_ops.conv2d_im2col(
        x, w, stride=2, padding=0))
    emit("conv/pallas_im2col_ref", time_fn(f_pal, x, w),
         "backend=pallas two-stage ref (interpret on cpu)")

    # attention S=256
    q = jax.random.normal(ks[2], (2, 256, 2, 2, 64))
    k = jax.random.normal(ks[3], (2, 256, 2, 64))
    v = jax.random.normal(ks[4], (2, 256, 2, 64))
    f_ref = jax.jit(lambda q, k, v: fa_ref.attention_ref(
        q, k, v, causal=True, scale=0.125))
    emit("attention/xla_ref", time_fn(f_ref, q, k, v), "")
    f_fa = jax.jit(lambda q, k, v: fa_ops.flash_attention(
        q, k, v, causal=True, scale=0.125, bq=64, bk=64))
    emit("attention/pallas_flash", time_fn(f_fa, q, k, v),
         "backend=pallas(interpret)")

    # rwkv6 T=256
    r = jax.random.normal(ks[0], (1, 256, 2, 32))
    kk = jax.random.normal(ks[1], (1, 256, 2, 32))
    vv = jax.random.normal(ks[2], (1, 256, 2, 32))
    ww = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (1, 256, 2, 32)) * 0.5))
    u = jax.random.normal(ks[4], (2, 32)) * 0.5
    f_seq = jax.jit(lambda *a: wkv_ref.wkv_sequential(*a)[0])
    emit("wkv6/sequential_scan", time_fn(f_seq, r, kk, vv, ww, u), "")
    f_chk = jax.jit(lambda *a: wkv_ref.wkv_chunked(*a, chunk=64)[0])
    emit("wkv6/chunked_jnp", time_fn(f_chk, r, kk, vv, ww, u), "")
    f_pl = jax.jit(lambda *a: wkv_pallas(*a, chunk=64, interpret=True))
    emit("wkv6/pallas_chunked", time_fn(f_pl, r, kk, vv, ww, u),
         "backend=pallas(interpret)")

    # LM zoo: whole training steps, xla vs pallas KernelPolicy
    lm_backend_sweep()


if __name__ == "__main__":
    main()
