"""Paper Table 1's conv-backend axis (cuda-convnet vs cuDNN R1/R2), on TPU
terms: XLA direct conv vs the Pallas im2col+MXU kernel (interpret mode on
CPU — correctness-equivalent, timing indicative only), plus the other two
Pallas kernels vs their oracles."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels.conv2d import ops as conv_ops, ref as conv_ref
from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.rwkv6 import ref as wkv_ref
from repro.kernels.rwkv6.rwkv6 import wkv_pallas


def main():
    ks = jax.random.split(jax.random.PRNGKey(0), 5)

    # conv1 of (reduced) AlexNet — both pallas pipelines vs the XLA direct
    # conv (Table 1's backend axis: cuda-convnet vs cuDNN R1/R2)
    x = jax.random.normal(ks[0], (8, 64, 64, 3))
    w = jax.random.normal(ks[1], (7, 7, 3, 16)) * 0.1
    f_xla = jax.jit(lambda x, w: conv_ref.conv2d_ref(x, w, 2, 0))
    emit("conv/xla_direct", time_fn(f_xla, x, w), "backend=lax.conv")
    f_fused = jax.jit(lambda x, w: conv_ops.conv2d_fused(
        x, w, stride=2, padding=0))
    emit("conv/pallas_fused", time_fn(f_fused, x, w),
         "backend=pallas implicit-GEMM (interpret on cpu)")
    f_pal = jax.jit(lambda x, w: conv_ops.conv2d_im2col(
        x, w, stride=2, padding=0))
    emit("conv/pallas_im2col_ref", time_fn(f_pal, x, w),
         "backend=pallas two-stage ref (interpret on cpu)")

    # attention S=256
    q = jax.random.normal(ks[2], (2, 256, 2, 2, 64))
    k = jax.random.normal(ks[3], (2, 256, 2, 64))
    v = jax.random.normal(ks[4], (2, 256, 2, 64))
    f_ref = jax.jit(lambda q, k, v: fa_ref.attention_ref(
        q, k, v, causal=True, scale=0.125))
    emit("attention/xla_ref", time_fn(f_ref, q, k, v), "")
    f_fa = jax.jit(lambda q, k, v: fa_ops.flash_attention(
        q, k, v, causal=True, scale=0.125, bq=64, bk=64))
    emit("attention/pallas_flash", time_fn(f_fa, q, k, v),
         "backend=pallas(interpret)")

    # rwkv6 T=256
    r = jax.random.normal(ks[0], (1, 256, 2, 32))
    kk = jax.random.normal(ks[1], (1, 256, 2, 32))
    vv = jax.random.normal(ks[2], (1, 256, 2, 32))
    ww = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (1, 256, 2, 32)) * 0.5))
    u = jax.random.normal(ks[4], (2, 32)) * 0.5
    f_seq = jax.jit(lambda *a: wkv_ref.wkv_sequential(*a)[0])
    emit("wkv6/sequential_scan", time_fn(f_seq, r, kk, vv, ww, u), "")
    f_chk = jax.jit(lambda *a: wkv_ref.wkv_chunked(*a, chunk=64)[0])
    emit("wkv6/chunked_jnp", time_fn(f_chk, r, kk, vv, ww, u), "")
    f_pl = jax.jit(lambda *a: wkv_pallas(*a, chunk=64, interpret=True))
    emit("wkv6/pallas_chunked", time_fn(f_pl, r, kk, vv, ww, u),
         "backend=pallas(interpret)")


if __name__ == "__main__":
    main()
