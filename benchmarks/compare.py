"""Diff the two newest BENCH_<date>.json trajectory files.

    PYTHONPATH=src python -m benchmarks.compare [--threshold 0.10]
    PYTHONPATH=src python -m benchmarks.compare OLD.json NEW.json
    PYTHONPATH=src python -m benchmarks.compare --only serving/

Rows are matched by name; each one reports the us_per_call ratio
new/old.  Rows slower by more than ``--threshold`` (default 10%) are
flagged as regressions and the exit code is 1 — the same contract the
bench suites themselves use, applied across PRs instead of within one
run.  Rows present in only ONE file are reported individually AND in
the summary: added rows are informational (suites grow every PR), and
removed rows fail the diff under ``--fail-removed`` — a retired row is
a retired CLAIM, so CI gates force the removal to be deliberate.
Otherwise the flags are advisory (absolute times on shared CI hosts
drift, which is why the threshold is generous) — a flagged row means
"explain or re-measure", not "revert".

``.partial.json`` files (fast/--only runs) are skipped when globbing:
they are subsets measured under different iteration counts, so ratios
against them are meaningless.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

HERE = os.path.dirname(os.path.abspath(__file__))


def load_rows(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    rows = payload.get("rows", [])
    by = {}
    for r in rows:
        if r["name"] in by:
            print(f"# WARNING: {os.path.basename(path)} has duplicate row "
                  f"{r['name']!r} — keeping the last", file=sys.stderr)
        by[r["name"]] = r
    return by


def newest_pair() -> tuple:
    files = sorted(f for f in glob.glob(os.path.join(HERE, "BENCH_*.json"))
                   if not f.endswith(".partial.json"))
    if len(files) < 2:
        sys.exit("need two BENCH_<date>.json files to compare; found "
                 f"{[os.path.basename(f) for f in files]}")
    return files[-2], files[-1]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*",
                    help="OLD.json NEW.json (default: two newest)")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="flag rows slower by more than this fraction")
    ap.add_argument("--fail-removed", action="store_true",
                    help="exit 1 when a row present in OLD is missing "
                    "from NEW (a retired suite must be retired on "
                    "purpose, not lost)")
    ap.add_argument("--only", default=None, metavar="PREFIX[,PREFIX...]",
                    help="restrict the diff to rows whose name starts "
                    "with one of the given prefixes (e.g. "
                    "'serving/engine,serving/latency' to gate the "
                    "engine rows but not the eager static-loop "
                    "baseline, whose wall time is host noise)")
    args = ap.parse_args()
    if args.files and len(args.files) != 2:
        ap.error("pass exactly two files (or none for the newest pair)")
    old_path, new_path = args.files or newest_pair()
    old, new = load_rows(old_path), load_rows(new_path)
    if args.only:
        pre = tuple(args.only.split(","))
        old = {k: v for k, v in old.items() if k.startswith(pre)}
        new = {k: v for k, v in new.items() if k.startswith(pre)}
    print(f"# old: {os.path.basename(old_path)}  ({len(old)} rows)")
    print(f"# new: {os.path.basename(new_path)}  ({len(new)} rows)")

    regressions = []
    print(f"{'row':44s} {'old_us':>12s} {'new_us':>12s} {'ratio':>7s}")
    for name in sorted(old.keys() & new.keys()):
        o, n = old[name]["us_per_call"], new[name]["us_per_call"]
        ratio = n / o if o else float("inf")
        mark = ""
        if ratio > 1 + args.threshold:
            mark = "  <-- REGRESSION"
            regressions.append((name, ratio))
        print(f"{name:44s} {o:12.1f} {n:12.1f} {ratio:6.2f}x{mark}")
    added = sorted(new.keys() - old.keys())
    removed = sorted(old.keys() - new.keys())
    for name in added:
        print(f"{name:44s} {'-':>12s} {new[name]['us_per_call']:12.1f}   new")
    for name in removed:
        print(f"{name:44s} {old[name]['us_per_call']:12.1f} {'-':>12s}   removed")
    print(f"\n# {len(old.keys() & new.keys())} common, {len(added)} added, "
          f"{len(removed)} removed")

    failed = False
    if regressions:
        print(f"{len(regressions)} row(s) regressed more than "
              f"{args.threshold:.0%}:")
        for name, ratio in sorted(regressions, key=lambda r: -r[1]):
            print(f"  {name}  {ratio:.2f}x")
        failed = True
    if removed and args.fail_removed:
        print(f"{len(removed)} row(s) removed (--fail-removed):")
        for name in removed:
            print(f"  {name}")
        failed = True
    if failed:
        sys.exit(1)
    print(f"no regressions above {args.threshold:.0%}")


if __name__ == "__main__":
    main()
