"""NumericsPolicy benchmark: what each precision axis buys (or costs).

Two families of rows (docs/numerics.md):

  numerics/train_step/<arch>/<policy>
      one full param-avg train step (fwd+bwd+update+exchange) under the
      default fp32 policy vs bf16 compute with fp32 master weights +
      dynamic loss scaling — the mixed-precision tax/win is HOST
      DEPENDENT (CPU hosts emulate bf16, accelerators win big), so the
      trajectory row is what matters, not the absolute sign.
  numerics/kv_bytes_per_slot/<dtype>  and  numerics/serve_tps/...
      the int8 KV cache's capacity claim, measured not asserted from
      theory: bytes of decode state per slot at fixed ring capacity,
      fp32 vs int8 (scales included).  The suite HARD-ASSERTS the
      headline — int8 fits >= 2x the slots of fp32 in equal ring bytes —
      and then serves the same request mix through the engine at equal
      slot counts to show the quality/throughput side.

Rows carry arch/numerics/kv metadata into BENCH_<date>.json.
"""
from __future__ import annotations

import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro import models
from repro.configs import ALEXNET_SMOKE, ARCHS, reduced
from repro.core import (init_param_avg_state, make_param_avg_step,
                        reshape_for_replicas)
from repro.models import alexnet as alexnet_mod
from repro.numerics import get_policy
from repro.optim import schedules
from repro.optim.optimizers import for_numerics, sgd_momentum
from repro.serving import Request, ServingEngine

CAPACITY = 64
REPLICAS = 2


def _lm_cfg():
    return reduced(ARCHS["olmo-1b"], n_layers=2, d_model=256)


def _train_case(arch):
    """(cfg, loss_fn, batch) for one arch of the step-time sweep."""
    if arch == "alexnet":
        cfg = ALEXNET_SMOKE
        rng = np.random.default_rng(0)
        batch = {"images": jnp.asarray(rng.normal(size=(
            16, cfg.image_size, cfg.image_size, cfg.in_channels)),
            jnp.float32),
            "labels": jnp.asarray(rng.integers(0, cfg.n_classes, 16))}
        loss = lambda p, b, c=None: alexnet_mod.loss_fn(  # noqa: E731
            p, c, b["images"], b["labels"])
        init = alexnet_mod.init
    else:
        cfg = _lm_cfg()
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 64)),
                           jnp.int32)
        batch = {"tokens": toks, "labels": toks}
        loss = lambda p, b, c=None: models.loss_fn(p, c, b)  # noqa: E731
        init = models.init
    return cfg, init, loss, batch


def _step_time(arch, policy_name):
    cfg, init, loss, batch = _train_case(arch)
    npol = get_policy(policy_name)
    cfg = dataclasses.replace(cfg, numerics=npol)
    opt = for_numerics(sgd_momentum(), npol)
    state = init_param_avg_state(jax.random.PRNGKey(0),
                                 lambda r: init(r, cfg), opt, REPLICAS,
                                 numerics=npol)
    step = jax.jit(make_param_avg_step(
        lambda p, b: loss(p, b, cfg), opt, schedules.constant(0.01),
        numerics=npol))
    rb = reshape_for_replicas(batch, REPLICAS)

    def one(s):
        s, _ = step(s, rb)
        return s

    # time_fn's warmup covers the compile; state threads through so the
    # donated buffers never alias a dead value
    return time_fn(one, state, warmup=2, iters=5)


def _cache_bytes(cfg, kv):
    c = dataclasses.replace(
        cfg, numerics=dataclasses.replace(cfg.numerics, kv_cache_dtype=kv))
    state = models.init_decode_state(c, 1, CAPACITY)
    return sum(leaf.nbytes for leaf in jax.tree.leaves(state.cache))


def _serve(cfg, params, kv, slots, reqs):
    c = dataclasses.replace(
        cfg, numerics=dataclasses.replace(cfg.numerics, kv_cache_dtype=kv))
    eng = ServingEngine(params, c, slots=slots, capacity=CAPACITY,
                        buckets=(8,))
    t0 = time.perf_counter()
    results = eng.run(list(reqs))
    wall = time.perf_counter() - t0
    return sum(len(r.tokens) for r in results), wall


def main():
    fast = os.environ.get("REPRO_BENCH_FAST") == "1"

    # --- train-step time: fp32 vs bf16+master, per arch -----------------
    for arch in (("alexnet",) if fast else ("alexnet", "olmo-1b")):
        base = _step_time(arch, "fp32")
        emit(f"numerics/train_step/{arch}/fp32", base, "baseline",
             arch=arch, numerics="fp32", replicas=REPLICAS)
        mixed = _step_time(arch, "bf16")
        emit(f"numerics/train_step/{arch}/bf16_master", mixed,
             f"vs_fp32={mixed / base:.2f}x",
             arch=arch, numerics="bf16", replicas=REPLICAS)

    # --- int8 KV: bytes per slot + the >=2x capacity claim --------------
    cfg = _lm_cfg()
    b_fp32 = _cache_bytes(cfg, "fp32")
    b_int8 = _cache_bytes(cfg, "int8")
    ratio = b_fp32 / b_int8
    emit("numerics/kv_bytes_per_slot/fp32", b_fp32, "bytes",
         arch=cfg.name, kv="fp32", capacity=CAPACITY)
    emit("numerics/kv_bytes_per_slot/int8", b_int8,
         f"slots_at_equal_bytes={ratio:.2f}x",
         arch=cfg.name, kv="int8", capacity=CAPACITY)
    # the headline claim, measured on the real state pytree (scales and
    # positions included) — fail the suite if quantization ever bloats
    assert ratio >= 2.0, (
        f"int8 KV fits only {ratio:.2f}x the slots of fp32 at equal ring "
        f"bytes (expected >= 2x): fp32={b_fp32}B int8={b_int8}B per slot")

    # --- serving throughput at equal slot counts, fp32 vs int8 KV -------
    params = models.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    n_req = 8 if fast else 16
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=8),
                    max_new_tokens=8) for _ in range(n_req)]
    for kv in ("fp32", "int8"):
        for slots in ((4,) if fast else (2, 4)):
            _serve(cfg, params, kv, slots, reqs[:2])      # warm
            toks, wall = _serve(cfg, params, kv, slots, reqs)
            emit(f"numerics/serve_tps/{kv}/slots{slots}",
                 wall / toks * 1e6, f"tok/s={toks / wall:.1f}",
                 arch=cfg.name, kv=kv, slots=slots, capacity=CAPACITY)


if __name__ == "__main__":
    from benchmarks.common import write_bench_json
    main()
    write_bench_json(partial=True)
