"""Paper Fig. 2 analogue: weight exchange-and-average strategies.

Compares all_reduce / ring / pairwise exchange of an AlexNet-sized pytree
across 8 host-device replicas: wall time + the collective ops each lowers
to (from compiled HLO) — the communication-schedule axis the paper explored
with P2P copies on a PCIe switch."""
from __future__ import annotations

from benchmarks.common import emit, run_subprocess_bench

CHILD = """
import time, re, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import exchange_average
from repro.models import alexnet
from repro.configs import ALEXNET_SMOKE

R = 8
mesh = jax.make_mesh((R,), ("data",))
params = alexnet.init(jax.random.PRNGKey(0), ALEXNET_SMOKE)
rep = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (R,) + x.shape), params)
sh = jax.tree.map(lambda x: NamedSharding(mesh, P(*("data",) + (None,) * (x.ndim - 1))), rep)
rep = jax.device_put(rep, sh)
n_bytes = sum(x.nbytes for x in jax.tree.leaves(rep))
for strat in ("all_reduce", "ring", "pairwise"):
    f = jax.jit(lambda t, s=strat: exchange_average(t, s), in_shardings=(sh,), out_shardings=sh)
    txt = f.lower(rep).compile().as_text()
    ops = {k: len(re.findall(k + r"(?:-start)?\\(", txt))
           for k in ("all-reduce", "collective-permute", "all-gather", "all-to-all")}
    jax.block_until_ready(f(rep))
    t0 = time.time()
    for _ in range(10):
        out = f(rep)
    jax.block_until_ready(out)
    us = (time.time() - t0) / 10 * 1e6
    opstr = ";".join(f"{k}:{v}" for k, v in ops.items() if v)
    print(f"RESULT,{strat},{us:.1f},bytes={n_bytes};{opstr}")
"""


def main():
    out = run_subprocess_bench(CHILD, devices=8)
    for line in out.splitlines():
        if line.startswith("RESULT"):
            _, strat, us, derived = line.split(",", 3)
            emit(f"exchange/{strat}", float(us), derived)


if __name__ == "__main__":
    main()
