"""Paper Fig. 2 analogue: weight exchange-and-average strategies.

Three tables:

1. bare exchange of an AlexNet-sized pytree per strategy — wall time + the
   collective ops each lowers to (from compiled HLO), the communication-
   schedule axis the paper explored with P2P copies on a PCIe switch;
2. full mesh-engine train step (shard_map, AlexNet-smoke) per strategy —
   end-to-end step time with the exchange on the critical path, the
   Table 1-style number — plus the (delay, compression) grid on the
   all_reduce strategy: delay=1 (one-step-stale overlapped exchange) x
   {none, bf16, topk} wire compression;
3. ``exchange_scaling`` — the replica-scaling curve this PR exists for:
   reference engine, sequential per-replica execution
   (``replica_exec="scan"``), fixed global batch, R in {1, 2, 4}.  At
   fixed global batch each replica's fwd/bwd runs at batch G/R, whose
   smaller working set is more cache-resident — the host analogue of
   the paper's multi-GPU scaling.  Timing is min-of-reps with the
   configs interleaved round-robin inside each rep, so background-load
   drift hits every config equally instead of whichever one ran during
   a busy window.

Tables 1-2 run over ``REPRO_DEVICES`` host-device replicas (default 4);
table 3 runs the reference engine in a single-device child.

    REPRO_DEVICES=4 PYTHONPATH=src python -m benchmarks.run \
        --only exchange_strategies
"""
from __future__ import annotations

import os

from benchmarks.common import emit, run_subprocess_bench

CHILD_EXCHANGE = """
import time, re, jax, jax.numpy as jnp
from repro.core import Exchanger, replica_specs
from repro.core.param_avg import shard_map
from repro.launch.mesh import make_replica_mesh
from repro.models import alexnet
from repro.configs import ALEXNET_SMOKE

R = jax.device_count()
mesh = make_replica_mesh(R)
params = alexnet.init(jax.random.PRNGKey(0), ALEXNET_SMOKE)
rep = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (R,) + x.shape), params)
spec = replica_specs(rep, "data")
from repro.sharding.specs import replica_sharding
rep = jax.device_put(rep, replica_sharding(rep, mesh, replica_axes=("data",)))
n_bytes = sum(x.nbytes for x in jax.tree.leaves(rep))
pow2 = R & (R - 1) == 0
strats = ("all_reduce", "ring", "pairwise") if pow2 else \
    ("all_reduce", "ring")
if not pow2:
    print(f"# pairwise skipped: needs power-of-two replicas, got {R}")
for strat in strats:
    ex = Exchanger(strat, axis="data")
    f = jax.jit(shard_map(lambda t: ex.average(t), mesh=mesh,
                          in_specs=(spec,), out_specs=spec, check_rep=False))
    txt = f.lower(rep).compile().as_text()
    ops = {k: len(re.findall(k + r"(?:-start)?\\(", txt))
           for k in ("all-reduce", "collective-permute", "all-gather", "all-to-all")}
    want = ex.expected_collective
    assert ops.get(want), (strat, want, ops)
    jax.block_until_ready(f(rep))
    t0 = time.time()
    for _ in range(10):
        out = f(rep)
    jax.block_until_ready(out)
    us = (time.time() - t0) / 10 * 1e6
    opstr = ";".join(f"{k}:{v}" for k, v in ops.items() if v)
    print(f"RESULT,{strat},{us:.1f},replicas={R};bytes={n_bytes};{opstr}")
"""

CHILD_STEP = """
import time, jax, jax.numpy as jnp, numpy as np
from repro.configs import ALEXNET_SMOKE
from repro.core import (ExchangeConfig, init_param_avg_state,
                        make_mesh_param_avg_step, reshape_for_replicas)
from repro.launch.mesh import make_replica_mesh
from repro.models import alexnet
from repro.optim import schedules
from repro.optim.optimizers import sgd_momentum
from repro.sharding.specs import replica_sharding

R = jax.device_count()
cfg = ALEXNET_SMOKE
mesh = make_replica_mesh(R)
opt = sgd_momentum()
loss = lambda p, b: alexnet.loss_fn(p, cfg, b["images"], b["labels"])
rng = np.random.default_rng(0)
batch = reshape_for_replicas(
    {"images": jnp.asarray(rng.normal(size=(4 * R, cfg.image_size,
                                            cfg.image_size, 3)), jnp.float32),
     "labels": jnp.asarray(rng.integers(0, cfg.n_classes, 4 * R), jnp.int32)},
    R)
strats = ("all_reduce", "ring", "pairwise", "none") if R & (R - 1) == 0 \
    else ("all_reduce", "ring", "none")
grid = [(s, ExchangeConfig(strategy=s)) for s in strats]
# the overlapped/compressed variants on the all_reduce strategy
grid += [("all_reduce/delay1/" + c.compression, c) for c in (
    ExchangeConfig(delay=1),
    ExchangeConfig(delay=1, compression="bf16"),
    ExchangeConfig(delay=1, compression="topk", topk_frac=0.01))]
for name, exch in grid:
    state = init_param_avg_state(jax.random.PRNGKey(0),
                                 lambda r: alexnet.init(r, cfg), opt, R,
                                 exchange=exch)
    state = jax.device_put(state, replica_sharding(state, mesh,
                                                   replica_axes=("data",)))
    b = jax.device_put(batch, replica_sharding(batch, mesh,
                                               replica_axes=("data",)))
    step = jax.jit(make_mesh_param_avg_step(loss, opt,
                                            schedules.constant(0.01),
                                            mesh=mesh, strategy=exch,
                                            replica_axes=("data",)),
                   donate_argnums=0)   # state updates in place
    state, _ = step(state, b)          # compile + warm
    jax.block_until_ready(state)
    t0 = time.time()
    for _ in range(5):
        state, l = step(state, b)
    jax.block_until_ready(state)
    us = (time.time() - t0) / 5 * 1e6
    print(f"STEP,{name},{us:.1f},replicas={R};engine=mesh")
"""

CHILD_SCALING = """
import os, time, jax, numpy as np
from repro.configs import ALEXNET_SMOKE
from repro.core import (ExchangeConfig, init_param_avg_state,
                        make_param_avg_step, reshape_for_replicas)
from repro.models import alexnet
from repro.optim.optimizers import sgd_momentum

FAST = os.environ.get("REPRO_BENCH_FAST") == "1"
G = 128 if FAST else 512          # global batch, fixed across R
REPS, ITERS = (3, 1) if FAST else (8, 2)
R_GRID = (1, 4) if FAST else (1, 2, 4)
COMPS = ("none",) if FAST else ("none", "bf16", "topk")

cfg = ALEXNET_SMOKE
opt = sgd_momentum()
loss_fn = lambda p, b: alexnet.loss_fn(p, cfg, b["images"], b["labels"])
rng = np.random.default_rng(0)

grid = [("delay0/none", 1, ExchangeConfig())]
for comp in COMPS:
    exch = ExchangeConfig(delay=1, compression=comp,
                          topk_frac=0.01 if comp == "topk" else 1.0)
    for R in R_GRID:
        grid.append((f"delay1/{comp}", R, exch))

runs = []
for desc, R, exch in grid:
    host = {"images": rng.normal(size=(G, cfg.image_size, cfg.image_size,
                                       3)).astype(np.float32),
            "labels": rng.integers(0, cfg.n_classes, G).astype(np.int32)}
    state = init_param_avg_state(jax.random.PRNGKey(0),
                                 lambda r: alexnet.init(r, cfg), opt, R,
                                 exchange=exch)
    batch = jax.device_put(reshape_for_replicas(host, R))
    step = jax.jit(make_param_avg_step(loss_fn, opt, lambda s: 0.01,
                                       strategy=exch, replica_exec="scan"),
                   donate_argnums=0)
    state, _ = step(state, batch)          # compile + warm
    jax.block_until_ready(state.params)
    runs.append([desc, R, step, state, batch, []])
# interleaved min-of-reps (see module docstring)
for rep in range(REPS):
    for r in runs:
        t0 = time.perf_counter()
        for _ in range(ITERS):
            r[3], l = r[2](r[3], r[4])
        jax.block_until_ready(r[3].params)
        r[5].append((time.perf_counter() - t0) / ITERS)
base = min(runs[0][5])
for desc, R, _, _, _, ts in runs:
    t = min(ts)
    print(f"SCALE,{desc}/{R}rep,{t * 1e6:.1f},"
          f"speedup_vs_sync_1rep={base / t:.3f}x;G={G};exec=scan")
"""


def main():
    devices = int(os.environ.get("REPRO_DEVICES", "4"))
    out = run_subprocess_bench(CHILD_EXCHANGE, devices=devices)
    for line in out.splitlines():
        if line.startswith("#"):
            print(line, flush=True)
        elif line.startswith("RESULT"):
            _, strat, us, derived = line.split(",", 3)
            emit(f"exchange/{strat}", float(us), derived)
    out = run_subprocess_bench(CHILD_STEP, devices=devices)
    rows = []
    for line in out.splitlines():
        if line.startswith("STEP"):
            _, strat, us, derived = line.split(",", 3)
            emit(f"exchange_step/{strat}", float(us), derived)
            rows.append((strat, float(us)))
    if rows:                      # human-readable per-strategy table
        base = dict(rows).get("none")
        print("# strategy                     step_us  overhead_vs_none")
        for strat, us in rows:
            ovh = f"{us - base:+.1f}us" if base else "n/a"
            print(f"# {strat:28s} {us:9.1f}  {ovh}")
    # replica-scaling curve (single-device child; the reference engine
    # lays replicas on a leading axis, no fake XLA devices needed)
    out = run_subprocess_bench(CHILD_SCALING, devices=1, timeout=900)
    for line in out.splitlines():
        if line.startswith("SCALE"):
            _, name, us, derived = line.split(",", 3)
            emit(f"exchange_scaling/{name}", float(us), derived)


if __name__ == "__main__":
    main()
