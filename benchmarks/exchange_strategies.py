"""Paper Fig. 2 analogue: weight exchange-and-average strategies.

Two tables over ``REPRO_DEVICES`` host-device replicas (default 4):

1. bare exchange of an AlexNet-sized pytree per strategy — wall time + the
   collective ops each lowers to (from compiled HLO), the communication-
   schedule axis the paper explored with P2P copies on a PCIe switch;
2. full mesh-engine train step (shard_map, AlexNet-smoke) per strategy —
   end-to-end step time with the exchange on the critical path, the
   Table 1-style number.

    REPRO_DEVICES=4 PYTHONPATH=src python -m benchmarks.run \
        --only exchange_strategies
"""
from __future__ import annotations

import os

from benchmarks.common import emit, run_subprocess_bench

CHILD_EXCHANGE = """
import time, re, jax, jax.numpy as jnp
from repro.core import Exchanger, replica_specs
from repro.core.param_avg import shard_map
from repro.launch.mesh import make_replica_mesh
from repro.models import alexnet
from repro.configs import ALEXNET_SMOKE

R = jax.device_count()
mesh = make_replica_mesh(R)
params = alexnet.init(jax.random.PRNGKey(0), ALEXNET_SMOKE)
rep = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (R,) + x.shape), params)
spec = replica_specs(rep, "data")
from repro.sharding.specs import replica_sharding
rep = jax.device_put(rep, replica_sharding(rep, mesh, replica_axes=("data",)))
n_bytes = sum(x.nbytes for x in jax.tree.leaves(rep))
pow2 = R & (R - 1) == 0
strats = ("all_reduce", "ring", "pairwise") if pow2 else \
    ("all_reduce", "ring")
if not pow2:
    print(f"# pairwise skipped: needs power-of-two replicas, got {R}")
for strat in strats:
    ex = Exchanger(strat, axis="data")
    f = jax.jit(shard_map(lambda t: ex.average(t), mesh=mesh,
                          in_specs=(spec,), out_specs=spec, check_rep=False))
    txt = f.lower(rep).compile().as_text()
    ops = {k: len(re.findall(k + r"(?:-start)?\\(", txt))
           for k in ("all-reduce", "collective-permute", "all-gather", "all-to-all")}
    want = ex.expected_collective
    assert ops.get(want), (strat, want, ops)
    jax.block_until_ready(f(rep))
    t0 = time.time()
    for _ in range(10):
        out = f(rep)
    jax.block_until_ready(out)
    us = (time.time() - t0) / 10 * 1e6
    opstr = ";".join(f"{k}:{v}" for k, v in ops.items() if v)
    print(f"RESULT,{strat},{us:.1f},replicas={R};bytes={n_bytes};{opstr}")
"""

CHILD_STEP = """
import time, jax, jax.numpy as jnp, numpy as np
from repro.configs import ALEXNET_SMOKE
from repro.core import (init_param_avg_state, make_mesh_param_avg_step,
                        reshape_for_replicas)
from repro.launch.mesh import make_replica_mesh
from repro.models import alexnet
from repro.optim import schedules
from repro.optim.optimizers import sgd_momentum
from repro.sharding.specs import replica_sharding

R = jax.device_count()
cfg = ALEXNET_SMOKE
mesh = make_replica_mesh(R)
opt = sgd_momentum()
loss = lambda p, b: alexnet.loss_fn(p, cfg, b["images"], b["labels"])
rng = np.random.default_rng(0)
batch = reshape_for_replicas(
    {"images": jnp.asarray(rng.normal(size=(4 * R, cfg.image_size,
                                            cfg.image_size, 3)), jnp.float32),
     "labels": jnp.asarray(rng.integers(0, cfg.n_classes, 4 * R), jnp.int32)},
    R)
strats = ("all_reduce", "ring", "pairwise", "none") if R & (R - 1) == 0 \
    else ("all_reduce", "ring", "none")
for strat in strats:
    state = init_param_avg_state(jax.random.PRNGKey(0),
                                 lambda r: alexnet.init(r, cfg), opt, R)
    state = jax.device_put(state, replica_sharding(state, mesh,
                                                   replica_axes=("data",)))
    b = jax.device_put(batch, replica_sharding(batch, mesh,
                                               replica_axes=("data",)))
    step = jax.jit(make_mesh_param_avg_step(loss, opt,
                                            schedules.constant(0.01),
                                            mesh=mesh, strategy=strat,
                                            replica_axes=("data",)),
                   donate_argnums=0)   # state updates in place
    state, _ = step(state, b)          # compile + warm
    jax.block_until_ready(state)
    t0 = time.time()
    for _ in range(5):
        state, l = step(state, b)
    jax.block_until_ready(state)
    us = (time.time() - t0) / 5 * 1e6
    print(f"STEP,{strat},{us:.1f},replicas={R};engine=mesh")
"""


def main():
    devices = int(os.environ.get("REPRO_DEVICES", "4"))
    out = run_subprocess_bench(CHILD_EXCHANGE, devices=devices)
    for line in out.splitlines():
        if line.startswith("#"):
            print(line, flush=True)
        elif line.startswith("RESULT"):
            _, strat, us, derived = line.split(",", 3)
            emit(f"exchange/{strat}", float(us), derived)
    out = run_subprocess_bench(CHILD_STEP, devices=devices)
    rows = []
    for line in out.splitlines():
        if line.startswith("STEP"):
            _, strat, us, derived = line.split(",", 3)
            emit(f"exchange_step/{strat}", float(us), derived)
            rows.append((strat, float(us)))
    if rows:                      # human-readable per-strategy table
        base = dict(rows).get("none")
        print("# strategy     step_us    exchange_overhead_vs_none")
        for strat, us in rows:
            ovh = f"{us - base:+.1f}us" if base else "n/a"
            print(f"# {strat:12s} {us:9.1f}  {ovh}")


if __name__ == "__main__":
    main()
