"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

Emits ``name,us_per_call,derived`` CSV rows and writes the whole run to
``benchmarks/BENCH_<date>.json`` (override with ``REPRO_BENCH_OUT``) so
future PRs have a trajectory baseline.  Mapping to the paper:
  table1_throughput   Table 1 (replicas x parallel-loading grid)
  loading_overlap     Fig. 1  (double-buffered loading)
  exchange_strategies Fig. 2  (exchange+average schedules)
  kernel_backends     Table 1's conv-backend axis (+ other Pallas kernels,
                      + the LM-zoo KernelPolicy xla-vs-pallas train-step
                      sweep — lm/<arch>/<backend> rows)
  parity_training     §3 accuracy-parity claim (param-avg vs grad-avg)
  session_throughput  Table 1 through the session layer (train_loop JSONL)
  serving_latency     continuous-batching engine vs the static decode loop
                      (tok/s, p50/p99 request latency, slots curve)
  serving_tier        multi-process tier: aggregate tok/s at 1 vs 2 engine
                      instances, decode-tick p99 colocated vs
                      disaggregated prefill (real worker processes)
"""
from __future__ import annotations

import argparse
import os
import sys
import traceback

from benchmarks import (common, exchange_strategies, kernel_backends,
                        loading_overlap, local_sgd_ablation, numerics_bench,
                        parity_training, serving_latency, serving_tier,
                        session_throughput, table1_throughput)

SUITES = {
    "table1_throughput": table1_throughput.main,
    "loading_overlap": loading_overlap.main,
    "exchange_strategies": exchange_strategies.main,
    "kernel_backends": kernel_backends.main,
    "parity_training": parity_training.main,
    "local_sgd_ablation": local_sgd_ablation.main,
    "session_throughput": session_throughput.main,
    "serving_latency": serving_latency.main,
    "serving_tier": serving_tier.main,
    "numerics_bench": numerics_bench.main,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(SUITES))
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failed = []
    ran = []
    for name, fn in SUITES.items():
        if args.only and name != args.only:
            continue
        ran.append(name)
        print(f"# --- {name} ---", flush=True)
        try:
            fn()
        except Exception as e:
            failed.append(name)
            traceback.print_exc()
            print(f"# FAILED {name}: {e}", flush=True)
    # a partial run (--only / fast mode) must not clobber the committed
    # full-suite baseline for the day — it goes to the tempdir instead
    partial = bool(args.only) or os.environ.get("REPRO_BENCH_FAST") == "1"
    path = common.write_bench_json(partial=partial,
                                   extra={"suites": ran, "failed": failed,
                                          "partial": partial})
    print(f"# wrote {path}", flush=True)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
