"""Multi-process serving-tier benchmark (docs/serving.md).

Two claims, measured through REAL worker processes (`python -m
repro.launch.serve --role engine/prefill`) behind the Router:

  aggregate_tps/procsN   bursty open-loop workload (every request
                         submitted up front) over N independent engine
                         instances.  Aggregate useful tok/s — the tier's
                         whole point is that this scales with N.  The
                         speedup is core-bound: on a 1-core box two
                         CPU-bound engines timeshare and the ratio is
                         ~1.0, so rows carry ``cores`` metadata and the
                         >=1.5x acceptance is asserted by CI on
                         multi-core runners, not here.
  p99_colocated /        decode-tick p99 with long prompts arriving
  p99_disagg             mid-stream.  Colocated: admission prefill runs
                         inside the instance's step loop, so every long
                         prompt is a full stall in the tick tail.
                         Disaggregated: a prefill worker absorbs the
                         prompt and the decode instance only ever
                         injects ready snapshots, so its tail stays
                         flat.  Row value is the p99 step time (us);
                         disagg must be LOWER.

Workers are spawned with the same smoke-sized model flags; each arm
warms the tier (compiles live in each worker process) before timing.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import emit
from repro.serving import Request, Router
from repro.serving.tier import spawn_worker

ARGV = ["--arch", "olmo-1b", "--smoke", "--layers", "2", "--d-model", "128",
        "--capacity", "64", "--seed", "0"]


def _spawn_tier(n, *, slots, disagg=False):
    argv = ARGV + ["--slots", str(slots)]
    insts = [spawn_worker("engine", argv, name=f"eng{i}") for i in range(n)]
    pw = spawn_worker("prefill", argv, name="prefill") if disagg else None
    for h in insts + ([pw] if pw else []):
        h.connect()
    return insts, pw


def _reqs(n, *, new, ln=8, seed=5):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, 512, size=ln),
                    max_new_tokens=new) for _ in range(n)]


def _shutdown(insts, pw):
    for h in insts + ([pw] if pw else []):
        h.shutdown()


# ------------------------------------------------------ aggregate tok/s ----

def _aggregate_tps(procs, *, n_req, new):
    insts, _ = _spawn_tier(procs, slots=4)
    try:
        tps, stats = 0.0, None
        for round_ in range(2):                   # round 0 warms compiles
            r = Router(insts)
            t0 = time.perf_counter()
            for q in _reqs(n_req, new=new):       # the open-loop burst
                r.submit(q)
            res = r.run_until_done(timeout=300)
            dt = time.perf_counter() - t0
            tps = sum(len(x["tokens"]) for x in res) / dt
            stats = r.stats()
        return tps, stats
    finally:
        _shutdown(insts, None)


# ------------------------------------------------- disagg p99 comparison ----

def _p99_arm(disagg, *, steady_new, n_long):
    """4 steady decode streams on a 6-slot instance; long prompts land
    mid-stream.  Returns the decode instance's p99 step time (seconds)."""
    insts, pw = _spawn_tier(1, slots=6, disagg=disagg)
    try:
        p99 = 0.0
        for round_ in range(2):                   # round 0 warms compiles
            r = Router(insts, prefill=pw)
            for q in _reqs(4, new=steady_new, seed=6):
                r.submit(q)
            time.sleep(0.3)                       # streams reach steady state
            for i in range(n_long):
                r.submit(_reqs(1, new=4, ln=48, seed=20 + i)[0])
                time.sleep(0.08)                  # arrivals spread over ticks
            r.run_until_done(timeout=300)
            r.stats()                             # flush remaining samples
            times = r.step_times[insts[0].name]
            if not times:
                raise RuntimeError("no step-time samples from instance")
            p99 = float(np.percentile(times, 99))
        return p99
    finally:
        _shutdown(insts, pw)


def main():
    fast = bool(os.environ.get("REPRO_BENCH_FAST"))
    n_req, new = (12, 16) if fast else (16, 32)
    cores = os.cpu_count() or 1
    meta = dict(arch="olmo-1b-smoke", slots=4, cores=cores, backend="xla")

    tps1, _ = _aggregate_tps(1, n_req=n_req, new=new)
    tps2, st2 = _aggregate_tps(2, n_req=n_req, new=new)
    scale = tps2 / tps1
    emit("serving_tier/aggregate_tps/procs1", 1e6 / tps1,
         f"tok/s={tps1:.1f}", procs=1, **meta)
    emit("serving_tier/aggregate_tps/procs2", 1e6 / tps2,
         f"tok/s={tps2:.1f};speedup_vs_procs1={scale:.2f}x;"
         f"deferred={st2['deferred']}", procs=2, **meta)
    if scale < 1.5 and cores >= 2:
        print(f"# WARNING: 2-process aggregate only {scale:.2f}x on "
              f"{cores} cores", flush=True)

    steady_new, n_long = (32, 4) if fast else (56, 6)
    p99_co = _p99_arm(False, steady_new=steady_new, n_long=n_long)
    p99_dis = _p99_arm(True, steady_new=steady_new, n_long=n_long)
    pmeta = dict(arch="olmo-1b-smoke", slots=6, cores=cores, backend="xla")
    emit("serving_tier/p99_colocated", p99_co * 1e6,
         f"p99_step_ms={p99_co * 1e3:.2f}", **pmeta)
    emit("serving_tier/p99_disagg", p99_dis * 1e6,
         f"p99_step_ms={p99_dis * 1e3:.2f};"
         f"vs_colocated={p99_dis / p99_co:.2f}x", **pmeta)
    if p99_dis >= p99_co:
        print(f"# WARNING: disagg p99 {p99_dis * 1e3:.2f}ms >= colocated "
              f"{p99_co * 1e3:.2f}ms", flush=True)


if __name__ == "__main__":
    main()
