"""Render the roofline table for EXPERIMENTS.md from results/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.roofline_report [--mesh pod1]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
               "long_500k": 3}


def load(mesh: str, results_dir: str = RESULTS):
    rows = []
    for f in glob.glob(os.path.join(results_dir, f"*_{mesh}.json")):
        d = json.load(open(f))
        rows.append(d)
    rows.sort(key=lambda d: (d["arch"], SHAPE_ORDER.get(d["shape"], 9)))
    return rows


def fmt_bytes(n):
    if n is None:
        return "-"
    return f"{n / 2 ** 30:.1f}"


def onesent(d):
    """One sentence on what would move the dominant term down."""
    dom = d["roofline"]["dominant"]
    if dom == "collective":
        return ("sequence-/activation-sharding over 'model' (reduce-scatter "
                "instead of all-reduce) cuts the per-layer TP collective")
    if dom == "memory":
        return ("operator fusion + bf16 activations reduce HLO bytes; "
                "on TPU most of this traffic fuses away")
    return "larger per-chip batch or fewer model shards raises MXU occupancy"


def table(rows):
    out = ["| arch | shape | mode | layout | compute s | memory s | "
           "collective s | dominant | MODEL_FLOPs | useful frac | "
           "temp GiB/dev |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for d in rows:
        r = d["roofline"]
        layout = (f"R={d['n_replicas']}"
                  + (f"+fsdp({d['fsdp_axis']})" if d["fsdp_axis"] else ""))
        out.append(
            f"| {d['arch']} | {d['shape']} | {d['mode']} | {layout} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | **{r['dominant']}** "
            f"| {r['model_flops']:.2e} | "
            f"{(r['useful_fraction'] or 0):.2f} "
            f"| {fmt_bytes(d['memory']['temp_size_in_bytes'])} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod1")
    ap.add_argument("--results", default=RESULTS)
    ap.add_argument("--sentences", action="store_true")
    args = ap.parse_args()
    rows = load(args.mesh, args.results)
    print(f"### Roofline — mesh {args.mesh} "
          f"({rows[0]['chips'] if rows else '?'} chips)\n")
    print(table(rows))
    if args.sentences:
        print()
        for d in rows:
            print(f"- **{d['arch']} x {d['shape']}** "
                  f"({d['roofline']['dominant']}-bound): {onesent(d)}")


if __name__ == "__main__":
    main()
