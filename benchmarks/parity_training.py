"""Paper §3 accuracy-parity analogue: train the same AlexNet three ways —
single worker (big batch), param-avg 4 replicas, grad-avg 4 replicas — and
report final loss + max param divergence.  The paper's claim (42.6% top-1
within 0.5% of Caffe) reduces, for a linear optimizer, to these curves
coinciding; we verify it numerically."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs import ALEXNET_SMOKE
from repro.core import (init_grad_avg_state, init_param_avg_state,
                        make_grad_avg_step, make_param_avg_step,
                        reshape_for_replicas, unreplicate)
from repro.data import synthetic
from repro.models import alexnet
from repro.optim import schedules
from repro.optim.optimizers import sgd_momentum

STEPS = 40
BATCH = 32


def main():
    cfg = ALEXNET_SMOKE
    opt = sgd_momentum(momentum=0.9, weight_decay=1e-4)
    sched = schedules.constant(0.02)
    loss_fn = lambda p, b: alexnet.loss_fn(p, cfg, b["images"], b["labels"])  # noqa

    sp = init_param_avg_state(jax.random.PRNGKey(0),
                              lambda r: alexnet.init(r, cfg), opt, 4)
    sg = init_grad_avg_state(jax.random.PRNGKey(0),
                             lambda r: alexnet.init(r, cfg), opt)
    # donate the TrainState: the old state is consumed each step
    pstep = jax.jit(make_param_avg_step(loss_fn, opt, sched),
                    donate_argnums=0)
    gstep = jax.jit(make_grad_avg_step(loss_fn, opt, sched),
                    donate_argnums=0)

    src = synthetic.blob_images(cfg.n_classes, BATCH, cfg.image_size, seed=0)
    lp = lg = None
    for i in range(STEPS):
        batch = {k: jnp.asarray(v) for k, v in next(src).items()}
        sp, lp = pstep(sp, reshape_for_replicas(batch, 4))
        sg, lg = gstep(sg, batch)
    div = max(float(jnp.max(jnp.abs(a[0].astype(jnp.float32)
                                    - b.astype(jnp.float32))))
              for a, b in zip(jax.tree.leaves(sp.params),
                              jax.tree.leaves(sg.params)))
    emit("parity/param_avg_final_loss", float(lp) * 1e6,
         f"loss={float(lp):.4f}")
    emit("parity/grad_avg_final_loss", float(lg) * 1e6,
         f"loss={float(lg):.4f}")
    emit("parity/param_divergence", div * 1e6,
         f"max_abs_diff={div:.2e} (paper claim: parity)")

    # held-out accuracy, param-avg model
    params = unreplicate(sp.params)
    batch = next(src)
    logits = alexnet.forward(params, cfg, jnp.asarray(batch["images"]))
    acc = float(jnp.mean(jnp.argmax(logits, -1) == batch["labels"]))
    emit("parity/param_avg_heldout_acc", acc * 1e6, f"acc={acc:.3f}")


if __name__ == "__main__":
    main()
