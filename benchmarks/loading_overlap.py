"""Paper Fig. 1 analogue: parallel data loading overlap, isolated.

Measures steady-state step time with the loader (fetch + preprocess +
device_put) either overlapped (prefetch=2, the paper's double buffer) or
serial (prefetch=0).  derived reports the hidden-latency fraction.

Also times the crop+flip host transform both ways (``loading/crop_*``):
the per-image block-copy loop vs the vectorized fancy-indexing gather.
Before/after verdict: vectorization REFUTED on CPU hosts — the loop is one
C-level memcpy per image and beats every gather formulation ~2-4x at all
shapes this repo trains (details in preprocess.random_crop_flip); the rows
here keep that measurement honest per environment."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.configs import ALEXNET_SMOKE
from repro.data import PrefetchLoader, synthetic
from repro.data.preprocess import make_image_preprocess, random_crop_flip
from repro.models import alexnet


def run(prefetch: int, steps: int = 15) -> float:
    cfg = ALEXNET_SMOKE
    params = alexnet.init(jax.random.PRNGKey(0), cfg)

    # no donation here on purpose: params are reused every step and the
    # only output is a scalar loss, so no input buffer can be reused —
    # donating would just raise "donated buffers were not usable"
    @jax.jit
    def fwd(p, b):
        return alexnet.loss_fn(p, cfg, b["images"], b["labels"])

    mean = synthetic.mean_image(
        synthetic.blob_images(10, 64, cfg.image_size + 8, seed=1), 2)
    prep = make_image_preprocess(mean, cfg.image_size, seed=0)
    loader = PrefetchLoader(
        synthetic.blob_images(10, 64, cfg.image_size + 8, seed=0),
        prefetch=prefetch, preprocess=prep,
        device_put=lambda b: jax.device_put(
            {k: jnp.asarray(v) for k, v in b.items()}))
    jax.block_until_ready(fwd(params, next(loader)))      # compile
    t0 = time.time()
    for i, batch in zip(range(steps), loader):
        jax.block_until_ready(fwd(params, batch))
    dt = (time.time() - t0) / steps
    loader.close()
    return dt


def crop_bench(batch: int = 256, size: int = 235, crop: int = 227):
    """Host preprocess in isolation: block-copy loop vs vectorized gather."""
    imgs = np.random.default_rng(0).normal(
        size=(batch, size, size, 3)).astype(np.float32)
    t_loop = time_fn(lambda: random_crop_flip(
        imgs, crop, np.random.default_rng(1), impl="loop"))
    t_vec = time_fn(lambda: random_crop_flip(
        imgs, crop, np.random.default_rng(1), impl="gather"))
    emit("loading/crop_loop", t_loop, f"B={batch} {size}->{crop}")
    emit("loading/crop_gather", t_vec,
         f"loop_vs_gather={t_vec / t_loop:.2f}x")


def main():
    serial = run(prefetch=0)
    overlap = run(prefetch=2)
    emit("loading/serial", serial * 1e6, "")
    emit("loading/overlapped", overlap * 1e6,
         f"overlap_gain={serial / overlap:.2f}x")
    crop_bench()


if __name__ == "__main__":
    main()
