"""Paper Fig. 1 analogue: parallel data loading overlap, isolated.

Measures steady-state step time with the loader (fetch + preprocess +
device_put) serial (prefetch=0), overlapped through the handoff queue
(prefetch=2, the paper's double buffer), or overlapped into rotating
preallocated pinned buffers (``staging="pinned"``, the paper's Fig. 1
taken literally — see data.pipeline.StagedPinnedLoader).  derived
reports the hidden-latency fraction plus the trainer-side stall
(``stall_ms``, time blocked in ``next(loader)`` per step — the same
quantity the session logs as ``stage_wait_ms``).

Also times the crop+flip host transform both ways (``loading/crop_*``):
the per-image block-copy loop vs the vectorized fancy-indexing gather.
Before/after verdict: vectorization REFUTED on CPU hosts — the loop is one
C-level memcpy per image and beats every gather formulation ~2-4x at all
shapes this repo trains (details in preprocess.random_crop_flip); the rows
here keep that measurement honest per environment."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.configs import ALEXNET_SMOKE
from repro.data import make_loader, synthetic
from repro.data.preprocess import make_image_preprocess, random_crop_flip
from repro.models import alexnet


def run(prefetch: int, steps: int = 15, staging: str = "queue"):
    """Returns (s_per_step, trainer_stall_ms_per_step)."""
    cfg = ALEXNET_SMOKE
    params = alexnet.init(jax.random.PRNGKey(0), cfg)

    # no donation here on purpose: params are reused every step and the
    # only output is a scalar loss, so no input buffer can be reused —
    # donating would just raise "donated buffers were not usable"
    @jax.jit
    def fwd(p, b):
        return alexnet.loss_fn(p, cfg, b["images"], b["labels"])

    mean = synthetic.mean_image(
        synthetic.blob_images(10, 64, cfg.image_size + 8, seed=1), 2)
    prep = make_image_preprocess(mean, cfg.image_size, seed=0)
    loader = make_loader(
        synthetic.blob_images(10, 64, cfg.image_size + 8, seed=0),
        prefetch=prefetch, staging=staging, preprocess=prep,
        device_put=lambda b: jax.device_put(
            {k: jnp.asarray(v) for k, v in b.items()}))
    loss = fwd(params, next(loader))                      # compile
    loader.fence(loss)
    jax.block_until_ready(loss)
    wait0 = loader.wait_ms_total
    t0 = time.time()
    for i, batch in zip(range(steps), loader):
        loss = fwd(params, batch)
        loader.fence(loss)
        jax.block_until_ready(loss)
    dt = (time.time() - t0) / steps
    stall = (loader.wait_ms_total - wait0) / steps
    loader.close()
    return dt, stall


def crop_bench(batch: int = 256, size: int = 235, crop: int = 227):
    """Host preprocess in isolation: block-copy loop vs vectorized gather."""
    imgs = np.random.default_rng(0).normal(
        size=(batch, size, size, 3)).astype(np.float32)
    t_loop = time_fn(lambda: random_crop_flip(
        imgs, crop, np.random.default_rng(1), impl="loop"))
    t_vec = time_fn(lambda: random_crop_flip(
        imgs, crop, np.random.default_rng(1), impl="gather"))
    emit("loading/crop_loop", t_loop, f"B={batch} {size}->{crop}")
    emit("loading/crop_gather", t_vec,
         f"loop_vs_gather={t_vec / t_loop:.2f}x")


def main():
    serial, s_stall = run(prefetch=0)
    overlap, o_stall = run(prefetch=2)
    pinned, p_stall = run(prefetch=2, staging="pinned")
    emit("loading/serial", serial * 1e6, f"stall_ms={s_stall:.1f}")
    emit("loading/overlapped", overlap * 1e6,
         f"overlap_gain={serial / overlap:.2f}x;stall_ms={o_stall:.1f}")
    emit("loading/staged_pinned", pinned * 1e6,
         f"overlap_gain={serial / pinned:.2f}x;stall_ms={p_stall:.1f}")
    crop_bench()


if __name__ == "__main__":
    main()
