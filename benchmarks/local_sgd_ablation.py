"""Beyond-paper ablation: sync-every-k (local SGD) vs the paper's
every-step averaging.

The paper exchanges after EVERY minibatch (its P2P copy was cheap under one
PCIe switch).  At pod scale the exchange is an all-reduce of the full
state, so skipping it k−1 of k steps trades convergence for communication.
This ablation trains the same AlexNet with k ∈ {1, 2, 4, 8} and reports
final loss + held-out accuracy + the per-step expected collective volume
(state bytes / k) — the curve a practitioner needs to pick k."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs import ALEXNET_SMOKE
from repro.core import (init_param_avg_state, make_param_avg_step,
                        reshape_for_replicas, unreplicate)
from repro.data import synthetic
from repro.models import alexnet
from repro.optim import schedules
from repro.optim.optimizers import sgd_momentum

STEPS = 120
R = 4


def run(sync_every: int):
    cfg = ALEXNET_SMOKE
    opt = sgd_momentum(momentum=0.9, weight_decay=1e-4)
    state = init_param_avg_state(jax.random.PRNGKey(0),
                                 lambda r: alexnet.init(r, cfg), opt, R)
    step = jax.jit(make_param_avg_step(
        lambda p, b: alexnet.loss_fn(p, cfg, b["images"], b["labels"]),
        opt, schedules.constant(0.02), sync_every=sync_every),
        donate_argnums=0)              # state updates in place
    src = synthetic.blob_images(cfg.n_classes, 32, cfg.image_size, seed=0)
    loss = None
    for i in range(STEPS):
        batch = {k: jnp.asarray(v) for k, v in next(src).items()}
        state, loss = step(state, reshape_for_replicas(batch, R))
    params = unreplicate(state.params)
    batch = next(synthetic.blob_images(cfg.n_classes, 64, cfg.image_size,
                                       seed=9))
    logits = alexnet.forward(params, cfg, jnp.asarray(batch["images"]))
    acc = float(jnp.mean(jnp.argmax(logits, -1) == batch["labels"]))
    state_bytes = sum(x.nbytes for x in jax.tree.leaves(state.params)) // R
    return float(loss), acc, state_bytes


def main():
    for k in (1, 2, 4, 8):
        loss, acc, sb = run(k)
        emit(f"local_sgd/sync_every_{k}", loss * 1e6,
             f"final_loss={loss:.4f};heldout_acc={acc:.3f};"
             f"avg_exchange_bytes_per_step={sb // k}")


if __name__ == "__main__":
    main()
