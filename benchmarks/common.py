"""Shared benchmark utilities: timing + CSV emission + trajectory record.

Benchmarks print ``name,us_per_call,derived`` rows (harness contract) and
run on host devices.  Multi-device benchmarks spawn a subprocess with
XLA_FLAGS set, keeping the main process at 1 device.

Every emitted row is also recorded in-process; ``write_bench_json``
persists the run as ``BENCH_<date>.json`` so future PRs have a baseline
trajectory to regress against (set ``REPRO_BENCH_OUT`` to override the
path).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

import jax

REPO = os.path.join(os.path.dirname(__file__), "..")

_ROWS: list = []


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time per call in microseconds (blocks on device)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us: float, derived: str = "", **meta):
    """Emit one CSV row; ``meta`` kwargs (arch=, slots=, backend=,
    groups=, model_parallel=, ...) are persisted on the JSON row so
    trajectories stay comparable across PRs even when row names drift —
    grouped-conv rows carry ``groups``/``model_parallel`` so a faithful
    AlexNet row never gets regressed against a legacy ungrouped one."""
    print(f"{name},{us:.1f},{derived}", flush=True)
    row = {"name": name, "us_per_call": round(us, 1), "derived": derived}
    if meta:
        row["meta"] = meta
    _ROWS.append(row)


def recorded_rows() -> list:
    return list(_ROWS)


def write_bench_json(path: str = None, extra: dict = None,
                     partial: bool = False) -> str:
    """Persist this run's rows as a BENCH_<date>.json trajectory file.

    ``partial`` runs (``--only`` / fast mode) land in the system tempdir
    — NOT in benchmarks/ — so a scratch run can never leave a stray
    ``.partial.json`` in the working tree next to the committed
    full-suite baseline (``*.partial.json`` is also gitignored as a
    belt-and-braces guard for REPRO_BENCH_OUT overrides).
    """
    date = time.strftime("%Y-%m-%d")
    if path is None:
        path = os.environ.get("REPRO_BENCH_OUT")
    if path is None:
        path = (os.path.join(tempfile.gettempdir(),
                             f"BENCH_{date}.partial.json") if partial
                else os.path.normpath(
                    os.path.join(REPO, "benchmarks", f"BENCH_{date}.json")))
    payload = {"date": date, "jax": jax.__version__,
               "backend": jax.default_backend(),
               "device_count": jax.device_count(), "rows": _ROWS}
    payload.update(extra or {})
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def run_subprocess_bench(code: str, devices: int, timeout=560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    if r.returncode != 0:
        raise RuntimeError(f"bench subprocess failed:\n{r.stderr[-2000:]}")
    return r.stdout
