"""Shared benchmark utilities: timing + CSV emission.

Benchmarks print ``name,us_per_call,derived`` rows (harness contract) and
run on host devices.  Multi-device benchmarks spawn a subprocess with
XLA_FLAGS set, keeping the main process at 1 device.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

import jax

REPO = os.path.join(os.path.dirname(__file__), "..")


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time per call in microseconds (blocks on device)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}", flush=True)


def run_subprocess_bench(code: str, devices: int, timeout=560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    if r.returncode != 0:
        raise RuntimeError(f"bench subprocess failed:\n{r.stderr[-2000:]}")
    return r.stdout
