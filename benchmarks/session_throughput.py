"""Table 1 via the session layer: train a short AlexNet session through
``repro.launch.train`` (checkpointing + eval enabled, i.e. the REAL loop a
user runs, not a stripped inner loop) and report the throughput summary the
session emits as JSONL (docs/training.md).

Rows: images/sec and step-time percentiles per replica count — the paper's
Table 1 axes, measured end-to-end including loader, eval and checkpoint
overhead.  Runs in subprocesses so the forced device count never leaks.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

from benchmarks.common import REPO, emit

STEPS = int(os.environ.get("REPRO_BENCH_SESSION_STEPS", "12"))


def run_session(devices: int) -> dict:
    with tempfile.TemporaryDirectory() as td:
        metrics = os.path.join(td, "metrics.jsonl")
        env = dict(os.environ)
        env["REPRO_DEVICES"] = str(devices)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.train", "--arch", "alexnet",
             "--smoke", "--steps", str(STEPS), "--batch", "16",
             "--ckpt-dir", os.path.join(td, "ck"), "--ckpt-every",
             str(STEPS // 2), "--eval-every", str(STEPS // 2),
             "--metrics-out", metrics, "--log-every", str(STEPS)],
            env=env, capture_output=True, text=True, timeout=560)
        if r.returncode != 0:
            raise RuntimeError(f"session failed:\n{r.stderr[-2000:]}")
        with open(metrics) as f:
            rows = [json.loads(line) for line in f if line.strip()]
    summaries = [x for x in rows if x.get("kind") == "summary"]
    assert summaries, rows
    return summaries[-1]


def main():
    for devices in (1, 2):
        s = run_session(devices)
        emit(f"session/replicas{devices}/step", s["step_ms_p50"] * 1e3,
             f"images_per_sec={s.get('images_per_sec')} "
             f"p90_ms={s['step_ms_p90']} p99_ms={s['step_ms_p99']}")


if __name__ == "__main__":
    main()
