"""Paper Table 1 analogue: AlexNet training time per 20 iterations,
{1, 2, 4} replicas x {parallel loading on/off} x conv backend.

The paper's numbers (Titan Black, batch 256 global): cuDNN-R2 2-GPU with
parallel loading 19.72 s / 20 iters vs 43.52 s for 1-GPU serial — a 2.2x
combined speedup.  Here replicas are host devices (CPU), so absolute times
are meaningless; the DERIVED column reports the speedup structure the
paper's table demonstrates (scaling efficiency + loading overlap gain).
"""
from __future__ import annotations

from benchmarks.common import emit, run_subprocess_bench

CHILD = """
import time, jax, jax.numpy as jnp
import numpy as np
from repro.configs import ALEXNET_SMOKE
from repro.core import init_param_avg_state, make_param_avg_step, reshape_for_replicas
from repro.data import PrefetchLoader, synthetic
from repro.data.preprocess import make_image_preprocess
from repro.models import alexnet
from repro.optim import schedules
from repro.optim.optimizers import sgd_momentum

R = __REPLICAS__
PREFETCH = __PREFETCH__
BACKEND = "__BACKEND__"
cfg = ALEXNET_SMOKE
GLOBAL_BATCH = 64
opt = sgd_momentum()
state = init_param_avg_state(jax.random.PRNGKey(0), lambda r: alexnet.init(r, cfg), opt, R)
step = jax.jit(make_param_avg_step(
    lambda p, b: alexnet.loss_fn(p, cfg, b["images"], b["labels"], conv_backend=BACKEND),
    opt, schedules.constant(0.01)))
mean = synthetic.mean_image(synthetic.blob_images(10, GLOBAL_BATCH, cfg.image_size + 8, seed=1), 2)
prep = make_image_preprocess(mean, cfg.image_size, seed=0)
src = map(lambda b: reshape_for_replicas({k: jnp.asarray(v) for k, v in prep(b).items()}, R),
          synthetic.blob_images(10, GLOBAL_BATCH, cfg.image_size + 8, seed=0))
loader = PrefetchLoader(src, prefetch=PREFETCH)
# warmup
state, _ = step(state, next(loader))
jax.block_until_ready(state.params)
t0 = time.time()
for i in range(20):
    state, loss = step(state, next(loader))
jax.block_until_ready(state.params)
print("RESULT", time.time() - t0)
loader.close()
"""


def main():
    results = {}
    for backend in ("xla",):
        for replicas in (1, 2, 4):
            for prefetch in (2, 0):
                code = (CHILD.replace("__REPLICAS__", str(replicas))
                        .replace("__PREFETCH__", str(prefetch))
                        .replace("__BACKEND__", backend))
                out = run_subprocess_bench(code, devices=replicas)
                secs = float([l for l in out.splitlines()
                              if l.startswith("RESULT")][0].split()[1])
                results[(backend, replicas, prefetch)] = secs
                load = "parload" if prefetch else "serial"
                emit(f"table1/{backend}/{replicas}rep/{load}",
                     secs / 20 * 1e6, f"s_per_20it={secs:.2f}")
    base = results[("xla", 1, 0)]
    for (backend, r, p), secs in results.items():
        if (r, p) != (1, 0):
            emit(f"table1/speedup/{r}rep/"
                 f"{'parload' if p else 'serial'}",
                 secs / 20 * 1e6, f"speedup_vs_serial1={base / secs:.2f}x")


if __name__ == "__main__":
    main()
