"""Paper Table 1 analogue: AlexNet training time per 20 iterations,
conv backend x {1, 2, 4} replicas x {parallel loading on/off}.

The paper's numbers (Titan Black, batch 256 global): cuDNN-R2 2-GPU with
parallel loading 19.72 s / 20 iters vs 43.52 s for 1-GPU serial — a 2.2x
combined speedup.  Here replicas are host devices (CPU), so absolute times
are meaningless; the DERIVED column reports the speedup structure the
paper's table demonstrates (scaling efficiency + loading overlap gain).

Both conv backends run end-to-end training: ``xla`` (lax.conv) and
``pallas`` (the fused implicit-GEMM kernel — interpret-mode on CPU, so
its absolute time reflects the Pallas interpreter, not the MXU; the row
exists to keep the compiled path exercised and regression-tracked).

A donation A/B pair (same config, ``donate_argnums=0`` on vs off) is
also emitted: the donated step must be no slower than the non-donated
baseline.  Set ``REPRO_BENCH_FAST=1`` for a 1-replica prefetch-only
smoke (CI) — both backends still run.
"""
from __future__ import annotations

import os

from benchmarks.common import emit, run_subprocess_bench

CHILD = """
import time, jax, jax.numpy as jnp
import numpy as np
from repro.configs import ALEXNET_SMOKE, ALEXNET_FAITHFUL_SMOKE
from repro.core import init_param_avg_state, make_param_avg_step, reshape_for_replicas
from repro.data import PrefetchLoader, synthetic
from repro.data.preprocess import make_image_preprocess
from repro.models import alexnet
from repro.optim import schedules
from repro.optim.optimizers import sgd_momentum

R = __REPLICAS__
PREFETCH = __PREFETCH__
BACKEND = "__BACKEND__"
DONATE = __DONATE__
ITERS = __ITERS__
cfg = ALEXNET_FAITHFUL_SMOKE if __FAITHFUL__ else ALEXNET_SMOKE
GLOBAL_BATCH = 64
opt = sgd_momentum()
state = init_param_avg_state(jax.random.PRNGKey(0), lambda r: alexnet.init(r, cfg), opt, R)
step = jax.jit(make_param_avg_step(
    lambda p, b: alexnet.loss_fn(p, cfg, b["images"], b["labels"], conv_backend=BACKEND),
    opt, schedules.constant(0.01)),
    donate_argnums=(0,) if DONATE else ())
mean = synthetic.mean_image(synthetic.blob_images(10, GLOBAL_BATCH, cfg.image_size + 8, seed=1), 2)
prep = make_image_preprocess(mean, cfg.image_size, seed=0)
src = map(lambda b: reshape_for_replicas({k: jnp.asarray(v) for k, v in prep(b).items()}, R),
          synthetic.blob_images(10, GLOBAL_BATCH, cfg.image_size + 8, seed=0))
loader = PrefetchLoader(src, prefetch=PREFETCH)
# warmup
state, _ = step(state, next(loader))
jax.block_until_ready(state.params)
t0 = time.time()
for i in range(ITERS):
    state, loss = step(state, next(loader))
jax.block_until_ready(state.params)
print("RESULT", (time.time() - t0) * 20 / ITERS)
loader.close()
"""


def _run(backend: str, replicas: int, prefetch: int, donate: bool = True,
         iters: int = 20, faithful: bool = False) -> float:
    code = (CHILD.replace("__REPLICAS__", str(replicas))
            .replace("__PREFETCH__", str(prefetch))
            .replace("__BACKEND__", backend)
            .replace("__DONATE__", str(int(donate)))
            .replace("__ITERS__", str(iters))
            .replace("__FAITHFUL__", str(int(faithful))))
    out = run_subprocess_bench(code, devices=replicas)
    return float([ln for ln in out.splitlines()
                  if ln.startswith("RESULT")][0].split()[1])


def main():
    fast = os.environ.get("REPRO_BENCH_FAST") == "1"
    backends = ("xla", "pallas")
    replica_grid = (1,) if fast else (1, 2, 4)
    prefetches = (2,) if fast else (2, 0)
    # interpret-mode pallas steps are ~100x slower; fewer timed iters
    # (the emitted number is normalized to s/20it either way)
    iters = {"xla": 4 if fast else 20, "pallas": 3 if fast else 5}

    results = {}
    for backend in backends:
        for replicas in replica_grid:
            for prefetch in prefetches:
                secs = _run(backend, replicas, prefetch,
                            iters=iters[backend])
                results[(backend, replicas, prefetch)] = secs
                load = "parload" if prefetch else "serial"
                emit(f"table1/{backend}/{replicas}rep/{load}",
                     secs / 20 * 1e6, f"s_per_20it={secs:.2f}",
                     groups=1, model_parallel=1, replicas=replicas)
    base = results.get(("xla", 1, 0))
    for (backend, r, p), secs in results.items():
        if backend == "xla" and base and (r, p) != (1, 0):
            emit(f"table1/speedup/{r}rep/"
                 f"{'parload' if p else 'serial'}",
                 secs / 20 * 1e6, f"speedup_vs_serial1={base / secs:.2f}x")

    # faithful-vs-legacy: the paper's grouped net (conv2/4/5 split into
    # 2 groups + LRN) against the legacy ungrouped smoke net — grouping
    # cuts conv2/4/5 FLOPs in half, LRN adds a normalization pass, so the
    # ratio tracks how well the grouped kernel realizes the saving
    for backend in backends:
        it = iters[backend]
        legacy = results[(backend, 1, prefetches[0])]
        secs = _run(backend, 1, prefetches[0], iters=it, faithful=True)
        emit(f"table1/{backend}/faithful/1rep", secs / 20 * 1e6,
             f"s_per_20it={secs:.2f};vs_legacy={legacy / secs:.2f}x",
             groups=2, model_parallel=1, replicas=1)

    # donation A/B: same config with and without donate_argnums=0 — the
    # in-place state update must not be slower than fresh allocations
    it = iters["xla"]
    on = results.get(("xla", 1, 2)) or _run("xla", 1, 2, donate=True,
                                            iters=it)
    off = _run("xla", 1, 2, donate=False, iters=it)
    emit("table1/donation/on", on / 20 * 1e6, f"s_per_20it={on:.2f}")
    emit("table1/donation/off", off / 20 * 1e6,
         f"s_per_20it={off:.2f};donated_speedup={off / on:.2f}x")


if __name__ == "__main__":
    main()
