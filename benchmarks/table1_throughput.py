"""Paper Table 1 analogue: AlexNet training time per 20 iterations,
conv backend x {1, 2, 4} replicas x {parallel loading on/off}.

The paper's numbers (Titan Black, batch 256 global): cuDNN-R2 2-GPU with
parallel loading 19.72 s / 20 iters vs 43.52 s for 1-GPU serial — a 2.2x
combined speedup.  Here replicas are host devices (CPU), so absolute times
are meaningless; the DERIVED column reports the speedup structure the
paper's table demonstrates (scaling efficiency + loading overlap gain).

Both conv backends run end-to-end training: ``xla`` (lax.conv) and
``pallas`` (the fused implicit-GEMM kernel — interpret-mode on CPU, so
its absolute time reflects the Pallas interpreter, not the MXU; the row
exists to keep the compiled path exercised and regression-tracked).

A donation A/B pair (same config, ``donate_argnums=0`` on vs off) is
also emitted: the donated step must be no slower than the non-donated
baseline.  Set ``REPRO_BENCH_FAST=1`` for a 1-replica prefetch-only
smoke (CI) — both backends still run.

``table1/xla/<R>rep/overlap`` rows measure the PR 7 recipe end-to-end
(parallel loading + pinned staging + ``delay=1`` overlapped exchange +
sequential per-replica execution) at global batch 512 against the
pre-PR serial/synchronous 1-replica path at the same batch;
``table1/speedup_overlap/<R>rep/parload`` carries the derived speedup
(CI asserts the 4-replica row stays above 1.0 in fast mode).  All
overlap configs run interleaved in ONE child process with min-of-reps
timing: this host has a single physical core under background load, so
two configs measured in separate subprocesses can land in differently
loaded windows and drown the few-percent locality signal in noise.
"""
from __future__ import annotations

import os

from benchmarks.common import emit, run_subprocess_bench

CHILD = """
import time, jax, jax.numpy as jnp
import numpy as np
from repro.configs import ALEXNET_SMOKE, ALEXNET_FAITHFUL_SMOKE
from repro.core import (ExchangeConfig, init_param_avg_state,
                        make_param_avg_step, reshape_for_replicas)
from repro.data import make_loader, synthetic
from repro.data.preprocess import make_image_preprocess
from repro.models import alexnet
from repro.optim import schedules
from repro.optim.optimizers import sgd_momentum

R = __REPLICAS__
PREFETCH = __PREFETCH__
BACKEND = "__BACKEND__"
DONATE = __DONATE__
ITERS = __ITERS__
cfg = ALEXNET_FAITHFUL_SMOKE if __FAITHFUL__ else ALEXNET_SMOKE
GLOBAL_BATCH = __GBATCH__
EXCH = ExchangeConfig(delay=__DELAY__)
EXEC = "__EXEC__"
STAGING = "__STAGING__"
opt = sgd_momentum()
state = init_param_avg_state(jax.random.PRNGKey(0), lambda r: alexnet.init(r, cfg), opt, R,
                             exchange=EXCH)
step = jax.jit(make_param_avg_step(
    lambda p, b: alexnet.loss_fn(p, cfg, b["images"], b["labels"], conv_backend=BACKEND),
    opt, schedules.constant(0.01), strategy=EXCH, replica_exec=EXEC),
    donate_argnums=(0,) if DONATE else ())
mean = synthetic.mean_image(synthetic.blob_images(10, GLOBAL_BATCH, cfg.image_size + 8, seed=1), 2)
prep = make_image_preprocess(mean, cfg.image_size, seed=0)
src = map(lambda b: reshape_for_replicas({k: np.asarray(v) for k, v in prep(b).items()}, R),
          synthetic.blob_images(10, GLOBAL_BATCH, cfg.image_size + 8, seed=0))
loader = make_loader(src, prefetch=PREFETCH, staging=STAGING)
# warmup; the fence token must be a non-donated output (loss), never
# part of the state the next call donates
state, loss = step(state, next(loader))
loader.fence(loss)
jax.block_until_ready(state.params)
t0 = time.time()
for i in range(ITERS):
    state, loss = step(state, next(loader))
    loader.fence(loss)
jax.block_until_ready(state.params)
print("RESULT", (time.time() - t0) * 20 / ITERS)
loader.close()
"""


CHILD_OVERLAP = """
import os, time, jax, numpy as np
from repro.configs import ALEXNET_SMOKE
from repro.core import (ExchangeConfig, init_param_avg_state,
                        make_param_avg_step, reshape_for_replicas)
from repro.data import make_loader, synthetic
from repro.data.preprocess import make_image_preprocess
from repro.models import alexnet
from repro.optim import schedules
from repro.optim.optimizers import sgd_momentum

FAST = os.environ.get("REPRO_BENCH_FAST") == "1"
G = 512                              # per-replica batch G/R spans the
R_GRID = (1, 4) if FAST else (1, 2, 4)   # cache-locality cliff
REPS, ITERS = (4, 2)
cfg = ALEXNET_SMOKE
opt = sgd_momentum()
mean = synthetic.mean_image(
    synthetic.blob_images(4, G, cfg.image_size + 8, seed=1), 2)
prep = make_image_preprocess(mean, cfg.image_size, seed=0)
N_BATCH = REPS * ITERS + 2           # warmup + timed draws per config

configs = [("base", 1, 0, "queue", ExchangeConfig(), "vmap")]
for R in R_GRID:
    configs.append((str(R), R, 2, "pinned", ExchangeConfig(delay=1),
                    "scan"))
runs = []
for name, R, prefetch, staging, exch, exec_ in configs:
    state = init_param_avg_state(jax.random.PRNGKey(0),
                                 lambda r: alexnet.init(r, cfg), opt, R,
                                 exchange=exch)
    step = jax.jit(make_param_avg_step(
        lambda p, b: alexnet.loss_fn(p, cfg, b["images"], b["labels"]),
        opt, schedules.constant(0.01), strategy=exch, replica_exec=exec_),
        donate_argnums=0)
    src = map(lambda b, R=R: reshape_for_replicas(prep(b), R),
              synthetic.blob_images(N_BATCH, G, cfg.image_size + 8, seed=0))
    loader = make_loader(src, prefetch=prefetch, staging=staging)
    state, loss = step(state, next(loader))      # compile + warm
    loader.fence(loss)
    jax.block_until_ready(state.params)
    runs.append([name, step, state, loader, []])
for rep in range(REPS):                          # interleaved min-of-reps
    for r in runs:
        name, step, state, loader, ts = r
        t0 = time.perf_counter()
        for _ in range(ITERS):
            state, loss = step(state, next(loader))
            loader.fence(loss)
        jax.block_until_ready(state.params)
        ts.append((time.perf_counter() - t0) / ITERS)
        r[2] = state
for name, _, _, loader, ts in runs:
    loader.close()
    print("OVR,%s,%.4f" % (name, min(ts) * 20))   # s per 20 iterations
"""


def _run(backend: str, replicas: int, prefetch: int, donate: bool = True,
         iters: int = 20, faithful: bool = False, gbatch: int = 64,
         delay: int = 0, exec_: str = "vmap",
         staging: str = "queue") -> float:
    code = (CHILD.replace("__REPLICAS__", str(replicas))
            .replace("__PREFETCH__", str(prefetch))
            .replace("__BACKEND__", backend)
            .replace("__DONATE__", str(int(donate)))
            .replace("__ITERS__", str(iters))
            .replace("__FAITHFUL__", str(int(faithful)))
            .replace("__GBATCH__", str(gbatch))
            .replace("__DELAY__", str(delay))
            .replace("__EXEC__", exec_)
            .replace("__STAGING__", staging))
    out = run_subprocess_bench(code, devices=replicas)
    return float([ln for ln in out.splitlines()
                  if ln.startswith("RESULT")][0].split()[1])


def main():
    fast = os.environ.get("REPRO_BENCH_FAST") == "1"
    backends = ("xla", "pallas")
    replica_grid = (1,) if fast else (1, 2, 4)
    prefetches = (2,) if fast else (2, 0)
    # interpret-mode pallas steps are ~100x slower; fewer timed iters
    # (the emitted number is normalized to s/20it either way)
    iters = {"xla": 4 if fast else 20, "pallas": 3 if fast else 5}

    results = {}
    for backend in backends:
        for replicas in replica_grid:
            for prefetch in prefetches:
                secs = _run(backend, replicas, prefetch,
                            iters=iters[backend])
                results[(backend, replicas, prefetch)] = secs
                load = "parload" if prefetch else "serial"
                emit(f"table1/{backend}/{replicas}rep/{load}",
                     secs / 20 * 1e6, f"s_per_20it={secs:.2f}",
                     groups=1, model_parallel=1, replicas=replicas)
    base = results.get(("xla", 1, 0))
    for (backend, r, p), secs in results.items():
        if backend == "xla" and base and (r, p) != (1, 0):
            emit(f"table1/speedup/{r}rep/"
                 f"{'parload' if p else 'serial'}",
                 secs / 20 * 1e6, f"speedup_vs_serial1={base / secs:.2f}x")

    # overlap rows: the PR's combined recipe — parallel loading + pinned
    # double-buffered staging + one-step-stale exchange + sequential
    # per-replica execution — against the pre-PR path (1 replica, serial
    # loading, synchronous exchange) at the SAME global batch.  One
    # interleaved child (see module docstring).
    out = run_subprocess_bench(CHILD_OVERLAP, devices=1, timeout=900)
    o_base = None
    for line in out.splitlines():
        if not line.startswith("OVR,"):
            continue
        _, name, secs = line.split(",", 2)
        secs = float(secs)
        if name == "base":
            o_base = secs
            emit("table1/xla/1rep/overlap_base", o_base / 20 * 1e6,
                 "s_per_20it=%.2f;G=512;serial+sync" % o_base)
        else:
            r = int(name)
            emit(f"table1/xla/{r}rep/overlap", secs / 20 * 1e6,
                 f"s_per_20it={secs:.2f};G=512;delay1+pinned+scan",
                 replicas=r)
            emit(f"table1/speedup_overlap/{r}rep/parload", secs / 20 * 1e6,
                 f"speedup_vs_serial1={o_base / secs:.2f}x")

    # faithful-vs-legacy: the paper's grouped net (conv2/4/5 split into
    # 2 groups + LRN) against the legacy ungrouped smoke net — grouping
    # cuts conv2/4/5 FLOPs in half, LRN adds a normalization pass, so the
    # ratio tracks how well the grouped kernel realizes the saving
    for backend in backends:
        it = iters[backend]
        legacy = results[(backend, 1, prefetches[0])]
        secs = _run(backend, 1, prefetches[0], iters=it, faithful=True)
        emit(f"table1/{backend}/faithful/1rep", secs / 20 * 1e6,
             f"s_per_20it={secs:.2f};vs_legacy={legacy / secs:.2f}x",
             groups=2, model_parallel=1, replicas=1)

    # donation A/B: same config with and without donate_argnums=0 — the
    # in-place state update must not be slower than fresh allocations
    it = iters["xla"]
    on = results.get(("xla", 1, 2)) or _run("xla", 1, 2, donate=True,
                                            iters=it)
    off = _run("xla", 1, 2, donate=False, iters=it)
    emit("table1/donation/on", on / 20 * 1e6, f"s_per_20it={on:.2f}")
    emit("table1/donation/off", off / 20 * 1e6,
         f"s_per_20it={off:.2f};donated_speedup={off / on:.2f}x")


if __name__ == "__main__":
    main()
