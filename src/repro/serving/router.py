"""Request router for the multi-process serving tier.

The router is the tier's single front door: it owns the global request
ids, spreads admissions over N engine instances, aggregates finished
results and per-instance metrics, and orchestrates the two tier-level
maneuvers — disaggregated prefill (hand a prompt to the prefill worker,
inject the resulting snapshot into a decode instance) and elastic drain
(replay a draining instance's live slots into its peers).

Admission policy is least-loaded-slots: instances are ranked by (most
free slots, shortest queue) on fresh stats each placement, so a burst
spreads instead of piling onto one instance while another idles
(tests/serving/test_router.py asserts the fairness).  Backpressure is
deferred admission, the block pool's defer-don't-fail semantics one
level up: a worker with no free slot and a full bounded queue answers
``defer``, the router requeues the request AT THE FRONT (FIFO order
survives) and retries on a later ``pump`` — nothing is dropped, nothing
errors.

Failure boundary: any transport error marks the instance dead and every
request placed on it is re-queued and re-placed on a peer from scratch
(generation restarts — the tokens an instance took to its grave are
regenerated, at-least-once semantics).  A DRAINING instance is the
graceful version: ``drain_instance`` snapshots its live rows and replays
them into peers mid-stream with zero dropped requests and byte-identical
token streams (greedy), because sampling is positional and the retire
arithmetic depends only on (prompt_len, tokens, capacity) — never on the
slot index or the host process.
"""
from __future__ import annotations

import collections
import time
from typing import Dict, List, Optional

from repro import checkpoint
from repro.serving import tier as tier_mod
from repro.serving.engine import Request
from repro.serving.tier import InstanceHandle


class DeadInstanceError(RuntimeError):
    """A request exhausted its placement retries on dying instances."""


class Router:
    def __init__(self, instances: List[InstanceHandle], *,
                 prefill: Optional[InstanceHandle] = None,
                 max_retries: int = 2):
        if not instances:
            raise ValueError("a router needs at least one engine instance")
        self.instances = list(instances)
        self.prefill_worker = prefill       # disaggregated mode when set
        self.max_retries = max_retries
        self._next_rid = 0
        # grid = router-global request id; instances keep their own rids
        self._pending: collections.deque = collections.deque()   # (grid, wire)
        self._pending_inject: collections.deque = collections.deque()
        self._placed: Dict[int, tuple] = {}      # grid -> (handle, local_rid)
        self._wire: Dict[int, dict] = {}         # grid -> wire request
        self._results: Dict[int, dict] = {}      # grid -> wire result
        self._t_submit: Dict[int, float] = {}
        self._t_done: Dict[int, float] = {}
        self._retries: collections.Counter = collections.Counter()
        self.deferred = 0                        # backpressure events seen
        self.step_times: Dict[str, List[float]] = \
            collections.defaultdict(list)

    # ------------------------------------------------------------ submit ----

    def submit(self, req) -> int:
        """Route one request (a ``serving.Request`` or its wire dict);
        returns the router-global request id."""
        wire = tier_mod.request_to_wire(req) if isinstance(req, Request) \
            else dict(req)
        grid = self._next_rid
        self._next_rid += 1
        wire["rid"] = grid                   # the tier-wide identity
        self._wire[grid] = wire
        self._t_submit[grid] = time.perf_counter()
        self._pending.append((grid, wire))
        self.pump()
        return grid

    def _alive(self) -> List[InstanceHandle]:
        return [i for i in self.instances if not i.dead]

    def _ranked(self) -> List[tuple]:
        """Alive, non-draining instances by (most free slots, shortest
        queue) — fresh stats, dead peers culled as a side effect."""
        ranked = []
        for inst in self._alive():
            try:
                _, st = inst.call("stats")
            except ConnectionError:
                self._on_death(inst)
                continue
            self.step_times[inst.name].extend(st.get("step_times", ()))
            if not st["draining"]:
                ranked.append((-st["free_slots"], st["queue_len"], st, inst))
        ranked.sort(key=lambda t: t[:2])
        return [(st, inst) for _, _, st, inst in ranked]

    def _place(self, grid: int, wire: dict) -> bool:
        if self.prefill_worker is not None:
            return self._place_disagg(grid, wire)
        for st, inst in self._ranked():
            try:
                status, rid = inst.call("submit", wire)
            except ConnectionError:
                self._on_death(inst)
                continue
            if status == "ok":
                self._placed[grid] = (inst, rid)
                return True
            self.deferred += 1               # defer / draining: next peer
        return False

    def _place_disagg(self, grid: int, wire: dict) -> bool:
        """Disaggregated path: prefill worker builds the snapshot, a
        decode instance injects it — the decode tick loop never runs a
        prefill."""
        try:
            _, buf = self.prefill_worker.call("prefill", wire)
        except ConnectionError:
            # no prefill worker, no disagg: fall back to colocated path
            self.prefill_worker = None
            return self._place(grid, wire)
        return self._inject(grid, buf)

    def _inject(self, grid: int, buf: bytes) -> bool:
        for st, inst in self._ranked():
            if st["free_slots"] == 0:
                continue
            try:
                status, rid = inst.call("inject", buf)
            except ConnectionError:
                self._on_death(inst)
                continue
            if status == "ok" and rid is not None:
                self._placed[grid] = (inst, rid)
                return True
            self.deferred += 1
        return False

    # -------------------------------------------------------------- pump ----

    def pump(self):
        """One router turn: collect finished results, then retry every
        deferred placement/injection (front of the queue first)."""
        for inst in self._alive():
            try:
                _, results = inst.call("poll")
            except ConnectionError:
                self._on_death(inst)
                continue
            by_rid = {rid: g for g, (h, rid) in self._placed.items()
                      if h is inst}
            for res in results:
                grid = by_rid.get(res["rid"])
                if grid is None:
                    continue                 # finished under an old identity
                self._results[grid] = res
                self._t_done[grid] = time.perf_counter()
                del self._placed[grid]
        for queue, place in ((self._pending, self._place),
                             (self._pending_inject, self._inject)):
            for _ in range(len(queue)):
                grid, payload = queue.popleft()
                if grid in self._results:
                    continue                 # completed before the retry
                if not place(grid, payload):
                    queue.appendleft((grid, payload))
                    break                    # FIFO: nothing jumps the head

    def _on_death(self, inst: InstanceHandle):
        """Mark ``inst`` dead and re-place everything it held: requests
        restart from their prompt on a peer (at-least-once; the dead
        instance's partial tokens are regenerated)."""
        if inst.dead:
            return
        inst.dead = True
        inst.close(timeout=1.0)
        for grid in [g for g, (h, _) in self._placed.items() if h is inst]:
            del self._placed[grid]
            self._retries[grid] += 1
            if self._retries[grid] > self.max_retries:
                raise DeadInstanceError(
                    f"request {grid} lost {self._retries[grid]} instances "
                    f"(max_retries={self.max_retries})")
            self._pending.appendleft((grid, self._wire[grid]))

    # ------------------------------------------------------------- drain ----

    def drain_instance(self, inst: InstanceHandle, *, timeout: float = 60.0):
        """Elastic drain: snapshot ``inst``'s live slots and queue, then
        replay every snapshot into a peer (mid-stream, byte-identical)
        and re-route the queued requests.  ``inst`` afterwards admits
        nothing (``DrainingError`` on submit) and can be shut down."""
        _, (snaps, queued) = inst.call("drain")
        by_rid = {rid: g for g, (h, rid) in self._placed.items()
                  if h is inst}
        for buf in snaps:
            grid = by_rid.get(checkpoint.peek_meta(buf)["rid"])
            if grid is None:
                continue
            del self._placed[grid]
            self._pending_inject.append((grid, buf))
        for wire in queued:
            grid = by_rid.get(wire["rid"])
            if grid is None:
                continue
            del self._placed[grid]
            self._pending.appendleft((grid, self._wire[grid]))
        deadline = time.monotonic() + timeout
        while self._pending_inject:
            self.pump()
            if not self._pending_inject:
                break
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"drain handoff: {len(self._pending_inject)} snapshots "
                    f"still homeless after {timeout:.0f}s")
            time.sleep(0.01)

    # ----------------------------------------------------------- results ----

    def outstanding(self) -> int:
        return len(self._wire) - len(self._results)

    def run_until_done(self, *, timeout: float = 600.0) -> List[dict]:
        """Pump until every submitted request finished; results ordered
        by global rid, each annotated with router-clock latency/ttft."""
        deadline = time.monotonic() + timeout
        while self.outstanding():
            self.pump()
            if self.outstanding() and time.monotonic() > deadline:
                raise TimeoutError(
                    f"{self.outstanding()} requests unfinished after "
                    f"{timeout:.0f}s (pending={len(self._pending)}, "
                    f"placed={len(self._placed)})")
            if self.outstanding():
                time.sleep(0.002)
        out = []
        for grid in sorted(self._results):
            res = dict(self._results[grid])
            res["grid"] = grid
            res["router_latency"] = self._t_done[grid] - self._t_submit[grid]
            out.append(res)
        return out

    def stats(self) -> dict:
        """Aggregated tier load + per-instance step-time samples."""
        per = {}
        for inst in self._alive():
            try:
                _, st = inst.call("stats")
            except ConnectionError:
                self._on_death(inst)
                continue
            self.step_times[inst.name].extend(st.pop("step_times", ()))
            per[inst.name] = st
        return {"instances": per, "deferred": self.deferred,
                "dead": [i.name for i in self.instances if i.dead],
                "outstanding": self.outstanding()}

    def shutdown(self):
        for inst in self.instances:
            inst.shutdown()
        if self.prefill_worker is not None:
            self.prefill_worker.shutdown()
