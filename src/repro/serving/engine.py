"""Slot-based continuous-batching serving engine.

The engine owns a fixed-shape ``models.DecodeState`` of ``slots`` rows on
a (possibly multi-device) mesh and runs ONE compiled decode step per
engine tick for all slots at once — every family in the zoo serves
through the same ``prefill`` / ``decode_step`` contract:

  admit    pop queued requests into free slots: the prompt is right-
           padded to a length BUCKET and prefilled (per-row ``length``
           masking keeps the padded prefill exactly equal to an unpadded
           one), then the request's fresh state is scattered into its
           slot with ``models.write_slots``.  Compile count is bounded
           by the bucket list, not by prompt lengths.
  decode   one jitted step: every slot consumes its last token at its
           own position (``DecodeState.pos`` is per-row) and samples the
           next (greedy / temperature / top-k).  Inactive slots decode
           garbage into their own rows — wasted FLOPs, zero recompiles.
  retire   rows that hit their token budget (or the cache capacity)
           free their slot for the next queued request.

On a mesh, params are replicated and the slot axis of the state is
sharded over the replica ('pod'/'data') axes via
``sharding.specs.cache_sharding``; the decode step donates the state and
pins its output sharding so the layout stays a loop invariant.

Vision rides the same admission loop: a conv-family engine (AlexNet)
treats each request's ``image`` as a one-token generation — freshly
freed slots are classified as ONE batched compiled forward
(``_admit_images``), and ``max_new_tokens == 1`` retires them before any
decode tick; vlm requests carrying raw ``image`` pixels get them encoded
to patch embeddings at submit() and prefill like any other prompt.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import models, numerics
from repro.serving import sampling

DEFAULT_BUCKETS = (16, 32, 64, 128, 256, 512)

# compiled decode/prefill steps are shared ACROSS engine instances (keyed
# by everything that shapes the computation: config identity, sampling
# settings, slot/capacity shapes, mesh) — a fresh engine on the same
# model serves its first request without recompiling anything
_DECODE_FNS: Dict[tuple, Any] = {}
_PREFILL_FNS: Dict[tuple, Any] = {}
_IMAGE_FNS: Dict[tuple, Any] = {}


def _replica_lead(mesh):
    from repro.launch.mesh import replica_axes_of
    axes = replica_axes_of(mesh)
    return axes, (axes if len(axes) > 1 else axes[0])


def _state_sharding(cfg, slots, capacity, enc_len, mesh):
    """DecodeState shardings: slot axis over the replica axes."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.sharding.specs import cache_sharding
    axes, lead = _replica_lead(mesh)
    cache = jax.eval_shape(
        lambda: models.init_decode_cache(cfg, slots, capacity, enc_len))
    return models.DecodeState(
        cache=cache_sharding(cache, cfg, mesh, batch_axes=axes),
        pos=NamedSharding(mesh, P(lead)))


def _decode_fn(cfg, temperature, top_k, slots, capacity, enc_len, mesh):
    key = (cfg, temperature, top_k, slots, capacity, enc_len, mesh)
    if key not in _DECODE_FNS:
        def decode(params, state, toks, rng):
            logits, state = models.decode_step(params, cfg, state, toks)
            tok = sampling.sample(rng, logits[:, 0],
                                  temperature=temperature, top_k=top_k)
            return tok[:, None], state

        kw = {}
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            _, lead = _replica_lead(mesh)
            kw["out_shardings"] = (
                NamedSharding(mesh, P(lead, None)),
                _state_sharding(cfg, slots, capacity, enc_len, mesh))
        _DECODE_FNS[key] = jax.jit(decode, donate_argnums=(1,), **kw)
    return _DECODE_FNS[key]


def _prefill_fn(cfg, temperature, top_k, capacity, bucket):
    key = (cfg, temperature, top_k, capacity, bucket)
    if key not in _PREFILL_FNS:
        def prefill(params, tokens, length, extras, rng):
            logits, sub = models.prefill(params, cfg, tokens, capacity,
                                         length=length, **extras)
            last = logits[jnp.arange(tokens.shape[0]), length - 1]
            tok = sampling.sample(rng, last, temperature=temperature,
                                  top_k=top_k)
            return tok[:, None], sub

        _PREFILL_FNS[key] = jax.jit(prefill)
    return _PREFILL_FNS[key]


def _image_fn(cfg, temperature, top_k, bucket):
    """Compiled image-classification 'prefill' for the conv family: one
    forward over a (bucket, H, W, C) batch, one sampled class id per row.
    Cached per (config, sampling, bucket) like the token prefills."""
    key = (cfg, temperature, top_k, bucket)
    if key not in _IMAGE_FNS:
        from repro.models import alexnet

        def classify(params, images, rng):
            logits = alexnet.forward(params, cfg, images)
            return sampling.sample(rng, logits, temperature=temperature,
                                   top_k=top_k)

        _IMAGE_FNS[key] = jax.jit(classify)
    return _IMAGE_FNS[key]


@dataclasses.dataclass
class Request:
    """One generation request (tokens in, tokens out).

    ``image`` is the raw-pixels entry point: for the conv family it IS
    the request (an (image_size, image_size, in_channels) array; the
    prompt is ignored and the result is one class id); for the vlm
    family ``submit`` encodes it to patch embeddings and prepends
    ``n_image_tokens`` placeholder positions to the prompt."""
    prompt: Any = ()                   # (L,) int sequence
    max_new_tokens: int = 32
    frames: Any = None                 # encdec: encoder input (T_enc, d)
    image: Any = None                  # conv / vlm: raw (H, W, C) pixels
    image_embeds: Any = None           # vlm (precomputed; wins over image)
    image_mask: Any = None             # vlm (over the PADDED prompt)
    rid: int = -1                      # assigned by submit()


@dataclasses.dataclass
class Result:
    rid: int
    prompt_len: int
    tokens: List[int]                  # generated ids (first from prefill)
    t_submit: float
    t_first: float                     # first token emitted (prefill done)
    t_done: float

    @property
    def ttft(self) -> float:
        return self.t_first - self.t_submit

    @property
    def latency(self) -> float:
        return self.t_done - self.t_submit


class ServingEngine:
    def __init__(self, params, cfg, *, slots: int = 4, capacity: int = 256,
                 buckets=None, temperature: float = 0.0, top_k: int = 0,
                 eos_id: Optional[int] = None, mesh=None, seed: int = 0,
                 enc_len: int = 64):
        self.cfg, self.slots, self.capacity = cfg, slots, capacity
        bs = tuple(sorted(b for b in (buckets or DEFAULT_BUCKETS)
                          if b <= capacity))
        if not bs or bs[-1] < capacity:
            bs += (capacity,)     # any prompt that fits the ring is admissible
        self.buckets = bs
        self.temperature, self.top_k, self.eos_id = temperature, top_k, eos_id
        self.mesh, self.enc_len = mesh, enc_len
        self.rng = jax.random.PRNGKey(seed)
        self.state = models.init_decode_state(cfg, slots, capacity,
                                              enc_len=enc_len)
        self.last_tok = jnp.zeros((slots, 1), jnp.int32)
        self._active: List[Optional[Request]] = [None] * slots
        self._results: Dict[int, Result] = {}
        self._queue: collections.deque = collections.deque()
        self._next_rid = 0
        self._buckets_used: set = set()
        self.decode_steps = 0          # compiled-step counter (ticks)

        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            axes, lead = _replica_lead(mesh)
            assert slots % int(np.prod([dict(zip(mesh.axis_names,
                                                 mesh.devices.shape))[a]
                                        for a in axes])) == 0, \
                (slots, mesh.shape)
            shard = _state_sharding(cfg, slots, capacity, enc_len, mesh)
            params = jax.device_put(
                params, jax.tree.map(lambda _: NamedSharding(mesh, P()),
                                     params))
            self.state = jax.device_put(self.state, shard)
            self.last_tok = jax.device_put(
                self.last_tok, NamedSharding(mesh, P(lead, None)))
        self.params = params
        self._decode = _decode_fn(cfg, temperature, top_k, slots, capacity,
                                  enc_len, mesh)

    # ----------------------------------------------------------- compile ----

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        raise ValueError(f"prompt length {n} exceeds the largest bucket "
                         f"{self.buckets[-1]} (capacity {self.capacity})")

    def _prefill_fn(self, bucket: int):
        """One compiled prefill per (config, bucket) — the compile bound —
        shared across engine instances."""
        self._buckets_used.add(bucket)
        return _prefill_fn(self.cfg, self.temperature, self.top_k,
                           self.capacity, bucket)

    @property
    def prefill_compiles(self) -> int:
        return len(self._buckets_used)

    # ------------------------------------------------------------- queue ----

    def submit(self, request: Request) -> int:
        if self.cfg.family == "conv":
            expect = (self.cfg.image_size, self.cfg.image_size,
                      self.cfg.in_channels)
            img = None if request.image is None \
                else np.asarray(request.image, np.float32)
            if img is None or img.shape != expect:
                raise ValueError(
                    f"conv-family request needs image of shape {expect}, "
                    f"got {None if img is None else img.shape}")
            request.image = img
            request.max_new_tokens = 1     # one class id per image
            prompt_len = 0
        else:
            if (self.cfg.family == "vlm" and request.image is not None
                    and request.image_embeds is None):
                # encode now (host-side, deterministic) and reserve the
                # image's positions at the front of the prompt, so the
                # bucket check below sees the true prefill length
                from repro.models import vision
                request.image_embeds = vision.encode_image(self.cfg,
                                                           request.image)
                n_img = self.cfg.n_image_tokens
                request.prompt = np.concatenate(
                    [np.zeros((n_img,), np.int32),
                     np.asarray(request.prompt, np.int32)])
                request.image_mask = np.arange(len(request.prompt)) < n_img
            if len(request.prompt) < 1:
                raise ValueError("empty prompt: there is no position to "
                                 "sample the first token from")
            self._bucket(len(request.prompt))  # reject overlong NOW
            prompt_len = len(request.prompt)
        request.rid = self._next_rid
        self._next_rid += 1
        self._results[request.rid] = Result(
            rid=request.rid, prompt_len=prompt_len, tokens=[],
            t_submit=time.perf_counter(), t_first=0.0, t_done=0.0)
        self._queue.append(request)
        return request.rid

    def _extras(self, req: Request, bucket: int) -> dict:
        cfg = self.cfg
        if cfg.family == "encdec":
            frames = req.frames
            if frames is None:
                frames = np.zeros((self.enc_len, cfg.d_model), np.float32)
            frames = np.asarray(frames)
            if frames.shape != (self.enc_len, cfg.d_model):
                # the cross-cache slots are built at enc_len — a mismatched
                # request would fail deep inside write_slots otherwise
                raise ValueError(
                    f"request frames shape {frames.shape} != engine "
                    f"(enc_len={self.enc_len}, d_model={cfg.d_model})")
            return {"frames": jnp.asarray(frames,
                                          numerics.param_dtype(cfg))[None]}
        if cfg.family == "vlm":
            emb = req.image_embeds
            if emb is None:
                emb = np.zeros((max(cfg.n_image_tokens, 1), cfg.d_model),
                               np.float32)
            mask = req.image_mask
            if mask is None:
                mask = np.zeros((bucket,), bool)
            else:
                mask = np.asarray(mask, bool)
                mask = np.pad(mask, (0, bucket - mask.shape[0]))
            return {"image_embeds": jnp.asarray(emb, numerics.param_dtype(cfg))[None],
                    "image_mask": jnp.asarray(mask)[None]}
        return {}

    def _admit(self, req: Request, slot: int) -> None:
        prompt = np.asarray(req.prompt, np.int32)
        bucket = self._bucket(len(prompt))
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :len(prompt)] = prompt
        if self.temperature == 0.0:
            k = self.rng
        else:
            self.rng, k = jax.random.split(self.rng)
        first, sub = self._prefill_fn(bucket)(
            self.params, jnp.asarray(toks),
            jnp.asarray([len(prompt)], jnp.int32), self._extras(req, bucket),
            k)
        self.state = models.write_slots(self.state, sub, [slot])
        self.last_tok = self.last_tok.at[slot].set(first[0])
        self._active[slot] = req
        res = self._results[req.rid]
        res.tokens.append(int(first[0, 0]))
        res.t_first = time.perf_counter()

    def _admit_images(self, reqs: List[Request], slots: List[int]) -> None:
        """Conv-family admission: ONE compiled forward classifies every
        freshly-admitted image (rows zero-padded up to a power-of-two
        bucket to bound compiles), then the class ids land in the rows'
        results.  ``max_new_tokens == 1`` retires the rows at the next
        fixpoint iteration — the decode step never traces for conv."""
        bucket = 1
        while bucket < len(reqs):
            bucket *= 2
        self._buckets_used.add(("img", bucket))
        cfg = self.cfg
        imgs = np.zeros((bucket, cfg.image_size, cfg.image_size,
                         cfg.in_channels), np.float32)
        for i, req in enumerate(reqs):
            imgs[i] = req.image
        if self.temperature == 0.0:
            k = self.rng
        else:
            self.rng, k = jax.random.split(self.rng)
        toks = _image_fn(cfg, self.temperature, self.top_k, bucket)(
            self.params, jnp.asarray(imgs), k)
        host = np.asarray(toks)
        # slot surgery still runs (the conv cache is the empty pytree, so
        # only pos moves) — rows read as occupied like any other family's
        sub = models.DecodeState(cache={},
                                 pos=jnp.ones((len(reqs),), jnp.int32))
        self.state = models.write_slots(self.state, sub, slots)
        self.last_tok = self.last_tok.at[jnp.asarray(slots)].set(
            jnp.asarray(host[:len(reqs), None]))
        now = time.perf_counter()
        for i, (slot, req) in enumerate(zip(slots, reqs)):
            self._active[slot] = req
            res = self._results[req.rid]
            res.tokens.append(int(host[i]))
            res.t_first = now

    def _retire(self, slot: int, now: float) -> Result:
        req = self._active[slot]
        self._active[slot] = None
        # hand the Result to the caller and forget it — a long-lived
        # engine must not accumulate one token list per request forever
        res = self._results.pop(req.rid)
        res.t_done = now
        return res

    def _hit_limits(self, req: Request) -> bool:
        """True if the row must not consume another decode tick: budget
        already met (single-token requests finish at prefill), or the
        ring is full — one more tick would silently window the context
        (pos[slot] == prompt_len + len(tokens) - 1 == capacity-1 is the
        last position the cache can hold).  SINGLE copy of the retire
        arithmetic, used both before and after the decode tick."""
        res = self._results[req.rid]
        return (len(res.tokens) >= req.max_new_tokens or
                res.prompt_len + len(res.tokens) - 1 >= self.capacity)

    # -------------------------------------------------------------- step ----

    def step(self) -> List[Result]:
        """Retire finished rows, admit what fits (repeating until the
        admission fixpoint, so a slot freed by a single-token request is
        refilled within the same tick), then run ONE decode tick.

        Returns the requests that finished on this tick."""
        finished = []
        while True:
            now = time.perf_counter()
            for slot, req in enumerate(self._active):
                if req is not None and self._hit_limits(req):
                    finished.append(self._retire(slot, now))
            admitted = False
            batch: List[tuple] = []    # conv family admits as ONE batch
            for slot in range(self.slots):
                if self._active[slot] is None and self._queue:
                    req = self._queue.popleft()
                    if self.cfg.family == "conv":
                        batch.append((slot, req))
                    else:
                        self._admit(req, slot)
                    admitted = True
            if batch:
                self._admit_images([r for _, r in batch],
                                   [s for s, _ in batch])
            if not admitted:
                break
        if not any(self._active) and not self._queue:
            return finished
        if self.temperature == 0.0:
            k = self.rng          # greedy: key unused, skip the eager split
        else:
            self.rng, k = jax.random.split(self.rng)
        toks, self.state = self._decode(self.params, self.state,
                                        self.last_tok, k)
        self.last_tok = toks
        self.decode_steps += 1
        host = np.asarray(toks)                       # device sync point
        now = time.perf_counter()
        for slot, req in enumerate(self._active):
            if req is None:
                continue
            res = self._results[req.rid]
            res.tokens.append(int(host[slot, 0]))
            done = self._hit_limits(req)
            done |= self.eos_id is not None and host[slot, 0] == self.eos_id
            if done:
                finished.append(self._retire(slot, now))
        return finished

    def run(self, requests=None) -> List[Result]:
        """Submit ``requests`` (if given) and step until everything is
        done.  Returns results in completion order."""
        for r in requests or ():
            self.submit(r)
        out = []
        while self._queue or any(r is not None for r in self._active):
            out.extend(self.step())
        return out
