"""Slot-based continuous-batching serving engine.

The engine owns a fixed-shape ``models.DecodeState`` of ``slots`` rows on
a (possibly multi-device) mesh and runs ONE compiled decode step per
engine tick for all slots at once — every family in the zoo serves
through the same ``prefill`` / ``decode_step`` contract:

  admit    pop queued requests into free slots: the prompt is right-
           padded to a length BUCKET and prefilled (per-row ``length``
           masking keeps the padded prefill exactly equal to an unpadded
           one), then the request's fresh state is scattered into its
           slot with ``models.write_slots``.  Compile count is bounded
           by the bucket list, not by prompt lengths.
  decode   one jitted step: every slot consumes its last token at its
           own position (``DecodeState.pos`` is per-row) and samples the
           next (greedy / temperature / top-k).  Inactive slots decode
           garbage into their own rows — wasted FLOPs, zero recompiles.
  retire   rows that hit their token budget (or the cache capacity)
           free their slot for the next queued request.

On a mesh, params are replicated and the slot axis of the state is
sharded over the replica ('pod'/'data') axes via
``sharding.specs.cache_sharding``; the decode step donates the state and
pins its output sharding so the layout stays a loop invariant.

Vision rides the same admission loop: a conv-family engine (AlexNet)
treats each request's ``image`` as a one-token generation — freshly
freed slots are classified as ONE batched compiled forward
(``_admit_images``), and ``max_new_tokens == 1`` retires them before any
decode tick; vlm requests carrying raw ``image`` pixels get them encoded
to patch embeddings at submit() and prefill like any other prompt.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import models, numerics
from repro.serving import sampling

DEFAULT_BUCKETS = (16, 32, 64, 128, 256, 512)


class DrainingError(RuntimeError):
    """submit() on a draining engine: the instance is snapshotting its
    live slots for handoff and must not take on new work.  The router
    (serving/router.py) catches this and re-routes to a peer."""

# compiled decode/prefill steps are shared ACROSS engine instances (keyed
# by everything that shapes the computation: config identity, sampling
# settings, slot/capacity shapes, mesh) — a fresh engine on the same
# model serves its first request without recompiling anything
_DECODE_FNS: Dict[tuple, Any] = {}
_PREFILL_FNS: Dict[tuple, Any] = {}
_IMAGE_FNS: Dict[tuple, Any] = {}


def _replica_lead(mesh):
    from repro.launch.mesh import replica_axes_of
    axes = replica_axes_of(mesh)
    return axes, (axes if len(axes) > 1 else axes[0])


def _state_sharding(cfg, slots, capacity, enc_len, mesh):
    """DecodeState shardings: slot axis over the replica axes."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.sharding.specs import cache_sharding
    axes, lead = _replica_lead(mesh)
    cache = jax.eval_shape(
        lambda: models.init_decode_cache(cfg, slots, capacity, enc_len))
    return models.DecodeState(
        cache=cache_sharding(cache, cfg, mesh, batch_axes=axes),
        pos=NamedSharding(mesh, P(lead)))


def _to_host(x):
    """THE device->host sync of the decode loop.  ``step()`` calls this
    exactly once per dispatch, on one packed (2, slots, K) array — the
    token block and the device-computed retire flags cross together
    (tests/serving/test_multi_tick.py counts calls to this hook)."""
    return np.asarray(x)


def _decode_fn(cfg, temperature, top_k, slots, capacity, enc_len, mesh,
               ticks, eos_id, blocked=False):
    key = (cfg, temperature, top_k, slots, capacity, enc_len, mesh, ticks,
           eos_id, blocked)
    if key not in _DECODE_FNS:
        def decode(params, state, toks, keys, table=None):
            # K device-resident ticks in ONE dispatch: sampled tokens,
            # per-row positions and eos flags never leave the device
            # between ticks; the scan donates the state through.  Rows
            # that retire mid-block keep decoding garbage — their extra
            # tokens are dropped host-side at drain, and re-admission's
            # write_slots overwrites the whole row anyway.
            def tick(carry, _):
                state, toks = carry
                logits, state = models.decode_step(params, cfg, state, toks,
                                                   table=table)
                tok = sampling.sample_slots(keys, state.pos, logits[:, 0],
                                            temperature=temperature,
                                            top_k=top_k)
                return (state, tok[:, None]), tok

            (state, last), seq = jax.lax.scan(tick, (state, toks), None,
                                              length=ticks)
            block = seq.T                              # (slots, K)
            flags = (jnp.zeros(block.shape, jnp.int32) if eos_id is None
                     else (block == eos_id).astype(jnp.int32))
            return jnp.stack([block, flags]), last, state

        kw = {}
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            _, lead = _replica_lead(mesh)
            kw["out_shardings"] = (
                NamedSharding(mesh, P(None, lead, None)),
                NamedSharding(mesh, P(lead, None)),
                _state_sharding(cfg, slots, capacity, enc_len, mesh))
        _DECODE_FNS[key] = jax.jit(decode, donate_argnums=(1,), **kw)
    return _DECODE_FNS[key]


def _prefill_fn(cfg, temperature, top_k, capacity, bucket):
    key = (cfg, temperature, top_k, capacity, bucket)
    if key not in _PREFILL_FNS:
        def prefill(params, tokens, length, extras, key):
            logits, sub = models.prefill(params, cfg, tokens, capacity,
                                         length=length, **extras)
            last = logits[jnp.arange(tokens.shape[0]), length - 1]
            # the first generated token sits at absolute position
            # ``length`` — sampled with the same positional fold_in rule
            # the decode loop uses, so streams do not depend on admission
            # order or tick batching
            tok = sampling.sample_slots(key[None], length, last,
                                        temperature=temperature, top_k=top_k)
            return tok[:, None], sub

        _PREFILL_FNS[key] = jax.jit(prefill)
    return _PREFILL_FNS[key]


def _image_fn(cfg, temperature, top_k, bucket):
    """Compiled image-classification 'prefill' for the conv family: one
    forward over a (bucket, H, W, C) batch, one sampled class id per row.
    Cached per (config, sampling, bucket) like the token prefills."""
    key = (cfg, temperature, top_k, bucket)
    if key not in _IMAGE_FNS:
        from repro.models import alexnet

        def classify(params, images, rng):
            logits = alexnet.forward(params, cfg, images)
            return sampling.sample(rng, logits, temperature=temperature,
                                   top_k=top_k)

        _IMAGE_FNS[key] = jax.jit(classify)
    return _IMAGE_FNS[key]


@dataclasses.dataclass
class Request:
    """One generation request (tokens in, tokens out).

    ``image`` is the raw-pixels entry point: for the conv family it IS
    the request (an (image_size, image_size, in_channels) array; the
    prompt is ignored and the result is one class id); for the vlm
    family ``submit`` encodes it to patch embeddings and prepends
    ``n_image_tokens`` placeholder positions to the prompt."""
    prompt: Any = ()                   # (L,) int sequence
    max_new_tokens: int = 32
    frames: Any = None                 # encdec: encoder input (T_enc, d)
    image: Any = None                  # conv / vlm: raw (H, W, C) pixels
    image_embeds: Any = None           # vlm (precomputed; wins over image)
    image_mask: Any = None             # vlm (over the PADDED prompt)
    rid: int = -1                      # assigned by submit()


@dataclasses.dataclass
class Result:
    rid: int
    prompt_len: int
    tokens: List[int]                  # generated ids (first from prefill)
    t_submit: float
    t_first: float                     # first token emitted (prefill done)
    t_done: float
    draft_proposed: int = 0            # spec decode: draft tokens offered
    draft_accepted: int = 0            # ... of which the target kept

    @property
    def ttft(self) -> float:
        return self.t_first - self.t_submit

    @property
    def latency(self) -> float:
        return self.t_done - self.t_submit

    @property
    def acceptance(self) -> float:
        return self.draft_accepted / max(self.draft_proposed, 1)


class ServingEngine:
    def __init__(self, params, cfg, *, slots: int = 4, capacity: int = 256,
                 buckets=None, temperature: float = 0.0, top_k: int = 0,
                 eos_id: Optional[int] = None, mesh=None, seed: int = 0,
                 enc_len: int = 64, ticks_per_dispatch: int = 1,
                 draft_params=None, draft_cfg=None, spec_tokens: int = 4,
                 block_size: int = 0, num_blocks: int = 0,
                 prefix_dedup: bool = True):
        self.cfg, self.slots, self.capacity = cfg, slots, capacity
        bs = tuple(sorted(b for b in (buckets or DEFAULT_BUCKETS)
                          if b <= capacity))
        if not bs or bs[-1] < capacity:
            bs += (capacity,)     # any prompt that fits the ring is admissible
        self.buckets = bs
        self.temperature, self.top_k, self.eos_id = temperature, top_k, eos_id
        self.mesh, self.enc_len = mesh, enc_len
        if ticks_per_dispatch < 1:
            raise ValueError(f"ticks_per_dispatch must be >= 1, "
                             f"got {ticks_per_dispatch}")
        self.ticks = ticks_per_dispatch
        if (draft_params is None) != (draft_cfg is None):
            raise ValueError("speculative decoding needs BOTH draft_params "
                             "and draft_cfg (or neither)")
        self.draft_cfg, self.spec_tokens = draft_cfg, int(spec_tokens)
        self.draft_state = None
        self.spec_proposed = 0         # draft tokens offered, engine-wide
        self.spec_accepted = 0         # ... kept by the target
        if draft_cfg is not None:
            from repro.serving import spec_decode
            if self.spec_tokens < 0:
                raise ValueError(
                    f"spec_tokens must be >= 0, got {spec_tokens}")
            spec_decode.check_spec_pair(cfg, draft_cfg,
                                        temperature=temperature,
                                        ticks=self.ticks)
            self.draft_state = models.init_decode_state(
                draft_cfg, slots, capacity, enc_len=enc_len)
        self.block_size = int(block_size)
        self.block_mgr = None
        self.table = None
        if self.block_size > 0:
            from repro.serving import blocks as blk
            if mesh is not None:
                raise NotImplementedError("block-table serving is "
                                          "single-device for now")
            if cfg.family not in ("dense", "moe"):
                raise NotImplementedError(
                    f"block-table caches need a pure-attention family "
                    f"(dense/moe), got {cfg.family!r} ({cfg.name})")
            if cfg.sliding_window is not None:
                raise NotImplementedError(
                    "block-table caches need full attention: a windowed "
                    "ring (cap < seq) wraps and would overwrite shared "
                    "blocks")
            if self.ticks != 1 or draft_cfg is not None:
                raise ValueError("block-table serving composes with "
                                 "neither multi-tick (rows must retire "
                                 "before the ring wraps) nor spec decode "
                                 "yet")
            if capacity % self.block_size:
                raise ValueError(f"capacity {capacity} not a multiple of "
                                 f"block_size {self.block_size}")
            self.n_k = capacity // self.block_size
            # default pool: fully private provisioning + the trash block —
            # sharing then makes it oversubscribable (pass num_blocks)
            nb = int(num_blocks) or slots * self.n_k + 1
            self.block_mgr = blk.BlockManager(nb, self.block_size,
                                              dedup=prefix_dedup,
                                              prefill_once=temperature == 0.0)
            self.table = jnp.zeros((slots, self.n_k), jnp.int32)
            self._slot_adm: List[Optional[Any]] = [None] * slots
        self.rng = jax.random.PRNGKey(seed)   # base key: slot keys fold rid
        if self.block_size > 0:
            from repro.serving import blocks as blk
            self.state = blk.init_blocked_state(cfg, self.block_mgr.nb,
                                                self.block_size, slots)
        else:
            self.state = models.init_decode_state(cfg, slots, capacity,
                                                  enc_len=enc_len)
        self.last_tok = jnp.zeros((slots, 1), jnp.int32)
        self.slot_keys = jnp.broadcast_to(
            self.rng, (slots,) + self.rng.shape)
        self._active: List[Optional[Request]] = [None] * slots
        self._results: Dict[int, Result] = {}
        self._queue: collections.deque = collections.deque()
        self._draining = False
        self._next_rid = 0
        self._buckets_used: set = set()
        self.decode_steps = 0          # model ticks run (K per dispatch)
        self.dispatches = 0            # compiled decode dispatches

        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            axes, lead = _replica_lead(mesh)
            assert slots % int(np.prod([dict(zip(mesh.axis_names,
                                                 mesh.devices.shape))[a]
                                        for a in axes])) == 0, \
                (slots, mesh.shape)
            shard = _state_sharding(cfg, slots, capacity, enc_len, mesh)
            params = jax.device_put(
                params, jax.tree.map(lambda _: NamedSharding(mesh, P()),
                                     params))
            self.state = jax.device_put(self.state, shard)
            self.last_tok = jax.device_put(
                self.last_tok, NamedSharding(mesh, P(lead, None)))
            self.slot_keys = jax.device_put(
                self.slot_keys, NamedSharding(mesh, P(lead, None)))
            if draft_cfg is not None:
                draft_params = jax.device_put(
                    draft_params,
                    jax.tree.map(lambda _: NamedSharding(mesh, P()),
                                 draft_params))
                self.draft_state = jax.device_put(
                    self.draft_state,
                    _state_sharding(draft_cfg, slots, capacity, enc_len,
                                    mesh))
        self.params = params
        self.draft_params = draft_params
        if draft_cfg is not None:
            from repro.serving import spec_decode
            self._spec = spec_decode.spec_fn(cfg, draft_cfg, self.spec_tokens,
                                             slots, capacity, enc_len, mesh,
                                             eos_id)
        else:
            self._spec = None
        self._decode = _decode_fn(cfg, temperature, top_k, slots, capacity,
                                  enc_len, mesh, self.ticks, eos_id,
                                  blocked=self.block_size > 0)

    # ----------------------------------------------------------- compile ----

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        raise ValueError(f"prompt length {n} exceeds the largest bucket "
                         f"{self.buckets[-1]} (capacity {self.capacity})")

    def _prefill_fn(self, bucket: int):
        """One compiled prefill per (config, bucket) — the compile bound —
        shared across engine instances."""
        self._buckets_used.add(bucket)
        return _prefill_fn(self.cfg, self.temperature, self.top_k,
                           self.capacity, bucket)

    @property
    def prefill_compiles(self) -> int:
        return len(self._buckets_used)

    # ------------------------------------------------------------- queue ----

    def submit(self, request: Request) -> int:
        if self._draining:
            raise DrainingError(
                "engine is draining (handoff in progress): submit to a "
                "peer instance instead")
        if self.cfg.family == "conv":
            expect = (self.cfg.image_size, self.cfg.image_size,
                      self.cfg.in_channels)
            img = None if request.image is None \
                else np.asarray(request.image, np.float32)
            if img is None or img.shape != expect:
                raise ValueError(
                    f"conv-family request needs image of shape {expect}, "
                    f"got {None if img is None else img.shape}")
            request.image = img
            request.max_new_tokens = 1     # one class id per image
            prompt_len = 0
        else:
            if (self.cfg.family == "vlm" and request.image is not None
                    and request.image_embeds is None):
                # encode now (host-side, deterministic) and reserve the
                # image's positions at the front of the prompt, so the
                # bucket check below sees the true prefill length
                from repro.models import vision
                request.image_embeds = vision.encode_image(self.cfg,
                                                           request.image)
                n_img = self.cfg.n_image_tokens
                request.prompt = np.concatenate(
                    [np.zeros((n_img,), np.int32),
                     np.asarray(request.prompt, np.int32)])
                request.image_mask = np.arange(len(request.prompt)) < n_img
            if len(request.prompt) < 1:
                raise ValueError("empty prompt: there is no position to "
                                 "sample the first token from")
            self._bucket(len(request.prompt))  # reject overlong NOW
            prompt_len = len(request.prompt)
        request.rid = self._next_rid
        self._next_rid += 1
        self._results[request.rid] = Result(
            rid=request.rid, prompt_len=prompt_len, tokens=[],
            t_submit=time.perf_counter(), t_first=0.0, t_done=0.0)
        self._queue.append(request)
        return request.rid

    def _extras(self, req: Request, bucket: int) -> dict:
        cfg = self.cfg
        if cfg.family == "encdec":
            frames = req.frames
            if frames is None:
                frames = np.zeros((self.enc_len, cfg.d_model), np.float32)
            frames = np.asarray(frames)
            if frames.shape != (self.enc_len, cfg.d_model):
                # the cross-cache slots are built at enc_len — a mismatched
                # request would fail deep inside write_slots otherwise
                raise ValueError(
                    f"request frames shape {frames.shape} != engine "
                    f"(enc_len={self.enc_len}, d_model={cfg.d_model})")
            return {"frames": jnp.asarray(frames,
                                          numerics.param_dtype(cfg))[None]}
        if cfg.family == "vlm":
            emb = req.image_embeds
            if emb is None:
                emb = np.zeros((max(cfg.n_image_tokens, 1), cfg.d_model),
                               np.float32)
            mask = req.image_mask
            if mask is None:
                mask = np.zeros((bucket,), bool)
            else:
                mask = np.asarray(mask, bool)
                mask = np.pad(mask, (0, bucket - mask.shape[0]))
            return {"image_embeds": jnp.asarray(emb, numerics.param_dtype(cfg))[None],
                    "image_mask": jnp.asarray(mask)[None]}
        return {}

    def _admit(self, req: Request, slot: int) -> bool:
        """Prefill ``req`` into ``slot``.  Returns False (request NOT
        consumed) only in blocked mode when the pool cannot host the row
        yet — the caller defers it instead of failing."""
        prompt = np.asarray(req.prompt, np.int32)
        bucket = self._bucket(len(prompt))
        # the request's OWN key, derived from its rid — sampling depends
        # only on (request, position), never on admission order
        req_key = sampling.slot_key(self.rng, req.rid)
        if self.block_mgr is not None:
            tok = self._admit_blocked(req, slot, bucket, req_key)
            if tok is None:
                return False
            self.last_tok = self.last_tok.at[slot].set(tok)
            self.slot_keys = self.slot_keys.at[slot].set(req_key)
            self._active[slot] = req
            res = self._results[req.rid]
            res.tokens.append(tok)
            res.t_first = time.perf_counter()
            return True
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :len(prompt)] = prompt
        first, sub = self._prefill_fn(bucket)(
            self.params, jnp.asarray(toks),
            jnp.asarray([len(prompt)], jnp.int32), self._extras(req, bucket),
            req_key)
        self.state = models.write_slots(self.state, sub, [slot])
        if self.draft_cfg is not None:
            # spec decode: the draft consumes the same prompt so its state
            # sits at the same position; its sampled first token is
            # discarded — the stream's first token is the TARGET's
            _, dsub = _prefill_fn(self.draft_cfg, self.temperature,
                                  self.top_k, self.capacity, bucket)(
                self.draft_params, jnp.asarray(toks),
                jnp.asarray([len(prompt)], jnp.int32), {}, req_key)
            self.draft_state = models.write_slots(self.draft_state, dsub,
                                                  [slot])
        self.last_tok = self.last_tok.at[slot].set(first[0])
        self.slot_keys = self.slot_keys.at[slot].set(req_key)
        self._active[slot] = req
        res = self._results[req.rid]
        res.tokens.append(int(first[0, 0]))
        res.t_first = time.perf_counter()
        return True

    def _admit_blocked(self, req: Request, slot: int, bucket: int,
                       req_key) -> Optional[int]:
        """Blocked-pool admission: place the row's table, then either
        skip the forward entirely (exact-prompt hit: shared blocks + COW
        tail clone + cached first token) or prefill and scatter into the
        row's blocks.  Returns the first token, or None to defer."""
        from repro.serving import blocks as blk
        prompt = np.asarray(req.prompt, np.int32)
        adm = self.block_mgr.admit(prompt, self.n_k)
        if adm is None:
            return None                   # pool exhausted — defer
        self.table = self.table.at[slot].set(jnp.asarray(adm.table,
                                                         jnp.int32))
        self._slot_adm[slot] = adm
        if adm.first_token is not None:
            for dst, src in adm.cow:      # tail clone: the row WILL write
                self.state = blk.copy_block(self.state, dst, src)
            self.state = models.DecodeState(
                cache=self.state.cache,
                pos=self.state.pos.at[slot].set(len(prompt)))
            return adm.first_token
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :len(prompt)] = prompt
        first, sub = self._prefill_fn(bucket)(
            self.params, jnp.asarray(toks),
            jnp.asarray([len(prompt)], jnp.int32), self._extras(req, bucket),
            req_key)
        self.state = blk.write_prefill(self.state, sub, adm.table, slot,
                                       self.block_size)
        if adm.snapshot is not None:
            # snapshot the tail block NOW, before any decode write dirties
            # it — future exact-prompt admissions clone from this copy
            tail_blk = adm.table[len(prompt) // self.block_size]
            self.state = blk.copy_block(self.state, adm.snapshot, tail_blk)
        tok = int(first[0, 0])
        self.block_mgr.finish(adm, tok)
        return tok

    def _admit_images(self, reqs: List[Request], slots: List[int]) -> None:
        """Conv-family admission: ONE compiled forward classifies every
        freshly-admitted image (rows zero-padded up to a power-of-two
        bucket to bound compiles), then the class ids land in the rows'
        results.  ``max_new_tokens == 1`` retires the rows at the next
        fixpoint iteration — the decode step never traces for conv."""
        bucket = 1
        while bucket < len(reqs):
            bucket *= 2
        self._buckets_used.add(("img", bucket))
        cfg = self.cfg
        imgs = np.zeros((bucket, cfg.image_size, cfg.image_size,
                         cfg.in_channels), np.float32)
        for i, req in enumerate(reqs):
            imgs[i] = req.image
        if self.temperature == 0.0:
            k = self.rng
        else:
            self.rng, k = jax.random.split(self.rng)
        toks = _image_fn(cfg, self.temperature, self.top_k, bucket)(
            self.params, jnp.asarray(imgs), k)
        host = np.asarray(toks)
        # slot surgery still runs (the conv cache is the empty pytree, so
        # only pos moves) — rows read as occupied like any other family's
        sub = models.DecodeState(cache={},
                                 pos=jnp.ones((len(reqs),), jnp.int32))
        self.state = models.write_slots(self.state, sub, slots)
        self.last_tok = self.last_tok.at[jnp.asarray(slots)].set(
            jnp.asarray(host[:len(reqs), None]))
        now = time.perf_counter()
        for i, (slot, req) in enumerate(zip(slots, reqs)):
            self._active[slot] = req
            res = self._results[req.rid]
            res.tokens.append(int(host[i]))
            res.t_first = now

    def _retire(self, slot: int, now: float) -> Result:
        req = self._active[slot]
        self._active[slot] = None
        if self.block_mgr is not None:
            self.block_mgr.release(self._slot_adm[slot])
            self._slot_adm[slot] = None
            # point the dead row at the trash block: its garbage decode
            # writes land where no live table looks
            self.table = self.table.at[slot].set(
                jnp.zeros((self.n_k,), jnp.int32))
        # hand the Result to the caller and forget it — a long-lived
        # engine must not accumulate one token list per request forever
        res = self._results.pop(req.rid)
        res.t_done = now
        return res

    def _hit_limits(self, req: Request) -> bool:
        """True if the row must not consume another decode tick: budget
        already met (single-token requests finish at prefill), or the
        ring is full — one more tick would silently window the context
        (pos[slot] == prompt_len + len(tokens) - 1 == capacity-1 is the
        last position the cache can hold).  SINGLE copy of the retire
        arithmetic, used both before and after the decode tick."""
        res = self._results[req.rid]
        return (len(res.tokens) >= req.max_new_tokens or
                res.prompt_len + len(res.tokens) - 1 >= self.capacity)

    # ---------------------------------------------------- tier interface ----
    # load inspection + elastic drain/handoff for the multi-process tier
    # (serving/router.py spreads on the stats; serving/tier.py ships the
    # snapshots between processes through checkpoint.pack_tree)

    @property
    def free_slots(self) -> int:
        return sum(r is None for r in self._active)

    @property
    def queue_len(self) -> int:
        return len(self._queue)

    def load(self) -> dict:
        """Router-facing load signal: slots free NOW, queued behind them,
        and whether the instance still admits."""
        return {"free_slots": self.free_slots, "queue_len": self.queue_len,
                "active": self.slots - self.free_slots,
                "draining": self._draining}

    def export_slot(self, slot: int) -> dict:
        """Snapshot one live slot for handoff: the row's DecodeState
        slice (``models.read_slots``), its last sampled token, its
        positional sampling key, and the request/result bookkeeping.
        Replaying the snapshot into ANY free slot of a same-shape engine
        (``import_snapshot``) continues the stream byte-identically —
        sampling is positional (slot_key x pos), retire arithmetic is
        (prompt_len, len(tokens), capacity), and neither depends on the
        slot index or on the peers' traffic."""
        req = self._active[slot]
        if req is None:
            raise ValueError(f"slot {slot} is not active")
        res = self._results[req.rid]
        sub = models.read_slots(self.state, [slot])
        return {
            "arrays": {
                "cache": jax.device_get(sub.cache),
                "pos": jax.device_get(sub.pos),
                "last_tok": jax.device_get(self.last_tok[slot:slot + 1]),
                "slot_key": jax.device_get(
                    jax.random.key_data(self.slot_keys[slot])
                    if jnp.issubdtype(self.slot_keys.dtype, jax.dtypes.prng_key)
                    else self.slot_keys[slot]),
            },
            "meta": {
                "rid": int(req.rid),         # engine-LOCAL id: the router
                "prompt": np.asarray(req.prompt, np.int64).tolist(),  # maps
                "max_new_tokens": int(req.max_new_tokens),   # it to its own
                "prompt_len": res.prompt_len,
                "tokens": list(res.tokens),
                "t_submit": res.t_submit, "t_first": res.t_first,
                "draft_proposed": res.draft_proposed,
                "draft_accepted": res.draft_accepted,
            },
        }

    def import_snapshot(self, snap: dict) -> Optional[int]:
        """Replay an ``export_slot`` snapshot into a free slot.  Returns
        the request's NEW (engine-local) rid, or None when no slot is
        free — the caller holds the snapshot and retries after a step.
        Also the disaggregation entry point: a prefill worker's output is
        the same snapshot shape (serving/tier.py), so a decode instance
        admits prefilled work without ever running a prefill itself."""
        slot = next((s for s, r in enumerate(self._active) if r is None),
                    None)
        if slot is None:
            return None
        arrays, meta = snap["arrays"], snap["meta"]
        sub = models.DecodeState(
            cache=jax.tree.map(jnp.asarray, arrays["cache"]),
            pos=jnp.asarray(arrays["pos"]))
        self.state = models.write_slots(self.state, sub, [slot])
        self.last_tok = self.last_tok.at[slot].set(
            jnp.asarray(arrays["last_tok"][0]))
        key = jnp.asarray(arrays["slot_key"])
        if jnp.issubdtype(self.slot_keys.dtype, jax.dtypes.prng_key):
            key = jax.random.wrap_key_data(key)
        self.slot_keys = self.slot_keys.at[slot].set(key)
        req = Request(prompt=np.asarray(meta["prompt"], np.int32),
                      max_new_tokens=meta["max_new_tokens"],
                      rid=self._next_rid)
        self._next_rid += 1
        self._active[slot] = req
        self._results[req.rid] = Result(
            rid=req.rid, prompt_len=meta["prompt_len"],
            tokens=list(meta["tokens"]),
            t_submit=meta["t_submit"], t_first=meta["t_first"], t_done=0.0,
            draft_proposed=meta["draft_proposed"],
            draft_accepted=meta["draft_accepted"])
        return req.rid

    def drain(self) -> tuple:
        """Elastic drain: stop admitting, snapshot every live slot, hand
        back the untouched queue.  Returns (snapshots, queued_requests);
        afterwards the engine is empty and rejects submits
        (``DrainingError``) — the router replays the snapshots into
        peers, so a rolling restart drops zero requests."""
        if self.block_mgr is not None:
            raise NotImplementedError(
                "drain: block-pool tables index a process-local pool; "
                "export/replay needs the dense ring layout")
        if self.draft_cfg is not None:
            raise NotImplementedError(
                "drain: spec engines would need the draft DecodeState "
                "exported alongside the target's")
        self._draining = True
        snaps = [self.export_slot(s) for s, r in enumerate(self._active)
                 if r is not None]
        queued = list(self._queue)
        self._queue.clear()
        for s, r in enumerate(self._active):
            if r is not None:
                self._active[s] = None
        for r in list(self._results):
            self._results.pop(r, None)       # queued rows held Results too
        return snaps, queued

    # -------------------------------------------------------------- step ----

    def step(self) -> List[Result]:
        """Retire finished rows, admit what fits (repeating until the
        admission fixpoint, so a slot freed by a single-token request is
        refilled within the same tick), then run ONE decode dispatch of
        ``ticks_per_dispatch`` device-resident model ticks.

        The host sees device data once per dispatch: a packed
        (2, slots, K) block of sampled tokens + retire flags
        (``_to_host``).  The drain applies exactly the K=1 retirement
        rules tick by tick — token streams are identical for every K;
        a row that finishes at drain-tick j keeps its slot until the
        next dispatch boundary, so retirement latency is bounded by K
        ticks (docs/serving.md).

        Returns the requests that finished on this step."""
        finished = []
        while True:
            now = time.perf_counter()
            for slot, req in enumerate(self._active):
                if req is not None and self._hit_limits(req):
                    finished.append(self._retire(slot, now))
            admitted = False
            batch: List[tuple] = []    # conv family admits as ONE batch
            for slot in range(self.slots):
                if self._active[slot] is None and self._queue:
                    req = self._queue.popleft()
                    if self.cfg.family == "conv":
                        batch.append((slot, req))
                        admitted = True
                    elif self._admit(req, slot):
                        admitted = True
                    else:
                        # block pool exhausted: requeue at the FRONT (FIFO
                        # order survives) and stop admitting this round —
                        # retirements will free blocks on later steps
                        self._queue.appendleft(req)
                        break
            if batch:
                self._admit_images([r for _, r in batch],
                                   [s for s, _ in batch])
            if not admitted:
                break
        if not any(self._active) and not self._queue:
            return finished
        if not any(self._active):
            # blocked mode deferred the queue head with an otherwise idle
            # engine — the pool is as free as it will ever get, so waiting
            # cannot help; surface the sizing error instead of spinning
            raise RuntimeError(
                f"block pool ({self.block_mgr.nb} x {self.block_size}) "
                f"cannot host one request of {self.n_k} blocks")
        if self._spec is not None:
            # propose + verify + commit in ONE dispatch; the drain applies
            # the same retirement rules per emitted token, reading each
            # row's accept count from the packed block
            out, self.last_tok, self.state, self.draft_state = self._spec(
                self.params, self.draft_params, self.state, self.draft_state,
                self.last_tok)
            self.decode_steps += 1      # one target pass per dispatch
            self.dispatches += 1
            host = _to_host(out)        # THE device sync point (one/dispatch)
            g1 = self.spec_tokens + 1
            emit, flags, acc = host[:, :g1], host[:, g1:2 * g1], host[:, -1]
            now = time.perf_counter()
            for slot, req in enumerate(self._active):
                if req is None:
                    continue
                res = self._results[req.rid]
                a = int(acc[slot])
                res.draft_proposed += self.spec_tokens
                res.draft_accepted += a - 1
                self.spec_proposed += self.spec_tokens
                self.spec_accepted += a - 1
                for j in range(a):
                    res.tokens.append(int(emit[slot, j]))
                    if self._hit_limits(req) or flags[slot, j]:
                        finished.append(self._retire(slot, now))
                        break
            return finished
        if self.block_mgr is not None:
            out, self.last_tok, self.state = self._decode(
                self.params, self.state, self.last_tok, self.slot_keys,
                self.table)
        else:
            out, self.last_tok, self.state = self._decode(
                self.params, self.state, self.last_tok, self.slot_keys)
        self.decode_steps += self.ticks
        self.dispatches += 1
        host = _to_host(out)            # THE device sync point (one/dispatch)
        block, flags = host[0], host[1]
        now = time.perf_counter()
        for j in range(self.ticks):
            for slot, req in enumerate(self._active):
                if req is None:
                    continue               # retired at an earlier drain tick
                res = self._results[req.rid]
                res.tokens.append(int(block[slot, j]))
                if self._hit_limits(req) or flags[slot, j]:
                    finished.append(self._retire(slot, now))
        return finished

    def run(self, requests=None) -> List[Result]:
        """Submit ``requests`` (if given) and step until everything is
        done.  Returns results in completion order."""
        for r in requests or ():
            self.submit(r)
        out = []
        while self._queue or any(r is not None for r in self._active):
            out.extend(self.step())
        return out
