"""Token sampling for the serving engine: greedy + temperature / top-k.

Pure jittable functions over final-position logits.  ``temperature`` and
``top_k`` are engine-level (compile-time) settings — they select the
sampling computation, they are not traced."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def sample(rng, logits, *, temperature: float = 0.0, top_k: int = 0):
    """logits (B, V) float32 -> (B,) int32 token ids.

    ``temperature == 0`` is greedy argmax (``rng`` unused).  ``top_k > 0``
    restricts sampling to each row's k highest-logit tokens.
    """
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / temperature
    if top_k:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, NEG, logits)
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)
