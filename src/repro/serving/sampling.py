"""Token sampling for the serving engine: greedy + temperature / top-k.

Pure jittable functions over final-position logits.  ``temperature`` and
``top_k`` are engine-level (compile-time) settings — they select the
sampling computation, they are not traced.

Randomness is POSITIONAL, not sequential: every slot carries its own base
key (``fold_in(engine_key, rid)``) and the key for the token at absolute
position ``p`` is ``fold_in(slot_key, p)``.  A token's sample therefore
depends only on (request, position) — never on which dispatch drew it —
which is what keeps sampled streams bit-identical across
``--ticks-per-dispatch`` 1/4/8 and across scheduling order
(``tests/serving/test_multi_tick.py`` asserts it)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def sample(rng, logits, *, temperature: float = 0.0, top_k: int = 0):
    """logits (B, V) float32 -> (B,) int32 token ids.

    ``temperature == 0`` is greedy argmax (``rng`` unused).  ``top_k > 0``
    restricts sampling to each row's k highest-logit tokens.
    """
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / temperature
    if top_k:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, NEG, logits)
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)


def slot_key(base, rid: int):
    """The per-slot base key for request ``rid``: fold_in(engine key, rid)."""
    return jax.random.fold_in(base, rid)


def sample_slots(keys, pos, logits, *, temperature: float = 0.0,
                 top_k: int = 0):
    """Per-slot positional sampling.  keys (B, ...) per-slot base PRNG
    keys (one per row); pos (B,) int32 absolute position of the token
    being sampled; logits (B, V) float32 -> (B,) int32.

    Each row's key is ``fold_in(keys[b], pos[b])`` — deterministic in
    (request, position), independent of dispatch batching (K) and of
    every other slot's traffic.  ``temperature == 0`` is greedy argmax
    (keys unused, so greedy needs no key bookkeeping at all)."""
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / temperature
    if top_k:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, NEG, logits)
    ks = jax.vmap(jax.random.fold_in)(keys, pos)
    return jax.vmap(
        lambda k, lg: jax.random.categorical(k, lg))(ks, logits) \
        .astype(jnp.int32)
