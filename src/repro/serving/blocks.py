"""Shared-prefix KV blocks: the ring cache cut into ref-counted,
fixed-size pool blocks (docs/serving.md).

Instead of one private (capacity,) ring per slot, the engine owns ONE
pool of ``num_blocks`` blocks of ``block_size`` ring positions each, and
a per-slot **block table** (slots, capacity/bs) mapping logical ring
slot ``s`` of a row onto ``pool[table[row, s // bs], s % bs]``.  All the
ring position arithmetic is untouched — only the physical placement of
a slot's bytes moves — which is why the decode-attention kernels take
the table as a second scalar-prefetch argument and otherwise run the
exact same tile loop.

What the indirection buys:

  sharing   the K/V of prompt position p depends only on tokens <= p, so
            two requests with the same prompt PREFIX produce bit-equal
            cache blocks.  ``BlockManager`` chain-hashes each full
            ``block_size`` prompt chunk (h_j = H(h_{j-1}, chunk_j)) and
            points a new row's table at already-filled blocks: prefill
            still runs (it must — the suffix needs its logits) but the
            pool holds ONE copy of the shared prefix, so a pool of NB
            blocks serves far more concurrent same-prefix rows than
            NB*bs/capacity (benchmarks/serving_latency.py measures it).
  prefill
  -once     an EXACT full-prompt repeat (greedy engines) admits with no
            forward at all: the manager cached the first sampled token
            and a snapshot of the tail block at first admission; the new
            row shares the full chunks and gets a copy-on-write clone of
            the tail snapshot (its decode will write into that block —
            the one genuine divergence point).
  safety    block 0 is the TRASH block and is never allocated: a retired
            slot's table is reset to all-zeros, so the garbage its
            inactive row keeps decoding lands harmlessly in block 0,
            which no live table references.  Live rows never write a
            shared block: decode writes sit at positions >= prompt_len,
            which per-admit full allocation places in private blocks,
            and rows retire before the ring wraps (``_hit_limits``).

Host-side policy (this file) is pure bookkeeping — refcounts, free
list, hash indices; device data only moves in ``write_prefill`` (scatter
a prefilled contiguous ring into the row's blocks) and ``copy_block``
(COW / snapshot clones).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import models

TRASH = 0     # block 0: retired rows write here, nobody reads it


def _chain(prev: bytes, chunk) -> bytes:
    return hashlib.sha1(prev + np.asarray(chunk, np.int32).tobytes()).digest()


@dataclasses.dataclass
class Admission:
    """One row's placement decision.  ``table`` is the full per-admit
    allocation (capacity/bs entries, shared prefix first).  When
    ``first_token`` is set the prefill forward is SKIPPED (exact-prompt
    hit): ``cow`` clones the tail snapshot into this row's private
    block.  Otherwise the engine prefills, scatters, then calls
    ``BlockManager.finish`` to register the new chunks/snapshot."""
    table: List[int]
    n_shared: int                      # shared full-prefix chunks
    prompt_len: int
    cow: List[Tuple[int, int]]         # (dst, src) block copies to run
    first_token: Optional[int] = None  # set => zero-forward admission
    # registration plan (prefill path only):
    new_chunks: List[Tuple[bytes, int]] = dataclasses.field(
        default_factory=list)
    pkey: Optional[bytes] = None
    snapshot: Optional[int] = None     # block to clone the tail into


class BlockManager:
    """Host-side allocator for the shared block pool.  ``dedup=False``
    turns every lookup/registration off (every admission gets fully
    private blocks) — the control arm of the capacity benchmark."""

    def __init__(self, num_blocks: int, block_size: int, dedup: bool = True,
                 prefill_once: bool = True):
        if num_blocks < 2:
            raise ValueError(f"need >= 2 blocks (trash + 1), "
                             f"got {num_blocks}")
        self.nb, self.bs, self.dedup = num_blocks, block_size, dedup
        # first-token reuse is only sound when sampling is deterministic
        # given the prompt (greedy) — chunk sharing is sound regardless
        self.prefill_once = prefill_once
        self.free: List[int] = list(range(num_blocks - 1, TRASH, -1))
        self.ref: Dict[int, int] = {}
        self.chunks: Dict[bytes, int] = {}      # chain hash -> block
        self._rev: Dict[int, bytes] = {}        # block -> chain hash
        self.prompts: Dict[bytes, Tuple[int, Optional[int]]] = {}
        self.prefills_skipped = 0
        self.peak = 0                      # high-water blocks in use

    # ------------------------------------------------------------ refs ----

    def _alloc(self) -> int:
        b = self.free.pop()
        self.ref[b] = 1
        self.peak = max(self.peak, self.in_use)
        return b

    def _share(self, b: int) -> int:
        self.ref[b] += 1
        return b

    def _unref(self, b: int) -> None:
        self.ref[b] -= 1
        if self.ref[b] == 0:
            del self.ref[b]
            h = self._rev.pop(b, None)
            if h is not None:
                self.chunks.pop(h, None)
            self.free.append(b)

    def _ensure(self, needed: int, protect: Optional[bytes]) -> bool:
        """Free snapshot-only pool space (evict cached prompts) until
        ``needed`` blocks are allocatable.  Never evicts ``protect``."""
        while len(self.free) < needed:
            victim = next((k for k in self.prompts if k != protect), None)
            if victim is None:
                return False
            _, snap = self.prompts.pop(victim)
            if snap is not None:
                self._unref(snap)
        return True

    @property
    def in_use(self) -> int:
        return self.nb - 1 - len(self.free)

    # ------------------------------------------------------- admissions ----

    def _hashes(self, prompt) -> Tuple[List[bytes], bytes]:
        hs, h = [], b"ring"
        for i in range(len(prompt) // self.bs):
            h = _chain(h, prompt[i * self.bs:(i + 1) * self.bs])
            hs.append(h)
        pkey = _chain(h, prompt[len(hs) * self.bs:])
        return hs, pkey

    def admit(self, prompt, n_k: int) -> Optional[Admission]:
        """Place one row (prompt = int sequence; n_k = capacity/bs table
        length).  Returns None when the pool cannot host the row right
        now — the engine defers the request instead of failing it."""
        prompt = list(map(int, prompt))
        n_full = len(prompt) // self.bs
        tail = len(prompt) - n_full * self.bs
        hs, pkey = ([], None) if not self.dedup else self._hashes(prompt)

        cached = self.dedup and self.prefill_once and \
            pkey in self.prompts and all(h in self.chunks for h in hs)
        if cached:
            first, snap = self.prompts[pkey]
            if not self._ensure(n_k - n_full, protect=pkey):
                return None
            table = [self._share(self.chunks[h]) for h in hs]
            cow = []
            if tail:
                table.append(self._alloc())
                cow.append((table[-1], snap))
            while len(table) < n_k:
                table.append(self._alloc())
            self.prefills_skipped += 1
            return Admission(table=table, n_shared=n_full,
                             prompt_len=len(prompt), cow=cow,
                             first_token=first)

        j = 0
        while self.dedup and j < n_full and hs[j] in self.chunks:
            j += 1
        register = self.dedup and self.prefill_once and \
            pkey not in self.prompts
        need_snap = register and tail > 0
        if not self._ensure(n_k - j + int(need_snap), protect=pkey):
            return None
        table = [self._share(self.chunks[h]) for h in hs[:j]]
        table += [self._alloc() for _ in range(n_k - j)]
        return Admission(
            table=table, n_shared=j, prompt_len=len(prompt), cow=[],
            new_chunks=[(hs[i], table[i]) for i in range(j, len(hs))],
            pkey=pkey if register else None,
            snapshot=self._alloc() if need_snap else None)

    def finish(self, adm: Admission, first_token: int) -> None:
        """Register what prefill just materialised: the row's fresh full
        chunks become shareable, and (greedy engines) the exact prompt
        maps to (first sampled token, tail snapshot) for prefill-once."""
        for h, b in adm.new_chunks:
            self.chunks[h] = b
            self._rev[b] = h
        if adm.pkey is not None:
            self.prompts[adm.pkey] = (int(first_token), adm.snapshot)

    def release(self, adm: Admission) -> None:
        for b in adm.table:
            self._unref(b)


# ------------------------------------------------------------ device ops ----

def _pool_axis(path) -> int:
    parts = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
    return 1 if models.stacked_cache_path("/".join(parts)) else 0


def init_blocked_state(cfg, num_blocks: int, block_size: int,
                       slots: int) -> models.DecodeState:
    """The pool-shaped DecodeState: every ring leaf built as if it were
    a batch of ``num_blocks`` rows of capacity ``block_size`` — i.e. the
    pool IS a ring cache whose batch axis means 'block'.  ``pos`` stays
    per-SLOT; the table maps between the two."""
    cache = models.init_decode_cache(cfg, num_blocks, block_size)
    return models.DecodeState(cache=cache,
                              pos=jnp.zeros((slots,), jnp.int32))


def write_prefill(state: models.DecodeState, sub: models.DecodeState,
                  table_row, slot: int, block_size: int) -> models.DecodeState:
    """Scatter a freshly prefilled CONTIGUOUS ring (batch 1, capacity
    n_k*bs) into the row's blocks.  Shared prefix blocks are rewritten
    with bit-identical bytes (same chunk + same prefix => same K/V), so
    no special-casing is needed."""
    ids = jnp.asarray(table_row, jnp.int32)
    n_k, bs = len(table_row), block_size

    def one(path, pool, s):
        if _pool_axis(path) == 1:
            ly = pool.shape[0]
            chunks = s[:, 0, :n_k * bs].reshape((ly, n_k, bs) + s.shape[3:])
            return pool.at[:, ids].set(chunks.astype(pool.dtype))
        chunks = s[0, :n_k * bs].reshape((n_k, bs) + s.shape[2:])
        return pool.at[ids].set(chunks.astype(pool.dtype))

    cache = jax.tree_util.tree_map_with_path(one, state.cache, sub.cache)
    return models.DecodeState(cache=cache,
                              pos=state.pos.at[slot].set(sub.pos[0]))


def copy_block(state: models.DecodeState, dst: int,
               src: int) -> models.DecodeState:
    """Clone one pool block across every ring leaf (COW / snapshots)."""

    def one(path, pool):
        if _pool_axis(path) == 1:
            return pool.at[:, dst].set(pool[:, src])
        return pool.at[dst].set(pool[src])

    return models.DecodeState(
        cache=jax.tree_util.tree_map_with_path(one, state.cache),
        pos=state.pos)
