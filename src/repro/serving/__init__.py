"""Continuous-batching inference subsystem (docs/serving.md).

``ServingEngine`` runs the whole model zoo through the unified
``models.DecodeState`` contract: fixed decode slots, bucketed interleaved
prefill, multi-tick decode dispatches (``ticks_per_dispatch`` device-
resident ticks per host sync), greedy / temperature / top-k sampling
with per-(request, position) keys, optional speculative decoding
(``draft_params``/``draft_cfg``/``spec_tokens`` — serving/spec_decode.py)
and shared-prefix block-pool caches (``block_size``/``num_blocks`` —
serving/blocks.py), params + state sharded over the replica mesh.

The multi-process tier stacks on top: N engine instances as worker
processes (serving/tier.py) behind a least-loaded ``Router``
(serving/router.py) with deferred-admission backpressure, disaggregated
prefill/decode, and elastic drain/handoff of live slots."""
from repro.serving.blocks import BlockManager
from repro.serving.engine import (DEFAULT_BUCKETS, DrainingError, Request,
                                  Result, ServingEngine)
from repro.serving.router import DeadInstanceError, Router
from repro.serving.sampling import sample, sample_slots, slot_key
from repro.serving.tier import InstanceHandle, PrefillWorker, TierError

__all__ = ["ServingEngine", "Request", "Result", "DEFAULT_BUCKETS",
           "BlockManager", "sample", "sample_slots", "slot_key",
           "DrainingError", "Router", "DeadInstanceError",
           "InstanceHandle", "PrefillWorker", "TierError"]
