"""Continuous-batching inference subsystem (docs/serving.md).

``ServingEngine`` runs the whole model zoo through the unified
``models.DecodeState`` contract: fixed decode slots, bucketed interleaved
prefill, multi-tick decode dispatches (``ticks_per_dispatch`` device-
resident ticks per host sync), greedy / temperature / top-k sampling
with per-(request, position) keys, optional speculative decoding
(``draft_params``/``draft_cfg``/``spec_tokens`` — serving/spec_decode.py)
and shared-prefix block-pool caches (``block_size``/``num_blocks`` —
serving/blocks.py), params + state sharded over the replica mesh."""
from repro.serving.blocks import BlockManager
from repro.serving.engine import (DEFAULT_BUCKETS, Request, Result,
                                  ServingEngine)
from repro.serving.sampling import sample, sample_slots, slot_key

__all__ = ["ServingEngine", "Request", "Result", "DEFAULT_BUCKETS",
           "BlockManager", "sample", "sample_slots", "slot_key"]
