"""Continuous-batching inference subsystem (docs/serving.md).

``ServingEngine`` runs the whole model zoo through the unified
``models.DecodeState`` contract: fixed decode slots, bucketed interleaved
prefill, one compiled decode step per tick, greedy / temperature / top-k
sampling, params + state sharded over the replica mesh."""
from repro.serving.engine import (DEFAULT_BUCKETS, Request, Result,
                                  ServingEngine)
from repro.serving.sampling import sample

__all__ = ["ServingEngine", "Request", "Result", "DEFAULT_BUCKETS", "sample"]
