"""Multi-process serving tier: worker processes + the wire protocol.

A tier is N independent engine instances, each a separate OS process
owning its own ``ServingEngine`` (own devices, own compiled functions —
the module-level compile caches make per-process spin-up cheap), fronted
by a ``serving.router.Router`` in the driver process.  No cross-process
collectives are involved: instances never communicate with each other,
only with the router, over ``multiprocessing.connection`` sockets
(length-prefixed pickles on localhost TCP with an authkey handshake).

Three worker roles share one loop (``worker_serve``):

  engine / decode   owns slots; autonomously steps whenever it has live
                    or queued work, answering RPCs between steps.  The
                    ``decode`` spelling is the disaggregated tier's
                    convention for an instance that only ever admits
                    pre-filled snapshots (``inject``) — the code path is
                    identical; what disaggregates is the traffic.
  prefill           owns NO slots: runs the engine's bucketed prefill on
                    submitted prompts and returns inject-ready snapshots
                    (``PrefillWorker``), so long prompts burn this
                    process's time, not a decode instance's tick loop.

State crosses processes as ``checkpoint.pack_tree`` buffers: one
request's DecodeState row (``engine.export_slot`` /
``PrefillWorker.prefill``) packs to a self-describing bytes blob the
receiver unpacks against its own config's structure
(``snapshot_like``) — the same raw-uint8 leaf container checkpoints
use, so bf16/int8 cache leaves round-trip exactly and drain/handoff
replay is byte-faithful.

Timing note: engines stamp Results with ``time.perf_counter``, whose
epoch is per-process — cross-process request metrics (latency, ttft)
are therefore the ROUTER's, measured on its own clock; per-engine
Result timestamps are only meaningful for requests that never moved.
"""
from __future__ import annotations

import os
import socket
import subprocess
import sys
import time
from multiprocessing.connection import Client, Listener
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint, models
from repro.serving import engine as engine_mod
from repro.serving import sampling
from repro.serving.engine import Request

AUTHKEY = b"repro-serving-tier"


class TierError(RuntimeError):
    """A worker answered an RPC with an application error."""


# ------------------------------------------------------------------ wire ----

def request_to_wire(req: Request) -> dict:
    """Text-only requests cross the tier; frames/images stay
    single-process for now (the snapshot container carries only the
    token-path DecodeState)."""
    if req.frames is not None or req.image is not None \
            or req.image_embeds is not None:
        raise NotImplementedError(
            "the serving tier routes token requests only; encdec frames "
            "and vision inputs serve single-process (docs/serving.md)")
    return {"prompt": np.asarray(req.prompt, np.int64).tolist(),
            "max_new_tokens": int(req.max_new_tokens), "rid": int(req.rid)}


def request_from_wire(d: dict) -> Request:
    return Request(prompt=np.asarray(d["prompt"], np.int32),
                   max_new_tokens=int(d["max_new_tokens"]))


def result_to_wire(res) -> dict:
    return {"rid": res.rid, "prompt_len": res.prompt_len,
            "tokens": list(res.tokens), "t_submit": res.t_submit,
            "t_first": res.t_first, "t_done": res.t_done,
            "draft_proposed": res.draft_proposed,
            "draft_accepted": res.draft_accepted}


# -------------------------------------------------------------- snapshots ----

def snapshot_like(cfg, capacity: int, enc_len: int = 64):
    """Structure template for unpacking a one-row slot snapshot: the
    treedef/key-paths are what matters (shapes and dtypes come from the
    buffer's own manifest)."""
    cache = jax.eval_shape(
        lambda: models.init_decode_cache(cfg, 1, capacity, enc_len))
    return {"cache": cache, "pos": 0, "last_tok": 0, "slot_key": 0}


def pack_snapshot(snap: dict) -> bytes:
    return checkpoint.pack_tree(snap["arrays"], meta=snap["meta"])


def unpack_snapshot(buf: bytes, like) -> dict:
    arrays, meta = checkpoint.unpack_tree(buf, like)
    return {"arrays": arrays, "meta": meta}


# --------------------------------------------------------- prefill worker ----

class PrefillWorker:
    """Disaggregated prefill: the engine's bucketed length-masked prefill
    without any decode slots.  ``prefill`` turns one wire request into an
    inject-ready snapshot — a decode instance admits it through
    ``engine.import_snapshot`` and never runs a prefill itself, so long
    prompts stop head-of-line-blocking decode ticks.

    ``seed`` must match the decode instances' so the positional sampling
    key derived here (``slot_key(PRNGKey(seed), rid)``) continues the
    same stream the colocated engine would have sampled."""

    def __init__(self, params, cfg, *, capacity: int, buckets=None,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 enc_len: int = 64):
        if cfg.family not in models.DECODE_FAMILIES or cfg.family == "encdec":
            raise NotImplementedError(
                f"prefill worker serves token families, got {cfg.family!r}")
        self.params, self.cfg, self.capacity = params, cfg, capacity
        bs = tuple(sorted(b for b in (buckets or engine_mod.DEFAULT_BUCKETS)
                          if b <= capacity))
        if not bs or bs[-1] < capacity:
            bs += (capacity,)
        self.buckets = bs
        self.temperature, self.top_k = temperature, top_k
        self.enc_len = enc_len
        self.rng = jax.random.PRNGKey(seed)
        self.prefills = 0

    def prefill(self, reqd: dict) -> dict:
        prompt = np.asarray(reqd["prompt"], np.int32)
        n = len(prompt)
        bucket = next((b for b in self.buckets if n <= b), None)
        if bucket is None:
            raise ValueError(f"prompt length {n} exceeds the largest "
                             f"bucket {self.buckets[-1]}")
        req_key = sampling.slot_key(self.rng, int(reqd.get("rid", 0)))
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :n] = prompt
        first, sub = engine_mod._prefill_fn(
            self.cfg, self.temperature, self.top_k, self.capacity, bucket)(
            self.params, jnp.asarray(toks), jnp.asarray([n], jnp.int32),
            {}, req_key)
        self.prefills += 1
        now = time.perf_counter()
        return {
            "arrays": {"cache": jax.device_get(sub.cache),
                       "pos": jax.device_get(sub.pos),
                       "last_tok": jax.device_get(first),
                       "slot_key": jax.device_get(req_key)},
            "meta": {"prompt": prompt.astype(np.int64).tolist(),
                     "max_new_tokens": int(reqd["max_new_tokens"]),
                     "prompt_len": n, "tokens": [int(first[0, 0])],
                     "t_submit": float(reqd.get("t_submit", now)),
                     "t_first": now, "rid": int(reqd.get("rid", -1)),
                     "draft_proposed": 0, "draft_accepted": 0},
        }


# ------------------------------------------------------------ worker loop ----

def worker_serve(obj, port: int, *, host: str = "127.0.0.1",
                 authkey: bytes = AUTHKEY, max_queue: Optional[int] = None):
    """Serve one ``ServingEngine`` or ``PrefillWorker`` to a single
    router connection until shutdown/disconnect.

    An engine worker steps AUTONOMOUSLY: whenever slots are live or the
    queue is non-empty it runs ``engine.step()`` and banks the finished
    results for the next ``poll``; RPCs are handled between steps.  This
    is what makes N instances genuinely concurrent — the router never
    drives ticks, it only feeds and drains them.

    Backpressure: a submit that finds no free slot and a full bounded
    queue (``max_queue``, default 2x slots) answers ``("defer", None)``
    instead of queueing unboundedly — the same defer-don't-fail
    semantics the block pool uses (serving/blocks.py); the router holds
    the request and retries on a later pump."""
    is_engine = isinstance(obj, engine_mod.ServingEngine)
    if is_engine and max_queue is None:
        max_queue = 2 * obj.slots
    with Listener((host, port), authkey=authkey) as listener:
        with listener.accept() as conn:
            if is_engine:
                _engine_loop(obj, conn, max_queue)
            else:
                _prefill_loop(obj, conn)


def _engine_loop(eng, conn, max_queue: int):
    done: List[dict] = []
    step_times: List[float] = []
    like = None
    while True:
        busy = any(r is not None for r in eng._active) or eng._queue
        if conn.poll(0.0 if busy else 0.02):
            try:
                cmd, payload = conn.recv()
            except EOFError:
                return                       # router went away: exit
            if cmd == "submit":
                if eng._draining:
                    conn.send(("draining", None))
                elif eng.free_slots == 0 and eng.queue_len >= max_queue:
                    conn.send(("defer", None))
                else:
                    rid = eng.submit(request_from_wire(payload))
                    conn.send(("ok", rid))
            elif cmd == "poll":
                conn.send(("ok", done))
                done = []
            elif cmd == "stats":
                st = eng.load()
                st["step_times"] = step_times
                st["decode_steps"] = eng.decode_steps
                step_times = []
                conn.send(("ok", st))
            elif cmd == "inject":
                if eng._draining:
                    conn.send(("draining", None))
                elif eng.free_slots == 0:
                    conn.send(("defer", None))
                else:
                    if like is None:
                        like = snapshot_like(eng.cfg, eng.capacity,
                                             eng.enc_len)
                    rid = eng.import_snapshot(unpack_snapshot(payload, like))
                    conn.send(("ok", rid))
            elif cmd == "drain":
                try:
                    snaps, queued = eng.drain()
                except NotImplementedError as e:
                    conn.send(("err", str(e)))
                    continue
                conn.send(("ok", ([pack_snapshot(s) for s in snaps],
                                  [request_to_wire(q) for q in queued])))
            elif cmd == "ping":
                conn.send(("ok", "pong"))
            elif cmd == "shutdown":
                conn.send(("ok", None))
                return
            else:
                conn.send(("err", f"unknown command {cmd!r}"))
        elif busy:
            t0 = time.perf_counter()
            finished = eng.step()
            step_times.append(time.perf_counter() - t0)
            done.extend(result_to_wire(r) for r in finished)


def _prefill_loop(pw, conn):
    while True:
        try:
            cmd, payload = conn.recv()
        except EOFError:
            return
        if cmd == "prefill":
            conn.send(("ok", pack_snapshot(pw.prefill(payload))))
        elif cmd == "stats":
            conn.send(("ok", {"prefills": pw.prefills, "free_slots": 0,
                              "queue_len": 0, "active": 0,
                              "draining": False, "step_times": []}))
        elif cmd == "ping":
            conn.send(("ok", "pong"))
        elif cmd == "shutdown":
            conn.send(("ok", None))
            return
        else:
            conn.send(("err", f"unknown command {cmd!r}"))


# --------------------------------------------------------------- handles ----

class InstanceHandle:
    """Router-side endpoint of one worker: a lazy socket + typed calls.
    Any transport failure (worker died, socket reset) surfaces as
    ``ConnectionError`` — the router's death-handling boundary."""

    def __init__(self, address, *, name: str = "", authkey: bytes = AUTHKEY,
                 proc: Optional[subprocess.Popen] = None):
        self.address = tuple(address)
        self.name = name or f"{self.address[0]}:{self.address[1]}"
        self.authkey, self.proc = authkey, proc
        self.dead = False
        self._conn = None

    def connect(self, timeout: float = 120.0):
        deadline = time.monotonic() + timeout
        while self._conn is None:
            try:
                self._conn = Client(self.address, authkey=self.authkey)
            except (ConnectionRefusedError, OSError):
                if self.proc is not None and self.proc.poll() is not None:
                    raise ConnectionError(
                        f"worker {self.name} exited with "
                        f"{self.proc.returncode} before accepting")
                if time.monotonic() > deadline:
                    raise ConnectionError(
                        f"worker {self.name} not accepting after "
                        f"{timeout:.0f}s")
                time.sleep(0.05)
        return self

    def call(self, cmd: str, payload=None):
        """-> (status, value).  status in {'ok', 'defer', 'draining'}."""
        if self.dead:
            raise ConnectionError(f"instance {self.name} is dead")
        if self._conn is None:
            self.connect()
        try:
            self._conn.send((cmd, payload))
            status, val = self._conn.recv()
        except (EOFError, OSError, BrokenPipeError) as e:
            raise ConnectionError(f"instance {self.name}: {e!r}") from e
        if status == "err":
            raise TierError(f"{self.name}: {val}")
        return status, val

    def shutdown(self, timeout: float = 10.0):
        try:
            if not self.dead:
                self.call("shutdown")
        except (ConnectionError, TierError):
            pass
        self.close(timeout=timeout)

    def close(self, timeout: float = 10.0):
        if self._conn is not None:
            self._conn.close()
            self._conn = None
        if self.proc is not None and self.proc.poll() is None:
            try:
                self.proc.wait(timeout=timeout)
            except subprocess.TimeoutExpired:
                self.proc.kill()
                self.proc.wait()


# ---------------------------------------------------------------- spawning ----

def free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def spawn_worker(role: str, model_args: List[str], *, port: Optional[int] = None,
                 env: Optional[dict] = None, name: str = "",
                 stdout=subprocess.DEVNULL) -> InstanceHandle:
    """Launch ``python -m repro.launch.serve --role <role> --port <p>
    <model_args>`` as a child process and hand back its (unconnected)
    handle.  ``model_args`` are plain serve.py flags — the same flags
    that describe a single-process engine describe each instance, which
    is what keeps a tier homogeneous (drain/handoff requires it)."""
    port = port or free_port()
    cmd = [sys.executable, "-m", "repro.launch.serve",
           "--role", role, "--port", str(port)] + list(model_args)
    env = {**os.environ, **(env or {})}
    src_dir = os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))     # .../src
    env["PYTHONPATH"] = src_dir + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.Popen(cmd, env=env, stdout=stdout,
                            stderr=subprocess.STDOUT)
    return InstanceHandle(("127.0.0.1", port), proc=proc,
                          name=name or f"{role}:{port}")
