"""Speculative decoding: a small family member drafts, the target
verifies — one fused device dispatch per accept/commit round.

The model zoo is used against itself (docs/serving.md): a draft model
(e.g. olmo-1b) runs ``gamma`` cheap greedy ticks from its own
DecodeState, then the target scores the whole candidate chunk in ONE
chunked call (``models.decode_seq`` with ``commit_len=0`` — pure
lookahead, nothing written).  Greedy acceptance:

    x      = [t0, d1 .. dγ]          t0 = last engine token, d = drafts
    tgt[j] = argmax target logits after consuming x[:j+1]
    m      = Σ cumprod(d_{j+1} == tgt[j])        accepted draft count
    a      = m + 1                               tokens emitted (>= 1)

The emitted tokens are exactly ``tgt[0..m]`` — accepted drafts EQUAL the
target's own greedy chain, plus the target's correction/continuation
token — so the output stream is token-identical to the plain greedy
engine (tests/serving/test_spec_decode.py, at 1 and 2 devices).

Commit is rollback-free by construction: the propose rollout's draft
state is DISCARDED, and rejected tokens never touch either ring, so
there is nothing to roll back.  Verify and commit share ONE target
forward: ``models.decode_seq_pending`` produces the verify logits plus
a pending chunk, the accept count ``a`` is derived from the logits, and
``models.commit_pending`` applies the accepted prefix as a masked
scatter / masked carry re-run — no second target forward per round (the
draft still re-runs its cheap chunk to advance its own state).

One packed (slots, 2(γ+1)+1) array — emitted tokens, eos flags, per-row
accept counts — crosses to host per dispatch, same single-transfer
discipline as the multi-tick loop.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro import models

# fused propose+verify+commit dispatches, shared across engine instances
_SPEC_FNS: Dict[tuple, Any] = {}

# families decode_seq covers that the serving tier can draft for / with
SPEC_FAMILIES = ("dense", "moe", "ssm", "hybrid")


def check_spec_pair(tcfg, dcfg, *, temperature: float, ticks: int):
    """Validate an (target, draft) engine configuration.  Greedy-only:
    distribution-preserving rejection sampling for temperature > 0 is a
    follow-up (ROADMAP); K>1 multi-tick and spec are separate dispatch
    shapes."""
    if temperature != 0.0:
        raise ValueError("speculative decoding is greedy-only "
                         f"(temperature=0), got temperature={temperature}")
    if ticks != 1:
        raise ValueError("speculative decoding replaces the multi-tick "
                         f"dispatch; use ticks_per_dispatch=1, got {ticks}")
    for name, cfg in (("target", tcfg), ("draft", dcfg)):
        if cfg.family not in SPEC_FAMILIES:
            raise NotImplementedError(
                f"spec decode needs a {SPEC_FAMILIES} {name}, got "
                f"{cfg.family!r} ({cfg.name})")
    if tcfg.vocab_size != dcfg.vocab_size:
        raise ValueError(
            f"draft/target vocabularies differ: {dcfg.vocab_size} vs "
            f"{tcfg.vocab_size} — acceptance compares token ids directly")


def truncated_draft(cfg, params, k: int):
    """The genuinely-cheap draft: the target's OWN first ``k`` layers,
    sharing its embedding, unembedding and final norm by reference —
    zero extra training, zero extra memory for the shared leaves, and a
    per-tick cost of k/L of the target's.

    Layers apply superblock-major (transformer.py): truncating at ``k``
    keeps the first ``k // P`` full superblocks (P = pattern length) as
    stacked params and peels the next ``k % P`` pattern positions into
    rem_blocks — exactly the order the full model would have run them.
    Because the draft IS a prefix of the target (same residual stream,
    same unembed), its greedy argmax correlates with the target's far
    better than an independent small model's would, which is what buys
    the acceptance rate a >1x speedup needs (docs/serving.md)."""
    pattern, np_, rem = models.transformer._split(cfg)
    P = len(pattern)
    if not 0 < k < cfg.n_layers:
        raise ValueError(f"draft layers must be in (0, {cfg.n_layers}), "
                         f"got {k}")
    j, r = divmod(k, P)
    dcfg = dataclasses.replace(cfg, n_layers=k,
                               name=f"{cfg.name}-draft{k}")
    dparams = {"embed": params["embed"], "final_norm": params["final_norm"]}
    if j > 0:
        dparams["blocks"] = tuple(jax.tree.map(lambda x: x[:j], bp)
                                  for bp in params["blocks"])
    else:
        dparams["blocks"] = ()
    if r == 0:
        rems = ()
    elif j < np_:
        # the partial superblock: stack index j of pattern positions 0..r-1
        rems = tuple(jax.tree.map(lambda x: x[j], params["blocks"][pi])
                     for pi in range(r))
    else:
        rems = tuple(params["rem_blocks"][:r])
    dparams["rem_blocks"] = rems
    return dcfg, dparams


def spec_fn(tcfg, dcfg, gamma: int, slots: int, capacity: int, enc_len: int,
            mesh, eos_id):
    """The compiled spec dispatch:
    (tparams, dparams, tstate, dstate, toks (slots,1))
      -> (packed (slots, 2(γ+1)+1) int32, last (slots,1), tstate, dstate)
    packed columns: [emit 0..γ | eos flags 0..γ | a].  Only each row's
    first ``a`` emit/flag entries are meaningful.  γ=0 degenerates to a
    plain verified tick (a == 1 always)."""
    key = (tcfg, dcfg, gamma, slots, capacity, enc_len, mesh, eos_id)
    if key not in _SPEC_FNS:
        def spec(tparams, dparams, tstate, dstate, toks):
            if gamma > 0:
                def dtick(carry, _):
                    st, tk = carry
                    lg, st = models.decode_step(dparams, dcfg, st, tk)
                    nt = jnp.argmax(lg[:, 0], axis=-1).astype(jnp.int32)
                    return (st, nt[:, None]), nt

                _, drafts = jax.lax.scan(dtick, (dstate, toks), None,
                                         length=gamma)
                x = jnp.concatenate([toks, drafts.T], axis=1)  # (B, γ+1)
            else:
                x = toks
            zero = jnp.zeros((slots,), jnp.int32)
            tlogits, pending = models.decode_seq_pending(tparams, tcfg,
                                                         tstate, x)
            tgt = jnp.argmax(tlogits, axis=-1).astype(jnp.int32)
            if gamma > 0:
                match = (x[:, 1:] == tgt[:, :-1]).astype(jnp.int32)
                m = jnp.sum(jnp.cumprod(match, axis=1), axis=1)
            else:
                m = zero
            a = m + 1
            # commit: ONE target forward per round — the verify pass's
            # pending chunk is committed directly; only the (cheap) draft
            # re-runs a chunk to advance its own state.  The propose
            # rollout's state was never kept, so rejected drafts exist
            # nowhere
            tstate = models.commit_pending(tparams, tcfg, tstate, pending, a)
            _, dstate = models.decode_seq(dparams, dcfg, dstate, x, a)
            emit = tgt                                 # emit j (j<a) = tgt_j
            last = tgt[jnp.arange(slots), m][:, None]
            flags = (jnp.zeros(emit.shape, jnp.int32) if eos_id is None
                     else (emit == eos_id).astype(jnp.int32))
            packed = jnp.concatenate([emit, flags, a[:, None]], axis=1)
            return packed, last, tstate, dstate

        kw = {}
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.serving.engine import _replica_lead, _state_sharding
            _, lead = _replica_lead(mesh)
            kw["out_shardings"] = (
                NamedSharding(mesh, P(lead, None)),
                NamedSharding(mesh, P(lead, None)),
                _state_sharding(tcfg, slots, capacity, enc_len, mesh),
                _state_sharding(dcfg, slots, capacity, enc_len, mesh))
        _SPEC_FNS[key] = jax.jit(spec, donate_argnums=(2, 3), **kw)
    return _SPEC_FNS[key]
