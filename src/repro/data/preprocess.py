"""Image preprocessing — the paper's loader-side transforms (footnote 2):
subtract the mean image, random crop, random horizontal flip.

Pure numpy, run on the host inside the loader thread (mirroring the paper's
separate loading process).  Deterministic given the seed.
"""
from __future__ import annotations

import numpy as np


def random_crop_flip(images: np.ndarray, crop: int, rng: np.random.Generator,
                     flip: bool = True, impl: str = "auto") -> np.ndarray:
    """images (B, H, W, C) -> (B, crop, crop, C).

    Two interchangeable kernels, bit-identical for a given ``rng`` state
    (all random draws happen here, before dispatch; parity-tested):

    ``loop``    per-image numpy block copies — each iteration is one
                C-level strided memcpy.
    ``gather``  one fancy-indexing gather for the whole batch (O(1)
                Python, the "vectorized" formulation).

    ``auto`` -> loop.  NOTE: vectorizing this was tried and REFUTED on CPU
    hosts: the loop's per-image cost is a block copy near the memory
    floor, while every gather formulation (multi-axis fancy indexing, flat
    int32 ``take``, two-stage row/col) pays elementwise index arithmetic —
    measured 2-4x SLOWER at every shape this repo trains (B=256 235->227:
    142ms vs 267-594ms; B=64 72->64: 0.8ms vs 3.1ms).  The interpreter
    overhead the loop was suspected of is ~microseconds/image.  Kept
    selectable for backends where gathers win; numbers in
    benchmarks/loading_overlap.py (``loading/crop_*`` rows).
    """
    b, h, w, c = images.shape
    assert h >= crop and w >= crop, (h, w, crop)
    ys = rng.integers(0, h - crop + 1, size=b)
    xs = rng.integers(0, w - crop + 1, size=b)
    do_flip = rng.random(b) < 0.5 if flip else np.zeros(b, bool)
    if impl == "auto":
        impl = "loop"
    if impl == "loop":
        out = np.empty((b, crop, crop, c), images.dtype)
        for i in range(b):
            patch = images[i, ys[i]:ys[i] + crop, xs[i]:xs[i] + crop]
            out[i] = patch[:, ::-1] if do_flip[i] else patch
        return out
    if impl == "gather":
        rows = ys[:, None] + np.arange(crop)[None, :]           # (B, crop)
        cols = xs[:, None] + np.arange(crop)[None, :]           # (B, crop)
        cols = np.where(do_flip[:, None], cols[:, ::-1], cols)  # flip = rev W
        return images[np.arange(b)[:, None, None],
                      rows[:, :, None], cols[:, None, :]]
    raise ValueError(f"unknown crop impl {impl!r} (loop|gather|auto)")


def subtract_mean(images: np.ndarray, mean_image: np.ndarray) -> np.ndarray:
    return images.astype(np.float32) - mean_image.astype(np.float32)


def make_image_preprocess(mean_image: np.ndarray, crop: int, seed: int = 0):
    """Returns a pytree-batch transform for PrefetchLoader."""
    rng = np.random.default_rng(seed)

    def f(batch):
        imgs = subtract_mean(batch["images"], mean_image)
        return {**batch, "images": random_crop_flip(imgs, crop, rng)}

    return f
