"""Image preprocessing — the paper's loader-side transforms (footnote 2):
subtract the mean image, random crop, random horizontal flip.

Pure numpy, run on the host inside the loader thread (mirroring the paper's
separate loading process).  Deterministic given the seed.
"""
from __future__ import annotations

import numpy as np


def random_crop_flip(images: np.ndarray, crop: int, rng: np.random.Generator,
                     flip: bool = True) -> np.ndarray:
    """images (B, H, W, C) -> (B, crop, crop, C)."""
    b, h, w, c = images.shape
    assert h >= crop and w >= crop, (h, w, crop)
    ys = rng.integers(0, h - crop + 1, size=b)
    xs = rng.integers(0, w - crop + 1, size=b)
    out = np.empty((b, crop, crop, c), images.dtype)
    do_flip = rng.random(b) < 0.5 if flip else np.zeros(b, bool)
    for i in range(b):
        patch = images[i, ys[i]:ys[i] + crop, xs[i]:xs[i] + crop]
        out[i] = patch[:, ::-1] if do_flip[i] else patch
    return out


def subtract_mean(images: np.ndarray, mean_image: np.ndarray) -> np.ndarray:
    return images.astype(np.float32) - mean_image.astype(np.float32)


def make_image_preprocess(mean_image: np.ndarray, crop: int, seed: int = 0):
    """Returns a pytree-batch transform for PrefetchLoader."""
    rng = np.random.default_rng(seed)

    def f(batch):
        imgs = subtract_mean(batch["images"], mean_image)
        return {**batch, "images": random_crop_flip(imgs, crop, rng)}

    return f
