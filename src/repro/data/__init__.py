from repro.data.pipeline import PrefetchLoader
from repro.data import preprocess, synthetic  # noqa: F401
