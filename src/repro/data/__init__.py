from repro.data.pipeline import (PrefetchLoader, StagedPinnedLoader,
                                 make_loader)
from repro.data import preprocess, synthetic  # noqa: F401
