"""Synthetic datasets with learnable structure (no ImageNet in this
container) — used by the end-to-end examples, tests and benchmarks.

``markov_lm``: tokens drawn from a sharp random Markov chain; a model that
learns the transition table reaches low loss, so training-curve tests have a
real signal.  ``blob_images``: class-conditional Gaussian blobs at
class-dependent locations — linearly separable-ish, AlexNet learns it in a
few hundred steps.
"""
from __future__ import annotations

from typing import Iterator

import numpy as np


MAX_DENSE_STATES = 4096


def markov_lm(vocab: int, batch: int, seq_len: int, seed: int = 0,
              sharpness: float = 8.0,
              sample_seed: int = None) -> Iterator[dict]:
    """For vocab > MAX_DENSE_STATES, the chain runs over K superstates and
    each token is drawn uniformly inside its superstate's block — a dense
    VxV table at LM vocabs would need tens of GB (50304^2 doubles = 20 GB,
    the OOM that killed the first 100M run).

    ``sample_seed`` draws a different sample path over the SAME transition
    table (the table comes from ``seed`` alone) — this is how the eval
    loop gets a held-out stream of the same language the model trains on.
    """
    rng = np.random.default_rng(seed)
    k = min(vocab, MAX_DENSE_STATES)
    block = vocab // k
    logits = rng.normal(size=(k, k)) * sharpness / np.sqrt(k)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    cum = np.cumsum(probs, axis=-1)
    if sample_seed is not None:       # same table, independent sample path
        rng = np.random.default_rng(sample_seed)
    while True:
        states = np.empty((batch, seq_len), np.int32)
        states[:, 0] = rng.integers(0, k, size=batch)
        u = rng.random((batch, seq_len))
        for t in range(1, seq_len):
            states[:, t] = np.minimum(
                (cum[states[:, t - 1]] < u[:, t:t + 1]).sum(-1), k - 1)
        if block > 1:
            toks = (states * block
                    + rng.integers(0, block, size=states.shape)).astype(
                        np.int32)
        else:
            toks = states
        yield {"tokens": toks, "labels": toks.copy()}


def blob_images(n_classes: int, batch: int, size: int, channels: int = 3,
                seed: int = 0, noise: float = 0.35,
                task_seed: int = 12345) -> Iterator[dict]:
    rng = np.random.default_rng(seed)
    # the TASK (class centers/colors) is fixed by task_seed so differently
    # seeded streams (train/eval/mean) describe the same classes
    task_rng = np.random.default_rng(task_seed)
    centers = task_rng.uniform(0.2, 0.8, size=(n_classes, 2))
    colors = task_rng.uniform(0.3, 1.0, size=(n_classes, channels))
    yy, xx = np.mgrid[0:size, 0:size] / size
    while True:
        labels = rng.integers(0, n_classes, size=batch).astype(np.int32)
        imgs = rng.normal(scale=noise, size=(batch, size, size, channels))
        for i, lab in enumerate(labels):
            cy, cx = centers[lab]
            blob = np.exp(-(((yy - cy) ** 2 + (xx - cx) ** 2) / 0.02))
            imgs[i] += blob[..., None] * colors[lab]
        yield {"images": imgs.astype(np.float32), "labels": labels}


def mean_image(it: Iterator[dict], n_batches: int = 4) -> np.ndarray:
    acc, n = 0.0, 0
    for _ in range(n_batches):
        b = next(it)["images"]
        acc = acc + b.sum(0)
        n += b.shape[0]
    return (acc / n).astype(np.float32)
