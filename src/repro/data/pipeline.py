"""Parallel data loading — the JAX adaptation of the paper's §2.1 / Fig. 1.

The paper runs a separate *loading process* that copies the next minibatch
disk -> host -> GPU while the training process computes, handing off
device-resident buffers "instantly".  The CPython-GIL/multi-process motivation
does not apply here (preprocessing is numpy, which releases the GIL, and
``jax.device_put`` is async), so the same overlap is achieved with a
background thread and a depth-2 queue — a double buffer:

    loader thread:   fetch -> preprocess -> device_put (async) ->- queue
    trainer thread:  queue ->- step(current)            (overlapped)

``device_put`` returns immediately; the transfer overlaps the in-flight step
exactly like the paper's staged copy.  Set ``prefetch=0`` to get the serial
baseline (the paper's "Parallel loading: No" rows in Table 1).

``StagedPinnedLoader`` is the paper's Fig. 1 taken literally: instead of
allocating fresh host+device arrays every batch (page faults on first touch
— the dominant cost of a cold ``device_put``), the loader thread stages into
a *rotating pair of preallocated host buffers* that the backend maps
zero-copy.  Reusing a buffer that the in-flight step may still be reading
would corrupt the batch, so reuse is gated on a **fence**: after dispatching
the step that consumed a batch, the trainer hands the loader any output
token of that step (``loader.fence(loss)``); the worker blocks on the
*previous* step's token before overwriting that slot — off the critical
path, exactly the "wait on the previous step's tokens" handshake the
overlap timeline in docs/architecture.md draws.

Both loaders expose stall observability: ``last_wait_ms`` (time the
trainer spent blocked in ``next()`` for the most recent batch) and
``wait_ms_total`` — the session logs these per step as ``stage_wait_ms``.
"""
from __future__ import annotations

import collections
import queue
import threading
import time
from typing import Callable, Iterator, Optional

import jax
import numpy as np


class PrefetchLoader:
    """Wraps a host-batch iterator with background staging onto device(s).

    Args:
      source: iterator yielding pytrees of numpy arrays.
      prefetch: queue depth (2 = classic double buffer; 0 = synchronous).
      preprocess: host-side transform run in the loader thread (the paper's
        mean-subtract / crop / flip happens here).
      device_put: function staging a host pytree onto device(s); defaults to
        ``jax.device_put`` (pass a sharded variant for multi-device).
    """

    def __init__(self, source: Iterator, prefetch: int = 2,
                 preprocess: Optional[Callable] = None,
                 device_put: Optional[Callable] = None):
        self._source = iter(source)
        self._prefetch = prefetch
        self._preprocess = preprocess or (lambda x: x)
        self._device_put = device_put or jax.device_put
        self._q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._done = False           # sentinel seen: stay exhausted
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.last_wait_ms = 0.0      # trainer stall for the latest batch
        self.wait_ms_total = 0.0
        if prefetch > 0:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    def _worker(self):
        try:
            for batch in self._source:
                if self._stop.is_set():
                    return
                staged = self._device_put(self._preprocess(batch))
                if not self._put(staged):
                    return
            self._put(_SENTINEL)
        except Exception as e:                      # surface in consumer
            self._put(_ExcBox(e))

    def _put(self, item) -> bool:
        """Enqueue unless stopped; never blocks past close() (a plain
        ``put`` on a full queue would deadlock the close/join)."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def __iter__(self):
        return self

    def __next__(self):
        if self._stop.is_set():
            # after close() the queue is drained and the worker dead — a
            # bare q.get() would block forever (the seed's hang)
            raise RuntimeError("PrefetchLoader is closed")
        t0 = time.perf_counter()
        if self._prefetch == 0:
            out = self._device_put(self._preprocess(next(self._source)))
            self._record_wait(t0)    # serial: the whole stage is a stall
            return out
        if self._done:
            raise StopIteration      # sentinel already consumed once
        while True:
            # timed get so a close() racing a blocked consumer unblocks it
            try:
                item = self._q.get(timeout=0.1)
                break
            except queue.Empty:
                if self._stop.is_set():
                    raise RuntimeError("PrefetchLoader is closed") from None
                t = self._thread       # snapshot: close() may null the field
                if t is not None and not t.is_alive() and self._q.empty():
                    # worker died without a sentinel (its exception was
                    # already re-raised once) — don't spin forever
                    raise RuntimeError(
                        "PrefetchLoader worker exited") from None
        if item is _SENTINEL:
            self._done = True
            raise StopIteration
        if isinstance(item, _ExcBox):
            raise item.exc
        self._record_wait(t0)
        return item

    def _record_wait(self, t0: float):
        self.last_wait_ms = (time.perf_counter() - t0) * 1e3
        self.wait_ms_total += self.last_wait_ms

    def fence(self, token):
        """No-op: queue handoff never reuses buffers, so there is nothing
        to fence.  Present so the training loop can treat both loaders
        uniformly (StagedPinnedLoader needs the fence for correctness)."""

    def close(self):
        """Stop and JOIN the worker — a loader per benchmark config would
        otherwise leak a thread each (the old close never joined)."""
        self._stop.set()
        # drain so the worker can observe the stop flag
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None


_SENTINEL = object()
_CLOSED = object()


class _ExcBox:
    def __init__(self, exc):
        self.exc = exc


class StagedPinnedLoader:
    """Double-buffered staging into preallocated pinned host buffers.

    The worker rotates over ``slots`` (2 = the classic double buffer)
    preallocated host buffers: ``np.copyto`` into the warm buffer (no page
    faults after the first lap), then ``device_put`` — which the CPU
    backend maps zero-copy, so the "transfer" is free but the device array
    ALIASES the host buffer.  Overwriting a slot is therefore gated on the
    fence of the step that last consumed it:

        batch = next(loader)        # pre-staged, returns without copying
        state, loss = step(state, batch)     # async dispatch
        loader.fence(loss)          # any output token of that step

    ``fence`` associates the token with the oldest un-fenced handout; the
    worker calls ``block_until_ready`` on it *in the loader thread* before
    re-staging that slot.  One fence per consumed batch is mandatory —
    with both slots awaiting fences the pipeline is intentionally stalled
    (that is the correctness condition) and ``next()`` raises rather than
    deadlock.  Stall metrics match ``PrefetchLoader``.
    """

    def __init__(self, source: Iterator,
                 preprocess: Optional[Callable] = None,
                 device_put: Optional[Callable] = None, slots: int = 2):
        assert slots >= 2, "need at least a double buffer"
        self._source = iter(source)
        self._preprocess = preprocess or (lambda x: x)
        self._device_put = device_put or jax.device_put
        self._slots = slots
        self._host = [None] * slots          # preallocated numpy buffers
        # per-slot fence tokens; pre-seeded None = slot starts free
        self._free = [queue.Queue(maxsize=1) for _ in range(slots)]
        for fq in self._free:
            fq.put(None)
        self._handout: collections.deque = collections.deque()
        self._q: queue.Queue = queue.Queue(maxsize=slots)
        self._done = False
        self._stop = threading.Event()
        self.last_wait_ms = 0.0
        self.wait_ms_total = 0.0
        self.fence_wait_ms_total = 0.0       # worker-side, off critical path
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    # ------------------------------------------------------ worker side --
    def _take_fence(self, s: int):
        """Previous consumer's token for slot ``s`` (None on first lap)."""
        while not self._stop.is_set():
            try:
                return self._free[s].get(timeout=0.05)
            except queue.Empty:
                continue
        return _CLOSED

    def _worker(self):
        try:
            s = 0
            for batch in self._source:
                if self._stop.is_set():
                    return
                host = self._preprocess(batch)
                tok = self._take_fence(s)
                if tok is _CLOSED:
                    return
                if tok is not None:
                    t0 = time.perf_counter()
                    jax.block_until_ready(tok)   # step done => reads done
                    self.fence_wait_ms_total += \
                        (time.perf_counter() - t0) * 1e3
                dst = self._host[s]
                leaves = jax.tree.leaves(host)
                if dst is None or len(jax.tree.leaves(dst)) != len(leaves) \
                        or any(d.shape != x.shape or d.dtype != x.dtype
                               for d, x in zip(jax.tree.leaves(dst),
                                               leaves)):
                    # first lap (or a ragged final batch): allocate fresh
                    dst = jax.tree.map(
                        lambda x: np.empty(x.shape, x.dtype), host)
                    self._host[s] = dst
                jax.tree.map(lambda d, x: np.copyto(d, x), dst, host)
                staged = self._device_put(dst)
                if not self._put((s, staged)):
                    return
                s = (s + 1) % self._slots
            self._put(_SENTINEL)
        except Exception as e:                  # surface in consumer
            self._put(_ExcBox(e))

    def _put(self, item) -> bool:
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    # ---------------------------------------------------- consumer side --
    def __iter__(self):
        return self

    def __next__(self):
        if self._stop.is_set():
            raise RuntimeError("StagedPinnedLoader is closed")
        if self._done:
            raise StopIteration
        t0 = time.perf_counter()
        while True:
            try:
                item = self._q.get(timeout=0.1)
                break
            except queue.Empty:
                if self._stop.is_set():
                    raise RuntimeError(
                        "StagedPinnedLoader is closed") from None
                if len(self._handout) >= self._slots:
                    raise RuntimeError(
                        "all staging slots await fences — call "
                        "loader.fence(<step output>) after each consumed "
                        "batch") from None
                if not self._thread.is_alive() and self._q.empty():
                    raise RuntimeError(
                        "StagedPinnedLoader worker exited") from None
        if item is _SENTINEL:
            self._done = True
            raise StopIteration
        if isinstance(item, _ExcBox):
            raise item.exc
        slot, staged = item
        self._handout.append(slot)
        self.last_wait_ms = (time.perf_counter() - t0) * 1e3
        self.wait_ms_total += self.last_wait_ms
        return staged

    def fence(self, token):
        """Mark the oldest un-fenced batch's slot reusable once ``token``
        (any device output of the step that consumed it) is ready."""
        if self._handout:
            self._free[self._handout.popleft()].put(token)

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None


def make_loader(source: Iterator, *, prefetch: int = 2,
                staging: str = "queue",
                preprocess: Optional[Callable] = None,
                device_put: Optional[Callable] = None):
    """Loader factory the launchers use.  ``staging="queue"`` is the
    depth-``prefetch`` handoff queue (``prefetch=0`` = serial baseline);
    ``staging="pinned"`` is the double-buffered pinned path (needs the
    ``fence`` handshake — see StagedPinnedLoader)."""
    if staging == "pinned":
        return StagedPinnedLoader(source, preprocess=preprocess,
                                  device_put=device_put,
                                  slots=max(prefetch, 2))
    if staging != "queue":
        raise ValueError(f"staging must be 'queue' or 'pinned', "
                         f"got {staging!r}")
    return PrefetchLoader(source, prefetch=prefetch, preprocess=preprocess,
                          device_put=device_put)
