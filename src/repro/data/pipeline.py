"""Parallel data loading — the JAX adaptation of the paper's §2.1 / Fig. 1.

The paper runs a separate *loading process* that copies the next minibatch
disk -> host -> GPU while the training process computes, handing off
device-resident buffers "instantly".  The CPython-GIL/multi-process motivation
does not apply here (preprocessing is numpy, which releases the GIL, and
``jax.device_put`` is async), so the same overlap is achieved with a
background thread and a depth-2 queue — a double buffer:

    loader thread:   fetch -> preprocess -> device_put (async) ->- queue
    trainer thread:  queue ->- step(current)            (overlapped)

``device_put`` returns immediately; the transfer overlaps the in-flight step
exactly like the paper's staged copy.  Set ``prefetch=0`` to get the serial
baseline (the paper's "Parallel loading: No" rows in Table 1).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax


class PrefetchLoader:
    """Wraps a host-batch iterator with background staging onto device(s).

    Args:
      source: iterator yielding pytrees of numpy arrays.
      prefetch: queue depth (2 = classic double buffer; 0 = synchronous).
      preprocess: host-side transform run in the loader thread (the paper's
        mean-subtract / crop / flip happens here).
      device_put: function staging a host pytree onto device(s); defaults to
        ``jax.device_put`` (pass a sharded variant for multi-device).
    """

    def __init__(self, source: Iterator, prefetch: int = 2,
                 preprocess: Optional[Callable] = None,
                 device_put: Optional[Callable] = None):
        self._source = iter(source)
        self._prefetch = prefetch
        self._preprocess = preprocess or (lambda x: x)
        self._device_put = device_put or jax.device_put
        self._q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._done = False           # sentinel seen: stay exhausted
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        if prefetch > 0:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    def _worker(self):
        try:
            for batch in self._source:
                if self._stop.is_set():
                    return
                staged = self._device_put(self._preprocess(batch))
                if not self._put(staged):
                    return
            self._put(_SENTINEL)
        except Exception as e:                      # surface in consumer
            self._put(_ExcBox(e))

    def _put(self, item) -> bool:
        """Enqueue unless stopped; never blocks past close() (a plain
        ``put`` on a full queue would deadlock the close/join)."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def __iter__(self):
        return self

    def __next__(self):
        if self._stop.is_set():
            # after close() the queue is drained and the worker dead — a
            # bare q.get() would block forever (the seed's hang)
            raise RuntimeError("PrefetchLoader is closed")
        if self._prefetch == 0:
            return self._device_put(self._preprocess(next(self._source)))
        if self._done:
            raise StopIteration      # sentinel already consumed once
        while True:
            # timed get so a close() racing a blocked consumer unblocks it
            try:
                item = self._q.get(timeout=0.1)
                break
            except queue.Empty:
                if self._stop.is_set():
                    raise RuntimeError("PrefetchLoader is closed") from None
                t = self._thread       # snapshot: close() may null the field
                if t is not None and not t.is_alive() and self._q.empty():
                    # worker died without a sentinel (its exception was
                    # already re-raised once) — don't spin forever
                    raise RuntimeError(
                        "PrefetchLoader worker exited") from None
        if item is _SENTINEL:
            self._done = True
            raise StopIteration
        if isinstance(item, _ExcBox):
            raise item.exc
        return item

    def close(self):
        """Stop and JOIN the worker — a loader per benchmark config would
        otherwise leak a thread each (the old close never joined)."""
        self._stop.set()
        # drain so the worker can observe the stop flag
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None


_SENTINEL = object()


class _ExcBox:
    def __init__(self, exc):
        self.exc = exc
