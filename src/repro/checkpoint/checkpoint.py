"""Pytree checkpointing: raw-bytes npz + JSON manifest.

bfloat16 has no native numpy dtype, so every leaf is stored as a uint8
buffer with (dtype, shape) recorded in the manifest — round-trips any jax
dtype exactly.  Layout:

    <dir>/step_<N>/manifest.json
    <dir>/step_<N>/arrays.npz     (key = flattened pytree path)
"""
from __future__ import annotations

import json
import os
import re
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def save(directory: str, step: int, tree: Any) -> str:
    d = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    flat = _flatten(tree)
    manifest, buffers = {}, {}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        manifest[key] = {"dtype": str(leaf.dtype), "shape": list(arr.shape)}
        buffers[key] = np.frombuffer(arr.tobytes(), np.uint8)
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump({"step": step, "arrays": manifest}, f)
    np.savez(os.path.join(d, "arrays.npz"), **buffers)
    return d


def restore(directory: str, step: int, like: Any) -> Any:
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)["arrays"]
    data = np.load(os.path.join(d, "arrays.npz"))
    flat_like = _flatten(like)
    restored = {}
    for key in flat_like:
        meta = manifest[key]
        buf = data[key].tobytes()
        np_dtype = jnp.dtype(meta["dtype"])       # ml_dtypes handles bf16
        arr = np.frombuffer(buf, dtype=np_dtype).reshape(meta["shape"])
        restored[key] = jnp.asarray(arr)
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    keys = list(_flatten(like).keys())
    return jax.tree_util.tree_unflatten(treedef,
                                        [restored[k] for k in keys])


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for name in os.listdir(directory)
             if (m := re.match(r"step_(\d+)$", name))]
    return max(steps) if steps else None
