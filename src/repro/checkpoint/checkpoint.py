"""Pytree checkpointing: raw-bytes npz + JSON manifest.

bfloat16 has no native numpy dtype, so every leaf is stored as a uint8
buffer with (dtype, shape) recorded in the manifest — round-trips any jax
dtype exactly.  Layout:

    <dir>/step_<N>/manifest.json
    <dir>/step_<N>/arrays.npz     (key = flattened pytree path)

Checkpoints are written atomically (into ``step_<N>.tmp``, renamed into
place) so a run killed mid-save never leaves a directory that
``latest_step`` would try to resume from; ``latest_step`` additionally
verifies completeness (manifest parses, arrays present) and skips corrupt
directories.

``restore`` is sharding-aware: pass ``sharding=`` a pytree of
``jax.sharding.Sharding`` (same structure as ``like``, e.g. from
``sharding.specs.replica_sharding``) and every leaf is ``device_put`` onto
the live mesh layout — a resumed TrainState lands on exactly the devices a
fresh run would use, for both the mesh and reference engines.  Without it,
leaves land on the default device (the seed behavior, which silently
dropped sharding).

The manifest carries an optional ``meta`` dict (JSON) for host-side session
state — loader position, LR-plateau controller state, RNG seeds — so a
training *session* (repro.train_loop) can resume deterministically, not
just the device arrays.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:08d}")


def save(directory: str, step: int, tree: Any, meta: dict = None) -> str:
    """Write ``tree`` (+ optional JSON-serializable ``meta``) atomically."""
    final = step_dir(directory, step)
    d = final + ".tmp"
    if os.path.isdir(d):
        shutil.rmtree(d)
    os.makedirs(d)
    flat = _flatten(tree)
    manifest, buffers = {}, {}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        manifest[key] = {"dtype": str(leaf.dtype), "shape": list(arr.shape)}
        buffers[key] = np.frombuffer(arr.tobytes(), np.uint8)
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump({"step": step, "arrays": manifest, "meta": meta}, f)
    np.savez(os.path.join(d, "arrays.npz"), **buffers)
    if os.path.isdir(final):
        shutil.rmtree(final)
    os.rename(d, final)
    return final


def restore(directory: str, step: int, like: Any, *,
            sharding: Any = None) -> Any:
    """Rebuild the pytree saved at ``step``.

    ``like`` supplies structure (values ignored; ShapeDtypeStructs work).
    ``sharding``, when given, is a pytree of ``jax.sharding.Sharding`` with
    the same structure; each restored leaf is ``device_put`` to its
    sharding so it lands on the live mesh layout instead of the default
    device.
    """
    d = step_dir(directory, step)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)["arrays"]
    data = np.load(os.path.join(d, "arrays.npz"))
    flat_like = _flatten(like)
    flat_shard = _flatten(sharding) if sharding is not None else {}
    restored = {}
    for key in flat_like:
        meta = manifest[key]
        buf = data[key].tobytes()
        np_dtype = jnp.dtype(meta["dtype"])       # ml_dtypes handles bf16
        arr = np.frombuffer(buf, dtype=np_dtype).reshape(meta["shape"])
        if key in flat_shard and flat_shard[key] is not None:
            restored[key] = jax.device_put(arr, flat_shard[key])
        else:
            restored[key] = jnp.asarray(arr)
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    keys = list(flat_like.keys())
    return jax.tree_util.tree_unflatten(treedef,
                                        [restored[k] for k in keys])


def pack_tree(tree: Any, meta: dict = None) -> bytes:
    """Serialize a pytree (+ optional JSON ``meta``) into one in-memory
    buffer — the wire form of ``save``: same raw-uint8 leaf container,
    same manifest layout, no filesystem.  The serving tier ships live
    ``DecodeState`` slot slices between processes with this
    (serving/tier.py drain/handoff, router→decode prefill handoff);
    ``unpack_tree`` round-trips any jax dtype exactly, bf16 included."""
    import io
    flat = _flatten(tree)
    manifest, buffers = {}, {}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        manifest[key] = {"dtype": str(leaf.dtype), "shape": list(arr.shape)}
        buffers[key] = np.frombuffer(arr.tobytes(), np.uint8)
    mjson = json.dumps({"arrays": manifest, "meta": meta}).encode()
    bio = io.BytesIO()
    bio.write(len(mjson).to_bytes(8, "little"))
    bio.write(mjson)
    np.savez(bio, **buffers)
    return bio.getvalue()


def peek_meta(buf: bytes) -> dict | None:
    """The ``meta`` dict of a ``pack_tree`` buffer WITHOUT the arrays —
    no ``like`` structure needed, nothing decoded but the JSON header
    (the router reads request bookkeeping off in-flight snapshots whose
    model structure only the engine processes know)."""
    n = int.from_bytes(buf[:8], "little")
    return json.loads(buf[8:8 + n].decode()).get("meta")


def unpack_tree(buf: bytes, like: Any) -> tuple[Any, dict | None]:
    """Inverse of ``pack_tree``: (tree shaped like ``like``, meta)."""
    import io
    n = int.from_bytes(buf[:8], "little")
    manifest = json.loads(buf[8:8 + n].decode())
    data = np.load(io.BytesIO(buf[8 + n:]))
    restored = {}
    for key in _flatten(like):
        meta = manifest["arrays"][key]
        arr = np.frombuffer(data[key].tobytes(),
                            dtype=jnp.dtype(meta["dtype"]))
        restored[key] = jnp.asarray(arr.reshape(meta["shape"]))
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    keys = list(_flatten(like).keys())
    tree = jax.tree_util.tree_unflatten(treedef,
                                        [restored[k] for k in keys])
    return tree, manifest.get("meta")


def load_meta(directory: str, step: int) -> dict | None:
    """The ``meta`` dict stored with ``save`` (None when absent)."""
    with open(os.path.join(step_dir(directory, step), "manifest.json")) as f:
        return json.load(f).get("meta")


def _complete(d: str) -> bool:
    """A checkpoint dir is resumable iff manifest parses and arrays exist."""
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            json.load(f)
    except (OSError, ValueError):
        return False
    return os.path.isfile(os.path.join(d, "arrays.npz"))


def latest_step(directory: str) -> int | None:
    """Largest step with a COMPLETE checkpoint (gaps fine; ``.tmp`` dirs
    from interrupted saves and corrupt/partial dirs are skipped)."""
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for name in os.listdir(directory)
             if (m := re.match(r"step_(\d+)$", name))
             and _complete(os.path.join(directory, name))]
    return max(steps) if steps else None
