from repro.checkpoint.checkpoint import (latest_step, load_meta, pack_tree,
                                         peek_meta, restore, save, step_dir,
                                         unpack_tree)
