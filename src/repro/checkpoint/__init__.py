from repro.checkpoint.checkpoint import (latest_step, load_meta, restore,
                                         save, step_dir)
