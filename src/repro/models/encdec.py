"""Encoder-decoder backbone (seamless-m4t-medium assignment).

The audio frontend is a STUB per the assignment carve-out: the encoder
consumes precomputed frame embeddings (B, T_enc, d_model) directly — the
mel-spectrogram + conformer feature extractor is not part of the backbone.

Encoder: bidirectional dense blocks.  Decoder: causal self-attention +
cross-attention over the encoder memory + MLP.  Both stacks scan over
layers.  Decode carries a self-attention ring cache and precomputed cross
K/V (built once from the encoder output).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models._unroll import scan_or_unroll

from repro.models import attention as attn
from repro.models.layers import (embed_apply, embed_init, mlp_apply, mlp_init,
                                 norm_apply, norm_init, unembed_apply)


def _dtype(cfg):
    from repro.numerics import param_dtype
    return param_dtype(cfg)


def init(rng, cfg):
    dt = _dtype(cfg)
    ks = jax.random.split(rng, 6)

    def enc_block(k):
        k1, k2 = jax.random.split(k)
        return {"norm1": norm_init(cfg, dt), "attn": attn.attn_init(k1, cfg, dt),
                "norm2": norm_init(cfg, dt), "ffn": mlp_init(k2, cfg, dt)}

    def dec_block(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"norm1": norm_init(cfg, dt), "self_attn": attn.attn_init(k1, cfg, dt),
                "norm_x": norm_init(cfg, dt), "cross_attn": attn.attn_init(k2, cfg, dt),
                "norm2": norm_init(cfg, dt), "ffn": mlp_init(k3, cfg, dt)}

    return {
        "embed": embed_init(ks[0], cfg, dt),
        "enc_blocks": jax.vmap(enc_block)(jax.random.split(ks[1], cfg.n_enc_layers)),
        "enc_norm": norm_init(cfg, dt),
        "dec_blocks": jax.vmap(dec_block)(jax.random.split(ks[2], cfg.n_layers)),
        "final_norm": norm_init(cfg, dt),
    }


def encode(params, cfg, frames, remat=False):
    """frames (B, T_enc, d_model) stub embeddings -> encoder memory."""
    def block(h, bp):
        x = norm_apply(bp["norm1"], cfg, h)
        h = h + attn.full_attention(bp["attn"], cfg, x, causal=False)
        x = norm_apply(bp["norm2"], cfg, h)
        return h + mlp_apply(bp["ffn"], cfg, x), None

    if remat:
        block = jax.checkpoint(block)
    h, _ = scan_or_unroll(block, frames, params["enc_blocks"])
    return norm_apply(params["enc_norm"], cfg, h)


def _dec_block_seq(bp, cfg, h, memory):
    x = norm_apply(bp["norm1"], cfg, h)
    h = h + attn.full_attention(bp["self_attn"], cfg, x, causal=True)
    x = norm_apply(bp["norm_x"], cfg, h)
    h = h + attn.full_attention(bp["cross_attn"], cfg, x, xc=memory,
                                causal=False, rope=False)
    x = norm_apply(bp["norm2"], cfg, h)
    return h + mlp_apply(bp["ffn"], cfg, x)


def forward(params, cfg, frames, dec_tokens, remat=False):
    """Returns (logits (B,S,V) f32, aux=0)."""
    memory = encode(params, cfg, frames, remat=remat)
    h = embed_apply(params["embed"], cfg, dec_tokens)

    def block(h, bp):
        return _dec_block_seq(bp, cfg, h, memory), None

    if remat:
        block = jax.checkpoint(block)
    h, _ = scan_or_unroll(block, h, params["dec_blocks"])
    h = norm_apply(params["final_norm"], cfg, h)
    return unembed_apply(params["embed"], cfg, h), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------- decode ----

def init_decode_cache(cfg, batch: int, seq_len: int, enc_len: int):
    dt = _dtype(cfg)
    nl = cfg.n_layers

    def stack(x):
        return jnp.broadcast_to(x[None], (nl,) + x.shape).copy()

    self_c = attn.init_cache(cfg, batch, attn.cache_capacity(cfg, seq_len), dt)
    cross = {"k": jnp.zeros((batch, enc_len, cfg.n_kv_heads, cfg.head_dim), dt),
             "v": jnp.zeros((batch, enc_len, cfg.n_kv_heads, cfg.head_dim), dt)}
    return {"self": jax.tree.map(stack, self_c),
            "cross": jax.tree.map(stack, cross)}


def prefill(params, cfg, frames, tokens, capacity: int, *, length=None):
    """Encode frames, run the decoder over the prompt, and fill the decode
    cache: per-layer self-attention ring buffers (capacity ``capacity``)
    plus the precomputed cross K/V.  Returns (logits (B,S,V), cache) —
    the cache is exactly what ``init_decode_cache`` + S ``decode_step``
    calls would have produced.  ``length`` supports right-padded prompts
    (per-row true lengths), as in ``transformer.prefill``.
    """
    dt = _dtype(cfg)
    b, s = tokens.shape
    memory = encode(params, cfg, frames)
    cross = build_cross_cache(params, cfg, memory)
    cap = attn.cache_capacity(cfg, capacity)
    h = embed_apply(params["embed"], cfg, tokens)

    def block(h, bp):
        x = norm_apply(bp["norm1"], cfg, h)
        h = h + attn.full_attention(bp["self_attn"], cfg, x, causal=True)
        sc = attn.fill_cache(bp["self_attn"], cfg, x,
                             attn.init_cache(cfg, b, cap, dt), length=length)
        x = norm_apply(bp["norm_x"], cfg, h)
        h = h + attn.full_attention(bp["cross_attn"], cfg, x, xc=memory,
                                    causal=False, rope=False)
        x = norm_apply(bp["norm2"], cfg, h)
        return h + mlp_apply(bp["ffn"], cfg, x), sc

    h, self_c = scan_or_unroll(block, h, params["dec_blocks"])
    h = norm_apply(params["final_norm"], cfg, h)
    logits = unembed_apply(params["embed"], cfg, h)
    return logits, {"self": self_c, "cross": cross}


def build_cross_cache(params, cfg, memory):
    """Precompute per-layer cross-attention K/V from the encoder memory."""
    def one(bp):
        _, k, v = attn._qkv(bp["cross_attn"], cfg, memory)
        return {"k": k, "v": v}

    return jax.vmap(one)(params["dec_blocks"])


def decode_step(params, cfg, cache, tokens, pos):
    """tokens (B,1); cache {'self': stacked ring, 'cross': stacked K/V}."""
    h = embed_apply(params["embed"], cfg, tokens)

    def block(h, xs):
        bp, sc, cc = xs
        x = norm_apply(bp["norm1"], cfg, h)
        y, new_sc = attn.decode_attention(bp["self_attn"], cfg, x, sc, pos)
        h = h + y
        x = norm_apply(bp["norm_x"], cfg, h)
        h = h + attn.cross_decode(bp["cross_attn"], cfg, x, cc)
        x = norm_apply(bp["norm2"], cfg, h)
        return h + mlp_apply(bp["ffn"], cfg, x), new_sc

    h, new_self = scan_or_unroll(block, h,
                               (params["dec_blocks"], cache["self"],
                                cache["cross"]))
    h = norm_apply(params["final_norm"], cfg, h)
    logits = unembed_apply(params["embed"], cfg, h)
    return logits, {"self": new_self, "cross": cache["cross"]}
