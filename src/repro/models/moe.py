"""Mixture-of-Experts FFN with top-k routing and capacity-bounded sort-based
dispatch (GShard semantics, MaxText-style mechanics).

One-hot dispatch einsums cost O(S·E·C·d) FLOPs — for mixtral-scale configs
that is >100x the expert FFN itself and would poison the roofline.  We
instead sort token-assignments by expert id, compute each token's rank within
its expert (position-in-expert), scatter into a static (E, C, d) buffer
(overflow tokens dropped, GShard-style), run batched expert matmuls, and
gather back weighted by the router gate.  FLOPs = capacity_factor x active.

Two dispatch scopes (``MoEConfig.dispatch``):
  ``flat``     one sort over all B*S tokens — maximum balance, but under
               GSPMD the sort/scatter crosses the whole mesh (collective-
               heavy; the baseline the paper-era GShard design implies)
  ``rowwise``  vmap the dispatch over the batch dim — routing stays local
               to each batch shard, trading a little capacity slack for
               locality (beyond-paper perf variant; see EXPERIMENTS §Perf)

Aux load-balance loss is the Switch Transformer form: E * Σ_e f_e * p_e.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import policy_of
from repro.models.layers import dense_init, matmul, mlp_apply, mlp_init


def moe_init(rng, cfg, dtype):
    d, f, m = cfg.d_model, cfg.d_ff, cfg.moe
    ks = jax.random.split(rng, 5)
    e = m.n_experts
    p = {
        "router": dense_init(ks[0], d, e, dtype),
        "w_in": (jax.random.normal(ks[1], (e, d, f), jnp.float32)
                 * d ** -0.5).astype(dtype),
        "w_out": (jax.random.normal(ks[2], (e, f, d), jnp.float32)
                  * f ** -0.5).astype(dtype),
    }
    if cfg.mlp in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(ks[3], (e, d, f), jnp.float32)
                       * d ** -0.5).astype(dtype)
    if m.shared_expert:
        p["shared"] = mlp_init(ks[4], cfg, dtype)
    return p


def _buffer_constraint(x, bspec):
    """GSPMD hint: first len(bspec) dims per bspec, rest unsharded.  Keeping
    the hidden (f) dim of intermediates UNSHARDED forces XLA to all-gather
    the (small) FSDP'd weight shards instead of partial-sum all-reducing the
    (huge) activation buffers."""
    if bspec is None:
        return x
    from jax.sharding import PartitionSpec as _P
    spec = _P(*(tuple(bspec) + (None,) * (x.ndim - len(bspec))))
    return jax.lax.with_sharding_constraint(x, spec)


def _expert_ffn_pallas(p, cfg, x):
    """x (E, C, d) -> (E, C, d) via per-expert Pallas GEMMs.

    Explicit opt-in through ``KernelPolicy(matmul="pallas")`` — one
    ``matmul_bias`` kernel call per expert weight (differentiable via its
    custom_vjp), so the whole MoE FFN can ride the same compiled GEMM the
    conv pipeline uses.  GSPMD buffer-sharding hints do not apply inside
    the manual kernels, so ``buffer_sharding`` is ignored on this path.
    """
    from repro.kernels.conv2d.conv2d import matmul_bias
    pol = policy_of(cfg)
    interpret = pol.interpret
    e = x.shape[0]
    f = p["w_in"].shape[-1]
    d = p["w_out"].shape[-1]
    zf = jnp.zeros((f,), x.dtype)
    zd = jnp.zeros((d,), x.dtype)
    gated = cfg.mlp in ("swiglu", "geglu")
    act = jax.nn.silu if cfg.mlp == "swiglu" else jax.nn.gelu
    outs = []
    for ei in range(e):
        h = matmul_bias(x[ei], p["w_in"][ei].astype(x.dtype), zf,
                        interpret=interpret, autotune=pol.autotune)
        if gated:
            g = matmul_bias(x[ei], p["w_gate"][ei].astype(x.dtype), zf,
                            interpret=interpret, autotune=pol.autotune)
            h = h * act(g)
        else:
            h = jax.nn.gelu(h)
        outs.append(matmul_bias(h, p["w_out"][ei].astype(x.dtype), zd,
                                interpret=interpret, autotune=pol.autotune))
    return jnp.stack(outs)


def _expert_ffn(p, cfg, x, bspec=None):
    """x (E, C, d) -> (E, C, d) via batched expert matmuls."""
    if policy_of(cfg).wants_pallas("matmul"):
        return _expert_ffn_pallas(p, cfg, x)
    h = jnp.einsum("ecd,edf->ecf", x, p["w_in"].astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if cfg.mlp == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", x, p["w_gate"].astype(x.dtype),
                       preferred_element_type=jnp.float32).astype(x.dtype)
        h = h * jax.nn.silu(g)
    elif cfg.mlp == "geglu":
        g = jnp.einsum("ecd,edf->ecf", x, p["w_gate"].astype(x.dtype),
                       preferred_element_type=jnp.float32).astype(x.dtype)
        h = h * jax.nn.gelu(g)
    else:
        h = jax.nn.gelu(h)
    # NOTE: constraining h to f-unsharded here was tried (EXPERIMENTS §Perf
    # B5) and REFUTED — it replicates the C dim across 'data' (5x compute).
    return jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(x.dtype),
                      preferred_element_type=jnp.float32).astype(x.dtype)


def _dispatch_ffn(p, cfg, xt, cf):
    """Sort-based dispatch over a flat token block xt (T, d).

    Returns (out (T, d), aux scalar)."""
    m = cfg.moe
    t, d = xt.shape
    e, k = m.n_experts, m.top_k

    logits = matmul(xt, p["router"]).astype(jnp.float32)     # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eid = jax.lax.top_k(probs, k)                      # (T, K)
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)

    # Switch-style load balance aux: E * Σ_e (fraction routed) * (mean prob)
    f_e = jnp.mean(jax.nn.one_hot(eid[:, 0], e, dtype=jnp.float32), axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f_e * p_e) * m.router_aux_coef

    cap = int(max(1, min(t * k, round(t * k * cf / e))))

    flat_e = eid.reshape(t * k)                              # (TK,)
    flat_tok = jnp.repeat(jnp.arange(t), k)                  # token id per slot
    order = jnp.argsort(flat_e)                              # stable
    se = flat_e[order]
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.cumsum(counts) - counts                     # exclusive prefix
    pos = jnp.arange(t * k) - starts[se]                     # rank in expert
    keep = pos < cap
    buf = jnp.zeros((e, cap, d), xt.dtype)
    src = xt[flat_tok[order]]                                # (TK, d)
    # pos >= cap is out-of-bounds on axis 1 => dropped by mode="drop"
    buf = buf.at[se, pos].set(src, mode="drop")

    bspec = getattr(m, "buffer_sharding", None)
    buf = _buffer_constraint(buf, bspec)
    out_buf = _expert_ffn(p, cfg, buf, bspec)                # (E, C, d)
    out_buf = _buffer_constraint(out_buf, bspec)

    vals = out_buf[se, jnp.clip(pos, 0, cap - 1)]            # (TK, d)
    vals = jnp.where(keep[:, None], vals, 0.0)
    gflat = gate.reshape(t * k)[order]
    out = jnp.zeros((t, d), xt.dtype).at[flat_tok[order]].add(
        vals * gflat[:, None].astype(xt.dtype))
    return out, aux


def moe_apply(p, cfg, x, capacity_factor: float | None = None):
    """x (B,S,d).  Returns (out (B,S,d), aux_loss scalar)."""
    m = cfg.moe
    cf = m.capacity_factor if capacity_factor is None else capacity_factor
    b, s, d = x.shape
    if getattr(m, "dispatch", "flat") == "rowwise":
        out, aux = jax.vmap(lambda xr: _dispatch_ffn(p, cfg, xr, cf))(x)
        aux = jnp.mean(aux)
    else:
        out, aux = _dispatch_ffn(p, cfg, x.reshape(b * s, d), cf)
        out = out.reshape(b, s, d)
    if m.shared_expert:
        out = out + mlp_apply(p["shared"], cfg, x.reshape(b * s, d)
                              ).reshape(b, s, d)
    return out, aux
