"""Decoder-only transformer composer covering dense / moe / ssm / hybrid /
vlm families through a per-layer *pattern* of block kinds.

Layers are grouped into superblocks of ``len(pattern)`` and scanned with
``jax.lax.scan`` (params stacked per pattern position) — this keeps the HLO
size O(pattern) instead of O(n_layers), which is what makes 40-layer configs
lower in reasonable time.  A remainder of ``n_layers % len(pattern)`` layers
is applied unstacked.

Block kinds:
  ``dense``  attention (full or SWA per config) + dense MLP
  ``moe``    attention + MoE FFN
  ``attn``   local (sliding-window) attention + MLP       [hybrid]
  ``rec``    RG-LRU recurrent block + MLP                 [hybrid]
  ``rwkv``   RWKV6 time-mix + channel-mix                 [ssm]
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models._unroll import scan_or_unroll

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import rwkv as rwkv_mod
from repro.models.layers import (embed_apply, embed_init, mlp_apply, mlp_init,
                                 norm_apply, norm_init, unembed_apply)


def block_kinds(cfg) -> Tuple[str, ...]:
    if cfg.family == "hybrid":
        return tuple(cfg.layer_pattern or ("rec", "rec", "attn"))
    if cfg.family == "moe":
        k = cfg.moe.every_k
        return ("dense",) * (k - 1) + ("moe",) if k > 1 else ("moe",)
    if cfg.family == "ssm":
        return ("rwkv",)
    return ("dense",)      # dense, vlm


def _dtype(cfg):
    # NumericsPolicy-aware: policy param_dtype wins, else the config's
    # legacy dtype field
    from repro.numerics import param_dtype
    return param_dtype(cfg)


def _split(cfg):
    pattern = block_kinds(cfg)
    np_, rem = divmod(cfg.n_layers, len(pattern))
    return pattern, np_, rem


# ------------------------------------------------------------------ init ----

def block_init(rng, cfg, kind: str):
    dt = _dtype(cfg)
    ks = jax.random.split(rng, 4)
    if kind == "rwkv":
        p = rwkv_mod.rwkv_block_init(ks[0], cfg, dt)
        p["norm1"] = norm_init(cfg, dt)
        p["norm2"] = norm_init(cfg, dt)
        return p
    p = {"norm1": norm_init(cfg, dt), "norm2": norm_init(cfg, dt)}
    if kind == "rec":
        p["mix"] = rglru_mod.rglru_block_init(ks[0], cfg, dt)
    else:
        p["attn"] = attn.attn_init(ks[0], cfg, dt)
    if kind == "moe":
        p["ffn"] = moe_mod.moe_init(ks[1], cfg, dt)
    else:
        p["ffn"] = mlp_init(ks[1], cfg, dt)
    return p


def init(rng, cfg):
    pattern, np_, rem = _split(cfg)
    ks = jax.random.split(rng, 4)
    dt = _dtype(cfg)
    params = {"embed": embed_init(ks[0], cfg, dt),
              "final_norm": norm_init(cfg, dt)}
    blocks = []
    if np_ > 0:
        for pi, kind in enumerate(pattern):
            krng = jax.random.split(jax.random.fold_in(ks[1], pi), np_)
            blocks.append(jax.vmap(lambda k, kd=kind: block_init(k, cfg, kd))(krng))
    params["blocks"] = tuple(blocks)
    params["rem_blocks"] = tuple(
        block_init(jax.random.fold_in(ks[2], i), cfg, pattern[i])
        for i in range(rem))
    return params


# --------------------------------------------------------------- forward ----

def _window(cfg, kind):
    # hybrid "attn" layers are local by construction; dense/moe layers are
    # windowed only when the config says so (mixtral/llama4/gemma-swa)
    return cfg.sliding_window


def block_apply_seq(p, cfg, kind, h, *, cache=None, length=None):
    """Full-sequence block.  Returns (h, aux, new_cache).

    ``cache`` (optional) is this block's decode-cache; when given, carry
    state (rwkv/rec) resumes from it and the returned new_cache reflects the
    processed sequence (attention blocks fill their ring buffer).

    ``length`` (scalar or (B,) int32) marks per-row true prefix lengths of
    a right-padded batch: the returned new_cache is the state each row
    would have after exactly ``length[b]`` tokens (causality keeps the
    sub-``length`` OUTPUTS exact without it; only cache extraction needs
    the mask).
    """
    aux = jnp.zeros((), jnp.float32)
    if kind == "rwkv":
        y, (tm_shift, wkv) = rwkv_mod.time_mix_seq(
            p, cfg, norm_apply(p["norm1"], cfg, h),
            None if cache is None else cache["tm_shift"],
            None if cache is None else cache["wkv"], length=length)
        h = h + y
        y, cm_shift = rwkv_mod.channel_mix_seq(
            p, cfg, norm_apply(p["norm2"], cfg, h),
            None if cache is None else cache["cm_shift"], length=length)
        h = h + y
        new_cache = {"tm_shift": tm_shift, "wkv": wkv, "cm_shift": cm_shift}
        return h, aux, new_cache

    x = norm_apply(p["norm1"], cfg, h)
    new_cache = None
    if kind == "rec":
        y, new_cache = rglru_mod.rglru_seq(
            p["mix"], cfg, x, None if cache is None else cache["mix"],
            length=length)
        new_cache = {"mix": new_cache}
    else:
        y = attn.full_attention(p["attn"], cfg, x, causal=True,
                                window=_window(cfg, kind))
        if cache is not None:
            new_cache = attn.fill_cache(p["attn"], cfg, x, cache,
                                        window=_window(cfg, kind),
                                        length=length)
    h = h + y
    x = norm_apply(p["norm2"], cfg, h)
    if kind == "moe":
        y, aux = moe_mod.moe_apply(p["ffn"], cfg, x)
    else:
        y = mlp_apply(p["ffn"], cfg, x)
    return h + y, aux, new_cache


def _scatter_image(cfg, h, image_embeds, image_mask):
    """Early fusion: replace masked token positions with patch embeddings."""
    idx = jnp.cumsum(image_mask.astype(jnp.int32), axis=1) - 1
    idx = jnp.clip(idx, 0, image_embeds.shape[1] - 1)
    gathered = jnp.take_along_axis(image_embeds.astype(h.dtype),
                                   idx[..., None], axis=1)
    return jnp.where(image_mask[..., None], gathered, h)


def forward(params, cfg, tokens, *, image_embeds=None, image_mask=None,
            return_cache=False, cache=None, remat=False, length=None):
    """tokens (B,S) -> (logits (B,S,V) float32, aux scalar[, cache]).

    ``remat=True`` checkpoints each scanned superblock (recompute in the
    backward pass) — required to fit long-sequence training activations.
    ``length`` (with return_cache) extracts per-row decode state at each
    row's true prefix length — see ``block_apply_seq``.
    """
    pattern, np_, rem = _split(cfg)
    b, s = tokens.shape
    h = embed_apply(params["embed"], cfg, tokens)
    if cfg.family == "vlm" and image_embeds is not None:
        h = _scatter_image(cfg, h, image_embeds, image_mask)
    if return_cache and cache is None:
        cache = init_decode_cache(cfg, b, s)
    aux = jnp.zeros((), jnp.float32)

    new_block_caches = ()
    if np_ > 0:
        def superblock(carry, xs):
            h, aux = carry
            bp = xs[0] if return_cache else xs
            bc = xs[1] if return_cache else (None,) * len(pattern)
            ncs = []
            for pi, kind in enumerate(pattern):
                h, a, nc = block_apply_seq(bp[pi], cfg, kind, h,
                                           cache=bc[pi], length=length)
                aux = aux + a
                ncs.append(nc)
            return (h, aux), (tuple(ncs) if return_cache else None)

        if getattr(cfg, "seq_shard", False):
            from jax.sharding import PartitionSpec as _P
            inner = superblock

            def superblock(carry, xs):      # noqa: F811
                (h, aux), ys = inner(carry, xs)
                h = jax.lax.with_sharding_constraint(
                    h, _P(None, "model", None))
                return (h, aux), ys

        xs = ((tuple(params["blocks"]), tuple(cache["blocks"]))
              if return_cache else tuple(params["blocks"]))
        sb = jax.checkpoint(superblock) if remat else superblock
        (h, aux), ys = scan_or_unroll(sb, (h, aux), xs)
        if return_cache:
            new_block_caches = ys

    new_rem = []
    for i, bp in enumerate(params["rem_blocks"]):
        bc = cache["rem_blocks"][i] if return_cache else None
        h, a, nc = block_apply_seq(bp, cfg, pattern[i], h, cache=bc,
                                   length=length)
        aux = aux + a
        new_rem.append(nc)

    h = norm_apply(params["final_norm"], cfg, h)
    logits = unembed_apply(params["embed"], cfg, h)
    if return_cache:
        return logits, aux, {"blocks": new_block_caches,
                             "rem_blocks": tuple(new_rem)}
    return logits, aux


def prefill(params, cfg, tokens, capacity: int, *, length=None,
            image_embeds=None, image_mask=None):
    """Prompt -> (logits (B,S,V), filled decode cache of ``capacity``).

    ``length`` supports right-padded bucketed prompts (per-row true
    lengths); the cache rows come out exactly as if each row had been
    prefilled unpadded at its own length.
    """
    b, s = tokens.shape
    cache = init_decode_cache(cfg, b, capacity)
    logits, _, cache = forward(params, cfg, tokens,
                               image_embeds=image_embeds,
                               image_mask=image_mask, return_cache=True,
                               cache=cache, length=length)
    return logits, cache


# ---------------------------------------------------------------- decode ----

def _block_cache_init(cfg, kind, batch, capacity, dtype):
    if kind == "rwkv":
        return rwkv_mod.init_rwkv_cache(cfg, batch, dtype)
    if kind == "rec":
        return {"mix": rglru_mod.init_rglru_cache(cfg, batch, dtype)}
    cap = attn.cache_capacity(cfg, capacity, _window(cfg, kind))
    return attn.init_cache(cfg, batch, cap, dtype)


def init_decode_cache(cfg, batch: int, seq_len: int):
    """Stacked per-pattern-position caches (+ remainder layers unstacked)."""
    dt = _dtype(cfg)
    pattern, np_, rem = _split(cfg)

    def stack(kind):
        one = _block_cache_init(cfg, kind, batch, seq_len, dt)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (np_,) + x.shape).copy(), one)

    return {
        "blocks": tuple(stack(kind) for kind in pattern) if np_ > 0 else (),
        "rem_blocks": tuple(
            _block_cache_init(cfg, pattern[i], batch, seq_len, dt)
            for i in range(rem)),
    }


def block_apply_decode(p, cfg, kind, h, cache, pos, table=None):
    """Single-token block.  h (B,1,d).  Returns (h, new_cache).

    ``table`` (B, cap/bs) int32 switches attention caches to block-pool
    layout (shared-prefix serving, docs/serving.md); recurrent kinds have
    constant-size state and ignore it."""
    if kind == "rwkv":
        x = norm_apply(p["norm1"], cfg, h)[:, 0]
        y, (tm_shift, wkv) = rwkv_mod.time_mix_decode(
            p, cfg, x, cache["tm_shift"], cache["wkv"])
        h = h + y[:, None]
        x = norm_apply(p["norm2"], cfg, h)[:, 0]
        y, cm_shift = rwkv_mod.channel_mix_decode(p, cfg, x, cache["cm_shift"])
        h = h + y[:, None]
        return h, {"tm_shift": tm_shift, "wkv": wkv, "cm_shift": cm_shift}

    x = norm_apply(p["norm1"], cfg, h)
    if kind == "rec":
        y, mix_cache = rglru_mod.rglru_decode(p["mix"], cfg, x[:, 0],
                                              cache["mix"])
        h = h + y[:, None]
        new_cache = {"mix": mix_cache}
    else:
        y, new_cache = attn.decode_attention(p["attn"], cfg, x, cache, pos,
                                             window=_window(cfg, kind),
                                             table=table)
        h = h + y
    x = norm_apply(p["norm2"], cfg, h)
    if kind == "moe":
        y, _ = moe_mod.moe_apply(p["ffn"], cfg, x,
                                 capacity_factor=_DECODE_MOE_CF(cfg))
    else:
        y = mlp_apply(p["ffn"], cfg, x)
    return h + y, new_cache


def block_apply_decode_seq(p, cfg, kind, h, cache, pos, commit_len):
    """T-token chunked decode block (speculative verify/commit).

    h (B,T,d).  Outputs match what T sequential ``block_apply_decode``
    steps would produce; the cache advances by each row's first
    ``commit_len[b]`` tokens only.  Split into the commit_len-independent
    forward (``block_decode_seq_pending``) and the commit
    (``block_commit_seq``) so a verify round can compute its accept
    count from the logits and still commit without a second forward.
    """
    h, pending = block_decode_seq_pending(p, cfg, kind, h, cache, pos)
    return h, block_commit_seq(p, cfg, kind, cache, pending, pos, commit_len)


def block_decode_seq_pending(p, cfg, kind, h, cache, pos):
    """The forward half: (h (B,T,d), pending) with the cache UNTOUCHED.

    ``pending`` carries exactly what ``block_commit_seq`` needs to
    commit any per-row prefix afterwards: the write-ready K/V chunk for
    attention kinds (commit is then a pure masked scatter), the normed
    sublayer inputs for recurrent kinds (commit re-runs only the
    length-masked carry, never the output path)."""
    if kind == "rwkv":
        x1 = norm_apply(p["norm1"], cfg, h)
        y, _ = rwkv_mod.time_mix_seq(p, cfg, x1, cache["tm_shift"],
                                     cache["wkv"])
        h = h + y
        x2 = norm_apply(p["norm2"], cfg, h)
        y, _ = rwkv_mod.channel_mix_seq(p, cfg, x2, cache["cm_shift"])
        return h + y, {"x1": x1, "x2": x2}

    x = norm_apply(p["norm1"], cfg, h)
    if kind == "rec":
        y, _ = rglru_mod.rglru_seq(p["mix"], cfg, x, cache["mix"])
        h = h + y
        pending = {"x1": x}
    else:
        y, pending = attn.decode_attention_seq_pending(
            p["attn"], cfg, x, cache, pos, window=_window(cfg, kind))
        h = h + y
    x = norm_apply(p["norm2"], cfg, h)
    if kind == "moe":
        y, _ = moe_mod.moe_apply(p["ffn"], cfg, x,
                                 capacity_factor=_DECODE_MOE_CF(cfg))
    else:
        y = mlp_apply(p["ffn"], cfg, x)
    return h + y, pending


def block_commit_seq(p, cfg, kind, cache, pending, pos, commit_len):
    """The commit half: advance ``cache`` by each row's first
    ``commit_len[b]`` tokens of a ``block_decode_seq_pending`` chunk."""
    b = jax.tree.leaves(cache)[0].shape[0]
    cl = jnp.broadcast_to(jnp.asarray(commit_len, jnp.int32), (b,))

    def committed(new_cache, old_cache):
        # the length-masked carries are exact for commit_len >= 1; at 0
        # the gather-last carries would grab position 0 instead of the
        # pre-chunk state, so keep the old cache wholesale there
        def sel(new, old):
            m = (cl > 0).reshape((b,) + (1,) * (new.ndim - 1))
            return jnp.where(m, new, old)
        return jax.tree.map(sel, new_cache, old_cache)

    if kind == "rwkv":
        _, (tm_shift, wkv) = rwkv_mod.time_mix_seq(
            p, cfg, pending["x1"], cache["tm_shift"], cache["wkv"],
            length=cl)
        _, cm_shift = rwkv_mod.channel_mix_seq(
            p, cfg, pending["x2"], cache["cm_shift"], length=cl)
        return committed(
            {"tm_shift": tm_shift, "wkv": wkv, "cm_shift": cm_shift}, cache)
    if kind == "rec":
        _, mix_cache = rglru_mod.rglru_seq(p["mix"], cfg, pending["x1"],
                                           cache["mix"], length=cl)
        return {"mix": committed(mix_cache, cache["mix"])}
    return attn.commit_attention_seq(cache, pending, pos, cl)


def _DECODE_MOE_CF(cfg) -> float:
    # decode is DROPLESS (capacity_factor=E makes cap = tokens*top_k):
    # capacity dropping depends on which tokens share the dispatch, so a
    # served token's logits would change with its co-scheduled slots and
    # with tick batching — breaking multi-tick / speculative
    # token-identity.  Training keeps the configured (dropping) factor.
    return float(cfg.moe.n_experts)


def decode_seq(params, cfg, cache, tokens, pos, commit_len):
    """Chunked decode: T tokens per row against the current cache, with a
    masked commit.  tokens (B,T) int32 at absolute positions
    ``pos .. pos+T-1`` (pos (B,) int32); commit_len (B,) int32 in [0,T].
    Returns (logits (B,T,V) f32, new_cache advanced by commit_len tokens).
    logits[:, j] match what sequential ``decode_step`` calls would produce
    for token j — this is speculative decoding's verify (commit_len=0)
    and commit (commit_len=accepted) primitive."""
    logits, pending = decode_seq_pending(params, cfg, cache, tokens, pos)
    return logits, decode_seq_commit(params, cfg, cache, pending, pos,
                                     commit_len)


def decode_seq_pending(params, cfg, cache, tokens, pos):
    """The commit_len-independent half of ``decode_seq``: full forward
    against the current cache, cache untouched.  Returns (logits
    (B,T,V) f32, pending) where ``pending`` feeds ``decode_seq_commit``.
    Speculative decoding uses this to verify and commit with ONE target
    forward per round: compute logits, derive the accept count, then
    commit the same pending chunk."""
    pattern, np_, rem = _split(cfg)
    h = embed_apply(params["embed"], cfg, tokens)

    block_pending = ()
    if np_ > 0:
        def superblock(h, xs):
            bp, bc = xs
            ps = []
            for pi, kind in enumerate(pattern):
                h, pd = block_decode_seq_pending(bp[pi], cfg, kind, h,
                                                 bc[pi], pos)
                ps.append(pd)
            return h, tuple(ps)

        h, block_pending = scan_or_unroll(
            superblock, h, (tuple(params["blocks"]), tuple(cache["blocks"])))

    rem_pending = []
    for i, bp in enumerate(params["rem_blocks"]):
        h, pd = block_decode_seq_pending(bp, cfg, pattern[i], h,
                                         cache["rem_blocks"][i], pos)
        rem_pending.append(pd)
    h = norm_apply(params["final_norm"], cfg, h)
    logits = unembed_apply(params["embed"], cfg, h)
    return logits, {"blocks": block_pending, "rem_blocks": tuple(rem_pending)}


def decode_seq_commit(params, cfg, cache, pending, pos, commit_len):
    """Advance ``cache`` by each row's first ``commit_len[b]`` tokens of a
    ``decode_seq_pending`` chunk.  No attention math re-runs — attention
    kinds are a masked ring scatter, recurrent kinds re-run only the
    length-masked carry from the stored sublayer inputs."""
    pattern, np_, rem = _split(cfg)

    new_block_caches = ()
    if np_ > 0:
        def superblock(c, xs):
            bp, bc, bpd = xs
            ncs = []
            for pi, kind in enumerate(pattern):
                ncs.append(block_commit_seq(bp[pi], cfg, kind, bc[pi],
                                            bpd[pi], pos, commit_len))
            return c, tuple(ncs)

        _, new_block_caches = scan_or_unroll(
            superblock, 0, (tuple(params["blocks"]), tuple(cache["blocks"]),
                            tuple(pending["blocks"])))

    new_rem = []
    for i, bp in enumerate(params["rem_blocks"]):
        new_rem.append(block_commit_seq(bp, cfg, pattern[i],
                                        cache["rem_blocks"][i],
                                        pending["rem_blocks"][i], pos,
                                        commit_len))
    return {"blocks": new_block_caches, "rem_blocks": tuple(new_rem)}


def decode_step(params, cfg, cache, tokens, pos, table=None):
    """One decode step.  tokens (B,1) int32; pos scalar int32 (absolute
    position of this token).  Returns (logits (B,1,V) f32, new_cache).
    ``table`` (B, cap/bs) int32: block-pool cache layout (see
    ``block_apply_decode``)."""
    pattern, np_, rem = _split(cfg)
    h = embed_apply(params["embed"], cfg, tokens)

    new_block_caches = ()
    if np_ > 0:
        def superblock(h, xs):
            bp, bc = xs
            ncs = []
            for pi, kind in enumerate(pattern):
                h, nc = block_apply_decode(bp[pi], cfg, kind, h, bc[pi], pos,
                                           table)
                ncs.append(nc)
            return h, tuple(ncs)

        h, new_block_caches = scan_or_unroll(
            superblock, h, (tuple(params["blocks"]), tuple(cache["blocks"])))

    new_rem = []
    for i, bp in enumerate(params["rem_blocks"]):
        h, nc = block_apply_decode(bp, cfg, pattern[i], h,
                                   cache["rem_blocks"][i], pos, table)
        new_rem.append(nc)
    h = norm_apply(params["final_norm"], cfg, h)
    logits = unembed_apply(params["embed"], cfg, h)
    return logits, {"blocks": new_block_caches, "rem_blocks": tuple(new_rem)}
