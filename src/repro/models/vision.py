"""Stub image encoder for VLM serving.

``encode_image(cfg, image)`` turns a raw (H, W, C) image into the
``(n_image_tokens, d_model)`` patch-embedding block the vlm family's
``prefill`` consumes at its masked positions.  The real Phi-3-Vision
encoder is a CLIP ViT; reproducing it is out of scope for this paper, so
this is a DETERMINISTIC stand-in with the right interface:

  * the image is mean-pooled onto a ``g x g`` grid
    (``g = ceil(sqrt(n_image_tokens))``) — spatial structure survives,
  * each cell gets its normalized (row, col) coordinates appended so
    distinct positions stay distinguishable even on flat images,
  * the features are projected to ``d_model`` with a fixed-seed random
    matrix (the same image always maps to the same embeddings, which is
    what the serving parity tests pin against).

Pure numpy on purpose: the encoder runs at request-admission time on the
host, outside any jit — no tracing, no device transfer until the engine
batches the prefill.
"""
from __future__ import annotations

import numpy as np

_PROJ_SEED = 0x51DE


def encode_image(cfg, image) -> np.ndarray:
    """image (H, W, C) or (H, W) float -> (cfg.n_image_tokens, cfg.d_model)
    float32 patch embeddings (deterministic; see module docstring)."""
    img = np.asarray(image, np.float32)
    if img.ndim == 2:
        img = img[..., None]
    if img.ndim != 3:
        raise ValueError(f"expected an (H, W, C) image, got {img.shape}")
    n, d = int(cfg.n_image_tokens), int(cfg.d_model)
    if n < 1:
        raise ValueError(f"{cfg.name}: n_image_tokens={n} — not a vlm config?")
    g = int(np.ceil(np.sqrt(n)))
    h, w, c = img.shape
    ys = np.linspace(0, h, g + 1).astype(int)
    xs = np.linspace(0, w, g + 1).astype(int)
    pooled = np.zeros((g, g, c), np.float32)
    for i in range(g):
        y0, y1 = ys[i], max(ys[i + 1], ys[i] + 1)
        for j in range(g):
            x0, x1 = xs[j], max(xs[j + 1], xs[j] + 1)
            pooled[i, j] = img[min(y0, h - 1):y1, min(x0, w - 1):x1].mean(
                axis=(0, 1))
    feats = pooled.reshape(g * g, c)[:n]
    iy, ix = np.divmod(np.arange(n, dtype=np.float32), g)
    feats = np.concatenate([feats, (iy / g)[:, None], (ix / g)[:, None]],
                           axis=1)
    proj = np.random.default_rng(_PROJ_SEED).standard_normal(
        (feats.shape[1], d)).astype(np.float32)
    return ((feats @ proj) / np.sqrt(feats.shape[1])).astype(np.float32)
