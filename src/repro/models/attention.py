"""Attention: GQA/MQA, causal + sliding-window, KV cache with ring buffer.

Four full-sequence implementations, selected by the config's
``KernelPolicy`` (``cfg.kernels``) or per call via ``impl=``:
  ``xla``      — masked-softmax einsum (materializes S×S scores; small S only)
  ``chunked``  — flash-style online-softmax scan over KV blocks (default for
                 long sequences; bounded memory, pure jnp, differentiable)
  ``qloop``    — static query-chunk loop (near-exact HLO flops; the dry-run's
                 lowering)
  ``flash``    — Pallas TPU kernel (``repro.kernels.flash_attention``),
                 forward AND backward (custom_vjp); interpret-mode on CPU

``impl=None`` resolves through the policy: an explicit per-op selector
wins; ``auto`` picks ``flash`` whenever the policy's backend resolves to
the Pallas path (global ``--kernel-backend pallas``, or ``auto`` on a
host where Pallas compiles) and the call shape supports it, else the
chunked/xla length heuristic.  Unsupported combinations (flash with
cross-attention memory, a query offset, or mismatched q/kv lengths;
``window`` with cross-attention on any impl) raise instead of silently
computing something else.

Decode uses a ring-buffer cache of capacity ``min(seq_len, window)`` so SWA
archs keep an O(window) working set at 512k positions.  Keys are stored
pre-RoPE'd (absolute positions), so wrap-around never invalidates them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import policy_of
from repro.models.layers import apply_rope, dense_init, rope_freqs
from repro.numerics import kv_cache_spec

NEG_INF = -1e30

IMPLS = ("xla", "chunked", "qloop", "flash")


def resolve_impl(cfg, *, sq: int, sk: int, cross: bool = False,
                 q_offset=0, impl: str = None) -> str:
    """Resolve the attention implementation for one call site.

    Precedence: explicit ``impl`` > ``cfg.kernels.attention`` > ``auto``.
    ``auto`` resolves to ``flash`` when the policy wants the Pallas path
    and the shape supports it — the policy/backend can now reach the
    kernel that used to be dead code behind an explicit kwarg.
    """
    pol = policy_of(cfg)
    sel = impl if impl is not None else (pol.attention or "auto")
    static_offset = isinstance(q_offset, int) and q_offset == 0
    flash_ok = not cross and static_offset and sq == sk
    if sel in ("flash", "pallas"):
        # explicit request for an impl that cannot honor the call → raise
        if not flash_ok:
            why = ("cross-attention memory" if cross else
                   "a traced/nonzero q_offset (positions live outside the "
                   "kernel's row indices)" if not static_offset else
                   f"sq={sq} != sk={sk}")
            raise ValueError(f"attention impl 'flash' does not support {why}")
        return "flash"
    if sel == "auto":
        if flash_ok and pol.wants_pallas("attention"):
            return "flash"
        return "chunked" if max(sq, sk) > 2048 else "xla"
    if sel not in IMPLS:
        raise ValueError(f"unknown attention impl {sel!r}; known: "
                         f"{IMPLS + ('auto',)}")
    return sel


def attn_init(rng, cfg, dtype):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 4)
    return {
        "wq": dense_init(ks[0], d, hq * hd, dtype).reshape(d, hq, hd),
        "wk": dense_init(ks[1], d, hkv * hd, dtype).reshape(d, hkv, hd),
        "wv": dense_init(ks[2], d, hkv * hd, dtype).reshape(d, hkv, hd),
        "wo": dense_init(ks[3], hq * hd, d, dtype).reshape(hq, hd, d),
    }


def _qkv(params, cfg, x, xc=None):
    """Project to q (from x) and k,v (from xc or x)."""
    xc = x if xc is None else xc
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k = jnp.einsum("bsd,dhk->bshk", xc, params["wk"].astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bshk", xc, params["wv"].astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return q, k, v


def _out(params, cfg, o):
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(o.dtype),
                      preferred_element_type=jnp.float32).astype(o.dtype)


def _group(q, n_kv):
    b, s, hq, hd = q.shape
    return q.reshape(b, s, n_kv, hq // n_kv, hd)


def _mask(q_pos, k_pos, window, causal: bool):
    """(..., Sq, Sk) boolean validity mask."""
    m = jnp.ones(q_pos.shape[-1:] + k_pos.shape[-1:], bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > q_pos[:, None] - window
    return m


def _sdpa_xla(q, k, v, mask, scale):
    """q (B,S,Hkv,G,hd), k/v (B,Sk,Hkv,hd), mask (Sq,Sk)."""
    s = jnp.einsum("bqhgk,bshk->bhgqs", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhgqs,bshk->bqhgk", p, v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def _sdpa_chunked(q, k, v, q_pos, k_pos, window, causal, scale,
                  q_block: int = 256, kv_block: int = 256):
    """Flash-style online softmax: scan over q blocks (outer) and kv blocks
    (inner carry of (m, l, acc)).  Never materializes S×S."""
    b, sq, h, g, hd = q.shape
    sk = k.shape[1]
    nq = -(-sq // q_block)
    nk = -(-sk // kv_block)
    pq, pk = nq * q_block - sq, nk * kv_block - sk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    qpos = jnp.pad(q_pos, (0, pq), constant_values=-(10 ** 9))
    kpos = jnp.pad(k_pos, (0, pk), constant_values=10 ** 9)
    qb = qp.reshape(b, nq, q_block, h, g, hd)
    kb = kp.reshape(b, nk, kv_block, h, hd)
    vb = vp.reshape(b, nk, kv_block, h, hd)
    qposb = qpos.reshape(nq, q_block)
    kposb = kpos.reshape(nk, kv_block)

    def q_step(_, qi):
        qcur, qpcur = qi                       # (b, qb, h, g, hd), (qb,)
        m0 = jnp.full((b, h, g, q_block), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, q_block, h, g, hd), jnp.float32)

        def kv_step(carry, ki):
            m, den, acc = carry
            kcur, vcur, kpcur = ki
            s = jnp.einsum("bqhgk,bshk->bhgqs", qcur, kcur,
                           preferred_element_type=jnp.float32) * scale
            valid = _mask(qpcur, kpcur, window, causal)
            s = jnp.where(valid, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            den = den * corr + jnp.sum(p, axis=-1)
            acc = acc * corr.transpose(0, 3, 1, 2)[..., None] + jnp.einsum(
                "bhgqs,bshk->bqhgk", p.astype(qcur.dtype), vcur,
                preferred_element_type=jnp.float32)
            return (m_new, den, acc), None

        (m, den, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4), kposb))
        out = acc / jnp.maximum(den, 1e-30).transpose(0, 3, 1, 2)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None,
                           (qb.transpose(1, 0, 2, 3, 4, 5), qposb))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * q_block, h, g, hd)
    return out[:, :sq]


def _sdpa_qloop(q, k, v, window, causal, scale, max_score_bytes=2 ** 28):
    """Static python loop over query chunks; per chunk the KV range is a
    STATIC slice [lo, hi) derived from causality/window — so HLO flops are
    near-exact (no masked-out wasted compute beyond chunk granularity) and
    the score temp is bounded.  Used by the dry-run lowering."""
    b, s, h, g, hd = q.shape
    sk = k.shape[1]
    qc = min(s, max(256, max_score_bytes // max(sk * 4, 1)))
    n_chunks = -(-s // qc)
    outs = []
    for i in range(n_chunks):
        lo_q, hi_q = i * qc, min((i + 1) * qc, s)
        hi_k = hi_q if causal else sk
        lo_k = 0
        if window is not None:
            lo_k = max(0, lo_q - window + 1)
        qch = q[:, lo_q:hi_q]
        kch = k[:, lo_k:hi_k]
        vch = v[:, lo_k:hi_k]
        mask = _mask(jnp.arange(lo_q, hi_q), jnp.arange(lo_k, hi_k),
                     window, causal)
        outs.append(_sdpa_xla(qch, kch, vch, mask, scale))
    return jnp.concatenate(outs, axis=1)


def full_attention(params, cfg, x, *, xc=None, causal=True, rope=True,
                   window=None, impl=None, q_offset=0):
    """Full-sequence attention.  x (B,S,d); xc = cross-attention memory.

    ``impl=None`` resolves through ``cfg.kernels`` (see ``resolve_impl``).
    """
    b, s, _ = x.shape
    if window is not None and xc is not None:
        raise ValueError("sliding-window masks are positional and do not "
                         "apply to cross-attention memory; got window="
                         f"{window} with xc")
    q, k, v = _qkv(params, cfg, x, xc)
    sk = k.shape[1]
    impl = resolve_impl(cfg, sq=s, sk=sk, cross=xc is not None,
                        q_offset=q_offset, impl=impl)
    q_pos = jnp.arange(s) + q_offset
    k_pos = jnp.arange(sk) + (0 if xc is None else 0)
    if rope and xc is None:
        inv = rope_freqs(cfg)
        q = apply_rope(q, q_pos, inv)
        k = apply_rope(k, k_pos, inv)
    qg = _group(q, cfg.n_kv_heads)
    scale = cfg.head_dim ** -0.5
    if impl == "xla":
        mask = _mask(q_pos, k_pos, window, causal)
        o = _sdpa_xla(qg, k, v, mask, scale)
    elif impl == "qloop":
        o = _sdpa_qloop(qg, k, v, window, causal, scale)
    elif impl == "chunked":
        o = _sdpa_chunked(qg, k, v, q_pos, k_pos, window, causal, scale)
    else:                                              # flash (resolved)
        from repro.kernels.flash_attention import ops as flash_ops
        pol = policy_of(cfg)
        o = flash_ops.flash_attention(qg, k, v, causal=causal, window=window,
                                      scale=scale, interpret=pol.interpret,
                                      autotune=pol.autotune)
    o = o.reshape(b, s, cfg.n_heads, cfg.head_dim)
    return _out(params, cfg, o)


# ------------------------------------------------------------- KV cache ----

def cache_capacity(cfg, seq_len: int, window=None) -> int:
    w = window if window is not None else cfg.sliding_window
    return min(seq_len, w) if w is not None else seq_len


def init_cache(cfg, batch: int, capacity: int, dtype):
    """Ring-buffer KV cache.  The storage dtype resolves through the
    config's ``NumericsPolicy`` (``kv_cache_dtype``): ``auto`` stores the
    model dtype; ``bf16`` halves the ring; ``int8`` quantizes per
    (row, slot, head) with fp32 ``k_scale``/``v_scale`` leaves riding
    next to the data — 2x/4x decode slots per byte of cache."""
    store, quant = kv_cache_spec(cfg, dtype)
    shape = (batch, capacity, cfg.n_kv_heads, cfg.head_dim)
    cache = {"k": jnp.zeros(shape, store), "v": jnp.zeros(shape, store)}
    if quant:
        cache["k_scale"] = jnp.zeros(shape[:3], jnp.float32)
        cache["v_scale"] = jnp.zeros(shape[:3], jnp.float32)
    return cache


def _kv_quant(x):
    """Symmetric per-(..., head) int8 quantization over the hd axis:
    x (..., hd) float -> (int8 values, fp32 scale (...)).  amax is clamped
    so all-zero slots (the unwritten ring tail) get scale eps, not 0."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1), 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def _kv_dequant(q, scale):
    return q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)


def cross_decode(params, cfg, x, cross_cache):
    """Cross-attention for one decode token.  x (B,1,d); cache K/V
    (B,T_enc,Hkv,hd) precomputed from the encoder memory.  No RoPE, no mask."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    qg = _group(q, cfg.n_kv_heads)
    k, v = cross_cache["k"], cross_cache["v"]
    s = jnp.einsum("bqhgk,bshk->bhgqs", qg, k.astype(x.dtype),
                   preferred_element_type=jnp.float32) * cfg.head_dim ** -0.5
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(x.dtype)
    o = jnp.einsum("bhgqs,bshk->bqhgk", p, v.astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    o = o.reshape(x.shape[0], 1, cfg.n_heads, cfg.head_dim)
    return _out(params, cfg, o)


def fill_cache(params, cfg, x, cache, *, window=None, rope=True,
               length=None):
    """Fill a ring-buffer cache from a full prefix x (B,S,d).

    Writes the last ``cap`` positions' K/V into their ring slots
    (slot = position % cap), matching what S decode_attention steps would
    have produced.

    ``length`` (scalar or (B,) int32) marks per-row true prefix lengths
    for right-padded prompts: row b behaves as if only its first
    ``length[b]`` positions existed — padded positions never reach the
    ring, so a bucketed prefill is exactly a shorter prefill.
    """
    b, s, _ = x.shape
    cap = cache["k"].shape[1]
    dt = cache["k"].dtype
    _, k, v = _qkv(params, cfg, x)
    if rope:
        inv = rope_freqs(cfg)
        k = apply_rope(k, jnp.arange(s), inv)
    take = min(cap, s)
    quant = "k_scale" in cache
    if length is None:
        positions = jnp.arange(s - take, s)
        slots = positions % cap
        kw, vw = k[:, s - take:], v[:, s - take:]
        if quant:
            kw, ks = _kv_quant(kw)
            vw, vs = _kv_quant(vw)
        out = {"k": cache["k"].at[:, slots].set(kw.astype(dt)),
               "v": cache["v"].at[:, slots].set(vw.astype(dt))}
        if quant:
            out["k_scale"] = cache["k_scale"].at[:, slots].set(ks)
            out["v_scale"] = cache["v_scale"].at[:, slots].set(vs)
        return out
    # per-row: the last `take` positions RELATIVE to each row's length.
    # `take` consecutive ints stay distinct mod cap, so the row scatter
    # never collides; positions < 0 write their slot's previous value
    # back (a no-op), keeping padded rows' rings untouched.
    ln = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (b,))
    positions = ln[:, None] - take + jnp.arange(take)[None, :]   # (B, take)
    valid = positions >= 0
    pclip = jnp.clip(positions, 0, s - 1)
    rows = jnp.arange(b)[:, None]
    slots = jnp.mod(positions, cap)
    kw, vw = k[rows, pclip], v[rows, pclip]
    if quant:
        kw, ks = _kv_quant(kw)
        vw, vs = _kv_quant(vw)
    k_g = jnp.where(valid[..., None, None], kw.astype(dt),
                    cache["k"][rows, slots])
    v_g = jnp.where(valid[..., None, None], vw.astype(dt),
                    cache["v"][rows, slots])
    out = {"k": cache["k"].at[rows, slots].set(k_g),
           "v": cache["v"].at[rows, slots].set(v_g)}
    if quant:
        out["k_scale"] = cache["k_scale"].at[rows, slots].set(
            jnp.where(valid[..., None], ks, cache["k_scale"][rows, slots]))
        out["v_scale"] = cache["v_scale"].at[rows, slots].set(
            jnp.where(valid[..., None], vs, cache["v_scale"][rows, slots]))
    return out


def decode_attention_seq(params, cfg, x, cache, pos, commit_len, *,
                         window=None, rope=True):
    """Chunked decode: T candidate tokens per row against an UNMUTATED
    ring cache, with a masked commit.

    x (B,T,d) holds tokens at absolute positions ``pos .. pos+T-1`` (pos
    (B,) int32 = tokens each row has consumed, so the ring holds
    positions <= pos-1).  Token j attends over the ring entries a
    sequential ``decode_attention`` at step j would still see (written,
    not yet overwritten by steps <= j, inside the window) PLUS the
    in-flight tokens 0..j — exactly what T sequential steps compute,
    without mutating the ring.  The write happens once at the end, only
    for each row's first ``commit_len[b]`` tokens (0 <= commit_len <= T,
    traced per row).

    This is speculative decoding's verify/commit primitive: the forward
    (``decode_attention_seq_pending``) is commit_len-independent, and
    the commit (``commit_attention_seq``) is a pure masked scatter of
    the write-ready K/V chunk the forward already computed — so a
    verify-then-commit round costs ONE attention forward, not two
    (docs/serving.md).  Rejected tokens never touch the ring, so there
    is nothing to roll back.

    Returns (out (B,T,d), new_cache committed through commit_len).
    """
    out, pending = decode_attention_seq_pending(params, cfg, x, cache, pos,
                                                window=window, rope=rope)
    return out, commit_attention_seq(cache, pending, pos, commit_len)


def decode_attention_seq_pending(params, cfg, x, cache, pos, *,
                                 window=None, rope=True):
    """The commit_len-independent forward half of ``decode_attention_seq``:
    returns (out (B,T,d), pending) where ``pending`` holds the
    write-ready (storage-dtype, quantized if the cache is) K/V chunk —
    everything ``commit_attention_seq`` needs to commit any prefix
    without re-running the attention math."""
    b, t, _ = x.shape
    cap = cache["k"].shape[1]
    if t > cap:
        raise ValueError(f"decode_seq over {t} tokens needs ring capacity "
                         f">= {t} (distinct slots mod cap); got {cap}")
    q, k_new, v_new = _qkv(params, cfg, x)
    pv = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    positions = pv[:, None] + jnp.arange(t)[None, :]          # (B, T)
    if rope:
        inv = rope_freqs(cfg)
        q = apply_rope(q, positions, inv)
        k_new = apply_rope(k_new, positions, inv)
    quant = "k_scale" in cache
    kw, vw = k_new, v_new
    if quant:
        kw, ks = _kv_quant(k_new)                 # int8 + (B,T,Hkv) scales
        vw, vs = _kv_quant(v_new)
        # sequential decode reads back what it wrote: use the dequantized
        # (lossy) in-flight K/V so verify == T plain ticks under int8 too
        k_new = _kv_dequant(kw, ks).astype(x.dtype)
        v_new = _kv_dequant(vw, vs).astype(x.dtype)

    # ring scores (read-only): slot i holds position
    # (pos-1) - ((pos-1 - i) mod cap); visible to query j iff it exists,
    # a sequential step <= j would not yet have overwritten it
    # (slot_pos > p_j - cap), and it is inside the window
    base = pv - 1
    idx = jnp.arange(cap)
    slot_pos = base[:, None] - jnp.mod(base[:, None] - idx[None, :], cap)
    valid_r = (slot_pos[:, None, :] >= 0) & \
        (slot_pos[:, None, :] > positions[:, :, None] - cap)  # (B,T,cap)
    if window is not None:
        valid_r &= slot_pos[:, None, :] > positions[:, :, None] - window
    ka, va = cache["k"], cache["v"]
    if quant:
        ka = _kv_dequant(ka, cache["k_scale"])
        va = _kv_dequant(va, cache["v_scale"])
    qg = _group(q, cfg.n_kv_heads)                # (B,T,Hkv,G,hd)
    scale = cfg.head_dim ** -0.5
    s_r = jnp.einsum("bqhgk,bshk->bhgqs", qg, ka,
                     preferred_element_type=jnp.float32) * scale
    s_r = jnp.where(valid_r[:, None, None], s_r, NEG_INF)

    # in-flight scores: causal over the T candidates themselves
    j = jnp.arange(t)
    valid_f = (j[None, :] <= j[:, None]) & ((j[:, None] - j[None, :]) < cap)
    if window is not None:
        valid_f &= (j[:, None] - j[None, :]) < window
    s_f = jnp.einsum("bqhgk,bshk->bhgqs", qg, k_new,
                     preferred_element_type=jnp.float32) * scale
    s_f = jnp.where(valid_f[None, None, None], s_f, NEG_INF)

    s = jnp.concatenate([s_r, s_f], axis=-1).astype(jnp.float32)
    p = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    va_all = jnp.concatenate([va.astype(jnp.float32),
                              v_new.astype(jnp.float32)], axis=1)
    o = jnp.einsum("bhgqs,bshk->bqhgk", p, va_all,
                   preferred_element_type=jnp.float32).astype(x.dtype)

    pending = {"k": kw, "v": vw}
    if quant:
        pending["k_scale"], pending["v_scale"] = ks, vs
    o = o.reshape(b, t, cfg.n_heads, cfg.head_dim)
    return _out(params, cfg, o), pending


def commit_attention_seq(cache, pending, pos, commit_len):
    """Masked commit of a ``decode_attention_seq_pending`` chunk
    (fill_cache's where-set pattern): T consecutive positions stay
    distinct mod cap, so the row scatter never collides; tokens past
    commit_len write their slot's previous value back.  No attention
    math runs here — this is the whole point of the pending split."""
    b, t = pending["k"].shape[:2]
    cap = cache["k"].shape[1]
    dt = cache["k"].dtype
    pv = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    cl = jnp.broadcast_to(jnp.asarray(commit_len, jnp.int32), (b,))
    positions = pv[:, None] + jnp.arange(t)[None, :]          # (B, T)
    rows = jnp.arange(b)[:, None]
    slots = jnp.mod(positions, cap)
    wvalid = jnp.arange(t)[None, :] < cl[:, None]             # (B, T)
    k_g = jnp.where(wvalid[..., None, None], pending["k"].astype(dt),
                    cache["k"][rows, slots])
    v_g = jnp.where(wvalid[..., None, None], pending["v"].astype(dt),
                    cache["v"][rows, slots])
    new_cache = {"k": cache["k"].at[rows, slots].set(k_g),
                 "v": cache["v"].at[rows, slots].set(v_g)}
    if "k_scale" in cache:
        new_cache["k_scale"] = cache["k_scale"].at[rows, slots].set(
            jnp.where(wvalid[..., None], pending["k_scale"],
                      cache["k_scale"][rows, slots]))
        new_cache["v_scale"] = cache["v_scale"].at[rows, slots].set(
            jnp.where(wvalid[..., None], pending["v_scale"],
                      cache["v_scale"][rows, slots]))
    return new_cache


def resolve_decode_impl(cfg) -> str:
    """``pallas`` (flash-decode kernel) or ``xla`` from the KernelPolicy."""
    pol = policy_of(cfg)
    sel = pol.decode_attention or pol.backend
    if sel == "auto":
        from repro.kernels.common import resolve_interpret
        sel = "pallas" if not resolve_interpret(pol.interpret) else "xla"
    if sel not in ("xla", "pallas"):
        raise ValueError(f"unknown decode_attention impl {sel!r}")
    return sel


def decode_attention(params, cfg, x, cache, pos, *, window=None, rope=True,
                     impl=None, table=None):
    """One-token decode.  x (B,1,d); cache {k,v} (B,W,Hkv,hd); pos is the
    token's absolute position — a scalar, or (B,) int32 for rows decoding
    at different depths (the continuous-batching engine's layout).

    Writes each row's new K/V at slot ``pos % W`` (ring buffer), attends
    over valid slots — through the policy-selected backend: the Pallas
    flash-decode kernel (``kernels.decode_attention``) or the XLA einsum.
    Returns (out (B,1,d), new_cache).

    ``table`` (B, cap/bs) int32 switches the cache to BLOCK-POOL layout
    (docs/serving.md): leaves are (n_blocks, bs, Hkv, hd) pools shared
    across rows, and row b's logical ring slot ``s`` lives at
    ``pool[table[b, s // bs], s % bs]``.  The ring arithmetic (slot = pos
    % cap, validity masks) is unchanged — the table only indirects the
    storage, which is what lets requests with a shared prefix point at
    the same physical blocks.
    """
    b = x.shape[0]
    q, k_new, v_new = _qkv(params, cfg, x)
    if table is None:
        cap = cache["k"].shape[1]
    else:
        bs = cache["k"].shape[1]
        cap = table.shape[1] * bs
    pv = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    if rope:
        inv = rope_freqs(cfg)
        q = apply_rope(q, pv[:, None], inv)
        k_new = apply_rope(k_new, pv[:, None], inv)
    slot = pv % cap
    rows = jnp.arange(b)
    quant = "k_scale" in cache
    kw, vw = k_new[:, 0], v_new[:, 0]
    if quant:
        kw, ks = _kv_quant(kw)                        # scale (B, Hkv)
        vw, vs = _kv_quant(vw)
    if table is None:
        wr, ws = rows, slot
    else:
        # pool write target: block id from the row's table, offset in block.
        # Distinct rows write distinct blocks (the engine never shares a
        # WRITABLE block; retired rows all point at the reserved trash
        # block, where colliding garbage writes are harmless by design).
        wr = jnp.take_along_axis(table, (slot // bs)[:, None], axis=1)[:, 0]
        ws = slot % bs
    k = cache["k"].at[wr, ws].set(kw.astype(cache["k"].dtype))
    v = cache["v"].at[wr, ws].set(vw.astype(cache["v"].dtype))
    new_cache = {"k": k, "v": v}
    if quant:
        new_cache["k_scale"] = cache["k_scale"].at[wr, ws].set(ks)
        new_cache["v_scale"] = cache["v_scale"].at[wr, ws].set(vs)
    qg = _group(q, cfg.n_kv_heads)                    # (B,1,Hkv,G,hd)
    scale = cfg.head_dim ** -0.5
    impl = resolve_decode_impl(cfg) if impl is None else impl
    if impl == "pallas":
        from repro.kernels.decode_attention import ops as da_ops
        pol = policy_of(cfg)
        o = da_ops.decode_attention(
            qg[:, 0], k, v, pv, window=window, scale=scale,
            interpret=pol.interpret, autotune=pol.autotune,
            k_scale=new_cache.get("k_scale"),
            v_scale=new_cache.get("v_scale"), table=table)
        o = o.astype(x.dtype)[:, None]                # (B,1,Hkv,G,hd)
    else:
        if table is None:
            ka, va = k, v
            ksc = new_cache.get("k_scale")
            vsc = new_cache.get("v_scale")
        else:
            # dereference the pool: (B, cap/bs, bs, ...) -> (B, cap, ...)
            ka = k[table].reshape((b, cap) + k.shape[2:])
            va = v[table].reshape((b, cap) + v.shape[2:])
            ksc = vsc = None
            if quant:
                ksc = new_cache["k_scale"][table].reshape(b, cap, -1)
                vsc = new_cache["v_scale"][table].reshape(b, cap, -1)
        # slot i holds absolute position pos - ((pos - i) mod W); valid
        # iff >= 0 (and inside the window when one is set)
        idx = jnp.arange(cap)
        slot_pos = pv[:, None] - jnp.mod(pv[:, None] - idx[None, :], cap)
        valid = slot_pos >= 0
        if window is not None and window < cap:
            valid &= slot_pos > pv[:, None] - window
        if quant:
            ka = _kv_dequant(ka, ksc)
            va = _kv_dequant(va, vsc)
        s = jnp.einsum("bqhgk,bshk->bhgqs", qg, ka,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(x.dtype)
        o = jnp.einsum("bhgqs,bshk->bqhgk", p, va,
                       preferred_element_type=jnp.float32).astype(x.dtype)
    o = o.reshape(b, 1, cfg.n_heads, cfg.head_dim)
    return _out(params, cfg, o), new_cache
