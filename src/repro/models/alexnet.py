"""AlexNet in JAX — the paper's own architecture.

Faithful to Krizhevsky et al. 2012 / the Theano implementation: 5 conv
layers (LRN after conv1/2, 3x3 stride-2 max-pool after conv1/2/5), two
4096-d fully-connected layers with dropout 0.5, softmax over 1000 classes.
With ``cfg.faithful`` the net is the paper's dual-GPU topology: conv2/4/5
are 2-group convolutions (``ConvSpec.groups`` — the intra-layer
model-parallel split each GPU held one half of) and LRN runs *after*
pool1/pool2, the Caffe reference ordering.  Legacy configs
(``faithful=False``) keep the PR-2 ordering (LRN before pool, no groups)
so their numerics never move.

The convolution backend is pluggable, mirroring the paper's cuda-convnet vs
cuDNN comparison (§2, Table 1):
  ``xla``               lax.conv_general_dilated (the library backend)
  ``pallas``            fused implicit-GEMM Pallas kernel — patch gather
                        inside the kernel, bias+ReLU epilogue fused, no
                        im2col tensor in HBM; grouped convs walk
                        block-diagonal N-tiles (docs/kernels.md)
  ``pallas_im2col_ref`` two-stage XLA im2col + Pallas GEMM (block-diagonal
                        weight embedding when grouped), kept for parity
                        testing the fused kernel
``interpret=None`` auto-resolves per backend (kernels/conv2d/tune.py);
block sizes come from the autotune cache.  Layout is NHWC (TPU-native)
rather than the paper's cuda-convnet C01B.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import policy_of, resolve_interpret
from repro.kernels.lrn import ops as lrn_ops
from repro.models.layers import softmax_xent


def resolve_conv_backend(cfg) -> str:
    """Conv backend from the config's KernelPolicy: explicit selector wins
    (``pallas_im2col_ref`` included); ``auto`` compiles the fused Pallas
    kernel where it can and keeps lax.conv elsewhere."""
    pol = policy_of(cfg)
    sel = pol.conv2d or pol.backend
    if sel == "auto":
        return "pallas" if not resolve_interpret(pol.interpret) else "xla"
    return sel


def conv2d(x, w, b, stride: int, padding: int, backend: str = "xla", *,
           relu: bool = False, groups: int = 1, interpret: bool = None,
           autotune: bool = None):
    """x (B,H,W,C_in), w (K,K,C_in/G,C_out).  The pallas backends fuse the
    bias add (+ optional ReLU) into the kernel epilogue; ``groups`` > 1
    runs the paper's intra-layer split on every backend."""
    if backend == "pallas":
        from repro.kernels.conv2d import ops as conv_ops
        return conv_ops.conv2d_fused(x, w, stride=stride, padding=padding,
                                     bias=b, relu=relu, groups=groups,
                                     interpret=interpret, autotune=autotune)
    if backend in ("pallas_im2col_ref", "pallas_im2col"):
        from repro.kernels.conv2d import ops as conv_ops
        return conv_ops.conv2d_im2col(x, w, stride=stride, padding=padding,
                                      bias=b, relu=relu, groups=groups,
                                      interpret=interpret, autotune=autotune)
    if backend == "xla":
        # fp32 accumulation via operand upcast, not preferred_element_type:
        # this jax's conv TRANSPOSE rejects mixed (bf16, f32-cotangent)
        # operands, while the casts' own vjps convert cotangents cleanly.
        # For fp32 inputs the casts are no-ops — bit-equal to the old form.
        y = jax.lax.conv_general_dilated(
            x.astype(jnp.float32), w.astype(jnp.float32),
            window_strides=(stride, stride),
            padding=[(padding, padding), (padding, padding)],
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=groups).astype(x.dtype)
        y = y + b.astype(y.dtype)
        return jax.nn.relu(y) if relu else y
    raise ValueError(f"unknown conv backend {backend!r}")


def resolve_lrn_backend(cfg) -> str:
    pol = policy_of(cfg)
    return "pallas" if pol.wants_pallas("lrn") else "xla"


def lrn(x, n: int = 5, alpha: float = 1e-4, beta: float = 0.75,
        k: float = 2.0, backend: str = "xla", interpret: bool = None):
    """Local response normalization across channels (AlexNet §3.3).
    Dispatches to ``kernels.lrn`` (XLA oracle or Pallas tile kernel)."""
    return lrn_ops.lrn(x, n=n, alpha=alpha, beta=beta, k=k, backend=backend,
                       interpret=interpret)


def maxpool(x, size: int = 3, stride: int = 2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, size, size, 1), (1, stride, stride, 1),
        "VALID")


def init(rng, cfg):
    from repro.numerics import param_dtype
    dt = param_dtype(cfg)
    params = {"convs": [], "fcs": []}
    c_in, hw = cfg.in_channels, cfg.image_size
    for i, cs in enumerate(cfg.convs):
        k = jax.random.fold_in(rng, i)
        # He init (the paper's 0.01 works at 227x224 ImageNet scale but
        # vanishes through the reduced net's 5 conv layers); a grouped
        # conv's receptive field only spans its group's channels
        fan_in = cs.kernel * cs.kernel * (c_in // cs.groups)
        w = jax.random.normal(
            k, (cs.kernel, cs.kernel, c_in // cs.groups, cs.out_channels),
            jnp.float32) * (2.0 / fan_in) ** 0.5
        params["convs"].append({"w": w.astype(dt),
                                "b": jnp.zeros((cs.out_channels,), dt)})
        hw = (hw + 2 * cs.padding - cs.kernel) // cs.stride + 1
        if cs.pool:
            hw = (hw - 3) // 2 + 1
        c_in = cs.out_channels
    flat = hw * hw * c_in
    dims = [(flat, cfg.fc_dim), (cfg.fc_dim, cfg.fc_dim),
            (cfg.fc_dim, cfg.n_classes)]
    for i, (di, do) in enumerate(dims):
        k = jax.random.fold_in(rng, 100 + i)
        params["fcs"].append({
            "w": (jax.random.normal(k, (di, do), jnp.float32) * di ** -0.5).astype(dt),
            "b": jnp.zeros((do,), dt)})
    return params


def forward(params, cfg, images, *, train: bool = False, dropout_rng=None,
            conv_backend: str = None, conv_interpret: bool = None):
    """images (B,H,W,C) -> logits (B, n_classes) float32.

    ``conv_backend=None`` resolves through ``cfg.kernels`` (KernelPolicy);
    an explicit argument wins (parity tests force specific backends)."""
    if conv_backend is None:
        conv_backend = resolve_conv_backend(cfg)
    if conv_interpret is None:
        conv_interpret = policy_of(cfg).interpret
    lrn_backend = resolve_lrn_backend(cfg)
    faithful = getattr(cfg, "faithful", False)

    def _lrn(h):
        return lrn(h, n=getattr(cfg, "lrn_n", 5),
                   alpha=getattr(cfg, "lrn_alpha", 1e-4),
                   beta=getattr(cfg, "lrn_beta", 0.75),
                   k=getattr(cfg, "lrn_k", 2.0),
                   backend=lrn_backend, interpret=conv_interpret)

    h = images
    for cp, cs in zip(params["convs"], cfg.convs):
        h = conv2d(h, cp["w"], cp["b"], cs.stride, cs.padding, conv_backend,
                   relu=True, groups=cs.groups, interpret=conv_interpret,
                   autotune=policy_of(cfg).autotune)
        # faithful ordering is the Caffe reference net's: pool THEN norm
        # (normalizing the pooled map); legacy nets normalized first
        if not faithful and cs.lrn:
            h = _lrn(h)
        if cs.pool:
            h = maxpool(h)
        if faithful and cs.lrn:
            h = _lrn(h)
    h = h.reshape(h.shape[0], -1)
    for i, fp in enumerate(params["fcs"]):
        if i > 0:
            h = jax.nn.relu(h)
            if train and cfg.dropout > 0:
                dropout_rng = jax.random.fold_in(dropout_rng, i)
                keep = jax.random.bernoulli(dropout_rng, 1 - cfg.dropout,
                                            h.shape)
                h = jnp.where(keep, h / (1 - cfg.dropout), 0.0)
        h = (jnp.matmul(h, fp["w"].astype(h.dtype),
                        preferred_element_type=jnp.float32).astype(h.dtype)
             + fp["b"].astype(h.dtype))
    return h.astype(jnp.float32)


def loss_fn(params, cfg, images, labels, *, train=False, dropout_rng=None,
            conv_backend=None, conv_interpret=None):
    logits = forward(params, cfg, images, train=train,
                     dropout_rng=dropout_rng, conv_backend=conv_backend,
                     conv_interpret=conv_interpret)
    return softmax_xent(logits[:, None, :], labels[:, None])
