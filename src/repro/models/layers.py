"""Shared layer primitives: norms, MLPs, embeddings, RoPE.

Functional style throughout: ``*_init(rng, ...) -> params`` and pure apply
functions.  Params are plain dicts (pytrees); layer stacks carry a leading
scan axis added by the model modules.  Matmuls accumulate in float32
(``preferred_element_type``) and cast back to the param dtype, mirroring MXU
behaviour; norms and softmax run in float32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(rng, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(rng, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def matmul(x, w):
    y = jnp.matmul(x, w.astype(x.dtype), preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------- norms ----

def norm_init(cfg, dtype):
    if cfg.norm == "np_ln":        # non-parametric (olmo): no learnables
        return {}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,), dtype),
                "bias": jnp.zeros((cfg.d_model,), dtype)}
    return {"scale": jnp.ones((cfg.d_model,), dtype)}          # rmsnorm


def norm_apply(params, cfg, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm in ("layernorm", "np_ln"):
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        if cfg.norm == "layernorm":
            y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:                                                      # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * params["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ----------------------------------------------------------------- MLPs ----

def mlp_init(rng, cfg, dtype, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    if cfg.mlp in ("swiglu", "geglu"):
        return {"w_in": dense_init(ks[0], d, f, dtype),
                "w_gate": dense_init(ks[1], d, f, dtype),
                "w_out": dense_init(ks[2], f, d, dtype)}
    return {"w_in": dense_init(ks[0], d, f, dtype),
            "w_out": dense_init(ks[2], f, d, dtype)}


def mlp_apply(params, cfg, x):
    h = matmul(x, params["w_in"])
    if cfg.mlp == "swiglu":
        h = h * jax.nn.silu(matmul(x, params["w_gate"]))
    elif cfg.mlp == "geglu":
        h = h * jax.nn.gelu(matmul(x, params["w_gate"]))
    else:
        h = jax.nn.gelu(h)
    return matmul(h, params["w_out"])


# ----------------------------------------------------------- embeddings ----

def embed_init(rng, cfg, dtype):
    v = getattr(cfg, "padded_vocab", cfg.vocab_size)
    p = {"tok": (jax.random.normal(rng, (v, cfg.d_model),
                                   jnp.float32) * 0.02).astype(dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(jax.random.fold_in(rng, 1),
                                  cfg.d_model, v, dtype)
    return p


def embed_apply(params, cfg, tokens):
    return jnp.take(params["tok"], tokens, axis=0)


def unembed_apply(params, cfg, h):
    if cfg.tie_embeddings:
        w = params["tok"].astype(h.dtype)          # (V, d)
        logits = jnp.matmul(h, w.T, preferred_element_type=jnp.float32)
    else:
        logits = jnp.matmul(h, params["lm_head"].astype(h.dtype),
                            preferred_element_type=jnp.float32)
    if getattr(cfg, "padded_vocab", cfg.vocab_size) != cfg.vocab_size:
        logits = logits[..., :cfg.vocab_size]      # mask padded rows
    return logits  # float32


# ----------------------------------------------------------------- RoPE ----

def rope_freqs(cfg, head_dim: int | None = None):
    hd = head_dim or cfg.head_dim
    exponent = jnp.arange(0, hd, 2, dtype=jnp.float32) / hd
    return 1.0 / (cfg.rope_theta ** exponent)      # (hd/2,)


def apply_rope(x, positions, inv_freq):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- loss ----

def softmax_xent(logits, labels, mask=None):
    """Mean cross-entropy; logits float32 (B, S, V), labels int (B, S)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
