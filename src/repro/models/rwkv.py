"""RWKV6 (Finch) block: data-dependent token-shift time-mix + channel-mix.

Faithful to arXiv:2404.05892 §3: ddlerp token shift with a shared low-rank
adapter producing per-projection interpolation weights; data-dependent decay
``w_t = exp(-exp(z_t))`` through its own low-rank adapter; per-head bonus
``u``; GroupNorm over heads on the WKV output.  The recurrence itself lives
in ``repro.kernels.rwkv6``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import policy_of, resolve_interpret
from repro.kernels.rwkv6 import ops as wkv_ops
from repro.models.layers import dense_init, matmul

DDLERP_RANK = 32
DECAY_RANK = 64


def resolve_wkv_impl(cfg, *, has_state: bool = False) -> str:
    """WKV impl from the config's KernelPolicy.

    ``auto`` takes the Pallas kernel whenever it would compile; the
    Pallas path starts from a zero state, so prefill-from-cache falls
    back to the (equivalent) chunked XLA form.
    """
    pol = policy_of(cfg)
    sel = pol.rwkv6 or pol.backend
    if sel == "auto":
        sel = "pallas" if not resolve_interpret(pol.interpret) else "chunked"
    elif sel == "xla":
        sel = "chunked"
    if sel == "pallas" and has_state:
        return "chunked"
    if sel not in ("sequential", "chunked", "pallas"):
        raise ValueError(f"unknown wkv impl {sel!r}")
    return sel


def rwkv_block_init(rng, cfg, dtype):
    d, h, hd, f = cfg.d_model, cfg.n_heads, cfg.head_dim, cfg.d_ff
    ks = jax.random.split(rng, 16)
    p = {
        # time-mix
        "tm": {
            "mu_base": jnp.zeros((d,), dtype),
            "mu_wkvrg": jnp.zeros((5, d), dtype),
            "lora_a": dense_init(ks[0], d, 5 * DDLERP_RANK, dtype, scale=0.01),
            "lora_b": (jax.random.normal(ks[1], (5, DDLERP_RANK, d), jnp.float32)
                       * 0.01).astype(dtype),
            "wr": dense_init(ks[2], d, d, dtype),
            "wk": dense_init(ks[3], d, d, dtype),
            "wv": dense_init(ks[4], d, d, dtype),
            "wg": dense_init(ks[5], d, d, dtype),
            "wo": dense_init(ks[6], d, d, dtype),
            "decay_base": jnp.full((d,), -4.0, dtype),   # w ≈ exp(-e^-4) ≈ .982
            "decay_a": dense_init(ks[7], d, DECAY_RANK, dtype, scale=0.01),
            "decay_b": dense_init(ks[8], DECAY_RANK, d, dtype, scale=0.01),
            "u": (jax.random.normal(ks[9], (h, hd), jnp.float32) * 0.5).astype(dtype),
            "ln_x_scale": jnp.ones((d,), dtype),
        },
        # channel-mix
        "cm": {
            "mu_k": jnp.zeros((d,), dtype),
            "mu_r": jnp.zeros((d,), dtype),
            "wk": dense_init(ks[10], d, f, dtype),
            "wv": dense_init(ks[11], f, d, dtype),
            "wr": dense_init(ks[12], d, d, dtype),
        },
    }
    return p


def _ddlerp(tm, x, x_prev):
    """Data-dependent lerp producing the 5 shifted inputs (w,k,v,r,g)."""
    xx = x_prev - x
    xxx = x + xx * tm["mu_base"].astype(x.dtype)
    lo = jnp.tanh(matmul(xxx, tm["lora_a"]))                     # (..., 5R)
    lo = lo.reshape(lo.shape[:-1] + (5, DDLERP_RANK))
    delta = jnp.einsum("...nr,nrd->...nd", lo, tm["lora_b"].astype(x.dtype),
                       preferred_element_type=jnp.float32).astype(x.dtype)
    mu = tm["mu_wkvrg"].astype(x.dtype) + delta                  # (..., 5, d)
    return x[..., None, :] + xx[..., None, :] * mu               # (..., 5, d)


def _decay(tm, xw):
    z = tm["decay_base"].astype(jnp.float32) + matmul(
        jnp.tanh(matmul(xw, tm["decay_a"])), tm["decay_b"]).astype(jnp.float32)
    return jnp.exp(-jnp.exp(jnp.minimum(z, 8.0)))                # (0,1)


def _groupnorm_heads(x, scale, h, eps=64e-5):
    """GroupNorm with one group per head over the flattened (H*hd) output."""
    b_shape = x.shape[:-1]
    xh = x.reshape(b_shape + (h, -1)).astype(jnp.float32)
    mu = jnp.mean(xh, axis=-1, keepdims=True)
    var = jnp.var(xh, axis=-1, keepdims=True)
    y = (xh - mu) * jax.lax.rsqrt(var + eps)
    y = y.reshape(b_shape + (-1,)) * scale.astype(jnp.float32)
    return y.astype(x.dtype)


def _length_mask(length, b, s):
    """(B, S) bool: position t is a real (non-padded) token of row b."""
    ln = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (b,))
    return jnp.arange(s)[None, :] < ln[:, None], ln


def _gather_last(x, ln):
    """x (B,S,...) -> x[b, ln[b]-1] per row (the last REAL position)."""
    idx = jnp.clip(ln - 1, 0, x.shape[1] - 1)
    return x[jnp.arange(x.shape[0]), idx]


def time_mix_seq(p, cfg, x, shift_state=None, wkv_state=None, length=None):
    """x (B,S,d).  Returns (out, (last_x, final_wkv_state)).

    The WKV impl is selected by ``cfg.kernels`` (the old ``impl=`` kwarg
    threading is gone — see docs/kernels.md for the migration note).

    ``length`` (scalar or (B,) int32; right-padded prefill): padded
    positions are frozen out of the recurrence by forcing decay w=1 and
    k=0 there — the WKV state update ``S <- diag(w) S + kᵀv`` becomes the
    identity, so ``s_fin`` is each row's state after exactly ``length[b]``
    real tokens; ``last_x`` gathers the last real position.  Outputs at
    real positions are untouched (they only see the past).
    """
    impl = resolve_wkv_impl(cfg, has_state=wkv_state is not None)
    tm = p["tm"]
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    prev = jnp.zeros((b, 1, d), x.dtype) if shift_state is None else shift_state[:, None]
    x_prev = jnp.concatenate([prev, x[:, :-1]], axis=1)
    xs = _ddlerp(tm, x, x_prev)                                   # (B,S,5,d)
    xw, xk, xv, xr, xg = (xs[:, :, i] for i in range(5))
    w = _decay(tm, xw).reshape(b, s, h, hd)
    r = matmul(xr, tm["wr"]).reshape(b, s, h, hd)
    k = matmul(xk, tm["wk"]).reshape(b, s, h, hd)
    v = matmul(xv, tm["wv"]).reshape(b, s, h, hd)
    g = jax.nn.silu(matmul(xg, tm["wg"]))
    if length is not None:
        real, ln = _length_mask(length, b, s)
        m = real[..., None, None]
        w = jnp.where(m, w, 1.0)
        k = jnp.where(m, k, 0.0)
    pol = policy_of(cfg)
    y, s_fin = wkv_ops.wkv(r, k, v, w, tm["u"].astype(jnp.float32),
                           wkv_state, impl=impl, chunk=min(64, s),
                           interpret=pol.interpret, autotune=pol.autotune)
    y = y.astype(x.dtype).reshape(b, s, d)
    y = _groupnorm_heads(y, tm["ln_x_scale"], h) * g
    last_x = x[:, -1] if length is None else _gather_last(x, ln)
    return matmul(y, tm["wo"]), (last_x, s_fin)


def time_mix_decode(p, cfg, x, shift_state, wkv_state):
    """x (B,d) single token."""
    tm = p["tm"]
    b, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    xs = _ddlerp(tm, x, shift_state)                              # (B,5,d)
    xw, xk, xv, xr, xg = (xs[:, i] for i in range(5))
    w = _decay(tm, xw).reshape(b, h, hd)
    r = matmul(xr, tm["wr"]).reshape(b, h, hd)
    k = matmul(xk, tm["wk"]).reshape(b, h, hd)
    v = matmul(xv, tm["wv"]).reshape(b, h, hd)
    g = jax.nn.silu(matmul(xg, tm["wg"]))
    y, s_new = wkv_ops.wkv_decode(r, k, v, w, tm["u"].astype(jnp.float32),
                                  wkv_state)
    y = y.astype(x.dtype).reshape(b, d)
    y = _groupnorm_heads(y, tm["ln_x_scale"], h) * g
    return matmul(y, tm["wo"]), (x, s_new)


def channel_mix_seq(p, cfg, x, shift_state=None, length=None):
    cm = p["cm"]
    b, s, d = x.shape
    prev = jnp.zeros((b, 1, d), x.dtype) if shift_state is None else shift_state[:, None]
    x_prev = jnp.concatenate([prev, x[:, :-1]], axis=1)
    xx = x_prev - x
    xk = x + xx * cm["mu_k"].astype(x.dtype)
    xr = x + xx * cm["mu_r"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(matmul(xk, cm["wk"])))
    out = jax.nn.sigmoid(matmul(xr, cm["wr"])) * matmul(kk, cm["wv"])
    if length is None:
        return out, x[:, -1]
    _, ln = _length_mask(length, b, s)
    return out, _gather_last(x, ln)


def channel_mix_decode(p, cfg, x, shift_state):
    cm = p["cm"]
    xx = shift_state - x
    xk = x + xx * cm["mu_k"].astype(x.dtype)
    xr = x + xx * cm["mu_r"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(matmul(xk, cm["wk"])))
    out = jax.nn.sigmoid(matmul(xr, cm["wr"])) * matmul(kk, cm["wv"])
    return out, x


def init_rwkv_cache(cfg, batch: int, dtype):
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    return {
        "tm_shift": jnp.zeros((batch, d), dtype),
        "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "cm_shift": jnp.zeros((batch, d), dtype),
    }
