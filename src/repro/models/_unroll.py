"""Scan-vs-unroll switch for layer stacks.

XLA's HLO cost analysis counts a while-loop body ONCE regardless of trip
count, so the dry-run's scan-cost correction compiles small-depth UNROLLED
variants to measure exact per-superblock costs.  Production code always
scans (small HLO, fast compile); only the dry-run flips ``UNROLL`` on.
"""
from __future__ import annotations

import jax

UNROLL = False


def scan_or_unroll(f, init, xs):
    """Drop-in for jax.lax.scan(f, init, xs) honoring the UNROLL flag."""
    if not UNROLL:
        return jax.lax.scan(f, init, xs)
    length = jax.tree.leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(length):
        xi = jax.tree.map(lambda x: x[i], xs)
        carry, y = f(carry, xi)
        ys.append(y)
    if ys and all(y is not None for y in jax.tree.leaves(ys[0])) and \
            ys[0] is not None:
        import jax.numpy as jnp
        stacked = jax.tree.map(lambda *leaves: jnp.stack(leaves), *ys)
    else:
        stacked = None
    return carry, stacked
