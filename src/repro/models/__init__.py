"""Unified model API: init / loss / decode dispatch by config family.

``model_inputs(cfg, shape)`` describes the input pytree for each
(arch x shape) — the single source of truth shared by the data pipeline,
the smoke tests and the dry-run's ShapeDtypeStruct specs.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import alexnet, encdec, transformer
from repro.models.layers import softmax_xent


def init(rng, cfg):
    if cfg.family == "encdec":
        return encdec.init(rng, cfg)
    return transformer.init(rng, cfg)


def logits_fn(params, cfg, batch, remat=False):
    """batch: dict of arrays per model_inputs.  Returns (logits, aux).

    Kernel selection (attention impl, recurrence backends, ...) rides on
    ``cfg.kernels`` (a ``repro.kernels.common.KernelPolicy``) — the old
    ``attn_impl=`` kwarg threading is gone; use
    ``dataclasses.replace(cfg, kernels=KernelPolicy(...))`` instead.
    """
    if cfg.family == "encdec":
        return encdec.forward(params, cfg, batch["frames"], batch["tokens"],
                              remat=remat)
    if cfg.family == "vlm":
        return transformer.forward(params, cfg, batch["tokens"],
                                   image_embeds=batch["image_embeds"],
                                   image_mask=batch["image_mask"],
                                   remat=remat)
    return transformer.forward(params, cfg, batch["tokens"], remat=remat)


def loss_fn(params, cfg, batch, remat=False):
    """Next-token cross entropy (+ MoE aux)."""
    logits, aux = logits_fn(params, cfg, batch, remat=remat)
    labels = batch["labels"]
    return softmax_xent(logits[:, :-1], labels[:, 1:]) + aux


def init_decode_cache(cfg, batch: int, seq_len: int, enc_len: int = 1024):
    if cfg.family == "encdec":
        return encdec.init_decode_cache(cfg, batch, seq_len, enc_len)
    return transformer.init_decode_cache(cfg, batch, seq_len)


def decode_step(params, cfg, cache, tokens, pos):
    if cfg.family == "encdec":
        return encdec.decode_step(params, cfg, cache, tokens, pos)
    return transformer.decode_step(params, cfg, cache, tokens, pos)


def model_inputs(cfg, batch: int, seq_len: int):
    """Shape/dtype description of the training/prefill batch."""
    spec = {"tokens": ((batch, seq_len), jnp.int32),
            "labels": ((batch, seq_len), jnp.int32)}
    if cfg.family == "encdec":
        spec["frames"] = ((batch, max(seq_len // 4, 8), cfg.d_model),
                          jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        n_img = cfg.n_image_tokens
        spec["image_embeds"] = ((batch, n_img, cfg.d_model),
                                jnp.dtype(cfg.dtype))
        spec["image_mask"] = ((batch, seq_len), jnp.bool_)
    return spec


__all__ = ["alexnet", "encdec", "transformer", "init", "logits_fn", "loss_fn",
           "init_decode_cache", "decode_step", "model_inputs"]
