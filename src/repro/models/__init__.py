"""Unified model API: init / loss / decode dispatch by config family.

``model_inputs(cfg, shape)`` describes the input pytree for each
(arch x shape) — the single source of truth shared by the data pipeline,
the smoke tests and the dry-run's ShapeDtypeStruct specs.

Decode follows the **DecodeState contract** (docs/serving.md): every
family exposes

  ``init_decode_state(cfg, batch, capacity)``  -> DecodeState
  ``prefill(params, cfg, state, tokens, ...)`` -> (logits, DecodeState)
  ``decode_step(params, cfg, state, tokens)``  -> (logits, DecodeState)

where the state is a pytree of fixed shape: a ring KV cache for
attention families (capacity ``min(capacity, sliding_window)`` per
layer), constant-size recurrent state for rwkv/rglru, and precomputed
cross K/V for encdec.  ``DecodeState.pos`` is PER ROW — rows of one
batch may sit at different depths, which is what lets the serving
engine decode heterogeneous slots in a single step.  Families without a
decode path raise ``NotImplementedError`` instead of silently borrowing
``transformer.decode_step``.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import alexnet, encdec, transformer, vision
from repro.models.layers import softmax_xent

# families whose decode path is the transformer composer's (its block
# kinds cover dense/moe attention, rwkv and rglru carry states natively)
_TRANSFORMER_DECODE = ("dense", "moe", "ssm", "hybrid", "vlm")
DECODE_FAMILIES = _TRANSFORMER_DECODE + ("encdec",)


def init(rng, cfg):
    if cfg.family == "conv":
        return alexnet.init(rng, cfg)
    if cfg.family == "encdec":
        return encdec.init(rng, cfg)
    return transformer.init(rng, cfg)


def logits_fn(params, cfg, batch, remat=False):
    """batch: dict of arrays per model_inputs.  Returns (logits, aux).

    Kernel selection (attention impl, recurrence backends, ...) rides on
    ``cfg.kernels`` (a ``repro.kernels.common.KernelPolicy``) — the old
    ``attn_impl=`` kwarg threading is gone; use
    ``dataclasses.replace(cfg, kernels=KernelPolicy(...))`` instead.
    """
    if cfg.family == "conv":
        return alexnet.forward(params, cfg, batch["images"]), 0.0
    if cfg.family == "encdec":
        return encdec.forward(params, cfg, batch["frames"], batch["tokens"],
                              remat=remat)
    if cfg.family == "vlm":
        return transformer.forward(params, cfg, batch["tokens"],
                                   image_embeds=batch["image_embeds"],
                                   image_mask=batch["image_mask"],
                                   remat=remat)
    return transformer.forward(params, cfg, batch["tokens"], remat=remat)


def loss_fn(params, cfg, batch, remat=False):
    """Next-token cross entropy (+ MoE aux); classification xent for conv."""
    logits, aux = logits_fn(params, cfg, batch, remat=remat)
    labels = batch["labels"]
    if cfg.family == "conv":
        return softmax_xent(logits[:, None, :], labels[:, None]) + aux
    return softmax_xent(logits[:, :-1], labels[:, 1:]) + aux


# ---------------------------------------------------------- DecodeState ----

@dataclasses.dataclass
class DecodeState:
    """Per-family decode state + per-row positions.

    ``cache`` is the family's state pytree (fixed shapes for the life of
    the state); ``pos`` (B,) int32 counts tokens each row has consumed —
    i.e. the absolute position the NEXT ``decode_step`` token will take.
    """
    cache: Any
    pos: jnp.ndarray


jax.tree_util.register_pytree_node(
    DecodeState,
    lambda s: ((s.cache, s.pos), None),
    lambda _, ch: DecodeState(cache=ch[0], pos=ch[1]))


def _check_decode_family(cfg):
    if cfg.family not in DECODE_FAMILIES:
        raise NotImplementedError(
            f"family {cfg.family!r} ({cfg.name}) has no decode path; "
            f"implement the DecodeState contract (init_decode_state / "
            f"prefill / decode_step — docs/serving.md) for it.  Families "
            f"with decode: {sorted(DECODE_FAMILIES)}")


def init_decode_cache(cfg, batch: int, seq_len: int, enc_len: int = 1024):
    """Low-level cache builder (the DecodeState's ``cache`` pytree)."""
    if cfg.family == "conv":
        # image classification is a single forward pass — the serving
        # engine admits a batch, emits one class id per image and retires
        # the rows before any decode tick; there is no state to carry
        return {}
    _check_decode_family(cfg)
    if cfg.family == "encdec":
        return encdec.init_decode_cache(cfg, batch, seq_len, enc_len)
    return transformer.init_decode_cache(cfg, batch, seq_len)


def init_decode_state(cfg, batch: int, capacity: int,
                      enc_len: int = 1024) -> DecodeState:
    return DecodeState(cache=init_decode_cache(cfg, batch, capacity, enc_len),
                       pos=jnp.zeros((batch,), jnp.int32))


def prefill(params, cfg, tokens, capacity: int, *, length=None, frames=None,
            image_embeds=None, image_mask=None):
    """Prompt (B,S) -> (logits (B,S,V), ready-to-decode DecodeState).

    ``length`` (scalar or (B,) int32) marks per-row true lengths of
    right-padded prompts: the state comes out exactly as if each row had
    been prefilled unpadded (ring writes, recurrent carries and ``pos``
    all respect it) — this is what bounds the serving engine's prefill
    compile count to its bucket count.
    """
    _check_decode_family(cfg)
    b, s = tokens.shape
    if cfg.family == "encdec":
        if frames is None:
            raise ValueError("encdec prefill needs frames= (encoder input)")
        logits, cache = encdec.prefill(params, cfg, frames, tokens, capacity,
                                       length=length)
    else:
        logits, cache = transformer.prefill(params, cfg, tokens, capacity,
                                            length=length,
                                            image_embeds=image_embeds,
                                            image_mask=image_mask)
    pos = jnp.broadcast_to(
        jnp.asarray(s if length is None else length, jnp.int32), (b,))
    return logits, DecodeState(cache=cache, pos=pos)


def decode_step(params, cfg, state, tokens, pos=None, table=None):
    """One decode step for every row.  tokens (B,1) int32.

    New API: ``state`` is a DecodeState (leave ``pos=None``) — returns
    (logits (B,1,V) f32, DecodeState) with each row's position advanced.
    Low-level form: ``state`` is a bare cache pytree and ``pos`` is the
    explicit scalar-or-(B,) position — returns (logits, new_cache); the
    dry-run lowers this form directly against its sharding specs.

    ``table`` (B, cap/bs) int32 switches attention cache leaves to the
    ref-counted block-pool layout (``serving/blocks.py``): logical ring
    slot ``s`` of row b lives at ``pool[table[b, s//bs], s%bs]``, which
    lets rows share prefilled prefix blocks (docs/serving.md).
    """
    _check_decode_family(cfg)
    if table is not None and cfg.family == "encdec":
        raise NotImplementedError("block-table caches are not implemented "
                                  "for the encdec family")
    if isinstance(state, DecodeState):
        if pos is not None:
            raise ValueError("pass positions via DecodeState.pos, not pos=")
        logits, cache = _decode_cache_step(params, cfg, state.cache, tokens,
                                           state.pos, table)
        return logits, DecodeState(cache=cache, pos=state.pos + 1)
    if pos is None:
        raise ValueError("bare-cache decode_step needs an explicit pos")
    return _decode_cache_step(params, cfg, state, tokens, pos, table)


def _decode_cache_step(params, cfg, cache, tokens, pos, table=None):
    if cfg.family == "encdec":
        return encdec.decode_step(params, cfg, cache, tokens, pos)
    return transformer.decode_step(params, cfg, cache, tokens, pos, table)


def decode_seq(params, cfg, state: DecodeState, tokens, commit_len):
    """Chunked decode: T tokens per row in ONE call, committing only each
    row's first ``commit_len[b]`` of them.  tokens (B,T) int32 at
    absolute positions ``state.pos .. state.pos+T-1``; commit_len (B,)
    int32 in [0,T] (traced).  Returns (logits (B,T,V) f32, DecodeState
    with ``pos += commit_len``).

    ``logits[:, j]`` equal what T sequential ``decode_step`` calls would
    produce — which makes this speculative decoding's verify primitive
    (``commit_len=0``: pure lookahead, no state change) and its commit
    primitive (``commit_len=accepted``: rejected tokens never touch the
    cache, so there is no rollback).  Ring writes are where-masked per
    row; recurrent carries are length-masked the same way prefill's are
    (serving/spec_decode.py builds the accept/commit loop on top).
    """
    _check_decode_family(cfg)
    if cfg.family == "encdec":
        raise NotImplementedError(
            "decode_seq (speculative decoding) is not implemented for the "
            "encdec family: cross-attention caches are per-utterance and "
            "the serving tier drafts text-only models")
    logits, pending = decode_seq_pending(params, cfg, state, tokens)
    return logits, commit_pending(params, cfg, state, pending, commit_len)


def decode_seq_pending(params, cfg, state: DecodeState, tokens):
    """The commit_len-independent half of ``decode_seq``: run the full
    T-token forward WITHOUT touching the state.  Returns (logits
    (B,T,V) f32, pending).  Feed ``pending`` to ``commit_pending`` to
    advance the state by any per-row prefix afterwards.

    This is what folds speculative decoding's verify+commit pair into
    ONE target forward per round: ``decode_seq_pending`` gives the
    verify logits, the accept count is derived from them, and
    ``commit_pending`` applies the accepted prefix as a masked scatter
    (attention) / masked carry re-run (recurrent kinds) — never a
    second forward (serving/spec_decode.py)."""
    _check_decode_family(cfg)
    if cfg.family == "encdec":
        raise NotImplementedError(
            "decode_seq (speculative decoding) is not implemented for the "
            "encdec family: cross-attention caches are per-utterance and "
            "the serving tier drafts text-only models")
    return transformer.decode_seq_pending(params, cfg, state.cache, tokens,
                                          state.pos)


def commit_pending(params, cfg, state: DecodeState, pending,
                   commit_len) -> DecodeState:
    """Commit each row's first ``commit_len[b]`` tokens of a
    ``decode_seq_pending`` chunk; returns the advanced DecodeState."""
    b = state.pos.shape[0]
    cl = jnp.broadcast_to(jnp.asarray(commit_len, jnp.int32), (b,))
    cache = transformer.decode_seq_commit(params, cfg, state.cache, pending,
                                          state.pos, cl)
    return DecodeState(cache=cache, pos=state.pos + cl)


# slot surgery: the continuous-batching engine swaps one request's state
# in/out of a fixed-slot DecodeState.  Scan-stacked cache leaves carry
# their layer axis BEFORE batch.
_STACKED_RE = re.compile(r"(^|/)(blocks|self|cross)/")


def stacked_cache_path(path_str: str) -> bool:
    """Whether a decode-cache leaf at this '/'-joined path carries a
    leading scan-stacked layer axis (batch is then axis 1, not 0).

    SINGLE SOURCE OF TRUTH for the cache layout rule: ``write_slots``
    scatters on it and ``sharding.specs.cache_sharding`` places the
    leading axis with it — if they ever disagreed, slot surgery would
    silently mix requests' states across layers."""
    return bool(_STACKED_RE.search(path_str)) and \
        "rem_blocks" not in path_str


def _leaf_batch_axis(path) -> int:
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
    return 1 if stacked_cache_path("/".join(parts)) else 0


def write_slots(state: DecodeState, sub: DecodeState, slots) -> DecodeState:
    """Scatter ``sub`` (batch = len(slots)) into ``state`` at ``slots``."""
    slots = jnp.asarray(slots, jnp.int32)

    def one(path, leaf, new):
        if _leaf_batch_axis(path) == 1:
            return leaf.at[:, slots].set(new.astype(leaf.dtype))
        return leaf.at[slots].set(new.astype(leaf.dtype))

    cache = jax.tree_util.tree_map_with_path(one, state.cache, sub.cache)
    return DecodeState(cache=cache, pos=state.pos.at[slots].set(sub.pos))


def read_slots(state: DecodeState, slots) -> DecodeState:
    """Gather rows ``slots`` of ``state`` into a sub-state (batch =
    len(slots)) — the exact inverse of ``write_slots``: round-tripping a
    sub-state through read/write is the identity, which is what makes
    drain/handoff snapshots (serving/tier.py) byte-faithful."""
    slots = jnp.asarray(slots, jnp.int32)

    def one(path, leaf):
        if _leaf_batch_axis(path) == 1:
            return leaf[:, slots]
        return leaf[slots]

    cache = jax.tree_util.tree_map_with_path(one, state.cache)
    return DecodeState(cache=cache, pos=state.pos[slots])


def model_inputs(cfg, batch: int, seq_len: int):
    """Shape/dtype description of the training/prefill batch.  For the
    conv family ``seq_len`` is ignored — the batch is images + labels."""
    from repro.numerics import param_dtype
    dt = param_dtype(cfg)
    if cfg.family == "conv":
        return {"images": ((batch, cfg.image_size, cfg.image_size,
                            cfg.in_channels), dt),
                "labels": ((batch,), jnp.int32)}
    spec = {"tokens": ((batch, seq_len), jnp.int32),
            "labels": ((batch, seq_len), jnp.int32)}
    if cfg.family == "encdec":
        spec["frames"] = ((batch, max(seq_len // 4, 8), cfg.d_model), dt)
    if cfg.family == "vlm":
        n_img = cfg.n_image_tokens
        spec["image_embeds"] = ((batch, n_img, cfg.d_model), dt)
        spec["image_mask"] = ((batch, seq_len), jnp.bool_)
    return spec


__all__ = ["alexnet", "encdec", "transformer", "vision", "init", "logits_fn",
           "loss_fn",
           "DecodeState", "DECODE_FAMILIES", "init_decode_cache",
           "init_decode_state", "prefill", "decode_step", "decode_seq",
           "decode_seq_pending", "commit_pending",
           "write_slots", "read_slots",
           "stacked_cache_path", "model_inputs"]
