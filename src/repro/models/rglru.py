"""Griffin / RecurrentGemma recurrent block (arXiv:2402.19427).

Block: two linear branches from the normed input; branch 1 goes through a
causal depthwise conv (width 4) and the RG-LRU; branch 2 is a GeLU gate;
their product is projected back to d_model.

RG-LRU per channel:
    r_t = sigmoid(block_diag_A x_t)          recurrence gate
    i_t = sigmoid(block_diag_I x_t)          input gate
    log a_t = -c * softplus(Lambda) * r_t    (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The sequence form is selected by the config's ``KernelPolicy``: the XLA
path uses ``jax.lax.associative_scan`` on the (a, b) pairs — the
TPU-native mapping of the paper-pool's linear-recurrence scan — and the
Pallas path runs the chunked VMEM-state kernel in
``repro.kernels.rglru`` (differentiable via its transpose-scan
custom_vjp).  A carried state folds into the first step's b term
(``b_1 += a_1 h_0``) so both paths start from zero state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import policy_of, resolve_interpret
from repro.models.layers import dense_init, matmul


def resolve_rglru_impl(cfg) -> str:
    """``pallas`` or ``xla`` (associative_scan) from the KernelPolicy."""
    pol = policy_of(cfg)
    sel = pol.rglru or pol.backend
    if sel == "auto":
        sel = "pallas" if not resolve_interpret(pol.interpret) else "xla"
    if sel not in ("xla", "pallas"):
        raise ValueError(f"unknown rglru impl {sel!r}")
    return sel


CONV_WIDTH = 4
N_DIAG_BLOCKS = 16
C_COEF = 8.0


def rglru_block_init(rng, cfg, dtype):
    d = cfg.d_model
    d_rnn = cfg.d_model
    bs = d_rnn // N_DIAG_BLOCKS
    ks = jax.random.split(rng, 8)
    # Lambda init so a^c in [0.9, 0.999] at r=1 (paper's init range)
    lam = jax.random.uniform(ks[5], (d_rnn,), jnp.float32, 0.3, 0.9)
    return {
        "wx": dense_init(ks[0], d, d_rnn, dtype),
        "wg": dense_init(ks[1], d, d_rnn, dtype),
        "wo": dense_init(ks[2], d_rnn, d, dtype),
        "conv_w": (jax.random.normal(ks[3], (CONV_WIDTH, d_rnn), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_rnn,), dtype),
        "gate_a": (jax.random.normal(ks[4], (N_DIAG_BLOCKS, bs, bs),
                                     jnp.float32) * bs ** -0.5).astype(dtype),
        "gate_i": (jax.random.normal(ks[6], (N_DIAG_BLOCKS, bs, bs),
                                     jnp.float32) * bs ** -0.5).astype(dtype),
        "lambda": lam.astype(dtype),
    }


def _block_diag(x, w):
    """x (..., d_rnn) @ block-diagonal w (NB, bs, bs)."""
    nb, bs, _ = w.shape
    xb = x.reshape(x.shape[:-1] + (nb, bs))
    y = jnp.einsum("...nb,nbc->...nc", xb, w.astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return y.reshape(x.shape)


def _gates(p, x):
    r = jax.nn.sigmoid(_block_diag(x, p["gate_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag(x, p["gate_i"]).astype(jnp.float32))
    log_a = -C_COEF * jax.nn.softplus(p["lambda"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    # sqrt(1-a^2) computed stably via log1p(-exp(2 log a))
    b_scale = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    bt = b_scale * (i * x.astype(jnp.float32))
    return a, bt


def _conv1d_causal(p, x, state=None):
    """Depthwise causal conv, width 4.  x (B,S,d); state (B,3,d) history."""
    b, s, d = x.shape
    pad = (jnp.zeros((b, CONV_WIDTH - 1, d), x.dtype) if state is None
           else state.astype(x.dtype))
    xp = jnp.concatenate([pad, x], axis=1)
    w = p["conv_w"].astype(x.dtype)
    y = sum(xp[:, i:i + s] * w[i] for i in range(CONV_WIDTH))
    new_state = xp[:, -(CONV_WIDTH - 1):]
    return y + p["conv_b"].astype(x.dtype), new_state


def rglru_seq(p, cfg, x, cache=None, length=None):
    """x (B,S,d).  Returns (out (B,S,d), new_cache).

    ``length`` (scalar or (B,) int32; right-padded prefill): padded
    positions are frozen out of the recurrence (a=1, b=0 makes the update
    the identity), so ``new_cache`` is each row's state after exactly
    ``length[b]`` real tokens; the conv history gathers the last
    CONV_WIDTH-1 real inputs per row.
    """
    b, s, _ = x.shape
    xb = matmul(x, p["wx"])
    gate = jax.nn.gelu(matmul(x, p["wg"]))
    conv_state = None if cache is None else cache["conv"]
    h0 = None if cache is None else cache["h"]
    xc, conv_state = _conv1d_causal(p, xb, conv_state)
    a, bt = _gates(p, xc)                        # (B,S,d) fp32
    if h0 is not None:
        # fold the carried state into the first step: b_1 += a_1 * h_0
        bt = bt.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))
    ln = None
    if length is not None:
        ln = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (b,))
        real = (jnp.arange(s)[None, :] < ln[:, None])[..., None]
        a = jnp.where(real, a, 1.0)
        bt = jnp.where(real, bt, 0.0)

    if resolve_rglru_impl(cfg) == "pallas":
        from repro.kernels.rglru.rglru import rglru_pallas
        pol = policy_of(cfg)
        h = rglru_pallas(a, bt, interpret=pol.interpret,
                         autotune=pol.autotune)
    else:
        def combine(lt, rt):
            al, bl = lt
            ar, br = rt
            return al * ar, ar * bl + br

        _, h = jax.lax.associative_scan(combine, (a, bt), axis=1)
    out = matmul((h.astype(x.dtype) * gate), p["wo"])
    if ln is None:
        new_conv = conv_state
    else:
        # conv_state would hold the last CONV_WIDTH-1 PADDED inputs; pull
        # each row's last real ones from the padded input stream instead:
        # xp index t+CONV_WIDTH-1 holds input position t, so positions
        # ln-3..ln-1 live at indices ln..ln+2
        pad = (jnp.zeros((b, CONV_WIDTH - 1, xb.shape[-1]), xb.dtype)
               if cache is None else cache["conv"].astype(xb.dtype))
        xp = jnp.concatenate([pad, xb], axis=1)
        idx = ln[:, None] + jnp.arange(CONV_WIDTH - 1)[None, :]
        new_conv = jnp.take_along_axis(xp, idx[..., None], axis=1)
    new_cache = {"conv": new_conv.astype(x.dtype),
                 "h": h[:, -1].astype(jnp.float32)}
    return out, new_cache


def rglru_decode(p, cfg, x, cache):
    """x (B,d) single step."""
    xb = matmul(x, p["wx"])
    gate = jax.nn.gelu(matmul(x, p["wg"]))
    xp = jnp.concatenate([cache["conv"].astype(x.dtype), xb[:, None]], axis=1)
    w = p["conv_w"].astype(x.dtype)
    xc = sum(xp[:, i] * w[i] for i in range(CONV_WIDTH)) + p["conv_b"].astype(x.dtype)
    a, bt = _gates(p, xc)
    h = a * cache["h"] + bt
    out = matmul((h.astype(x.dtype) * gate), p["wo"])
    return out, {"conv": xp[:, 1:], "h": h}


def init_rglru_cache(cfg, batch: int, dtype):
    d_rnn = cfg.d_model
    return {"conv": jnp.zeros((batch, CONV_WIDTH - 1, d_rnn), dtype),
            "h": jnp.zeros((batch, d_rnn), jnp.float32)}
