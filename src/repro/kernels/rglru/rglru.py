"""Pallas TPU kernel for the RG-LRU diagonal linear recurrence.

Grid: (B, D/bd, T/C) with the chunk axis sequential; the carried state
(1, bd) lives in VMEM scratch.  Within a chunk the closed-form prefix
product runs on (C, bd) tiles — VPU elementwise work with fp32
accumulation, which is exactly how Griffin's TPU implementation avoids a
per-timestep loop.  Channel tiles (bd=128) match the lane width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names it TPUCompilerParams; newer jax renames to CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _rglru_kernel(a_ref, b_ref, h_ref, state, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    a = a_ref[0].astype(jnp.float32)          # (C, bd)
    b = b_ref[0].astype(jnp.float32)
    loga = jnp.log(jnp.maximum(a, 1e-37))
    logp = jnp.cumsum(loga, axis=0)
    p = jnp.exp(logp)
    scaled = b * jnp.exp(-logp)
    h_all = p * (state[...] + jnp.cumsum(scaled, axis=0))
    state[...] = h_all[-1:, :]
    h_ref[0] = h_all.astype(h_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "bd", "interpret"))
def rglru_pallas(a, b, *, chunk: int = 128, bd: int = 128,
                 interpret: bool = True):
    """a, b: (B, T, D) with T % chunk == 0 and D % bd == 0."""
    bsz, t, d = a.shape
    assert t % chunk == 0 and d % bd == 0, (t, d, chunk, bd)

    spec = pl.BlockSpec((1, chunk, bd), lambda i, j, k: (i, k, j))
    return pl.pallas_call(
        functools.partial(_rglru_kernel, chunk=chunk),
        grid=(bsz, d // bd, t // chunk),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((bsz, t, d), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
