"""Pallas TPU kernel for the RG-LRU diagonal linear recurrence.

Grid: (B, D/bd, T/C) with the chunk axis sequential; the carried state
(1, bd) lives in VMEM scratch.  Within a chunk the prefix products run
on (C, C, bd) decay tiles with exponents ``logP_t - logP_s ≤ 0`` (s≤t),
so no term ever overflows — the naive ``P_t * cumsum(b_s / P_s)``
rescaling blows through fp32 range under the model's strong decay
(``a ≈ e^-10`` compounds to ``e^±640`` over a 64-chunk), which is why
chunks stay short and the same bounded-exponent scheme as the WKV6
kernel is used instead.  Channel tiles (bd=128) match the lane width.

Differentiable via ``jax.custom_vjp``: the cotangent of a linear
recurrence ``h_t = a_t h_{t-1} + b_t`` is itself a linear recurrence run
*backwards* (``g_t = dh_t + a_{t+1} g_{t+1}``), so the backward pass is
one more call of the SAME Pallas kernel on the time-reversed, one-step-
shifted coefficients (the transpose scan), plus elementwise products:

    db_t = g_t            da_t = g_t * h_{t-1}

Ragged T/D are zero-padded (a=1, b=0 — inert steps/channels) by the
public wrapper and sliced off; block sizes come from the shared autotune
cache (``repro.kernels.common``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import pow2_clip, resolve_interpret

# jax 0.4.x names it TPUCompilerParams; newer jax renames to CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _rglru_kernel(a_ref, b_ref, h_ref, state, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    a = a_ref[0].astype(jnp.float32)          # (C, bd)
    b = b_ref[0].astype(jnp.float32)
    loga = jnp.log(jnp.maximum(a, 1e-37))
    logp = jnp.cumsum(loga, axis=0)
    # A[t,s] = P_t / P_s = exp(logp_t - logp_s) for s <= t: with decaying
    # coefficients (a <= 1) every exponent is <= 0, so nothing overflows
    # regardless of decay strength; masked (s > t) entries are killed
    # INSIDE the exp (-1e30 -> 0), so a growing recurrence (a > 1) stays
    # exact too instead of being silently clamped
    expo = logp[:, None, :] - logp[None, :, :]           # (C, C, bd)
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))
    dec = jnp.exp(jnp.where(tri[:, :, None], expo, -1e30))
    h_all = jnp.einsum("tsc,sc->tc", dec, b,
                       preferred_element_type=jnp.float32)
    h_all = h_all + jnp.exp(logp) * state[...]
    state[...] = h_all[-1:, :]
    h_ref[0] = h_all.astype(h_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "bd", "interpret"))
def _rglru_impl(a, b, chunk, bd, interpret):
    """a, b: (B, T, D) with T % chunk == 0 and D % bd == 0."""
    bsz, t, d = a.shape
    assert t % chunk == 0 and d % bd == 0, (t, d, chunk, bd)

    spec = pl.BlockSpec((1, chunk, bd), lambda i, j, k: (i, k, j))
    return pl.pallas_call(
        functools.partial(_rglru_kernel, chunk=chunk),
        grid=(bsz, d // bd, t // chunk),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((bsz, t, d), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _rglru_core(a, b, chunk, bd, interpret):
    return _rglru_impl(a, b, chunk, bd, interpret)


def _rglru_core_fwd(a, b, chunk, bd, interpret):
    h = _rglru_impl(a, b, chunk, bd, interpret)
    # zero-size marker keeps b's dtype for the cotangent cast without
    # saving b itself (its value is not needed by the transpose scan)
    return h, (a, h, jnp.zeros((), b.dtype))


def _rglru_core_bwd(chunk, bd, interpret, res, dh):
    a, h, b_proto = res
    af = a.astype(jnp.float32)
    # g_t = dh_t + a_{t+1} g_{t+1}: the same recurrence on the reversed
    # sequence with coefficients shifted one step — run the kernel again
    a_rev = jnp.flip(af, axis=1)
    a_shift = jnp.concatenate([jnp.ones_like(a_rev[:, :1]), a_rev[:, :-1]],
                              axis=1)
    g = jnp.flip(_rglru_impl(a_shift, jnp.flip(dh.astype(jnp.float32), 1),
                             chunk, bd, interpret), axis=1)
    h_prev = jnp.concatenate(
        [jnp.zeros_like(h[:, :1]), h[:, :-1]], axis=1).astype(jnp.float32)
    return (g * h_prev).astype(a.dtype), g.astype(b_proto.dtype)


_rglru_core.defvjp(_rglru_core_fwd, _rglru_core_bwd)


def rglru_blocks(t: int, d: int, dtype, *, interpret: bool,
                 autotune: bool = None):
    """(chunk, bd), shared-autotuned on compiled backends."""
    from repro.kernels import common
    # chunk cap 64: the (C, C, bd) decay tile is the VMEM budget
    # (64·64·128 fp32 = 2 MB); larger chunks square it away
    default = (pow2_clip(t, 64), pow2_clip(d, 128))
    key = ("rglru", t, d, str(dtype))
    if not common.autotune_enabled(interpret, autotune):
        return common.autotune(key, [default], None)
    cands = {default} | {(c, default[1]) for c in (16, 32, 64)
                         if c <= pow2_clip(t, 64)}
    import numpy as np
    rng = np.random.default_rng(0)
    a = (1.0 / (1.0 + np.exp(-rng.normal(size=(2, t, d)) - 2.0))
         ).astype(dtype)
    b = rng.normal(size=(2, t, d)).astype(dtype)

    def measure(c):
        chunk, bd = c
        return common.time_call(
            lambda: rglru_pallas(a, b, chunk=chunk, bd=bd, interpret=False))
    return common.autotune(key, sorted(cands), measure)


def rglru_pallas(a, b, *, chunk: int = None, bd: int = None,
                 interpret: bool = None, autotune: bool = None):
    """a, b: (B, T, D), any T/D (padded internally).  Differentiable.

    Precondition: a > 0 (log-space prefix products).  Decaying
    coefficients (a <= 1, the RG-LRU's range) are unconditionally stable;
    a growing recurrence (a > 1) is computed exactly but inherits fp32
    range limits on the within-chunk product ``prod a``."""
    bsz, t, d = a.shape
    interpret = resolve_interpret(interpret)
    if chunk is None or bd is None:
        tc, tb = rglru_blocks(t, d, a.dtype, interpret=interpret,
                              autotune=autotune)
        chunk, bd = chunk or tc, bd or tb
    chunk = min(chunk, pow2_clip(t, chunk))
    bd = min(bd, pow2_clip(d, bd))
    t_pad = -(-t // chunk) * chunk
    d_pad = -(-d // bd) * bd
    if t_pad != t or d_pad != d:
        # inert padding: a=1, b=0 carries the state unchanged and keeps
        # padded channels at zero (sliced off below)
        widths = ((0, 0), (0, t_pad - t), (0, d_pad - d))
        a = jnp.pad(a, widths, constant_values=1.0)
        b = jnp.pad(b, widths)
    h = _rglru_core(a, b, chunk, bd, interpret)
    return h[:, :t, :d] if (t_pad != t or d_pad != d) else h
