"""Public RG-LRU recurrence op with implementation dispatch."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rglru import ref
from repro.kernels.rglru.rglru import rglru_pallas


@functools.partial(jax.jit, static_argnames=("impl", "chunk"))
def rglru_scan(a, b, h0=None, *, impl: str = "chunked", chunk: int = 64):
    """h_t = a_t h_{t-1} + b_t.  Returns (h (B,T,D), h_final)."""
    if impl == "sequential":
        return ref.rglru_sequential(a, b, h0)
    if impl == "chunked":
        return ref.rglru_chunked(a, b, h0, chunk=chunk)
    if impl == "pallas":
        if h0 is not None:
            raise NotImplementedError("pallas path starts from zero state")
        h = rglru_pallas(a, b, chunk=chunk, interpret=True)
        return h, h[:, -1].astype("float32")
    raise ValueError(f"unknown impl {impl!r}")
