"""Public RG-LRU recurrence op with implementation dispatch."""
from __future__ import annotations

import functools

import jax

from repro.kernels import common
from repro.kernels.rglru import ref
from repro.kernels.rglru.rglru import rglru_blocks, rglru_pallas


@functools.partial(jax.jit, static_argnames=("impl", "chunk", "interpret"))
def rglru_scan(a, b, h0=None, *, impl: str = "chunked", chunk: int = 64,
               interpret: bool = None):
    """h_t = a_t h_{t-1} + b_t.  Returns (h (B,T,D), h_final)."""
    if impl == "sequential":
        return ref.rglru_sequential(a, b, h0)
    if impl == "chunked":
        return ref.rglru_chunked(a, b, h0, chunk=chunk)
    if impl == "pallas":
        if h0 is not None:
            raise NotImplementedError(
                "pallas path starts from zero state; fold h0 into b "
                "(b[:, 0] += a[:, 0] * h0) as models/rglru.py does")
        h = rglru_pallas(a, b, chunk=chunk, interpret=interpret)
        return h, h[:, -1].astype("float32")
    raise ValueError(f"unknown impl {impl!r}")


def _example(seed: int = 0):
    import jax.numpy as jnp
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (2, 100, 24)) * 0.5 + 2.0)
    b = jax.random.normal(ks[1], (2, 100, 24))
    return a.astype(jnp.float32), b


common.register(common.KernelOp(
    name="rglru",
    pallas=lambda a, b: rglru_pallas(a, b, chunk=32),
    ref=lambda a, b: ref.rglru_sequential(a, b)[0],
    example=_example,
    tuner=rglru_blocks,
    tol=2e-4,
    grad_argnums=(0, 1),
))
