from repro.kernels.rglru import ops, ref  # noqa: F401
