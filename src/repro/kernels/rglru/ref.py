"""Pure-jnp oracle for the RG-LRU gated linear recurrence.

    h_t = a_t * h_{t-1} + b_t          (elementwise over channels)

``rglru_sequential`` is the ground-truth scan; ``rglru_chunked`` computes
within-chunk prefix products in closed form and carries the state across
chunks — the same TPU-native chunking used for RWKV6, here for the simpler
diagonal recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_sequential(a, b, h0=None):
    """a, b: (B, T, D); returns (h (B,T,D), h_final (B,D))."""
    bsz, t, d = a.shape
    h0 = jnp.zeros((bsz, d), jnp.float32) if h0 is None else h0

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    hT, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                          (a.astype(jnp.float32).transpose(1, 0, 2),
                           b.astype(jnp.float32).transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2), hT


def rglru_chunked(a, b, h0=None, chunk: int = 64):
    """Chunked-parallel form.  Within a chunk of length C:

        h_t = P_t * h_in + sum_{s<=t} (P_t / P_s) * b_s,   P_t = prod a_{<=t}

    computed as P_t * (h_in + cumsum(b_s / P_s)) with the division guarded
    by the log-space cumulative product (a in (0,1], so P decays; chunks are
    kept short so 1/P_s stays in fp32 range — same scheme as WKV6).
    """
    bsz, t, d = a.shape
    t_orig = t
    if t % chunk:
        pad = chunk - t % chunk
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        t += pad
    nc = t // chunk
    h0 = jnp.zeros((bsz, d), jnp.float32) if h0 is None else h0
    ac = a.astype(jnp.float32).reshape(bsz, nc, chunk, d).transpose(1, 0, 2, 3)
    bc = b.astype(jnp.float32).reshape(bsz, nc, chunk, d).transpose(1, 0, 2, 3)

    def one_chunk(h, ab):
        aa, bb = ab                                   # (B, C, D)
        loga = jnp.log(jnp.maximum(aa, 1e-37))
        logp = jnp.cumsum(loga, axis=1)               # log P_t
        p = jnp.exp(logp)
        scaled = bb * jnp.exp(-logp)                  # b_s / P_s
        h_all = p * (h[:, None, :] + jnp.cumsum(scaled, axis=1))
        return h_all[:, -1, :], h_all

    hT, hs = jax.lax.scan(one_chunk, h0, (ac, bc))
    h = hs.transpose(1, 0, 2, 3).reshape(bsz, t, d)
    return h[:, :t_orig], hT
