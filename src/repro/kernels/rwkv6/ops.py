"""Public WKV6 op: jit'd wrapper dispatching between implementations.

``impl``:
  ``chunked``    — pure-jnp chunked-parallel (default; lowers on any backend,
                   used by the dry-run and CPU training)
  ``sequential`` — the scan oracle (decode path / small shapes)
  ``pallas``     — the TPU kernel (interpret-mode on CPU hosts)
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.rwkv6 import ref
from repro.kernels.rwkv6.rwkv6 import wkv_pallas


@functools.partial(jax.jit, static_argnames=("impl", "chunk"))
def wkv(r, k, v, w, u, s0=None, *, impl: str = "chunked", chunk: int = 64):
    """Returns (y, final_state).  See ref.wkv_sequential for semantics."""
    if impl == "sequential":
        return ref.wkv_sequential(r, k, v, w, u, s0)
    if impl == "chunked":
        return ref.wkv_chunked(r, k, v, w, u, s0, chunk=chunk)
    if impl == "pallas":
        if s0 is not None:
            raise NotImplementedError("pallas path starts from zero state")
        y = wkv_pallas(r, k, v, w, u, chunk=chunk, interpret=True)
        # final state from the chunked oracle (cheap relative to the seq pass)
        _, s_fin = ref.wkv_chunked(r, k, v, w, u, chunk=chunk)
        return y, s_fin
    raise ValueError(f"unknown impl {impl!r}")


wkv_decode = ref.wkv_decode
