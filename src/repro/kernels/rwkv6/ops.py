"""Public WKV6 op: jit'd wrapper dispatching between implementations.

``impl``:
  ``chunked``    — pure-jnp chunked-parallel (lowers on any backend,
                   used by the dry-run and CPU training)
  ``sequential`` — the scan oracle (decode path / small shapes)
  ``pallas``     — the TPU kernel (differentiable; interpret mode
                   resolves through the shared kernel infrastructure —
                   REPRO_PALLAS_INTERPRET applies here too)
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import common
from repro.kernels.rwkv6 import ref
from repro.kernels.rwkv6.rwkv6 import rwkv_blocks, wkv_pallas


@functools.partial(jax.jit, static_argnames=("impl", "chunk", "interpret",
                                             "autotune"))
def wkv(r, k, v, w, u, s0=None, *, impl: str = "chunked", chunk: int = 64,
        interpret: bool = None, autotune: bool = None):
    """Returns (y, final_state).  See ref.wkv_sequential for semantics."""
    if impl == "sequential":
        return ref.wkv_sequential(r, k, v, w, u, s0)
    if impl == "chunked":
        return ref.wkv_chunked(r, k, v, w, u, s0, chunk=chunk)
    if impl == "pallas":
        if s0 is not None:
            raise NotImplementedError("pallas path starts from zero state")
        # the kernel emits its final VMEM state directly — no second
        # recurrence pass for the prefill/return_cache path
        return wkv_pallas(r, k, v, w, u, chunk=chunk, interpret=interpret,
                          autotune=autotune, return_state=True)
    raise ValueError(f"unknown impl {impl!r}")


wkv_decode = ref.wkv_decode


def _example(seed: int = 0):
    import jax.numpy as jnp
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    b, t, h, kk = 1, 100, 2, 16                # odd length on purpose
    r, k, v = (jax.random.normal(ks[i], (b, t, h, kk)) for i in range(3))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, t, h, kk)) * 0.5))
    u = jax.random.normal(ks[4], (h, kk)) * 0.5
    return r, k, v, w, u


common.register(common.KernelOp(
    name="rwkv6",
    pallas=lambda r, k, v, w, u: wkv_pallas(r, k, v, w, u, chunk=32),
    ref=lambda r, k, v, w, u: ref.wkv_sequential(r, k, v, w, u)[0],
    example=_example,
    tuner=rwkv_blocks,
    tol=2e-4,
    grad_argnums=(0, 1, 2, 3, 4),
))
