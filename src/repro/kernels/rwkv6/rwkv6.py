"""Pallas TPU kernel for the chunked RWKV6 WKV recurrence.

Grid: (B*H, T/C) with the chunk axis ``arbitrary`` (sequential) — the running
state S (K×K, fp32) lives in a VMEM scratch buffer and is carried across
chunk steps, so HBM traffic per chunk is just the (C,K) operand tiles plus
one (C,K) output tile.  All matmuls are (C,K)x(K,K) / (C,C)x(C,K) — MXU-
aligned for K=64/128 with fp32 accumulation.

The intra-chunk decay weights use exponents ``cum_{t-1} - cum_s ≤ 0`` (s<t),
so no term ever overflows — same scheme as the jnp oracle in ``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names it TPUCompilerParams; newer jax renames to CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_scratch, *,
                chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scratch[...] = jnp.zeros_like(s_scratch)

    r = r_ref[0].astype(jnp.float32)          # (C, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)          # (1, K)
    s = s_scratch[...]                        # (K, K)

    lw = jnp.log(w)
    cum = jnp.cumsum(lw, axis=0)              # (C, K)
    cum_prev = cum - lw

    # intra-chunk scores A[t,s] = Σ_i r[t,i] k[s,i] e^{cum_prev[t,i]-cum[s,i]}, s<t
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=-1)
    expo = cum_prev[:, None, :] - cum[None, :, :]          # (C, C, K)
    dec = jnp.exp(jnp.minimum(expo, 0.0)) * tri[:, :, None]
    a = jnp.einsum("tk,sk,tsk->ts", r, k, dec,
                   preferred_element_type=jnp.float32)
    diag = jnp.sum(r * u * k, axis=-1)                     # (C,)
    a = a + jnp.eye(chunk, dtype=jnp.float32) * diag[:, None]

    y = jnp.dot(a, v, preferred_element_type=jnp.float32)
    y = y + jnp.dot(r * jnp.exp(cum_prev), s,
                    preferred_element_type=jnp.float32)

    cend = cum[-1:, :]                                     # (1, K)
    kscaled = k * jnp.exp(cend - cum)
    s_scratch[...] = jnp.exp(cend[0])[:, None] * s + jnp.dot(
        kscaled.T, v, preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_pallas(r, k, v, w, u, *, chunk: int = 64, interpret: bool = True):
    """r,k,v,w: (B,T,H,K); u: (H,K).  Returns y (B,T,H,K)."""
    b, t, h, kk = r.shape
    assert t % chunk == 0
    nc = t // chunk
    # (B*H, T, K) layout so each grid row owns one head's full sequence
    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, t, kk)
    rf, kf, vf, wf = map(fold, (r, k, v, w))
    uf = jnp.broadcast_to(u[None], (b, h, kk)).reshape(b * h, 1, kk)

    spec = pl.BlockSpec((1, chunk, kk), lambda i, j: (i, j, 0))
    uspec = pl.BlockSpec((1, 1, kk), lambda i, j: (i, 0, 0))
    y = pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk),
        grid=(b * h, nc),
        in_specs=[spec, spec, spec, spec, uspec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b * h, t, kk), r.dtype),
        scratch_shapes=[pltpu.VMEM((kk, kk), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(rf, kf, vf, wf, uf)
    return y.reshape(b, h, t, kk).transpose(0, 2, 1, 3)
