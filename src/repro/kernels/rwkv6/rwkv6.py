"""Pallas TPU kernel for the chunked RWKV6 WKV recurrence.

Grid: (B*H, T/C) with the chunk axis ``arbitrary`` (sequential) — the running
state S (K×K, fp32) lives in a VMEM scratch buffer and is carried across
chunk steps, so HBM traffic per chunk is just the (C,K) operand tiles plus
one (C,K) output tile.  All matmuls are (C,K)x(K,K) / (C,C)x(C,K) — MXU-
aligned for K=64/128 with fp32 accumulation.

The intra-chunk decay weights use exponents ``cum_{t-1} - cum_s ≤ 0`` (s<t),
so no term ever overflows — same scheme as the jnp oracle in ``ref.py``.

Differentiable via ``jax.custom_vjp`` with a *chunked-state backward*:
the WKV recurrence's cotangent needs the per-chunk states, so the
backward recomputes the chunked-parallel reference (``ref.wkv_chunked``,
pure XLA, same chunk size → identical state trajectory up to fp32
rounding) and pulls the cotangent through it.  This mirrors the conv2d
precedent — Pallas forward on the hot path, XLA transpose on the
backward — and keeps memory at O(T·K) residuals (the saved operands),
never an unchunked (T, K, K) state history.

Ragged T is padded with inert steps (k=v=r=0, w=1 — state unchanged) by
the public wrapper; the chunk size comes from the shared autotune cache.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import pow2_clip, resolve_interpret
from repro.kernels.rwkv6 import ref

# jax 0.4.x names it TPUCompilerParams; newer jax renames to CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_out_ref,
                s_scratch, *, chunk: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scratch[...] = jnp.zeros_like(s_scratch)

    r = r_ref[0].astype(jnp.float32)          # (C, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)          # (1, K)
    s = s_scratch[...]                        # (K, K)

    lw = jnp.log(w)
    cum = jnp.cumsum(lw, axis=0)              # (C, K)
    cum_prev = cum - lw

    # intra-chunk scores A[t,s] = Σ_i r[t,i] k[s,i] e^{cum_prev[t,i]-cum[s,i]}, s<t
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=-1)
    expo = cum_prev[:, None, :] - cum[None, :, :]          # (C, C, K)
    dec = jnp.exp(jnp.minimum(expo, 0.0)) * tri[:, :, None]
    a = jnp.einsum("tk,sk,tsk->ts", r, k, dec,
                   preferred_element_type=jnp.float32)
    diag = jnp.sum(r * u * k, axis=-1)                     # (C,)
    a = a + jnp.eye(chunk, dtype=jnp.float32) * diag[:, None]

    y = jnp.dot(a, v, preferred_element_type=jnp.float32)
    y = y + jnp.dot(r * jnp.exp(cum_prev), s,
                    preferred_element_type=jnp.float32)

    cend = cum[-1:, :]                                     # (1, K)
    kscaled = k * jnp.exp(cend - cum)
    s_scratch[...] = jnp.exp(cend[0])[:, None] * s + jnp.dot(
        kscaled.T, v, preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        # the final state is already in VMEM — emitting it here is what
        # lets the prefill path skip a second full recurrence pass
        s_out_ref[0] = s_scratch[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _wkv_impl(r, k, v, w, u, chunk, interpret):
    """r,k,v,w: (B,T,H,K) with T % chunk == 0; u: (H,K).
    -> (y (B,T,H,K), final state (B,H,K,K) fp32)."""
    b, t, h, kk = r.shape
    assert t % chunk == 0
    nc = t // chunk
    # (B*H, T, K) layout so each grid row owns one head's full sequence
    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, t, kk)
    rf, kf, vf, wf = map(fold, (r, k, v, w))
    uf = jnp.broadcast_to(u[None], (b, h, kk)).reshape(b * h, 1, kk)

    spec = pl.BlockSpec((1, chunk, kk), lambda i, j: (i, j, 0))
    uspec = pl.BlockSpec((1, 1, kk), lambda i, j: (i, 0, 0))
    y, s_fin = pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk, n_chunks=nc),
        grid=(b * h, nc),
        in_specs=[spec, spec, spec, spec, uspec],
        out_specs=[spec,
                   pl.BlockSpec((1, kk, kk), lambda i, j: (i, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((b * h, t, kk), r.dtype),
                   jax.ShapeDtypeStruct((b * h, kk, kk), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((kk, kk), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(rf, kf, vf, wf, uf)
    return (y.reshape(b, h, t, kk).transpose(0, 2, 1, 3),
            s_fin.reshape(b, h, kk, kk))


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _wkv_core(r, k, v, w, u, chunk, interpret):
    return _wkv_impl(r, k, v, w, u, chunk, interpret)


def _wkv_core_fwd(r, k, v, w, u, chunk, interpret):
    out = _wkv_impl(r, k, v, w, u, chunk, interpret)
    return out, (r, k, v, w, u)


def _wkv_core_bwd(chunk, interpret, res, dy):
    r, k, v, w, u = res
    dy_y, dy_s = dy
    # chunked-state backward: recompute the chunked-parallel XLA reference
    # (same chunk size → same per-chunk state trajectory) and pull both
    # cotangents (output AND final state) through it; scan residuals stay
    # O(T/C) chunk states
    _, pull = jax.vjp(
        lambda r_, k_, v_, w_, u_: ref.wkv_chunked(r_, k_, v_, w_, u_,
                                                   chunk=chunk),
        r, k, v, w, u)
    dr, dk, dv, dw, du = pull((dy_y.astype(jnp.float32),
                               dy_s.astype(jnp.float32)))
    return (dr.astype(r.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            dw.astype(w.dtype), du.astype(u.dtype))


_wkv_core.defvjp(_wkv_core_fwd, _wkv_core_bwd)


def rwkv_blocks(t: int, kk: int, dtype, *, interpret: bool,
                autotune: bool = None):
    """(chunk,) for the WKV kernel, shared-autotuned on compiled backends."""
    from repro.kernels import common
    default = (pow2_clip(t, 64),)
    key = ("rwkv6", t, kk, str(dtype))
    if not common.autotune_enabled(interpret, autotune):
        return common.autotune(key, [default], None)
    cands = {default} | {(c,) for c in (32, 64, 128)
                         if c <= pow2_clip(t, 128)}
    import numpy as np
    rng = np.random.default_rng(0)
    r, k, v = (rng.normal(size=(1, t, 2, kk)).astype(dtype)
               for _ in range(3))
    w = np.exp(-np.exp(rng.normal(size=(1, t, 2, kk)) * 0.5)).astype(dtype)
    u = (rng.normal(size=(2, kk)) * 0.5).astype(dtype)

    def measure(c):
        return common.time_call(
            lambda: wkv_pallas(r, k, v, w, u, chunk=c[0], interpret=False))
    return common.autotune(key, sorted(cands), measure)


def wkv_pallas(r, k, v, w, u, *, chunk: int = None, interpret: bool = None,
               autotune: bool = None, return_state: bool = False):
    """r,k,v,w: (B,T,H,K); u: (H,K).  Returns y (B,T,H,K), or
    (y, final_state (B,H,K,K)) with ``return_state=True`` — the state
    comes straight from the kernel's VMEM scratch, so prefill needs no
    second recurrence pass.  Any T (padded internally with inert steps:
    k=v=0, w=1 leave the state unchanged).  Differentiable."""
    b, t, h, kk = r.shape
    interpret = resolve_interpret(interpret)
    if chunk is None:
        chunk = rwkv_blocks(t, kk, r.dtype, interpret=interpret,
                            autotune=autotune)[0]
    chunk = min(chunk, pow2_clip(t, chunk))
    t_pad = -(-t // chunk) * chunk
    if t_pad != t:
        widths = ((0, 0), (0, t_pad - t), (0, 0), (0, 0))
        r, k, v = (jnp.pad(x, widths) for x in (r, k, v))
        w = jnp.pad(w, widths, constant_values=1.0)
    y, s_fin = _wkv_core(r, k, v, w, u, chunk, interpret)
    y = y[:, :t] if t_pad != t else y
    return (y, s_fin) if return_state else y
