from repro.kernels.rwkv6 import ops, ref  # noqa: F401
