"""Pure-jnp oracles for the RWKV6 (Finch) WKV recurrence.

Per head with state S ∈ R^{K×V} (key-dim × value-dim):

    y_t = (S_{t-1} + diag(u) k_t v_t^T)^T r_t
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

``wkv_sequential`` is the ground-truth scan (O(T) steps, used as the oracle
and for single-token decode).  ``wkv_chunked`` is the TPU-native
chunked-parallel form: within a chunk the (C,C) decay-weighted scores are
computed with exponents ``cum_{t-1}-cum_s ≤ 0`` (never overflows); across
chunks the state is carried by ``lax.scan``.  This is the hardware adaptation
of the reference CUDA kernel — MXU-shaped matmuls instead of a per-timestep
warp loop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv_sequential(r, k, v, w, u, s0=None):
    """r,k,v,w: (B,T,H,K) with w = per-step decay in (0,1); u: (H,K).

    Returns y (B,T,H,K) and final state (B,H,K,K).
    """
    b, t, h, kk = r.shape
    s0 = jnp.zeros((b, h, kk, kk), jnp.float32) if s0 is None else s0

    def step(s, xs):
        rt, kt, vt, wt = xs              # (B,H,K)
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,K,K)
        s_eff = s + u[None, :, :, None] * kv
        y = jnp.einsum("bhk,bhkv->bhv", rt, s_eff)
        s = wt[..., :, None] * s + kv
        return s, y

    xs = tuple(x.astype(jnp.float32).transpose(1, 0, 2, 3) for x in (r, k, v, w))
    s_fin, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), s_fin


def wkv_chunked(r, k, v, w, u, s0=None, chunk: int = 64):
    """Chunked-parallel WKV6; bit-compatible with ``wkv_sequential`` (fp32).

    T is padded internally to a chunk multiple with inert steps
    (k=0, v=0, w=1: state unchanged); padded outputs are sliced off.
    """
    b, t, h, kk = r.shape
    t_orig = t
    if t % chunk:
        pad = chunk - t % chunk
        zero = ((0, 0), (0, pad), (0, 0), (0, 0))
        r = jnp.pad(r, zero)
        k = jnp.pad(k, zero)
        v = jnp.pad(v, zero)
        w = jnp.pad(w, zero, constant_values=1.0)
        t = t + pad
    nc = t // chunk
    s0 = jnp.zeros((b, h, kk, kk), jnp.float32) if s0 is None else s0

    rc, kc, vc, wc = (x.astype(jnp.float32)
                      .reshape(b, nc, chunk, h, kk)
                      .transpose(1, 0, 3, 2, 4)       # (nc, B, H, C, K)
                      for x in (r, k, v, w))
    lw = jnp.log(wc)                                   # ≤ 0
    cum = jnp.cumsum(lw, axis=-2)                      # (nc,B,H,C,K) cum_t = Σ_{s≤t} log w_s
    cum_prev = cum - lw                                # cum_{t-1} (cum_0 = 0)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)   # s < t

    def one_chunk(s, xs):
        rr, kk_, vv, cm, cmp_ = xs                     # (B,H,C,K)
        # intra-chunk scores: A[t,s] = Σ_i r[t,i] k[s,i] e^{cum_{t-1,i}-cum_{s,i}}  (s<t)
        dec = jnp.exp(jnp.where(tri[:, :, None],
                                cmp_[..., :, None, :] - cm[..., None, :, :],
                                -jnp.inf))             # (B,H,C,C,K)
        a = jnp.einsum("bhtk,bhsk,bhtsk->bhts", rr, kk_, dec)
        diag = jnp.einsum("bhtk,hk,bhtk->bht", rr, u.astype(jnp.float32), kk_)
        a = a + jnp.eye(chunk) * diag[..., :, None]
        y = jnp.einsum("bhts,bhsv->bhtv", a, vv)
        # cross-chunk: y_t += (r_t ⊙ e^{cum_{t-1}}) S
        y = y + jnp.einsum("bhtk,bhkv->bhtv", rr * jnp.exp(cmp_), s)
        # state update: S' = diag(e^{cum_C}) S + Σ_s (k_s e^{cum_C - cum_s}) v_s^T
        cend = cm[..., -1:, :]                          # (B,H,1,K)
        kscaled = kk_ * jnp.exp(cend - cm)
        s = jnp.exp(cend[..., 0, :])[..., :, None] * s + \
            jnp.einsum("bhsk,bhsv->bhkv", kscaled, vv)
        return s, y

    s_fin, ys = jax.lax.scan(one_chunk, s0, (rc, kc, vc, cum, cum_prev))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, t, h, kk)
    return y[:, :t_orig], s_fin


def wkv_decode(r, k, v, w, u, s):
    """Single-token decode.  r,k,v,w: (B,H,K); s: (B,H,K,K)."""
    r, k, v, w = (x.astype(jnp.float32) for x in (r, k, v, w))
    kv = k[..., :, None] * v[..., None, :]
    y = jnp.einsum("bhk,bhkv->bhv", r, s + u[None, :, :, None] * kv)
    s = w[..., :, None] * s + kv
    return y, s
