"""LRN dispatch + registry entry.

``lrn`` routes on ``backend``: ``xla`` (the jnp oracle — XLA fuses it
adequately for small nets) or ``pallas`` (single-pass VMEM tile kernel).
The model layer picks via ``KernelPolicy.wants_pallas("lrn")``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.lrn import ref as lrn_ref_mod
from repro.kernels.lrn.lrn import lrn_pallas  # noqa: F401


def lrn(x, *, n: int = 5, alpha: float = 1e-4, beta: float = 0.75,
        k: float = 2.0, backend: str = "xla", interpret: bool = None):
    if backend == "pallas":
        return lrn_pallas(x, n=n, alpha=alpha, beta=beta, k=k,
                          interpret=interpret)
    if backend == "xla":
        return lrn_ref_mod.lrn_ref(x, n=n, alpha=alpha, beta=beta, k=k)
    raise ValueError(f"unknown lrn backend {backend!r}")


def _example(seed: int = 0):
    import numpy as np
    rng = np.random.default_rng(seed)
    # (B, H, W, C) with C straddling a non-multiple of the window
    return (jnp.asarray(rng.normal(size=(2, 7, 7, 24)).astype(np.float32)),)


common.register(common.KernelOp(
    name="lrn",
    pallas=lambda x: lrn_pallas(x),
    ref=lambda x: lrn_ref_mod.lrn_ref(x),
    example=_example,
    tuner=None,
    tol=2e-5,            # elementwise + window sum: no MXU accumulation
    grad_argnums=(0,),
))
