from repro.kernels.lrn import ops, ref  # noqa: F401
