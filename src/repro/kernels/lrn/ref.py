"""Pure-jnp oracle for local response normalization (AlexNet §3.3).

``y_c = x_c / (k + alpha * sum_{c' in window(c)} x_{c'}^2) ** beta`` with
a size-``n`` channel window centred on ``c`` (zero-padded at the edges).
The paper's constants are ``n=5, alpha=1e-4, beta=0.75`` (the Caffe
reference net); ``k=2`` per Krizhevsky et al.
"""
from __future__ import annotations

import jax.numpy as jnp


def window_sum(v, n: int):
    """Size-``n`` zero-padded sliding-window sum over the channel axis."""
    c = v.shape[-1]
    pad = n // 2
    vp = jnp.pad(v, [(0, 0)] * (v.ndim - 1) + [(pad, pad)])
    return sum(vp[..., i:i + c] for i in range(n))


def lrn_ref(x, n: int = 5, alpha: float = 1e-4, beta: float = 0.75,
            k: float = 2.0):
    """x (..., C) -> (..., C), same dtype; fp32 internal math."""
    xf = x.astype(jnp.float32)
    den = jnp.power(k + alpha * window_sum(xf * xf, n), beta)
    return (xf / den).astype(x.dtype)
