"""Pallas LRN: fused tile kernel over (rows, channels) slabs.

LRN is memory-bound — the XLA lowering of the windowed sum reads the
activation ``n+1`` times through HBM.  The Pallas kernel stages one
``(bm, C)`` slab in VMEM, builds the size-``n`` channel-window sum from
static shifted slices of the staged tile, and writes the normalized
output in the same pass: one HBM read, one write per element.  Every
position's channel window lives inside its own row, so the grid over
row tiles is embarrassingly parallel and group/batch/space dims can all
be flattened into M.

Differentiable via ``jax.custom_vjp``; the backward is the closed form

    dx = dy * d**-b - 2*a*b * x * W(dy * x * d**-(b+1)),   d = k + a*W(x²)

(W = the symmetric window sum — channel i is in window(j) iff j is in
window(i)) lowered to XLA, mirroring the conv kernel's XLA backward.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common
from repro.kernels.lrn import ref as lrn_ref_mod

_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

_ROW_CAP = 512          # M-tile cap, same scale as the conv kernel's


def _lrn_kernel(x_ref, o_ref, *, n: int, alpha: float, beta: float,
                k: float):
    xv = x_ref[...].astype(jnp.float32)          # (bm, c_pad)
    sq = xv * xv
    pad = n // 2
    z = jnp.zeros((xv.shape[0], pad), jnp.float32)
    sqp = jnp.concatenate([z, sq, z], axis=1)    # channel zero-pad in VMEM
    win = sum(sqp[:, i:i + xv.shape[1]] for i in range(n))
    y = xv / jnp.power(k + alpha * win, beta)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n", "alpha", "beta", "k",
                                             "bm", "interpret"))
def _lrn_impl(x, n, alpha, beta, k, bm, interpret):
    shape = x.shape
    c = shape[-1]
    m = 1
    for d in shape[:-1]:
        m *= d
    xm = x.reshape(m, c)
    m_pad = -(-m // bm) * bm
    c_pad = -(-c // common.LANE) * common.LANE
    if (m_pad, c_pad) != (m, c):
        # zero channel padding is harmless: it can only ADD zeros to the
        # window sums of real channels, exactly like the oracle's pad
        xm = jnp.pad(xm, ((0, m_pad - m), (0, c_pad - c)))
    out = pl.pallas_call(
        functools.partial(_lrn_kernel, n=n, alpha=alpha, beta=beta, k=k),
        grid=(m_pad // bm,),
        in_specs=[pl.BlockSpec((bm, c_pad), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, c_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, c_pad), x.dtype),
        compiler_params=_CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(xm)
    if (m_pad, c_pad) != (m, c):
        out = out[:m, :c]
    return out.reshape(shape)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5, 6))
def _lrn_core(x, n, alpha, beta, k, bm, interpret):
    return _lrn_impl(x, n, alpha, beta, k, bm, interpret)


def _lrn_fwd(x, n, alpha, beta, k, bm, interpret):
    return _lrn_impl(x, n, alpha, beta, k, bm, interpret), x


def _lrn_bwd(n, alpha, beta, k, bm, interpret, x, dy):
    xf = x.astype(jnp.float32)
    dyf = dy.astype(jnp.float32)
    d = k + alpha * lrn_ref_mod.window_sum(xf * xf, n)
    dx = (dyf * jnp.power(d, -beta)
          - 2.0 * alpha * beta * xf
          * lrn_ref_mod.window_sum(dyf * xf * jnp.power(d, -(beta + 1.0)),
                                   n))
    return (dx.astype(x.dtype),)


_lrn_core.defvjp(_lrn_fwd, _lrn_bwd)


def lrn_pallas(x, *, n: int = 5, alpha: float = 1e-4, beta: float = 0.75,
               k: float = 2.0, bm: int = None, interpret: bool = None):
    """x (..., C) -> (..., C); leading dims flattened into row tiles.
    Differentiable."""
    interpret = common.resolve_interpret(interpret)
    if bm is None:
        m = 1
        for d in x.shape[:-1]:
            m *= d
        bm = common.pow2_clip(m, _ROW_CAP)
    return _lrn_core(x, n, float(alpha), float(beta), float(k), bm,
                     interpret)
