"""Pure-jnp oracle for flash attention: masked softmax attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def attention_ref(q, k, v, *, causal=True, window=None, scale=1.0):
    """q (B,S,Hkv,G,hd); k,v (B,S,Hkv,hd)."""
    s = jnp.einsum("bqhgk,bshk->bhgqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    sq, sk = q.shape[1], k.shape[1]
    rows = jnp.arange(sq)[:, None]
    cols = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask, s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgqs,bshk->bqhgk", p,
                      v.astype(jnp.float32)).astype(q.dtype)
