"""Pallas TPU flash attention — forward AND backward — with causal +
sliding-window masks.

Forward grid: (B*Hq, S/bq, S/bk) — the KV axis is ``arbitrary``
(sequential) and the online-softmax running stats (m, l, acc) live in
VMEM scratch carried across KV steps.  GQA is handled in the BlockSpec
index maps: the K/V block row for query head h is ``b*Hkv + h // group``
— no materialized head repetition.  Alongside the output the kernel
writes the per-row log-sum-exp ``lse = m + log(l)``, the residual the
backward pass needs to rebuild probabilities tile-by-tile.

Backward (``jax.custom_vjp`` — Pallas calls have no automatic AD) is the
standard recompute-style pass over (bq, bk) tiles:

  ``dq`` kernel: grid (B*Hq, S/bq, S/bk), KV sequential — per tile,
      rebuild ``p = exp(s - lse)``, form ``ds = p * (dp - delta)`` with
      ``dp = do @ vᵀ`` and ``delta = rowsum(do * o)`` (precomputed), and
      accumulate ``dq += ds @ k * scale`` in VMEM scratch.
  ``dk/dv`` kernel: grid (B*Hq, S/bk, S/bq), Q sequential — accumulate
      ``dv += pᵀ @ do`` and ``dk += dsᵀ @ q * scale`` per *query* head;
      the wrapper then sums the group axis onto the Hkv heads.

No (S, S) score matrix ever materializes in either direction (asserted
by jaxpr walk in tests/kernels/test_grad_parity.py).

Sequence lengths that don't divide the block sizes are zero-padded by
the public wrapper and masked in-kernel via the static ``s_valid`` bound
(so odd lengths work on both passes); block shapes (bq, hd) / (bk, hd)
are MXU-aligned for hd ∈ {64, 128, 256}.  Numerics: scores are computed
in fp32; masked lanes use -1e30.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import pow2_clip, resolve_interpret

# jax 0.4.x names it TPUCompilerParams; newer jax renames to CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG = -1e30


def _tile_mask(rows, cols, *, causal, window, s_valid, with_rows: bool):
    """The (bq, bk) validity mask — single source of truth for fwd + bwd."""
    mask = cols < s_valid                      # zero-padded KV tail
    if with_rows:
        mask &= rows < s_valid                 # padded query rows (bwd only)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    return mask


def _tile_visible(qi, ki, *, bq, bk, causal, window, s_valid):
    """Whether the (qi, ki) tile has ANY unmasked entry.  Fully-masked
    tiles (above the causal diagonal, outside the sliding window, or in
    the padded KV tail) skip their MXU work entirely — for windowed
    attention that turns the O(S²) tile sweep into O(S·window) compute."""
    vis = ki * bk < s_valid
    if causal:
        vis &= ki * bk <= qi * bq + (bq - 1)       # first col <= last row
    if window is not None:
        vis &= ki * bk + (bk - 1) > qi * bq - window  # last col in window
    return vis


# --------------------------------------------------------------- forward ----

def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr,
                  *, bq: int, bk: int, causal: bool, window, scale: float,
                  n_k: int, s_valid: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(_tile_visible(qi, ki, bq=bq, bk=bk, causal=causal,
                           window=window, s_valid=s_valid))
    def _accumulate():
        q = q_ref[0].astype(jnp.float32)               # (bq, hd)
        k = k_ref[0].astype(jnp.float32)               # (bk, hd)
        v = v_ref[0].astype(jnp.float32)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = _tile_mask(rows, cols, causal=causal, window=window,
                          s_valid=s_valid, with_rows=False)
        s = jnp.where(mask, s, NEG)

        m_prev = m_scr[...]                            # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)
        # lse stays hugely negative (~NEG) for fully-masked padded rows,
        # so the backward's exp(s - lse) never sees an inf there
        lse_ref[0] = (m_scr[...] + jnp.log(jnp.maximum(l, 1e-30)))[:, 0]


@functools.partial(jax.jit, static_argnames=("n_q_heads", "n_kv_heads",
                                             "causal", "window", "scale",
                                             "bq", "bk", "interpret",
                                             "s_valid"))
def _flash_fwd_impl(q, k, v, n_q_heads, n_kv_heads, causal, window, scale,
                    bq, bk, interpret, s_valid):
    """Padded folded inputs -> (o (B*Hq,S,hd), lse (B*Hq,S) fp32)."""
    bhq, s, hd = q.shape
    group = n_q_heads // n_kv_heads
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    n_k = s // bk

    def q_map(i, j, kk):
        return (i, j, 0)

    def kv_map(i, j, kk):
        b, h = i // n_q_heads, i % n_q_heads
        return (b * n_kv_heads + h // group, kk, 0)

    def lse_map(i, j, kk):
        return (i, j)

    return pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, causal=causal,
                          window=window, scale=scale, n_k=n_k,
                          s_valid=s_valid),
        grid=(bhq, s // bq, n_k),
        in_specs=[pl.BlockSpec((1, bq, hd), q_map),
                  pl.BlockSpec((1, bk, hd), kv_map),
                  pl.BlockSpec((1, bk, hd), kv_map)],
        out_specs=[pl.BlockSpec((1, bq, hd), q_map),
                   pl.BlockSpec((1, bq), lse_map)],
        out_shape=[jax.ShapeDtypeStruct((bhq, s, hd), q.dtype),
                   jax.ShapeDtypeStruct((bhq, s), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, hd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


# -------------------------------------------------------------- backward ----

def _flash_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref, dq_ref,
                     dq_scr, *, bq: int, bk: int, causal: bool, window,
                     scale: float, n_k: int, s_valid: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    @pl.when(_tile_visible(qi, ki, bq=bq, bk=bk, causal=causal,
                           window=window, s_valid=s_valid))
    def _accumulate():
        q = q_ref[0].astype(jnp.float32)               # (bq, hd)
        k = k_ref[0].astype(jnp.float32)               # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)             # (bq, hd)
        lse = lse_ref[0][:, None]                      # (bq, 1)
        delta = dl_ref[0][:, None]                     # (bq, 1)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = _tile_mask(rows, cols, causal=causal, window=window,
                          s_valid=s_valid, with_rows=True)
        p = jnp.exp(jnp.where(mask, s - lse, NEG))     # (bq, bk)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta)
        dq_scr[...] += jax.lax.dot(
            ds, k, preferred_element_type=jnp.float32) * scale

    @pl.when(ki == n_k - 1)
    def _finalize():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


def _flash_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref, dk_ref,
                      dv_ref, dk_scr, dv_scr, *, bq: int, bk: int,
                      causal: bool, window, scale: float, n_q: int,
                      s_valid: int):
    ki = pl.program_id(1)
    qi = pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    @pl.when(_tile_visible(qi, ki, bq=bq, bk=bk, causal=causal,
                           window=window, s_valid=s_valid))
    def _accumulate():
        q = q_ref[0].astype(jnp.float32)               # (bq, hd)
        k = k_ref[0].astype(jnp.float32)               # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0][:, None]
        delta = dl_ref[0][:, None]

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = _tile_mask(rows, cols, causal=causal, window=window,
                          s_valid=s_valid, with_rows=True)
        p = jnp.exp(jnp.where(mask, s - lse, NEG))
        dv_scr[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta)
        dk_scr[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    @pl.when(qi == n_q - 1)
    def _finalize():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_q_heads", "n_kv_heads",
                                             "causal", "window", "scale",
                                             "bq", "bk", "interpret",
                                             "s_valid"))
def _flash_bwd_impl(q, k, v, do, lse, delta, n_q_heads, n_kv_heads, causal,
                    window, scale, bq, bk, interpret, s_valid):
    """Returns (dq (B*Hq,S,hd), dk_q, dv_q (B*Hq,S,hd) per *query* head —
    the caller group-sums onto the Hkv heads)."""
    bhq, s, hd = q.shape
    group = n_q_heads // n_kv_heads
    n_q, n_k = s // bq, s // bk

    def q_map(i, j, kk):
        return (i, j, 0)

    def kv_map(i, j, kk):
        b, h = i // n_q_heads, i % n_q_heads
        return (b * n_kv_heads + h // group, kk, 0)

    def lse_map(i, j, kk):
        return (i, j)

    dq = pl.pallas_call(
        functools.partial(_flash_dq_kernel, bq=bq, bk=bk, causal=causal,
                          window=window, scale=scale, n_k=n_k,
                          s_valid=s_valid),
        grid=(bhq, n_q, n_k),
        in_specs=[pl.BlockSpec((1, bq, hd), q_map),
                  pl.BlockSpec((1, bk, hd), kv_map),
                  pl.BlockSpec((1, bk, hd), kv_map),
                  pl.BlockSpec((1, bq, hd), q_map),
                  pl.BlockSpec((1, bq), lse_map),
                  pl.BlockSpec((1, bq), lse_map)],
        out_specs=pl.BlockSpec((1, bq, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((bhq, s, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bq, hd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    # dk/dv: the KV tile is the parallel axis, queries are sequential
    def q_map2(i, j, qq):
        return (i, qq, 0)

    def kv_map2(i, j, qq):
        b, h = i // n_q_heads, i % n_q_heads
        return (b * n_kv_heads + h // group, j, 0)

    def lse_map2(i, j, qq):
        return (i, qq)

    def out_map2(i, j, qq):
        return (i, j, 0)

    dk_q, dv_q = pl.pallas_call(
        functools.partial(_flash_dkv_kernel, bq=bq, bk=bk, causal=causal,
                          window=window, scale=scale, n_q=n_q,
                          s_valid=s_valid),
        grid=(bhq, n_k, n_q),
        in_specs=[pl.BlockSpec((1, bq, hd), q_map2),
                  pl.BlockSpec((1, bk, hd), kv_map2),
                  pl.BlockSpec((1, bk, hd), kv_map2),
                  pl.BlockSpec((1, bq, hd), q_map2),
                  pl.BlockSpec((1, bq), lse_map2),
                  pl.BlockSpec((1, bq), lse_map2)],
        out_specs=[pl.BlockSpec((1, bk, hd), out_map2),
                   pl.BlockSpec((1, bk, hd), out_map2)],
        out_shape=[jax.ShapeDtypeStruct((bhq, s, hd), jnp.float32),
                   jax.ShapeDtypeStruct((bhq, s, hd), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bk, hd), jnp.float32),
                        pltpu.VMEM((bk, hd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk_q, dv_q


# ---------------------------------------------------------------- custom ----

@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8, 9, 10, 11))
def _flash_core(q, k, v, n_q_heads, n_kv_heads, causal, window, scale, bq,
                bk, interpret, s_valid):
    o, _ = _flash_fwd_impl(q, k, v, n_q_heads, n_kv_heads, causal, window,
                           scale, bq, bk, interpret, s_valid)
    return o


def _flash_core_fwd(q, k, v, n_q_heads, n_kv_heads, causal, window, scale,
                    bq, bk, interpret, s_valid):
    o, lse = _flash_fwd_impl(q, k, v, n_q_heads, n_kv_heads, causal, window,
                             scale, bq, bk, interpret, s_valid)
    return o, (q, k, v, o, lse)


def _flash_core_bwd(n_q_heads, n_kv_heads, causal, window, scale, bq, bk,
                    interpret, s_valid, res, dy):
    q, k, v, o, lse = res
    do = dy.astype(jnp.float32)
    delta = jnp.sum(do * o.astype(jnp.float32), axis=-1)   # (B*Hq, S)
    dq, dk_q, dv_q = _flash_bwd_impl(q, k, v, do, lse, delta, n_q_heads,
                                     n_kv_heads, causal, window, scale, bq,
                                     bk, interpret, s_valid)
    # fold the per-query-head dk/dv onto the shared Hkv heads (GQA)
    group = n_q_heads // n_kv_heads
    b = q.shape[0] // n_q_heads
    s, hd = q.shape[1], q.shape[2]
    dk = dk_q.reshape(b, n_kv_heads, group, s, hd).sum(2)
    dv = dv_q.reshape(b, n_kv_heads, group, s, hd).sum(2)
    return (dq.astype(q.dtype),
            dk.reshape(b * n_kv_heads, s, hd).astype(k.dtype),
            dv.reshape(b * n_kv_heads, s, hd).astype(v.dtype))


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


# ----------------------------------------------------------------- entry ----

def flash_blocks(s: int, hd: int, dtype, *, interpret: bool,
                 autotune: bool = None, kv_dtype=None):
    """(bq, bk) tile sizes, shared-autotuned on compiled backends.
    ``kv_dtype`` widens the cache key to the (q, kv) dtype tuple when the
    operands differ (mixed-precision NumericsPolicy)."""
    from repro.kernels import common
    default = (pow2_clip(s, 128), pow2_clip(s, 128))
    dt_key = str(dtype) if kv_dtype is None else (str(dtype), str(kv_dtype))
    key = ("flash", s, hd, dt_key)
    if not common.autotune_enabled(interpret, autotune):
        return common.autotune(key, [default], None)
    cap = pow2_clip(s, 256)
    cands = {default} | {(bq, bk) for bq in (64, 128, 256)
                         for bk in (64, 128, 256)
                         if bq <= cap and bk <= cap}
    import numpy as np
    rng = np.random.default_rng(0)
    q = rng.normal(size=(4, s, hd)).astype(dtype)
    kv = rng.normal(size=(4, s, hd)).astype(kv_dtype or dtype)

    def measure(c):
        bq, bk = c
        return common.time_call(
            lambda: flash_attention_folded(
                q, kv, kv, n_q_heads=4, n_kv_heads=4, causal=True,
                scale=hd ** -0.5, bq=bq, bk=bk, interpret=False))
    return common.autotune(key, sorted(cands), measure)


def flash_attention_folded(q, k, v, *, n_q_heads: int, n_kv_heads: int,
                           causal=True, window=None, scale=1.0,
                           bq: int = None, bk: int = None,
                           interpret: bool = None, autotune: bool = None):
    """q (B*Hq, S, hd); k, v (B*Hkv, S, hd).  Differentiable in q, k, v.

    ``interpret=None`` auto-resolves (compiled on TPU); ``bq/bk=None``
    come from the shared autotune cache.  S may be any length — inputs
    are zero-padded to the block step and masked in-kernel.
    """
    bhq, s, hd = q.shape
    interpret = resolve_interpret(interpret)
    if bq is None or bk is None:
        kvd = None if k.dtype == q.dtype else k.dtype
        tbq, tbk = flash_blocks(s, hd, q.dtype, interpret=interpret,
                                autotune=autotune, kv_dtype=kvd)
        bq, bk = bq or tbq, bk or tbk
    bq = min(bq, pow2_clip(s, bq))
    bk = min(bk, pow2_clip(s, bk))
    step = math.lcm(bq, bk)
    s_pad = -(-s // step) * step
    if s_pad != s:
        pad = ((0, 0), (0, s_pad - s), (0, 0))
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    o = _flash_core(q, k, v, n_q_heads, n_kv_heads, causal, window, scale,
                    bq, bk, interpret, s)
    return o[:, :s] if s_pad != s else o
