"""Pallas TPU flash attention (forward) with causal + sliding-window masks.

Grid: (B*Hq, S/bq, S/bk) — the KV axis is ``arbitrary`` (sequential) and the
online-softmax running stats (m, l, acc) live in VMEM scratch carried across
KV steps.  GQA is handled in the BlockSpec index maps: the K/V block row for
query head h is ``b*Hkv + h // group`` — no materialized head repetition.

Block shapes (bq, hd) / (bk, hd) are MXU-aligned for hd ∈ {64, 128, 256}.
Numerics: scores are computed in fp32; masked lanes use -1e30 (every valid
query row attends to at least itself under causal masking, so no row is ever
fully masked).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names it TPUCompilerParams; newer jax renames to CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  bq: int, bk: int, causal: bool, window, scale: float,
                  n_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                   # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                   # (bk, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask, s, NEG)

    m_prev = m_scr[...]                                # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_q_heads", "n_kv_heads",
                                             "causal", "window", "scale",
                                             "bq", "bk", "interpret"))
def flash_attention_folded(q, k, v, *, n_q_heads: int, n_kv_heads: int,
                           causal=True, window=None, scale=1.0,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = True):
    """q (B*Hq, S, hd); k, v (B*Hkv, S, hd)."""
    bhq, s, hd = q.shape
    group = n_q_heads // n_kv_heads
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    n_k = s // bk

    def q_map(i, j, kk):
        return (i, j, 0)

    def kv_map(i, j, kk):
        b, h = i // n_q_heads, i % n_q_heads
        return (b * n_kv_heads + h // group, kk, 0)

    return pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, causal=causal,
                          window=window, scale=scale, n_k=n_k),
        grid=(bhq, s // bq, n_k),
        in_specs=[pl.BlockSpec((1, bq, hd), q_map),
                  pl.BlockSpec((1, bk, hd), kv_map),
                  pl.BlockSpec((1, bk, hd), kv_map)],
        out_specs=pl.BlockSpec((1, bq, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((bhq, s, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, hd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
