"""Public flash-attention op in model layout (B,S,Hkv,G,hd)."""
from __future__ import annotations


from repro.kernels.flash_attention.flash_attention import \
    flash_attention_folded


def flash_attention(q, k, v, *, causal=True, window=None, scale=1.0,
                    bq: int = 128, bk: int = 128, interpret: bool = True):
    """q (B,S,Hkv,G,hd); k,v (B,S,Hkv,hd).  Returns (B,S,Hkv,G,hd)."""
    b, s, hkv, g, hd = q.shape
    hq = hkv * g
    qf = q.transpose(0, 2, 3, 1, 4).reshape(b * hq, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, hd)
    bq_ = min(bq, s)
    bk_ = min(bk, s)
    o = flash_attention_folded(qf, kf, vf, n_q_heads=hq, n_kv_heads=hkv,
                               causal=causal, window=window, scale=scale,
                               bq=bq_, bk=bk_, interpret=interpret)
    return o.reshape(b, hkv, g, s, hd).transpose(0, 3, 1, 2, 4)
