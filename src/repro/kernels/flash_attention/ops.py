"""Public flash-attention op in model layout (B,S,Hkv,G,hd)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.flash_attention import (
    flash_attention_folded, flash_blocks)


def flash_attention(q, k, v, *, causal=True, window=None, scale=1.0,
                    bq: int = None, bk: int = None, interpret: bool = None,
                    autotune: bool = None):
    """q (B,S,Hkv,G,hd); k,v (B,S,Hkv,hd).  Returns (B,S,Hkv,G,hd).

    Differentiable (``jax.custom_vjp`` recompute backward — see
    flash_attention.py); ``interpret``/``bq``/``bk`` resolve through the
    shared kernel infrastructure when None.
    """
    b, s, hkv, g, hd = q.shape
    hq = hkv * g
    qf = q.transpose(0, 2, 3, 1, 4).reshape(b * hq, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, hd)
    o = flash_attention_folded(qf, kf, vf, n_q_heads=hq, n_kv_heads=hkv,
                               causal=causal, window=window, scale=scale,
                               bq=bq, bk=bk, interpret=interpret,
                               autotune=autotune)
    return o.reshape(b, hkv, g, s, hd).transpose(0, 3, 1, 2, 4)


def _example(seed: int = 0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    b, s, hkv, g, hd = 1, 96, 2, 2, 32          # odd-length + GQA on purpose
    q = jax.random.normal(ks[0], (b, s, hkv, g, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, hd), jnp.float32)
    return q, k, v


common.register(common.KernelOp(
    name="flash_attention",
    pallas=lambda q, k, v: flash_attention(q, k, v, causal=True, window=64,
                                           scale=q.shape[-1] ** -0.5),
    ref=lambda q, k, v: ref.attention_ref(q, k, v, causal=True, window=64,
                                          scale=q.shape[-1] ** -0.5),
    example=_example,
    tuner=flash_blocks,
    tol=2e-4,
    grad_argnums=(0, 1, 2),
))
