from repro.kernels.decode_attention import ops, ref  # noqa: F401
