"""Public flash-decode op + registry entry.

Single-token decode attention over the ring KV cache, in the model's
(B, Hkv, G, hd) / (B, W, Hkv, hd) layout with per-row positions.  The
Pallas path is selected by ``KernelPolicy`` exactly like every other
kernel (``cfg.kernels.decode_attention`` or the global backend); the op
is registered ``differentiable=False`` — decode is inference-only and
the kernel deliberately carries no custom_vjp.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.decode_attention import ref
from repro.kernels.decode_attention.decode_attention import (
    decode_attention_pallas, decode_blocks)


def decode_attention(q, k, v, pos, *, window=None, scale=1.0,
                     impl: str = "pallas", bk: int = None,
                     interpret: bool = None, autotune: bool = None,
                     k_scale=None, v_scale=None, table=None):
    """q (B,Hkv,G,hd); k,v (B,W,Hkv,hd); pos (B,) int32 -> (B,Hkv,G,hd).
    ``k_scale``/``v_scale`` (B,W,Hkv) fp32: int8-quantized cache.

    ``table`` (B, cap/bs) int32: k/v are (NB, bs, Hkv, hd) block pools and
    the table maps each row's ring slots onto pool blocks.  The Pallas
    path indirects tiles through a second scalar-prefetch argument; the
    xla path dereferences the pool with a gather and runs the plain ref."""
    if impl == "xla":
        if table is not None:
            b = q.shape[0]
            cap = table.shape[1] * k.shape[1]
            k = k[table].reshape((b, cap) + k.shape[2:])
            v = v[table].reshape((b, cap) + v.shape[2:])
            if k_scale is not None:
                k_scale = k_scale[table].reshape(b, cap, -1)
                v_scale = v_scale[table].reshape(b, cap, -1)
        return ref.decode_attention_ref(q, k, v, pos, window=window,
                                        scale=scale, k_scale=k_scale,
                                        v_scale=v_scale)
    return decode_attention_pallas(q, k, v, pos, window=window, scale=scale,
                                   bk=bk, interpret=interpret,
                                   autotune=autotune, k_scale=k_scale,
                                   v_scale=v_scale, table=table)


def _example(seed: int = 0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    b, w, hkv, g, hd = 2, 40, 2, 2, 32        # odd capacity (pad path) + GQA
    q = jax.random.normal(ks[0], (b, hkv, g, hd))
    k = jax.random.normal(ks[1], (b, w, hkv, hd))
    v = jax.random.normal(ks[2], (b, w, hkv, hd))
    # one row mid-fill, one row wrapped past capacity
    pos = jnp.asarray([5, 97], jnp.int32)
    return q, k, v, pos


common.register(common.KernelOp(
    name="decode_attention",
    pallas=lambda q, k, v, pos: decode_attention_pallas(
        q, k, v, pos, window=32, scale=q.shape[-1] ** -0.5),
    ref=lambda q, k, v, pos: ref.decode_attention_ref(
        q, k, v, pos, window=32, scale=q.shape[-1] ** -0.5),
    example=_example,
    tuner=decode_blocks,
    tol=2e-4,
    differentiable=False,
))
