"""Pure-jnp oracle for single-token ring-cache decode attention.

Mirrors the slot arithmetic in ``models.attention.decode_attention``: the
ring cache of capacity W holds the last W absolute positions; slot ``i``
holds position ``pos - ((pos - i) mod W)`` and is valid iff that position
is >= 0 (and inside the sliding window when one is set).  ``pos`` is the
absolute position of the token being decoded, PER ROW — the continuous-
batching engine decodes slots at different depths in one call.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def slot_positions(pos, cap: int):
    """(B,) pos -> (B, W) absolute position held by each ring slot."""
    idx = jnp.arange(cap)
    return pos[:, None] - jnp.mod(pos[:, None] - idx[None, :], cap)


def decode_attention_ref(q, k, v, pos, *, window=None, scale=1.0,
                         k_scale=None, v_scale=None):
    """q (B,Hkv,G,hd) one token per row; k,v (B,W,Hkv,hd) ring cache AFTER
    the current token's K/V was written; pos (B,) int32.  Returns
    (B,Hkv,G,hd) float32-accumulated attention output in q.dtype.

    ``k_scale``/``v_scale`` (B,W,Hkv) fp32 mark an int8 cache: values
    dequantize as ``int8 * scale`` before the fp32 attention math."""
    cap = k.shape[1]
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    if k_scale is not None:
        k = k * k_scale.astype(jnp.float32)[..., None]
        v = v * v_scale.astype(jnp.float32)[..., None]
    sp = slot_positions(pos, cap)                       # (B, W)
    valid = sp >= 0
    if window is not None:
        valid &= sp > pos[:, None] - window
    s = jnp.einsum("bhgk,bshk->bhgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = jnp.where(valid[:, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgs,bshk->bhgk", p,
                      v.astype(jnp.float32)).astype(q.dtype)
