"""Pallas TPU flash-decode: one query token per sequence against a ring
KV cache, with GQA group-sum and sliding-window masking.

The decode hot loop is memory-bound — one (G, hd) query tile sweeps the
(W, hd) cache — so unlike the training flash kernel there is no (S, S)
anything and no backward pass: this op is deliberately custom-vjp-free
(``jax.grad`` through it is a usage error; training uses
``kernels.flash_attention``).

Layout: q is folded to (B*Hkv, G, hd) with the G query heads of one KV
head as MXU rows (padded to the fp32 sublane count); the cache folds to
(B*Hkv, W, hd).  Grid is (B*Hkv, W/bk) with the KV axis ``arbitrary``
(sequential) carrying the online-softmax stats in VMEM scratch.

Per-row positions ride in as a scalar-prefetch argument
(``pltpu.PrefetchScalarGridSpec``): each grid row reads its own ``pos``
to build the ring-validity mask in-kernel — slot ``c`` holds absolute
position ``pos - ((pos - c) mod W)``, valid iff >= 0 and inside the
window.  Tiles that cannot contain a valid slot (the unwritten tail of a
not-yet-full ring, or the zero-padded cache tail) skip their MXU work
via ``pl.when``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import SUBLANE, pow2_clip, resolve_interpret

# jax 0.4.x names it TPUCompilerParams; newer jax renames to CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, *refs, bk: int, gp: int,
                   window, scale: float, n_k: int, n_kv_heads: int,
                   cap: int, quant: bool):
    if quant:
        # int8 cache: per-slot fp32 scales ride as two extra (1, bk) blocks
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = refs
    else:
        o_ref, m_scr, l_scr, acc_scr = refs
    i = pl.program_id(0)                   # b * Hkv + kv-head
    ki = pl.program_id(1)
    p = pos_ref[i // n_kv_heads]           # this row's absolute position

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # a tile is visible iff it can hold a valid slot: the ring has been
    # written up to slot p while p < cap (so tiles past p are untouched
    # zeros), and every slot holds a live position once the ring wrapped
    visible = (ki * bk < cap) & ((ki * bk <= p) | (p >= cap))

    @pl.when(visible)
    def _accumulate():
        q = q_ref[0].astype(jnp.float32)                   # (gp, hd)
        k = k_ref[0].astype(jnp.float32)                   # (bk, hd)
        v = v_ref[0].astype(jnp.float32)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if quant:
            # dequantize in the score domain: s[g,c] needs k[c]*ks[c], and
            # q.k_int scaled per COLUMN is a free (1, bk) lane broadcast —
            # no (bk, 1) relayout of the cache tile
            s = s * ks_ref[...]
        cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (gp, bk), 1)
        slot_pos = p - jnp.mod(p - cols, cap)
        mask = (cols < cap) & (slot_pos >= 0)
        if window is not None:
            mask &= slot_pos > p - window
        s = jnp.where(mask, s, NEG)

        m_prev = m_scr[...]                                # (gp, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        pexp = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(pexp, axis=1,
                                                  keepdims=True)
        if quant:
            # v dequant folds the same way: (pexp @ diag(vs)) @ v_int
            pexp = pexp * vs_ref[...]
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            pexp.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_kv_heads", "window", "scale",
                                             "bk", "interpret", "cap"))
def _decode_impl(q, k, v, ks, vs, pos, n_kv_heads, window, scale, bk,
                 interpret, cap):
    """Folded padded inputs: q (B*Hkv, gp, hd), k/v (B*Hkv, Wp, hd),
    ks/vs (B*Hkv, Wp) fp32 dequant scales or None (unquantized cache),
    pos (B,) int32 -> o (B*Hkv, gp, hd)."""
    bh, gp, hd = q.shape
    wp = k.shape[1]
    assert wp % bk == 0, (wp, bk)
    n_k = wp // bk
    quant = ks is not None

    in_specs = [pl.BlockSpec((1, gp, hd), lambda i, ki, pos_ref: (i, 0, 0)),
                pl.BlockSpec((1, bk, hd), lambda i, ki, pos_ref: (i, ki, 0)),
                pl.BlockSpec((1, bk, hd), lambda i, ki, pos_ref: (i, ki, 0))]
    args = [q, k, v]
    if quant:
        in_specs += [pl.BlockSpec((1, bk), lambda i, ki, pos_ref: (i, ki)),
                     pl.BlockSpec((1, bk), lambda i, ki, pos_ref: (i, ki))]
        args += [ks, vs]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bh, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, gp, hd), lambda i, ki, pos_ref: (i, 0, 0)),
        scratch_shapes=[pltpu.VMEM((gp, 1), jnp.float32),
                        pltpu.VMEM((gp, 1), jnp.float32),
                        pltpu.VMEM((gp, hd), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_decode_kernel, bk=bk, gp=gp, window=window,
                          scale=scale, n_k=n_k, n_kv_heads=n_kv_heads,
                          cap=cap, quant=quant),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bh, gp, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(pos, *args)


def _decode_kernel_table(pos_ref, tab_ref, *refs, **kw):
    # table mode: the block table is consumed by the BlockSpec index maps
    # (scalar prefetch); the in-kernel math is identical — logical column
    # ki*bk + j IS ring slot position, wherever the bytes physically live
    _decode_kernel(pos_ref, *refs, **kw)


@functools.partial(jax.jit, static_argnames=("n_kv_heads", "window", "scale",
                                             "interpret"))
def _decode_impl_table(q, k, v, ks, vs, pos, table, n_kv_heads, window,
                      scale, interpret):
    """Block-pool variant: q (B*Hkv, gp, hd); k/v (NB*Hkv, bs, hd) pools
    folded like the ring layout; table (B, cap/bs) int32 block ids;
    ks/vs (NB*Hkv, bs) fp32 or None.  The KV tile size IS the block size
    (one pool block per grid step), and the tile for grid row i, step ki
    is fetched from folded row ``table[i // Hkv, ki] * Hkv + i % Hkv`` —
    slot indirection via the second scalar-prefetch argument."""
    bh, gp, hd = q.shape
    bs = k.shape[1]
    n_k = table.shape[1]
    cap = n_k * bs
    quant = ks is not None

    def kvmap(i, ki, pos_ref, tab_ref):
        return (tab_ref[i // n_kv_heads, ki] * n_kv_heads
                + i % n_kv_heads, 0, 0)

    def scmap(i, ki, pos_ref, tab_ref):
        return (tab_ref[i // n_kv_heads, ki] * n_kv_heads
                + i % n_kv_heads, 0)

    in_specs = [pl.BlockSpec((1, gp, hd),
                             lambda i, ki, pos_ref, tab_ref: (i, 0, 0)),
                pl.BlockSpec((1, bs, hd), kvmap),
                pl.BlockSpec((1, bs, hd), kvmap)]
    args = [q, k, v]
    if quant:
        in_specs += [pl.BlockSpec((1, bs), scmap),
                     pl.BlockSpec((1, bs), scmap)]
        args += [ks, vs]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bh, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, gp, hd),
                               lambda i, ki, pos_ref, tab_ref: (i, 0, 0)),
        scratch_shapes=[pltpu.VMEM((gp, 1), jnp.float32),
                        pltpu.VMEM((gp, 1), jnp.float32),
                        pltpu.VMEM((gp, hd), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_decode_kernel_table, bk=bs, gp=gp, window=window,
                          scale=scale, n_k=n_k, n_kv_heads=n_kv_heads,
                          cap=cap, quant=quant),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bh, gp, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(pos, table, *args)


def decode_blocks(cap: int, hd: int, dtype, *, interpret: bool,
                  autotune: bool = None, kv_dtype=None):
    """(bk,) KV tile size, shared-autotuned on compiled backends.

    ``kv_dtype`` widens the cache key when the ring cache's storage dtype
    differs from the query's (bf16/int8 KV under a NumericsPolicy): tile
    timing depends on the bytes swept, so mixed-dtype calls must not
    share winners with same-dtype ones."""
    from repro.kernels import common
    default = (pow2_clip(cap, 128),)
    dt_key = str(dtype) if kv_dtype is None else (str(dtype), str(kv_dtype))
    key = ("decode_attn", cap, hd, dt_key)
    if not common.autotune_enabled(interpret, autotune):
        return common.autotune(key, [default], None)
    cands = {default} | {(bk,) for bk in (64, 128, 256)
                         if bk <= pow2_clip(cap, 256)}
    import numpy as np
    rng = np.random.default_rng(0)
    q = rng.normal(size=(4, 2, 4, hd)).astype(dtype)
    kwargs = {}
    if kv_dtype is not None and jnp.dtype(kv_dtype) == jnp.int8:
        kv = rng.integers(-127, 128, size=(4, cap, 2, hd)).astype(np.int8)
        sc = (np.abs(rng.normal(size=(4, cap, 2))) + 1e-3).astype(np.float32)
        kwargs = {"k_scale": sc, "v_scale": sc}
    else:
        kv = rng.normal(size=(4, cap, 2, hd)).astype(kv_dtype or dtype)
    pos = np.full((4,), cap - 1, np.int32)

    def measure(c):
        return common.time_call(
            lambda: decode_attention_pallas(
                q, kv, kv, pos, scale=hd ** -0.5, bk=c[0], interpret=False,
                **kwargs))
    return common.autotune(key, sorted(cands), measure)


def decode_attention_pallas(q, k, v, pos, *, window=None, scale=1.0,
                            bk: int = None, interpret: bool = None,
                            autotune: bool = None, k_scale=None,
                            v_scale=None, table=None):
    """q (B,Hkv,G,hd); k,v (B,W,Hkv,hd) ring cache; pos (B,) int32.
    ``k_scale``/``v_scale`` (B,W,Hkv) fp32 mark an int8-quantized cache —
    dequantized in-kernel (see ``_decode_kernel``).

    ``table`` (B, cap/bs) int32 switches k/v to BLOCK-POOL layout
    (NB, bs, Hkv, hd) — row b's ring slot s lives in
    ``pool[table[b, s//bs], s%bs]``; the KV tile size becomes the block
    size and the table rides as a second scalar-prefetch argument.

    Returns (B,Hkv,G,hd).  NOT differentiable (inference fast path).
    """
    b, hkv, g, hd = q.shape
    interpret = resolve_interpret(interpret)
    if table is not None:
        bs = k.shape[1]
        gp = -(-g // SUBLANE) * SUBLANE
        qf = q.reshape(b * hkv, g, hd)
        if gp != g:
            qf = jnp.pad(qf, ((0, 0), (0, gp - g), (0, 0)))
        kf = k.transpose(0, 2, 1, 3).reshape(-1, bs, hd)
        vf = v.transpose(0, 2, 1, 3).reshape(-1, bs, hd)
        ksf = vsf = None
        if k_scale is not None:
            ksf = jnp.asarray(k_scale, jnp.float32).transpose(0, 2, 1) \
                .reshape(-1, bs)
            vsf = jnp.asarray(v_scale, jnp.float32).transpose(0, 2, 1) \
                .reshape(-1, bs)
        o = _decode_impl_table(qf, kf, vf, ksf, vsf,
                               jnp.asarray(pos, jnp.int32),
                               jnp.asarray(table, jnp.int32),
                               hkv, window, scale, interpret)
        return o[:, :g].reshape(b, hkv, g, hd)
    cap = k.shape[1]
    if bk is None:
        kvd = None if k.dtype == q.dtype else k.dtype
        (bk,) = decode_blocks(cap, hd, q.dtype, interpret=interpret,
                              autotune=autotune, kv_dtype=kvd)
    bk = min(bk, pow2_clip(cap, bk))
    gp = -(-g // SUBLANE) * SUBLANE
    qf = q.reshape(b * hkv, g, hd)
    if gp != g:
        qf = jnp.pad(qf, ((0, 0), (0, gp - g), (0, 0)))
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, cap, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, cap, hd)
    wp = -(-cap // bk) * bk
    if wp != cap:
        pad = ((0, 0), (0, wp - cap), (0, 0))
        kf, vf = jnp.pad(kf, pad), jnp.pad(vf, pad)
    ksf = vsf = None
    if k_scale is not None:
        ksf = jnp.asarray(k_scale, jnp.float32).transpose(0, 2, 1) \
            .reshape(b * hkv, cap)
        vsf = jnp.asarray(v_scale, jnp.float32).transpose(0, 2, 1) \
            .reshape(b * hkv, cap)
        if wp != cap:
            ksf = jnp.pad(ksf, ((0, 0), (0, wp - cap)))
            vsf = jnp.pad(vsf, ((0, 0), (0, wp - cap)))
    o = _decode_impl(qf, kf, vf, ksf, vsf, jnp.asarray(pos, jnp.int32),
                     hkv, window, scale, bk, interpret, cap)
    return o[:, :g].reshape(b, hkv, g, hd)
