"""Block-size autotuning for the conv2d kernels.

The shared machinery (``resolve_interpret``, the process-level autotune
cache, measurement helpers) lives in ``repro.kernels.common`` — this
module was its first client and now only contributes the conv-specific
tuners.  ``resolve_interpret`` / ``autotune`` / ``clear_cache`` /
``cache_info`` are re-exported for back-compat with PR-2-era callers.

``matmul_blocks`` / ``conv_blocks``: ``(bm, bk, bn)`` were fixed at 128³
regardless of problem shape.  Block sizes come from the shared
shape-keyed autotune cache: for each distinct problem shape the
candidate blockings are measured once (compiled backends only — timing
the interpreter is meaningless) and the winner is memoised for the rest
of the process.  Tiny problems get clipped blocks instead of padding
everything up to 128.  ``REPRO_CONV_AUTOTUNE=0`` (or the global
``REPRO_PALLAS_AUTOTUNE=0``) disables measurement and always returns the
heuristic default.
"""
from __future__ import annotations

import os
from typing import Tuple

from repro.kernels import common
from repro.kernels.common import (LANE, autotune_enabled, cache_info,
                                  clear_cache, pow2_clip, resolve_interpret,
                                  time_call)

# re-export (PR-2 name): the cache entry point — NOT the ``autotune=``
# override parameter the block tuners take below
autotune = common.autotune

__all__ = ["resolve_interpret", "autotune", "clear_cache", "cache_info",
           "matmul_blocks", "conv_blocks"]

_pow2_clip = pow2_clip           # back-compat aliases (PR-2 private names)
_time_call = time_call
_LANE = LANE


def _autotune_enabled(interpret: bool, override: bool = None) -> bool:
    # a policy override beats the env switches; legacy env wins otherwise
    if override is None and os.environ.get("REPRO_CONV_AUTOTUNE") == "0":
        return False
    return autotune_enabled(interpret, override)


def matmul_blocks(m: int, k: int, n: int, dtype, *, interpret: bool,
                  autotune: bool = None,
                  w_dtype=None) -> Tuple[int, int, int]:
    """(bm, bk, bn) for the blocked matmul; autotuned on compiled backends.

    ``w_dtype`` widens the cache key to the (x, w) dtype tuple when the
    operands differ (a NumericsPolicy mixing precisions): winners are
    measured per byte-traffic profile, so mixed-dtype problems must not
    share entries with same-dtype ones."""
    default = (pow2_clip(m, LANE), pow2_clip(k, LANE), pow2_clip(n, LANE))
    dt_key = str(dtype) if w_dtype is None else (str(dtype), str(w_dtype))
    key = ("matmul", m, k, n, dt_key)
    if not _autotune_enabled(interpret, autotune):
        return common.autotune(key, [default], None)

    cands = {default}
    for bm in (128, 256, 512):
        for bn in (128, 256):
            if bm <= 2 * m and bn <= 2 * n:
                cands.add((bm, pow2_clip(min(k, LANE), LANE), bn))
    import numpy as np
    from repro.kernels.conv2d import conv2d as _k
    x = np.random.default_rng(0).normal(size=(m, k)).astype(dtype)
    w = np.random.default_rng(1).normal(size=(k, n)).astype(w_dtype or dtype)
    b = np.zeros((n,), w_dtype or dtype)

    def measure(c):
        bm, bk, bn = c
        return time_call(
            lambda: _k.matmul_bias(x, w, b, bm=bm, bk=bk, bn=bn,
                                   interpret=False))
    return common.autotune(key, sorted(cands), measure)


# fused conv tiles M = OH*OW; a larger cap than the matmul's 128 keeps
# the in-kernel whole-image window gather from being repeated across
# many M-tiles (each M-tile program re-gathers the windows it slices)
_CONV_BM_CAP = 512


def conv_blocks(b: int, oh: int, ow: int, kernel: int, cin: int, cout: int,
                stride: int, dtype, *, groups: int = 1, interpret: bool,
                autotune: bool = None, w_dtype=None) -> Tuple[int, int]:
    """(bm, bn) for the fused implicit-GEMM conv (reduction is unrolled
    in-kernel, so there is no bk).  ``groups`` is part of the cache key —
    a grouped layer tiles N per diagonal block (Cout/G wide), so its
    winning blocking is NOT the ungrouped layer's — and bn defaults to
    the per-group output width, never a whole-Cout tile.  ``w_dtype``
    widens the key to the (x, w) dtype tuple for mixed-precision calls
    (see ``matmul_blocks``)."""
    m = oh * ow
    default = (pow2_clip(m, _CONV_BM_CAP), pow2_clip(cout // groups, LANE))
    dt_key = str(dtype) if w_dtype is None else (str(dtype), str(w_dtype))
    key = ("conv", b, oh, ow, kernel, cin, cout, stride, groups, dt_key)
    if not _autotune_enabled(interpret, autotune):
        return common.autotune(key, [default], None)

    cands = {default}
    for bm in (128, 256, 512):
        if bm <= 2 * m:
            cands.add((bm, default[1]))
    import numpy as np
    from repro.kernels.conv2d import ops as _ops
    h = (oh - 1) * stride + kernel
    w_sz = (ow - 1) * stride + kernel
    x = np.random.default_rng(0).normal(size=(b, h, w_sz, cin)).astype(dtype)
    wt = np.random.default_rng(1).normal(
        size=(kernel, kernel, cin // groups, cout)).astype(w_dtype or dtype)

    def measure(c):
        bm, bn = c
        return time_call(
            lambda: _ops.conv2d_fused(x, wt, stride=stride, padding=0,
                                      groups=groups, bm=bm, bn=bn,
                                      interpret=False))
    return common.autotune(key, sorted(cands), measure)
