"""Backend resolution + block-size autotuning for the conv2d kernels.

Two jobs, both previously hardcoded at call sites:

1. ``resolve_interpret``: the Pallas kernels ran with ``interpret=True``
   unconditionally — i.e. the "fast path" was always the Python
   interpreter.  Now ``interpret=None`` means "compile when the backend
   can" (TPU), falling back to interpret mode on CPU/GPU hosts.  Override
   with ``REPRO_PALLAS_INTERPRET=0|1`` for debugging.

2. ``matmul_blocks`` / ``conv_blocks``: ``(bm, bk, bn)`` were fixed at
   128³ regardless of problem shape.  Now block sizes come from a small
   shape-keyed autotune cache: for each distinct problem shape the
   candidate blockings are measured once (compiled backends only — timing
   the interpreter is meaningless) and the winner is memoised for the
   rest of the process.  Tiny problems get clipped blocks instead of
   padding everything up to 128.  ``REPRO_CONV_AUTOTUNE=0`` disables
   measurement and always returns the heuristic default.

The cache is process-local by design: block choice depends on the
hardware the process is on, and a step function traces each conv shape
exactly once, so one measurement per shape amortises to zero.
"""
from __future__ import annotations

import os
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax

# shape-keyed winner cache: key -> blocks tuple
_CACHE: Dict[tuple, tuple] = {}
# how many measured autotune sweeps ran (introspection / tests)
_STATS = {"measured": 0, "hits": 0}

_SUBLANE = 8          # TPU fp32 sublane count — block floor
_LANE = 128           # TPU lane count — preferred alignment


def resolve_interpret(interpret=None) -> bool:
    """Resolve the tri-state ``interpret`` flag.

    None  -> auto: compile on TPU, interpret elsewhere (the pinned
             kernels are Mosaic/TPU programs; CPU runs them through the
             Pallas interpreter for correctness work).
    bool  -> honoured as given (tests force both modes).
    Env   -> REPRO_PALLAS_INTERPRET=0|1 overrides auto-detection only.
    """
    if interpret is not None:
        return bool(interpret)
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def _pow2_clip(dim: int, cap: int) -> int:
    """Smallest power of two >= dim, clipped to [_SUBLANE, cap]."""
    p = 1 << max(dim - 1, 0).bit_length()
    return max(min(p, cap), _SUBLANE)


def clear_cache() -> None:
    _CACHE.clear()
    _STATS["measured"] = _STATS["hits"] = 0


def cache_info() -> dict:
    return {"entries": len(_CACHE), **_STATS}


def autotune(key: tuple, candidates: Sequence[tuple],
             measure: Optional[Callable[[tuple], float]]) -> tuple:
    """Return the cached winner for ``key``, measuring once on a miss.

    ``measure(candidate) -> seconds``; exceptions disqualify a candidate
    (e.g. a blocking the compiler rejects) rather than failing the tune.
    A single candidate is cached without measuring (``measure`` may be
    None then).
    """
    if key in _CACHE:
        _STATS["hits"] += 1
        return _CACHE[key]
    best, best_t = candidates[0], float("inf")
    if len(candidates) > 1:
        _STATS["measured"] += 1
        for cand in candidates:
            try:
                t = measure(cand)
            except Exception:
                continue
            if t < best_t:
                best, best_t = cand, t
    _CACHE[key] = best
    return best


def _time_call(fn, *args, iters: int = 3) -> float:
    jax.block_until_ready(fn(*args))          # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _autotune_enabled(interpret: bool) -> bool:
    # interpreter timings reflect Python overhead, not the MXU — skip
    return (not interpret
            and os.environ.get("REPRO_CONV_AUTOTUNE", "1") != "0")


def matmul_blocks(m: int, k: int, n: int, dtype, *,
                  interpret: bool) -> Tuple[int, int, int]:
    """(bm, bk, bn) for the blocked matmul; autotuned on compiled backends."""
    default = (_pow2_clip(m, _LANE), _pow2_clip(k, _LANE),
               _pow2_clip(n, _LANE))
    key = ("matmul", m, k, n, str(dtype))
    if not _autotune_enabled(interpret):
        return autotune(key, [default], None)

    cands = {default}
    for bm in (128, 256, 512):
        for bn in (128, 256):
            if bm <= 2 * m and bn <= 2 * n:
                cands.add((bm, _pow2_clip(min(k, _LANE), _LANE), bn))
    import numpy as np
    from repro.kernels.conv2d import conv2d as _k
    x = np.random.default_rng(0).normal(size=(m, k)).astype(dtype)
    w = np.random.default_rng(1).normal(size=(k, n)).astype(dtype)
    b = np.zeros((n,), dtype)

    def measure(c):
        bm, bk, bn = c
        return _time_call(
            lambda: _k.matmul_bias(x, w, b, bm=bm, bk=bk, bn=bn,
                                   interpret=False))
    return autotune(key, sorted(cands), measure)


# fused conv tiles M = OH*OW; a larger cap than the matmul's 128 keeps
# the in-kernel whole-image window gather from being repeated across
# many M-tiles (each M-tile program re-gathers the windows it slices)
_CONV_BM_CAP = 512


def conv_blocks(b: int, oh: int, ow: int, kernel: int, cin: int, cout: int,
                stride: int, dtype, *, interpret: bool) -> Tuple[int, int]:
    """(bm, bn) for the fused implicit-GEMM conv (reduction is unrolled
    in-kernel, so there is no bk)."""
    m = oh * ow
    default = (_pow2_clip(m, _CONV_BM_CAP), _pow2_clip(cout, _LANE))
    key = ("conv", b, oh, ow, kernel, cin, cout, stride, str(dtype))
    if not _autotune_enabled(interpret):
        return autotune(key, [default], None)

    cands = {default}
    for bm in (128, 256, 512):
        if bm <= 2 * m:
            cands.add((bm, default[1]))
    import numpy as np
    from repro.kernels.conv2d import ops as _ops
    h = (oh - 1) * stride + kernel
    w_sz = (ow - 1) * stride + kernel
    x = np.random.default_rng(0).normal(size=(b, h, w_sz, cin)).astype(dtype)
    wt = np.random.default_rng(1).normal(
        size=(kernel, kernel, cin, cout)).astype(dtype)

    def measure(c):
        bm, bn = c
        return _time_call(
            lambda: _ops.conv2d_fused(x, wt, stride=stride, padding=0,
                                      bm=bm, bn=bn, interpret=False))
    return autotune(key, sorted(cands), measure)
