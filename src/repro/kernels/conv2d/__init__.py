from repro.kernels.conv2d import ops, ref  # noqa: F401
