from repro.kernels.conv2d import ops, ref, tune  # noqa: F401
