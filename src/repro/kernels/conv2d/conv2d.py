"""Pallas TPU blocked matmul — the MXU half of the im2col convolution.

The paper's hot-spot is convolution, and its Table 1 compares conv backends
(cuda-convnet vs cuDNN R1/R2).  The TPU-native adaptation is NOT a direct
port of either CUDA kernel: on TPU, convolution is lowered to im2col patch
extraction + a systolic-array matmul.  This kernel is that matmul — blocked
(bm, bk) x (bk, bn) tiles staged through VMEM with an fp32 accumulator
carried across the K grid axis, bias add + optional ReLU fused into the
final tile write (mirroring cuDNN's fused epilogue).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names it TPUCompilerParams; newer jax renames to CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, acc_scr, *, n_k: int,
                   relu: bool):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot(
        x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _epilogue():
        y = acc_scr[...] + b_ref[...].astype(jnp.float32)
        if relu:
            y = jnp.maximum(y, 0.0)
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "relu",
                                             "interpret"))
def matmul_bias(x, w, b, *, bm: int = 128, bk: int = 128, bn: int = 128,
                relu: bool = False, interpret: bool = True):
    """(M,K) @ (K,N) + b(N,) with fused epilogue.  Pads to block multiples."""
    m, k = x.shape
    _, n = w.shape
    mp = -(-m // bm) * bm
    kp = -(-k // bk) * bk
    np_ = -(-n // bn) * bn
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    bp = jnp.pad(b, (0, np_ - n))[None, :]

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=kp // bk, relu=relu),
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                  pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
                  pl.BlockSpec((1, bn), lambda i, j, kk: (0, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xp, wp, bp)
    return out[:m, :n]
