"""Pallas TPU conv kernels — the MXU half of the paper's hot path.

The paper's hot-spot is convolution, and its Table 1 compares conv
backends (cuda-convnet vs cuDNN R1/R2).  The TPU-native adaptation has
two Pallas programs:

``matmul_bias``
    Blocked (bm, bk) x (bk, bn) matmul staged through VMEM with an fp32
    accumulator carried across the K grid axis, bias add + optional ReLU
    fused into the final tile write (mirroring cuDNN's fused epilogue).
    This is the GEMM stage of the *reference* two-stage im2col path.

``conv2d_fused``
    Implicit-GEMM convolution: the grid walks (batch, M-tile, N-tile)
    output tiles and each program instance gathers its input window
    slices directly from the VMEM-staged ``(H, W, C)`` operand — the
    ``(B*OH*OW, K*K*C)`` im2col patch tensor never exists in HBM.  The
    K*K reduction is unrolled in-kernel as static strided slices feeding
    the MXU, so all three grid axes are parallel.

Both are differentiable via ``jax.custom_vjp`` (Pallas calls have no
automatic AD): ``matmul_bias`` back-propagates through two more Pallas
matmuls; ``conv2d_fused`` lowers its backward to XLA's conv-transpose
kernels via ``jax.linear_transpose`` (no recomputed forward).

``interpret``/block sizes are resolved by ``tune.py``: ``interpret=None``
auto-compiles on TPU and interprets elsewhere; ``bm/bk/bn=None`` come
from the shape-keyed autotune cache.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.conv2d import tune

# jax 0.4.x names it TPUCompilerParams; newer jax renames to CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


# ---------------------------------------------------------------------------
# blocked matmul + fused bias/ReLU epilogue (im2col reference GEMM stage)
# ---------------------------------------------------------------------------

def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, acc_scr, *, n_k: int,
                   relu: bool):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot(
        x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _epilogue():
        y = acc_scr[...] + b_ref[...].astype(jnp.float32)
        if relu:
            y = jnp.maximum(y, 0.0)
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "relu",
                                             "interpret"))
def _matmul_bias_impl(x, w, b, bm, bk, bn, relu, interpret):
    m, k = x.shape
    _, n = w.shape
    mp = -(-m // bm) * bm
    kp = -(-k // bk) * bk
    np_ = -(-n // bn) * bn
    # pad only what is misaligned — aligned calls must not pay HBM copies
    xp = x if (mp == m and kp == k) else jnp.pad(x, ((0, mp - m),
                                                     (0, kp - k)))
    wp = w if (kp == k and np_ == n) else jnp.pad(w, ((0, kp - k),
                                                      (0, np_ - n)))
    bp = (b if np_ == n else jnp.pad(b, (0, np_ - n)))[None, :]

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=kp // bk, relu=relu),
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                  pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
                  pl.BlockSpec((1, bn), lambda i, j, kk: (0, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xp, wp, bp)
    return out if (mp == m and np_ == n) else out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _matmul_bias_core(x, w, b, bm, bk, bn, relu, interpret):
    return _matmul_bias_impl(x, w, b, bm, bk, bn, relu, interpret)


def _matmul_bias_fwd(x, w, b, bm, bk, bn, relu, interpret):
    y = _matmul_bias_impl(x, w, b, bm, bk, bn, relu, interpret)
    return y, (x, w, b, y)


def _matmul_bias_bwd(bm, bk, bn, relu, interpret, res, dy):
    x, w, b, y = res
    if relu:
        dy = dy * (y > 0).astype(dy.dtype)
    db = dy.sum(0).astype(b.dtype)
    # dx = dy @ w.T, dw = x.T @ dy — same MXU kernel, permuted blockings
    zk = jnp.zeros((x.shape[1],), dy.dtype)
    zn = jnp.zeros((dy.shape[1],), dy.dtype)
    dx = _matmul_bias_impl(dy, w.T, zk, bm, bn, bk, False, interpret)
    dw = _matmul_bias_impl(x.T, dy, zn, bk, bm, bn, False, interpret)
    return dx.astype(x.dtype), dw.astype(w.dtype), db


_matmul_bias_core.defvjp(_matmul_bias_fwd, _matmul_bias_bwd)


def matmul_bias(x, w, b, *, bm: int = None, bk: int = None, bn: int = None,
                relu: bool = False, interpret: bool = None,
                autotune: bool = None):
    """(M,K) @ (K,N) + b(N,) with fused bias/ReLU epilogue.

    ``interpret=None`` auto-resolves (compiled on TPU); ``bm/bk/bn=None``
    come from the autotune cache (``autotune`` overrides measurement).
    Differentiable.
    """
    interpret = tune.resolve_interpret(interpret)
    if bm is None or bk is None or bn is None:
        m, k = x.shape
        n = w.shape[1]
        wd = None if w.dtype == x.dtype else w.dtype
        tbm, tbk, tbn = tune.matmul_blocks(m, k, n, x.dtype,
                                           interpret=interpret,
                                           autotune=autotune, w_dtype=wd)
        bm, bk, bn = bm or tbm, bk or tbk, bn or tbn
    return _matmul_bias_core(x, w, b, bm, bk, bn, relu, interpret)


# ---------------------------------------------------------------------------
# fused implicit-GEMM convolution
# ---------------------------------------------------------------------------

def _conv_fused_kernel(x_ref, w_ref, b_ref, o_ref, *, kernel: int,
                       stride: int, oh: int, ow: int, m_pad: int,
                       relu: bool):
    """One (batch b, M-tile i, group-walking N-tile j) output tile.

    x_ref (1, Hp, Wp, 1, Cg) — ONE group's input-channel slice of the
    padded image, staged in VMEM (the BlockSpec index map picks the
    group, so a program never sees the other groups' channels);
    w_ref (1, K*K*Cg, bn) — that group's weight slab; b_ref (1, 1, bn);
    o_ref (1, bm, 1, bn).

    Patch rows are gathered on the fly: for each static kernel offset
    (kh, kw) the strided window slice of the image IS the (M, Cg) slab
    of the im2col matrix belonging to that offset, so the reduction is
    K*K unrolled (bm, Cg) @ (Cg, bn) MXU dots — implicit GEMM.  Grouped
    convolution is just the N axis walking block-diagonal tiles: the
    grid's j axis enumerates (group, in-group N-tile) pairs and the
    index maps route each j to its diagonal block — no per-group Python
    loop, no HBM blowup.
    """
    i = pl.program_id(1)
    xv = x_ref[0, :, :, 0, :]
    c = xv.shape[-1]
    bm, bn = o_ref.shape[1], o_ref.shape[3]
    span_h = (oh - 1) * stride + 1
    span_w = (ow - 1) * stride + 1
    acc = jnp.zeros((bm, bn), jnp.float32)
    for kh in range(kernel):
        for kw in range(kernel):
            xs = xv[kh:kh + span_h:stride, kw:kw + span_w:stride, :]
            xs = xs.reshape(oh * ow, c)
            if m_pad != oh * ow:
                xs = jnp.pad(xs, ((0, m_pad - oh * ow), (0, 0)))
            blk = jax.lax.dynamic_slice_in_dim(xs, i * bm, bm, 0)
            q = kh * kernel + kw
            acc += jax.lax.dot(
                blk.astype(jnp.float32),
                w_ref[0, q * c:(q + 1) * c, :].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    y = acc + b_ref[0].astype(jnp.float32)
    if relu:
        y = jnp.maximum(y, 0.0)
    o_ref[0, :, 0, :] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("kernel", "stride", "oh", "ow",
                                             "bm", "bn", "relu", "groups",
                                             "interpret"))
def _conv_fused_impl(x, w, bias, kernel, stride, oh, ow, bm, bn, relu,
                     groups, interpret):
    b_, hp, wp, cin = x.shape
    cout = w.shape[-1]
    cig = cin // groups          # input channels per group
    npg = cout // groups         # output channels per group
    m = oh * ow
    m_pad = -(-m // bm) * bm
    npg_pad = -(-npg // bn) * bn     # pad per group so bn tiles never
    kkc = kernel * kernel * cig      # straddle a group boundary
    # (K,K,Cg,Cout) -> (G, K*K*Cg, npg) stacked per-group weight slabs;
    # Cout is group-major (group g owns channels [g*npg, (g+1)*npg))
    wmat = w.reshape(kkc, groups, npg).transpose(1, 0, 2)
    bvec = bias.reshape(groups, 1, npg)
    if npg_pad != npg:
        wmat = jnp.pad(wmat, ((0, 0), (0, 0), (0, npg_pad - npg)))
        bvec = jnp.pad(bvec, ((0, 0), (0, 0), (0, npg_pad - npg)))
    xg = x.reshape(b_, hp, wp, groups, cig)
    tiles_pg = npg_pad // bn         # N-tiles per diagonal block

    out = pl.pallas_call(
        functools.partial(_conv_fused_kernel, kernel=kernel, stride=stride,
                          oh=oh, ow=ow, m_pad=m_pad, relu=relu),
        grid=(b_, m_pad // bm, groups * tiles_pg),
        in_specs=[
            pl.BlockSpec((1, hp, wp, 1, cig),
                         lambda b, i, j: (b, 0, 0, j // tiles_pg, 0)),
            pl.BlockSpec((1, kkc, bn),
                         lambda b, i, j: (j // tiles_pg, 0, j % tiles_pg)),
            pl.BlockSpec((1, 1, bn),
                         lambda b, i, j: (j // tiles_pg, 0, j % tiles_pg)),
        ],
        out_specs=pl.BlockSpec(
            (1, bm, 1, bn),
            lambda b, i, j: (b, i, j // tiles_pg, j % tiles_pg)),
        out_shape=jax.ShapeDtypeStruct((b_, m_pad, groups, npg_pad),
                                       x.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(xg, wmat, bvec)
    out = out if (m_pad == m and npg_pad == npg) else out[:, :m, :, :npg]
    return out.reshape(b_, oh, ow, cout)


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8, 9, 10, 11))
def _conv_fused_core(x, w, bias, kernel, stride, oh, ow, bm, bn, relu,
                     groups, interpret):
    return _conv_fused_impl(x, w, bias, kernel, stride, oh, ow, bm, bn,
                            relu, groups, interpret)


def _conv_fused_fwd(x, w, bias, kernel, stride, oh, ow, bm, bn, relu,
                    groups, interpret):
    y = _conv_fused_impl(x, w, bias, kernel, stride, oh, ow, bm, bn, relu,
                         groups, interpret)
    return y, (x, w, bias, y)


def _conv_fused_bwd(kernel, stride, oh, ow, bm, bn, relu, groups, interpret,
                    res, dy):
    x, w, bias, y = res
    if relu:
        dy = dy * (y > 0).astype(dy.dtype)
    db = dy.sum((0, 1, 2)).astype(bias.dtype)
    # conv is bilinear: each partial is the transpose of a linear map, so
    # XLA's conv-grad kernels fall out of linear_transpose with no
    # recomputed forward (x was padded by the caller; padding=VALID here).
    # feature_group_count keeps the backward block-diagonal too: dx and dw
    # for one group never read the other groups' cotangents.
    dyf = dy.astype(jnp.float32)

    def conv_x(x_):
        return jax.lax.conv_general_dilated(
            x_, w.astype(jnp.float32), (stride, stride), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=groups)

    def conv_w(w_):
        return jax.lax.conv_general_dilated(
            x.astype(jnp.float32), w_, (stride, stride), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=groups)

    dx, = jax.linear_transpose(conv_x, x.astype(jnp.float32))(dyf)
    dw, = jax.linear_transpose(conv_w, w.astype(jnp.float32))(dyf)
    return dx.astype(x.dtype), dw.astype(w.dtype), db


_conv_fused_core.defvjp(_conv_fused_fwd, _conv_fused_bwd)


def conv2d_fused(x, w, *, stride: int, padding: int, bias=None,
                 relu: bool = False, groups: int = 1, bm: int = None,
                 bn: int = None, interpret: bool = None,
                 autotune: bool = None):
    """Implicit-GEMM conv: x (B,H,W,Cin), w (K,K,Cin/G,Cout) ->
    (B,OH,OW,Cout).

    The im2col patch tensor never materializes in HBM — each grid program
    gathers its windows from the (B,H,W,C) operand.  ``groups`` > 1 is
    the paper's intra-layer model parallelism (AlexNet conv2/4/5): the N
    grid axis walks block-diagonal tiles and each program stages only its
    group's input-channel slice.  Differentiable.
    """
    interpret = tune.resolve_interpret(interpret)
    k, _, wcin, cout = w.shape
    cin = x.shape[-1]
    if wcin * groups != cin:
        raise ValueError(f"w in-channels {wcin} x groups {groups} != "
                         f"x channels {cin}")
    if cout % groups:
        raise ValueError(f"cout {cout} not divisible by groups {groups}")
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding),
                        (0, 0)))
    b_, hp, wp, _ = x.shape
    oh = (hp - k) // stride + 1
    ow = (wp - k) // stride + 1
    if bm is None or bn is None:
        wd = None if w.dtype == x.dtype else w.dtype
        tbm, tbn = tune.conv_blocks(b_, oh, ow, k, cin, cout, stride,
                                    x.dtype, groups=groups,
                                    interpret=interpret, autotune=autotune,
                                    w_dtype=wd)
        bm, bn = bm or tbm, bn or tbn
    if bias is None:
        bias = jnp.zeros((cout,), x.dtype)
    return _conv_fused_core(x, w, bias, k, stride, oh, ow, bm, bn, relu,
                            groups, interpret)
