"""im2col convolution: patch extraction (XLA) + Pallas MXU matmul."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.conv2d.conv2d import matmul_bias


def im2col(x, kernel: int, stride: int, padding: int):
    """x (B,H,W,C) -> patches (B, OH, OW, K*K*C)."""
    b, h, w, c = x.shape
    patches = jax.lax.conv_general_dilated_patches(
        x, (kernel, kernel), (stride, stride),
        [(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    # conv_general_dilated_patches yields channel-major (C*K*K) features;
    # reorder to (K*K*C) to match w.reshape(K*K*Cin, Cout)
    oh, ow = patches.shape[1], patches.shape[2]
    patches = patches.reshape(b, oh, ow, c, kernel * kernel)
    patches = patches.transpose(0, 1, 2, 4, 3).reshape(b, oh, ow,
                                                       kernel * kernel * c)
    return patches


def conv2d_im2col(x, w, *, stride: int, padding: int, bias=None,
                  relu: bool = False, interpret: bool = True):
    """x (B,H,W,Cin), w (K,K,Cin,Cout)."""
    k, _, cin, cout = w.shape
    patches = im2col(x, k, stride, padding)
    b, oh, ow, feat = patches.shape
    wmat = w.reshape(k * k * cin, cout)
    bvec = jnp.zeros((cout,), x.dtype) if bias is None else bias
    y = matmul_bias(patches.reshape(b * oh * ow, feat), wmat, bvec,
                    relu=relu, interpret=interpret)
    return y.reshape(b, oh, ow, cout)
