"""Conv backends: fused implicit-GEMM (hot path) + two-stage im2col ref.

``conv2d_fused`` (re-exported from conv2d.py) is the production path:
patch extraction lives inside the Pallas kernel, so no im2col tensor
ever hits HBM.  ``conv2d_im2col`` is the original two-stage pipeline —
XLA patch extraction feeding the Pallas GEMM — kept as the
``pallas_im2col_ref`` backend for parity testing the fused kernel
against an independent formulation.

Patch features from ``conv_general_dilated_patches`` come out
channel-major (C*K*K).  The seed transposed the *patch tensor*
(B*OH*OW, K*K*C) — a huge per-step HBM shuffle; instead we reorder the
small (K*K*C, Cout) weight matrix once into channel-major row order and
memoise it per concrete weight array.
"""
from __future__ import annotations

from collections import OrderedDict

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.conv2d import ref as conv_ref
from repro.kernels.conv2d.conv2d import conv2d_fused, matmul_bias  # noqa: F401

# id(w) -> (weakref-or-None, reordered) for concrete weight arrays; bounded
_WCACHE: OrderedDict = OrderedDict()
_WCACHE_MAX = 32


def _reorder(w, groups: int):
    # channel-major rows; grouped weights embed as a block-diagonal
    # (Cin*K*K, Cout) matrix: group g's K*K*Cg rows are contiguous in the
    # patches' channel-major feature order, its Cout/G columns are
    # group-major — zeros everywhere else, so one GEMM does all groups
    wm = w.transpose(2, 0, 1, 3).reshape(-1, w.shape[-1])
    if groups == 1:
        return wm
    npg = w.shape[-1] // groups
    from jax.scipy.linalg import block_diag
    return block_diag(*[wm[:, g * npg:(g + 1) * npg]
                        for g in range(groups)])


def reorder_weights(w, groups: int = 1):
    """(K,K,Cin/G,Cout) -> (Cin*K*K, Cout) rows in the patches'
    channel-major order (block-diagonal when grouped).  Memoised for
    concrete arrays (a training step reuses the same weight buffers until
    the optimizer writes new ones)."""
    if isinstance(w, jax.core.Tracer):        # under jit: XLA will CSE it
        return _reorder(w, groups)
    key = (id(w), groups)
    hit = _WCACHE.get(key)
    if hit is not None and hit[0]() is w:
        _WCACHE.move_to_end(key)
        return hit[1]
    out = _reorder(w, groups)
    try:
        import weakref
        ref = weakref.ref(w, lambda _, k=key: _WCACHE.pop(k, None))
    except TypeError:
        return out    # unweakrefable: bare ids can be recycled — no cache
    _WCACHE[key] = (ref, out)
    while len(_WCACHE) > _WCACHE_MAX:
        _WCACHE.popitem(last=False)
    return out


def im2col(x, kernel: int, stride: int, padding: int):
    """x (B,H,W,C) -> patches (B, OH, OW, C*K*K), channel-major features
    (the producer's native layout — no patch-tensor transpose)."""
    return jax.lax.conv_general_dilated_patches(
        x, (kernel, kernel), (stride, stride),
        [(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def conv2d_im2col(x, w, *, stride: int, padding: int, bias=None,
                  relu: bool = False, groups: int = 1,
                  interpret: bool = None, autotune: bool = None):
    """Two-stage reference: XLA im2col + Pallas GEMM.  x (B,H,W,Cin),
    w (K,K,Cin/G,Cout).  Grouped convs run as ONE GEMM against the
    block-diagonal weight embedding (an independent formulation from the
    fused kernel's block-diagonal N-tile walk — parity fodder)."""
    k, _, _, cout = w.shape
    patches = im2col(x, k, stride, padding)
    b, oh, ow, feat = patches.shape
    wmat = reorder_weights(w, groups)
    bvec = jnp.zeros((cout,), x.dtype) if bias is None else bias
    y = matmul_bias(patches.reshape(b * oh * ow, feat), wmat, bvec,
                    relu=relu, interpret=interpret, autotune=autotune)
    return y.reshape(b, oh, ow, cout)


def _example(seed: int = 0):
    import numpy as np
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(2, 13, 13, 5)).astype(np.float32)
    w = (rng.normal(size=(3, 3, 5, 11)) * 0.2).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(w)


def _example_grouped(seed: int = 0):
    import numpy as np
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(2, 13, 13, 8)).astype(np.float32)
    w = (rng.normal(size=(3, 3, 4, 12)) * 0.2).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(w)


common.register(common.KernelOp(
    name="conv2d",
    pallas=lambda x, w: conv2d_fused(x, w, stride=2, padding=1),
    ref=lambda x, w: conv_ref.conv2d_ref(x, w, 2, 1),
    example=_example,
    tuner=None,          # conv_blocks/matmul_blocks in tune.py (shape-rich)
    tol=2e-4,
    grad_argnums=(0, 1),
))

common.register(common.KernelOp(
    name="conv2d_grouped",
    pallas=lambda x, w: conv2d_fused(x, w, stride=1, padding=1, groups=2),
    ref=lambda x, w: conv_ref.conv2d_ref(x, w, 1, 1, groups=2),
    example=_example_grouped,
    tuner=None,
    tol=2e-4,
    grad_argnums=(0, 1),
))
