"""Pure-jnp oracle: direct convolution via lax.conv_general_dilated."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def conv2d_ref(x, w, stride: int, padding: int, groups: int = 1):
    """x (B,H,W,Cin), w (K,K,Cin/G,Cout) -> (B,OH,OW,Cout).

    ``groups`` maps to XLA's ``feature_group_count`` — output channels
    are group-major, matching the fused kernel's layout."""
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
        preferred_element_type=jnp.float32).astype(x.dtype)


def matmul_bias_ref(x, w, b, relu: bool = False):
    y = x.astype(jnp.float32) @ w.astype(jnp.float32) + b.astype(jnp.float32)
    if relu:
        y = jnp.maximum(y, 0.0)
    return y.astype(x.dtype)
