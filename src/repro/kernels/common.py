"""Shared kernel infrastructure: backend resolution, autotune cache,
the kernel-op registry, and the ``KernelPolicy`` selector.

PR 2 built this machinery for conv2d only (``conv2d/tune.py``); every
other Pallas kernel hardcoded ``interpret=True`` and had no way to be
selected by the model layer.  This module hoists the shared parts so all
four kernel families (conv2d, flash_attention, rglru, rwkv6) resolve
their execution mode, tune their block sizes, and register themselves
the same way:

``resolve_interpret``
    Tri-state ``interpret`` flag: ``None`` means "compile when the
    backend can" (TPU), falling back to the Pallas interpreter on
    CPU/GPU hosts.  ``REPRO_PALLAS_INTERPRET=0|1`` overrides for every
    kernel (the env var used to reach only conv2d).

``autotune`` / ``clear_cache`` / ``cache_info``
    The process-level shape-keyed winner cache.  Keys are namespaced per
    kernel (``("matmul", ...)``, ``("flash", ...)``, ...); a measured
    sweep runs once per shape on compiled backends and the winner is
    memoised for the rest of the process.  ``REPRO_PALLAS_AUTOTUNE=0``
    disables measurement globally (``REPRO_CONV_AUTOTUNE`` is still
    honoured by the conv tuners for back-compat).

``KernelOp`` / ``register`` / ``get_op`` / ``ops``
    The registration pattern: each kernel package registers its public
    pallas entry point, the XLA reference it must match, its block-size
    tuner, and its fp32 parity tolerance.  Tests iterate the registry
    (``tests/kernels/test_grad_parity.py``) so a new kernel gets parity
    and gradient coverage by registering, not by copying a test file.

``KernelPolicy``
    The single per-run selector threaded ``configs/base.py`` →
    ``models/*`` → ``launch/train.py --kernel-backend``.  One global
    ``backend`` default (``xla | pallas | auto``) plus per-op overrides
    (which may also name a concrete impl, e.g. ``attention="qloop"``)
    and interpret/autotune overrides.  ``auto`` resolves to the Pallas
    path exactly when it would compile (i.e. not interpret mode), so
    CPU development keeps the fast XLA paths while a TPU host trains the
    whole zoo on the Pallas kernels with no flag changes.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax

# shape-keyed winner cache: key -> blocks tuple
_CACHE: Dict[tuple, tuple] = {}
# how many measured autotune sweeps ran (introspection / tests)
_STATS = {"measured": 0, "hits": 0}

SUBLANE = 8           # TPU fp32 sublane count — block floor
LANE = 128            # TPU lane count — preferred alignment


# ------------------------------------------------------------------ mode ----

def resolve_interpret(interpret=None) -> bool:
    """Resolve the tri-state ``interpret`` flag for ANY Pallas kernel.

    None  -> auto: compile on TPU, interpret elsewhere (the pinned
             kernels are Mosaic/TPU programs; CPU runs them through the
             Pallas interpreter for correctness work).
    bool  -> honoured as given (tests force both modes).
    Env   -> REPRO_PALLAS_INTERPRET=0|1 overrides auto-detection only.
    """
    if interpret is not None:
        return bool(interpret)
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def pow2_clip(dim: int, cap: int) -> int:
    """Smallest power of two >= dim, clipped to [SUBLANE, cap]."""
    p = 1 << max(dim - 1, 0).bit_length()
    return max(min(p, cap), SUBLANE)


def autotune_enabled(interpret: bool, override: Optional[bool] = None,
                     env: str = "REPRO_PALLAS_AUTOTUNE") -> bool:
    """Whether a tuner should measure candidates (vs heuristic default).

    Interpreter timings reflect Python overhead, not the MXU — never
    measure in interpret mode.  ``override`` (a ``KernelPolicy.autotune``
    value) wins over the env switch.
    """
    if interpret:
        return False
    if override is not None:
        return bool(override)
    return os.environ.get(env, "1") != "0"


# ----------------------------------------------------------------- cache ----

def clear_cache() -> None:
    _CACHE.clear()
    _STATS["measured"] = _STATS["hits"] = 0


def cache_info() -> dict:
    return {"entries": len(_CACHE), **_STATS}


def cache_state() -> dict:
    """JSON-able snapshot of the winner cache (key repr -> block list).

    Checkpoint manifests stash this so a resumed run reuses the SAME
    measured block sizes: autotune winners depend on timing noise, and a
    different blocking changes fp reduction order — which would silently
    break the bit-exact-resume guarantee the session layer makes.
    """
    return {repr(k): list(v) for k, v in _CACHE.items()}


def load_cache_state(state: dict) -> int:
    """Seed the winner cache from a ``cache_state()`` snapshot; existing
    entries win (the live process may already have measured).  Returns
    the number of entries loaded."""
    import ast
    n = 0
    for key_repr, blocks in (state or {}).items():
        try:
            key = ast.literal_eval(key_repr)
        except (ValueError, SyntaxError):
            continue
        if key not in _CACHE:
            _CACHE[key] = tuple(blocks)
            n += 1
    return n


def autotune(key: tuple, candidates: Sequence[tuple],
             measure: Optional[Callable[[tuple], float]]) -> tuple:
    """Return the cached winner for ``key``, measuring once on a miss.

    ``measure(candidate) -> seconds``; exceptions disqualify a candidate
    (e.g. a blocking the compiler rejects) rather than failing the tune.
    A single candidate is cached without measuring (``measure`` may be
    None then).
    """
    if key in _CACHE:
        _STATS["hits"] += 1
        return _CACHE[key]
    best, best_t = candidates[0], float("inf")
    if len(candidates) > 1:
        _STATS["measured"] += 1
        for cand in candidates:
            try:
                t = measure(cand)
            except Exception:
                continue
            if t < best_t:
                best, best_t = cand, t
    _CACHE[key] = best
    return best


def time_call(fn, *args, iters: int = 3) -> float:
    """Mean wall-time per call in seconds (compile+warm excluded)."""
    jax.block_until_ready(fn(*args))          # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


# -------------------------------------------------------------- registry ----

@dataclasses.dataclass(frozen=True)
class KernelOp:
    """One registered kernel family.

    ``pallas`` and ``ref`` share a signature over ``example(seed)``'s
    args and return the same pytree, so the registry-driven parity and
    gradient tests need no per-kernel glue.  ``differentiable`` ops must
    support ``jax.grad`` through the pallas path (custom_vjp).
    """
    name: str
    pallas: Callable                      # pallas entry point
    ref: Callable                         # XLA reference, same signature
    example: Callable                     # seed -> tuple of example args
    tuner: Optional[Callable] = None      # shape -> block sizes
    tol: float = 2e-4                     # fp32 parity tolerance
    differentiable: bool = True
    grad_argnums: Tuple[int, ...] = (0,)  # args to diff in parity tests


_REGISTRY: Dict[str, KernelOp] = {}
_OP_PACKAGES = ("conv2d", "decode_attention", "flash_attention", "lrn",
                "rglru", "rwkv6")


def register(op: KernelOp) -> KernelOp:
    _REGISTRY[op.name] = op
    return op


def _ensure_registered() -> None:
    # registration happens at kernel-package import; do it lazily so that
    # importing KernelPolicy (e.g. from repro.configs) stays light — no
    # consumer pays the full pallas import chain until it asks for ops
    import importlib
    for name in _OP_PACKAGES:
        importlib.import_module(f"repro.kernels.{name}")


def get_op(name: str) -> KernelOp:
    _ensure_registered()
    return _REGISTRY[name]


def ops() -> Dict[str, KernelOp]:
    _ensure_registered()
    return dict(_REGISTRY)


# ---------------------------------------------------------------- policy ----

BACKENDS = ("auto", "xla", "pallas")
# ops a global ``backend=pallas`` switches over, and the impl name the
# model layer maps it to
_PALLAS_IMPL = {"attention": "flash", "rglru": "pallas", "rwkv6": "pallas",
                "conv2d": "pallas", "decode_attention": "pallas",
                "lrn": "pallas"}


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """Per-run kernel selection, carried on the model config.

    ``backend`` is the global default; per-op fields override it and may
    name a concrete impl (``attention="qloop"`` for the dry-run's
    static-slice lowering, ``conv2d="pallas_im2col_ref"`` for parity).
    ``interpret`` / ``autotune`` override the env/backend resolution for
    every kernel the policy reaches.
    """
    backend: str = "auto"                 # xla | pallas | auto
    attention: Optional[str] = None       # auto|xla|chunked|qloop|flash
    # single-token flash-decode over the ring cache (serving hot path)
    decode_attention: Optional[str] = None  # auto|xla|pallas
    rglru: Optional[str] = None           # auto|xla|pallas
    rwkv6: Optional[str] = None           # auto|sequential|chunked|pallas
    conv2d: Optional[str] = None          # auto|xla|pallas|pallas_im2col_ref
    lrn: Optional[str] = None             # auto|xla|pallas
    # explicit opt-in ONLY (the global backend does not flip it): route
    # dense/MoE projection GEMMs through kernels.conv2d.matmul_bias —
    # XLA's einsum is already near-roofline there, so this is for A/B
    # benchmarking the Pallas GEMM, not a default
    matmul: Optional[str] = None          # None|pallas
    interpret: Optional[bool] = None
    autotune: Optional[bool] = None

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, "
                             f"got {self.backend!r}")

    def wants_pallas(self, op: str) -> bool:
        """True when the policy resolves ``op`` to its Pallas impl:
        explicitly, or via ``auto``/the global backend on a host where
        Pallas compiles.  Ops outside ``_PALLAS_IMPL`` (``matmul``) are
        explicit-opt-in: the global backend never flips them."""
        sel = getattr(self, op, None)
        if sel is None:
            if op not in _PALLAS_IMPL:
                return False
            sel = self.backend
        if sel in ("pallas", _PALLAS_IMPL.get(op)):
            return True
        if sel == "auto":
            return not resolve_interpret(self.interpret)
        return False

    def describe(self) -> dict:
        """Stable summary for logging / checkpoint manifests."""
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)
                if getattr(self, f.name) is not None}


def policy_of(cfg) -> KernelPolicy:
    """The config's policy, defaulting for configs predating the field."""
    pol = getattr(cfg, "kernels", None)
    return pol if pol is not None else KernelPolicy()
