"""Pallas kernel families behind one shared infrastructure layer.

``repro.kernels.common`` provides backend resolution (interpret vs
compiled), the process-level autotune cache, the ``KernelOp`` registry,
and the ``KernelPolicy`` selector the model layer consumes.  The kernel
packages (conv2d, flash_attention, rglru, rwkv6) register themselves on
import; ``common.ops()`` imports them lazily, so config-only consumers
of ``KernelPolicy`` never pay the pallas import chain.
"""
from repro.kernels.common import (KernelOp, KernelPolicy,  # noqa: F401
                                  policy_of, resolve_interpret)
