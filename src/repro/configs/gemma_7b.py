"""gemma-7b [dense] — GeGLU, head_dim=256, 16 heads / 16 kv heads.

[arXiv:2403.08295] Gemma: Open Models Based on Gemini Research and Technology.
Exact published shape: 28 layers, d_model 3072, 16 heads (kv=16), d_ff 24576
(GeGLU), vocab 256000, head_dim 256, RoPE.

``gemma-7b-swa`` is an explicit sliding-window VARIANT (gemma-2-style, window
4096) used only to exercise the dense-arch long_500k carve-out per DESIGN.md.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    mlp="geglu",
    norm="rmsnorm",
    rope_theta=10000.0,
    tie_embeddings=True,
    citation="arXiv:2403.08295",
    notes="GeGLU, head_dim=256 (decoupled from d_model/heads); MQA on the 2b sibling",
)

SWA_VARIANT = dataclasses.replace(
    CONFIG, name="gemma-7b-swa", sliding_window=4096,
    notes=CONFIG.notes + "; gemma-2-style SWA variant for long_500k",
)
