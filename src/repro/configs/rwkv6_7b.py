"""rwkv6-7b [ssm] — Finch: attention-free, data-dependent decay.

[arXiv:2404.05892] Eagle and Finch: RWKV with Matrix-Valued States and
Dynamic Recurrence.  32 layers, d_model 4096, d_ff 14336 (channel-mix),
vocab 65536, attention-free.  WKV6 heads: 64 heads of size 64 (d_model/64).

The recurrence is computed in chunked-parallel form (TPU-native adaptation of
the reference CUDA kernel) — see ``repro.kernels.rwkv6`` and
``repro.models.rwkv``.  O(1) decode state => runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,          # WKV heads, head_dim 64
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    mlp="gelu",          # channel-mix uses squared-relu; flag handled in model
    norm="layernorm",
    citation="arXiv:2404.05892",
    notes="Finch (RWKV6): data-dependent decay, matrix-valued state; chunked-parallel prefill, O(1) decode",
)
