"""phi-3-vision-4.2b [vlm] — phi3-mini decoder + CLIP frontend (stubbed).

[hf:microsoft/Phi-3-vision-128k-instruct]  32 layers, d_model 3072, 32 heads
(kv=32), d_ff 8192, vocab 32064.  The vision encoder (CLIP ViT-L/14) and
projector are a STUB per the assignment carve-out: ``input_specs()`` supplies
precomputed patch embeddings (B, 1024, d_model) scattered into the token
stream at image positions given by a mask.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    mlp="swiglu",
    norm="rmsnorm",
    n_image_tokens=1024,
    citation="hf:microsoft/Phi-3-vision-128k-instruct",
    notes="phi3-mini backbone + CLIP stub; full attention => long_500k skipped",
)
