"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.

[arXiv:2401.04088] Mixtral of Experts.  32 layers, d_model 4096, 32 heads
(GQA kv=8), expert d_ff 14336, vocab 32000, 8 experts top-2, SWA window 4096.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    mlp="swiglu",
    norm="rmsnorm",
    moe=MoEConfig(n_experts=8, top_k=2),
    sliding_window=4096,
    citation="arXiv:2401.04088",
    notes="8 experts < model-axis 16 => 2-D (expert x tensor) sharding; native SWA enables long_500k",
)
