"""seamless-m4t-medium [audio] — encoder-decoder multimodal backbone.

[arXiv:2308.11596] SeamlessM4T: Massively Multilingual & Multimodal Machine
Translation.  Backbone only (per assignment carve-out): 12 encoder + 12
decoder layers, d_model 1024, 16 heads (kv=16), d_ff 4096, vocab 256206.

The audio frontend (mel-spectrogram + conv feature extractor) is a STUB:
``input_specs()`` supplies precomputed frame embeddings (B, T_enc, d_model)
with T_enc = seq_len // 4 (the conformer codec's downsampling factor).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,            # decoder depth
    n_enc_layers=12,        # encoder depth
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    mlp="gelu",
    norm="layernorm",
    citation="arXiv:2308.11596",
    notes="enc-dec; audio frontend stubbed as precomputed frame embeddings; decode uses fixed 1024-frame encoder memory",
)
