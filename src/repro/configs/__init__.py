"""Architecture registry.

``get_config(name)`` returns the exact published ModelConfig; ``--arch <id>``
in the launchers resolves through this table.  AlexNet (the paper's own
architecture) lives alongside the 10 assigned LLM-family archs but has its own
config class (conv nets do not share the transformer schema).
"""
from __future__ import annotations

from repro.configs import (alexnet, gemma_7b, llama4_maverick, minicpm_2b,
                           minitron_8b, mixtral_8x7b, olmo_1b, phi3_vision,
                           recurrentgemma_9b, rwkv6_7b, seamless_m4t_medium)
from repro.configs.base import (SHAPES, ModelConfig, MoEConfig, ShapeConfig,
                                reduced, supports_shape)

ARCHS = {
    "gemma-7b": gemma_7b.CONFIG,
    "gemma-7b-swa": gemma_7b.SWA_VARIANT,
    "minicpm-2b": minicpm_2b.CONFIG,
    "minitron-8b": minitron_8b.CONFIG,
    "mixtral-8x7b": mixtral_8x7b.CONFIG,
    "llama4-maverick-400b-a17b": llama4_maverick.CONFIG,
    "olmo-1b": olmo_1b.CONFIG,
    "seamless-m4t-medium": seamless_m4t_medium.CONFIG,
    "rwkv6-7b": rwkv6_7b.CONFIG,
    "phi-3-vision-4.2b": phi3_vision.CONFIG,
    "recurrentgemma-9b": recurrentgemma_9b.CONFIG,
}

# The 10 assigned architecture ids (gemma-7b-swa is a variant, not an extra).
ASSIGNED = [
    "gemma-7b", "minicpm-2b", "minitron-8b", "mixtral-8x7b",
    "llama4-maverick-400b-a17b", "olmo-1b", "seamless-m4t-medium",
    "rwkv6-7b", "phi-3-vision-4.2b", "recurrentgemma-9b",
]

ALEXNET = alexnet.CONFIG
ALEXNET_SMOKE = alexnet.SMOKE
ALEXNET_FAITHFUL = alexnet.FAITHFUL
ALEXNET_FAITHFUL_SMOKE = alexnet.FAITHFUL_SMOKE


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = [
    "ARCHS", "ASSIGNED", "ALEXNET", "ALEXNET_SMOKE",
    "ALEXNET_FAITHFUL", "ALEXNET_FAITHFUL_SMOKE", "SHAPES",
    "ModelConfig", "MoEConfig", "ShapeConfig", "get_config", "reduced",
    "supports_shape",
]
