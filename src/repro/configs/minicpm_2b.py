"""minicpm-2b [dense] — llama-like, trained with the WSD schedule.

[arXiv:2404.06395] MiniCPM: Unveiling the Potential of Small Language Models.
40 layers, d_model 2304, 36 heads (kv=36), d_ff 5760, vocab 122753.
head_dim = 2304/36 = 64.  The WSD (warmup-stable-decay) schedule the paper
introduces lives in ``repro.optim.schedules.wsd``.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122753,
    mlp="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
    citation="arXiv:2404.06395",
    notes="WSD schedule; llama-like block; vocab 122753 exercises uneven GSPMD sharding",
)
