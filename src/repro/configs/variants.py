"""Named beyond-paper config variants used by the §Perf hillclimb.

Each is a pure transformation of a published config; the baseline configs
stay untouched so paper-faithful and optimized rows are reported separately.
"""
from __future__ import annotations

import dataclasses


def vocab_pad(cfg):
    """Megatron-style padded vocab: embedding shardable over 'model'."""
    return dataclasses.replace(cfg, pad_vocab_to_multiple=128)


def rowwise_moe(cfg):
    """Batch-row-local MoE dispatch (routing never crosses batch shards)."""
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch="rowwise"))


def moe2d(cfg):
    """2-D MoE layout hint: experts over 'model', token capacity over
    'data'.  Pair with --mesh-shape 32,8 so n_experts divides 'model'."""
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe,
                                     buffer_sharding=("model", "data")))


def moe2d_rowwise(cfg):
    """Row-local dispatch (gathers never cross batch shards) + experts over
    'model' (buffer hint; per-row capacity stays shard-local).  Pair with
    --mesh-shape 32,8."""
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch="rowwise",
                                     buffer_sharding=("model",)))


def seqpar(cfg):
    """Sequence parallelism: inter-block activations shard S over 'model'."""
    return dataclasses.replace(cfg, seq_shard=True)


def tuned(cfg):
    return seqpar(rowwise_moe(vocab_pad(cfg)))


VARIANTS = {
    # identity: re-measure with current step-code (e.g. after the R=1
    # vmap-squeeze fix) without overwriting the recorded baseline JSON
    "r1squeeze": lambda cfg: cfg,
    "vocab_pad": vocab_pad,
    "rowwise_moe": rowwise_moe,
    "seqpar": seqpar,
    "moe2d": moe2d,
    "moe2d_rowwise": moe2d_rowwise,
    "vocab_pad_seqpar": lambda cfg: seqpar(vocab_pad(cfg)),
    "rowwise_seqpar": lambda cfg: seqpar(rowwise_moe(cfg)),
    "tuned": tuned,
}
