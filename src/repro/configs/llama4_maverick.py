"""llama4-maverick-400b-a17b [moe] — 128 experts top-1 + shared expert.

[hf:meta-llama/Llama-4-Scout-17B-16E model card family]  48 layers, d_model
5120, 40 heads (GQA kv=8), expert d_ff 8192, vocab 202048, MoE 128e top-1,
early fusion.  Llama-4 uses iRoPE chunked local attention (chunk 8192) on most
layers, which is what lets this arch run long_500k with a bounded cache; we
model it as sliding-window 8192.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    mlp="swiglu",
    norm="rmsnorm",
    moe=MoEConfig(n_experts=128, top_k=1, shared_expert=True, every_k=2),
    sliding_window=8192,
    citation="hf:meta-llama/Llama-4-Scout-17B-16E",
    notes="MoE 128e top-1 + shared expert; early fusion (image tokens in-stream); chunked local attn ~= SWA 8192",
)
