"""olmo-1b [dense] — non-parametric LayerNorm.

[arXiv:2402.00838] OLMo: Accelerating the Science of Language Models.
16 layers, d_model 2048, 16 heads (kv=16), d_ff 8192, vocab 50304,
non-parametric LN (no scale/bias), SwiGLU... OLMo uses plain (non-gated) MLP
with d_ff 8192; we keep the published non-gated GELU MLP.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50304,
    mlp="gelu",
    norm="np_ln",
    tie_embeddings=True,
    citation="arXiv:2402.00838",
    notes="non-parametric LayerNorm (elementwise_affine=False); non-gated MLP",
)
