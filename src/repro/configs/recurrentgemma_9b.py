"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2 recurrent : 1 attn.

[arXiv:2402.19427] Griffin: Mixing Gated Linear Recurrences with Local
Attention.  38 layers, d_model 4096, 16 heads (MQA kv=1), d_ff 12288,
vocab 256000, head_dim 256 (from the 2b/9b family: wide MQA heads), local
attention window 2048, pattern (rec, rec, attn).

O(1) recurrent state + bounded local-attention cache => runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    mlp="geglu",
    norm="rmsnorm",
    sliding_window=2048,
    layer_pattern=("rec", "rec", "attn"),
    citation="arXiv:2402.19427",
    notes="RG-LRU gated linear recurrence (associative scan) : local MQA attn 2:1; kv=1 => head_dim sharded over model axis",
)
