"""minitron-8b [dense] — pruned Nemotron-4.

[arXiv:2407.14679] Compact Language Models via Pruning and Knowledge
Distillation.  32 layers, d_model 4096, 32 heads (GQA kv=8), d_ff 16384,
vocab 256000, head_dim 128.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256000,
    mlp="swiglu",
    norm="layernorm",
    citation="arXiv:2407.14679",
    notes="pruned nemotron; GQA 4:1",
)
