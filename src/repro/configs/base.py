"""Configuration schema for architectures and input shapes.

Every assigned architecture gets one ``configs/<id>.py`` exporting ``CONFIG``
(the exact published shape, cited) and the registry builds reduced variants
for CPU smoke tests.  Full configs are only ever lowered via ShapeDtypeStruct
in the dry-run; they are never materialized on this host.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.param_avg import ExchangeConfig
from repro.kernels.common import KernelPolicy
from repro.numerics import NumericsPolicy


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    shared_expert: bool = False
    router_aux_coef: float = 0.01
    every_k: int = 1  # MoE on every k-th layer (llama4 interleaves, k=2); dense FFN otherwise
    capacity_factor: float = 1.25  # GShard token-drop capacity; tests may raise to n_experts for no-drop
    dispatch: str = "flat"  # "flat" (global sort) | "rowwise" (per-batch-row; shard-local, perf variant)
    # perf knob (beyond-paper): GSPMD hint sharding the (E, C, d) expert
    # buffers, e.g. ("model", "data") = experts over 'model', token capacity
    # over 'data' — keeps expert contractions local (weights all-gather
    # instead of activation partial-sum all-reduce).
    buffer_sharding: tuple = None


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """A single transformer-family architecture.

    ``family`` selects the model implementation:
      dense   — decoder-only transformer (GQA/MQA, optional SWA)
      moe     — dense + mixture-of-experts FFN
      ssm     — RWKV6 attention-free (data-dependent decay)
      hybrid  — RG-LRU recurrent blocks : local-attention blocks (pattern)
      encdec  — encoder-decoder (audio backbone; frontend stubbed)
      vlm     — dense decoder consuming stub patch embeddings
    """

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    mlp: str = "swiglu"          # swiglu | geglu | gelu
    norm: str = "rmsnorm"        # rmsnorm | layernorm | np_ln (non-parametric)
    moe: Optional[MoEConfig] = None
    sliding_window: Optional[int] = None  # None = full causal attention
    # hybrid only: repeating per-layer pattern, e.g. ("rec", "rec", "attn")
    layer_pattern: Optional[Tuple[str, ...]] = None
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    n_enc_layers: int = 0        # encdec: encoder depth (n_layers = decoder depth)
    n_image_tokens: int = 0      # vlm: stub patch-embedding count
    # perf knob (beyond-paper): pad the embedding table so the vocab dim is
    # shardable over the model axis (Megatron-style padded vocab); logits
    # are sliced back to vocab_size, so the math is unchanged.
    pad_vocab_to_multiple: int = 0
    # perf knob (beyond-paper): sequence parallelism — constrain inter-block
    # activations to shard their sequence dim over 'model', turning per-layer
    # TP all-reduces into reduce-scatter/all-gather pairs (half the bytes)
    # and sharding norm compute.
    seq_shard: bool = False
    # kernel backend selection (repro.kernels.common.KernelPolicy): global
    # xla|pallas|auto default + per-op overrides; carried on the config so
    # every layer resolves the same way without kwarg threading.  Override
    # per run with dataclasses.replace(cfg, kernels=...) — the launchers'
    # --kernel-backend / --attn-impl flags do exactly that.
    kernels: KernelPolicy = KernelPolicy()
    # replica exchange policy (core.param_avg.ExchangeConfig): strategy,
    # wire compression, delay (0 = synchronous, 1 = one-step-stale
    # overlapped) and sync_every, carried on the config the same way the
    # kernel policy is — the launchers' --strategy / --exchange-* flags
    # dataclasses.replace it per run.
    exchange: ExchangeConfig = ExchangeConfig()
    # precision policy (repro.numerics.NumericsPolicy): param/compute
    # dtypes, fp32 master weights, loss scaling, KV-cache quantization —
    # carried like ``kernels:`` so every layer resolves precision without
    # kwarg threading; the launchers' --numerics / --kv-cache-dtype flags
    # dataclasses.replace it per run.  ``dtype`` below stays the legacy
    # default the policy inherits when param_dtype is None.
    numerics: NumericsPolicy = NumericsPolicy()
    dtype: str = "bfloat16"
    citation: str = ""
    notes: str = ""

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def padded_vocab(self) -> int:
        m = self.pad_vocab_to_multiple
        if m and self.vocab_size % m:
            return (self.vocab_size + m - 1) // m * m
        return self.vocab_size

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def supports_long_decode(self) -> bool:
        """True if a 512k-token decode has a bounded working set (sub-quadratic)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None

    def has_decode(self) -> bool:
        return True  # all assigned archs have a decoder; AlexNet is handled separately

    def n_params(self) -> int:
        """Total parameter count (embeddings included; MoE counts all experts)."""
        d, f, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab_size
        embed = V * d * (1 if self.tie_embeddings else 2)
        gated = self.mlp in ("swiglu", "geglu")
        ffn_one = d * f * (3 if gated else 2)
        if self.moe is not None:
            moe_ffn = self.moe.n_experts * ffn_one + d * self.moe.n_experts  # + router
            if self.moe.shared_expert:
                moe_ffn += ffn_one
            frac_moe = 1.0 / self.moe.every_k
            ffn = frac_moe * moe_ffn + (1 - frac_moe) * ffn_one
        else:
            ffn = ffn_one
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.family == "ssm":
            # RWKV6 block: time-mix (~4 d^2 + low-rank ddlerp/decay) + channel-mix
            per_layer = 4 * d * d + d * f * 2 + 6 * d * 64
        elif self.family == "hybrid":
            # pattern mix: recurrent block ≈ 3*d*d (gates + in/out proj) vs attn
            pat = self.layer_pattern or ("rec", "rec", "attn")
            frac_attn = pat.count("attn") / len(pat)
            per_layer = frac_attn * attn + (1 - frac_attn) * (3 * d * d) + ffn
        else:
            per_layer = attn + ffn
        total = embed + L * per_layer
        if self.family == "encdec":
            # encoder stack + decoder cross-attention blocks
            total += self.n_enc_layers * (attn + ffn) + L * attn
        return int(total)

    def n_active_params(self) -> int:
        """Active-per-token parameter count (MoE: top_k experts only)."""
        if self.moe is None:
            return self.n_params()
        d, f = self.d_model, self.d_ff
        gated = self.mlp in ("swiglu", "geglu")
        ffn_one = d * f * (3 if gated else 2)
        inactive = (self.moe.n_experts - self.moe.top_k) * ffn_one
        n_moe_layers = self.n_layers // self.moe.every_k
        return int(self.n_params() - n_moe_layers * inactive)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """Whether (arch, shape) is a supported dry-run combination (skips per DESIGN.md)."""
    if shape.name == "long_500k":
        return cfg.supports_long_decode()
    return True


def reduced(cfg: ModelConfig, n_layers: int = 2, d_model: int = 256,
            vocab: int = 512) -> ModelConfig:
    """A tiny same-family variant for CPU smoke tests (≤2 layers, d_model≤512)."""
    head_dim = 32
    if cfg.family == "ssm":
        # RWKV time-mix projections are d -> d: heads must tile d_model
        n_heads = d_model // head_dim
    else:
        n_heads = max(2, d_model // 64)
    n_kv = min(cfg.n_kv_heads, n_heads) if cfg.n_kv_heads > 1 else 1
    if n_heads % max(n_kv, 1):
        n_kv = n_heads
    moe = None
    if cfg.moe is not None:
        moe = MoEConfig(n_experts=min(4, cfg.moe.n_experts),
                        top_k=min(cfg.moe.top_k, 2),
                        shared_expert=cfg.moe.shared_expert)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=d_model * 2,
        vocab_size=vocab,
        moe=moe,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else None,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        n_image_tokens=min(cfg.n_image_tokens, 8),
        dtype="float32",
    )
