"""AlexNet — the paper's own architecture (Krizhevsky et al., 2012).

5 conv layers (3 followed by max-pool), local response normalization after
conv1/conv2, 2 fully-connected layers + softmax over 1000 classes.  This is
the exact single-tower variant the Theano paper trains (their Fig. 1/2 and
Table 1); batch 256 on 1 replica / 128 per replica on 2.

Two flavours coexist:

``CONFIG`` / ``SMOKE`` (``faithful=False``)
    The legacy nets from PR 2 — ungrouped convs, LRN applied *before*
    the pool.  Kept byte-identical so BENCH rows and golden traces from
    earlier PRs stay comparable.

``FAITHFUL`` / ``FAITHFUL_SMOKE`` (``faithful=True``)
    The paper's dual-GPU topology: conv2/4/5 are 2-group convolutions
    (the intra-layer model-parallel split — each GPU held one group),
    and LRN runs *after* pool1/pool2 with the Caffe reference constants
    ``size=5, alpha=1e-4, beta=0.75`` (SNIPPETS.md snippets 2–3; Jia et
    al. 2014).  FAITHFUL totals 60,965,224 params — the canonical ~61M.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.core.param_avg import ExchangeConfig
from repro.kernels.common import KernelPolicy
from repro.numerics import NumericsPolicy


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    out_channels: int
    kernel: int
    stride: int
    padding: int
    pool: bool       # 3x3 stride-2 max pool after this conv
    lrn: bool        # local response normalization after this conv
    groups: int = 1  # grouped conv (the paper's per-GPU split); must
                     # divide both in- and out-channels


@dataclasses.dataclass(frozen=True)
class AlexNetConfig:
    name: str = "alexnet"
    family: str = "conv"
    image_size: int = 227
    in_channels: int = 3
    n_classes: int = 1000
    convs: Tuple[ConvSpec, ...] = (
        ConvSpec(96, 11, 4, 0, pool=True, lrn=True),
        ConvSpec(256, 5, 1, 2, pool=True, lrn=True),
        ConvSpec(384, 3, 1, 1, pool=False, lrn=False),
        ConvSpec(384, 3, 1, 1, pool=False, lrn=False),
        ConvSpec(256, 3, 1, 1, pool=True, lrn=False),
    )
    fc_dim: int = 4096
    dropout: float = 0.5
    # faithful=True switches to the paper ordering: conv -> relu -> pool
    # -> LRN (the Caffe reference net).  The legacy nets normalized
    # before pooling; that order is preserved under faithful=False so
    # their numerics (and golden traces) never move.
    faithful: bool = False
    # LRN constants (only read where ConvSpec.lrn is set)
    lrn_n: int = 5
    lrn_alpha: float = 1e-4
    lrn_beta: float = 0.75
    lrn_k: float = 2.0
    # same KernelPolicy the LM zoo carries: conv2d resolves xla|pallas|
    # pallas_im2col_ref through it when the forward gets no explicit backend
    kernels: KernelPolicy = KernelPolicy()
    # replica exchange policy, same carriage as ModelConfig.exchange
    exchange: ExchangeConfig = ExchangeConfig()
    # precision policy, same carriage as ModelConfig.numerics
    numerics: NumericsPolicy = NumericsPolicy()
    dtype: str = "float32"
    citation: str = "Krizhevsky et al. 2012; Ding et al. ICLR 2015 (this paper)"

    def __post_init__(self):
        c_in = self.in_channels
        for i, cs in enumerate(self.convs):
            if c_in % cs.groups or cs.out_channels % cs.groups:
                raise ValueError(
                    f"{self.name}: conv{i + 1} groups={cs.groups} must "
                    f"divide in={c_in} and out={cs.out_channels} channels")
            c_in = cs.out_channels

    def feature_hw(self, image_size: int = None) -> int:
        """Spatial size after the conv stack.  Raises ValueError when
        ``image_size`` is too small for the architecture (a conv or pool
        window would not fit) — the check behind ``--image-size``
        validation in launch/train.py."""
        size = self.image_size if image_size is None else image_size
        hw = size
        for i, cs in enumerate(self.convs):
            hw = (hw + 2 * cs.padding - cs.kernel) // cs.stride + 1
            if hw < 1:
                raise ValueError(
                    f"image size {size} invalid for {self.name}: conv{i + 1} "
                    f"(k={cs.kernel}, s={cs.stride}, p={cs.padding}) would "
                    f"see a {hw}-wide feature map")
            if cs.pool:
                hw = (hw - 3) // 2 + 1
                if hw < 1:
                    raise ValueError(
                        f"image size {size} invalid for {self.name}: the "
                        f"3x3/2 pool after conv{i + 1} would see an empty "
                        "feature map")
        return hw

    def n_params(self) -> int:
        c_in = self.in_channels
        total = 0
        for cs in self.convs:
            # a grouped conv only connects within its group: Cin/G
            total += (cs.kernel * cs.kernel * (c_in // cs.groups)
                      * cs.out_channels + cs.out_channels)
            c_in = cs.out_channels
        flat = self.feature_hw() ** 2 * c_in
        total += flat * self.fc_dim + self.fc_dim
        total += self.fc_dim * self.fc_dim + self.fc_dim
        total += self.fc_dim * self.n_classes + self.n_classes
        return total


CONFIG = AlexNetConfig()

# Reduced variant for CPU smoke tests / examples: 64x64 images, thin channels.
SMOKE = AlexNetConfig(
    name="alexnet-smoke",
    image_size=64,
    n_classes=10,
    convs=(
        ConvSpec(16, 7, 2, 0, pool=True, lrn=True),
        ConvSpec(32, 5, 1, 2, pool=True, lrn=True),
        ConvSpec(32, 3, 1, 1, pool=False, lrn=False),
        ConvSpec(32, 3, 1, 1, pool=False, lrn=False),
        ConvSpec(32, 3, 1, 1, pool=True, lrn=False),
    ),
    fc_dim=128,
)

# The paper-faithful dual-GPU topology (see module docstring).
FAITHFUL = AlexNetConfig(
    name="alexnet-faithful",
    faithful=True,
    convs=(
        ConvSpec(96, 11, 4, 0, pool=True, lrn=True),
        ConvSpec(256, 5, 1, 2, pool=True, lrn=True, groups=2),
        ConvSpec(384, 3, 1, 1, pool=False, lrn=False),
        ConvSpec(384, 3, 1, 1, pool=False, lrn=False, groups=2),
        ConvSpec(256, 3, 1, 1, pool=True, lrn=False, groups=2),
    ),
)

FAITHFUL_SMOKE = AlexNetConfig(
    name="alexnet-faithful-smoke",
    faithful=True,
    image_size=64,
    n_classes=10,
    convs=(
        ConvSpec(16, 7, 2, 0, pool=True, lrn=True),
        ConvSpec(32, 5, 1, 2, pool=True, lrn=True, groups=2),
        ConvSpec(32, 3, 1, 1, pool=False, lrn=False),
        ConvSpec(32, 3, 1, 1, pool=False, lrn=False, groups=2),
        ConvSpec(32, 3, 1, 1, pool=True, lrn=False, groups=2),
    ),
    fc_dim=128,
)
