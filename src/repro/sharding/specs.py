"""PartitionSpec assignment: name/shape rules over flattened param paths.

Layout policy (Megatron-style tensor parallelism on the 'model' axis):
  embeddings       vocab over 'model' (GSPMD pads uneven vocabs)
  attention        head axis over 'model' when divisible, else head_dim
  dense MLP        d_ff over 'model'
  MoE experts      expert axis over 'model' when divisible, else d_ff;
                   with ``fsdp_axis`` set, d_ff additionally over that axis
                   (used when a full replica cannot fit per data slice)
  rwkv / rglru     output-feature dim over 'model'
  norms / scalars  replicated

Training state in param-avg mode carries a leading replica axis, sharded
over ``replica_axes`` (('pod','data') by default — one full model+momentum
copy per replica, exactly the paper's memory layout).

Caches: KV heads over 'model' when divisible, else the sequence axis.
"""
from __future__ import annotations

import re
from typing import Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= _axis_size(mesh, n)
        return out
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):              # dataclass GetAttrKey
            parts.append(str(p.name))
        else:
            parts.append(str(p).strip("."))
    return "/".join(parts)


def param_spec(path: str, shape: Tuple[int, ...], cfg, mesh: Mesh,
               model_axis="model", fsdp_axis=None) -> P:
    """Base spec for one parameter leaf (no stack/replica axes)."""
    m = _axis_size(mesh, model_axis)

    def div(n):
        return n % m == 0

    # ---- embeddings ----
    # pjit requires explicitly-sharded ARGUMENT dims to divide evenly, so
    # uneven vocabs (minicpm 122753, olmo 50304...) shard d_model instead.
    if re.search(r"embed/tok$", path):
        if div(shape[-2]):
            return P(model_axis, None)
        return P(None, model_axis) if div(shape[-1]) else P(None, None)
    if re.search(r"embed/lm_head$", path):
        if div(shape[-1]):
            return P(None, model_axis)
        return P(model_axis, None) if div(shape[-2]) else P(None, None)

    # ---- attention projections (d, H, hd) / (H, hd, d) ----
    if re.search(r"(attn|self_attn|cross_attn)/w[qkv]$", path):
        h = shape[-2]
        if div(h):
            return P(None, model_axis, None)
        if div(shape[-1]):
            return P(None, None, model_axis)
        return P(None, None, None)
    if re.search(r"(attn|self_attn|cross_attn)/wo$", path):
        if div(shape[-3]):
            return P(model_axis, None, None)
        if div(shape[-2]):
            return P(None, model_axis, None)
        return P(None, None, None)

    # ---- MoE ----
    if re.search(r"ffn/router$", path):
        return P(None, None)
    if re.search(r"ffn/w_(in|gate)$", path) and len(shape) == 3:   # (E,d,f)
        e = shape[-3]
        if div(e):
            return P(model_axis, None, fsdp_axis)
        return P(None, None, (model_axis,) if fsdp_axis is None else
                 (model_axis, fsdp_axis))
    if re.search(r"ffn/w_out$", path) and len(shape) == 3:         # (E,f,d)
        e = shape[-3]
        if div(e):
            return P(model_axis, fsdp_axis, None)
        return P(None, (model_axis,) if fsdp_axis is None else
                 (model_axis, fsdp_axis), None)

    # ---- dense MLP (d,f)/(f,d), incl. moe shared expert & rwkv cm ----
    if re.search(r"(ffn|shared)/w_(in|gate)$", path) or \
            re.search(r"cm/wk$", path):
        return P(None, model_axis)
    if re.search(r"(ffn|shared|cm)/w?_?out$", path) or \
            re.search(r"(ffn|shared)/w_out$", path) or \
            re.search(r"cm/wv$", path):
        return P(model_axis, None)
    if re.search(r"cm/wr$", path):
        return P(None, model_axis)

    # ---- rwkv time-mix ----
    if re.search(r"tm/w[rkvg]$", path):
        return P(None, model_axis)
    if re.search(r"tm/wo$", path):
        return P(model_axis, None)
    if re.search(r"tm/u$", path):
        return P(model_axis, None) if div(shape[0]) else P(None, None)

    # ---- rglru ----
    if re.search(r"mix/w[xg]$", path):
        return P(None, model_axis)
    if re.search(r"mix/wo$", path):
        return P(model_axis, None)
    if re.search(r"mix/conv_w$", path):
        return P(None, model_axis)
    if re.search(r"mix/(conv_b|lambda)$", path):
        return P(model_axis) if div(shape[0]) else P(None)
    if re.search(r"mix/gate_[ai]$", path):
        return P(model_axis, None, None) if div(shape[0]) else P(None, None, None)

    # ---- alexnet ----
    # out-channel sharding doubles as the paper's per-GPU group split:
    # Cout is group-major, so when every shard holds WHOLE groups
    # (g % m == 0; ungrouped convs just need divisibility) the grouped
    # conv partitions with no cross-device channel traffic.  Splitting
    # a group across shards is not just slow — XLA's SPMD convolution
    # handler CHECK-fails on it, and a channel-sharded ACTIVATION feeding
    # a grouped conv trips the same check, so the rule is global to the
    # conv stack: if ANY grouped layer cannot nest with m, every conv in
    # the net replicates (the fc tower still shards).
    mc = re.search(r"convs/(\d+)/([wb])$", path)
    if mc:
        convs = getattr(cfg, "convs", None)
        nest = convs is None or all(
            cs.groups == 1 or cs.groups % m == 0 for cs in convs)
        ok = div(shape[-1]) and nest
        if mc.group(2) == "w":
            return P(None, None, None, model_axis) if ok \
                else P(*([None] * 4))
        return P(model_axis) if ok else P(None)
    if re.search(r"fcs/\d+/w$", path):
        return P(None, model_axis) if div(shape[-1]) else P(None, None)
    if re.search(r"fcs/\d+/b$", path):
        return P(model_axis) if div(shape[-1]) else P(None)

    # norms, biases, lora adapters, scalars: replicated
    return P(*([None] * len(shape)))


def _add_fsdp(spec: P, shape, fsdp_axis, mesh: Mesh) -> P:
    """ZeRO-3-style extension: place ``fsdp_axis`` on the largest still-
    unsharded dim of a big weight (>= 1M elems), if divisible.  Gives every
    family (not just MoE) an FSDP layout when a full replica cannot fit."""
    if fsdp_axis is None or fsdp_axis in jax.tree.leaves(tuple(spec)):
        return spec
    n = 1
    for d in shape:
        n *= d
    if len(shape) < 2 or n < 1 << 20:
        return spec
    size = _axis_size(mesh, fsdp_axis)
    cands = [i for i, (s, ax) in enumerate(zip(shape, tuple(spec)))
             if ax is None and s % size == 0]
    if not cands:
        return spec
    i = max(cands, key=lambda i: shape[i])
    parts = list(spec)
    parts[i] = fsdp_axis
    return P(*parts)


def state_sharding(state_shapes, cfg, mesh: Mesh, *, replica_axes=None,
                   fsdp_axis=None, model_axis="model"):
    """NamedSharding tree for a TrainState (or bare params pytree).

    ``replica_axes``: mesh axes carrying the leading replica dim of every
    leaf (param-avg mode); None for unreplicated (grad-avg / serve).
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(state_shapes)
    out = []
    for path, leaf in flat:
        ps = _path_str(path)
        shape = leaf.shape
        extra = 0
        if replica_axes is not None and not re.search(r"(^|/)step$", ps):
            extra += 1
        if re.search(r"(^|/)(blocks/\d+|enc_blocks|dec_blocks)/", ps) and \
                "rem_blocks" not in ps:
            extra += 1           # scan-stacked layer axis
        # optimizer state mirrors param structure after the leading
        # {velocity,mu,nu} key; adam count is a scalar
        if re.search(r"(^|/)count$", ps) or re.search(r"(^|/)step$", ps):
            out.append(NamedSharding(mesh, P(*([None] * leaf.ndim))))
            continue
        base_ndim = leaf.ndim - extra
        base = param_spec(ps, shape[extra:], cfg, mesh,
                          model_axis=model_axis, fsdp_axis=fsdp_axis)
        # NOTE: applying _add_fsdp to DENSE weights was tried and REFUTED
        # (EXPERIMENTS §Perf A5: GSPMD turns the gathers into 20x memory/
        # collective traffic); fsdp stays MoE-expert-only via param_spec.
        assert len(base) <= base_ndim, (ps, shape, base)
        lead = []
        if replica_axes is not None:
            if len(replica_axes) == 0:
                lead.append(None)        # R=1 axis present but unsharded
            else:
                lead.append(replica_axes if len(replica_axes) > 1 else
                            replica_axes[0])
            extra -= 1
        lead.extend([None] * extra)
        spec = P(*lead, *tuple(base) + (None,) * (base_ndim - len(base)))
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def replica_sharding(tree_shapes, mesh: Mesh, *,
                     replica_axes=("pod", "data")):
    """NamedShardings for the mesh-native exchange engine: every leaf's
    leading replica axis over ``replica_axes`` (one replica per mesh
    slice), all other dims unsharded, scalars replicated.  This is the
    device layout ``core.make_mesh_param_avg_step`` expects for both the
    TrainState and each batch — built from ``core.replica_specs`` so the
    two can never diverge."""
    from repro.core.steps import replica_specs
    axes = tuple(a for a in replica_axes if a in mesh.axis_names)
    lead = (axes if len(axes) > 1 else axes[0]) if axes else None
    return jax.tree.map(lambda spec: NamedSharding(mesh, spec),
                        replica_specs(tree_shapes, lead),
                        is_leaf=lambda x: isinstance(x, P))


def batch_sharding(batch_shapes, mesh: Mesh, *, batch_axes=("pod", "data"),
                   inner_axis=None, replicated: bool = False):
    """Batch arrays: leading axis over ``batch_axes`` (this is the replica
    axis in param-avg mode); optional ``inner_axis`` shards the per-replica
    batch dim (dim 1) — used in the FSDP fallback layout."""
    axes = tuple(a for a in batch_axes if a in mesh.axis_names)

    def one(leaf):
        if replicated:
            return NamedSharding(mesh, P(*([None] * leaf.ndim)))
        lead = None
        if axes and leaf.shape[0] % _axis_size(mesh, axes) == 0:
            lead = axes if len(axes) > 1 else axes[0]
        rest = [None] * (leaf.ndim - 1)
        if inner_axis is not None and leaf.ndim > 1 and \
                leaf.shape[1] % _axis_size(mesh, inner_axis) == 0:
            rest[0] = inner_axis
        return NamedSharding(mesh, P(lead, *rest))

    return jax.tree.map(one, batch_shapes)


def cache_sharding(cache_shapes, cfg, mesh: Mesh, *,
                   batch_axes=("pod", "data"), model_axis="model"):
    """Decode-cache sharding: batch over data axes; KV heads over 'model'
    when divisible, else the sequence axis; recurrent states over 'model'
    on their feature dim.  On a mesh without a model axis (the serving
    engine's replica mesh) only the batch/slot axis is sharded."""
    if model_axis not in mesh.axis_names:
        model_axis = None
    m = _axis_size(mesh, model_axis)
    axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    b_axis = (axes if len(axes) > 1 else axes[0]) if axes else None
    bsz = _axis_size(mesh, axes) if axes else 1
    from repro.models import stacked_cache_path
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    out = []
    for path, leaf in flat:
        ps = _path_str(path)
        stacked = stacked_cache_path(ps)
        lead = (None,) if stacked else ()
        shape = leaf.shape[1:] if stacked else leaf.shape
        ba = b_axis if (b_axis and shape[0] % bsz == 0) else None
        if re.search(r"/(k|v)$", ps):                 # (B, S, Hkv, hd)
            if shape[2] % m == 0:
                spec = P(*lead, ba, None, model_axis, None)
            else:
                spec = P(*lead, ba, model_axis, None, None)
        elif re.search(r"/(k_scale|v_scale)$", ps):   # (B, S, Hkv) int8 KV
            # co-shard with the k/v leaves they dequantize (same axis
            # choice) so the decode kernel reads its scales locally
            if shape[2] % m == 0:
                spec = P(*lead, ba, None, model_axis)
            else:
                spec = P(*lead, ba, model_axis, None)
        elif re.search(r"/wkv$", ps):                 # (B, H, hd, hd)
            spec = P(*lead, ba, model_axis if shape[1] % m == 0 else None,
                     None, None)
        elif re.search(r"/(tm_shift|cm_shift|h)$", ps):   # (B, d)
            spec = P(*lead, ba, model_axis if shape[-1] % m == 0 else None)
        elif re.search(r"/conv$", ps):                # (B, 3, d_rnn)
            spec = P(*lead, ba, None,
                     model_axis if shape[-1] % m == 0 else None)
        else:
            spec = P(*lead, *([None] * len(shape)))
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)
