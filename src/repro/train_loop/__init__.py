"""Resumable training sessions: deterministic resume, validation-driven
plateau LR decay, and Table-1 throughput metrics (see session.py)."""
from repro.train_loop.eval import (EVAL_SEED_OFFSET, alexnet_metrics,
                                   lm_metrics, run_eval, take)
from repro.train_loop.metrics import MetricsWriter, percentile, read_jsonl
from repro.train_loop.session import SessionResult, TrainSession
