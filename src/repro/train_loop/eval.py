"""Validation metrics + held-out streams for the session's eval loop.

The paper reports Table 2 as top-1/top-5 *validation error* over days of
training and drops the LR when that error plateaus; this module supplies
the metric functions ``core.make_eval_step`` jits and the held-out
synthetic streams they run on.

Eval streams are STATELESS across the session: each eval pass rebuilds a
freshly-seeded stream and takes its first ``n`` batches, so validation is
a pure function of the parameters.  That is what makes resume trivially
deterministic — there is no eval-stream position to checkpoint.  The eval
seed is offset from the train seed so the two streams never overlap draws
(held-out in the only sense that exists for an infinite synthetic source).
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

EVAL_SEED_OFFSET = 100_003        # train seed + this = eval stream seed


def alexnet_metrics(cfg, *, conv_backend: str = None) -> Callable:
    """(params, batch{images,labels}) -> {loss, top1_err} (both f32)."""
    from repro.models import alexnet
    from repro.models.layers import softmax_xent

    def metric_fn(params, batch):
        logits = alexnet.forward(params, cfg, batch["images"],
                                 conv_backend=conv_backend)
        loss = softmax_xent(logits[:, None, :], batch["labels"][:, None])
        top1 = jnp.mean(
            (jnp.argmax(logits, axis=-1) == batch["labels"]).astype(
                jnp.float32))
        return {"loss": loss, "top1_err": 1.0 - top1}

    return metric_fn


def lm_metrics(cfg) -> Callable:
    """(params, batch) -> {loss, perplexity} for the LM zoo.

    Kernel selection rides on ``cfg.kernels`` — eval runs the same
    backends the train step does (the old ``attn_impl=`` kwarg is gone).
    """
    from repro import models

    def metric_fn(params, batch):
        loss = models.loss_fn(params, cfg, batch)
        return {"loss": loss, "perplexity": jnp.exp(loss)}

    return metric_fn


def take(stream, n: int) -> list:
    """Materialize the first ``n`` host batches of an iterator."""
    it = iter(stream)
    return [next(it) for _ in range(n)]


def run_eval(eval_step, params, batches, device_put) -> dict:
    """Average ``eval_step`` metrics over host ``batches``; returns plain
    floats (the host-side plateau controller consumes these)."""
    acc: dict = {}
    for b in batches:
        m = eval_step(params, device_put(b))
        for k, v in m.items():
            acc[k] = acc.get(k, 0.0) + float(v)
    return {k: v / len(batches) for k, v in acc.items()}
