"""Resumable training sessions — the Theano-MPI/Caffe-style evolution of
the paper's training *script* into a restartable *session*.

A session owns the train loop that ``launch/train.py`` used to inline:

    while step < total:
        batch   -> jitted param-avg step        (mesh or reference engine)
        every eval_every:  jitted eval on a held-out stream
                           -> plateau controller (may divide LR by 10)
        every ckpt_every:  atomic checkpoint (arrays + session meta)

and makes the whole thing deterministic under kill/resume:

* **State** — the TrainState is checkpointed with its step counter and
  restored via ``checkpoint.restore(..., sharding=...)`` onto the SAME
  mesh layout a fresh run would use (``sharding`` is the NamedSharding
  tree the engine device_puts with), so the resumed compiled step is the
  same program over the same device placement.
* **Data** — synthetic streams are seeded iterators; the manifest records
  how many batches the train stream yielded, and resume rebuilds the
  stream and fast-forwards host-side past exactly that many draws (this
  also replays the preprocess RNG, which advances per batch).  The
  prefetch queue needs no persistence: batches a killed run staged but
  never trained on are re-drawn identically.
* **Schedule** — the LR controller's decision state (current LR, best
  metric, bad-eval count) rides in the manifest meta, so a resumed
  session drops the LR at the same step the uninterrupted one does.
* **Eval** — stateless by construction: each eval rebuilds a freshly
  seeded held-out stream (train_loop.eval), so it adds no resume state.

Together: an interrupted-and-resumed run reproduces the uninterrupted
loss trace bit-exactly (asserted per-step in tests/train_loop/ and the CI
``resume-smoke`` job).

Throughput is recorded per step and rolled up into the paper's Table 1
format (images/sec + step-time percentiles) as JSONL via
``train_loop.metrics``; ``benchmarks/session_throughput.py`` consumes it.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax

from repro import checkpoint
from repro.optim import schedules
from repro.train_loop.eval import run_eval, take
from repro.train_loop.metrics import MetricsWriter


@dataclasses.dataclass
class SessionResult:
    start_step: int              # 0 for fresh runs, N when resumed at N
    final_step: int
    state: Any
    losses: list                 # [(step, loss), ...] one per executed step
    evals: list                  # [(step, {metric: float}), ...]
    lr_drops: list               # steps whose eval dropped the LR
    summary: dict                # Table-1 rollup (also last JSONL line)


class TrainSession:
    """See module docstring.  All engine specifics stay with the caller:

    Args:
      state: freshly-initialized TrainState (step 0); doubles as the
        restore template on resume.
      build_step: ``schedule -> jitted step(state, batch)`` factory; called
        once at start and again after every plateau LR drop (the LR is a
        compile-time constant, so each segment runs fully compiled).
      make_stream: zero-arg factory for the host-batch iterator from step
        0 (preprocess + replica reshape included, NO device_put) — must be
        re-creatable so resume can fast-forward a fresh copy.
      controller: LR controller (``schedules.as_controller`` accepts plain
        compiled schedules too).
      device_put: stages a host batch onto the engine's layout.
      sharding: NamedSharding pytree for ``state`` (None = default device).
      eval_step / make_eval_batches / eval_every: validation loop; the
        controller is fed ``plateau_metric`` from each eval's averages.
      images_per_step: global batch items per step (Table 1 throughput
        unit; sequences for the LM zoo).
    """

    def __init__(self, *, state, build_step: Callable, make_stream: Callable,
                 controller=None, steps: int, device_put=None, sharding=None,
                 eval_step=None, make_eval_batches=None, eval_every: int = 0,
                 eval_batches: int = 2, plateau_metric: str = "loss",
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 0,
                 resume: bool = False, prefetch: int = 2,
                 staging: str = "queue", log_every: int = 10,
                 images_per_step: int = 0, metrics_path: Optional[str] = None,
                 run_meta: Optional[dict] = None):
        self.state = state
        self.build_step = build_step
        self.make_stream = make_stream
        self.controller = schedules.as_controller(
            controller if controller is not None
            else schedules.constant(0.01))
        self.steps = steps
        self.device_put = device_put or jax.device_put
        self.sharding = sharding
        self.eval_step = jax.jit(eval_step) if eval_step is not None else None
        self.make_eval_batches = make_eval_batches
        self.eval_every = eval_every if eval_step is not None else 0
        self.eval_batches = eval_batches
        self.plateau_metric = plateau_metric
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.resume = resume
        self.prefetch = prefetch
        self.staging = staging
        self.log_every = log_every
        self.images_per_step = images_per_step
        self.metrics_path = metrics_path
        # run_meta rides in the checkpoint manifest: resume compares it so
        # a run restarted under a different kernel policy/backend gets a
        # loud warning — cross-backend numerics differ in the last bits,
        # which silently breaks the bit-exact-resume guarantee
        self.run_meta = run_meta or {}
        self._ff_batches = 0          # train batches to skip on resume
        self._eval_cache = None       # eval streams are freshly-seeded and
        # deterministic (train_loop.eval), so the batches are identical on
        # every pass — materialize once instead of re-running the source's
        # table build each eval
        if resume and not ckpt_dir:
            raise ValueError("--resume needs a checkpoint directory")

    # ------------------------------------------------------------------
    def _try_restore(self) -> int:
        """Restore the latest complete checkpoint; returns the start step."""
        step = checkpoint.latest_step(self.ckpt_dir) if self.resume else None
        if step is None:
            return 0
        self.state = checkpoint.restore(self.ckpt_dir, step, self.state,
                                        sharding=self.sharding)
        meta = checkpoint.load_meta(self.ckpt_dir, step) or {}
        if "controller" in meta:
            self.controller.load_state_dict(meta["controller"])
        # reuse the checkpoint's autotuned block sizes: re-measuring under
        # timing noise could pick different winners, whose different fp
        # reduction order would silently break bit-exact resume
        from repro.kernels import common as _kernels_common
        _kernels_common.load_cache_state(meta.get("autotune_cache"))
        saved = meta.get("run_meta") or {}
        drift = {k: (saved.get(k), v) for k, v in self.run_meta.items()
                 if k in saved and saved.get(k) != v}
        if drift:
            print("WARNING: resuming under a different configuration than "
                  "the checkpoint was written with — the continued loss "
                  "trace will NOT be bit-exact: "
                  + ", ".join(f"{k}: {a!r} -> {b!r}"
                              for k, (a, b) in sorted(drift.items())),
                  flush=True)
        # the manifest is authoritative for the stream position (== step
        # today, but decoupled so a future loop drawing !=1 batch/step
        # keeps resuming correctly)
        self._ff_batches = meta.get("batches_consumed", step)
        return step

    def _save(self, step: int):
        from repro.kernels import common as _kernels_common
        checkpoint.save(
            self.ckpt_dir, step, self.state,
            meta={"controller": self.controller.state_dict(),
                  "batches_consumed": step,
                  "plateau_metric": self.plateau_metric,
                  "run_meta": self.run_meta,
                  "autotune_cache": _kernels_common.cache_state()})

    def _run_eval(self, step: int, writer, result: SessionResult) -> bool:
        """One validation pass; returns True iff the LR dropped."""
        if self._eval_cache is None:
            self._eval_cache = take(self.make_eval_batches(),
                                    self.eval_batches)
        avg = run_eval(self.eval_step, self.state.params, self._eval_cache,
                       self.device_put)
        dropped = self.controller.update(avg[self.plateau_metric])
        writer.eval(step, avg, dropped)
        result.evals.append((step, avg))
        if dropped:
            result.lr_drops.append(step)
            print(f"step {step:5d} eval "
                  f"{self.plateau_metric}={avg[self.plateau_metric]:.4f} "
                  f"plateaued -> lr {self.controller.lr:.2e}", flush=True)
        return dropped

    # ------------------------------------------------------------------
    def run(self) -> SessionResult:
        from repro.data import make_loader        # local: keeps import light

        start = self._try_restore() if self.ckpt_dir else 0
        result = SessionResult(start, start, self.state, [], [], [], {})
        if start >= self.steps:
            print(f"checkpoint at step {start} >= --steps {self.steps}; "
                  "nothing to do", flush=True)
            return result

        writer = MetricsWriter(
            self.metrics_path, images_per_step=self.images_per_step,
            resume_step=start if start else None)
        loader = None
        compiling = True                  # first call of each jit compiles
        t_session = time.perf_counter()
        try:
            stream = self.make_stream()
            for _ in range(self._ff_batches):   # deterministic fast-forward
                next(stream)
            # loader construction starts the worker thread, so everything
            # from here on runs under the finally that closes it
            loader = make_loader(stream, prefetch=self.prefetch,
                                 staging=self.staging,
                                 device_put=self.device_put)
            sched_fn = self.controller.schedule()
            step_fn = self.build_step(sched_fn)
            # a metrics trace needs the loss + honest wall time every step,
            # which costs a host sync per step; without it, sync only at
            # log/eval boundaries and keep jax's async dispatch pipelined
            per_step_sync = self.metrics_path is not None
            for i in range(start, self.steps):
                t0 = time.perf_counter()
                batch = next(loader)
                stage_wait_ms = loader.last_wait_ms
                self.state, loss = step_fn(self.state, batch)
                # pinned staging: the slot this batch occupies may be
                # overwritten only after this step's reads finish — hand
                # the loader a fence token of the dispatched step
                loader.fence(loss)
                at_log = (i + 1) % self.log_every == 0 or i == start
                if per_step_sync or at_log:
                    loss_f = float(loss)          # blocks on the device
                    result.losses.append((i + 1, loss_f))
                if per_step_sync:
                    # loss-scaling state (NumericsPolicy) rides the
                    # TrainState as replica-identical scalars; trace them
                    # so a run's scale trajectory and skip count are
                    # auditable from the JSONL alone
                    ns = getattr(self.state, "numerics", None)
                    # compile steps are logged, excluded from percentiles
                    writer.train(i + 1, loss_f, float(sched_fn(i)),
                                 time.perf_counter() - t0,
                                 timed=not compiling,
                                 stage_wait_ms=stage_wait_ms,
                                 loss_scale=float(ns["scale"])
                                 if ns is not None else None,
                                 skipped_steps=int(ns["skipped"])
                                 if ns is not None else None)
                compiling = False
                if at_log:
                    print(f"step {i + 1:5d} loss {loss_f:.4f} "
                          f"({(time.perf_counter() - t_session) / (i + 1 - start):.3f}"
                          "s/step)", flush=True)
                if self.eval_every and (i + 1) % self.eval_every == 0:
                    if self._run_eval(i + 1, writer, result):
                        sched_fn = self.controller.schedule()
                        step_fn = self.build_step(sched_fn)
                        compiling = True
                if self.ckpt_dir and self.ckpt_every and \
                        (i + 1) % self.ckpt_every == 0:
                    self._save(i + 1)
                result.final_step = i + 1
        finally:
            if loader is not None:
                loader.close()            # never leak the worker thread
            result.state = self.state     # original buffer was donated
            result.summary = writer.summary(result.final_step)
            writer.close()
        return result
