"""Session metrics: JSONL trace + Table-1-style throughput summary.

One line per event, ``kind`` discriminated:

    {"kind": "train", "step": 7, "loss": 4.31, "lr": 0.01,
     "step_time_ms": 12.4, "images_per_sec": 2580.6}
    {"kind": "eval", "step": 10, "loss": 4.1, "top1_err": 0.87,
     "lr_dropped": false}
    {"kind": "summary", "steps": 100, "images_per_sec": 2612.0,
     "step_ms_p50": 12.2, "step_ms_p90": 13.0, "step_ms_p99": 19.8, ...}

``images_per_sec`` is the paper's Table 1 unit (for LM archs it carries
sequences/sec — same field, the batch item is a sequence).  The trace is
the session's single source of truth: tests diff resumed-vs-uninterrupted
``train`` lines bit-exactly, and ``benchmarks/session_throughput.py`` turns
the ``summary`` line into benchmark rows.

On resume, entries past the restored step are dropped (they came from the
killed run's un-checkpointed tail) and the file continues in place, so one
session — however many restarts — yields one coherent trace.
"""
from __future__ import annotations

import json
import math
import os
from typing import Optional


def percentile(sorted_vals, q: float) -> float:
    """Nearest-rank percentile (q in [0,100]) of an ascending list:
    the smallest value with at least q% of the sample at or below it."""
    if not sorted_vals:
        return float("nan")
    i = min(len(sorted_vals) - 1,
            max(0, math.ceil(q / 100 * len(sorted_vals)) - 1))
    return sorted_vals[i]


def read_jsonl(path: str, kind: str = None, *,
               tolerant: bool = False) -> list:
    """Parse a metrics file; optionally filter to one ``kind``.

    ``tolerant`` skips unparseable lines — a run SIGKILLed mid-write
    leaves a torn final line, and the resume path must shrug it off (the
    torn record is part of the un-checkpointed tail it drops anyway).
    """
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except ValueError:
                if tolerant:
                    continue
                raise
            if kind is None or rec.get("kind") == kind:
                out.append(rec)
    return out


class MetricsWriter:
    """Append-only JSONL writer with throughput bookkeeping."""

    def __init__(self, path: Optional[str], *, images_per_step: int = 0,
                 resume_step: int = None):
        self._path = path
        self._f = None
        self._images = images_per_step
        self._times_ms: list = []
        self._stage_ms: list = []
        self._last_scale: Optional[float] = None
        self._last_skipped: Optional[int] = None
        if path is None:
            return
        if resume_step is not None and os.path.exists(path):
            # drop the killed run's tail beyond the checkpoint we resumed
            # (tolerant: a SIGKILL mid-write leaves a torn final line)
            kept = [r for r in read_jsonl(path, tolerant=True)
                    if r.get("step", 0) <= resume_step
                    and r.get("kind") != "summary"]
            with open(path, "w") as f:
                for r in kept:
                    f.write(json.dumps(r) + "\n")
            self._f = open(path, "a")
        else:
            os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
            self._f = open(path, "w")

    def _write(self, rec: dict):
        if self._f is not None:
            self._f.write(json.dumps(rec) + "\n")
            self._f.flush()

    def train(self, step: int, loss: float, lr: float, step_time_s: float,
              *, timed: bool = True, stage_wait_ms: Optional[float] = None,
              loss_scale: Optional[float] = None,
              skipped_steps: Optional[int] = None):
        """``timed=False`` marks a compile step: logged, but excluded from
        the throughput percentiles (it would dominate p99).
        ``stage_wait_ms`` is how long the trainer was blocked waiting for
        this step's batch to be staged (loader stall — observable loading
        overlap, not inferred).  ``loss_scale``/``skipped_steps`` trace the
        NumericsPolicy loss-scaling state (only written when scaling is
        on): the current scale and the cumulative non-finite-skip count."""
        ms = step_time_s * 1e3
        if timed:
            self._times_ms.append(ms)
        rec = {"kind": "train", "step": step, "loss": loss, "lr": lr,
               "step_time_ms": round(ms, 3)}
        if stage_wait_ms is not None:
            rec["stage_wait_ms"] = round(stage_wait_ms, 3)
            if timed:
                self._stage_ms.append(stage_wait_ms)
        if loss_scale is not None:
            rec["loss_scale"] = loss_scale
            self._last_scale = loss_scale
        if skipped_steps is not None:
            rec["skipped_steps"] = skipped_steps
            self._last_skipped = skipped_steps
        if not timed:
            rec["compile"] = True
        if self._images and timed and step_time_s > 0:
            rec["images_per_sec"] = round(self._images / step_time_s, 1)
        self._write(rec)

    def eval(self, step: int, metrics: dict, lr_dropped: bool):
        self._write({"kind": "eval", "step": step, **metrics,
                     "lr_dropped": lr_dropped})

    def summary(self, steps: int) -> dict:
        """Table-1-format rollup over this process's timed steps (excludes
        the compile step — callers time steady-state only)."""
        ts = sorted(self._times_ms)
        total_s = sum(ts) / 1e3
        out = {"kind": "summary", "steps": steps,
               "timed_steps": len(ts),
               "step_ms_p50": round(percentile(ts, 50), 3),
               "step_ms_p90": round(percentile(ts, 90), 3),
               "step_ms_p99": round(percentile(ts, 99), 3)}
        if self._stage_ms:
            out["stage_wait_ms_mean"] = round(
                sum(self._stage_ms) / len(self._stage_ms), 3)
            out["stage_wait_ms_p90"] = round(
                percentile(sorted(self._stage_ms), 90), 3)
        if self._images and total_s > 0:
            out["images_per_sec"] = round(len(ts) * self._images / total_s, 1)
        if self._last_scale is not None:
            out["loss_scale"] = self._last_scale
        if self._last_skipped is not None:
            out["skipped_steps"] = self._last_skipped
        self._write(out)
        return out

    def close(self):
        if self._f is not None:
            self._f.close()
            self._f = None
