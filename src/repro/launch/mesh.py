"""Production mesh definitions (TPU v5e target).

Single pod: 256 chips as (data=16, model=16).  Multi-pod: 2 pods = 512
chips as (pod=2, data=16, model=16) — the 'pod' axis crosses the
inter-pod links (DCN/optical), mirroring the paper's "GPUs under different
PCI-E switches" locality boundary (§4.4).

Defined as FUNCTIONS so importing this module never touches jax device
state; only the dry-run entrypoint forces the 512-device host platform.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 2, pod: int = 1):
    """Small mesh over host devices for tests/examples (requires
    XLA_FLAGS=--xla_force_host_platform_device_count set by the caller)."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def make_replica_mesh(n_replicas: int, *, pod: int = 1):
    """Mesh for the mesh-native exchange engine: one device per replica,
    replica axes ('pod','data') [or just ('data',)], no inner model axis.
    Uses the first ``n_replicas`` devices, so it composes with processes
    that have more devices than replicas (the extras idle — the engine is
    pure data parallelism, exactly the paper's regime)."""
    import numpy as np
    devs = jax.devices()
    assert n_replicas <= len(devs), (n_replicas, len(devs))
    assert n_replicas % pod == 0, (n_replicas, pod)
    arr = np.asarray(devs[:n_replicas])
    if pod > 1:
        return jax.sharding.Mesh(arr.reshape(pod, n_replicas // pod),
                                 ("pod", "data"))
    return jax.sharding.Mesh(arr, ("data",))


def replica_axes_of(mesh):
    """The mesh axes that carry replicas (everything but 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def mesh_chips(mesh) -> int:
    return mesh.devices.size
