"""Training driver: resumable sessions on this host's devices.

Builds the model / loss / data streams for an arch, picks the exchange
engine, and hands the loop to ``repro.train_loop.TrainSession`` — which
owns checkpoint/resume, the validation + plateau-LR loop, and Table-1
throughput metrics (docs/training.md).

Runs the paper's parameter-averaging data parallelism end-to-end on this
host's devices (set REPRO_DEVICES=N to fan out over N host devices — this
driver sets XLA_FLAGS itself when the variable is present, BEFORE importing
jax, so it must stay the first import in the process).

NOTE: without --smoke, --arch alexnet now means the FULL 227px net (the
seed silently swapped in the smoke config whenever --image-size < 128);
pass --smoke for CPU-sized runs, --image-size to override either config.

Examples:
    REPRO_DEVICES=8 PYTHONPATH=src python -m repro.launch.train \
        --arch olmo-1b --smoke --steps 50 --replicas 4
    PYTHONPATH=src python -m repro.launch.train --arch alexnet --smoke \
        --steps 100
    # checkpoint every 10 steps, then pick up where a killed run stopped:
    PYTHONPATH=src python -m repro.launch.train --arch alexnet --smoke \
        --steps 100 --ckpt-dir /tmp/ck --ckpt-every 10 --resume
    # the paper's LR rule: eval every 20 steps, /10 when error plateaus:
    PYTHONPATH=src python -m repro.launch.train --arch alexnet --smoke \
        --steps 200 --schedule plateau --eval-every 20
"""
import os

if os.environ.get("REPRO_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DEVICES"])

# ruff: noqa: E402
import argparse
import dataclasses

import jax
import numpy as np

from repro import models
from repro.configs import (ALEXNET, ALEXNET_FAITHFUL, ALEXNET_FAITHFUL_SMOKE,
                           ALEXNET_SMOKE, get_config, reduced)
from repro.core import (ExchangeConfig, init_param_avg_state, make_eval_step,
                        make_mesh_param_avg_step, make_param_avg_step,
                        replica_spread, reshape_for_replicas)
from repro.kernels.common import KernelPolicy
from repro.launch.mesh import make_replica_mesh
from repro.numerics import get_policy
from repro.sharding.specs import replica_sharding, state_sharding
from repro.data import synthetic
from repro.models import alexnet as alexnet_mod
from repro.optim import schedules
from repro.optim.optimizers import for_numerics, get_optimizer
from repro.train_loop import (EVAL_SEED_OFFSET, TrainSession, alexnet_metrics,
                              lm_metrics)


@dataclasses.dataclass
class Build:
    """Everything arch-specific the session needs."""
    cfg: object
    init: callable
    loss: callable                    # loss(params, batch) -> scalar
    make_stream: callable             # () -> fresh host-batch iterator
    make_eval_batches: callable       # () -> fresh held-out iterator
    eval_metric_fn: callable          # (params, batch) -> {name: scalar}
    plateau_metric: str               # the metric the LR controller tracks


def make_policy(args) -> KernelPolicy:
    """One KernelPolicy from the CLI: ``--kernel-backend`` is the global
    default, ``--attn-impl`` / ``--conv-backend`` stay as per-op
    overrides.  The policy rides on the config — nothing downstream
    takes kernel kwargs anymore."""
    return KernelPolicy(backend=args.kernel_backend,
                        attention=args.attn_impl,
                        conv2d=args.conv_backend)


def build_lm(args, numerics) -> Build:
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg, n_layers=args.layers or 2,
                      d_model=args.d_model or 256)
    # numerics must land on cfg HERE, before the init/loss closures below
    # capture it — a later dataclasses.replace on build.cfg rebinds the
    # field but leaves the closures training fp32 params
    cfg = dataclasses.replace(cfg, kernels=make_policy(args),
                              numerics=numerics)

    def add_extras(b):
        out = {"tokens": b["tokens"], "labels": b["labels"]}
        bsz, s = b["tokens"].shape
        if cfg.family == "encdec":
            out["frames"] = np.random.default_rng(0).normal(
                size=(bsz, max(s // 4, 8), cfg.d_model)).astype(np.float32)
        if cfg.family == "vlm":
            n = cfg.n_image_tokens
            out["image_embeds"] = np.zeros((bsz, n, cfg.d_model), np.float32)
            mask = np.zeros((bsz, s), bool)
            mask[:, :n] = True
            out["image_mask"] = mask
        return out

    def make_stream():
        return map(add_extras, synthetic.markov_lm(
            cfg.vocab_size, args.batch, args.seq_len, seed=args.seed))

    def make_eval_batches():
        # same Markov chain (table from args.seed), held-out sample path
        return map(add_extras, synthetic.markov_lm(
            cfg.vocab_size, args.batch, args.seq_len, seed=args.seed,
            sample_seed=args.seed + EVAL_SEED_OFFSET))

    def loss(params, batch):
        return models.loss_fn(params, cfg, batch)

    return Build(cfg, lambda r: models.init(r, cfg), loss, make_stream,
                 make_eval_batches, lm_metrics(cfg),
                 plateau_metric="loss")


def build_alexnet(args, error, numerics) -> Build:
    if args.faithful:
        cfg = ALEXNET_FAITHFUL_SMOKE if args.smoke else ALEXNET_FAITHFUL
    else:
        cfg = ALEXNET_SMOKE if args.smoke else ALEXNET
    # see build_lm: the closures capture cfg now, so numerics goes on now
    cfg = dataclasses.replace(cfg, kernels=make_policy(args),
                              numerics=numerics)
    if args.image_size is not None:
        try:
            cfg.feature_hw(args.image_size)   # conv/pool windows must fit
        except ValueError as e:
            error(str(e))
        cfg = dataclasses.replace(cfg, image_size=args.image_size)
    from repro.data.preprocess import make_image_preprocess
    mean = synthetic.mean_image(
        synthetic.blob_images(cfg.n_classes, args.batch, cfg.image_size + 8,
                              seed=args.seed + 1), 2)

    def make_stream():
        # fresh preprocess per stream: its RNG advances once per batch, so
        # resume's fast-forward replays crops/flips exactly
        prep = make_image_preprocess(mean, cfg.image_size, seed=args.seed)
        return map(prep, synthetic.blob_images(
            cfg.n_classes, args.batch, cfg.image_size + 8, seed=args.seed))

    def make_eval_batches():
        es = args.seed + EVAL_SEED_OFFSET
        prep = make_image_preprocess(mean, cfg.image_size, seed=es)
        return map(prep, synthetic.blob_images(
            cfg.n_classes, args.batch, cfg.image_size + 8, seed=es))

    def loss(params, batch):
        return alexnet_mod.loss_fn(params, cfg, batch["images"],
                                   batch["labels"])

    return Build(cfg, lambda r: alexnet_mod.init(r, cfg), loss, make_stream,
                 make_eval_batches, alexnet_metrics(cfg),
                 plateau_metric="top1_err")


def make_controller(args):
    if args.schedule == "constant":
        return schedules.StaticController(schedules.constant(args.lr))
    if args.schedule == "wsd":
        return schedules.StaticController(
            schedules.wsd(args.lr, args.steps // 10,
                          int(args.steps * 0.7), args.steps // 5))
    if args.schedule == "cosine":
        return schedules.StaticController(
            schedules.cosine(args.lr, args.steps // 10, args.steps))
    return schedules.plateau_decay(
        args.lr, factor=args.plateau_factor, patience=args.plateau_patience,
        threshold=args.plateau_threshold)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="alexnet")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--image-size", type=int, default=None,
                    help="override the AlexNet config's image size (errors "
                    "if the conv stack cannot consume it; default: the "
                    "config's own size — 227 full, 64 smoke)")
    ap.add_argument("--replicas", type=int, default=None)
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="size of the mesh's 'model' axis: shards grouped "
                    "conv / FC output channels (and the LM zoo's tensor-"
                    "parallel dims) across devices — the paper's intra-"
                    "layer 2-GPU split is --faithful --model-parallel 2. "
                    "Uses the reference engine (replicas x model mesh); "
                    "requires replicas * model-parallel <= devices")
    ap.add_argument("--faithful", action="store_true",
                    help="paper-faithful AlexNet: 2-group conv2/4/5 + LRN "
                    "after pool1/pool2 (the Caffe reference topology); "
                    "without it the legacy PR-2 net is trained")
    ap.add_argument("--strategy", default="all_reduce")
    ap.add_argument("--engine", default="auto",
                    choices=["auto", "mesh", "reference"],
                    help="mesh: shard_map + real collectives (one replica "
                    "per device); reference: leading-axis-R vmap + GSPMD "
                    "(supports replicas < devices via tensor parallelism); "
                    "auto: mesh when replicas == devices > 1")
    ap.add_argument("--sync-every", type=int, default=1)
    ap.add_argument("--exchange-delay", type=int, default=0, choices=[0, 1],
                    help="0: synchronous exchange after the update (the "
                    "paper's path); 1: one-step-stale overlapped exchange "
                    "— the collective for step t's params runs inside step "
                    "t+1's program, concurrent with its forward/backward")
    ap.add_argument("--exchange-compression", default="none",
                    choices=["none", "bf16", "topk"],
                    help="wire compression for the exchange: bf16 halves "
                    "the moved bytes; topk sends the top-k-magnitude "
                    "entries of the delta-from-consensus with error-"
                    "feedback residuals (needs --exchange-delay 1)")
    ap.add_argument("--topk-frac", type=float, default=0.01,
                    help="kept fraction per leaf for --exchange-"
                    "compression topk (1.0 = identity, bit-equal to none)")
    ap.add_argument("--replica-exec", default="vmap",
                    choices=["vmap", "scan"],
                    help="reference engine only: how the R independent "
                    "replicas execute — vmap (batched) or scan "
                    "(sequential lax.map; each replica's smaller batch is "
                    "more cache-resident on CPU hosts)")
    ap.add_argument("--staging", default="queue",
                    choices=["queue", "pinned"],
                    help="batch staging: queue = prefetch handoff queue; "
                    "pinned = double-buffered preallocated host buffers "
                    "with fence-gated reuse (data/pipeline.py)")
    ap.add_argument("--optimizer", default="sgd_momentum")
    ap.add_argument("--schedule", default="constant",
                    choices=["constant", "wsd", "cosine", "plateau"],
                    help="plateau = the paper's rule: divide LR by 10 when "
                    "the validation metric plateaus (needs --eval-every)")
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--plateau-factor", type=float, default=0.1)
    ap.add_argument("--plateau-patience", type=int, default=2)
    ap.add_argument("--plateau-threshold", type=float, default=1e-3)
    ap.add_argument("--kernel-backend", default="auto",
                    choices=["auto", "xla", "pallas"],
                    help="global kernel policy for EVERY op family "
                    "(attention/rglru/rwkv6/conv2d): pallas = the "
                    "compiled kernels everywhere (interpreter on CPU "
                    "hosts — correctness-equivalent), xla = library "
                    "paths, auto = pallas exactly where it compiles")
    ap.add_argument("--attn-impl", default=None,
                    choices=["auto", "xla", "chunked", "qloop", "flash"],
                    help="per-op override of --kernel-backend for "
                    "attention")
    ap.add_argument("--conv-backend", default=None,
                    choices=["xla", "pallas", "pallas_im2col_ref"],
                    help="per-op override of --kernel-backend for conv: "
                    "pallas = fused implicit-GEMM kernel; "
                    "pallas_im2col_ref = two-stage XLA-im2col + Pallas "
                    "GEMM parity path")
    ap.add_argument("--numerics", default="fp32",
                    choices=["fp32", "bf16"],
                    help="NumericsPolicy preset: fp32 = the pre-policy "
                    "default (bit-equal); bf16 = bf16 params/compute with "
                    "fp32 master weights in the optimizer state and "
                    "dynamic loss scaling (docs/numerics.md)")
    ap.add_argument("--kv-cache-dtype", default="auto",
                    choices=["auto", "fp32", "bf16", "int8"],
                    help="decode KV-cache storage dtype (serving only "
                    "matters for --arch LMs; auto follows the model "
                    "dtype, int8 quantizes per head/slot — 2x slots per "
                    "byte)")
    ap.add_argument("--prefetch", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest complete checkpoint in "
                    "--ckpt-dir and continue bit-exactly (fresh start if "
                    "the directory has none)")
    ap.add_argument("--eval-every", type=int, default=0,
                    help="validation pass every N steps (0 = off)")
    ap.add_argument("--eval-batches", type=int, default=2)
    ap.add_argument("--metrics-out", default=None,
                    help="JSONL trace path (train/eval/summary records, "
                    "docs/training.md); implies per-step host sync")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    if args.schedule == "plateau" and args.eval_every <= 0:
        ap.error("--schedule plateau needs --eval-every > 0 (the plateau "
                 "rule is driven by validation metrics)")
    if args.resume and not args.ckpt_dir:
        ap.error("--resume needs --ckpt-dir")

    n_dev = jax.device_count()
    mp = args.model_parallel
    n_rep = args.replicas or (n_dev // mp if mp > 1 else n_dev)
    assert args.batch % n_rep == 0, (args.batch, n_rep)
    if mp > 1:
        if args.engine == "mesh":
            ap.error("--model-parallel needs the reference engine (the "
                     "mesh engine's shard_map owns every non-replica axis)")
        if n_rep * mp > n_dev:
            ap.error(f"--replicas {n_rep} x --model-parallel {mp} needs "
                     f"{n_rep * mp} devices, have {n_dev} "
                     "(set REPRO_DEVICES)")

    try:
        exch = ExchangeConfig(strategy=args.strategy,
                              compression=args.exchange_compression,
                              topk_frac=args.topk_frac,
                              delay=args.exchange_delay,
                              sync_every=args.sync_every)
    except ValueError as e:
        ap.error(str(e))

    npol = get_policy(args.numerics)
    if args.kv_cache_dtype != "auto":
        npol = dataclasses.replace(npol, kv_cache_dtype=args.kv_cache_dtype)

    if args.arch == "alexnet":
        build = build_alexnet(args, ap.error, npol)
    else:
        build = build_lm(args, npol)
    build.cfg = dataclasses.replace(build.cfg, exchange=exch)

    opt = for_numerics(get_optimizer(args.optimizer), npol)
    controller = make_controller(args)

    engine = args.engine
    if engine == "auto":
        engine = "mesh" if (n_dev > 1 and n_rep == n_dev and mp == 1
                            and args.replica_exec == "vmap") \
            else "reference"
    if engine == "mesh" and args.replica_exec == "scan":
        ap.error("--replica-exec scan is a reference-engine execution mode "
                 "(the mesh engine runs one replica per device); use "
                 "--engine reference")

    rng = jax.random.PRNGKey(args.seed)
    state = init_param_avg_state(rng, build.init, opt, n_rep, exchange=exch,
                                 numerics=npol)

    sharding = None
    if engine == "mesh":
        # mesh-native engine: shard_map over ('data',), one replica per
        # device, exchange lowers to real collectives (docs/architecture.md)
        mesh = make_replica_mesh(n_rep)
        sharding = replica_sharding(state, mesh, replica_axes=("data",))
        state = jax.device_put(state, sharding)
        put = lambda b: jax.device_put(  # noqa: E731
            b, replica_sharding(b, mesh, replica_axes=("data",)))

        def build_step(sched):
            # donate the TrainState: params/opt-state update in place
            # instead of allocating a fresh copy of the state every step
            return jax.jit(make_mesh_param_avg_step(
                build.loss, opt, sched, mesh=mesh, strategy=exch,
                replica_axes=("data",), numerics=npol),
                donate_argnums=0)
    else:
        out_shardings = None
        if n_dev > 1:
            from jax.sharding import NamedSharding, PartitionSpec as P
            mesh = jax.make_mesh((n_rep, mp if mp > 1 else n_dev // n_rep),
                                 ("data", "model"))
            if mp > 1:
                # replica x model layout: replicas over 'data', grouped
                # conv / FC output channels (and the LM zoo's tensor-
                # parallel dims) over 'model' via sharding/specs.py — the
                # paper's intra-layer split, run by GSPMD
                sharding = state_sharding(state, build.cfg, mesh,
                                          replica_axes=("data",))
            else:
                sharding = replica_sharding(state, mesh,
                                            replica_axes=("data",))
            state = jax.device_put(state, sharding)
            put = lambda b: jax.device_put(  # noqa: E731
                b, replica_sharding(b, mesh, replica_axes=("data",)))
            # pin the state's layout as a loop invariant: left to GSPMD,
            # the output sharding of a step can drift from the layout a
            # fresh device_put (or a sharding-aware restore) produces,
            # compiling a second executable whose reduction order differs
            # in the last float bits — which would break bit-exact resume
            out_shardings = (sharding, NamedSharding(mesh, P()))
        else:
            put = jax.device_put

        def build_step(sched):
            kw = {} if out_shardings is None else \
                {"out_shardings": out_shardings}
            return jax.jit(make_param_avg_step(
                build.loss, opt, sched, strategy=exch,
                replica_exec=args.replica_exec, numerics=npol),
                donate_argnums=0, **kw)

    session = TrainSession(
        state=state, build_step=build_step,
        make_stream=lambda: map(
            lambda b: reshape_for_replicas(b, n_rep), build.make_stream()),
        controller=controller, steps=args.steps, device_put=put,
        sharding=sharding,
        eval_step=make_eval_step(build.eval_metric_fn)
        if args.eval_every else None,
        make_eval_batches=build.make_eval_batches,
        eval_every=args.eval_every, eval_batches=args.eval_batches,
        plateau_metric=build.plateau_metric,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        resume=args.resume, prefetch=args.prefetch, staging=args.staging,
        log_every=args.log_every, images_per_step=args.batch,
        metrics_path=args.metrics_out,
        run_meta={"kernels": make_policy(args).describe(),
                  "numerics": npol.describe(),
                  "engine": engine, "strategy": args.strategy,
                  "exchange": exch.describe(),
                  "replica_exec": args.replica_exec,
                  "staging": args.staging,
                  "model_parallel": mp})

    print(f"arch={getattr(build.cfg, 'name', args.arch)} replicas={n_rep} "
          f"devices={n_dev} model_parallel={mp} "
          f"engine={engine} exchange={exch.describe()} "
          f"replica_exec={args.replica_exec} staging={args.staging} "
          f"kernels={make_policy(args).describe()} "
          f"numerics={npol.describe()}"
          + (f" resume_from={args.ckpt_dir}" if args.resume else ""))
    result = session.run()
    spread = float(replica_spread(result.state.params))
    summ = result.summary
    through = (f"; images/sec {summ['images_per_sec']} "
               f"p50 {summ.get('step_ms_p50')}ms "
               f"p99 {summ.get('step_ms_p99')}ms"
               if "images_per_sec" in summ else "")
    print(f"done: steps {result.start_step} -> {result.final_step}; "
          f"final loss "
          f"{result.losses[-1][1] if result.losses else float('nan'):.4f}; "
          f"replica spread {spread:.2e}" + through
          + (f"; lr drops at {result.lr_drops}" if result.lr_drops else ""))


if __name__ == "__main__":
    main()
