"""Training driver: real steps on host devices.

Runs the paper's parameter-averaging data parallelism end-to-end on this
host's devices (set REPRO_DEVICES=N to fan out over N host devices — this
driver sets XLA_FLAGS itself when the variable is present, BEFORE importing
jax, so it must stay the first import in the process).

Examples:
    REPRO_DEVICES=8 PYTHONPATH=src python -m repro.launch.train \
        --arch olmo-1b --smoke --steps 50 --replicas 4
    PYTHONPATH=src python -m repro.launch.train --arch alexnet --steps 100
"""
import os

if os.environ.get("REPRO_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DEVICES"])

# ruff: noqa: E402
import argparse
import time

import jax
import numpy as np

from repro import checkpoint, models
from repro.configs import ALEXNET, ALEXNET_SMOKE, get_config, reduced
from repro.core import (init_param_avg_state, make_mesh_param_avg_step,
                        make_param_avg_step, reshape_for_replicas,
                        replica_spread)
from repro.launch.mesh import make_replica_mesh
from repro.sharding.specs import replica_sharding
from repro.data import PrefetchLoader, synthetic
from repro.models import alexnet as alexnet_mod
from repro.optim import schedules
from repro.optim.optimizers import get_optimizer


def build_lm(args):
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg, n_layers=args.layers or 2,
                      d_model=args.d_model or 256)
    source = synthetic.markov_lm(cfg.vocab_size, args.batch, args.seq_len,
                                 seed=args.seed)

    def add_extras(b):
        out = {"tokens": b["tokens"], "labels": b["labels"]}
        bsz, s = b["tokens"].shape
        if cfg.family == "encdec":
            out["frames"] = np.random.default_rng(0).normal(
                size=(bsz, max(s // 4, 8), cfg.d_model)).astype(np.float32)
        if cfg.family == "vlm":
            n = cfg.n_image_tokens
            out["image_embeds"] = np.zeros((bsz, n, cfg.d_model), np.float32)
            mask = np.zeros((bsz, s), bool)
            mask[:, :n] = True
            out["image_mask"] = mask
        return out

    def loss(params, batch):
        return models.loss_fn(params, cfg, batch, attn_impl=args.attn_impl)

    init = lambda r: models.init(r, cfg)  # noqa: E731
    return cfg, init, loss, map(add_extras, source)


def build_alexnet(args):
    cfg = ALEXNET_SMOKE if (args.smoke or args.image_size < 128) else ALEXNET
    source = synthetic.blob_images(cfg.n_classes, args.batch,
                                   cfg.image_size + 8, seed=args.seed)
    mean = synthetic.mean_image(
        synthetic.blob_images(cfg.n_classes, args.batch, cfg.image_size + 8,
                              seed=args.seed + 1), 2)
    from repro.data.preprocess import make_image_preprocess
    prep = make_image_preprocess(mean, cfg.image_size, seed=args.seed)

    def loss(params, batch):
        return alexnet_mod.loss_fn(params, cfg, batch["images"],
                                   batch["labels"],
                                   conv_backend=args.conv_backend)

    init = lambda r: alexnet_mod.init(r, cfg)  # noqa: E731
    return cfg, init, loss, map(prep, source)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="alexnet")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--image-size", type=int, default=64)
    ap.add_argument("--replicas", type=int, default=None)
    ap.add_argument("--strategy", default="all_reduce")
    ap.add_argument("--engine", default="auto",
                    choices=["auto", "mesh", "reference"],
                    help="mesh: shard_map + real collectives (one replica "
                    "per device); reference: leading-axis-R vmap + GSPMD "
                    "(supports replicas < devices via tensor parallelism); "
                    "auto: mesh when replicas == devices > 1")
    ap.add_argument("--sync-every", type=int, default=1)
    ap.add_argument("--optimizer", default="sgd_momentum")
    ap.add_argument("--schedule", default="constant")
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--attn-impl", default="auto")
    ap.add_argument("--conv-backend", default="xla",
                    choices=["xla", "pallas", "pallas_im2col_ref"],
                    help="pallas: fused implicit-GEMM kernel (compiled on "
                    "TPU, interpreter elsewhere); pallas_im2col_ref: "
                    "two-stage XLA-im2col + Pallas GEMM parity path")
    ap.add_argument("--prefetch", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    n_dev = jax.device_count()
    n_rep = args.replicas or n_dev
    assert args.batch % n_rep == 0, (args.batch, n_rep)

    if args.arch == "alexnet":
        cfg, init, loss, source = build_alexnet(args)
    else:
        cfg, init, loss, source = build_lm(args)

    opt = get_optimizer(args.optimizer)
    if args.schedule == "constant":
        sched = schedules.constant(args.lr)
    elif args.schedule == "wsd":
        sched = schedules.wsd(args.lr, args.steps // 10,
                              int(args.steps * 0.7), args.steps // 5)
    else:
        sched = schedules.cosine(args.lr, args.steps // 10, args.steps)

    engine = args.engine
    if engine == "auto":
        engine = "mesh" if (n_dev > 1 and n_rep == n_dev) else "reference"

    rng = jax.random.PRNGKey(args.seed)
    state = init_param_avg_state(rng, init, opt, n_rep)

    if engine == "mesh":
        # mesh-native engine: shard_map over ('data',), one replica per
        # device, exchange lowers to real collectives (docs/architecture.md)
        mesh = make_replica_mesh(n_rep)
        # donate the TrainState: params/opt-state update in place instead
        # of allocating a fresh copy of the full state every step
        step_fn = jax.jit(make_mesh_param_avg_step(
            loss, opt, sched, mesh=mesh, strategy=args.strategy,
            replica_axes=("data",), sync_every=args.sync_every),
            donate_argnums=0)
        state = jax.device_put(state, replica_sharding(
            state, mesh, replica_axes=("data",)))
        put = lambda b: jax.device_put(  # noqa: E731
            b, replica_sharding(b, mesh, replica_axes=("data",)))
    else:
        step_fn = jax.jit(make_param_avg_step(loss, opt, sched,
                                              strategy=args.strategy,
                                              sync_every=args.sync_every),
                          donate_argnums=0)
        if n_dev > 1:
            mesh = jax.make_mesh((n_rep, n_dev // n_rep), ("data", "model"))
            state = jax.device_put(state, replica_sharding(
                state, mesh, replica_axes=("data",)))
            put = lambda b: jax.device_put(  # noqa: E731
                b, replica_sharding(b, mesh, replica_axes=("data",)))
        else:
            put = jax.device_put

    loader = PrefetchLoader(
        map(lambda b: reshape_for_replicas(b, n_rep), source),
        prefetch=args.prefetch, device_put=put)

    print(f"arch={getattr(cfg, 'name', args.arch)} replicas={n_rep} "
          f"devices={n_dev} engine={engine} strategy={args.strategy} "
          f"sync_every={args.sync_every}")
    losses = []
    t0 = time.time()
    for i in range(args.steps):
        batch = next(loader)
        state, loss_val = step_fn(state, batch)
        if (i + 1) % args.log_every == 0 or i == 0:
            lv = float(loss_val)
            losses.append(lv)
            print(f"step {i + 1:5d} loss {lv:.4f} "
                  f"({(time.time() - t0) / (i + 1):.3f}s/step)", flush=True)
        if args.ckpt_dir and args.ckpt_every and \
                (i + 1) % args.ckpt_every == 0:
            checkpoint.save(args.ckpt_dir, i + 1, state)
    spread = float(replica_spread(state.params))
    print(f"done: {args.steps} steps in {time.time() - t0:.1f}s; "
          f"final loss {losses[-1] if losses else float('nan'):.4f}; "
          f"replica spread {spread:.2e}")
    loader.close()


if __name__ == "__main__":
    main()
