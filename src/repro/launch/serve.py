"""Serving driver: the continuous-batching engine on this host's devices,
single-process or as a multi-process tier.

Builds a (reduced, randomly-initialized — or checkpoint-restored) model,
spins up ``repro.serving.ServingEngine`` with ``--slots`` fixed decode
slots on a replica mesh over the host devices, feeds it a synthetic
request stream, and reports tok/s + per-request p50/p99 latency.

    REPRO_DEVICES=2 PYTHONPATH=src python -m repro.launch.serve \
        --arch olmo-1b --smoke --slots 4 --requests 16 \
        --kernel-backend pallas

The engine serves every family in the zoo through the DecodeState
contract (docs/serving.md); ``--arch seamless-m4t-medium`` exercises the
encdec path with stub frames, ``--arch rwkv6-7b`` the constant-state
recurrent path.  Vision runs through the SAME admission loop:
``--arch alexnet`` serves image-classification requests (one class id
per image, batched through ``_admit_images`` — no decode ticks), and
``--images`` attaches raw pixels to every vlm request so
``phi-3-vision-4.2b`` prefills real (stub-encoded) patch embeddings.

Tier mode (docs/serving.md):

    python -m repro.launch.serve --arch olmo-1b --smoke --tier 2

spawns 2 engine worker processes (same model flags) behind a
``serving.Router`` and routes the stream through them; ``--disagg`` adds
a dedicated prefill worker and decode instances admit only pre-filled
snapshots.  The worker side of the same flags is ``--role
{engine,decode,prefill} --port P`` — spawned automatically by ``--tier``
or launched by hand for a multi-host layout.
"""
import os

if os.environ.get("REPRO_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DEVICES"])

# ruff: noqa: E402
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro import models
from repro.configs import ALEXNET, ALEXNET_SMOKE, get_config, reduced
from repro.kernels.common import KernelPolicy
from repro.launch.mesh import make_replica_mesh
from repro.numerics import get_policy
from repro.serving import Request, Router, ServingEngine


def build_parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--images", action="store_true",
                    help="vlm: attach random raw pixels to every request "
                    "(encoded to patch embeddings at submit)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--slots", type=int, default=4,
                    help="fixed decode slots (the continuous batch)")
    ap.add_argument("--capacity", type=int, default=128,
                    help="per-request position budget (ring capacity for "
                    "full-attention archs; SWA/recurrent state is smaller)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16,
                    help="mean synthetic prompt length (lengths vary "
                    "around it to exercise the buckets)")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--ticks-per-dispatch", type=int, default=1,
                    help="device-resident decode ticks per host sync: the "
                    "drain sees a (slots, K) token block per dispatch")
    ap.add_argument("--draft-arch", default=None,
                    help="enable speculative decoding with this arch as "
                    "the draft model (greedy only; reduced under --smoke)")
    ap.add_argument("--draft-layers", type=int, default=0,
                    help="> 0: make the draft a TRUNCATED member of the "
                    "target arch — the target's own first k layers + its "
                    "embed/unembed (use with --draft-arch self)")
    ap.add_argument("--spec-tokens", type=int, default=4,
                    help="draft tokens proposed per verify round (gamma)")
    ap.add_argument("--block-size", type=int, default=0,
                    help="> 0: shared-prefix block-pool KV cache with this "
                    "many ring positions per block (single device, "
                    "full-attention archs)")
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="pool size for --block-size (default: full "
                    "private provisioning, slots*capacity/bs + trash)")
    ap.add_argument("--kernel-backend", default="auto",
                    choices=["auto", "xla", "pallas"],
                    help="KernelPolicy backend — pallas engages the "
                    "flash-decode kernel (interpret mode on CPU hosts)")
    ap.add_argument("--numerics", default="fp32",
                    choices=["fp32", "bf16"],
                    help="NumericsPolicy preset for the served model "
                    "(docs/numerics.md)")
    ap.add_argument("--kv-cache-dtype", default="auto",
                    choices=["auto", "fp32", "bf16", "int8"],
                    help="KV-cache storage dtype: auto follows the model "
                    "dtype; int8 quantizes per head/slot with fp32 scales "
                    "— 2x decode slots at equal ring bytes")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    # ------------------------------------------------------------- tier ----
    ap.add_argument("--tier", "--instances", type=int, default=0,
                    dest="tier",
                    help="> 0: spawn this many engine worker processes and "
                    "route the request stream through serving.Router")
    ap.add_argument("--disagg", action="store_true",
                    help="tier mode: add a dedicated prefill worker; "
                    "decode instances admit only pre-filled snapshots")
    ap.add_argument("--role", default="driver",
                    choices=["driver", "router", "engine", "decode",
                             "prefill"],
                    help="worker roles serve one router connection on "
                    "--port; router is an alias for the --tier driver")
    ap.add_argument("--port", type=int, default=0,
                    help="worker roles: localhost port to listen on")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="worker backpressure bound (default 2x slots): "
                    "beyond it submits answer 'defer'")
    return ap


def build_cfg(args):
    if args.arch == "alexnet":
        cfg = ALEXNET_SMOKE if args.smoke else ALEXNET
    else:
        cfg = get_config(args.arch)
        if args.smoke:
            cfg = reduced(cfg, n_layers=args.layers or 2,
                          d_model=args.d_model or 256)
    npol = get_policy(args.numerics)
    if args.kv_cache_dtype != "auto":
        npol = dataclasses.replace(npol, kv_cache_dtype=args.kv_cache_dtype)
    return dataclasses.replace(
        cfg, kernels=KernelPolicy(backend=args.kernel_backend), numerics=npol)


def build_spec(args, cfg, params):
    """The speculative-decoding kwargs: an independent draft arch, or —
    with --draft-layers k — a truncated-layer member of the TARGET arch
    sharing its embed/unembed and first k blocks (serving/spec_decode.py
    truncated_draft), which is what makes acceptance high enough to pay."""
    if not args.draft_arch and not args.draft_layers:
        return {}
    if args.draft_layers:
        from repro.serving.spec_decode import truncated_draft
        dcfg, dparams = truncated_draft(cfg, params, args.draft_layers)
        return {"draft_params": dparams, "draft_cfg": dcfg,
                "spec_tokens": args.spec_tokens}
    dcfg = get_config(args.draft_arch)
    if args.smoke:
        dcfg = reduced(dcfg, n_layers=args.layers or 2,
                       d_model=args.d_model or 256)
    dcfg = dataclasses.replace(
        dcfg, kernels=KernelPolicy(backend=args.kernel_backend),
        numerics=cfg.numerics)
    if dcfg.vocab_size != cfg.vocab_size:
        dcfg = dataclasses.replace(dcfg, vocab_size=cfg.vocab_size)
    return {"draft_params": models.init(jax.random.PRNGKey(args.seed + 1),
                                        dcfg),
            "draft_cfg": dcfg, "spec_tokens": args.spec_tokens}


def build_engine(args, cfg, *, mesh=None):
    params = models.init(jax.random.PRNGKey(args.seed), cfg)
    spec = build_spec(args, cfg, params)
    return ServingEngine(params, cfg, slots=args.slots,
                         capacity=args.capacity,
                         temperature=args.temperature, top_k=args.top_k,
                         mesh=mesh, seed=args.seed,
                         ticks_per_dispatch=args.ticks_per_dispatch,
                         block_size=args.block_size,
                         num_blocks=args.num_blocks, **spec)


def make_requests(args, cfg):
    rs = np.random.default_rng(args.seed)
    reqs = []
    n_img = cfg.n_image_tokens if args.images else 0
    hi = max(args.capacity - args.max_new - n_img, 2)
    for i in range(args.requests):
        if cfg.family == "conv":
            reqs.append(Request(image=rs.standard_normal(
                (cfg.image_size, cfg.image_size, cfg.in_channels))))
            continue
        ln = int(np.clip(rs.integers(max(args.prompt_len // 2, 1),
                                     args.prompt_len * 2), 1, hi))
        reqs.append(Request(
            prompt=rs.integers(0, cfg.vocab_size, size=ln),
            max_new_tokens=args.max_new,
            image=rs.standard_normal((32, 32, 3)) if args.images else None))
    return reqs


def worker_argv(args):
    """The model/engine flags a spawned worker needs to build the SAME
    engine this driver would — tier instances must be homogeneous for
    drain/handoff to replay snapshots."""
    argv = ["--arch", args.arch, "--slots", str(args.slots),
            "--capacity", str(args.capacity),
            "--temperature", str(args.temperature),
            "--top-k", str(args.top_k),
            "--ticks-per-dispatch", str(args.ticks_per_dispatch),
            "--kernel-backend", args.kernel_backend,
            "--numerics", args.numerics,
            "--kv-cache-dtype", args.kv_cache_dtype,
            "--seed", str(args.seed)]
    if args.smoke:
        argv.append("--smoke")
    if args.layers is not None:
        argv += ["--layers", str(args.layers)]
    if args.d_model is not None:
        argv += ["--d-model", str(args.d_model)]
    if args.max_queue:
        argv += ["--max-queue", str(args.max_queue)]
    return argv


def run_worker(args):
    from repro.serving import tier
    if not args.port:
        raise SystemExit("worker roles need --port")
    cfg = build_cfg(args)
    if args.role == "prefill":
        params = models.init(jax.random.PRNGKey(args.seed), cfg)
        obj = tier.PrefillWorker(params, cfg, capacity=args.capacity,
                                 temperature=args.temperature,
                                 top_k=args.top_k, seed=args.seed)
    else:
        obj = build_engine(args, cfg)
    tier.worker_serve(obj, args.port,
                      max_queue=args.max_queue or None)


def run_tier(args):
    from repro.serving import tier
    cfg = build_cfg(args)
    if cfg.family == "conv" or args.images:
        raise SystemExit("the tier routes token requests; vision serves "
                         "single-process")
    argv = worker_argv(args)
    instances = [tier.spawn_worker("engine", argv, name=f"engine{i}")
                 for i in range(args.tier)]
    prefill = tier.spawn_worker("prefill", argv) if args.disagg else None
    for h in instances + ([prefill] if prefill else []):
        h.connect()
    router = Router(instances, prefill=prefill)
    reqs = make_requests(args, cfg)
    print(f"tier: {args.tier} instance(s)"
          + (" + prefill worker" if prefill else "")
          + f", arch={cfg.name} slots={args.slots}/instance")
    t0 = time.perf_counter()
    for r in reqs:
        router.submit(r)
    results = router.run_until_done()
    wall = time.perf_counter() - t0
    toks = sum(len(r["tokens"]) for r in results)
    lats = sorted(r["router_latency"] for r in results)
    p = lambda q: lats[min(int(q * len(lats)), len(lats) - 1)]  # noqa: E731
    st = router.stats()
    router.shutdown()
    print(f"served {len(results)} requests / {toks} tokens in {wall:.2f}s "
          f"({toks / wall:.1f} tok/s aggregate, "
          f"{router.deferred} deferred admissions, "
          f"dead={st['dead'] or 'none'})")
    print(f"router latency p50 {p(0.5) * 1e3:.0f}ms p99 {p(0.99) * 1e3:.0f}ms")
    print("serve OK")


def main():
    args = build_parser().parse_args()
    if args.role in ("engine", "decode", "prefill"):
        run_worker(args)
        return
    if args.tier or args.role == "router":
        if not args.tier:
            raise SystemExit("--role router needs --tier N (instances to "
                             "spawn)")
        run_tier(args)
        return

    cfg = build_cfg(args)
    if args.images and cfg.family != "vlm":
        raise SystemExit(f"--images needs a vlm arch, {cfg.name} is "
                         f"{cfg.family}")

    n_dev = jax.device_count()
    mesh = make_replica_mesh(n_dev) if n_dev > 1 else None
    if mesh is not None and args.slots % n_dev:
        raise SystemExit(f"--slots {args.slots} must divide over "
                         f"{n_dev} devices")
    if args.max_new >= args.capacity:
        raise SystemExit(f"--max-new {args.max_new} must be < --capacity "
                         f"{args.capacity}: the ring holds capacity "
                         "positions, prompt included")

    engine = build_engine(args, cfg, mesh=mesh)
    reqs = make_requests(args, cfg)

    print(f"arch={cfg.name} family={cfg.family} devices={n_dev} "
          f"slots={args.slots} capacity={args.capacity} "
          f"kernels={cfg.kernels.describe()} "
          f"numerics={cfg.numerics.describe()}")
    t0 = time.perf_counter()
    results = engine.run(reqs)
    wall = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in results)
    lats = sorted(r.latency for r in results)
    p = lambda q: lats[min(int(q * len(lats)), len(lats) - 1)]  # noqa: E731
    print(f"served {len(results)} requests / {toks} tokens in {wall:.2f}s "
          f"({toks / wall:.1f} tok/s, {engine.decode_steps} decode ticks / "
          f"{engine.dispatches} dispatches, "
          f"{engine.prefill_compiles} prefill compiles)")
    if engine.spec_proposed:
        print(f"spec: {engine.spec_accepted}/{engine.spec_proposed} draft "
              f"tokens accepted "
              f"({engine.spec_accepted / engine.spec_proposed:.2f})")
    if engine.block_mgr is not None:
        print(f"blocks: peak {engine.block_mgr.peak}/{engine.block_mgr.nb} "
              f"in use, {engine.block_mgr.prefills_skipped} prefills "
              f"skipped")
    print(f"latency p50 {p(0.5) * 1e3:.0f}ms p99 {p(0.99) * 1e3:.0f}ms "
          f"ttft p50 {sorted(r.ttft for r in results)[len(results) // 2] * 1e3:.0f}ms")
    print("serve OK")


if __name__ == "__main__":
    main()
