"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

MUST be imported/run before any other jax usage: the first two lines force
512 host placeholder devices so the production meshes can be built.  Do NOT
replicate this env var anywhere else (tests/benches see 1 device).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b \
        --shape train_4k --mesh pod1
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh pod1 pod2

Writes one JSON per combo under results/dryrun/ with memory analysis, HLO
cost analysis, and the collective-bytes breakdown parsed from the optimized
HLO — the inputs to the roofline table in EXPERIMENTS.md.

Scan-cost correction: XLA's cost analysis counts a while-loop body ONCE,
but our models scan over ``np_`` layer superblocks.  We therefore compile
two auxiliary depths (1 and 2 superblocks, identical shapes otherwise) and
extrapolate  total(np_) = outer + np_ * body  with body = c(2) - c(1),
outer = 2 c(1) - c(2), per metric (flops / bytes / collective bytes).  The
full-depth compile still provides the memory analysis (activation stacking
scales with depth) and proves the real config lowers.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import models
from repro.configs import ASSIGNED, SHAPES, get_config, supports_shape
from repro.core import (as_exchanger, init_param_avg_state,
                        make_param_avg_step)
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh, mesh_chips)
from repro.models.transformer import block_kinds
from repro.optim import schedules
from repro.optim.optimizers import sgd_momentum
from repro.sharding.specs import (batch_sharding, cache_sharding,
                                  state_sharding)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# Per-device budget (v5e: 16 GB HBM); above this for a full replica's train
# state we fall back to FSDP sharding of the big dims over 'data' and keep
# the replica (param-averaging) axis on 'pod' only.  This is the documented
# capacity limit of the paper's naive data parallelism (DESIGN.md §5).
FSDP_THRESHOLD_BYTES = 10e9


def train_state_bytes_per_device(cfg, model_size: int) -> float:
    n = cfg.n_params()
    return (2 * n + 4 * n) / model_size      # bf16 params + fp32 momentum


def pick_layout(cfg, mesh, mode: str):
    names = mesh.axis_names
    sizes = dict(zip(names, mesh.devices.shape))
    model_size = sizes.get("model", 1)
    if mode != "train":
        serve_heavy = (2 * cfg.n_params()) / model_size > FSDP_THRESHOLD_BYTES
        return None, ("data" if serve_heavy else None), 1
    heavy = train_state_bytes_per_device(cfg, model_size) > FSDP_THRESHOLD_BYTES
    if heavy:
        replica_axes = tuple(a for a in ("pod",) if a in names)
        fsdp = "data"
    else:
        replica_axes = tuple(a for a in ("pod", "data") if a in names)
        fsdp = None
    n_rep = 1
    for a in replica_axes:
        n_rep *= sizes[a]
    return replica_axes, fsdp, max(n_rep, 1)


def abstract_train_state(cfg, n_replicas: int, momentum_dtype="float32"):
    opt = sgd_momentum(state_dtype=momentum_dtype)
    rng = jax.random.PRNGKey(0)
    return jax.eval_shape(
        lambda: init_param_avg_state(rng, lambda r: models.init(r, cfg), opt,
                                     n_replicas))


def batch_structs(cfg, shape, n_replicas, replica_axes):
    spec = models.model_inputs(cfg, shape.global_batch, shape.seq_len)
    structs = {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in spec.items()}
    if replica_axes is not None:
        structs = {
            k: jax.ShapeDtypeStruct(
                (n_replicas, v.shape[0] // n_replicas) + v.shape[1:], v.dtype)
            for k, v in structs.items()}
    return structs


def build_lowered(cfg, shape, mesh, mode, replica_axes, fsdp, n_rep,
                  attn_impl="qloop", strategy="all_reduce",
                  microbatch: int = 1, momentum_dtype: str = "float32"):
    """Lower one step function; returns the jax Lowered object."""
    # the attention impl rides on the config's KernelPolicy now; qloop is
    # the dry-run default (static KV slices -> near-exact HLO flops)
    from repro.kernels.common import policy_of
    cfg = dataclasses.replace(
        cfg, kernels=dataclasses.replace(policy_of(cfg),
                                         attention=attn_impl))
    if mode == "train":
        state_sh = abstract_train_state(cfg, n_rep, momentum_dtype)
        state_shard = state_sharding(state_sh, cfg, mesh,
                                     replica_axes=replica_axes,
                                     fsdp_axis=fsdp)
        bstructs = batch_structs(cfg, shape, n_rep, replica_axes)
        b_shard = batch_sharding(bstructs, mesh,
                                 batch_axes=replica_axes or (),
                                 inner_axis=fsdp)
        opt = sgd_momentum(state_dtype=momentum_dtype)
        # reference engine on purpose: production meshes combine replicas
        # with a model axis, which shard_map cannot yet delegate to GSPMD
        # (no partial-auto mode in the pinned jax) — same Exchanger API as
        # the mesh engine, axis-0 execution.
        step = make_param_avg_step(
            lambda p, b: models.loss_fn(p, cfg, b, remat=True),
            opt, schedules.constant(1e-2), strategy=as_exchanger(strategy),
            microbatch=microbatch)
        jitted = jax.jit(step, in_shardings=(state_shard, b_shard),
                         out_shardings=(state_shard,
                                        NamedSharding(mesh, P())))
        return jitted.lower(state_sh, bstructs)
    if mode == "prefill":
        params_sh = jax.eval_shape(
            lambda: models.init(jax.random.PRNGKey(0), cfg))
        p_shard = state_sharding(params_sh, cfg, mesh, fsdp_axis=fsdp)
        bstructs = batch_structs(cfg, shape, 1, None)
        bstructs.pop("labels")
        b_shard = batch_sharding(bstructs, mesh)

        def fn(params, batch):
            logits, _ = models.logits_fn(params, cfg, batch)
            return logits

        jitted = jax.jit(fn, in_shardings=(p_shard, b_shard))
        return jitted.lower(params_sh, bstructs)
    # decode
    params_sh = jax.eval_shape(
        lambda: models.init(jax.random.PRNGKey(0), cfg))
    p_shard = state_sharding(params_sh, cfg, mesh, fsdp_axis=fsdp)
    b = shape.global_batch
    cache_sh = jax.eval_shape(
        lambda: models.init_decode_cache(cfg, b, shape.seq_len))
    c_shard = cache_sharding(cache_sh, cfg, mesh)
    toks = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    t_shard = batch_sharding(toks, mesh)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def fn(params, cache, tokens, pos):
        return models.decode_step(params, cfg, cache, tokens, pos)

    jitted = jax.jit(fn,
                     in_shardings=(p_shard, c_shard, t_shard,
                                   NamedSharding(mesh, P())),
                     out_shardings=(None, c_shard))
    return jitted.lower(params_sh, cache_sh, toks, pos)


# ------------------------------------------------------- HLO text parsing ----

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|"
                       r"pred)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dm in _SHAPE_RE.finditer(segment):
        dt, dims = dm.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-op-kind operand bytes summed over the module (per device).

    Operand sizes derive from the RESULT shape on the instruction's LHS:
    all-reduce / all-to-all / collective-permute move ~result-size data;
    all-gather's operand is result/group; reduce-scatter's is result*group.
    """
    out = {}
    for line in hlo_text.splitlines():
        m = None
        for kind in _COLL_KINDS:
            mm = re.search(rf"= *((?:\([^)]*\))|(?:\S+)) +{kind}"
                           rf"(?:-start)?\(", line)
            if mm:
                m = (kind, mm.group(1))
                break
        if m is None:
            continue
        kind, result_seg = m
        nbytes = _shape_bytes(result_seg)
        gm = _GROUPS_RE.search(line)
        group = len(gm.group(1).split(",")) if gm else 1
        if kind == "all-gather" and group:
            nbytes = nbytes // max(group, 1)
        elif kind == "reduce-scatter":
            nbytes = nbytes * group
        rec = out.setdefault(kind, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += nbytes
    out["total_bytes"] = sum(v["bytes"] for v in out.values()
                             if isinstance(v, dict))
    return out


def cost_analysis_dict(compiled) -> dict:
    """jax <= 0.4.x returns a one-element list of dicts; newer jax returns
    the dict itself."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def analyze(compiled) -> dict:
    cost = cost_analysis_dict(compiled)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "transcendentals": float(cost.get("transcendentals", 0.0)),
        "collectives": parse_collectives(compiled.as_text()),
    }


def _correct(raw: dict, c1: dict, c2: dict, np_: int) -> dict:
    """corrected = raw + (np_-1) * body, with body = c2 - c1 measured from
    UNROLLED depth-1 and depth-2 compiles (exact per-superblock cost).  The
    raw scan compile counts the body once, so np_-1 copies are missing."""
    def addl(a, b):
        return max(b - a, 0.0) * (np_ - 1)

    out = {k: raw[k] + addl(c1[k], c2[k]) for k in ("flops", "bytes",
                                                    "transcendentals")}
    colls = {}
    kinds = (set(c1["collectives"]) | set(c2["collectives"]) |
             set(raw["collectives"])) - {"total_bytes"}
    for kind in kinds:
        r = raw["collectives"].get(kind, {"count": 0, "bytes": 0})
        a = c1["collectives"].get(kind, {"count": 0, "bytes": 0})
        b = c2["collectives"].get(kind, {"count": 0, "bytes": 0})
        colls[kind] = {
            "count": int(r["count"] + addl(float(a["count"]),
                                           float(b["count"]))),
            "bytes": r["bytes"] + addl(float(a["bytes"]), float(b["bytes"]))}
    colls["total_bytes"] = sum(v["bytes"] for v in colls.values()
                               if isinstance(v, dict))
    out["collectives"] = colls
    return out


def _with_depth(cfg, n_super: int):
    """Same config with n_layers = n_super*len(pattern) + remainder."""
    if cfg.family == "encdec":
        return dataclasses.replace(cfg, n_layers=n_super,
                                   n_enc_layers=n_super)
    p = len(block_kinds(cfg))
    rem = cfg.n_layers % p
    return dataclasses.replace(cfg, n_layers=n_super * p + rem)


def _depth_units(cfg) -> int:
    """Number of scanned superblocks in the real config."""
    if cfg.family == "encdec":
        return cfg.n_layers          # enc and dec scale together
    return cfg.n_layers // len(block_kinds(cfg))


def make_mesh_named(mesh_name: str, mesh_shape=None):
    """pod1/pod2 production meshes; optional custom (data, model)
    factorization of the 256-chip pod for §Perf experiments."""
    if mesh_shape is not None:
        dims = tuple(int(x) for x in mesh_shape.split(","))
        if mesh_name == "pod2":
            return jax.make_mesh((2,) + dims, ("pod", "data", "model"))
        assert dims[0] * dims[1] == 256, dims
        return jax.make_mesh(dims, ("data", "model"))
    return make_production_mesh(multi_pod=(mesh_name == "pod2"))


def lower_one(arch: str, shape_name: str, mesh_name: str,
              attn_impl: str = "qloop", strategy: str = "all_reduce",
              layout_override=None, skip_aux: bool = False,
              variant: str = None, mesh_shape: str = None,
              microbatch: int = 1, momentum_dtype: str = "float32"):
    from repro.configs.variants import VARIANTS
    cfg = get_config(arch)
    if variant:
        cfg = VARIANTS[variant](cfg)
    shape = SHAPES[shape_name]
    mesh = make_mesh_named(mesh_name, mesh_shape)
    chips = mesh_chips(mesh)
    mode = shape.kind
    replica_axes, fsdp, n_rep = pick_layout(cfg, mesh, mode)
    if layout_override:
        replica_axes, fsdp, n_rep = layout_override(mesh, replica_axes,
                                                    fsdp, n_rep)
    info = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
            "chips": chips, "mode": mode, "n_replicas": n_rep,
            "replica_axes": list(replica_axes or []), "fsdp_axis": fsdp,
            "strategy": strategy, "attn_impl": attn_impl,
            "variant": variant, "mesh_shape": mesh_shape,
            "microbatch": microbatch,
            "params": cfg.n_params(),
            "active_params": cfg.n_active_params()}

    with mesh:
        t0 = time.time()
        lowered = build_lowered(cfg, shape, mesh, mode, replica_axes, fsdp,
                                n_rep, attn_impl, strategy, microbatch,
                                momentum_dtype)
        info["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        info["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        info["memory"] = {
            k: getattr(mem, k, None)
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes")}
        raw = analyze(compiled)
        info["cost_raw"] = {k: raw[k] for k in ("flops", "bytes")}
        info["collectives_raw"] = raw["collectives"]

        np_ = _depth_units(cfg)
        if skip_aux or np_ <= 1:
            corrected = raw
            info["scan_correction"] = "none"
        else:
            from repro.models import _unroll
            aux = []
            try:
                _unroll.UNROLL = True
                for d in (1, 2):
                    cfg_d = _with_depth(cfg, d)
                    low_d = build_lowered(cfg_d, shape, mesh, mode,
                                          replica_axes, fsdp, n_rep,
                                          attn_impl, strategy, microbatch,
                                          momentum_dtype)
                    aux.append(analyze(low_d.compile()))
            finally:
                _unroll.UNROLL = False
            info["aux_flops"] = [a["flops"] for a in aux]
            info["aux_bytes"] = [a["bytes"] for a in aux]
            corrected = _correct(raw, aux[0], aux[1], np_)
            info["scan_correction"] = f"unrolled-body np={np_}"

    info["cost"] = {k: corrected[k] for k in ("flops", "bytes",
                                              "transcendentals")}
    info["collectives"] = corrected["collectives"]
    info["roofline"] = roofline_terms(info, cfg, shape, chips)
    return info


def roofline_terms(info: dict, cfg, shape, chips: int) -> dict:
    """The three roofline terms in seconds (cost analysis is per-device
    under SPMD, so per-chip peaks divide directly)."""
    flops = info["cost"]["flops"]
    bytes_ = info["cost"]["bytes"]
    coll = info["collectives"]["total_bytes"]
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = bytes_ / HBM_BW
    collective_s = coll / ICI_BW
    dom = max(("compute", compute_s), ("memory", memory_s),
              ("collective", collective_s), key=lambda kv: kv[1])[0]
    n_active = cfg.n_active_params()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6 if shape.kind == "train" else 2
    model_flops = mult * n_active * tokens
    hlo_total = flops * chips
    return {"compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s, "dominant": dom,
            "model_flops": model_flops, "hlo_flops_total": hlo_total,
            "useful_fraction": (model_flops / hlo_total) if hlo_total else None}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="*", default=None)
    ap.add_argument("--shape", nargs="*", default=None)
    ap.add_argument("--mesh", nargs="*", default=["pod1"],
                    choices=["pod1", "pod2"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--attn-impl", default="qloop")
    ap.add_argument("--strategy", default="all_reduce")
    ap.add_argument("--skip-aux", action="store_true",
                    help="skip the scan-correction aux compiles (faster)")
    ap.add_argument("--variant", default=None,
                    help="named config variant (configs/variants.py)")
    ap.add_argument("--mesh-shape", default=None,
                    help="custom data,model factorization, e.g. 32,8")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--momentum-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    archs = args.arch or (ASSIGNED if args.all else ["gemma-7b"])
    shapes = args.shape or (list(SHAPES) if args.all else ["train_4k"])
    os.makedirs(args.out, exist_ok=True)

    failures = []
    for mesh_name in args.mesh:
        for arch in archs:
            cfg = get_config(arch)
            for shape_name in shapes:
                arch_eff = arch
                if not supports_shape(cfg, SHAPES[shape_name]):
                    if arch == "gemma-7b" and shape_name == "long_500k":
                        arch_eff = "gemma-7b-swa"   # documented SWA variant
                    else:
                        print(f"SKIP {arch} x {shape_name} "
                              f"(unsupported, see DESIGN.md)")
                        continue
                tag = f"{arch}_{shape_name}_{mesh_name}"
                if args.variant:
                    tag += f"__{args.variant}"
                if args.strategy != "all_reduce":
                    tag += f"__{args.strategy}"
                if args.mesh_shape:
                    tag += f"__mesh{args.mesh_shape.replace(',', 'x')}"
                if args.microbatch > 1:
                    tag += f"__mb{args.microbatch}"
                if args.momentum_dtype != "float32":
                    tag += "__mombf16"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"CACHED {tag}")
                    continue
                print(f"RUN {tag} ...", flush=True)
                try:
                    info = lower_one(arch_eff, shape_name, mesh_name,
                                     attn_impl=args.attn_impl,
                                     strategy=args.strategy,
                                     skip_aux=args.skip_aux,
                                     variant=args.variant,
                                     mesh_shape=args.mesh_shape,
                                     microbatch=args.microbatch,
                                     momentum_dtype=args.momentum_dtype)
                    with open(path, "w") as f:
                        json.dump(info, f, indent=1)
                    r = info["roofline"]
                    print(f"  OK lower={info['lower_s']}s "
                          f"compile={info['compile_s']}s "
                          f"compute={r['compute_s']:.4f}s "
                          f"memory={r['memory_s']:.4f}s "
                          f"coll={r['collective_s']:.4f}s "
                          f"dom={r['dominant']} "
                          f"useful={r['useful_fraction']:.2f}", flush=True)
                except Exception as e:
                    failures.append((tag, repr(e)))
                    print(f"  FAIL {tag}: {e}")
                    traceback.print_exc()
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("all requested dry-runs passed")


if __name__ == "__main__":
    main()
