"""The paper's contribution: data parallelism by parameter averaging."""
from repro.core.param_avg import (COMPRESSIONS, EXPECTED_COLLECTIVE,
                                  STRATEGIES, ExchangeConfig, Exchanger,
                                  as_exchanger, exchange_average, replicate,
                                  replica_spread, unreplicate)
from repro.core.steps import (TrainState, init_exchange_state,
                              init_grad_avg_state, init_param_avg_state,
                              make_eval_step, make_grad_avg_step,
                              make_mesh_param_avg_step, make_param_avg_step,
                              make_serve_step, replica_specs,
                              reshape_for_replicas)
