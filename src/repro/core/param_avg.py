"""Replica exchange-and-average — the paper's §2.2 / Fig. 2, generalized.

Two execution engines share one ``Exchanger`` API:

* **mesh engine** (``axis=<mesh axis name(s)>``): each replica is one shard
  of a ``jax.shard_map`` program over the ('pod','data') mesh axes, and the
  strategies are per-shard collectives (``jax.lax.pmean`` /
  ``jax.lax.ppermute``) — every strategy lowers to the real collective its
  name promises.  This is the production path (launch/train.py,
  examples/train_dataparallel.py).
* **reference engine** (``axis=None``): replicated state carries an explicit
  leading axis R and each strategy is an ordinary jnp program over axis 0.
  It is the interpretable spec the mesh engine is tested against, and the
  path the GSPMD dry-run (launch/dryrun.py) compiles when replicas combine
  with tensor parallelism.

Strategy -> collective:

  ``all_reduce``  mean across replicas                       -> all-reduce
                  (1 fused reduction per step)
  ``ring``        R-1 neighbour shifts, accumulate           -> collective-
                  permute chain (the closest analogue of the paper's
                  sequential P2P copies around a ring)
  ``pairwise``    log2(R) hypercube exchange+average rounds  -> collective-
                  permute pairs (R=2 reproduces the paper's Fig. 2 EXACTLY:
                  one exchange, then average on both replicas)
  ``none``        no synchronization (local SGD / sync-every-k)

All strategies are exact means (for power-of-two R), so they are numerically
interchangeable; they differ only in the communication schedule — which is
precisely the axis the paper's Table 1 explores with its hardware.  The same
function is applied to params AND optimizer state (momentum), per the
paper's footnote 3.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

try:                                    # jax >= 0.6 top-level export
    from jax import shard_map           # type: ignore[attr-defined]
except ImportError:                     # jax 0.4.x experimental home
    from jax.experimental.shard_map import shard_map

STRATEGIES = ("all_reduce", "ring", "pairwise", "none")

# HLO op each strategy's mesh-engine lowering must contain (None: no
# communication).  tests/core/test_exchange_mesh.py asserts this.
EXPECTED_COLLECTIVE = {"all_reduce": "all-reduce",
                       "ring": "collective-permute",
                       "pairwise": "collective-permute",
                       "none": None}

AxisName = Union[str, Tuple[str, ...]]


# ------------------------------------------------ reference (axis-0) engine --

def _avg_all_reduce(x):
    return jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True), x.shape)


def _avg_ring(x):
    r = x.shape[0]
    acc = x
    cur = x
    for _ in range(r - 1):
        cur = jnp.roll(cur, shift=1, axis=0)     # neighbour pass
        acc = acc + cur
    return acc / r


def _avg_pairwise(x):
    r = x.shape[0]
    assert r & (r - 1) == 0, f"pairwise needs power-of-two replicas, got {r}"
    idx = jnp.arange(r)
    dim = 1
    while dim < r:
        partner = idx ^ dim                      # hypercube neighbour
        x = 0.5 * (x + jnp.take(x, partner, axis=0))
        dim <<= 1
    return x


_FNS = {"all_reduce": _avg_all_reduce, "ring": _avg_ring,
        "pairwise": _avg_pairwise}


# ------------------------------------------------- mesh (per-shard) engine --
# These run INSIDE shard_map: x is one replica's slice (no leading R axis)
# and the replica index lives on the named mesh axis.

def _shard_all_reduce(x, axis):
    return jax.lax.pmean(x, axis)


def _shard_ring(x, axis):
    r = jax.lax.psum(1, axis)
    perm = [(i, (i + 1) % r) for i in range(r)]  # same direction as the
    acc = x                                      # reference's roll(+1)
    cur = x
    for _ in range(r - 1):
        cur = jax.lax.ppermute(cur, axis, perm)
        acc = acc + cur
    return acc / r


def _shard_pairwise(x, axis):
    r = jax.lax.psum(1, axis)
    assert r & (r - 1) == 0, f"pairwise needs power-of-two replicas, got {r}"
    dim = 1
    while dim < r:
        perm = [(i, i ^ dim) for i in range(r)]
        x = 0.5 * (x + jax.lax.ppermute(x, axis, perm))
        dim <<= 1
    return x


_SHARD_FNS = {"all_reduce": _shard_all_reduce, "ring": _shard_ring,
              "pairwise": _shard_pairwise}


# ------------------------------------------------------------ Exchanger API --

@dataclasses.dataclass(frozen=True)
class Exchanger:
    """One exchange schedule bound to an execution engine.

    ``axis=None``: reference engine — ``average`` expects every leaf to
    carry a leading replica axis R and averages over it in plain jnp.

    ``axis=<name or tuple of names>``: mesh engine — ``average`` must be
    called inside ``jax.shard_map`` with ``axis`` manual; leaves are single
    replica slices and the average is a real collective over the mesh axis.
    """
    strategy: str = "all_reduce"
    axis: Optional[AxisName] = None

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}; "
                             f"one of {STRATEGIES}")

    @property
    def is_mesh(self) -> bool:
        return self.axis is not None

    @property
    def expected_collective(self) -> Optional[str]:
        """HLO op the mesh engine lowers this strategy to."""
        return EXPECTED_COLLECTIVE[self.strategy]

    def average(self, tree):
        """Exchange+average every leaf (params or optimizer state)."""
        if self.strategy == "none":
            return tree
        if self.is_mesh:
            fn = _SHARD_FNS[self.strategy]

            def avg(x):
                if x.ndim == 0:      # scalars (e.g. adam count) stay equal
                    return x
                xf = x.astype(jnp.float32)
                return fn(xf, self.axis).astype(x.dtype)
        else:
            fn = _FNS[self.strategy]

            def avg(x):
                if x.ndim == 0:
                    return x
                xf = x.astype(jnp.float32)
                return fn(xf).astype(x.dtype)

        return jax.tree.map(avg, tree)


def as_exchanger(strategy: Union[str, Exchanger],
                 axis: Optional[AxisName] = None) -> Exchanger:
    """Accept a strategy name or a ready Exchanger (axis overrides engine)."""
    if isinstance(strategy, Exchanger):
        if axis is not None and strategy.axis != axis:
            return dataclasses.replace(strategy, axis=axis)
        return strategy
    return Exchanger(strategy, axis=axis)


def exchange_average(tree, strategy: Union[str, Exchanger] = "all_reduce"):
    """Average every leaf of a replicated pytree over its leading R axis
    (reference engine; kept as the stable public entrypoint)."""
    ex = as_exchanger(strategy)
    if ex.is_mesh:
        raise ValueError("exchange_average is the axis-0 reference; call "
                         "Exchanger.average inside shard_map for the mesh "
                         "engine")
    return ex.average(tree)


def replicate(tree, n_replicas: int):
    """Give every leaf a leading replica axis (identical initial copies —
    the paper initializes both GPUs' models identically)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_replicas,) + x.shape), tree)


def unreplicate(tree):
    """Take replica 0 (after averaging all replicas are identical)."""
    return jax.tree.map(lambda x: x[0], tree)


def replica_spread(tree) -> jnp.ndarray:
    """Max abs deviation across replicas — 0 right after a sync step; a
    diagnostic for local-SGD drift."""
    def spread(x):
        if x.ndim == 0:
            return jnp.zeros((), jnp.float32)
        xf = x.astype(jnp.float32)
        return jnp.max(jnp.abs(xf - jnp.mean(xf, axis=0, keepdims=True)))
    return jax.tree.reduce(jnp.maximum, jax.tree.map(spread, tree),
                           jnp.zeros((), jnp.float32))
