"""Replica exchange-and-average — the paper's §2.2 / Fig. 2, generalized.

Replicated state carries an explicit leading axis R (one slice per replica,
sharded over the ('pod','data') mesh axes), so each strategy is an ordinary
jnp program over axis 0 whose lowering produces the corresponding collective:

  ``all_reduce``  mean over axis 0, broadcast back          -> all-reduce
  ``ring``        R-1 neighbour shifts, accumulate           -> collective-permute
                  chain (the closest analogue of the paper's sequential
                  P2P copies around a ring)
  ``pairwise``    log2(R) hypercube exchange+average rounds  -> collective-permute
                  pairs (R=2 reproduces the paper's Fig. 2 EXACTLY:
                  one exchange, then average on both replicas)
  ``none``        no synchronization (local SGD / sync-every-k)

All strategies are exact means (for power-of-two R), so they are numerically
interchangeable; they differ only in the communication schedule — which is
precisely the axis the paper's Table 1 explores with its hardware.  The same
function is applied to params AND optimizer state (momentum), per the
paper's footnote 3.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

STRATEGIES = ("all_reduce", "ring", "pairwise", "none")


def _avg_all_reduce(x):
    return jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True), x.shape)


def _avg_ring(x):
    r = x.shape[0]
    acc = x
    cur = x
    for _ in range(r - 1):
        cur = jnp.roll(cur, shift=1, axis=0)     # neighbour pass
        acc = acc + cur
    return acc / r


def _avg_pairwise(x):
    r = x.shape[0]
    assert r & (r - 1) == 0, f"pairwise needs power-of-two replicas, got {r}"
    idx = jnp.arange(r)
    dim = 1
    while dim < r:
        partner = idx ^ dim                      # hypercube neighbour
        x = 0.5 * (x + jnp.take(x, partner, axis=0))
        dim <<= 1
    return x


_FNS = {"all_reduce": _avg_all_reduce, "ring": _avg_ring,
        "pairwise": _avg_pairwise}


def exchange_average(tree, strategy: str = "all_reduce"):
    """Average every leaf of a replicated pytree over its leading R axis."""
    if strategy == "none":
        return tree
    if strategy not in _FNS:
        raise ValueError(f"unknown strategy {strategy!r}; one of {STRATEGIES}")
    fn = _FNS[strategy]

    def avg(x):
        if x.ndim == 0:          # scalars (e.g. adam count) are already equal
            return x
        xf = x.astype(jnp.float32)
        return fn(xf).astype(x.dtype)

    return jax.tree.map(avg, tree)


def replicate(tree, n_replicas: int):
    """Give every leaf a leading replica axis (identical initial copies —
    the paper initializes both GPUs' models identically)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_replicas,) + x.shape), tree)


def unreplicate(tree):
    """Take replica 0 (after averaging all replicas are identical)."""
    return jax.tree.map(lambda x: x[0], tree)


def replica_spread(tree) -> jnp.ndarray:
    """Max abs deviation across replicas — 0 right after a sync step; a
    diagnostic for local-SGD drift."""
    def spread(x):
        if x.ndim == 0:
            return jnp.zeros((), jnp.float32)
        xf = x.astype(jnp.float32)
        return jnp.max(jnp.abs(xf - jnp.mean(xf, axis=0, keepdims=True)))
    return jax.tree.reduce(jnp.maximum, jax.tree.map(spread, tree),
                           jnp.zeros((), jnp.float32))
