"""Replica exchange-and-average — the paper's §2.2 / Fig. 2, generalized.

Two execution engines share one ``Exchanger`` API:

* **mesh engine** (``axis=<mesh axis name(s)>``): each replica is one shard
  of a ``jax.shard_map`` program over the ('pod','data') mesh axes, and the
  strategies are per-shard collectives (``jax.lax.pmean`` /
  ``jax.lax.ppermute``) — every strategy lowers to the real collective its
  name promises.  This is the production path (launch/train.py,
  examples/train_dataparallel.py).
* **reference engine** (``axis=None``): replicated state carries an explicit
  leading axis R and each strategy is an ordinary jnp program over axis 0.
  It is the interpretable spec the mesh engine is tested against, and the
  path the GSPMD dry-run (launch/dryrun.py) compiles when replicas combine
  with tensor parallelism.

Strategy -> collective:

  ``all_reduce``  mean across replicas                       -> all-reduce
                  (1 fused reduction per step)
  ``ring``        R-1 neighbour shifts, accumulate           -> collective-
                  permute chain (the closest analogue of the paper's
                  sequential P2P copies around a ring)
  ``pairwise``    log2(R) hypercube exchange+average rounds  -> collective-
                  permute pairs (R=2 reproduces the paper's Fig. 2 EXACTLY:
                  one exchange, then average on both replicas)
  ``none``        no synchronization (local SGD / sync-every-k)

All strategies are exact means (for power-of-two R), so they are numerically
interchangeable; they differ only in the communication schedule — which is
precisely the axis the paper's Table 1 explores with its hardware.  The same
function is applied to params AND optimizer state (momentum), per the
paper's footnote 3.

Compression (beyond-paper, the Theano-MPI direction): an ``Exchanger`` can
carry an ``ExchangeCompression`` policy lowering the exchanged volume:

  ``none``  full-precision dense exchange (the paper's path; default)
  ``bf16``  wire dtype bf16 — halves the physically-moved bytes
  ``topk``  top-k-magnitude sparsification of the *delta from the shared
            consensus base* with error-feedback residuals (what top-k
            drops this step is carried into the next step's delta, so
            nothing is lost, only delayed).  Stateful: needs the
            base+residual buffers that ride on ``TrainState.exchange``
            under the delay=1 overlapped exchange (core/steps.py), and an
            all-gather schedule (k values + k indices per replica), so it
            composes with ``all_reduce`` only.

``average`` is the stateless whole-value exchange (none/bf16);
``average_delta`` is the stateful compressed-delta exchange the delayed
path uses (none/bf16/topk, with residual threading).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple, Union

import jax
import jax.numpy as jnp

try:                                    # jax >= 0.6 top-level export
    from jax import shard_map           # type: ignore[attr-defined]
except ImportError:                     # jax 0.4.x experimental home
    from jax.experimental.shard_map import shard_map

STRATEGIES = ("all_reduce", "ring", "pairwise", "none")
COMPRESSIONS = ("none", "bf16", "topk")

# HLO op each strategy's mesh-engine lowering must contain (None: no
# communication).  tests/core/test_exchange_mesh.py asserts this.
EXPECTED_COLLECTIVE = {"all_reduce": "all-reduce",
                       "ring": "collective-permute",
                       "pairwise": "collective-permute",
                       "none": None}

AxisName = Union[str, Tuple[str, ...]]


# ------------------------------------------------ reference (axis-0) engine --

def _avg_all_reduce(x):
    return jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True), x.shape)


def _avg_ring(x):
    r = x.shape[0]
    acc = x
    cur = x
    for _ in range(r - 1):
        cur = jnp.roll(cur, shift=1, axis=0)     # neighbour pass
        acc = acc + cur
    return acc / r


def _avg_pairwise(x):
    r = x.shape[0]
    assert r & (r - 1) == 0, f"pairwise needs power-of-two replicas, got {r}"
    idx = jnp.arange(r)
    dim = 1
    while dim < r:
        partner = idx ^ dim                      # hypercube neighbour
        x = 0.5 * (x + jnp.take(x, partner, axis=0))
        dim <<= 1
    return x


_FNS = {"all_reduce": _avg_all_reduce, "ring": _avg_ring,
        "pairwise": _avg_pairwise}


# ------------------------------------------------- mesh (per-shard) engine --
# These run INSIDE shard_map: x is one replica's slice (no leading R axis)
# and the replica index lives on the named mesh axis.

def _shard_all_reduce(x, axis):
    return jax.lax.pmean(x, axis)


def _shard_ring(x, axis):
    r = jax.lax.psum(1, axis)
    perm = [(i, (i + 1) % r) for i in range(r)]  # same direction as the
    acc = x                                      # reference's roll(+1)
    cur = x
    for _ in range(r - 1):
        cur = jax.lax.ppermute(cur, axis, perm)
        acc = acc + cur
    return acc / r


def _shard_pairwise(x, axis):
    r = jax.lax.psum(1, axis)
    assert r & (r - 1) == 0, f"pairwise needs power-of-two replicas, got {r}"
    dim = 1
    while dim < r:
        perm = [(i, i ^ dim) for i in range(r)]
        x = 0.5 * (x + jax.lax.ppermute(x, axis, perm))
        dim <<= 1
    return x


_SHARD_FNS = {"all_reduce": _shard_all_reduce, "ring": _shard_ring,
              "pairwise": _shard_pairwise}


# ------------------------------------------------------------ Exchanger API --

@dataclasses.dataclass(frozen=True)
class Exchanger:
    """One exchange schedule bound to an execution engine.

    ``axis=None``: reference engine — ``average`` expects every leaf to
    carry a leading replica axis R and averages over it in plain jnp.

    ``axis=<name or tuple of names>``: mesh engine — ``average`` must be
    called inside ``jax.shard_map`` with ``axis`` manual; leaves are single
    replica slices and the average is a real collective over the mesh axis.

    ``compression`` lowers the exchanged volume (module docstring).
    ``topk_frac`` is the kept fraction per leaf for ``topk`` (1.0 keeps
    everything — identity compression, bit-equal to ``none`` by
    construction: it routes through the same dense path).
    """
    strategy: str = "all_reduce"
    axis: Optional[AxisName] = None
    compression: str = "none"
    topk_frac: float = 0.01

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}; "
                             f"one of {STRATEGIES}")
        if self.compression not in COMPRESSIONS:
            raise ValueError(f"unknown compression {self.compression!r}; "
                             f"one of {COMPRESSIONS}")
        if not 0.0 < self.topk_frac <= 1.0:
            raise ValueError(f"topk_frac must be in (0, 1], "
                             f"got {self.topk_frac}")
        if self.compression == "topk" and self.strategy not in (
                "all_reduce", "none"):
            raise ValueError(
                "topk compression is an all-gather schedule (k values + k "
                "indices per replica); ring/pairwise permute dense buffers "
                f"— use bf16 with strategy {self.strategy!r}")

    @property
    def is_mesh(self) -> bool:
        return self.axis is not None

    @property
    def expected_collective(self) -> Optional[str]:
        """HLO op the mesh engine lowers this strategy to."""
        if self.strategy != "none" and self.compression == "topk" \
                and self.topk_frac < 1.0:
            return "all-gather"
        return EXPECTED_COLLECTIVE[self.strategy]

    @property
    def is_stateful(self) -> bool:
        """True when the exchange needs base+residual buffers on the train
        state (the delayed compressed-delta path)."""
        return self.compression != "none"

    def _wire_cast(self, x):
        """Cast to the wire dtype (what the collective physically moves)."""
        if self.compression == "bf16":
            return x.astype(jnp.bfloat16)
        return x.astype(jnp.float32)

    def average(self, tree):
        """Stateless exchange+average of whole values (params or optimizer
        state).  Supports ``none``/``bf16``; ``topk`` is delta-based and
        needs ``average_delta`` (whole params are dense — sparsifying them
        directly would discard most of the model)."""
        if self.strategy == "none":
            return tree
        if self.compression == "topk":
            raise ValueError(
                "topk compression is stateful (delta from a shared base + "
                "error-feedback residual); use average_delta via the "
                "delay=1 overlapped exchange (core/steps.py)")
        if self.is_mesh:
            fn = _SHARD_FNS[self.strategy]

            def avg(x):
                if x.ndim == 0:      # scalars (e.g. adam count) stay equal
                    return x
                xf = self._wire_cast(x)
                return fn(xf, self.axis).astype(jnp.float32).astype(x.dtype)
        else:
            fn = _FNS[self.strategy]

            def avg(x):
                if x.ndim == 0:
                    return x
                xf = self._wire_cast(x)
                return fn(xf).astype(jnp.float32).astype(x.dtype)

        return jax.tree.map(avg, tree)

    # ------------------------------------------------ compressed deltas --
    def _n_replicas(self, x):
        if self.is_mesh:
            return jax.lax.psum(1, self.axis)
        return x.shape[0]

    def _mean(self, x):
        """Dense collective mean in x's dtype (engine-dispatched)."""
        return (_SHARD_FNS[self.strategy](x, self.axis) if self.is_mesh
                else _FNS[self.strategy](x))

    def _topk_mean(self, d, k: int):
        """Mean of per-replica top-k-sparsified deltas + what was dropped.

        Returns ``(mean, kept)`` where ``kept`` is this replica's dense
        top-k selection (for the error-feedback residual ``d - kept``).
        Mesh engine: all-gather k values + k indices per replica and
        scatter-add locally — the collective moves 2k entries instead of n.
        Reference engine: the same flattened scatter in the same order, so
        the two engines agree bitwise.
        """
        if self.is_mesh:
            n = d.size
            flat = d.reshape(-1)
            mag, idx = jax.lax.top_k(jnp.abs(flat), k)
            vals = jnp.take(flat, idx)
            allv = jax.lax.all_gather(vals, self.axis)       # (R, k)
            alli = jax.lax.all_gather(idx, self.axis)        # (R, k)
            r = jax.lax.psum(1, self.axis)
            total = jnp.zeros((n,), d.dtype).at[alli.reshape(-1)].add(
                allv.reshape(-1))
            kept = jnp.zeros((n,), d.dtype).at[idx].set(vals)
            return (total / r).reshape(d.shape), kept.reshape(d.shape)
        r, n = d.shape[0], d[0].size
        flat = d.reshape(r, n)
        mag, idx = jax.lax.top_k(jnp.abs(flat), k)           # (R, k)
        vals = jnp.take_along_axis(flat, idx, axis=1)
        total = jnp.zeros((n,), d.dtype).at[idx.reshape(-1)].add(
            vals.reshape(-1))
        mean = jnp.broadcast_to(total / r, (r, n)).reshape(d.shape)
        rowed = idx + n * jnp.arange(r, dtype=idx.dtype)[:, None]
        kept = jnp.zeros((r * n,), d.dtype).at[rowed.reshape(-1)].set(
            vals.reshape(-1)).reshape(d.shape)
        return mean, kept

    def average_delta(self, tree, base, residual):
        """Stateful compressed exchange of deltas with error feedback.

        ``base`` must be replica-identical (the previous exchange's output —
        every replica computed the same average).  Per leaf::

            d    = (x - base) + residual        # what we owe the consensus
            c    = compress(d)                  # what actually moves
            out  = base + collective_mean(c)    # new consensus(+own kept)
            res' = d - c                        # dropped -> next step

        Returns ``(averaged_tree, new_residual)``.  With ``none`` or
        ``topk_frac=1.0`` the compressor is the identity, so ``out`` equals
        the dense delta exchange bit-for-bit and ``res'`` stays zero.
        """
        if self.strategy == "none":
            return tree, residual

        def one(x, b, r):
            if x.ndim == 0:             # scalars never exchanged
                return x, r
            d = x.astype(jnp.float32) - b.astype(jnp.float32) + r
            if self.compression == "topk":
                per_rep = d.size if self.is_mesh else d[0].size
                k = max(1, int(round(self.topk_frac * per_rep)))
                if k < per_rep:
                    avg_c, kept = self._topk_mean(d, k)
                    return ((b.astype(jnp.float32) + avg_c).astype(x.dtype),
                            d - kept)
                # k == n: identity compression.  The residual is zero by
                # induction (it starts zero and stays zero here), so
                # base + mean(x - base) == mean(x) exactly — take the SAME
                # dense whole-value arithmetic as compression "none" so the
                # result is bit-equal to it, not just close.
                avg_c = self._mean(x.astype(jnp.float32))
                return avg_c.astype(jnp.float32).astype(x.dtype), \
                    jnp.zeros_like(r)
            if self.compression == "bf16":
                c = d.astype(jnp.bfloat16)
                avg_c = self._mean(c).astype(jnp.float32)
                return (b.astype(jnp.float32) + avg_c).astype(x.dtype), \
                    d - c.astype(jnp.float32)
            avg_c = self._mean(d)
            return (b.astype(jnp.float32) + avg_c).astype(x.dtype), \
                jnp.zeros_like(r)

        flat = jax.tree.map(one, tree, base, residual)
        avg = jax.tree.map(lambda _, p: p[0], tree, flat)
        new_res = jax.tree.map(lambda _, p: p[1], tree, flat)
        return avg, new_res

    def logical_bytes(self, tree, n_replicas: int) -> int:
        """Bytes one replica logically transmits per exchange under this
        policy (the low-bandwidth axis the benchmark reports).  ``none``:
        full fp32 leaves; ``bf16``: half; ``topk``: k values + k int32
        indices per leaf."""
        total = 0
        for x in jax.tree.leaves(tree):
            if x.ndim == 0:
                continue
            n = int(x.size) // (n_replicas if not self.is_mesh else 1)
            if self.strategy == "none":
                continue
            if self.compression == "bf16":
                total += 2 * n
            elif self.compression == "topk":
                k = max(1, int(round(self.topk_frac * n)))
                total += (4 + 4) * k if k < n else 4 * n
            else:
                total += 4 * n
        return total


def as_exchanger(strategy: Union[str, Exchanger, "ExchangeConfig"],
                 axis: Optional[AxisName] = None) -> Exchanger:
    """Accept a strategy name, an ExchangeConfig, or a ready Exchanger
    (axis overrides engine)."""
    if isinstance(strategy, ExchangeConfig):
        return strategy.exchanger(axis=axis)
    if isinstance(strategy, Exchanger):
        if axis is not None and strategy.axis != axis:
            return dataclasses.replace(strategy, axis=axis)
        return strategy
    return Exchanger(strategy, axis=axis)


@dataclasses.dataclass(frozen=True)
class ExchangeConfig:
    """The exchange policy that rides on configs and the CLI — everything
    about how replicas synchronize, in one frozen value:

    ``strategy``     communication schedule (STRATEGIES)
    ``compression``  wire compression (COMPRESSIONS)
    ``topk_frac``    kept fraction for topk
    ``delay``        0 = synchronous exchange after the update (the paper's
                     path, bit-equal to the pre-policy engines); 1 = one-
                     step-stale overlapped exchange (core/steps.py): the
                     collective for step t's parameters runs inside step
                     t+1's program, concurrent with its forward/backward
    ``sync_every``   local SGD: exchange every k-th step only
    """
    strategy: str = "all_reduce"
    compression: str = "none"
    topk_frac: float = 0.01
    delay: int = 0
    sync_every: int = 1

    def __post_init__(self):
        if self.delay not in (0, 1):
            raise ValueError(f"delay must be 0 or 1, got {self.delay}")
        if self.sync_every < 1:
            raise ValueError(f"sync_every must be >= 1, "
                             f"got {self.sync_every}")
        if self.compression == "topk" and self.delay == 0:
            raise ValueError(
                "topk compression needs the delay=1 overlapped exchange "
                "(its error-feedback residual and consensus base live in "
                "TrainState.exchange, which only the delayed path carries)")
        # strategy/compression cross-validation happens in Exchanger
        self.exchanger()

    def exchanger(self, axis: Optional[AxisName] = None) -> Exchanger:
        return Exchanger(self.strategy, axis=axis,
                         compression=self.compression,
                         topk_frac=self.topk_frac)

    def describe(self) -> str:
        out = f"{self.strategy}/delay{self.delay}/{self.compression}"
        if self.compression == "topk":
            out += f"@{self.topk_frac:g}"
        if self.sync_every != 1:
            out += f"/every{self.sync_every}"
        return out


def exchange_average(tree, strategy: Union[str, Exchanger] = "all_reduce"):
    """Average every leaf of a replicated pytree over its leading R axis
    (reference engine; kept as the stable public entrypoint)."""
    ex = as_exchanger(strategy)
    if ex.is_mesh:
        raise ValueError("exchange_average is the axis-0 reference; call "
                         "Exchanger.average inside shard_map for the mesh "
                         "engine")
    return ex.average(tree)


def replicate(tree, n_replicas: int):
    """Give every leaf a leading replica axis (identical initial copies —
    the paper initializes both GPUs' models identically)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_replicas,) + x.shape), tree)


def unreplicate(tree):
    """Take replica 0 (after averaging all replicas are identical)."""
    return jax.tree.map(lambda x: x[0], tree)


def replica_spread(tree) -> jnp.ndarray:
    """Max abs deviation across replicas — 0 right after a sync step; a
    diagnostic for local-SGD drift."""
    def spread(x):
        if x.ndim == 0:
            return jnp.zeros((), jnp.float32)
        xf = x.astype(jnp.float32)
        return jnp.max(jnp.abs(xf - jnp.mean(xf, axis=0, keepdims=True)))
    return jax.tree.reduce(jnp.maximum, jax.tree.map(spread, tree),
                           jnp.zeros((), jnp.float32))
