"""Train/serve step builders.

``make_param_avg_step`` is the paper's algorithm (Fig. 2): per-replica
independent forward/backward/update (NO gradient communication), then
exchange+average of params and optimizer state.  It runs on the reference
engine (leading replica axis R + vmap, GSPMD sharding) and is what the
multi-pod dry-run compiles.  ``make_mesh_param_avg_step`` is the mesh-native
engine: the SAME algorithm as a ``jax.shard_map`` program over the replica
mesh axes, where the exchange lowers to real collectives (see
core/param_avg.py).  ``make_grad_avg_step`` is the modern baseline: single
param copy, gradients mean-reduced across the batch by XLA.  ``sync_every``
turns the paper's every-step averaging into local SGD (beyond-paper
extension — expressible only in the param-avg formulation).

State layout (both param-avg engines): every leaf has leading axis
R = #replicas; batches are (R, per_replica_batch, ...).  The reference
engine vmaps over axis 0; the mesh engine shards axis 0 one-replica-per-
shard so each program instance owns exactly one replica — the paper's
one-model-per-GPU memory layout, literally.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.param_avg import (AxisName, ExchangeConfig, Exchanger,
                                  as_exchanger, replicate, shard_map)
from repro.numerics import (NumericsPolicy, all_finite, cast_floats,
                            init_loss_scale_state, next_loss_scale_state)
from repro.optim.optimizers import Optimizer, apply_updates


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    """``exchange`` is the overlapped-exchange auxiliary state (None for
    the synchronous delay=0 path and for uncompressed delay=1): the
    replica-identical consensus ``base`` the compressed deltas are taken
    against, and the per-replica error-feedback ``residual``.  It rides on
    the donated TrainState so the in-flight buffers update in place.

    ``numerics`` is the loss-scaling state (None unless the
    ``NumericsPolicy`` enables static/dynamic scaling): the current
    scale, the clean-step counter, and the skipped-step count — scalars,
    replica-identical bookkeeping like the optimizer's ``count``."""
    params: Any
    opt_state: Any
    step: jnp.ndarray
    exchange: Any = None
    numerics: Any = None


def init_exchange_state(params_r, opt_r, exchanger: Exchanger,
                        delay: int = 0):
    """Auxiliary state for the delayed compressed exchange (None when the
    exchange is stateless).  ``base`` starts as a copy of the initial
    replicated state (all replicas identical at init, so it IS the
    consensus); ``residual`` starts at zero (nothing dropped yet).  Copies,
    not aliases: the TrainState is donated, and a leaf donated twice
    would force a silent defensive copy every step."""
    if delay == 0 or not exchanger.is_stateful \
            or exchanger.strategy == "none":
        return None
    tree = (params_r, opt_r)
    base = jax.tree.map(jnp.copy, tree)
    residual = jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32) if x.ndim else
        jnp.zeros((), jnp.float32), tree)
    return {"base": base, "residual": residual}


def init_param_avg_state(rng, init_fn, optimizer: Optimizer,
                         n_replicas: int, *,
                         exchange: Union[ExchangeConfig, None] = None,
                         numerics: Union[NumericsPolicy, None] = None
                         ) -> TrainState:
    params = init_fn(rng)
    params_r = replicate(params, n_replicas)
    opt_r = jax.vmap(optimizer.init)(params_r)
    aux = None
    if exchange is not None:
        aux = init_exchange_state(params_r, opt_r, exchange.exchanger(),
                                  exchange.delay)
    return TrainState(params_r, opt_r, jnp.zeros((), jnp.int32), aux,
                      init_loss_scale_state(numerics))


def init_grad_avg_state(rng, init_fn, optimizer: Optimizer, *,
                        numerics: Union[NumericsPolicy, None] = None
                        ) -> TrainState:
    params = init_fn(rng)
    return TrainState(params, optimizer.init(params),
                      jnp.zeros((), jnp.int32), None,
                      init_loss_scale_state(numerics))


def _make_loss_and_grad(loss_fn: Callable, microbatch: int,
                        compute_dtype=None):
    """Shared by both engines.  loss_fn(params, batch) -> scalar.

    ``loss_fn`` must be differentiable END TO END for whatever kernel
    backend its config's ``KernelPolicy`` selects — every Pallas kernel
    (conv2d, flash_attention, rglru, rwkv6) carries a ``jax.custom_vjp``
    precisely so ``jax.value_and_grad`` here works identically on the
    ``xla`` and ``pallas`` policies (no kernel kwargs reach this layer).

    ``microbatch`` > 1 accumulates gradients over that many slices of the
    per-replica batch (fp32 accumulator) — bounds activation memory at the
    cost of re-reading params per slice.

    ``compute_dtype`` (NumericsPolicy.compute_dtype, when it differs from
    the params' own dtype) casts float params at the loss boundary; the
    returned ``loss_and_grad(params, batch, scale=None)`` multiplies the
    loss by ``scale`` inside the differentiated function, so the grads
    come out scaled — callers unscale in fp32 (loss-scaling contract).
    """
    cdt = None if compute_dtype is None else jnp.dtype(compute_dtype)

    def run_loss(params, batch, scale):
        if cdt is not None:
            # float inputs (images, frames, patch embeds) follow the
            # params to the compute dtype — mixed-dtype conv/matmul
            # operands are a lax type error, not an implicit upcast
            params = cast_floats(params, cdt)
            batch = cast_floats(batch, cdt)
        loss = loss_fn(params, batch)
        if scale is not None:
            loss = loss * scale.astype(loss.dtype)
        return loss

    def loss_and_grad(params, batch, scale=None):
        if microbatch == 1:
            if cdt is None and scale is None:
                # the pre-policy trace, bit-equal
                return jax.value_and_grad(loss_fn)(params, batch)
            return jax.value_and_grad(run_loss)(params, batch, scale)
        from repro.models._unroll import scan_or_unroll
        # split as (b/m, m) then move m to the front: microbatch i takes the
        # i-th row of each contiguous group, so a batch dim sharded over
        # 'data' stays cleanly sharded after the reshape (a plain (m, b/m)
        # reshape interleaves shards and GSPMD replicates — 4x compute).
        mb = jax.tree.map(
            lambda x: jnp.moveaxis(
                x.reshape((x.shape[0] // microbatch, microbatch)
                          + x.shape[1:]), 1, 0), batch)
        acc0 = (jnp.zeros((), jnp.float32),
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params))

        def mstep(carry, mbatch):
            lsum, gsum = carry
            li, g = jax.value_and_grad(run_loss)(params, mbatch, scale)
            gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                gsum, g)
            return (lsum + li, gsum), None

        (lsum, gsum), _ = scan_or_unroll(mstep, acc0, mb)
        inv = 1.0 / microbatch
        return lsum * inv, jax.tree.map(lambda g: g * inv, gsum)

    return loss_and_grad


def _select_step(finite, new_tree, old_tree):
    """Per-leaf ``where``: keep the candidate update only on finite steps
    (the loss-scaling skip — both branches are already computed, so the
    select is free next to a cond)."""
    return jax.tree.map(lambda n, o: jnp.where(finite, n, o),
                        new_tree, old_tree)


def _synced(exchanger: Exchanger, params, opt_state, step, sync_every: int):
    """Apply the exchange, every step or gated every ``sync_every`` steps."""
    if sync_every == 1:
        return exchanger.average(params), exchanger.average(opt_state)
    do_sync = (step + 1) % sync_every == 0
    if exchanger.is_mesh:
        # cond, not where: do_sync is replicated across shards (every shard
        # holds the same step counter), so all shards branch together and
        # the skipped steps really skip the collectives — with where, local
        # SGD would pay full exchange traffic every step
        return jax.lax.cond(
            do_sync,
            lambda t: (exchanger.average(t[0]), exchanger.average(t[1])),
            lambda t: t, (params, opt_state))
    params = jax.tree.map(lambda a, b: jnp.where(do_sync, a, b),
                          exchanger.average(params), params)
    opt_state = jax.tree.map(lambda a, b: jnp.where(do_sync, a, b),
                             exchanger.average(opt_state), opt_state)
    return params, opt_state


def _delayed_synced(exchanger: Exchanger, prev_params, prev_opt,
                    new_params, new_opt, aux, step, sync_every: int):
    """One-step-stale overlapped exchange (delay=1).

    The collective runs on the *incoming* (pre-update) parameters, which
    have no data dependency on this step's forward/backward — XLA is free
    to schedule the communication concurrently with the compute instead of
    serializing it after the update.  The local progress is then grafted
    onto the fresh consensus::

        w_{t+1} = avg(w_t) + (new_t - w_t)

    (exact for the paper's every-step averaging up to one step of
    staleness; scalars — optimizer step counts — take the local value).
    With a stateful (compressed) exchanger the consensus comes from
    ``average_delta`` against ``aux["base"]`` with error-feedback
    residuals, and ``aux`` is rolled forward.  Returns
    ``(params, opt_state, aux)``."""
    if exchanger.strategy == "none":
        return new_params, new_opt, aux

    def exchange(operand):
        pp, po, np_, no_, ax = operand
        if exchanger.is_stateful:
            (avg_p, avg_o), new_res = exchanger.average_delta(
                (pp, po), ax["base"], ax["residual"])
            ax = {"base": (avg_p, avg_o), "residual": new_res}
        else:
            avg_p = exchanger.average(pp)
            avg_o = exchanger.average(po)

        def graft(a, n, w):
            return a + (n - w) if a.ndim else n

        return (jax.tree.map(graft, avg_p, np_, pp),
                jax.tree.map(graft, avg_o, no_, po), ax)

    operand = (prev_params, prev_opt, new_params, new_opt, aux)
    if sync_every == 1:
        return exchange(operand)
    # cond (not where) for the same reason as _synced: the predicate is
    # replica-identical, so gated-off steps really skip the collectives.
    do_sync = (step + 1) % sync_every == 0
    return jax.lax.cond(do_sync, exchange,
                        lambda t: (t[2], t[3], t[4]), operand)


def make_param_avg_step(loss_fn: Callable, optimizer: Optimizer,
                        schedule: Callable, *,
                        strategy: Union[str, Exchanger,
                                        ExchangeConfig] = "all_reduce",
                        sync_every: int = 1, microbatch: int = 1,
                        delay: int = 0, replica_exec: str = "vmap",
                        numerics: Union[NumericsPolicy, None] = None):
    """Reference engine.  loss_fn(params, batch) -> scalar; returns
    step(state, batch).  batch leaves have leading axis R matching
    state.params.  ``strategy`` is a name, an axis-less ``Exchanger``, or
    an ``ExchangeConfig`` (which then supplies ``delay``/``sync_every``).

    ``delay=1`` selects the one-step-stale overlapped exchange
    (``_delayed_synced``); ``delay=0`` is the synchronous path, bit-equal
    to the pre-policy engine.  ``replica_exec`` picks how the R
    independent replicas execute: ``"vmap"`` (batched, the default) or
    ``"scan"`` (sequential replicas, unrolled in the traced program; at
    fixed global batch each replica's smaller microbatch is more
    cache-resident, which is where replica scaling pays on hosts
    without R-way parallel compute).

    ``numerics`` (NumericsPolicy) engages mixed precision: a
    ``compute_dtype`` casts params at the loss boundary, and loss scaling
    multiplies the loss before the backward pass, unscales the grads in
    fp32, and SKIPS the whole update (params, optimizer state, scale
    growth) when any replica's grads go non-finite — the finite check is
    ANDed across all replicas so they stay in lockstep.  Pair with
    ``optim.optimizers.for_numerics`` so fp32 masters ride the optimizer
    state.  A default/fp32 policy leaves the pre-policy trace bit-equal.
    """
    if isinstance(strategy, ExchangeConfig):
        sync_every = strategy.sync_every
        delay = strategy.delay
    exchanger = as_exchanger(strategy)
    if exchanger.is_mesh:
        raise ValueError("make_param_avg_step is the axis-0 reference "
                         "engine; use make_mesh_param_avg_step for a "
                         "mesh-bound Exchanger")
    if delay not in (0, 1):
        raise ValueError(f"delay must be 0 or 1, got {delay}")
    if replica_exec not in ("vmap", "scan"):
        raise ValueError(f"replica_exec must be 'vmap' or 'scan', "
                         f"got {replica_exec!r}")
    if exchanger.is_stateful and delay == 0 \
            and exchanger.compression == "topk":
        raise ValueError("topk compression requires delay=1 (its "
                         "base+residual state rides the delayed exchange)")
    active = numerics is not None and not numerics.is_training_default
    scaling = active and numerics.loss_scale != "none"
    loss_and_grad = _make_loss_and_grad(
        loss_fn, microbatch,
        compute_dtype=(numerics.compute_dtype or numerics.param_dtype)
        if active else None)

    def _unscaled(loss, grads, scale):
        """Undo the loss scale in fp32 + the cross-replica finite check."""
        if scale is None:
            return loss, grads, jnp.asarray(True)
        inv = 1.0 / scale
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * inv, grads)
        # one scalar over EVERY replica's grads: the skip decision must be
        # replica-identical or the replicas fall out of lockstep
        return loss * inv, grads, all_finite(grads)

    def _single_replica_update(state, batch, lr, scale=None):
        # degenerate single-replica case: skip vmap entirely — the
        # size-1 batched axis confuses GSPMD sharding propagation
        # (observed as "involuntary full rematerialization" resharding)
        p0 = jax.tree.map(lambda x: x[0], state.params)
        o0 = jax.tree.map(lambda x: x[0] if x.ndim > 0 else x,
                          state.opt_state)
        b0 = jax.tree.map(lambda x: x[0], batch)
        loss, grads = loss_and_grad(p0, b0, scale)
        loss, grads, finite = _unscaled(loss, grads, scale)
        updates, o0 = optimizer.update(grads, o0, p0, lr)
        p0 = apply_updates(p0, updates)
        params = jax.tree.map(lambda x: x[None], p0)
        opt_state = jax.tree.map(
            lambda x: x[None] if x.ndim > 0 else x, o0)
        # re-attach scalar leaves' replica axis bookkeeping
        opt_state = jax.tree.map(
            lambda new, old: new if new.ndim == old.ndim else
            jnp.broadcast_to(new, old.shape),
            opt_state, state.opt_state)
        return params, opt_state, loss, finite

    def _replica_update(state, batch, lr, scale=None):
        """Independent per-replica update -> (params_r, opt_r, mean loss,
        all-replica finite flag)."""
        if replica_exec == "scan":
            # sequential replicas, unrolled: replica i's op sequence is
            # emitted after replica i-1's, so each forward/backward runs
            # at per-replica batch size with a cache-resident working
            # set.  lax.map would also sequence, but its while-loop
            # lowering defeats XLA:CPU fusion (measured ~7x slower than
            # this unroll); vmap fuses replicas back into global-batch
            # ops and loses the locality entirely.
            n_rep = jax.tree.leaves(batch)[0].shape[0]
            outs = []
            for ri in range(n_rep):
                p = jax.tree.map(lambda x: x[ri], state.params)
                o = jax.tree.map(lambda x: x[ri] if x.ndim else x,
                                 state.opt_state)
                b = jax.tree.map(lambda x: x[ri], batch)
                loss, grads = loss_and_grad(p, b, scale)
                loss, grads, finite = _unscaled(loss, grads, scale)
                updates, o = optimizer.update(grads, o, p, lr)
                outs.append((apply_updates(p, updates), o, loss, finite))
            params = jax.tree.map(lambda *xs: jnp.stack(xs),
                                  *[t[0] for t in outs])
            # scalar opt leaves are replica-identical bookkeeping; keep
            # them unstacked so the state layout matches the vmap path
            opt_state = jax.tree.map(
                lambda old, *xs: jnp.stack(xs) if old.ndim else xs[0],
                state.opt_state, *[t[1] for t in outs])
            return (params, opt_state,
                    jnp.mean(jnp.stack([t[2] for t in outs])),
                    jnp.stack([t[3] for t in outs]).all())
        # 1) independent per-replica grads — no cross-replica communication
        losses, grads = jax.vmap(
            lambda p, b: loss_and_grad(p, b, scale),
            in_axes=(0, 0))(state.params, batch)
        loss = jnp.mean(losses)
        finite = jnp.asarray(True)
        if scale is not None:
            inv = 1.0 / scale
            grads = jax.tree.map(lambda g: g.astype(jnp.float32) * inv,
                                 grads)
            # the (R, ...) grads reduce to ONE flag — the AND over replicas
            loss, finite = loss * inv, all_finite(grads)
        # 2) independent per-replica optimizer updates
        updates, opt_state = jax.vmap(
            lambda g, s, p: optimizer.update(g, s, p, lr))(
                grads, state.opt_state, state.params)
        params = jax.vmap(apply_updates)(state.params, updates)
        return params, opt_state, loss, finite

    def step(state: TrainState, batch) -> tuple:
        lr = schedule(state.step)
        n_rep = jax.tree.leaves(batch)[0].shape[0]
        scale = state.numerics["scale"] if scaling else None

        if delay == 0 and replica_exec == "vmap" and not active:
            # the pre-policy synchronous path, unchanged
            if n_rep == 1:
                params, opt_state, loss, _ = _single_replica_update(
                    state, batch, lr)
                return TrainState(params, opt_state, state.step + 1), loss
            params, opt_state, loss, _ = _replica_update(state, batch, lr)
            # 3) exchange & average params AND optimizer state (paper fn. 3)
            params, opt_state = _synced(exchanger, params, opt_state,
                                        state.step, sync_every)
            return TrainState(params, opt_state, state.step + 1), loss

        if n_rep == 1 and replica_exec == "vmap":
            params, opt_state, loss, finite = _single_replica_update(
                state, batch, lr, scale)
        else:
            params, opt_state, loss, finite = _replica_update(
                state, batch, lr, scale)

        ns = state.numerics
        if scaling:
            # poisoned step: keep the incoming state, halve the scale
            params = _select_step(finite, params, state.params)
            opt_state = _select_step(finite, opt_state, state.opt_state)
            ns = next_loss_scale_state(numerics, ns, finite)

        if delay == 0:
            params, opt_state = _synced(exchanger, params, opt_state,
                                        state.step, sync_every)
            return TrainState(params, opt_state, state.step + 1,
                              state.exchange, ns), loss

        params, opt_state, aux = _delayed_synced(
            exchanger, state.params, state.opt_state, params, opt_state,
            state.exchange, state.step, sync_every)
        return TrainState(params, opt_state, state.step + 1, aux, ns), loss

    return step


def replica_specs(tree, axis: AxisName):
    """shard_map PartitionSpecs for a param-avg pytree: leading replica dim
    over ``axis``, scalars replicated."""
    return jax.tree.map(
        lambda x: P(axis, *([None] * (x.ndim - 1))) if x.ndim else P(), tree)


def make_mesh_param_avg_step(loss_fn: Callable, optimizer: Optimizer,
                             schedule: Callable, *, mesh,
                             strategy: Union[str, Exchanger,
                                             ExchangeConfig] = "all_reduce",
                             replica_axes=("pod", "data"),
                             sync_every: int = 1, microbatch: int = 1,
                             delay: int = 0,
                             numerics: Union[NumericsPolicy, None] = None):
    """Mesh-native engine: the whole train step is one ``shard_map``
    program over ``replica_axes`` of ``mesh``; each shard owns exactly one
    replica and the exchange is a real collective (all-reduce /
    collective-permute — see core/param_avg.py).

    Requires one replica per mesh slice (R == prod of replica axis sizes)
    and all remaining mesh axes of size 1: shard_map's partial-auto mode is
    not implemented in the pinned jax, so tensor-parallel inner axes cannot
    yet be delegated to GSPMD inside the manual region — combine replicas
    with TP via the reference engine instead (launch/dryrun.py does).

    ``delay=1``: one-step-stale overlapped exchange.  The collective's
    input is the shard's *incoming* parameters — independent of this
    step's forward/backward — so XLA's latency-hiding scheduler can run
    the all-reduce / permute chain concurrently with the compute instead
    of after it (see ``_delayed_synced``).  ``delay=0`` is unchanged.

    ``numerics`` mirrors the reference engine: compute-dtype cast at the
    loss boundary, loss scaling with the skip decision ANDed across the
    replica axes via ``jax.lax.pmin`` — every shard must agree or the
    replicas fall out of lockstep.  Default/fp32 policy: pre-policy
    trace, bit-equal.
    """
    if isinstance(strategy, ExchangeConfig):
        sync_every = strategy.sync_every
        delay = strategy.delay
    if delay not in (0, 1):
        raise ValueError(f"delay must be 0 or 1, got {delay}")
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    axes = tuple(a for a in replica_axes if a in mesh.axis_names)
    if not axes:
        raise ValueError(f"none of {replica_axes} in mesh axes "
                         f"{mesh.axis_names}")
    for name, size in sizes.items():
        if name not in axes and size != 1:
            raise ValueError(
                f"mesh engine needs non-replica axis {name!r} of size 1 "
                f"(got {size}); shard_map auto-mode is unavailable — use "
                "make_param_avg_step (reference engine) for replica x TP")
    axis = axes if len(axes) > 1 else axes[0]
    n_rep = math.prod(sizes[a] for a in axes)
    exchanger = as_exchanger(strategy, axis=axis)
    if exchanger.is_stateful and delay == 0 \
            and exchanger.compression == "topk":
        raise ValueError("topk compression requires delay=1 (its "
                         "base+residual state rides the delayed exchange)")
    active = numerics is not None and not numerics.is_training_default
    scaling = active and numerics.loss_scale != "none"
    loss_and_grad = _make_loss_and_grad(
        loss_fn, microbatch,
        compute_dtype=(numerics.compute_dtype or numerics.param_dtype)
        if active else None)

    def shard_step(state: TrainState, batch) -> tuple:
        # per-shard leaves keep a leading local-replica axis of size 1
        lr = schedule(state.step)
        p_prev = jax.tree.map(lambda x: x[0], state.params)
        o_prev = jax.tree.map(lambda x: x[0] if x.ndim > 0 else x,
                              state.opt_state)
        b0 = jax.tree.map(lambda x: x[0], batch)
        scale = state.numerics["scale"] if scaling else None
        loss, grads = loss_and_grad(p_prev, b0, scale)
        ns = state.numerics
        finite = None
        if scaling:
            inv = 1.0 / scale
            grads = jax.tree.map(lambda g: g.astype(jnp.float32) * inv,
                                 grads)
            loss = loss * inv
            # the skip decision must be identical on every shard: AND the
            # local flags across the replica axes (min over 0/1 ints)
            finite = jax.lax.pmin(
                all_finite(grads).astype(jnp.int32), axis) > 0
        updates, o0 = optimizer.update(grads, o_prev, p_prev, lr)
        p0 = apply_updates(p_prev, updates)
        if scaling:
            # poisoned step: keep the incoming state, halve the scale
            p0 = _select_step(finite, p0, p_prev)
            o0 = _select_step(finite, o0, o_prev)
            ns = next_loss_scale_state(numerics, ns, finite)
        aux = state.exchange
        if delay == 0:
            p0, o0 = _synced(exchanger, p0, o0, state.step, sync_every)
        else:
            if aux is not None:
                aux = jax.tree.map(lambda x: x[0] if x.ndim > 0 else x,
                                   aux)
            p0, o0, aux = _delayed_synced(exchanger, p_prev, o_prev,
                                          p0, o0, aux, state.step,
                                          sync_every)
            if aux is not None:
                aux = jax.tree.map(
                    lambda x: x[None] if x.ndim > 0 else x, aux)
        params = jax.tree.map(lambda x: x[None], p0)
        opt_state = jax.tree.map(
            lambda new, old: new[None] if old.ndim > new.ndim else new,
            o0, state.opt_state)
        loss = jax.lax.pmean(loss, axis)
        return TrainState(params, opt_state, state.step + 1, aux, ns), loss

    def step(state: TrainState, batch) -> tuple:
        r = jax.tree.leaves(batch)[0].shape[0]
        if r != n_rep:
            raise ValueError(
                f"mesh engine needs one replica per mesh slice: batch has "
                f"R={r} but {axes} span {n_rep} devices")
        sspec = replica_specs(state, axis)
        bspec = replica_specs(batch, axis)
        fn = shard_map(shard_step, mesh=mesh, in_specs=(sspec, bspec),
                       out_specs=(sspec, P()), check_rep=False)
        return fn(state, batch)

    return step


def make_eval_step(metric_fn: Callable, *, replica_axis: bool = True):
    """Jitted validation step: ``metric_fn(params, batch) -> dict`` of
    scalar metrics (loss + top-1 error for AlexNet, loss + perplexity for
    the LM zoo — see ``repro.train_loop.eval``).

    ``replica_axis=True`` (param-avg engines) evaluates the AVERAGED model
    — the mean over the leading replica axis, i.e. the ensemble the paper
    reports validation error for.  With ``sync_every=1`` replicas are
    already identical and the mean is a no-op numerically; under local SGD
    it is the natural "current consensus" model.  Batches carry NO replica
    axis: eval is a property of the model, not of the parallel layout.
    """

    def eval_step(params, batch):
        if replica_axis:
            params = jax.tree.map(
                lambda x: jnp.mean(x.astype(jnp.float32), axis=0).astype(
                    x.dtype), params)
        return metric_fn(params, batch)

    return eval_step


def make_grad_avg_step(loss_fn: Callable, optimizer: Optimizer,
                       schedule: Callable, *,
                       numerics: Union[NumericsPolicy, None] = None):
    """Modern baseline: loss is a mean over the global batch, so XLA
    all-reduces gradients inside the backward pass.  ``numerics`` engages
    the same mixed-precision contract as the param-avg engines (single
    param copy, so the finite check needs no cross-replica reduction)."""
    active = numerics is not None and not numerics.is_training_default
    scaling = active and numerics.loss_scale != "none"
    loss_and_grad = _make_loss_and_grad(
        loss_fn, 1, compute_dtype=(numerics.compute_dtype or numerics.param_dtype)
        if active else None)

    def step(state: TrainState, batch) -> tuple:
        lr = schedule(state.step)
        scale = state.numerics["scale"] if scaling else None
        loss, grads = loss_and_grad(state.params, batch, scale)
        ns = state.numerics
        finite = None
        if scaling:
            inv = 1.0 / scale
            grads = jax.tree.map(lambda g: g.astype(jnp.float32) * inv,
                                 grads)
            loss = loss * inv
            finite = all_finite(grads)
        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params, lr)
        params = apply_updates(state.params, updates)
        if scaling:
            params = _select_step(finite, params, state.params)
            opt_state = _select_step(finite, opt_state, state.opt_state)
            ns = next_loss_scale_state(numerics, ns, finite)
        return TrainState(params, opt_state, state.step + 1,
                          state.exchange, ns), loss

    return step


def make_serve_step(decode_fn: Callable):
    """decode_fn(params, cache, tokens, pos) -> (logits, cache).

    Greedy argmax serving step: feeds back the sampled token.
    """

    def step(params, cache, tokens, pos):
        logits, cache = decode_fn(params, cache, tokens, pos)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(tokens.dtype)
        return next_tok, cache

    return step


def reshape_for_replicas(batch, n_replicas: int):
    """(B, ...) host batch -> (R, B/R, ...)."""
    def f(x):
        b = x.shape[0]
        assert b % n_replicas == 0, (b, n_replicas)
        return x.reshape((n_replicas, b // n_replicas) + x.shape[1:])
    return jax.tree.map(f, batch)
