"""Train/serve step builders.

``make_param_avg_step`` is the paper's algorithm (Fig. 2): per-replica
independent forward/backward/update (NO gradient communication), then
exchange+average of params and optimizer state.  It runs on the reference
engine (leading replica axis R + vmap, GSPMD sharding) and is what the
multi-pod dry-run compiles.  ``make_mesh_param_avg_step`` is the mesh-native
engine: the SAME algorithm as a ``jax.shard_map`` program over the replica
mesh axes, where the exchange lowers to real collectives (see
core/param_avg.py).  ``make_grad_avg_step`` is the modern baseline: single
param copy, gradients mean-reduced across the batch by XLA.  ``sync_every``
turns the paper's every-step averaging into local SGD (beyond-paper
extension — expressible only in the param-avg formulation).

State layout (both param-avg engines): every leaf has leading axis
R = #replicas; batches are (R, per_replica_batch, ...).  The reference
engine vmaps over axis 0; the mesh engine shards axis 0 one-replica-per-
shard so each program instance owns exactly one replica — the paper's
one-model-per-GPU memory layout, literally.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.param_avg import (AxisName, Exchanger, as_exchanger,
                                  replicate, shard_map)
from repro.optim.optimizers import Optimizer, apply_updates


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jnp.ndarray


def init_param_avg_state(rng, init_fn, optimizer: Optimizer,
                         n_replicas: int) -> TrainState:
    params = init_fn(rng)
    params_r = replicate(params, n_replicas)
    opt_r = jax.vmap(optimizer.init)(params_r)
    return TrainState(params_r, opt_r, jnp.zeros((), jnp.int32))


def init_grad_avg_state(rng, init_fn, optimizer: Optimizer) -> TrainState:
    params = init_fn(rng)
    return TrainState(params, optimizer.init(params),
                      jnp.zeros((), jnp.int32))


def _make_loss_and_grad(loss_fn: Callable, microbatch: int):
    """Shared by both engines.  loss_fn(params, batch) -> scalar.

    ``loss_fn`` must be differentiable END TO END for whatever kernel
    backend its config's ``KernelPolicy`` selects — every Pallas kernel
    (conv2d, flash_attention, rglru, rwkv6) carries a ``jax.custom_vjp``
    precisely so ``jax.value_and_grad`` here works identically on the
    ``xla`` and ``pallas`` policies (no kernel kwargs reach this layer).

    ``microbatch`` > 1 accumulates gradients over that many slices of the
    per-replica batch (fp32 accumulator) — bounds activation memory at the
    cost of re-reading params per slice.
    """

    def loss_and_grad(params, batch):
        if microbatch == 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        from repro.models._unroll import scan_or_unroll
        # split as (b/m, m) then move m to the front: microbatch i takes the
        # i-th row of each contiguous group, so a batch dim sharded over
        # 'data' stays cleanly sharded after the reshape (a plain (m, b/m)
        # reshape interleaves shards and GSPMD replicates — 4x compute).
        mb = jax.tree.map(
            lambda x: jnp.moveaxis(
                x.reshape((x.shape[0] // microbatch, microbatch)
                          + x.shape[1:]), 1, 0), batch)
        acc0 = (jnp.zeros((), jnp.float32),
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params))

        def mstep(carry, mbatch):
            lsum, gsum = carry
            li, g = jax.value_and_grad(loss_fn)(params, mbatch)
            gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                gsum, g)
            return (lsum + li, gsum), None

        (lsum, gsum), _ = scan_or_unroll(mstep, acc0, mb)
        inv = 1.0 / microbatch
        return lsum * inv, jax.tree.map(lambda g: g * inv, gsum)

    return loss_and_grad


def _synced(exchanger: Exchanger, params, opt_state, step, sync_every: int):
    """Apply the exchange, every step or gated every ``sync_every`` steps."""
    if sync_every == 1:
        return exchanger.average(params), exchanger.average(opt_state)
    do_sync = (step + 1) % sync_every == 0
    if exchanger.is_mesh:
        # cond, not where: do_sync is replicated across shards (every shard
        # holds the same step counter), so all shards branch together and
        # the skipped steps really skip the collectives — with where, local
        # SGD would pay full exchange traffic every step
        return jax.lax.cond(
            do_sync,
            lambda t: (exchanger.average(t[0]), exchanger.average(t[1])),
            lambda t: t, (params, opt_state))
    params = jax.tree.map(lambda a, b: jnp.where(do_sync, a, b),
                          exchanger.average(params), params)
    opt_state = jax.tree.map(lambda a, b: jnp.where(do_sync, a, b),
                             exchanger.average(opt_state), opt_state)
    return params, opt_state


def make_param_avg_step(loss_fn: Callable, optimizer: Optimizer,
                        schedule: Callable, *,
                        strategy: Union[str, Exchanger] = "all_reduce",
                        sync_every: int = 1, microbatch: int = 1):
    """Reference engine.  loss_fn(params, batch) -> scalar; returns
    step(state, batch).  batch leaves have leading axis R matching
    state.params.  ``strategy`` is a name or an axis-less ``Exchanger``.
    """
    exchanger = as_exchanger(strategy)
    if exchanger.is_mesh:
        raise ValueError("make_param_avg_step is the axis-0 reference "
                         "engine; use make_mesh_param_avg_step for a "
                         "mesh-bound Exchanger")
    loss_and_grad = _make_loss_and_grad(loss_fn, microbatch)

    def step(state: TrainState, batch) -> tuple:
        lr = schedule(state.step)

        n_rep = jax.tree.leaves(batch)[0].shape[0]
        if n_rep == 1:
            # degenerate single-replica case: skip vmap entirely — the
            # size-1 batched axis confuses GSPMD sharding propagation
            # (observed as "involuntary full rematerialization" resharding)
            p0 = jax.tree.map(lambda x: x[0], state.params)
            o0 = jax.tree.map(lambda x: x[0] if x.ndim > 0 else x,
                              state.opt_state)
            b0 = jax.tree.map(lambda x: x[0], batch)
            loss, grads = loss_and_grad(p0, b0)
            updates, o0 = optimizer.update(grads, o0, p0, lr)
            p0 = apply_updates(p0, updates)
            params = jax.tree.map(lambda x: x[None], p0)
            opt_state = jax.tree.map(
                lambda x: x[None] if x.ndim > 0 else x, o0)
            # re-attach scalar leaves' replica axis bookkeeping
            opt_state = jax.tree.map(
                lambda new, old: new if new.ndim == old.ndim else
                jnp.broadcast_to(new, old.shape),
                opt_state, state.opt_state)
            return TrainState(params, opt_state, state.step + 1), loss

        # 1) independent per-replica grads — no cross-replica communication
        losses, grads = jax.vmap(loss_and_grad, in_axes=(0, 0))(
            state.params, batch)
        # 2) independent per-replica optimizer updates
        updates, opt_state = jax.vmap(
            lambda g, s, p: optimizer.update(g, s, p, lr))(
                grads, state.opt_state, state.params)
        params = jax.vmap(apply_updates)(state.params, updates)

        # 3) exchange & average params AND optimizer state (paper fn. 3)
        params, opt_state = _synced(exchanger, params, opt_state,
                                    state.step, sync_every)

        new_state = TrainState(params, opt_state, state.step + 1)
        return new_state, jnp.mean(losses)

    return step


def replica_specs(tree, axis: AxisName):
    """shard_map PartitionSpecs for a param-avg pytree: leading replica dim
    over ``axis``, scalars replicated."""
    return jax.tree.map(
        lambda x: P(axis, *([None] * (x.ndim - 1))) if x.ndim else P(), tree)


def make_mesh_param_avg_step(loss_fn: Callable, optimizer: Optimizer,
                             schedule: Callable, *, mesh,
                             strategy: Union[str, Exchanger] = "all_reduce",
                             replica_axes=("pod", "data"),
                             sync_every: int = 1, microbatch: int = 1):
    """Mesh-native engine: the whole train step is one ``shard_map``
    program over ``replica_axes`` of ``mesh``; each shard owns exactly one
    replica and the exchange is a real collective (all-reduce /
    collective-permute — see core/param_avg.py).

    Requires one replica per mesh slice (R == prod of replica axis sizes)
    and all remaining mesh axes of size 1: shard_map's partial-auto mode is
    not implemented in the pinned jax, so tensor-parallel inner axes cannot
    yet be delegated to GSPMD inside the manual region — combine replicas
    with TP via the reference engine instead (launch/dryrun.py does).
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    axes = tuple(a for a in replica_axes if a in mesh.axis_names)
    if not axes:
        raise ValueError(f"none of {replica_axes} in mesh axes "
                         f"{mesh.axis_names}")
    for name, size in sizes.items():
        if name not in axes and size != 1:
            raise ValueError(
                f"mesh engine needs non-replica axis {name!r} of size 1 "
                f"(got {size}); shard_map auto-mode is unavailable — use "
                "make_param_avg_step (reference engine) for replica x TP")
    axis = axes if len(axes) > 1 else axes[0]
    n_rep = math.prod(sizes[a] for a in axes)
    exchanger = as_exchanger(strategy, axis=axis)
    loss_and_grad = _make_loss_and_grad(loss_fn, microbatch)

    def shard_step(state: TrainState, batch) -> tuple:
        # per-shard leaves keep a leading local-replica axis of size 1
        lr = schedule(state.step)
        p0 = jax.tree.map(lambda x: x[0], state.params)
        o0 = jax.tree.map(lambda x: x[0] if x.ndim > 0 else x,
                          state.opt_state)
        b0 = jax.tree.map(lambda x: x[0], batch)
        loss, grads = loss_and_grad(p0, b0)
        updates, o0 = optimizer.update(grads, o0, p0, lr)
        p0 = apply_updates(p0, updates)
        p0, o0 = _synced(exchanger, p0, o0, state.step, sync_every)
        params = jax.tree.map(lambda x: x[None], p0)
        opt_state = jax.tree.map(
            lambda new, old: new[None] if old.ndim > new.ndim else new,
            o0, state.opt_state)
        loss = jax.lax.pmean(loss, axis)
        return TrainState(params, opt_state, state.step + 1), loss

    def step(state: TrainState, batch) -> tuple:
        r = jax.tree.leaves(batch)[0].shape[0]
        if r != n_rep:
            raise ValueError(
                f"mesh engine needs one replica per mesh slice: batch has "
                f"R={r} but {axes} span {n_rep} devices")
        sspec = replica_specs(state, axis)
        bspec = replica_specs(batch, axis)
        fn = shard_map(shard_step, mesh=mesh, in_specs=(sspec, bspec),
                       out_specs=(sspec, P()), check_rep=False)
        return fn(state, batch)

    return step


def make_eval_step(metric_fn: Callable, *, replica_axis: bool = True):
    """Jitted validation step: ``metric_fn(params, batch) -> dict`` of
    scalar metrics (loss + top-1 error for AlexNet, loss + perplexity for
    the LM zoo — see ``repro.train_loop.eval``).

    ``replica_axis=True`` (param-avg engines) evaluates the AVERAGED model
    — the mean over the leading replica axis, i.e. the ensemble the paper
    reports validation error for.  With ``sync_every=1`` replicas are
    already identical and the mean is a no-op numerically; under local SGD
    it is the natural "current consensus" model.  Batches carry NO replica
    axis: eval is a property of the model, not of the parallel layout.
    """

    def eval_step(params, batch):
        if replica_axis:
            params = jax.tree.map(
                lambda x: jnp.mean(x.astype(jnp.float32), axis=0).astype(
                    x.dtype), params)
        return metric_fn(params, batch)

    return eval_step


def make_grad_avg_step(loss_fn: Callable, optimizer: Optimizer,
                       schedule: Callable):
    """Modern baseline: loss is a mean over the global batch, so XLA
    all-reduces gradients inside the backward pass."""

    def step(state: TrainState, batch) -> tuple:
        lr = schedule(state.step)
        loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params, lr)
        params = apply_updates(state.params, updates)
        return TrainState(params, opt_state, state.step + 1), loss

    return step


def make_serve_step(decode_fn: Callable):
    """decode_fn(params, cache, tokens, pos) -> (logits, cache).

    Greedy argmax serving step: feeds back the sampled token.
    """

    def step(params, cache, tokens, pos):
        logits, cache = decode_fn(params, cache, tokens, pos)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(tokens.dtype)
        return next_tok, cache

    return step


def reshape_for_replicas(batch, n_replicas: int):
    """(B, ...) host batch -> (R, B/R, ...)."""
    def f(x):
        b = x.shape[0]
        assert b % n_replicas == 0, (b, n_replicas)
        return x.reshape((n_replicas, b // n_replicas) + x.shape[1:])
    return jax.tree.map(f, batch)
