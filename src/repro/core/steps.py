"""Train/serve step builders.

``make_param_avg_step`` is the paper's algorithm (Fig. 2): per-replica
independent forward/backward/update (NO gradient communication), then
exchange+average of params and optimizer state.  ``make_grad_avg_step`` is
the modern baseline: single param copy, gradients mean-reduced across the
batch by XLA.  ``sync_every`` turns the paper's every-step averaging into
local SGD (beyond-paper extension — expressible only in the param-avg
formulation).

State layout (param_avg): every leaf has leading axis R = #replicas, sharded
over ('pod','data'); batches are (R, per_replica_batch, ...).  vmap over
axis 0 keeps each replica's computation on its own mesh slice.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.param_avg import exchange_average, replicate
from repro.optim.optimizers import Optimizer, apply_updates


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jnp.ndarray


def init_param_avg_state(rng, init_fn, optimizer: Optimizer,
                         n_replicas: int) -> TrainState:
    params = init_fn(rng)
    params_r = replicate(params, n_replicas)
    opt_r = jax.vmap(optimizer.init)(params_r)
    return TrainState(params_r, opt_r, jnp.zeros((), jnp.int32))


def init_grad_avg_state(rng, init_fn, optimizer: Optimizer) -> TrainState:
    params = init_fn(rng)
    return TrainState(params, optimizer.init(params),
                      jnp.zeros((), jnp.int32))


def make_param_avg_step(loss_fn: Callable, optimizer: Optimizer,
                        schedule: Callable, *, strategy: str = "all_reduce",
                        sync_every: int = 1, microbatch: int = 1):
    """loss_fn(params, batch) -> scalar.  Returns step(state, batch).

    batch leaves have leading axis R matching state.params.
    ``microbatch`` > 1 accumulates gradients over that many slices of the
    per-replica batch (fp32 accumulator) — bounds activation memory at the
    cost of re-reading params per slice.
    """

    def loss_and_grad(params, batch):
        if microbatch == 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        from repro.models._unroll import scan_or_unroll
        # split as (b/m, m) then move m to the front: microbatch i takes the
        # i-th row of each contiguous group, so a batch dim sharded over
        # 'data' stays cleanly sharded after the reshape (a plain (m, b/m)
        # reshape interleaves shards and GSPMD replicates — 4x compute).
        mb = jax.tree.map(
            lambda x: jnp.moveaxis(
                x.reshape((x.shape[0] // microbatch, microbatch)
                          + x.shape[1:]), 1, 0), batch)
        acc0 = (jnp.zeros((), jnp.float32),
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params))

        def mstep(carry, mbatch):
            lsum, gsum = carry
            l, g = jax.value_and_grad(loss_fn)(params, mbatch)
            gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                gsum, g)
            return (lsum + l, gsum), None

        (lsum, gsum), _ = scan_or_unroll(mstep, acc0, mb)
        inv = 1.0 / microbatch
        return lsum * inv, jax.tree.map(lambda g: g * inv, gsum)

    def step(state: TrainState, batch) -> tuple:
        lr = schedule(state.step)

        n_rep = jax.tree.leaves(batch)[0].shape[0]
        if n_rep == 1:
            # degenerate single-replica case: skip vmap entirely — the
            # size-1 batched axis confuses GSPMD sharding propagation
            # (observed as "involuntary full rematerialization" resharding)
            p0 = jax.tree.map(lambda x: x[0], state.params)
            o0 = jax.tree.map(lambda x: x[0] if x.ndim > 0 else x,
                              state.opt_state)
            b0 = jax.tree.map(lambda x: x[0], batch)
            loss, grads = loss_and_grad(p0, b0)
            updates, o0 = optimizer.update(grads, o0, p0, lr)
            p0 = apply_updates(p0, updates)
            params = jax.tree.map(lambda x: x[None], p0)
            opt_state = jax.tree.map(
                lambda x: x[None] if x.ndim > 0 else x, o0)
            # re-attach scalar leaves' replica axis bookkeeping
            opt_state = jax.tree.map(
                lambda new, old: new if new.ndim == old.ndim else
                jnp.broadcast_to(new, old.shape),
                opt_state, state.opt_state)
            return TrainState(params, opt_state, state.step + 1), loss

        # 1) independent per-replica grads — no cross-replica communication
        losses, grads = jax.vmap(loss_and_grad, in_axes=(0, 0))(
            state.params, batch)
        # 2) independent per-replica optimizer updates
        updates, opt_state = jax.vmap(
            lambda g, s, p: optimizer.update(g, s, p, lr))(
                grads, state.opt_state, state.params)
        params = jax.vmap(apply_updates)(state.params, updates)

        # 3) exchange & average params AND optimizer state (paper fn. 3)
        if sync_every == 1:
            params = exchange_average(params, strategy)
            opt_state = exchange_average(opt_state, strategy)
        else:
            do_sync = (state.step + 1) % sync_every == 0
            params = jax.tree.map(
                lambda a, b: jnp.where(do_sync, a, b),
                exchange_average(params, strategy), params)
            opt_state = jax.tree.map(
                lambda a, b: jnp.where(do_sync, a, b),
                exchange_average(opt_state, strategy), opt_state)

        new_state = TrainState(params, opt_state, state.step + 1)
        return new_state, jnp.mean(losses)

    return step


def make_grad_avg_step(loss_fn: Callable, optimizer: Optimizer,
                       schedule: Callable):
    """Modern baseline: loss is a mean over the global batch, so XLA
    all-reduces gradients inside the backward pass."""

    def step(state: TrainState, batch) -> tuple:
        lr = schedule(state.step)
        loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params, lr)
        params = apply_updates(state.params, updates)
        return TrainState(params, opt_state, state.step + 1), loss

    return step


def make_serve_step(decode_fn: Callable):
    """decode_fn(params, cache, tokens, pos) -> (logits, cache).

    Greedy argmax serving step: feeds back the sampled token.
    """

    def step(params, cache, tokens, pos):
        logits, cache = decode_fn(params, cache, tokens, pos)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(tokens.dtype)
        return next_tok, cache

    return step


def reshape_for_replicas(batch, n_replicas: int):
    """(B, ...) host batch -> (R, B/R, ...)."""
    def f(x):
        b = x.shape[0]
        assert b % n_replicas == 0, (b, n_replicas)
        return x.reshape((n_replicas, b // n_replicas) + x.shape[1:])
    return jax.tree.map(f, batch)
