"""NumericsPolicy: precision as a first-class policy axis.

The paper squeezed AlexNet-scale training out of limited GPU memory by
being ruthless about where bytes live; this module is that discipline as
a policy object.  Mirroring ``KernelPolicy`` (PR 4), a frozen
``NumericsPolicy`` rides on every config (``cfg.numerics``) so each
layer resolves the same precision decisions without kwarg threading:

* **models/** read ``param_dtype(cfg)`` at init (None = inherit the
  config's legacy ``dtype`` field, which stops being dead weight).
* **core/steps.py + optim/** read ``compute_dtype`` / ``master_weights``
  / ``loss_scale``: bf16 compute with fp32 master weights held in
  optimizer state, and static/dynamic loss scaling whose non-finite
  detection SKIPS the update and halves the scale (see
  ``next_loss_scale_state``).
* **serving/ + models/attention.py** read ``kv_cache_dtype``: the ring
  KV cache stores bf16 or int8 (per-head-per-slot scales, dequantized
  in-kernel) — 2x/4x decode slots per byte of HBM.
* **checkpoint/ + train_loop/** stash ``describe()`` in the manifest's
  ``run_meta`` so resuming under a different policy warns loudly.

The default policy is inert by construction: ``is_training_default``
gates every train-step change, so ``numerics=fp32`` is bit-equal to the
pre-policy engine (golden-trace suite holds the line).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

LOSS_SCALES = ("none", "static", "dynamic")
KV_CACHE_DTYPES = ("auto", "fp32", "bf16", "int8")

_KV_JNP = {"fp32": "float32", "bf16": "bfloat16", "int8": "int8"}


@dataclasses.dataclass(frozen=True)
class NumericsPolicy:
    """Per-run precision policy; carried on configs next to ``kernels:``
    and ``exchange:``.

    ``param_dtype``/``compute_dtype`` are dtype names or None:
    param None = inherit ``cfg.dtype``; compute None = params' own dtype
    (no cast inserted).  ``accum_dtype`` documents the reduction dtype —
    every kernel epilogue and optimizer already accumulates fp32 and
    asserting it here keeps that a contract, not an accident.

    ``master_weights`` keeps an fp32 master copy of the params in
    optimizer state (``optim.optimizers.with_master_weights``); the
    replica exchange averages optimizer state too (paper footnote 3), so
    the consensus — and PR 7's error-feedback residuals — stay exact
    fp32 math even when the live params are bf16.

    ``loss_scale``: ``none`` | ``static`` (fixed multiplier, non-finite
    steps still skipped) | ``dynamic`` (halve on non-finite, grow 2x
    after ``growth_interval`` clean steps).

    ``kv_cache_dtype``: ``auto`` (model dtype) | ``fp32`` | ``bf16`` |
    ``int8`` (quantized ring cache with per-head-per-slot fp32 scales).
    """

    param_dtype: Optional[str] = None
    compute_dtype: Optional[str] = None
    accum_dtype: str = "float32"
    master_weights: bool = False
    loss_scale: str = "none"
    loss_scale_init: float = 2.0 ** 15
    growth_interval: int = 200
    kv_cache_dtype: str = "auto"

    def __post_init__(self):
        for name in ("param_dtype", "compute_dtype", "accum_dtype"):
            val = getattr(self, name)
            if val is not None:
                jnp.dtype(val)                      # raises on unknown names
        if self.accum_dtype != "float32":
            raise ValueError("accum_dtype is a contract, not a knob: every "
                             "kernel epilogue and optimizer accumulates "
                             f"float32 (got {self.accum_dtype!r})")
        if self.loss_scale not in LOSS_SCALES:
            raise ValueError(f"loss_scale must be one of {LOSS_SCALES}, "
                             f"got {self.loss_scale!r}")
        if self.kv_cache_dtype not in KV_CACHE_DTYPES:
            raise ValueError(f"kv_cache_dtype must be one of "
                             f"{KV_CACHE_DTYPES}, got "
                             f"{self.kv_cache_dtype!r}")
        if self.loss_scale_init <= 0:
            raise ValueError(f"loss_scale_init must be > 0, got "
                             f"{self.loss_scale_init}")

    # ------------------------------------------------------------------
    @property
    def is_training_default(self) -> bool:
        """True when the TRAIN-side policy is inert — the step builders
        take the pre-policy code path verbatim, keeping ``numerics=fp32``
        bit-equal to earlier PRs (kv_cache_dtype is serve-side only and
        does not disturb training)."""
        return (self.compute_dtype is None and not self.master_weights
                and self.loss_scale == "none")

    def describe(self) -> str:
        """Compact string for logs / checkpoint run_meta drift checks."""
        if self == NumericsPolicy():
            return "fp32"
        parts = []
        if self.param_dtype:
            parts.append(f"param={self.param_dtype}")
        if self.compute_dtype:
            parts.append(f"compute={self.compute_dtype}")
        if self.master_weights:
            parts.append("master_fp32")
        if self.loss_scale != "none":
            parts.append(f"loss_scale={self.loss_scale}")
        if self.kv_cache_dtype != "auto":
            parts.append(f"kv={self.kv_cache_dtype}")
        return ",".join(parts) or "fp32"


PRESETS = {
    # bit-equal to the pre-policy engine (the acceptance bar)
    "fp32": NumericsPolicy(),
    # the mixed-precision recipe: bf16 live params/compute, fp32 masters
    # in optimizer state, dynamic loss scaling, bf16 KV at serve time
    "bf16": NumericsPolicy(param_dtype="bfloat16", master_weights=True,
                           loss_scale="dynamic", kv_cache_dtype="bf16"),
}


def get_policy(name) -> NumericsPolicy:
    """Preset name -> policy (a NumericsPolicy passes through)."""
    if isinstance(name, NumericsPolicy):
        return name
    if name not in PRESETS:
        raise ValueError(f"unknown numerics preset {name!r}; known: "
                         f"{sorted(PRESETS)}")
    return PRESETS[name]


def numerics_of(cfg) -> NumericsPolicy:
    """The config's policy (default policy for configs without the field
    — stubs and tests predating this axis)."""
    pol = getattr(cfg, "numerics", None)
    return pol if pol is not None else NumericsPolicy()


def param_dtype(cfg):
    """Init/storage dtype for model parameters (and model inputs)."""
    pol = numerics_of(cfg)
    return jnp.dtype(pol.param_dtype or getattr(cfg, "dtype", "float32"))


def compute_dtype(cfg):
    """Dtype activations run in (falls back to the param dtype)."""
    pol = numerics_of(cfg)
    return jnp.dtype(pol.compute_dtype or pol.param_dtype
                     or getattr(cfg, "dtype", "float32"))


def kv_cache_spec(cfg, model_dtype):
    """(storage dtype, quantized?) for the ring KV cache."""
    sel = numerics_of(cfg).kv_cache_dtype
    if sel == "auto":
        return jnp.dtype(model_dtype), False
    return jnp.dtype(_KV_JNP[sel]), sel == "int8"


# ---------------------------------------------------------------- trees ----

def cast_floats(tree, dtype):
    """Cast inexact (float) leaves; integer/bool leaves pass through."""
    dt = jnp.dtype(dtype)
    return jax.tree.map(
        lambda x: x.astype(dt) if jnp.issubdtype(x.dtype, jnp.inexact)
        else x, tree)


def all_finite(tree) -> jnp.ndarray:
    """Scalar bool: every float leaf is fully finite.  Reduces each leaf
    to its sum first — one cheap scalar isfinite per leaf instead of a
    full-size predicate tensor (inf/nan propagate through sums)."""
    leaves = [jnp.isfinite(jnp.sum(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)
              if jnp.issubdtype(x.dtype, jnp.inexact)]
    if not leaves:
        return jnp.asarray(True)
    return jnp.stack(leaves).all()


# ----------------------------------------------------------- loss scale ----

def init_loss_scale_state(policy: NumericsPolicy):
    """TrainState.numerics pytree: None when scaling is off.  Scalars are
    replica-identical bookkeeping (like optimizer ``count``), so both
    engines carry them unreplicated/replicated-P()."""
    if policy is None or policy.loss_scale == "none":
        return None
    return {"scale": jnp.asarray(policy.loss_scale_init, jnp.float32),
            "good_steps": jnp.zeros((), jnp.int32),
            "skipped": jnp.zeros((), jnp.int32)}


def next_loss_scale_state(policy: NumericsPolicy, ns, finite):
    """Roll the loss-scale state one step.

    ``dynamic``: non-finite grads halve the scale (floor 1.0) and reset
    the clean-step counter; ``growth_interval`` consecutive clean steps
    double it (cap 2**24).  ``static``: the scale never moves.  Both
    count skipped steps — the update itself is skipped by the caller.
    """
    skipped = ns["skipped"] + (1 - finite.astype(jnp.int32))
    if policy.loss_scale == "static":
        return {"scale": ns["scale"], "good_steps": ns["good_steps"],
                "skipped": skipped}
    good = jnp.where(finite, ns["good_steps"] + 1, 0)
    grow = good >= policy.growth_interval
    scale = jnp.where(finite,
                      jnp.where(grow, ns["scale"] * 2.0, ns["scale"]),
                      ns["scale"] * 0.5)
    scale = jnp.clip(scale, 1.0, 2.0 ** 24)
    good = jnp.where(grow, 0, good)
    return {"scale": scale, "good_steps": good, "skipped": skipped}
