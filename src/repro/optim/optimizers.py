"""Optimizers, implemented from scratch (no optax in this environment).

The paper trains AlexNet with SGD + momentum and averages BOTH parameters
and momentum across replicas (footnote 3) — so optimizer state here is a
first-class pytree that the data-parallel core can exchange+average exactly
like the paper does.

An optimizer is a pair of pure functions bundled in ``Optimizer``:
    init(params)                        -> state
    update(grads, state, params, lr)    -> (updates, state)
``apply_updates`` adds updates to params.  SGD+momentum is *linear* in the
gradient/state, which is what makes the paper's parameter averaging exactly
equivalent to gradient averaging (proved in tests/core/test_param_avg.py);
AdamW is provided as the non-linear counterexample and the modern default.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

OptState = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], OptState]
    update: Callable[..., tuple]
    name: str = "optimizer"


def _tree_zeros_like(params, dtype=None):
    return jax.tree.map(
        lambda p: jnp.zeros_like(p, dtype=dtype or p.dtype), params)


def sgd_momentum(momentum: float = 0.9, weight_decay: float = 5e-4,
                 nesterov: bool = False,
                 state_dtype=jnp.float32) -> Optimizer:
    """The paper's optimizer (AlexNet defaults: m=0.9, wd=5e-4).

    ``state_dtype=bf16`` halves the momentum buffer (beyond-paper memory
    variant; the paper stored fp32 momentum per GPU) — update math stays
    fp32, only storage is cast."""

    def init(params):
        return {"velocity": _tree_zeros_like(params, state_dtype)}

    def update(grads, state, params, lr):
        g_eff = jax.tree.map(
            lambda g, p: g.astype(jnp.float32)
            + weight_decay * p.astype(jnp.float32), grads, params)
        vel = jax.tree.map(lambda v, g: momentum * v.astype(jnp.float32) + g,
                           state["velocity"], g_eff)
        if nesterov:
            step_dir = jax.tree.map(lambda v, g: momentum * v + g, vel, g_eff)
        else:
            step_dir = vel
        updates = jax.tree.map(lambda s: -lr * s, step_dir)
        vel = jax.tree.map(lambda v: v.astype(state_dtype), vel)
        return updates, {"velocity": vel}

    return Optimizer(init, update, "sgd_momentum")


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        return {"mu": _tree_zeros_like(params, jnp.float32),
                "nu": _tree_zeros_like(params, jnp.float32),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        count = state["count"] + 1
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)
        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state["mu"], grads)
        nu = jax.tree.map(
            lambda n, g: b2 * n + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["nu"], grads)
        updates = jax.tree.map(
            lambda m, n, p: -lr * ((m / c1) / (jnp.sqrt(n / c2) + eps)
                                   + weight_decay * p.astype(jnp.float32)),
            mu, nu, params)
        return updates, {"mu": mu, "nu": nu, "count": count}

    return Optimizer(init, update, "adamw")


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
                        params, updates)


def with_master_weights(inner: Optimizer) -> Optimizer:
    """Mixed-precision wrapper: an fp32 master copy of the params lives in
    optimizer state; the inner optimizer's update math runs against the
    masters, and the live (possibly bf16) params become a cast of the new
    master each step.

    The returned updates are ``new_master - params_f32`` so the existing
    ``apply_updates`` contract — ``(p_f32 + u).astype(p.dtype)`` — lands
    the params on ``cast(new_master)`` without a new apply path.  Because
    the masters ride in optimizer state, the paper's exchange (params AND
    optimizer state averaged, footnote 3) and PR 7's compressed
    error-feedback path operate on exact fp32 masters for free.
    """

    def init(params):
        # jnp.array (not astype): astype is a no-op ALIAS for fp32 params,
        # and a master sharing its param's buffer makes a donated
        # TrainState donate the same buffer twice
        return {"master": jax.tree.map(
                    lambda p: jnp.array(p, jnp.float32), params),
                "inner": inner.init(params)}

    def update(grads, state, params, lr):
        master = state["master"]
        updates, inner_state = inner.update(grads, state["inner"], master,
                                            lr)
        new_master = jax.tree.map(lambda m, u: m + u, master, updates)
        out = jax.tree.map(lambda nm, p: nm - p.astype(jnp.float32),
                           new_master, params)
        return out, {"master": new_master, "inner": inner_state}

    return Optimizer(init, update, inner.name + "+master")


def for_numerics(optimizer: Optimizer, numerics) -> Optimizer:
    """Wrap per the NumericsPolicy (identity when masters are off)."""
    if numerics is None or not getattr(numerics, "master_weights", False):
        return optimizer
    return with_master_weights(optimizer)


def get_optimizer(name: str, **kw) -> Optimizer:
    if name == "sgd_momentum":
        return sgd_momentum(**kw)
    if name == "adamw":
        return adamw(**kw)
    raise ValueError(f"unknown optimizer {name!r}")
