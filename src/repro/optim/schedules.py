"""Learning-rate schedules.

``step_decay`` is the paper's AlexNet schedule (divide by 10 when validation
error plateaus — realized as fixed-epoch steps as in the Caffe reference).
``wsd`` is MiniCPM's warmup-stable-decay (arXiv:2404.06395 §4), included
because minicpm-2b is an assigned architecture.
"""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def step_decay(lr: float, decay_every: int, factor: float = 0.1):
    def f(step):
        k = jnp.floor_divide(step, decay_every).astype(jnp.float32)
        return lr * factor ** k
    return f


def cosine(lr: float, warmup: int, total: int, min_ratio: float = 0.1):
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_ratio * lr + (1 - min_ratio) * lr * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return f


def wsd(lr: float, warmup: int, stable: int, decay: int,
        min_ratio: float = 0.01):
    """Warmup-Stable-Decay: linear warmup, flat plateau, exponential-ish
    (here: linear in log space) decay over the final ``decay`` steps."""
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup - stable) / max(decay, 1), 0.0, 1.0)
        dec = lr * jnp.exp(jnp.log(min_ratio) * prog)
        out = jnp.where(step < warmup, warm,
                        jnp.where(step < warmup + stable, lr, dec))
        return out
    return f


def get_schedule(name: str, **kw):
    return {"constant": constant, "step_decay": step_decay, "cosine": cosine,
            "wsd": wsd}[name](**kw)
