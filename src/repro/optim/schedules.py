"""Learning-rate schedules.

``step_decay`` is the paper's AlexNet schedule (divide by 10 when validation
error plateaus — realized as fixed-epoch steps as in the Caffe reference).
``plateau_decay`` realizes the rule as written: a HOST-side controller fed
by the validation loop that divides the LR by ``factor`` when the metric
stops improving (repro.train_loop re-jits the step with the new constant —
a handful of recompiles per run, exactly the paper's restart-with-lower-LR
workflow).  ``wsd`` is MiniCPM's warmup-stable-decay (arXiv:2404.06395 §4),
included because minicpm-2b is an assigned architecture.

Compiled schedules are plain callables ``step -> lr``.  The session layer
works with *controllers* (``as_controller``), which add the host-side
protocol: ``schedule()`` returns the compiled callable for the current
segment, ``update(metric)`` reports whether the LR just changed (step must
be re-jitted), and ``state_dict``/``load_state_dict`` round-trip through
the checkpoint manifest so a resumed session makes the same decisions.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def step_decay(lr: float, decay_every: int, factor: float = 0.1):
    def f(step):
        k = jnp.floor_divide(step, decay_every).astype(jnp.float32)
        return lr * factor ** k
    return f


def cosine(lr: float, warmup: int, total: int, min_ratio: float = 0.1):
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_ratio * lr + (1 - min_ratio) * lr * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return f


def wsd(lr: float, warmup: int, stable: int, decay: int,
        min_ratio: float = 0.01):
    """Warmup-Stable-Decay: linear warmup, flat plateau, exponential-ish
    (here: linear in log space) decay over the final ``decay`` steps."""
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup - stable) / max(decay, 1), 0.0, 1.0)
        dec = lr * jnp.exp(jnp.log(min_ratio) * prog)
        out = jnp.where(step < warmup, warm,
                        jnp.where(step < warmup + stable, lr, dec))
        return out
    return f


def get_schedule(name: str, **kw):
    return {"constant": constant, "step_decay": step_decay, "cosine": cosine,
            "wsd": wsd}[name](**kw)


# ---------------------------------------------------------------------------
# Host-side controllers (validation-driven schedules)
# ---------------------------------------------------------------------------


class StaticController:
    """Wraps a compiled ``step -> lr`` schedule in the controller protocol:
    ``update`` never requests a re-jit and there is no state to persist."""

    def __init__(self, fn):
        self._fn = fn

    def schedule(self):
        return self._fn

    def update(self, metric: float) -> bool:
        return False

    def state_dict(self) -> dict:
        return {}

    def load_state_dict(self, d: dict) -> None:
        pass


@dataclasses.dataclass
class PlateauController:
    """The paper's AlexNet rule, literally: divide the LR by ``1/factor``
    when the validation metric plateaus (no relative improvement of at
    least ``threshold`` for ``patience`` consecutive ``update`` calls).

    Stateful and host-side by design — the decision depends on observed
    validation metrics, which XLA cannot see.  The session feeds every
    eval result through ``update``; a ``True`` return means the LR just
    dropped and the train step must be rebuilt with ``schedule()`` (the
    new LR is a compile-time constant, so each segment runs at full
    compiled speed).  ``state_dict`` captures every decision input, so a
    resumed session replays identically.
    """

    lr: float
    factor: float = 0.1
    patience: int = 2
    threshold: float = 1e-3
    min_lr: float = 0.0
    mode: str = "min"                 # "min": lower metric is better
    # --- mutable decision state (persisted in the checkpoint manifest) ---
    best: float = None
    num_bad: int = 0
    n_drops: int = 0

    def __post_init__(self):
        if self.mode not in ("min", "max"):
            raise ValueError(f"mode must be min|max, got {self.mode!r}")
        if not 0 < self.factor < 1:
            raise ValueError(f"factor must be in (0,1), got {self.factor}")

    def schedule(self):
        cur = self.lr
        return lambda step: jnp.asarray(cur, jnp.float32)

    def _improved(self, metric: float) -> bool:
        if self.best is None:
            return True
        # relative margin on |best| (a plain best*(1-t) would invert the
        # comparison for negative metrics, e.g. log-likelihoods)
        margin = self.threshold * abs(self.best)
        if self.mode == "min":
            return metric < self.best - margin
        return metric > self.best + margin

    def update(self, metric: float) -> bool:
        """Feed one validation metric; True iff the LR just dropped."""
        metric = float(metric)
        if self._improved(metric):
            self.best = metric
            self.num_bad = 0
            return False
        self.num_bad += 1
        if self.num_bad < self.patience or self.lr <= self.min_lr:
            return False
        self.lr = max(self.lr * self.factor, self.min_lr)
        self.num_bad = 0
        self.n_drops += 1
        return True

    def state_dict(self) -> dict:
        return {"lr": self.lr, "best": self.best, "num_bad": self.num_bad,
                "n_drops": self.n_drops}

    def load_state_dict(self, d: dict) -> None:
        self.lr = d["lr"]
        self.best = d["best"]
        self.num_bad = d["num_bad"]
        self.n_drops = d["n_drops"]


def plateau_decay(lr: float, factor: float = 0.1, patience: int = 2,
                  threshold: float = 1e-3, min_lr: float = 0.0,
                  mode: str = "min") -> PlateauController:
    """Controller realizing "divide by 10 when validation error plateaus"."""
    return PlateauController(lr, factor, patience, threshold, min_lr, mode)


def as_controller(sched) -> "StaticController | PlateauController":
    """Normalize a compiled schedule / controller to the controller API."""
    if hasattr(sched, "schedule") and hasattr(sched, "update"):
        return sched
    if callable(sched):
        return StaticController(sched)
    raise TypeError(f"not a schedule or controller: {sched!r}")
