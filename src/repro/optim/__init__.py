from repro.optim.optimizers import (adamw, apply_updates, sgd_momentum,
                                    OptState, Optimizer)
from repro.optim import schedules  # noqa: F401
