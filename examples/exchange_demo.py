"""Toy multi-replica exchange demo — the analogue of the paper's companion
repo ``theano_multi_gpu`` (a minimal 2-GPU weight-exchange example).

Shows the three exchange schedules producing the same average, the Fig. 2
three-step structure for 2 replicas, and local-SGD drift/resync.

    PYTHONPATH=src python examples/exchange_demo.py
"""
import jax
import jax.numpy as jnp

from repro.core import exchange_average, replica_spread

R = 4
rng = jax.random.PRNGKey(0)
# pretend each replica just finished an independent SGD update (step 1)
weights = {"w1": jax.random.normal(rng, (R, 8, 8)),
           "momentum": jax.random.normal(jax.random.fold_in(rng, 1),
                                         (R, 8, 8))}
print(f"{R} replicas, pre-exchange spread: "
      f"{float(replica_spread(weights)):.3f}")

for strategy in ("all_reduce", "ring", "pairwise"):
    # steps 2+3 of Fig. 2: exchange, then average (params AND momentum)
    avg = exchange_average(weights, strategy)
    spread = float(replica_spread(avg))
    err = float(jnp.max(jnp.abs(avg["w1"][0] - jnp.mean(weights["w1"], 0))))
    print(f"  {strategy:10s}: post spread {spread:.2e}, "
          f"error vs true mean {err:.2e}")

# the 2-GPU case is EXACTLY the paper's figure: one pairwise exchange
two = {"w": jnp.stack([jnp.zeros((4,)), jnp.ones((4,))])}
print("\n2 replicas (the paper's setup):")
print("  before:", two["w"][:, 0].tolist())
after = exchange_average(two, "pairwise")
print("  after exchange+average:", after["w"][:, 0].tolist(),
      "(both replicas now hold the mean)")

# local SGD: skip syncs, drift grows, one sync resets it
drift = weights
print("\nlocal-SGD drift:")
for k in range(3):
    drift = jax.tree.map(
        lambda x: x + 0.1 * jax.random.normal(jax.random.fold_in(rng, k),
                                              x.shape), drift)
    print(f"  after local step {k + 1}: spread "
          f"{float(replica_spread(drift)):.3f}")
drift = exchange_average(drift, "all_reduce")
print(f"  after sync: spread {float(replica_spread(drift)):.2e}")
print("exchange_demo OK")
