"""Serving example: the DecodeState contract + the continuous-batching
engine (docs/serving.md).

Trains a small SWA LM on Markov data briefly (so generation is
non-trivial), then serves it two ways:

1. the raw contract — ``models.prefill`` fills the ring cache (capacity
   = sliding window) and ``models.decode_step`` extends it; positions
   live in ``DecodeState.pos`` as DEVICE scalars, so the jitted step is
   compiled exactly once (the old example passed a python int ``pos``,
   re-staging the scalar host->device on every token);
2. ``repro.serving.ServingEngine`` — a stream of variable-length
   requests through fixed decode slots with bucketed prefill.

    PYTHONPATH=src python examples/serve.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs import ARCHS, reduced
from repro.core import (init_param_avg_state, make_param_avg_step,
                        reshape_for_replicas, unreplicate)
from repro.data import synthetic
from repro.optim import schedules
from repro.optim.optimizers import adamw
from repro.serving import Request, ServingEngine

VOCAB, PROMPT, GEN, BATCH = 64, 24, 16, 4

cfg = reduced(ARCHS["gemma-7b-swa"], vocab=VOCAB)
cfg = dataclasses.replace(cfg, sliding_window=16)   # exercise the ring

# --- quick training so the model has something to say -----------------
opt = adamw(weight_decay=0.0)
state = init_param_avg_state(jax.random.PRNGKey(0),
                             lambda r: models.init(r, cfg), opt, 1)
step = jax.jit(make_param_avg_step(lambda p, b: models.loss_fn(p, cfg, b),
                                   opt, schedules.constant(3e-3)))
src = synthetic.markov_lm(VOCAB, 8, 64, seed=1)
for i in range(30):
    batch = {k: jnp.asarray(v) for k, v in next(src).items()}
    state, loss = step(state, reshape_for_replicas(batch, 1))
params = unreplicate(state.params)
print(f"trained 30 steps, loss {float(loss):.3f}")

# --- 1. the raw DecodeState contract ----------------------------------
prompts = jnp.asarray(next(src)["tokens"][:BATCH, :PROMPT])
total = PROMPT + GEN

t0 = time.time()
logits, dstate = models.prefill(params, cfg, prompts, total)
cap = dstate.cache["blocks"][0]["k"].shape[2]
print(f"prefill {PROMPT} tokens x{BATCH}: {time.time() - t0:.3f}s "
      f"(cache capacity {cap} = window; pos={dstate.pos.tolist()})")

decode = jax.jit(lambda p, s, t: models.decode_step(p, cfg, s, t))
cur = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
generated = [cur]
t0 = time.time()
for _ in range(GEN - 1):
    # positions ride in dstate.pos on device — one compile for the loop
    lg, dstate = decode(params, dstate, cur)
    cur = jnp.argmax(lg, -1).astype(jnp.int32)
    generated.append(cur)
gen = jnp.concatenate(generated, axis=1)
dt = time.time() - t0
print(f"decoded {gen.shape[1]} tokens x{BATCH} in {dt:.3f}s "
      f"({BATCH * gen.shape[1] / dt:.0f} tok/s)")
for b in range(BATCH):
    print(f"  prompt {prompts[b, -6:].tolist()} -> {gen[b].tolist()}")
assert gen.shape == (BATCH, GEN)

# --- 2. the continuous-batching engine --------------------------------
rng = np.random.default_rng(0)
stream = synthetic.markov_lm(VOCAB, 8, 64, seed=2)
reqs = [Request(prompt=np.asarray(next(stream)["tokens"][0, :int(ln)]),
                max_new_tokens=int(new))
        for ln, new in zip(rng.integers(4, 24, size=8),
                           rng.integers(4, GEN + 8, size=8))]
engine = ServingEngine(params, cfg, slots=BATCH, capacity=64,
                       buckets=(8, 16, 24))
t0 = time.time()
results = engine.run(reqs)
dt = time.time() - t0
toks = sum(len(r.tokens) for r in results)
print(f"engine: {len(results)} requests / {toks} tokens in {dt:.3f}s "
      f"({toks / dt:.0f} tok/s; {engine.decode_steps} ticks, "
      f"{engine.prefill_compiles} prefill compiles)")
for r in sorted(results, key=lambda r: r.rid):
    print(f"  req {r.rid} (len {r.prompt_len:2d}) -> {r.tokens}")
print("serve OK")
