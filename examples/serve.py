"""Serving example: batched prefill + greedy decode with a KV cache.

Trains a small LM on Markov data briefly (so generation is non-trivial),
then serves a batch of prompts: prefill fills the ring cache, decode_step
extends one token at a time.  Also demonstrates the SWA ring buffer by
serving a sliding-window variant.

    PYTHONPATH=src python examples/serve.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import models
from repro.configs import ARCHS, reduced
from repro.core import (init_param_avg_state, make_param_avg_step,
                        reshape_for_replicas, unreplicate)
from repro.data import synthetic
from repro.models import transformer
from repro.optim import schedules
from repro.optim.optimizers import adamw

VOCAB, PROMPT, GEN, BATCH = 64, 24, 16, 4

cfg = reduced(ARCHS["gemma-7b-swa"], vocab=VOCAB)
cfg = dataclasses.replace(cfg, sliding_window=16)   # exercise the ring

# --- quick training so the model has something to say -----------------
opt = adamw(weight_decay=0.0)
state = init_param_avg_state(jax.random.PRNGKey(0),
                             lambda r: models.init(r, cfg), opt, 1)
step = jax.jit(make_param_avg_step(lambda p, b: models.loss_fn(p, cfg, b),
                                   opt, schedules.constant(3e-3)))
src = synthetic.markov_lm(VOCAB, 8, 64, seed=1)
for i in range(30):
    batch = {k: jnp.asarray(v) for k, v in next(src).items()}
    state, loss = step(state, reshape_for_replicas(batch, 1))
params = unreplicate(state.params)
print(f"trained 30 steps, loss {float(loss):.3f}")

# --- serve -------------------------------------------------------------
prompts = jnp.asarray(next(src)["tokens"][:BATCH, :PROMPT])
total = PROMPT + GEN

t0 = time.time()
logits, _, cache = transformer.forward(
    params, cfg, prompts, return_cache=True,
    cache=transformer.init_decode_cache(cfg, BATCH, total))
print(f"prefill {PROMPT} tokens x{BATCH}: {time.time() - t0:.3f}s "
      f"(cache capacity {cache['blocks'][0]['k'].shape[2]} = window)")

decode = jax.jit(
    lambda p, c, t, pos: transformer.decode_step(p, cfg, c, t, pos))
cur = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
generated = [cur]
t0 = time.time()
for t in range(PROMPT, total - 1):
    lg, cache = decode(params, cache, cur, t)
    cur = jnp.argmax(lg, -1).astype(jnp.int32)
    generated.append(cur)
gen = jnp.concatenate(generated, axis=1)
dt = time.time() - t0
print(f"decoded {gen.shape[1]} tokens x{BATCH} in {dt:.3f}s "
      f"({BATCH * gen.shape[1] / dt:.0f} tok/s)")
for b in range(BATCH):
    print(f"  prompt {prompts[b, -6:].tolist()} -> {gen[b].tolist()}")

# sanity: greedy continuation of train-distribution prompts should often
# follow the Markov chain's argmax transition
assert gen.shape == (BATCH, GEN - 0)
print("serve OK")
