"""Quickstart: the paper in one file.

Trains AlexNet on synthetic images with BOTH of the paper's mechanisms:
  1. data parallelism by parameter averaging (2 replicas, Fig. 2), and
  2. double-buffered parallel data loading (Fig. 1),
then verifies the trained replicas are identical and the model learned.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import ALEXNET_SMOKE
from repro.core import (init_param_avg_state, make_param_avg_step,
                        replica_spread, reshape_for_replicas, unreplicate)
from repro.data import PrefetchLoader, synthetic
from repro.data.preprocess import make_image_preprocess
from repro.models import alexnet
from repro.optim import schedules
from repro.optim.optimizers import sgd_momentum

REPLICAS = 2
STEPS = 80
BATCH = 32

cfg = ALEXNET_SMOKE
opt = sgd_momentum(momentum=0.9, weight_decay=1e-4)      # the paper's optimizer
sched = schedules.step_decay(0.02, decay_every=60)       # AlexNet-style decay

state = init_param_avg_state(jax.random.PRNGKey(0),
                             lambda r: alexnet.init(r, cfg), opt, REPLICAS)
step = jax.jit(make_param_avg_step(
    lambda p, b: alexnet.loss_fn(p, cfg, b["images"], b["labels"]),
    opt, sched, strategy="pairwise"),    # 2 replicas => exactly Fig. 2
    donate_argnums=0)                    # state updates in place

# loader process analogue: prefetch + preprocess (mean-subtract, crop, flip)
mean = synthetic.mean_image(
    synthetic.blob_images(10, BATCH, cfg.image_size + 8, seed=1), 4)
loader = PrefetchLoader(
    synthetic.blob_images(10, BATCH, cfg.image_size + 8, seed=0),
    prefetch=2,
    preprocess=make_image_preprocess(mean, cfg.image_size, seed=0),
    device_put=lambda b: jax.device_put(
        reshape_for_replicas({k: jnp.asarray(v) for k, v in b.items()},
                             REPLICAS)))

t0 = time.time()
try:
    # try/finally: a mid-loop exception must not leak the loader thread
    for i, batch in zip(range(STEPS), loader):
        state, loss = step(state, batch)
        if (i + 1) % 20 == 0:
            print(f"step {i + 1:3d}  loss {float(loss):.4f}  "
                  f"({(time.time() - t0) / (i + 1):.3f} s/step)")
finally:
    loader.close()

spread = float(replica_spread(state.params))
print(f"\nreplica spread after training: {spread:.2e}  "
      f"(exchange+average keeps replicas identical)")
params = unreplicate(state.params)
# evaluate with the SAME preprocessing the loader applied during training
batch = make_image_preprocess(mean, cfg.image_size, seed=1)(
    next(synthetic.blob_images(10, 64, cfg.image_size + 8, seed=9)))
logits = alexnet.forward(params, cfg, jnp.asarray(batch["images"]))
acc = float(jnp.mean(jnp.argmax(logits, -1) == batch["labels"]))
print(f"held-out accuracy: {acc:.2%}")
assert spread < 1e-5 and acc > 0.5
print("quickstart OK")
