"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with the paper's parameter-averaging data parallelism over multiple host
devices.

Defaults are CPU-friendly (~20M params, 200 steps); pass --full for the
~100M configuration.  This file sets XLA_FLAGS itself and must be run as a
script, not imported after jax.

    PYTHONPATH=src python examples/train_dataparallel.py [--full] \
        [--devices 4] [--steps 200]
"""
import argparse
import os

ap = argparse.ArgumentParser()
ap.add_argument("--devices", type=int, default=4)
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--full", action="store_true",
                help="~100M params (slow on CPU)")
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq-len", type=int, default=128)
ap.add_argument("--sync-every", type=int, default=1)
args = ap.parse_args()

os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={args.devices}")

# ruff: noqa: E402
import dataclasses
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import models
from repro.configs import ARCHS, reduced
from repro.core import (init_param_avg_state, make_mesh_param_avg_step,
                        replica_spread, reshape_for_replicas)
from repro.data import PrefetchLoader, synthetic
from repro.launch.mesh import make_replica_mesh
from repro.optim import schedules
from repro.optim.optimizers import adamw
from repro.sharding.specs import replica_sharding

# vocab sized so ~150 steps of data gives >100 observations per Markov
# state — otherwise the chain is unlearnable within the demo budget
cfg = reduced(ARCHS["olmo-1b"], n_layers=4, d_model=512, vocab=2048)
if args.full:
    cfg = dataclasses.replace(reduced(ARCHS["olmo-1b"], n_layers=8,
                                      d_model=768, vocab=50304),
                              d_ff=3072, n_heads=12, n_kv_heads=12)
n_params = cfg.n_params()
print(f"model: {cfg.name} {n_params / 1e6:.1f}M params, "
      f"{args.devices} devices")

R = args.devices
# mesh-native engine: one replica per device on a ('data',) mesh; the
# exchange inside the shard_map step lowers to a real all-reduce
# (docs/architecture.md)
mesh = make_replica_mesh(R)
opt = adamw(weight_decay=0.01)
sched = schedules.cosine(3e-3, warmup=args.steps // 10, total=args.steps)
state = init_param_avg_state(jax.random.PRNGKey(0),
                             lambda r: models.init(r, cfg), opt, R)
sshard = replica_sharding(jax.eval_shape(lambda: state), mesh,
                          replica_axes=("data",))
state = jax.device_put(state, sshard)
step = jax.jit(make_mesh_param_avg_step(
    lambda p, b: models.loss_fn(p, cfg, b), opt, sched, mesh=mesh,
    replica_axes=("data",), sync_every=args.sync_every),
    in_shardings=(sshard, None),
    out_shardings=(sshard, NamedSharding(mesh, P())),
    donate_argnums=0)                    # state updates in place

loader = PrefetchLoader(
    synthetic.markov_lm(cfg.vocab_size, args.batch * R, args.seq_len,
                        seed=0,
                        # transition sharpness scales ~1/sqrt(V); compensate
                        # so the chain stays learnable at LM-sized vocabs
                        sharpness=3.0 * cfg.vocab_size ** 0.5),
    prefetch=2,
    device_put=lambda b: jax.device_put(
        rb := reshape_for_replicas(
            {k: jnp.asarray(v) for k, v in b.items()}, R),
        replica_sharding(rb, mesh, replica_axes=("data",))))

t0 = time.time()
first = None
try:
    # try/finally: a mid-loop exception must not leak the loader thread
    for i, batch in zip(range(args.steps), loader):
        state, loss = step(state, batch)
        if i == 0:
            first = float(loss)
        if (i + 1) % max(args.steps // 10, 1) == 0:
            print(f"step {i + 1:4d}  loss {float(loss):.4f}  "
                  f"{(time.time() - t0) / (i + 1):.2f}s/step", flush=True)
finally:
    loader.close()
final = float(loss)
print(f"\nloss {first:.3f} -> {final:.3f}; "
      f"replica spread {float(replica_spread(state.params)):.2e}")
assert final < first, "training did not reduce loss"
print("train_dataparallel OK")
