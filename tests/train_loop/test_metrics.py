"""Metrics JSONL: percentiles, torn-tail tolerance on resume, truncation."""
import json

import pytest

from repro.train_loop import MetricsWriter, percentile, read_jsonl


def test_percentile_nearest_rank():
    vals = sorted([5.0, 1.0, 3.0, 2.0, 4.0])
    assert percentile(vals, 50) == 3.0
    assert percentile(vals, 0) == 1.0
    assert percentile(vals, 100) == 5.0
    # even n: nearest-rank p50 of [1,2,3,4] is the 2nd value, not the 3rd
    assert percentile([1.0, 2.0, 3.0, 4.0], 50) == 2.0
    assert percentile([1.0, 2.0], 50) == 1.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 90) == 4.0
    assert percentile([], 50) != percentile([], 50)      # nan


def test_resume_truncates_tail_and_tolerates_torn_line(tmp_path):
    """A SIGKILL mid-write leaves a torn final line; resuming must drop it
    (it's part of the un-checkpointed tail) instead of crashing."""
    path = tmp_path / "m.jsonl"
    w = MetricsWriter(str(path), images_per_step=8)
    for s in (1, 2, 3, 4):
        w.train(s, loss=float(s), lr=0.1, step_time_s=0.01)
    w.close()
    with open(path, "a") as f:
        f.write('{"kind": "train", "step": 5, "lo')      # torn write
    # resume from the step-3 checkpoint
    w2 = MetricsWriter(str(path), images_per_step=8, resume_step=3)
    w2.train(4, loss=40.0, lr=0.1, step_time_s=0.01)
    w2.close()
    recs = read_jsonl(str(path), "train")
    assert [r["step"] for r in recs] == [1, 2, 3, 4]
    assert recs[-1]["loss"] == 40.0                      # replayed, not stale


def test_read_jsonl_strict_raises_on_garbage(tmp_path):
    p = tmp_path / "bad.jsonl"
    p.write_text('{"kind": "train", "step": 1}\n{oops\n')
    with pytest.raises(ValueError):
        read_jsonl(str(p))
    assert [r["step"] for r in read_jsonl(str(p), tolerant=True)] == [1]


def test_stage_wait_ms_logged_and_summarized(tmp_path):
    """Per-step loader stall lands in the train records and the summary
    aggregates it (mean + p90) over TIMED steps only."""
    p = tmp_path / "m.jsonl"
    w = MetricsWriter(str(p), images_per_step=4)
    w.train(1, 1.0, 0.1, 5.0, timed=False, stage_wait_ms=900.0)  # compile
    w.train(2, 1.0, 0.1, 0.01, stage_wait_ms=2.0)
    w.train(3, 1.0, 0.1, 0.01, stage_wait_ms=4.0)
    w.train(4, 1.0, 0.1, 0.01)                 # loader without the metric
    s = w.summary(4)
    w.close()
    recs = read_jsonl(str(p), "train")
    assert recs[0]["stage_wait_ms"] == 900.0   # logged even on compile...
    assert [r.get("stage_wait_ms") for r in recs[1:]] == [2.0, 4.0, None]
    assert s["stage_wait_ms_mean"] == 3.0      # ...but excluded here
    assert s["stage_wait_ms_p90"] == 4.0


def test_summary_omits_stage_wait_when_never_reported(tmp_path):
    p = tmp_path / "m.jsonl"
    w = MetricsWriter(str(p), images_per_step=4)
    w.train(1, 1.0, 0.1, 0.01)
    s = w.summary(1)
    w.close()
    assert "stage_wait_ms_mean" not in s


def test_summary_excludes_compile_steps(tmp_path):
    p = tmp_path / "m.jsonl"
    w = MetricsWriter(str(p), images_per_step=4)
    w.train(1, 1.0, 0.1, 5.0, timed=False)               # compile step
    w.train(2, 1.0, 0.1, 0.01)
    w.train(3, 1.0, 0.1, 0.03)
    s = w.summary(3)
    w.close()
    assert s["timed_steps"] == 2
    assert s["step_ms_p99"] <= 30.001                    # 5s compile excluded
    recs = read_jsonl(str(p), "train")
    assert recs[0].get("compile") is True
    assert "images_per_sec" not in recs[0]