"""Resumable sessions: an interrupted-and-resumed run must reproduce the
uninterrupted loss trace BIT-exactly, on both exchange engines, across
host-device counts (the ISSUE-3 acceptance matrix; CI job resume-smoke).

Subprocess-driven through the real CLI so the whole stack is exercised:
arg wiring, engine setup, sharding-aware restore, stream fast-forward,
metrics JSONL.  Device counts are forced per-subprocess so nothing leaks
into the main test process (the dry-run isolation rule)."""
import json

import pytest
from _subproc import run_isolated

# tiny but real: smoke AlexNet at 48px keeps per-config compile ~seconds
BASE = ["--arch", "alexnet", "--smoke", "--image-size", "48", "--batch", "8",
        "--log-every", "100"]


def run_cli(args, devices: int, timeout=560):
    return run_isolated(["-m", "repro.launch.train"] + args, devices,
                        timeout).stdout


def train_trace(path):
    """step -> (loss, lr) from a metrics JSONL, full float precision."""
    out = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if r.get("kind") == "train":
                out[r["step"]] = (r["loss"], r["lr"])
    return out


@pytest.mark.parametrize("engine,devices", [
    ("reference", 1), ("reference", 2), ("reference", 4),
    ("mesh", 1), ("mesh", 2), ("mesh", 4),
])
def test_resume_reproduces_uninterrupted_trace(tmp_path, engine, devices):
    common = BASE + ["--engine", engine, "--ckpt-every", "3"]
    # uninterrupted: 6 steps straight through
    m_full = str(tmp_path / "full.jsonl")
    run_cli(common + ["--steps", "6", "--ckpt-dir", str(tmp_path / "ck_a"),
                      "--metrics-out", m_full], devices)
    # killed at step 4 (one step PAST the step-3 checkpoint: the resumed
    # run must discard that un-checkpointed tail and replay it)...
    m_part = str(tmp_path / "part.jsonl")
    run_cli(common + ["--steps", "4", "--ckpt-dir", str(tmp_path / "ck_b"),
                      "--metrics-out", m_part], devices)
    # ...then resumed from step 3 up to 6, appending to the same trace
    out = run_cli(common + ["--steps", "6",
                            "--ckpt-dir", str(tmp_path / "ck_b"),
                            "--metrics-out", m_part, "--resume"], devices)
    assert "steps 3 -> 6" in out
    full, part = train_trace(m_full), train_trace(m_part)
    assert set(full) == set(part) == {1, 2, 3, 4, 5, 6}
    for step in sorted(full):
        assert full[step] == part[step], (
            f"step {step}: uninterrupted {full[step]} != resumed "
            f"{part[step]} ({engine}, {devices} devices)")


def test_resume_with_eval_and_plateau_state(tmp_path):
    """The plateau controller's decision state rides in the manifest: a
    resume mid-patience must drop the LR at the same step the
    uninterrupted run does, and eval metrics must match bit-exactly."""
    common = BASE + ["--engine", "mesh", "--ckpt-every", "3",
                     "--schedule", "plateau", "--eval-every", "2",
                     "--plateau-patience", "1",
                     "--plateau-threshold", "0.5"]
    m_full = str(tmp_path / "full.jsonl")
    run_cli(common + ["--steps", "6", "--ckpt-dir", str(tmp_path / "a"),
                      "--metrics-out", m_full], devices=2)
    m_part = str(tmp_path / "part.jsonl")
    run_cli(common + ["--steps", "4", "--ckpt-dir", str(tmp_path / "b"),
                      "--metrics-out", m_part], devices=2)
    run_cli(common + ["--steps", "6", "--ckpt-dir", str(tmp_path / "b"),
                      "--metrics-out", m_part, "--resume"], devices=2)

    def recs(path, kind):
        with open(path) as f:
            return [r for line in f if (r := json.loads(line)).get("kind")
                    == kind]

    full_t, part_t = train_trace(m_full), train_trace(m_part)
    assert full_t == part_t
    fe = [(r["step"], r["loss"], r["top1_err"], r["lr_dropped"])
          for r in recs(m_full, "eval")]
    pe = [(r["step"], r["loss"], r["top1_err"], r["lr_dropped"])
          for r in recs(m_part, "eval")]
    assert fe == pe
    # the rigged threshold guarantees at least one LR drop in 3 evals
    assert any(r[3] for r in fe)
    lrs = sorted({lr for _, lr in full_t.values()}, reverse=True)
    assert len(lrs) >= 2 and abs(lrs[1] / lrs[0] - 0.1) < 1e-6


def test_resume_nothing_to_do(tmp_path):
    args = BASE + ["--steps", "3", "--ckpt-dir", str(tmp_path / "ck"),
                   "--ckpt-every", "3"]
    run_cli(args, devices=1)
    out = run_cli(args + ["--resume"], devices=1)
    assert "nothing to do" in out


def test_invalid_image_size_errors():
    r = run_isolated(
        ["-m", "repro.launch.train", "--arch", "alexnet", "--steps", "1",
         "--image-size", "48"],         # full net: pool window underflows
        devices=1, timeout=120, check=False)
    assert r.returncode != 0
    assert "invalid" in r.stderr and "48" in r.stderr


def test_plateau_without_eval_errors():
    r = run_isolated(
        ["-m", "repro.launch.train", "--arch", "alexnet", "--smoke",
         "--steps", "1", "--schedule", "plateau"],
        devices=1, timeout=120, check=False)
    assert r.returncode != 0 and "--eval-every" in r.stderr


def test_resume_warns_on_numerics_switch(tmp_path):
    """The NumericsPolicy describe() rides in run_meta: resuming under a
    different policy must print the loud drift warning (the continued
    trace will not be bit-exact), while a same-policy resume stays
    quiet."""
    common = BASE + ["--ckpt-every", "2", "--ckpt-dir", str(tmp_path / "ck")]
    run_cli(common + ["--steps", "2"], devices=1)
    quiet = run_cli(common + ["--steps", "4", "--resume"], devices=1)
    assert "WARNING: resuming under a different configuration" not in quiet
    out = run_cli(common + ["--steps", "6", "--resume",
                            "--kv-cache-dtype", "int8"], devices=1)
    assert "WARNING: resuming under a different configuration" in out
    assert "numerics" in out and "kv=int8" in out


def test_bf16_session_logs_loss_scale(tmp_path):
    """--numerics bf16 end to end through the session: metrics JSONL
    train records carry the live loss scale and skipped-step count, and
    the summary repeats the final values."""
    m = str(tmp_path / "m.jsonl")
    out = run_cli(BASE + ["--steps", "3", "--numerics", "bf16",
                          "--metrics-out", m], devices=1)
    assert "numerics=param=bfloat16,master_fp32,loss_scale=dynamic" in out
    recs = [json.loads(line) for line in open(m)]
    trains = [r for r in recs if r.get("kind") == "train"]
    assert trains and all(r["loss_scale"] == 2.0 ** 15 for r in trains)
    assert all(r["skipped_steps"] == 0 for r in trains)
    summ = [r for r in recs if r.get("kind") == "summary"]
    assert summ and summ[-1]["loss_scale"] == 2.0 ** 15
    assert summ[-1]["skipped_steps"] == 0
