"""Plateau-LR controller: unit decisions + an in-process session driving a
real LR drop through the validation loop (ISSUE-3 acceptance)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (init_param_avg_state, make_eval_step,
                        make_param_avg_step)
from repro.optim import schedules
from repro.optim.optimizers import sgd_momentum
from repro.train_loop import TrainSession


# ---------------------------------------------------------------- unit ----
def test_plateau_drops_after_patience():
    c = schedules.plateau_decay(1.0, factor=0.1, patience=2, threshold=0.01)
    assert not c.update(10.0)           # first observation = best
    assert not c.update(10.0)           # bad 1 (no 1% improvement)
    assert c.update(10.0)               # bad 2 -> drop
    assert abs(c.lr - 0.1) < 1e-12 and c.n_drops == 1
    assert not c.update(1.0)            # big improvement resets
    assert c.best == 1.0 and c.num_bad == 0


def test_plateau_improvement_resets_patience():
    c = schedules.plateau_decay(1.0, patience=2, threshold=0.01)
    c.update(10.0)
    assert not c.update(10.0)           # bad 1
    assert not c.update(9.0)            # 10% improvement resets
    assert not c.update(9.0)            # bad 1 again
    assert c.update(9.0)                # bad 2 -> drop
    assert abs(c.lr - 0.1) < 1e-12


def test_plateau_min_lr_floor():
    c = schedules.plateau_decay(1.0, factor=0.1, patience=1, min_lr=0.05)
    c.update(1.0)
    assert c.update(1.0) and abs(c.lr - 0.1) < 1e-12
    assert c.update(1.0) and c.lr == 0.05       # clamped
    assert not c.update(1.0)                     # at the floor: no drop
    assert c.lr == 0.05


def test_plateau_negative_metrics():
    """The relative margin must not invert for negative metrics (a plain
    best*(1-threshold) would count strictly-worse values as improved)."""
    c = schedules.plateau_decay(1.0, patience=2, threshold=1e-3)
    c.update(-2.0)
    assert not c.update(-1.999)          # worse, within margin: bad eval 1
    assert c.best == -2.0                # best must NOT creep toward zero
    assert c.update(-1.999)              # bad eval 2 -> drop
    assert abs(c.lr - 0.1) < 1e-12
    assert not c.update(-2.5)            # genuinely better: improvement
    assert c.best == -2.5


def test_plateau_mode_max():
    c = schedules.plateau_decay(1.0, patience=1, mode="max", threshold=0.01)
    c.update(0.5)                        # accuracy-style metric
    assert not c.update(0.6)             # improving
    assert c.update(0.6)                 # stalled -> drop
    assert abs(c.lr - 0.1) < 1e-12


def test_plateau_state_dict_roundtrip_replays_decisions():
    a = schedules.plateau_decay(1.0, patience=2, threshold=0.01)
    metrics = [5.0, 5.0, 4.9999, 5.0, 5.0, 5.0, 5.0]
    mid = 3
    for m in metrics[:mid]:
        a.update(m)
    b = schedules.plateau_decay(1.0, patience=2, threshold=0.01)
    b.load_state_dict(a.state_dict())            # "resume" b at the split
    trace_a = [a.update(m) for m in metrics[mid:]]
    trace_b = [b.update(m) for m in metrics[mid:]]
    assert trace_a == trace_b
    assert a.state_dict() == b.state_dict()


def test_as_controller_wraps_plain_schedules():
    c = schedules.as_controller(schedules.constant(0.5))
    assert float(c.schedule()(0)) == 0.5
    assert c.update(1.0) is False and c.state_dict() == {}
    p = schedules.plateau_decay(0.1)
    assert schedules.as_controller(p) is p


# --------------------------------------------------------- integration ----
def test_session_validation_loop_drives_lr_drop(tmp_path):
    """A full in-process session on a tiny quadratic model: the validation
    loop must feed the controller and rebuild the train step with the
    dropped LR (asserted from the recorded per-step LRs)."""
    opt = sgd_momentum(weight_decay=0.0)

    def init(rng):
        return {"w": jnp.ones((4,), jnp.float32)}

    def loss(params, batch):
        return jnp.mean((batch["x"] @ params["w"] - batch["y"]) ** 2)

    state = init_param_avg_state(jax.random.PRNGKey(0), init, opt, 1)
    controller = schedules.plateau_decay(0.05, factor=0.1, patience=1,
                                         threshold=0.5)

    def make_stream():
        rng = np.random.default_rng(0)
        def gen():
            while True:
                x = rng.normal(size=(1, 8, 4)).astype(np.float32)
                yield {"x": x, "y": (x @ np.arange(4.0,
                                                   dtype=np.float32))}
        return gen()

    def make_eval_batches():
        rng = np.random.default_rng(99)
        def gen():
            while True:
                x = rng.normal(size=(8, 4)).astype(np.float32)
                yield {"x": x, "y": (x @ np.arange(4.0,
                                                   dtype=np.float32))}
        return gen()

    def metric_fn(params, batch):
        return {"loss": jnp.mean((batch["x"] @ params["w"]
                                  - batch["y"]) ** 2)}

    session = TrainSession(
        state=state,
        build_step=lambda sched: jax.jit(
            make_param_avg_step(loss, opt, sched), donate_argnums=0),
        make_stream=make_stream, controller=controller, steps=8,
        eval_step=make_eval_step(metric_fn),
        make_eval_batches=make_eval_batches, eval_every=2, eval_batches=1,
        plateau_metric="loss",
        metrics_path=str(tmp_path / "m.jsonl"), log_every=100)
    result = session.run()

    assert result.lr_drops, "validation loop never dropped the LR"
    from repro.train_loop import read_jsonl
    lrs = [r["lr"] for r in read_jsonl(str(tmp_path / "m.jsonl"), "train")]
    assert abs(lrs[0] - 0.05) < 1e-8            # pre-drop segment
    assert min(lrs) < 0.05 * 0.11               # post-drop segment (/10)
    evals = read_jsonl(str(tmp_path / "m.jsonl"), "eval")
    assert len(evals) == 4 and any(e["lr_dropped"] for e in evals)
