"""Golden-trace regression: 20-step fp32 loss traces for four zoo
members, pinned to committed JSON files.  ANY numerics change — a kernel
edit, an init reshuffle, an op-ordering 'refactor' — shows up as drift
here before it can silently corrupt a training run.

Regenerate deliberately with::

    REPRO_UPDATE_GOLDEN=1 PYTHONPATH=src python -m pytest \
        tests/train_loop/test_golden_traces.py

and commit the diff with an explanation of WHY the numbers moved.

The traces are recorded on the XLA backend.  ``REPRO_GOLDEN_PALLAS=1``
(the CI vision job) re-runs every trace through the Pallas kernel path —
same golden files, looser tolerance (fp32 formulation noise between the
fused kernels and lax), proving the two backends train the same model.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import (ALEXNET_FAITHFUL_SMOKE, ALEXNET_SMOKE, ARCHS,
                           reduced)
from repro.kernels.common import KernelPolicy

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "..", "golden")
UPDATE = bool(os.environ.get("REPRO_UPDATE_GOLDEN"))
PALLAS = bool(os.environ.get("REPRO_GOLDEN_PALLAS"))

STEPS = 20
LR, MOMENTUM = 0.01, 0.9

# fp32 smoke-sized members: the paper's own net in both flavours plus a
# dense-attention and a recurrent LM
IMAGE_SIZE = 48     # smallest hw the smoke conv stack supports cleanly


def _alexnet(cfg):
    return dataclasses.replace(cfg, image_size=IMAGE_SIZE, dtype="float32")


def _lm(name):
    return dataclasses.replace(reduced(ARCHS[name]), dtype="float32")


TRACES = {
    "alexnet_legacy": lambda: _alexnet(ALEXNET_SMOKE),
    "alexnet_faithful": lambda: _alexnet(ALEXNET_FAITHFUL_SMOKE),
    "olmo_1b": lambda: _lm("olmo-1b"),
    "rwkv6_7b": lambda: _lm("rwkv6-7b"),
}


def _batch(cfg, rng, step):
    k = jax.random.fold_in(rng, step)
    if cfg.family == "conv":
        return {"images": jax.random.normal(
                    k, (4, cfg.image_size, cfg.image_size,
                        cfg.in_channels), jnp.float32),
                "labels": jax.random.randint(jax.random.fold_in(k, 1),
                                             (4,), 0, cfg.n_classes)}
    toks = jax.random.randint(k, (4, 32), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": toks}


def _trace(cfg):
    """Plain jitted SGD-momentum — deliberately none of the param-avg
    machinery, so this pins MODEL numerics, not engine numerics."""
    params = models.init(jax.random.PRNGKey(0), cfg)
    mom = jax.tree.map(jnp.zeros_like, params)

    @jax.jit
    def step(params, mom, batch):
        loss, g = jax.value_and_grad(
            lambda p: models.loss_fn(p, cfg, batch))(params)
        mom = jax.tree.map(lambda m, d: MOMENTUM * m + d, mom, g)
        params = jax.tree.map(lambda p, m: p - LR * m, params, mom)
        return params, mom, loss

    rng = jax.random.PRNGKey(7)
    losses = []
    for i in range(STEPS):
        params, mom, loss = step(params, mom, _batch(cfg, rng, i))
        losses.append(float(loss))
    return losses


def _golden_path(name):
    return os.path.join(GOLDEN_DIR, f"{name}.json")


@pytest.mark.parametrize("name", sorted(TRACES))
def test_golden_trace(name):
    backend = "pallas" if PALLAS else "xla"
    cfg = dataclasses.replace(TRACES[name](),
                              kernels=KernelPolicy(backend=backend))
    losses = _trace(cfg)
    assert all(np.isfinite(losses)), losses
    path = _golden_path(name)
    if UPDATE:
        with open(path, "w") as f:
            json.dump({"name": name, "steps": STEPS, "lr": LR,
                       "momentum": MOMENTUM, "backend": "xla",
                       "losses": losses}, f, indent=1)
            f.write("\n")
        pytest.skip(f"regenerated {path}")
    if not os.path.exists(path):
        pytest.fail(f"{path} missing — run with REPRO_UPDATE_GOLDEN=1 "
                    "and commit the trace")
    with open(path) as f:
        golden = json.load(f)
    drift = float(np.max(np.abs(np.asarray(losses)
                                - np.asarray(golden["losses"]))))
    # xla must reproduce the recorded trace to 1e-4; the pallas rerun of
    # the SAME files tolerates fused-vs-lax fp32 formulation noise
    tol = 5e-3 if PALLAS else 1e-4
    assert drift <= tol, \
        (f"{name}: max loss drift {drift:.2e} > {tol:.0e} over {STEPS} "
         f"steps (backend={backend}) — if this change is intentional, "
         "regenerate with REPRO_UPDATE_GOLDEN=1 and justify it")
