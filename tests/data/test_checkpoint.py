"""Checkpoint roundtrip across dtypes and pytree shapes."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint


def test_roundtrip_mixed_dtypes(tmp_path):
    tree = {
        "bf16": jnp.full((3, 4), 1.5, jnp.bfloat16),
        "f32": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "i32": jnp.arange(5, dtype=jnp.int32),
        "nested": [{"a": jnp.zeros((2, 2))}, (jnp.ones((1,)),)],
    }
    checkpoint.save(str(tmp_path), 42, tree)
    like = jax.tree.map(jnp.zeros_like, tree)
    out = checkpoint.restore(str(tmp_path), 42, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_step(tmp_path):
    assert checkpoint.latest_step(str(tmp_path)) is None
    tree = {"x": jnp.ones(2)}
    checkpoint.save(str(tmp_path), 10, tree)
    checkpoint.save(str(tmp_path), 30, tree)
    assert checkpoint.latest_step(str(tmp_path)) == 30


def test_train_state_roundtrip(tmp_path):
    from repro.core import init_param_avg_state
    from repro.optim.optimizers import sgd_momentum
    from repro import models
    from repro.configs import ARCHS, reduced
    cfg = reduced(ARCHS["olmo-1b"])
    opt = sgd_momentum()
    state = init_param_avg_state(jax.random.PRNGKey(0),
                                 lambda r: models.init(r, cfg), opt, 2)
    checkpoint.save(str(tmp_path), 1, state)
    like = jax.tree.map(jnp.zeros_like, state)
    out = checkpoint.restore(str(tmp_path), 1, like)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
