"""Checkpoint roundtrip across dtypes, pytree shapes, shardings and
corrupt/partial directories (ISSUE-3: restore must land on the live mesh
layout; latest_step must never hand back a half-written checkpoint)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from _subproc import run_child

from repro import checkpoint


def test_roundtrip_mixed_dtypes(tmp_path):
    tree = {
        "bf16": jnp.full((3, 4), 1.5, jnp.bfloat16),
        "f32": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "i32": jnp.arange(5, dtype=jnp.int32),
        "nested": [{"a": jnp.zeros((2, 2))}, (jnp.ones((1,)),)],
    }
    checkpoint.save(str(tmp_path), 42, tree)
    like = jax.tree.map(jnp.zeros_like, tree)
    out = checkpoint.restore(str(tmp_path), 42, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_step(tmp_path):
    assert checkpoint.latest_step(str(tmp_path)) is None
    tree = {"x": jnp.ones(2)}
    checkpoint.save(str(tmp_path), 10, tree)
    checkpoint.save(str(tmp_path), 30, tree)
    assert checkpoint.latest_step(str(tmp_path)) == 30


def test_latest_step_skips_gaps_and_corrupt_dirs(tmp_path):
    tree = {"x": jnp.ones(2)}
    checkpoint.save(str(tmp_path), 10, tree)
    checkpoint.save(str(tmp_path), 30, tree)        # gap: no step 20
    # half-written dir (killed before arrays.npz landed)
    partial = tmp_path / "step_00000040"
    partial.mkdir()
    (partial / "manifest.json").write_text('{"step": 40, "arrays": {}}')
    # corrupt manifest
    corrupt = tmp_path / "step_00000050"
    corrupt.mkdir()
    (corrupt / "manifest.json").write_text("{not json")
    (corrupt / "arrays.npz").write_bytes(b"")
    # interrupted atomic save (tmp suffix never renamed into place)
    (tmp_path / "step_00000060.tmp").mkdir()
    # junk that matches nothing
    (tmp_path / "step_junk").mkdir()
    assert checkpoint.latest_step(str(tmp_path)) == 30
    # a later COMPLETE checkpoint wins again
    checkpoint.save(str(tmp_path), 70, tree)
    assert checkpoint.latest_step(str(tmp_path)) == 70


def test_save_is_atomic_and_overwrites(tmp_path):
    tree = {"x": jnp.ones(3)}
    d = checkpoint.save(str(tmp_path), 5, tree)
    assert not os.path.isdir(d + ".tmp")            # tmp renamed away
    checkpoint.save(str(tmp_path), 5, {"x": 2 * jnp.ones(3)})   # re-save
    out = checkpoint.restore(str(tmp_path), 5, {"x": jnp.zeros(3)})
    np.testing.assert_array_equal(np.asarray(out["x"]), 2 * np.ones(3))


def test_meta_roundtrip(tmp_path):
    meta = {"controller": {"lr": 0.01, "best": 0.5, "num_bad": 1,
                           "n_drops": 0}, "batches_consumed": 7}
    checkpoint.save(str(tmp_path), 7, {"x": jnp.ones(2)}, meta=meta)
    assert checkpoint.load_meta(str(tmp_path), 7) == meta
    # seed-style saves without meta read back as None
    checkpoint.save(str(tmp_path), 8, {"x": jnp.ones(2)})
    assert checkpoint.load_meta(str(tmp_path), 8) is None


def test_restore_without_manifest_meta_key(tmp_path):
    """Manifests written by the seed (no 'meta' key) must still restore."""
    checkpoint.save(str(tmp_path), 3, {"x": jnp.arange(4.0)})
    mpath = tmp_path / "step_00000003" / "manifest.json"
    m = json.loads(mpath.read_text())
    del m["meta"]
    mpath.write_text(json.dumps(m))
    out = checkpoint.restore(str(tmp_path), 3, {"x": jnp.zeros(4)})
    np.testing.assert_array_equal(np.asarray(out["x"]), np.arange(4.0))
    assert checkpoint.latest_step(str(tmp_path)) == 3


_SHARDED_ROUNDTRIP = """
import jax, jax.numpy as jnp, numpy as np
from repro import checkpoint
from repro.core import init_param_avg_state
from repro.launch.mesh import make_replica_mesh
from repro.optim.optimizers import sgd_momentum
from repro.models import alexnet
from repro.configs import ALEXNET_SMOKE
from repro.sharding.specs import replica_sharding

R = jax.device_count()
mesh = make_replica_mesh(R)
# bf16 params: the uint8 raw-bytes container must round-trip them exactly
import dataclasses
cfg = dataclasses.replace(ALEXNET_SMOKE, dtype="bfloat16")
state = init_param_avg_state(jax.random.PRNGKey(0),
                             lambda r: alexnet.init(r, cfg),
                             sgd_momentum(state_dtype=jnp.bfloat16), R)
shard = replica_sharding(state, mesh, replica_axes=("data",))
state = jax.device_put(state, shard)
checkpoint.save("{d}", 4, state)

like = jax.tree.map(jnp.zeros_like, state)
out = checkpoint.restore("{d}", 4, like, sharding=shard)
flat_in, _ = jax.tree_util.tree_flatten(state)
flat_out, _ = jax.tree_util.tree_flatten(out)
flat_sh, _ = jax.tree_util.tree_flatten(shard,
    is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding))
assert len(flat_in) == len(flat_out) == len(flat_sh)
for a, b, s in zip(flat_in, flat_out, flat_sh):
    assert b.sharding == s, (b.sharding, s)          # live mesh layout
    assert b.sharding == a.sharding, (b.sharding, a.sharding)
    assert a.dtype == b.dtype, (a.dtype, b.dtype)
    np.testing.assert_array_equal(np.asarray(a, np.float32),
                                  np.asarray(b, np.float32))
# the seed behavior (no sharding arg) lands on the default device
plain = checkpoint.restore("{d}", 4, like)
leaf = jax.tree_util.tree_flatten(plain)[0][0]
assert leaf.sharding != flat_sh[0] or R == 1
print("OK")
"""


def _sharded_roundtrip(tmp_path, devices):
    out = run_child(_SHARDED_ROUNDTRIP.replace("{d}", str(tmp_path)),
                    devices=devices)
    assert "OK" in out


def test_sharded_bf16_roundtrip_2dev(tmp_path):
    """Restore must land every leaf on the same Sharding a fresh run uses
    (2 devices; bf16 params + bf16 momentum)."""
    _sharded_roundtrip(tmp_path, 2)


def test_sharded_bf16_roundtrip_4dev(tmp_path):
    _sharded_roundtrip(tmp_path, 4)


def test_train_state_roundtrip(tmp_path):
    from repro.core import init_param_avg_state
    from repro.optim.optimizers import sgd_momentum
    from repro import models
    from repro.configs import ARCHS, reduced
    cfg = reduced(ARCHS["olmo-1b"])
    opt = sgd_momentum()
    state = init_param_avg_state(jax.random.PRNGKey(0),
                                 lambda r: models.init(r, cfg), opt, 2)
    checkpoint.save(str(tmp_path), 1, state)
    like = jax.tree.map(jnp.zeros_like, state)
    out = checkpoint.restore(str(tmp_path), 1, like)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


_QUANTIZED_ROUNDTRIP = """
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro import checkpoint, configs, models
from repro.launch.mesh import make_replica_mesh
from repro.numerics import NumericsPolicy
from repro.sharding.specs import cache_sharding

R = jax.device_count()
cfg = dataclasses.replace(configs.reduced(configs.get_config("olmo-1b")),
                          numerics=NumericsPolicy(kv_cache_dtype="int8"))
params = models.init(jax.random.PRNGKey(0), cfg)
toks = jax.random.randint(jax.random.PRNGKey(1), (R, 16), 0, cfg.vocab_size)
_, st = models.prefill(params, cfg, toks, 32)
mesh = make_replica_mesh(R)
shard = cache_sharding(st.cache, cfg, mesh, batch_axes=("data",))
cache = jax.device_put(st.cache, shard)

# fp8 rides the same uint8 raw-bytes container (e.g. fp8 residuals or a
# future fp8 KV) — prove the manifest dtype survives alongside the
# sharded int8 state
tree = {"cache": cache,
        "fp8": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)
               .astype(jnp.float8_e4m3fn)}
checkpoint.save("{d}", 7, tree)
like = jax.tree.map(jnp.zeros_like, tree)
out = checkpoint.restore("{d}", 7, like,
                         sharding={"cache": shard, "fp8": None})
seen = set()
for (p1, a), (p2, b) in zip(jax.tree_util.tree_flatten_with_path(tree)[0],
                            jax.tree_util.tree_flatten_with_path(out)[0]):
    assert a.dtype == b.dtype, (p1, a.dtype, b.dtype)
    seen.add(str(a.dtype))
    np.testing.assert_array_equal(np.asarray(a, np.float32),
                                  np.asarray(b, np.float32))
assert "int8" in seen and "float8_e4m3fn" in seen and "float32" in seen, seen
# the cache subtree must land back on the live mesh layout
for s, b in zip(jax.tree.leaves(shard), jax.tree.leaves(out["cache"])):
    assert b.sharding == s, (b.sharding, s)
print("QUANT-CKPT-OK")
"""


def _quantized_roundtrip(tmp_path, devices):
    out = run_child(_QUANTIZED_ROUNDTRIP.replace("{d}", str(tmp_path)),
                    devices=devices)
    assert "QUANT-CKPT-OK" in out


def test_quantized_state_roundtrip_2dev(tmp_path):
    """int8 ring KV (values + fp32 scales) and fp8 leaves round-trip with
    dtype AND live-mesh sharding intact (2 devices)."""
    _quantized_roundtrip(tmp_path, 2)


def test_quantized_state_roundtrip_4dev(tmp_path):
    _quantized_roundtrip(tmp_path, 4)
