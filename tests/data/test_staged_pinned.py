"""StagedPinnedLoader: the fence-gated double-buffered staging path
(paper Fig. 1 taken literally — PR 7).

The invariant under test: a staged batch's host buffer is ALIASED by the
device array handed to the trainer (zero-copy on the CPU backend), so
the worker must not overwrite a slot until the step that consumed it has
fenced.  Every test here drives the loader exactly as the training loop
does — ``batch = next(loader); ...; loader.fence(token)``.
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.data import make_loader
from repro.data.pipeline import PrefetchLoader, StagedPinnedLoader


def counter_source(n, delay=0.0):
    for i in range(n):
        if delay:
            time.sleep(delay)
        yield {"x": np.full((2, 3), i, np.float32)}


def test_order_and_values_preserved():
    ld = StagedPinnedLoader(counter_source(8))
    vals = []
    for b in ld:
        vals.append(int(np.asarray(b["x"])[0, 0]))
        ld.fence(b["x"])
    assert vals == list(range(8))
    ld.close()


def test_batch_content_stable_until_fence():
    """The handed-out batch must keep its values while the NEXT batches
    stream through the other slot — the whole point of the fence."""
    ld = StagedPinnedLoader(counter_source(6))
    first = next(ld)
    kept = np.asarray(first["x"]).copy()
    ld.fence(first["x"])
    second = next(ld)                    # other slot
    time.sleep(0.2)                      # worker wants to re-stage slot 0
    # slot 0's fence (first's token) is already released, so slot 0 may be
    # rewritten NOW — but second (slot 1) is un-fenced and must be intact
    np.testing.assert_array_equal(np.asarray(second["x"]),
                                  np.full((2, 3), 1, np.float32))
    np.testing.assert_array_equal(kept, np.full((2, 3), 0, np.float32))
    ld.fence(second["x"])
    ld.close()


def test_missing_fence_raises_instead_of_deadlocking():
    ld = StagedPinnedLoader(counter_source(10))
    next(ld)
    next(ld)                             # both slots handed out, no fences
    with pytest.raises(RuntimeError, match="await fences"):
        next(ld)
    ld.close()


def test_three_slots_allow_deeper_pipeline():
    ld = make_loader(counter_source(10), prefetch=3, staging="pinned")
    assert isinstance(ld, StagedPinnedLoader)
    a, b, c = next(ld), next(ld), next(ld)   # three in flight is fine
    with pytest.raises(RuntimeError, match="await fences"):
        next(ld)
    for t in (a, b, c):
        ld.fence(t["x"])
    assert int(np.asarray(next(ld)["x"])[0, 0]) == 3
    ld.close()


def test_worker_blocks_on_unready_fence_token(monkeypatch):
    """The worker must wait on the fence token before re-staging: with a
    slow token the re-stage of that slot cannot complete early."""
    import repro.data.pipeline as pl
    gate = threading.Event()
    orig = jax.block_until_ready

    def slow_ready(tok):
        if isinstance(tok, str) and tok == "slow":
            gate.wait(timeout=5.0)
            return tok
        return orig(tok)

    monkeypatch.setattr(pl.jax, "block_until_ready", slow_ready)
    ld = StagedPinnedLoader(counter_source(8))
    first = next(ld)
    ld.fence("slow")                     # slot 0 gated on the slow token
    next(ld)                             # slot 1 was pre-staged
    time.sleep(0.3)
    # batch 2 targets slot 0, whose fence is still blocked: queue stays
    # empty and first's buffer is untouched
    assert ld._q.empty()
    np.testing.assert_array_equal(np.asarray(first["x"]),
                                  np.full((2, 3), 0, np.float32))
    gate.set()
    ld.fence(None)
    assert int(np.asarray(next(ld)["x"])[0, 0]) == 2
    assert ld.fence_wait_ms_total > 200, ld.fence_wait_ms_total
    ld.close()


def test_buffers_reused_after_first_lap():
    """After one lap the host buffers are warm: same id each revisit
    (the no-page-faults property the staging exists for)."""
    ld = StagedPinnedLoader(counter_source(9))
    seen = {}
    for i, b in enumerate(ld):
        ld.fence(b["x"])
        time.sleep(0.05)                 # let the worker re-stage
        slot = i % 2
        buf_id = id(ld._host[slot]["x"])
        if slot in seen and i >= 2:
            assert buf_id == seen[slot], f"slot {slot} reallocated lap {i}"
        seen[slot] = buf_id
    ld.close()


def test_ragged_final_batch_reallocates():
    def ragged():
        yield {"x": np.zeros((4, 3), np.float32)}
        yield {"x": np.zeros((4, 3), np.float32)}
        yield {"x": np.ones((2, 3), np.float32)}     # smaller tail

    ld = StagedPinnedLoader(ragged())
    shapes = []
    for b in ld:
        shapes.append(np.asarray(b["x"]).shape)
        ld.fence(b["x"])
    assert shapes == [(4, 3), (4, 3), (2, 3)]
    ld.close()


def test_stop_iteration_and_stays_exhausted():
    ld = StagedPinnedLoader(counter_source(3))
    for b in ld:
        ld.fence(b["x"])
    for _ in range(2):
        with pytest.raises(StopIteration):
            next(ld)
    ld.close()


def test_close_joins_worker_and_next_raises():
    ld = StagedPinnedLoader(counter_source(100, delay=0.001))
    b = next(ld)
    ld.fence(b["x"])
    worker = ld._thread
    ld.close()
    assert ld._thread is None
    worker.join(timeout=2.0)
    assert not worker.is_alive()
    with pytest.raises(RuntimeError, match="closed"):
        next(ld)


def test_close_unblocks_worker_waiting_on_fence():
    """close() with both slots un-fenced must not hang the join."""
    ld = StagedPinnedLoader(counter_source(10))
    next(ld)
    next(ld)
    time.sleep(0.1)                      # worker parks in _take_fence
    t0 = time.time()
    ld.close()
    assert time.time() - t0 < 2.0


def test_worker_exception_propagates():
    def bad():
        yield {"x": np.zeros((2, 3), np.float32)}
        raise ValueError("boom")

    ld = StagedPinnedLoader(bad())
    b = next(ld)
    ld.fence(b["x"])
    with pytest.raises(ValueError, match="boom"):
        next(ld)
        next(ld)
    ld.close()


def test_wait_metrics_accumulate():
    ld = StagedPinnedLoader(counter_source(4, delay=0.05))
    total = 0.0
    for b in ld:
        ld.fence(b["x"])
        assert ld.last_wait_ms >= 0.0
        total = ld.wait_ms_total
    assert total > 0.0
    ld.close()


def test_prefetch_loader_fence_is_noop():
    ld = PrefetchLoader(counter_source(3), prefetch=2)
    for b in ld:
        ld.fence(b["x"])                 # uniform API, no effect
    ld.close()


def test_make_loader_factory_dispatch():
    assert isinstance(make_loader(counter_source(1), staging="queue"),
                      PrefetchLoader)
    pinned = make_loader(counter_source(1), prefetch=0, staging="pinned")
    assert isinstance(pinned, StagedPinnedLoader)
    assert pinned._slots == 2            # floor: always a double buffer
    with pytest.raises(ValueError, match="staging"):
        make_loader(counter_source(1), staging="dma")
