"""Data pipeline: prefetch ordering/overlap (paper §2.1) + preprocessing
properties (hypothesis)."""
import time

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.data import PrefetchLoader, synthetic
from repro.data.preprocess import (make_image_preprocess, random_crop_flip,
                                   subtract_mean)


def counter_source(n, delay=0.0):
    for i in range(n):
        if delay:
            time.sleep(delay)
        yield {"x": np.full((2, 3), i, np.float32)}


def test_order_preserved():
    ld = PrefetchLoader(counter_source(10), prefetch=2)
    vals = [int(b["x"][0, 0]) for b in ld]
    assert vals == list(range(10))


def test_stop_iteration():
    ld = PrefetchLoader(counter_source(3), prefetch=2)
    assert len(list(ld)) == 3


def test_sync_mode_matches():
    a = [int(b["x"][0, 0]) for b in PrefetchLoader(counter_source(5),
                                                   prefetch=0)]
    assert a == list(range(5))


def test_overlap_hides_load_latency():
    """With prefetch, consumer wait ~ max(load, compute); without, the sum.
    (The paper's Fig. 1 claim, measured.)"""
    load, compute, n = 0.03, 0.03, 8

    def consume(prefetch):
        ld = PrefetchLoader(counter_source(n, delay=load), prefetch=prefetch)
        t0 = time.time()
        for _ in ld:
            time.sleep(compute)       # "training"
        return time.time() - t0

    t_overlap = consume(2)
    t_serial = consume(0)
    # serial ~ n*(load+compute); overlapped ~ n*max(load,compute) + load
    assert t_overlap < t_serial * 0.82, (t_overlap, t_serial)


def test_close_joins_worker_thread():
    """close() must JOIN the worker — the seed leaked one thread per
    loader (a real problem for benchmark sweeps building many loaders),
    and a worker blocked on a full queue must still exit."""
    ld = PrefetchLoader(counter_source(100, delay=0.001), prefetch=2)
    next(ld)
    worker = ld._thread
    assert worker is not None and worker.is_alive()
    ld.close()
    assert ld._thread is None
    worker.join(timeout=2.0)
    assert not worker.is_alive()


def test_close_idempotent_and_after_exhaustion():
    ld = PrefetchLoader(counter_source(2), prefetch=2)
    assert len(list(ld)) == 2
    ld.close()
    ld.close()


def test_next_after_close_raises_instead_of_hanging():
    """The seed blocked forever in q.get() here: queue drained by close(),
    worker dead, nothing ever arriving.  Must raise instead."""
    ld = PrefetchLoader(counter_source(50), prefetch=2)
    next(ld)
    ld.close()
    with pytest.raises(RuntimeError, match="closed"):
        next(ld)
    with pytest.raises(RuntimeError, match="closed"):   # and stays raised
        next(ld)


def test_next_after_close_sync_mode():
    ld = PrefetchLoader(counter_source(5), prefetch=0)
    next(ld)
    ld.close()
    with pytest.raises(RuntimeError, match="closed"):
        next(ld)


def test_close_unblocks_waiting_consumer():
    """close() from another thread must wake a consumer already blocked in
    __next__ on an empty queue (slow producer)."""
    import threading
    ld = PrefetchLoader(counter_source(3, delay=30.0), prefetch=2)
    err = []

    def consume():
        try:
            next(ld)
        except RuntimeError as e:
            err.append(e)

    t = threading.Thread(target=consume)
    t.start()
    time.sleep(0.3)               # let it block on the empty queue
    ld.close()
    t.join(timeout=5.0)
    assert not t.is_alive() and err, "consumer stayed blocked past close()"


def test_exhausted_loader_keeps_raising_stopiteration():
    """Second next() after the sentinel used to hang (sentinel consumed
    once, queue then empty forever)."""
    ld = PrefetchLoader(counter_source(1), prefetch=2)
    next(ld)
    for _ in range(3):
        with pytest.raises(StopIteration):
            next(ld)
    ld.close()


def test_worker_exception_propagates():
    def bad():
        yield {"x": np.zeros(2)}
        raise ValueError("boom")

    ld = PrefetchLoader(bad(), prefetch=2)
    next(ld)
    with pytest.raises(ValueError, match="boom"):
        next(ld)
        next(ld)


@settings(max_examples=20, deadline=None)
@given(h=st.integers(10, 40), crop=st.integers(4, 10),
       seed=st.integers(0, 1000))
def test_crop_within_bounds_and_shape(h, crop, seed):
    rng = np.random.default_rng(seed)
    imgs = rng.normal(size=(3, h, h, 2)).astype(np.float32)
    out = random_crop_flip(imgs, crop, np.random.default_rng(seed))
    assert out.shape == (3, crop, crop, 2)
    assert np.isfinite(out).all()


def test_crop_flip_impl_parity():
    """loop and gather kernels must be bit-identical (same RNG draws, same
    output) — the benchmark in loading_overlap.py compares their speed."""
    rng = np.random.default_rng(7)
    imgs = rng.normal(size=(16, 40, 40, 3)).astype(np.float32)
    for flip in (True, False):
        a = random_crop_flip(imgs, 32, np.random.default_rng(3), flip=flip,
                             impl="loop")
        b = random_crop_flip(imgs, 32, np.random.default_rng(3), flip=flip,
                             impl="gather")
        np.testing.assert_array_equal(a, b)
        assert a.dtype == b.dtype == np.float32


def test_crop_flip_impls_consume_rng_identically():
    """A stream switching impls mid-run must keep the same draw sequence
    (all draws happen before dispatch)."""
    r1, r2 = np.random.default_rng(5), np.random.default_rng(5)
    imgs = np.random.default_rng(0).normal(size=(4, 12, 12, 1))
    random_crop_flip(imgs, 8, r1, impl="loop")
    random_crop_flip(imgs, 8, r2, impl="gather")
    assert r1.integers(0, 1 << 30) == r2.integers(0, 1 << 30)


def test_crop_flip_unknown_impl():
    with pytest.raises(ValueError, match="unknown crop impl"):
        random_crop_flip(np.zeros((1, 8, 8, 1)), 4,
                         np.random.default_rng(0), impl="simd")


def test_flip_is_involution():
    rng = np.random.default_rng(0)
    imgs = rng.normal(size=(1, 8, 8, 1)).astype(np.float32)
    flipped = imgs[:, :, ::-1]
    np.testing.assert_array_equal(flipped[:, :, ::-1], imgs)


def test_mean_subtraction_centers():
    it = synthetic.blob_images(4, 16, 24, seed=3)
    mean = synthetic.mean_image(synthetic.blob_images(4, 16, 24, seed=3), 8)
    batch = next(it)
    out = subtract_mean(batch["images"], mean)
    assert abs(out.mean()) < abs(batch["images"].mean()) + 0.1


def test_preprocess_deterministic_given_seed():
    imgs = np.random.default_rng(1).normal(size=(4, 32, 32, 3)).astype(
        np.float32)
    mean = np.zeros((32, 32, 3), np.float32)
    f1 = make_image_preprocess(mean, 24, seed=5)
    f2 = make_image_preprocess(mean, 24, seed=5)
    o1 = f1({"images": imgs})["images"]
    o2 = f2({"images": imgs})["images"]
    np.testing.assert_array_equal(o1, o2)


def test_markov_lm_learnable_structure():
    """Sharp transition table => next token is predictable (low entropy)."""
    it = synthetic.markov_lm(32, 8, 256, seed=0)
    b = next(it)
    toks = b["tokens"]
    assert toks.shape == (8, 256)
    assert toks.min() >= 0 and toks.max() < 32
    # bigram predictability: most common successor frequency well above 1/V
    from collections import Counter, defaultdict
    succ = defaultdict(Counter)
    for row in toks:
        for a, bb in zip(row[:-1], row[1:]):
            succ[int(a)][int(bb)] += 1
    top_frac = np.mean([c.most_common(1)[0][1] / sum(c.values())
                        for c in succ.values() if sum(c.values()) >= 5])
    assert top_frac > 0.12, top_frac  # >> 1/V = 0.03
