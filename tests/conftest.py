import os
import sys

import numpy as np
import pytest

# make tests/_hyp.py (hypothesis optional-dependency shim) importable from
# test modules in subdirectories regardless of pytest's import mode
sys.path.insert(0, os.path.dirname(__file__))


@pytest.fixture(scope="session")
def rng():
    import jax
    return jax.random.PRNGKey(0)


def assert_close(a, b, rtol=1e-4, atol=1e-4):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=rtol, atol=atol)
