import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    import jax
    return jax.random.PRNGKey(0)


def assert_close(a, b, rtol=1e-4, atol=1e-4):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=rtol, atol=atol)
