import os
import sys

import numpy as np
import pytest

# make tests/_hyp.py (hypothesis optional-dependency shim) importable from
# test modules in subdirectories regardless of pytest's import mode
sys.path.insert(0, os.path.dirname(__file__))


@pytest.fixture(scope="session")
def rng():
    import jax
    return jax.random.PRNGKey(0)


# Every jitted executable the suite compiles stays mmapped in the XLA CPU
# client for the life of the process; the full tier-1 run now compiles
# enough of them to hit the kernel's vm.max_map_count (65530 by default),
# at which point the NEXT backend_compile segfaults inside XLA.  At each
# module boundary, if the process is using a big fraction of the limit,
# drop the jit caches — within-module compile-cache assumptions (e.g. the
# serving engine's warm-process reuse tests) are untouched, and modules
# are independent across that boundary by construction.
_MAPS_FILE = "/proc/self/maps"


def _n_maps():
    try:
        with open(_MAPS_FILE) as f:
            return sum(1 for _ in f)
    except OSError:        # non-Linux: no /proc, and no 65530 cliff either
        return 0


def _max_maps():
    try:
        with open("/proc/sys/vm/max_map_count") as f:
            return int(f.read())
    except (OSError, ValueError):
        return 65530


def _clear_jit():
    import gc

    import jax
    jax.clear_caches()
    gc.collect()


@pytest.fixture(scope="module", autouse=True)
def _shed_jit_maps():
    # 0.25: the heaviest single module grows ~33k maps on its own, so the
    # clear must fire while there is still >33k of headroom below the cap
    if _n_maps() > 0.25 * _max_maps():
        _clear_jit()
    yield


def pytest_runtest_teardown(item, nextitem):
    # emergency brake inside a module: better a recompile than a segfault
    if _n_maps() > 0.8 * _max_maps():
        _clear_jit()


def assert_close(a, b, rtol=1e-4, atol=1e-4):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=rtol, atol=atol)
