"""Serving-tier state movement, in one process: snapshot containers
round-trip byte-exactly, export/import is the identity on a slot's
stream, and drain replays into a fresh engine with zero dropped
requests and byte-identical tokens (greedy).  The multi-process layer
on top is tests/serving/test_router.py."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint, models
from repro.configs import ARCHS, reduced
from repro.kernels.common import KernelPolicy
from repro.serving import DrainingError, Request, ServingEngine
from repro.serving import tier as tier_mod

CAP = 32


def _cfg(**over):
    cfg = reduced(ARCHS["olmo-1b"], n_layers=2, d_model=64, vocab=128)
    return dataclasses.replace(cfg, kernels=KernelPolicy(backend="xla"),
                               **over)


def _params(cfg):
    return models.init(jax.random.PRNGKey(0), cfg)


def _reqs(cfg, n=6, ln=6, new=12, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, size=ln),
                    max_new_tokens=new) for _ in range(n)]


def _streams(results):
    return sorted(tuple(r.tokens) for r in results)


# ----------------------------------------------------------- containers ----

def test_pack_tree_roundtrip_exact_bytes():
    tree = {"a": np.arange(6, dtype=np.int32).reshape(2, 3),
            "b": (np.ones((3,), jnp.bfloat16) * 1.5,
                  np.array([-7], np.int8))}
    buf = checkpoint.pack_tree(tree, meta={"rid": 41, "note": "x"})
    assert checkpoint.peek_meta(buf) == {"rid": 41, "note": "x"}
    out, meta = checkpoint.unpack_tree(buf, tree)
    assert meta["rid"] == 41
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert x.dtype == y.dtype
        assert np.array_equal(np.asarray(x).view(np.uint8),
                              np.asarray(y).view(np.uint8))


def test_read_slots_inverts_write_slots():
    cfg = _cfg()
    cache = models.init_decode_cache(cfg, 4, CAP)
    state = models.DecodeState(
        cache=jax.tree.map(
            lambda l: jnp.add(l, jnp.arange(l.size, dtype=l.dtype)
                              .reshape(l.shape)) if l.size else l,
            cache),
        pos=jnp.asarray([3, 5, 7, 9], jnp.int32))
    sub = models.read_slots(state, [2])
    blank = models.DecodeState(cache=models.init_decode_cache(cfg, 4, CAP),
                               pos=jnp.zeros((4,), jnp.int32))
    back = models.write_slots(blank, sub, [2])
    again = models.read_slots(back, [2])
    for x, y in zip(jax.tree.leaves(sub), jax.tree.leaves(again)):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    assert int(again.pos[0]) == 7


def test_snapshot_pack_unpack_is_exact():
    cfg = _cfg()
    eng = ServingEngine(_params(cfg), cfg, slots=2, capacity=CAP)
    for q in _reqs(cfg, n=2):
        eng.submit(q)
    eng.step()
    snap = eng.export_slot(0)
    like = tier_mod.snapshot_like(cfg, CAP, eng.enc_len)
    buf = tier_mod.pack_snapshot(snap)
    assert checkpoint.peek_meta(buf)["rid"] == snap["meta"]["rid"]
    out = tier_mod.unpack_snapshot(buf, like)
    for x, y in zip(jax.tree.leaves(snap["arrays"]),
                    jax.tree.leaves(out["arrays"])):
        assert np.asarray(x).dtype == np.asarray(y).dtype
        assert np.array_equal(np.asarray(x), np.asarray(y))
    assert out["meta"]["tokens"] == snap["meta"]["tokens"]


# -------------------------------------------------------- drain / replay ----

def test_drain_replay_byte_identical():
    """The acceptance criterion: snapshot mid-stream, replay into a
    DIFFERENT engine (different slots get used, different peers), finish
    — the union of token streams equals an uninterrupted run's exactly."""
    cfg = _cfg()
    params = _params(cfg)
    ref = ServingEngine(params, cfg, slots=3, capacity=CAP).run(_reqs(cfg))
    want = _streams(ref)

    eng1 = ServingEngine(params, cfg, slots=3, capacity=CAP)
    for q in _reqs(cfg):
        eng1.submit(q)
    fin = []
    for _ in range(4):                       # mid-flight: some tokens out
        fin += eng1.step()
    snaps, queued = eng1.drain()
    assert len(snaps) + len(queued) + len(fin) == 6   # nothing dropped
    assert eng1.load()["draining"]

    like = tier_mod.snapshot_like(cfg, CAP, eng1.enc_len)
    bufs = [tier_mod.pack_snapshot(s) for s in snaps]   # cross-process wire
    eng2 = ServingEngine(params, cfg, slots=3, capacity=CAP)
    for b in bufs:
        assert eng2.import_snapshot(tier_mod.unpack_snapshot(b, like)) \
            is not None
    for q in queued:
        eng2.submit(Request(prompt=q.prompt,
                            max_new_tokens=q.max_new_tokens))
    fin += eng2.run([])
    assert _streams(fin) == want


def test_submit_while_draining_is_typed_error():
    cfg = _cfg()
    eng = ServingEngine(_params(cfg), cfg, slots=2, capacity=CAP)
    eng.drain()
    with pytest.raises(DrainingError):
        eng.submit(_reqs(cfg, n=1)[0])


def test_drain_gates_unsupported_modes():
    cfg = _cfg()
    params = _params(cfg)
    eng = ServingEngine(params, cfg, slots=2, capacity=CAP,
                        block_size=16, num_blocks=8)
    with pytest.raises(NotImplementedError):
        eng.drain()


# ------------------------------------------------------- prefill worker ----

def test_prefill_worker_matches_colocated_stream():
    """Disaggregation must be invisible: a prefill-worker snapshot
    injected into a decode engine continues exactly the stream the
    colocated engine would have produced."""
    cfg = _cfg()
    params = _params(cfg)
    reqs = _reqs(cfg, n=3, seed=4)
    want = _streams(ServingEngine(params, cfg, slots=3,
                                  capacity=CAP).run(reqs))

    pw = tier_mod.PrefillWorker(params, cfg, capacity=CAP)
    eng = ServingEngine(params, cfg, slots=3, capacity=CAP)
    like = tier_mod.snapshot_like(cfg, CAP, eng.enc_len)
    for rid, q in enumerate(reqs):
        wire = tier_mod.request_to_wire(q)
        wire["rid"] = rid                    # router normally stamps this
        buf = tier_mod.pack_snapshot(pw.prefill(wire))
        assert eng.import_snapshot(tier_mod.unpack_snapshot(buf, like)) \
            is not None
    assert pw.prefills == 3
    assert _streams(eng.run([])) == want


def test_wire_rejects_vision_requests():
    with pytest.raises(NotImplementedError):
        tier_mod.request_to_wire(
            Request(prompt=[1, 2], max_new_tokens=4,
                    image=np.zeros((8, 8, 3), np.float32)))
