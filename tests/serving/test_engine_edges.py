"""Serving-engine edge cases around admission and slot surgery: prompts
that fill the ring exactly, whole admission waves retiring inside one
tick, and write_slots over the vision-family decode states."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs import ALEXNET_FAITHFUL_SMOKE, ARCHS, reduced
from repro.kernels.common import KernelPolicy
from repro.models import vision
from repro.serving import Request, ServingEngine

XLA = KernelPolicy(backend="xla")


def _cfg(arch="olmo-1b", **over):
    return dataclasses.replace(reduced(ARCHS[arch]), kernels=XLA, **over)


def _params(cfg):
    return models.init(jax.random.PRNGKey(0), cfg)


def _prompts(cfg, lengths, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=ln) for ln in lengths]


def test_submit_accepts_prompt_equal_to_capacity():
    """A prompt of exactly ring capacity is admissible (the bucket list
    is topped up to capacity) and must retire on its prefill token —
    pos has reached the last position the cache can hold."""
    cfg = _cfg()
    eng = ServingEngine(_params(cfg), cfg, slots=2, capacity=16,
                        buckets=(8,))
    (full,) = _prompts(cfg, [16])
    eng.submit(Request(prompt=full, max_new_tokens=4))
    results = []
    while eng._queue or any(r is not None for r in eng._active):
        results.extend(eng.step())
    assert len(results) == 1
    assert len(results[0].tokens) == 1          # prefill token only
    assert eng.decode_steps == 0                # no wrapped tick


def test_admission_fixpoint_drains_whole_waves():
    """Every slot freed by single-token requests refills within the SAME
    step(): 3 waves x 2 slots of max_new=1 requests finish with zero
    decode ticks, in one step call."""
    cfg = _cfg()
    eng = ServingEngine(_params(cfg), cfg, slots=2, capacity=32,
                        buckets=(8,))
    for p in _prompts(cfg, [5, 6, 7, 5, 6, 7]):
        eng.submit(Request(prompt=p, max_new_tokens=1))
    finished = eng.step()
    assert len(finished) == 6                   # all waves drained
    assert eng.decode_steps == 0
    assert not eng._queue and not any(eng._active)


def test_mixed_single_and_multi_token_admission():
    """Single-token rows cycle through their slot while the long row
    keeps decoding — the fixpoint never starves either kind."""
    cfg = _cfg()
    eng = ServingEngine(_params(cfg), cfg, slots=2, capacity=32,
                        buckets=(8,))
    prompts = _prompts(cfg, [5, 5, 5, 5, 5])
    budgets = [6, 1, 1, 1, 1]
    results = eng.run([Request(prompt=p, max_new_tokens=m)
                       for p, m in zip(prompts, budgets)])
    by_rid = {r.rid: r for r in results}
    assert [len(by_rid[i].tokens) for i in range(5)] == budgets
    assert eng.decode_steps == 5                # only the 6-token row ticks


def test_write_slots_on_vlm_image_state():
    """Slot surgery over a vlm prefill that consumed real image embeds:
    the written slot carries the image-conditioned cache, others stay."""
    cfg = _cfg("phi-3-vision-4.2b")
    params = _params(cfg)
    img = np.random.default_rng(3).standard_normal((20, 20, 3))
    emb = vision.encode_image(cfg, img)
    n = cfg.n_image_tokens
    prompt = np.concatenate([np.zeros(n, np.int32),
                             _prompts(cfg, [4])[0]])
    mask = (np.arange(len(prompt)) < n)[None]
    st = models.init_decode_state(cfg, 3, 32)
    _, sub = models.prefill(params, cfg, jnp.asarray(prompt)[None], 32,
                            image_embeds=jnp.asarray(emb)[None],
                            image_mask=jnp.asarray(mask))
    st2 = models.write_slots(st, sub, [1])
    assert st2.pos.tolist() == [0, len(prompt), 0]
    for (path, before), after, new in zip(
            jax.tree_util.tree_leaves_with_path(st.cache),
            jax.tree.leaves(st2.cache), jax.tree.leaves(sub.cache)):
        ax = models._leaf_batch_axis(path)
        before, after, new = (np.asarray(a) for a in (before, after, new))
        idx = [slice(None)] * before.ndim
        idx[ax] = 1
        np.testing.assert_array_equal(after[tuple(idx)],
                                      np.take(new, 0, axis=ax))
        idx[ax] = 0
        np.testing.assert_array_equal(after[tuple(idx)], before[tuple(idx)])


def test_write_slots_on_conv_state_is_pos_only():
    """The conv family's decode state is pure bookkeeping: an empty cache
    pytree plus per-row pos — write_slots must handle the no-leaves
    case."""
    cfg = dataclasses.replace(ALEXNET_FAITHFUL_SMOKE, kernels=XLA)
    st = models.init_decode_state(cfg, 4, 8)
    sub = models.DecodeState(cache={}, pos=jnp.asarray([1, 1], jnp.int32))
    st2 = models.write_slots(st, sub, [0, 3])
    assert st2.cache == {}
    assert st2.pos.tolist() == [1, 0, 0, 1]
