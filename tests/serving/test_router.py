"""Router + multi-process tier edge cases (docs/serving.md): bursty
admission spreads over instances, an instance dying mid-request gets its
work re-placed on a peer, drain hands live streams to peers with zero
dropped requests, and a draining instance rejects new admissions.

These tests spawn REAL worker processes (`python -m repro.launch.serve
--role engine`) — each one imports jax and compiles, so the module keeps
the process count minimal and shares a tier across tests."""
import time

import jax
import numpy as np
import pytest

from repro import models
from repro.launch import serve
from repro.serving import Request, Router, ServingEngine
from repro.serving.tier import spawn_worker

ARGV = ["--arch", "olmo-1b", "--smoke", "--layers", "2", "--d-model", "64",
        "--slots", "2", "--capacity", "48"]


def _reqs(n=6, new=16, seed=3):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, 512, size=6), max_new_tokens=new)
            for _ in range(n)]


def _reference_streams(n=6, new=16, seed=3):
    """What the tier must emit: the same engine the workers build
    (serve.build_cfg on the same argv), run in-process."""
    args = serve.build_parser().parse_args(ARGV)
    cfg = serve.build_cfg(args)
    params = models.init(jax.random.PRNGKey(args.seed), cfg)
    eng = ServingEngine(params, cfg, slots=6, capacity=args.capacity,
                        seed=args.seed)
    return sorted(tuple(r.tokens) for r in eng.run(_reqs(n, new, seed)))


@pytest.fixture(scope="module")
def tier():
    insts = [spawn_worker("engine", ARGV, name=f"eng{i}") for i in range(2)]
    for h in insts:
        h.connect()
    yield insts
    for h in insts:
        h.shutdown()


def test_burst_spreads_over_instances(tier):
    """Bursty admission fairness: 8 requests into 2x2-slot instances
    must land on BOTH instances — least-loaded ranking refreshes stats
    every placement, so no instance starves while another queues."""
    r = Router(tier)
    for q in _reqs(n=8, new=8, seed=11):
        r.submit(q)
    res = r.run_until_done(timeout=300)
    assert len(res) == 8
    st = r.stats()["instances"]
    stepped = [n for n, s in st.items() if s["decode_steps"] > 0]
    assert len(stepped) == 2, f"one instance starved: {st}"


def test_drain_handoff_zero_drops_byte_identical(tier):
    """The tentpole acceptance check, across real processes: drain an
    instance mid-stream, its slots replay into the peer, every request
    finishes, and the union of token streams is byte-identical to an
    uninterrupted single-engine run (greedy, positional sampling)."""
    r = Router(tier)
    for q in _reqs():
        r.submit(q)
    time.sleep(0.5)                          # let streams get mid-flight
    r.drain_instance(tier[0])
    res = r.run_until_done(timeout=300)
    assert len(res) == 6                     # zero dropped requests
    assert sorted(tuple(x["tokens"]) for x in res) == _reference_streams()
    # the drained instance now rejects admissions with a typed status
    status, _ = tier[0].call("submit", {"prompt": [1, 2],
                                        "max_new_tokens": 2, "rid": 99})
    assert status == "draining"
    tier[0].drained = True                   # later tests must skip it


def test_instance_death_retries_on_peer():
    """Kill a worker mid-request: the router marks it dead, re-places
    its outstanding requests on the peer from scratch (at-least-once),
    and every request still finishes."""
    insts = [spawn_worker("engine", ARGV, name=f"mort{i}") for i in range(2)]
    try:
        for h in insts:
            h.connect()
        r = Router(insts)
        for q in _reqs():
            r.submit(q)
        time.sleep(0.3)                      # some requests placed + ticking
        insts[0].proc.kill()
        res = r.run_until_done(timeout=300)
        assert len(res) == 6
        assert sorted(tuple(x["tokens"]) for x in res) \
            == _reference_streams()
        assert r.stats()["dead"] == ["mort0"]
    finally:
        for h in insts:
            h.shutdown()
