"""Continuous-batching engine correctness: scheduling must never change
WHAT gets generated — only when.  Every output token is diffed against a
sequential per-request reference decode; plus slot reuse, bucketed
compile bounds, sampling, and the 2-device mesh path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _subproc import run_child
from repro import models
from repro.configs import ARCHS, reduced
from repro.kernels.common import KernelPolicy
from repro.serving import Request, ServingEngine, sample

XLA = KernelPolicy(backend="xla")


def _cfg(arch="olmo-1b", **over):
    return dataclasses.replace(reduced(ARCHS[arch]), kernels=XLA, **over)


def _params(cfg):
    return models.init(jax.random.PRNGKey(0), cfg)


def _prompts(cfg, lengths, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=ln) for ln in lengths]


def _reference_greedy(params, cfg, prompt, n, capacity):
    lg, st = models.prefill(params, cfg, jnp.asarray(prompt)[None],
                            capacity)
    cur = jnp.argmax(lg[:, -1:], -1).astype(jnp.int32)
    out = [int(cur[0, 0])]
    for _ in range(n - 1):
        lg, st = models.decode_step(params, cfg, st, cur)
        cur = jnp.argmax(lg[:, 0], -1).astype(jnp.int32)[:, None]
        out.append(int(cur[0, 0]))
    return out


@pytest.mark.parametrize("arch", ["olmo-1b", "rwkv6-7b",
                                  "recurrentgemma-9b"])
def test_engine_matches_sequential_greedy(arch, rng):
    """More requests than slots, heterogeneous prompt lengths and budgets:
    every request's tokens == its standalone greedy decode."""
    cfg = _cfg(arch)
    if arch == "recurrentgemma-9b":
        cfg = dataclasses.replace(cfg, n_layers=3)
    params = _params(cfg)
    lengths = [5, 9, 13, 7, 11, 3]
    budgets = [6, 3, 8, 5, 2, 7]
    eng = ServingEngine(params, cfg, slots=2, capacity=64,
                        buckets=(8, 16))
    results = eng.run([Request(prompt=p, max_new_tokens=m)
                       for p, m in zip(_prompts(cfg, lengths), budgets)])
    assert len(results) == len(lengths)
    assert eng.prefill_compiles <= 2          # bounded by the bucket list
    for r in sorted(results, key=lambda r: r.rid):
        ref = _reference_greedy(params, cfg,
                                _prompts(cfg, lengths)[r.rid],
                                budgets[r.rid], 64)
        assert r.tokens == ref, (r.rid, r.tokens, ref)
        assert r.t_first >= r.t_submit and r.t_done >= r.t_first


def test_slot_reuse_and_continuous_admission(rng):
    """With 1 slot and 3 requests the engine must serialize through the
    same slot — state surgery cannot leak between requests."""
    cfg = _cfg()
    params = _params(cfg)
    prompts = _prompts(cfg, [6, 6, 6])
    eng = ServingEngine(params, cfg, slots=1, capacity=32, buckets=(8,))
    results = eng.run([Request(prompt=p, max_new_tokens=4)
                       for p in prompts])
    for r in sorted(results, key=lambda r: r.rid):
        assert r.tokens == _reference_greedy(params, cfg, prompts[r.rid],
                                             4, 32)


def test_engine_interleaves_instead_of_blocking(rng):
    """One long request must not head-of-line-block the short ones: with
    2 slots, 1 long + 4 short requests finish in far fewer ticks than
    static batching would need (the ≥2x acceptance property)."""
    cfg = _cfg()
    params = _params(cfg)
    reqs = [Request(prompt=p, max_new_tokens=m)
            for p, m in zip(_prompts(cfg, [4] * 5), [24, 3, 3, 3, 3])]
    eng = ServingEngine(params, cfg, slots=2, capacity=64, buckets=(4,))
    eng.run(reqs)
    # static 2-wide batching: ceil-grouped [24,3],[3,3],[3] -> 24+3+3=30
    # ticks of which only 36/60 row-ticks are useful; the engine retires
    # and refills slots, so it needs ~max(24, 3*4/1) ~ 24 ticks
    assert eng.decode_steps <= 26, eng.decode_steps


def test_temperature_topk_sampling(rng):
    """top-k sampling stays inside each row's k best logits; greedy is
    the temperature=0 special case of the same jitted step."""
    logits = jnp.asarray([[0.0, 5.0, 4.0, -1.0],
                          [9.0, 0.1, 0.2, 0.3]])
    assert sample(rng, logits).tolist() == [1, 0]
    for seed in range(8):
        t = sample(jax.random.PRNGKey(seed), logits, temperature=1.0,
                   top_k=2)
        assert t[0] in (1, 2) and t[1] in (0, 3)
    # engine runs end-to-end with sampling on
    cfg = _cfg()
    params = _params(cfg)
    eng = ServingEngine(params, cfg, slots=2, capacity=32, buckets=(8,),
                        temperature=0.8, top_k=8, seed=3)
    res = eng.run([Request(prompt=p, max_new_tokens=4)
                   for p in _prompts(cfg, [5, 7, 6])])
    assert sorted(len(r.tokens) for r in res) == [4, 4, 4]


def test_capacity_retires_requests(rng):
    """A request whose generation would overrun the ring capacity is
    retired at the boundary instead of silently attending to a wrapped
    (overwritten) cache."""
    cfg = _cfg()
    params = _params(cfg)
    eng = ServingEngine(params, cfg, slots=1, capacity=12, buckets=(8,))
    (res,) = eng.run([Request(prompt=_prompts(cfg, [8])[0],
                              max_new_tokens=100)])
    # pos may reach capacity: prompt 8 + first token + 4 decode ticks
    assert len(res.tokens) == 5, res.tokens


def test_full_ring_prompt_never_decodes_a_wrapped_tick(rng):
    """A prompt that fills the ring exactly must retire on its prefill
    token — one more decode tick would write slot pos%W over prompt
    token 0 and silently window the context."""
    cfg = _cfg()
    params = _params(cfg)
    eng = ServingEngine(params, cfg, slots=1, capacity=16, buckets=(16,))
    (res,) = eng.run([Request(prompt=_prompts(cfg, [16])[0],
                              max_new_tokens=8)])
    assert len(res.tokens) == 1          # prefill token only
    assert eng.decode_steps == 0


def test_freed_slot_refills_within_the_same_tick(rng):
    """A single-token request must not cost a decode tick: its slot is
    retired and re-admitted before the tick runs, so 3 one-token
    requests + 1 normal one need only the normal one's ticks."""
    cfg = _cfg()
    params = _params(cfg)
    prompts = _prompts(cfg, [4, 4, 4, 4])
    eng = ServingEngine(params, cfg, slots=1, capacity=32, buckets=(4,))
    res = eng.run([Request(prompt=p, max_new_tokens=m)
                   for p, m in zip(prompts, [1, 1, 1, 4])])
    assert sorted(len(r.tokens) for r in res) == [1, 1, 1, 4]
    assert eng.decode_steps == 3         # only the 4-token request decodes


def test_results_are_pruned_after_retirement(rng):
    cfg = _cfg()
    params = _params(cfg)
    eng = ServingEngine(params, cfg, slots=1, capacity=32, buckets=(4,))
    res = eng.run([Request(prompt=p, max_new_tokens=2)
                   for p in _prompts(cfg, [4, 4, 4])])
    assert len(res) == 3
    assert eng._results == {}            # long-lived engines stay bounded


def test_write_slots_roundtrip(rng):
    """Slot surgery: writing a 1-row state into slot k changes exactly
    slot k (stacked AND unstacked cache leaves)."""
    cfg = dataclasses.replace(_cfg("recurrentgemma-9b"), n_layers=4)
    params = _params(cfg)
    toks, = _prompts(cfg, [10])
    st = models.init_decode_state(cfg, 3, 16)
    _, sub = models.prefill(params, cfg, jnp.asarray(toks)[None], 16)
    st2 = models.write_slots(st, sub, [1])
    assert st2.pos.tolist() == [0, 10, 0]
    for a, b, s in zip(jax.tree_util.tree_leaves_with_path(st.cache),
                       jax.tree.leaves(st2.cache),
                       jax.tree.leaves(sub.cache)):
        path, before = a
        ax = models._leaf_batch_axis(path)
        before, after = np.asarray(before), np.asarray(b)
        new = np.asarray(s)
        idx = [slice(None)] * before.ndim
        idx[ax] = 1
        np.testing.assert_array_equal(after[tuple(idx)],
                                      np.take(new, 0, axis=ax))
        idx[ax] = 0
        np.testing.assert_array_equal(after[tuple(idx)],
                                      before[tuple(idx)])


def test_engine_rejects_bad_prompts_at_submit(rng):
    cfg = _cfg()
    eng = ServingEngine(_params(cfg), cfg, slots=1, capacity=16,
                        buckets=(8,))
    # the bucket list is topped up to capacity: any prompt that fits the
    # ring is admissible (here via the implicit 16 bucket)...
    assert eng.buckets == (8, 16)
    eng.submit(Request(prompt=_prompts(cfg, [12])[0], max_new_tokens=2))
    # ...but beyond the ring capacity there is nowhere to put it
    with pytest.raises(ValueError, match="exceeds the largest bucket"):
        eng.submit(Request(prompt=_prompts(cfg, [20])[0], max_new_tokens=2))
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(prompt=[], max_new_tokens=2))


def test_mesh_engine_matches_single_device():
    """2-device replica mesh: slots sharded over 'data', params
    replicated — identical tokens to the 1-device engine."""
    run_child("""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro import models
from repro.configs import ARCHS, reduced
from repro.kernels.common import KernelPolicy
from repro.launch.mesh import make_replica_mesh
from repro.serving import Request, ServingEngine

cfg = dataclasses.replace(reduced(ARCHS['gemma-7b-swa']), sliding_window=16,
                          kernels=KernelPolicy(backend='xla'))
params = models.init(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg.vocab_size, size=ln) for ln in (5, 9, 13, 7)]

def run(mesh):
    eng = ServingEngine(params, cfg, slots=4, capacity=32, buckets=(16,),
                        mesh=mesh)
    res = eng.run([Request(prompt=p, max_new_tokens=5) for p in prompts])
    return {r.rid: r.tokens for r in res}

a = run(make_replica_mesh(2))
b = run(None)
assert a == b, (a, b)
print('mesh-engine OK')
""", devices=2)
