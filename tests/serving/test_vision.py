"""Vision through the continuous-batching engine: conv-family image
classification rides the SAME admission loop as token generation (one
batched compiled forward per admission wave, zero decode ticks), and vlm
requests carrying raw pixels prefill exactly like the explicit
image-embeds API."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _subproc import run_child
from repro import models
from repro.configs import ALEXNET_FAITHFUL_SMOKE, ARCHS, reduced
from repro.kernels.common import KernelPolicy
from repro.models import alexnet, vision
from repro.serving import Request, ServingEngine

XLA = KernelPolicy(backend="xla")
CONV_CFG = dataclasses.replace(ALEXNET_FAITHFUL_SMOKE, kernels=XLA)


def _images(cfg, n, seed=0):
    rs = np.random.default_rng(seed)
    return [rs.standard_normal((cfg.image_size, cfg.image_size,
                                cfg.in_channels)) for _ in range(n)]


def test_conv_engine_classifies_matching_argmax():
    """10 requests through 4 slots: every result is the standalone
    argmax class, in ONE token, with NO decode ticks spent."""
    cfg = CONV_CFG
    params = models.init(jax.random.PRNGKey(0), cfg)
    imgs = _images(cfg, 10)
    eng = ServingEngine(params, cfg, slots=4, capacity=32)
    results = eng.run([Request(image=im) for im in imgs])
    assert len(results) == 10
    assert eng.decode_steps == 0          # classification never decodes
    ref = np.asarray(jnp.argmax(alexnet.forward(
        params, cfg, jnp.asarray(np.stack(imgs), jnp.float32)), axis=-1))
    for r in results:
        assert r.tokens == [int(ref[r.rid])], (r.rid, r.tokens)
        assert r.prompt_len == 0
        assert r.t_first >= r.t_submit and r.t_done >= r.t_first
    # bucketed compiles: 4-slot waves pad to the pow-2 image buckets
    assert eng._buckets_used <= {("img", 1), ("img", 2), ("img", 4)}


def test_conv_engine_rejects_bad_images():
    cfg = CONV_CFG
    eng = ServingEngine(models.init(jax.random.PRNGKey(0), cfg), cfg,
                        slots=2, capacity=16)
    with pytest.raises(ValueError, match="image of shape"):
        eng.submit(Request(image=np.zeros((3, 3, 3))))
    with pytest.raises(ValueError, match="image of shape"):
        eng.submit(Request(prompt=[1, 2, 3]))      # tokens are not images


def test_conv_engine_state_stays_consistent():
    """write_slots on the empty conv cache: pos moves, nothing breaks,
    and a second wave reuses the slots cleanly."""
    cfg = CONV_CFG
    params = models.init(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, slots=2, capacity=16)
    first = eng.run([Request(image=im) for im in _images(cfg, 2, seed=1)])
    second = eng.run([Request(image=im) for im in _images(cfg, 2, seed=2)])
    assert len(first) == 2 and len(second) == 2
    assert eng._results == {}             # retired results are pruned
    assert eng.state.cache == {}          # conv carries no decode state


def test_vlm_raw_image_matches_explicit_embeds():
    """Request(image=...) == the pre-existing explicit-embeds API: same
    encoder, same prompt layout, same greedy tokens."""
    cfg = dataclasses.replace(reduced(ARCHS["phi-3-vision-4.2b"]),
                              kernels=XLA)
    params = models.init(jax.random.PRNGKey(0), cfg)
    rs = np.random.default_rng(0)
    img = rs.standard_normal((24, 24, 3))
    prompt = rs.integers(1, cfg.vocab_size, size=6)

    eng_a = ServingEngine(params, cfg, slots=2, capacity=64)
    (ra,) = eng_a.run([Request(prompt=prompt.copy(), image=img,
                               max_new_tokens=5)])

    n = cfg.n_image_tokens
    p2 = np.concatenate([np.zeros(n, np.int32), prompt])
    eng_b = ServingEngine(params, cfg, slots=2, capacity=64)
    (rb,) = eng_b.run([Request(prompt=p2,
                               image_embeds=vision.encode_image(cfg, img),
                               image_mask=np.arange(len(p2)) < n,
                               max_new_tokens=5)])
    assert ra.tokens == rb.tokens
    assert ra.prompt_len == rb.prompt_len == n + len(prompt)


def test_encode_image_contract():
    cfg = reduced(ARCHS["phi-3-vision-4.2b"])
    img = np.random.default_rng(1).standard_normal((17, 23, 3))
    emb = vision.encode_image(cfg, img)
    assert emb.shape == (cfg.n_image_tokens, cfg.d_model)
    assert emb.dtype == np.float32
    # deterministic: admission-time encoding is reproducible
    np.testing.assert_array_equal(emb, vision.encode_image(cfg, img))
    # distinct images produce distinct embeddings
    other = vision.encode_image(cfg, img + 1.0)
    assert not np.allclose(emb, other)
    # grayscale input is accepted
    assert vision.encode_image(cfg, img[..., 0]).shape == emb.shape
    with pytest.raises(ValueError, match="image"):
        vision.encode_image(cfg, np.zeros((4,)))


def test_conv_family_has_no_decode_path():
    """The decode contract stays honest: conv exposes an (empty) decode
    state for slot surgery, but prefill/decode_step still refuse."""
    cfg = CONV_CFG
    st = models.init_decode_state(cfg, 3, 16)
    assert st.cache == {} and st.pos.tolist() == [0, 0, 0]
    st2 = models.write_slots(
        st, models.DecodeState(cache={}, pos=jnp.ones((1,), jnp.int32)),
        [2])
    assert st2.pos.tolist() == [0, 0, 1]
    with pytest.raises(NotImplementedError):
        models.prefill(None, cfg, jnp.zeros((1, 4), jnp.int32), 16)
    with pytest.raises(NotImplementedError):
        models.decode_step(None, cfg, st, jnp.zeros((1, 1), jnp.int32))


def test_mesh_conv_engine_matches_single_device():
    """2-device replica mesh serves images identically to 1 device."""
    run_child("""
import dataclasses
import jax, numpy as np
from repro import models
from repro.configs import ALEXNET_FAITHFUL_SMOKE
from repro.kernels.common import KernelPolicy
from repro.launch.mesh import make_replica_mesh
from repro.serving import Request, ServingEngine

cfg = dataclasses.replace(ALEXNET_FAITHFUL_SMOKE,
                          kernels=KernelPolicy(backend='xla'))
params = models.init(jax.random.PRNGKey(0), cfg)
rs = np.random.default_rng(0)
imgs = [rs.standard_normal((cfg.image_size, cfg.image_size,
                            cfg.in_channels)) for _ in range(6)]

def run(mesh):
    eng = ServingEngine(params, cfg, slots=2, capacity=16, mesh=mesh)
    res = eng.run([Request(image=im) for im in imgs])
    assert eng.decode_steps == 0
    return {r.rid: r.tokens for r in res}

a = run(make_replica_mesh(2))
b = run(None)
assert a == b, (a, b)
print('mesh-vision OK')
""", devices=2)
