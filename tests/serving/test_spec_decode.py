"""Speculative decoding correctness: greedy spec output must be token-
identical to the plain engine whatever the draft proposes — acceptance
only changes how many dispatches it takes.  Covers dense and hybrid
targets, an adversarial (random) draft that rejects nearly everything, a
self-draft that accepts everything, the γ=0 degenerate tick, draft/
target cache consistency after rejections, the decode_seq primitive the
whole thing is built on, and the 2-device mesh path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _subproc import run_child
from repro import models
from repro.configs import ARCHS, reduced
from repro.kernels.common import KernelPolicy
from repro.serving import Request, ServingEngine

XLA = KernelPolicy(backend="xla")


def _cfg(arch="olmo-1b", **over):
    cfg = dataclasses.replace(reduced(ARCHS[arch]), kernels=XLA, **over)
    if arch == "recurrentgemma-9b":
        cfg = dataclasses.replace(cfg, n_layers=3)   # attn + both rec kinds
    return cfg


def _reqs(cfg, seed=0, n=5, budget=8):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, size=ln),
                    max_new_tokens=budget)
            for ln in [5, 9, 3, 7, 11][:n]]


def _streams(results):
    return {r.rid: tuple(r.tokens) for r in results}


# ----------------------------------------------------- decode_seq unit ----

@pytest.mark.parametrize("arch", ["olmo-1b", "recurrentgemma-9b"])
def test_decode_seq_matches_sequential(arch):
    """decode_seq's logits == T sequential decode_step calls; commit_len
    = 0 leaves every state leaf bit-identical (the verify contract);
    commit_len = a advances exactly a tokens (the commit contract)."""
    cfg = _cfg(arch)
    params = models.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, size=(2, 6))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(2, 4)),
                       jnp.int32)
    _, st = models.prefill(params, cfg, jnp.asarray(prompt), 32)

    seq_logits = []
    ref = st
    for j in range(4):
        lg, ref = models.decode_step(params, cfg, ref, toks[:, j:j + 1])
        seq_logits.append(lg[:, 0])
    seq_logits = jnp.stack(seq_logits, 1)

    # verify: commit nothing — logits equal, state untouched
    lg0, st0 = models.decode_seq(params, cfg, st, toks, 0)
    np.testing.assert_allclose(lg0, seq_logits, atol=2e-4, rtol=2e-4)
    jax.tree.map(np.testing.assert_array_equal, st.cache, st0.cache)
    np.testing.assert_array_equal(st.pos, st0.pos)

    # commit: per-row lengths — pos advances by exactly commit_len
    _, st2 = models.decode_seq(params, cfg, st, toks,
                               jnp.asarray([3, 1], jnp.int32))
    np.testing.assert_array_equal(st2.pos, st.pos + jnp.asarray([3, 1]))
    # committed prefix must continue exactly like the sequential state
    ref2 = st
    for j in range(3):
        _, ref2 = models.decode_step(params, cfg, ref2, toks[:, j:j + 1])
    nxt = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(2, 1)), jnp.int32)
    lg_a, _ = models.decode_step(
        params, cfg,
        models.DecodeState(cache=st2.cache, pos=st2.pos), nxt)
    # row 0 committed 3 of the same tokens the sequential ref consumed
    lg_b, _ = models.decode_step(params, cfg, ref2, nxt)
    np.testing.assert_allclose(lg_a[0], lg_b[0], atol=2e-4, rtol=2e-4)


def test_decode_seq_rejects_encdec():
    cfg = _cfg("seamless-m4t-medium")
    params = models.init(jax.random.PRNGKey(0), cfg)
    st = models.init_decode_state(cfg, 1, 16)
    with pytest.raises(NotImplementedError, match="encdec"):
        models.decode_seq(params, cfg, st, jnp.zeros((1, 2), jnp.int32), 0)


# ------------------------------------------------------- engine parity ----

@pytest.mark.parametrize("arch", ["olmo-1b", "recurrentgemma-9b"])
def test_spec_greedy_identity(arch):
    """Adversarial draft (random weights, rejects nearly all) and
    self-draft (accepts all): both produce the plain engine's streams."""
    cfg = _cfg(arch)
    params = models.init(jax.random.PRNGKey(0), cfg)
    dparams = models.init(jax.random.PRNGKey(9), cfg)
    base = _streams(ServingEngine(params, cfg, slots=2, capacity=64,
                                  buckets=(16,)).run(_reqs(cfg)))

    adv = ServingEngine(params, cfg, slots=2, capacity=64, buckets=(16,),
                        draft_params=dparams, draft_cfg=cfg, spec_tokens=2)
    assert _streams(adv.run(_reqs(cfg))) == base
    assert adv.spec_accepted <= adv.spec_proposed

    own = ServingEngine(params, cfg, slots=2, capacity=64, buckets=(16,),
                        draft_params=params, draft_cfg=cfg, spec_tokens=3)
    assert _streams(own.run(_reqs(cfg))) == base
    # the draft IS the target: every proposal must be accepted, and the
    # engine must finish in fewer dispatches than token-at-a-time decode
    assert own.spec_proposed > 0
    assert own.spec_accepted == own.spec_proposed
    assert own.dispatches < adv.dispatches


def test_gamma_zero_degenerates_to_plain_tick():
    cfg = _cfg()
    params = models.init(jax.random.PRNGKey(0), cfg)
    dparams = models.init(jax.random.PRNGKey(9), cfg)
    plain = ServingEngine(params, cfg, slots=2, capacity=64, buckets=(16,))
    base = _streams(plain.run(_reqs(cfg)))
    eng = ServingEngine(params, cfg, slots=2, capacity=64, buckets=(16,),
                        draft_params=dparams, draft_cfg=cfg, spec_tokens=0)
    assert _streams(eng.run(_reqs(cfg))) == base
    assert eng.spec_proposed == 0 and eng.spec_accepted == 0
    assert eng.dispatches == plain.dispatches


def test_per_request_acceptance_accounting():
    cfg = _cfg()
    params = models.init(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, slots=2, capacity=64, buckets=(16,),
                        draft_params=params, draft_cfg=cfg, spec_tokens=3)
    results = eng.run(_reqs(cfg, n=4))
    for r in results:
        assert r.draft_proposed > 0
        assert r.draft_accepted == r.draft_proposed   # self-draft
        assert r.acceptance == 1.0
    assert sum(r.draft_proposed for r in results) == eng.spec_proposed


def test_draft_state_consistent_after_rejections():
    """After dispatches full of rejections, the draft's cache equals a
    sequential draft decode of the ACCEPTED stream — rejected proposals
    left no trace (the rollback-free commit property)."""
    cfg = _cfg()
    params = models.init(jax.random.PRNGKey(0), cfg)
    dparams = models.init(jax.random.PRNGKey(9), cfg)
    prompt = [3, 1, 4, 1, 5]
    eng = ServingEngine(params, cfg, slots=1, capacity=64, buckets=(16,),
                        draft_params=dparams, draft_cfg=cfg, spec_tokens=2)
    eng.submit(Request(prompt=prompt, max_new_tokens=16))
    for _ in range(3):
        eng.step()
    [req] = [r for r in eng._active if r is not None]
    emitted = eng._results[req.rid].tokens
    assert eng.spec_accepted < eng.spec_proposed      # rejections happened

    # both models consumed prompt + emitted[:-1]
    _, ref = models.prefill(params, cfg, jnp.asarray(prompt)[None], 64)
    _, dref = models.prefill(dparams, cfg, jnp.asarray(prompt)[None], 64)
    for t in emitted[:-1]:
        _, ref = models.decode_step(params, cfg, ref,
                                    jnp.asarray([[t]], jnp.int32))
        _, dref = models.decode_step(dparams, cfg, dref,
                                     jnp.asarray([[t]], jnp.int32))
    for eng_state, ref_state in ((eng.state, ref),
                                 (eng.draft_state, dref)):
        np.testing.assert_array_equal(eng_state.pos, ref_state.pos)
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(a, b, atol=2e-4),
            eng_state.cache, ref_state.cache)


def test_spec_gates():
    cfg = _cfg()
    params = models.init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="greedy"):
        ServingEngine(params, cfg, temperature=0.5, draft_params=params,
                      draft_cfg=cfg)
    with pytest.raises(ValueError, match="ticks"):
        ServingEngine(params, cfg, ticks_per_dispatch=2,
                      draft_params=params, draft_cfg=cfg)
    with pytest.raises(ValueError, match="BOTH"):
        ServingEngine(params, cfg, draft_params=params)
    ecfg = _cfg("seamless-m4t-medium")
    with pytest.raises(NotImplementedError):
        ServingEngine(models.init(jax.random.PRNGKey(0), ecfg), ecfg,
                      draft_params=params, draft_cfg=cfg)
    small = dataclasses.replace(cfg, vocab_size=cfg.vocab_size // 2)
    with pytest.raises(ValueError, match="vocab"):
        ServingEngine(params, cfg, draft_params=params, draft_cfg=small)


def test_spec_two_device_identity():
    """Greedy spec on a 2-device replica mesh == single-device plain."""
    run_child("""
import dataclasses, numpy as np, jax
from repro import models
from repro.configs import ARCHS, reduced
from repro.kernels.common import KernelPolicy
from repro.launch.mesh import make_replica_mesh
from repro.serving import Request, ServingEngine

cfg = dataclasses.replace(reduced(ARCHS["olmo-1b"]),
                          kernels=KernelPolicy(backend="xla"))
params = models.init(jax.random.PRNGKey(0), cfg)
dparams = models.init(jax.random.PRNGKey(9), cfg)
rng = np.random.default_rng(0)
mk = lambda: [Request(prompt=rng2, max_new_tokens=6)
              for rng2 in [rng.integers(0, cfg.vocab_size, size=ln)
                           for ln in (5, 9, 3, 7)]]
reqs = mk()
plain = ServingEngine(params, cfg, slots=2, capacity=64, buckets=(16,))
base = {r.rid: tuple(r.tokens) for r in plain.run(list(reqs))}
rng = np.random.default_rng(0)
mesh = make_replica_mesh(jax.device_count())
eng = ServingEngine(params, cfg, slots=2, capacity=64, buckets=(16,),
                    mesh=mesh, draft_params=dparams, draft_cfg=cfg,
                    spec_tokens=2)
got = {r.rid: tuple(r.tokens) for r in eng.run(mk())}
assert got == base, (got, base)
print("mesh spec OK", eng.spec_proposed, eng.spec_accepted)
""", devices=2)
