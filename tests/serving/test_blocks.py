"""Shared-prefix block-pool serving: the table indirection must be
invisible in the streams (blocked == plain, on both kernel backends),
sharing must actually dedup pool blocks and skip repeat prefills, and
the manager's refcount/COW/eviction bookkeeping must hold under
exhaustion.  Plus direct parity for the table-indirected decode kernel
(xla gather vs Pallas scalar-prefetch path)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import ARCHS, reduced
from repro.kernels.common import KernelPolicy
from repro.kernels.decode_attention.decode_attention import (
    decode_attention_pallas)
from repro.kernels.decode_attention.ops import decode_attention
from repro.serving import BlockManager, Request, ServingEngine
from repro.serving.blocks import TRASH

XLA = KernelPolicy(backend="xla")


def _cfg(**over):
    over.setdefault("kernels", XLA)
    return dataclasses.replace(reduced(ARCHS["olmo-1b"]), **over)


def _params(cfg):
    return models.init(jax.random.PRNGKey(0), cfg)


def _streams(results):
    return {r.rid: tuple(r.tokens) for r in results}


def _reqs(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, size=ln),
                    max_new_tokens=m)
            for ln, m in zip([5, 9, 13, 7, 11, 3], [6, 3, 8, 5, 2, 7])]


# ---------------------------------------------------------- manager unit ----

def test_manager_refcounts_and_trash():
    m = BlockManager(num_blocks=9, block_size=4)
    adm = m.admit([1, 2, 3, 4, 5], n_k=2)    # 1 full chunk + tail
    assert len(adm.table) == 2 and TRASH not in adm.table
    assert adm.snapshot is not None          # tail snapshot planned
    m.finish(adm, first_token=7)
    assert m.in_use == 3                     # 2 table + 1 snapshot
    m.release(adm)
    assert m.in_use == 1                     # snapshot stays registered
    # freed chunk block left the hash index with its refcount
    assert m.chunks == {}


def test_manager_prefix_sharing_and_prefill_once():
    m = BlockManager(num_blocks=32, block_size=4)
    a = m.admit(list(range(8)) + [9], n_k=4)          # 2 chunks + tail
    m.finish(a, first_token=42)
    # same 2-chunk prefix, different tail: shares both full chunks
    b = m.admit(list(range(8)) + [7, 7], n_k=4)
    assert b.first_token is None and b.n_shared == 2
    assert b.table[:2] == a.table[:2]
    # exact repeat: zero-forward admission + COW clone of the snapshot
    c = m.admit(list(range(8)) + [9], n_k=4)
    assert c.first_token == 42
    assert c.table[:2] == a.table[:2]
    assert c.cow == [(c.table[2], a.snapshot)]
    assert m.prefills_skipped == 1
    # refcounts: shared chunks held by a, b and c
    for blk in a.table[:2]:
        assert m.ref[blk] == 3


def test_manager_eviction_under_pressure():
    """Exhaustion first evicts cached prompts (snapshot blocks); only a
    truly full pool defers."""
    m = BlockManager(num_blocks=6, block_size=4)     # 5 usable
    a = m.admit([1, 2, 3, 4, 5], n_k=2)              # 2 + snapshot = 3
    m.finish(a, first_token=1)
    assert m.in_use == 3 and len(m.prompts) == 1
    # needs 3 (2 table + own snapshot), only 2 free: must evict a's
    # cached prompt (the snapshot block) to fit
    b = m.admit([9, 9], n_k=2)
    assert b is not None
    m.finish(b, first_token=2)
    assert len(m.prompts) == 1                       # b's registration only
    assert m.in_use == 5                             # pool full
    # nothing evictable covers 3 blocks: truly full -> defer (and the
    # failed attempt consumed the last cached prompt trying)
    assert m.admit([8, 8], n_k=2) is None
    assert len(m.prompts) == 0
    m.release(a)
    m.release(b)
    assert m.admit([8, 8], n_k=2) is not None


def test_manager_dedup_off():
    m = BlockManager(num_blocks=32, block_size=4, dedup=False)
    a = m.admit([1, 2, 3, 4, 5], n_k=2)
    m.finish(a, first_token=1)
    b = m.admit([1, 2, 3, 4, 5], n_k=2)
    assert b.first_token is None and b.n_shared == 0
    assert not (set(a.table) & set(b.table))
    assert m.prefills_skipped == 0


# ---------------------------------------------------------- engine level ----

@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_blocked_matches_plain(backend):
    """Block-table serving generates the plain engine's exact streams —
    with the xla gather path and the Pallas scalar-prefetch kernel."""
    cfg = _cfg(kernels=KernelPolicy(backend=backend))
    params = _params(cfg)
    base = _streams(ServingEngine(params, cfg, slots=2, capacity=64,
                                  buckets=(16,)).run(_reqs(cfg)))
    eng = ServingEngine(params, cfg, slots=2, capacity=64, buckets=(16,),
                        block_size=16)
    assert _streams(eng.run(_reqs(cfg))) == base


def test_sharing_skips_prefills_and_saves_blocks():
    """Same prompt admitted 4x: one prefill, three zero-forward
    admissions (COW tail clones), identical streams, and a lower pool
    high-water mark than the dedup-off control."""
    cfg = _cfg()
    params = _params(cfg)
    prompt = list(range(1, 40))          # 2 full 16-blocks + tail of 7
    mk = lambda: [Request(prompt=prompt, max_new_tokens=6)        # noqa: E731
                  for _ in range(4)]
    shared = ServingEngine(params, cfg, slots=4, capacity=64, buckets=(64,),
                           block_size=16)
    s_res = shared.run(mk())
    assert len({tuple(r.tokens) for r in s_res}) == 1
    assert shared.block_mgr.prefills_skipped == 3
    assert shared.prefill_compiles == 1

    private = ServingEngine(params, cfg, slots=4, capacity=64, buckets=(64,),
                            block_size=16, prefix_dedup=False)
    p_res = private.run(mk())
    assert {tuple(r.tokens) for r in p_res} == {tuple(s_res[0].tokens)}
    assert private.block_mgr.prefills_skipped == 0
    assert shared.block_mgr.peak < private.block_mgr.peak


def test_pool_exhaustion_defers_requests():
    """A pool that fits one row at a time still completes every request
    (deferred admissions run after retirements free blocks)."""
    cfg = _cfg()
    params = _params(cfg)
    eng = ServingEngine(params, cfg, slots=2, capacity=64, buckets=(16,),
                        block_size=16, num_blocks=64 // 16 + 2,
                        prefix_dedup=False)
    res = eng.run([Request(prompt=[5, 6, 7], max_new_tokens=5)
                   for _ in range(3)])
    assert len(res) == 3
    assert len({tuple(r.tokens) for r in res}) == 1


def test_undersized_pool_raises():
    cfg = _cfg()
    eng = ServingEngine(_params(cfg), cfg, slots=1, capacity=64,
                        buckets=(16,), block_size=16, num_blocks=3)
    eng.submit(Request(prompt=[1, 2], max_new_tokens=4))
    with pytest.raises(RuntimeError, match="block pool"):
        eng.step()


def test_blocked_gates():
    cfg = _cfg()
    params = _params(cfg)
    with pytest.raises(ValueError, match="multiple"):
        ServingEngine(params, cfg, capacity=60, block_size=16)
    with pytest.raises(ValueError, match="neither"):
        ServingEngine(params, cfg, capacity=64, block_size=16,
                      ticks_per_dispatch=4)
    swa = _cfg(sliding_window=32)
    with pytest.raises(NotImplementedError, match="full attention"):
        ServingEngine(_params(swa), swa, capacity=64, block_size=16)
    rec = dataclasses.replace(reduced(ARCHS["rwkv6-7b"]), kernels=XLA)
    with pytest.raises(NotImplementedError, match="family"):
        ServingEngine(_params(rec), rec, capacity=64, block_size=16)


# ---------------------------------------------------------- kernel level ----

@pytest.mark.parametrize("quant", [False, True])
def test_table_kernel_parity(quant):
    """decode_attention with a block table: Pallas (interpret) scalar-
    prefetch indirection == xla pool-gather reference, fp32 and int8."""
    rng = np.random.default_rng(0)
    b, n_k, bs, hkv, g, hd = 3, 4, 8, 2, 2, 32
    nb = 1 + b * n_k                       # trash + one block per table slot
    q = jnp.asarray(rng.standard_normal((b, hkv, g, hd)), jnp.float32)
    pos = jnp.asarray([5, 17, 31], jnp.int32)
    kw = {}
    if quant:
        k = jnp.asarray(rng.integers(-127, 127, (nb, bs, hkv, hd)), jnp.int8)
        v = jnp.asarray(rng.integers(-127, 127, (nb, bs, hkv, hd)), jnp.int8)
        kw["k_scale"] = jnp.asarray(
            rng.uniform(0.01, 0.1, (nb, bs, hkv)), jnp.float32)
        kw["v_scale"] = jnp.asarray(
            rng.uniform(0.01, 0.1, (nb, bs, hkv)), jnp.float32)
    else:
        k = jnp.asarray(rng.standard_normal((nb, bs, hkv, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((nb, bs, hkv, hd)), jnp.float32)
    # distinct blocks per row, deliberately scrambled order
    table = jnp.asarray(rng.permutation(np.arange(1, 1 + b * n_k))
                        .reshape(b, n_k), jnp.int32)
    ref = decode_attention(q, k, v, pos, impl="xla", scale=hd ** -0.5,
                           table=table, **kw)
    got = decode_attention_pallas(q, k, v, pos, scale=hd ** -0.5,
                                  table=table, interpret=True, **kw)
    np.testing.assert_allclose(got, ref, atol=2e-4, rtol=2e-4)
    # windowed reads through the table too
    refw = decode_attention(q, k, v, pos, impl="xla", scale=hd ** -0.5,
                            window=16, table=table, **kw)
    gotw = decode_attention_pallas(q, k, v, pos, scale=hd ** -0.5,
                                   window=16, table=table, interpret=True,
                                   **kw)
    np.testing.assert_allclose(gotw, refw, atol=2e-4, rtol=2e-4)
