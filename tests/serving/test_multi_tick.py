"""Multi-tick decode dispatches: K device-resident ticks per host sync
must change WHEN the host sees tokens, never WHAT is generated.  Streams
are diffed across K; the single device->host transfer per dispatch is
counted through the ``engine._to_host`` hook; the dispatch jaxpr is
checked to actually contain a length-K scan (not K unrolled syncs)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro import models
from repro.configs import ARCHS, reduced
from repro.kernels.common import KernelPolicy
from repro.serving import Request, ServingEngine
from repro.serving import engine as engine_mod

XLA = KernelPolicy(backend="xla")


def _cfg(arch="olmo-1b", **over):
    return dataclasses.replace(reduced(ARCHS[arch]), kernels=XLA, **over)


def _params(cfg):
    return models.init(jax.random.PRNGKey(0), cfg)


def _reqs(cfg, seed=0):
    rng = np.random.default_rng(seed)
    lengths = [5, 9, 13, 7, 11, 3]
    budgets = [6, 3, 8, 5, 2, 7]
    return [Request(prompt=rng.integers(0, cfg.vocab_size, size=ln),
                    max_new_tokens=m)
            for ln, m in zip(lengths, budgets)]


def _streams(results):
    return {r.rid: tuple(r.tokens) for r in results}


def test_stream_identity_across_ticks():
    """K in {1, 4, 8}: identical tokens per request, fewer dispatches."""
    cfg = _cfg()
    params = _params(cfg)
    base = None
    for k in (1, 4, 8):
        eng = ServingEngine(params, cfg, slots=2, capacity=64,
                            buckets=(16,), ticks_per_dispatch=k)
        got = _streams(eng.run(_reqs(cfg)))
        assert eng.decode_steps == eng.dispatches * k
        if base is None:
            base, base_dispatches = got, eng.dispatches
        else:
            assert got == base, f"K={k} changed the generated tokens"
            assert eng.dispatches < base_dispatches
    assert len(base) == 6


def test_temperature_streams_identical_across_ticks():
    """Positional fold_in sampling: the same (request, position) draws
    the same token whether ticks are batched 1, 4 or 8 at a time."""
    cfg = _cfg()
    params = _params(cfg)
    outs = []
    for k in (1, 4, 8):
        eng = ServingEngine(params, cfg, slots=2, capacity=64,
                            buckets=(16,), temperature=0.9, top_k=8,
                            seed=3, ticks_per_dispatch=k)
        outs.append(_streams(eng.run(_reqs(cfg))))
    assert outs[0] == outs[1] == outs[2]


def test_eos_retires_within_dispatch():
    """A row whose eos lands mid-block stops exactly where K=1 stops —
    retirement latency is bounded by the dispatch, not visible in the
    stream."""
    cfg = _cfg()
    params = _params(cfg)
    probe = ServingEngine(params, cfg, slots=1, capacity=64, buckets=(16,))
    [res] = probe.run([Request(prompt=[3, 1, 4, 1, 5], max_new_tokens=10)])
    assert len(res.tokens) == 10
    eos = res.tokens[4]                   # cut the stream mid-way
    for k in (1, 4, 8):
        eng = ServingEngine(params, cfg, slots=1, capacity=64, buckets=(16,),
                            eos_id=eos, ticks_per_dispatch=k)
        [r] = eng.run([Request(prompt=[3, 1, 4, 1, 5], max_new_tokens=10)])
        assert r.tokens == res.tokens[:res.tokens.index(eos) + 1]


def test_one_host_transfer_per_dispatch(monkeypatch):
    """The decode loop syncs device->host EXACTLY once per dispatch, on
    the one packed (2, slots, K) array."""
    cfg = _cfg()
    params = _params(cfg)
    calls = []
    real = engine_mod._to_host
    monkeypatch.setattr(engine_mod, "_to_host",
                        lambda x: (calls.append(np.shape(x)), real(x))[1])
    eng = ServingEngine(params, cfg, slots=2, capacity=64, buckets=(16,),
                        ticks_per_dispatch=4)
    eng.run(_reqs(cfg))
    assert len(calls) == eng.dispatches
    assert all(s == (2, 2, 4) for s in calls)     # (2, slots, K) packed


def _find_scans(jaxpr, out):
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "scan":
            out.append(eqn.params["length"])
        for v in eqn.params.values():
            if hasattr(v, "jaxpr"):
                _find_scans(v.jaxpr, out)
    return out


@pytest.mark.parametrize("k", [4, 8])
def test_dispatch_is_one_fused_scan(k):
    """The compiled dispatch contains a single top-level length-K scan —
    the K ticks are device-resident, not K host-driven steps."""
    cfg = _cfg()
    params = _params(cfg)
    eng = ServingEngine(params, cfg, slots=2, capacity=64, buckets=(16,),
                        ticks_per_dispatch=k)
    jaxpr = jax.make_jaxpr(eng._decode)(params, eng.state, eng.last_tok,
                                        eng.slot_keys)
    assert k in _find_scans(jaxpr.jaxpr, [])


def test_bad_ticks_rejected():
    cfg = _cfg()
    with pytest.raises(ValueError, match="ticks_per_dispatch"):
        ServingEngine(_params(cfg), cfg, ticks_per_dispatch=0)
