"""Optimizers vs hand-computed updates; schedule properties."""
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.optim import schedules
from repro.optim.optimizers import adamw, apply_updates, sgd_momentum


def test_sgd_momentum_manual():
    opt = sgd_momentum(momentum=0.9, weight_decay=0.0)
    p = {"w": jnp.array([1.0, 2.0])}
    s = opt.init(p)
    g = {"w": jnp.array([0.5, -1.0])}
    u1, s = opt.update(g, s, p, 0.1)
    np.testing.assert_allclose(u1["w"], -0.1 * jnp.array([0.5, -1.0]))
    u2, s = opt.update(g, s, p, 0.1)
    # v2 = 0.9*g + g = 1.9 g
    np.testing.assert_allclose(u2["w"], -0.1 * 1.9 * jnp.array([0.5, -1.0]),
                               rtol=1e-6)


def test_weight_decay_pulls_to_zero():
    opt = sgd_momentum(momentum=0.0, weight_decay=0.1)
    p = {"w": jnp.array([1.0])}
    s = opt.init(p)
    u, _ = opt.update({"w": jnp.array([0.0])}, s, p, 1.0)
    assert float(u["w"][0]) < 0


def test_adamw_first_step_is_lr_sized():
    opt = adamw(weight_decay=0.0)
    p = {"w": jnp.array([0.0, 0.0])}
    s = opt.init(p)
    g = {"w": jnp.array([3.0, -0.01])}
    u, _ = opt.update(g, s, p, 1e-3)
    # bias-corrected first step ~ lr * sign(g)
    np.testing.assert_allclose(jnp.abs(u["w"]), 1e-3, rtol=1e-3)


def test_apply_updates_dtype_preserved():
    p = {"w": jnp.ones((2,), jnp.bfloat16)}
    out = apply_updates(p, {"w": jnp.full((2,), 0.5, jnp.float32)})
    assert out["w"].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out["w"], np.float32), 1.5)


@settings(max_examples=20, deadline=None)
@given(lr=st.floats(1e-5, 1.0), warmup=st.integers(1, 50),
       total=st.integers(60, 500))
def test_cosine_schedule_bounds(lr, warmup, total):
    f = schedules.cosine(lr, warmup, total)
    for step in [0, warmup // 2, warmup, (warmup + total) // 2, total]:
        v = float(f(step))
        assert 0.0 <= v <= lr * (1 + 1e-6)
    assert abs(float(f(warmup)) - lr) < lr * 0.1 + 1e-9


def test_wsd_three_phases():
    f = schedules.wsd(1.0, warmup=10, stable=50, decay=20)
    assert float(f(5)) == 0.5                      # warmup: linear
    assert abs(float(f(30)) - 1.0) < 1e-6          # stable: flat
    assert float(f(70)) < 0.5                      # decay
    assert float(f(80)) <= 0.011                   # floor ~ min_ratio


def test_step_decay():
    f = schedules.step_decay(1.0, decay_every=10, factor=0.1)
    assert abs(float(f(5)) - 1.0) < 1e-6
    assert abs(float(f(15)) - 0.1) < 1e-6
    assert abs(float(f(25)) - 0.01) < 1e-7


def test_schedules_monotone_after_peak():
    f = schedules.cosine(1.0, warmup=5, total=100)
    vals = [float(f(s)) for s in range(5, 100, 5)]
    assert all(a >= b - 1e-9 for a, b in zip(vals, vals[1:]))
