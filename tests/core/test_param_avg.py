"""Properties of the paper's parameter-averaging data parallelism.

The central theorem this reproduction rests on: with identical init and a
LINEAR optimizer (SGD+momentum — the paper's), exchange-and-average after
independent updates is exactly gradient averaging, which is why the paper's
65-epoch accuracy lands within 0.5% of the single-GPU baseline.  AdamW (a
nonlinear optimizer) breaks the equivalence — asserted as a counterexample.
Hypothesis drives the linear-model cases over random shapes/seeds.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import (exchange_average, init_grad_avg_state,
                        init_param_avg_state, make_grad_avg_step,
                        make_param_avg_step, replica_spread, replicate,
                        reshape_for_replicas, unreplicate)
from repro.optim import schedules
from repro.optim.optimizers import adamw, sgd_momentum


def linear_loss(params, batch):
    pred = batch["x"] @ params["w"] + params["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


def make_batches(n, b, din, dout, seed):
    rng = np.random.default_rng(seed)
    return [{"x": jnp.asarray(rng.normal(size=(b, din)), jnp.float32),
             "y": jnp.asarray(rng.normal(size=(b, dout)), jnp.float32)}
            for _ in range(n)]


def init_fn(din, dout):
    return lambda r: {"w": jax.random.normal(r, (din, dout)) * 0.3,
                      "b": jnp.zeros((dout,))}


@settings(max_examples=10, deadline=None)
@given(r=st.sampled_from([2, 4, 8]), din=st.integers(2, 12),
       dout=st.integers(1, 6), seed=st.integers(0, 10 ** 6),
       momentum=st.floats(0.0, 0.95), wd=st.floats(0.0, 0.01))
def test_param_avg_equals_grad_avg_sgd(r, din, dout, seed, momentum, wd):
    """The paper's method == gradient averaging, exactly, for SGD+momentum."""
    opt = sgd_momentum(momentum=momentum, weight_decay=wd)
    sch = schedules.constant(0.05)
    rng = jax.random.PRNGKey(seed % 2 ** 31)
    sp = init_param_avg_state(rng, init_fn(din, dout), opt, r)
    sg = init_grad_avg_state(rng, init_fn(din, dout), opt)
    pstep = jax.jit(make_param_avg_step(linear_loss, opt, sch))
    gstep = jax.jit(make_grad_avg_step(linear_loss, opt, sch))
    for batch in make_batches(6, 4 * r, din, dout, seed):
        sp, _ = pstep(sp, reshape_for_replicas(batch, r))
        sg, _ = gstep(sg, batch)
    for a, b in zip(jax.tree.leaves(sp.params), jax.tree.leaves(sg.params)):
        np.testing.assert_allclose(a[0], b, rtol=2e-5, atol=2e-5)


def test_adamw_breaks_equivalence():
    """Nonlinear optimizer: param averaging != grad averaging (sanity that
    the equivalence above is not vacuous)."""
    opt = adamw()
    sch = schedules.constant(0.05)
    rng = jax.random.PRNGKey(3)
    sp = init_param_avg_state(rng, init_fn(8, 4), opt, 4)
    sg = init_grad_avg_state(rng, init_fn(8, 4), opt)
    pstep = jax.jit(make_param_avg_step(linear_loss, opt, sch))
    gstep = jax.jit(make_grad_avg_step(linear_loss, opt, sch))
    for batch in make_batches(5, 16, 8, 4, 7):
        sp, _ = pstep(sp, reshape_for_replicas(batch, 4))
        sg, _ = gstep(sg, batch)
    diff = max(float(jnp.max(jnp.abs(a[0] - b))) for a, b in
               zip(jax.tree.leaves(sp.params), jax.tree.leaves(sg.params)))
    assert diff > 1e-4


@pytest.mark.parametrize("strategy", ["all_reduce", "ring", "pairwise"])
def test_strategies_compute_exact_mean(strategy):
    """ring / pairwise / all_reduce are numerically the same mean."""
    rng = jax.random.PRNGKey(0)
    tree = {"a": jax.random.normal(rng, (8, 3, 5)),
            "b": jax.random.normal(jax.random.fold_in(rng, 1), (8, 7))}
    out = exchange_average(tree, strategy)
    for k in tree:
        expected = jnp.broadcast_to(jnp.mean(tree[k], 0, keepdims=True),
                                    tree[k].shape)
        np.testing.assert_allclose(out[k], expected, rtol=1e-5, atol=1e-6)


def test_pairwise_requires_power_of_two():
    tree = {"a": jnp.ones((6, 2))}
    with pytest.raises(AssertionError):
        exchange_average(tree, "pairwise")


@settings(max_examples=8, deadline=None)
@given(r=st.sampled_from([2, 4]), seed=st.integers(0, 100))
def test_exchange_average_idempotent(r, seed):
    rng = jax.random.PRNGKey(seed)
    tree = {"w": jax.random.normal(rng, (r, 4, 3))}
    once = exchange_average(tree, "all_reduce")
    twice = exchange_average(once, "all_reduce")
    np.testing.assert_allclose(once["w"], twice["w"], rtol=1e-6, atol=1e-7)
    assert float(replica_spread(once)) < 1e-6


def test_local_sgd_sync_every():
    """sync_every=k: replicas drift for k-1 steps then re-coincide."""
    opt = sgd_momentum()
    sch = schedules.constant(0.05)
    rng = jax.random.PRNGKey(1)
    state = init_param_avg_state(rng, init_fn(6, 3), opt, 4)
    step = jax.jit(make_param_avg_step(linear_loss, opt, sch, sync_every=3))
    spreads = []
    for batch in make_batches(6, 8, 6, 3, 11):
        state, _ = step(state, reshape_for_replicas(batch, 4))
        spreads.append(float(replica_spread(state.params)))
    # steps 1,2 drift; step 3 syncs; etc.
    assert spreads[0] > 1e-6 and spreads[1] > 1e-6
    assert spreads[2] < 1e-6
    assert spreads[5] < 1e-6 and spreads[4] > 1e-6


def test_sync_every_one_equals_every_step_sync():
    opt = sgd_momentum()
    sch = schedules.constant(0.05)
    rng = jax.random.PRNGKey(2)
    s1 = init_param_avg_state(rng, init_fn(6, 3), opt, 2)
    s2 = init_param_avg_state(rng, init_fn(6, 3), opt, 2)
    stepa = jax.jit(make_param_avg_step(linear_loss, opt, sch, sync_every=1))
    stepb = jax.jit(make_param_avg_step(linear_loss, opt, sch))
    for batch in make_batches(4, 8, 6, 3, 13):
        s1, _ = stepa(s1, reshape_for_replicas(batch, 2))
        s2, _ = stepb(s2, reshape_for_replicas(batch, 2))
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)


def test_replicate_unreplicate_roundtrip():
    tree = {"x": jnp.arange(12.0).reshape(3, 4)}
    r = replicate(tree, 4)
    assert r["x"].shape == (4, 3, 4)
    back = unreplicate(r)
    np.testing.assert_allclose(back["x"], tree["x"])


def test_momentum_is_averaged_too():
    """Paper footnote 3: optimizer state participates in the exchange."""
    opt = sgd_momentum(momentum=0.9)
    sch = schedules.constant(0.05)
    rng = jax.random.PRNGKey(5)
    state = init_param_avg_state(rng, init_fn(6, 3), opt, 4)
    step = jax.jit(make_param_avg_step(linear_loss, opt, sch))
    batch = make_batches(1, 8, 6, 3, 17)[0]
    state, _ = step(state, reshape_for_replicas(batch, 4))
    assert float(replica_spread(state.opt_state)) < 1e-6


def test_microbatch_equivalent():
    """Gradient accumulation (microbatch>1) == one big batch, exactly (the
    loss is a mean and SGD is linear)."""
    opt = sgd_momentum(momentum=0.9)
    sch = schedules.constant(0.05)
    rng = jax.random.PRNGKey(7)
    s1 = init_param_avg_state(rng, init_fn(6, 3), opt, 2)
    s2 = init_param_avg_state(rng, init_fn(6, 3), opt, 2)
    step1 = jax.jit(make_param_avg_step(linear_loss, opt, sch))
    step4 = jax.jit(make_param_avg_step(linear_loss, opt, sch, microbatch=4))
    for batch in make_batches(3, 16, 6, 3, 23):
        rb = reshape_for_replicas(batch, 2)
        s1, l1 = step1(s1, rb)
        s2, l4 = step4(s2, rb)
        np.testing.assert_allclose(l1, l4, rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
