"""Buffer donation: jitted train steps with donate_argnums=0 must reuse
the TrainState buffers (in-place update) and stay numerically identical
to the non-donated step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (init_param_avg_state, make_param_avg_step,
                        reshape_for_replicas)
from repro.optim import schedules
from repro.optim.optimizers import sgd_momentum

R = 2


def _init(rng):
    k1, k2 = jax.random.split(rng)
    return {"w": jax.random.normal(k1, (8, 4)),
            "b": jax.random.normal(k2, (4,))}


def _loss(params, batch):
    y = batch["x"] @ params["w"] + params["b"]
    return jnp.mean((y - batch["y"]) ** 2)


def _state_and_batch():
    opt = sgd_momentum()
    state = init_param_avg_state(jax.random.PRNGKey(0), _init, opt, R)
    rng = np.random.default_rng(0)
    batch = reshape_for_replicas(
        {"x": jnp.asarray(rng.normal(size=(2 * R, 8)), jnp.float32),
         "y": jnp.asarray(rng.normal(size=(2 * R, 4)), jnp.float32)}, R)
    return opt, state, batch


def _donation_supported():
    f = jax.jit(lambda x: x + 1, donate_argnums=0)
    x = jnp.ones((8,))
    f(x)
    return x.is_deleted()


def test_donated_step_consumes_state_buffers():
    if not _donation_supported():
        pytest.skip("backend ignores buffer donation")
    opt, state, batch = _state_and_batch()
    step = jax.jit(make_param_avg_step(_loss, opt,
                                       schedules.constant(0.01)),
                   donate_argnums=0)
    old_leaves = jax.tree.leaves(state.params)
    new_state, _ = step(state, batch)
    jax.block_until_ready(new_state.params)
    # donation-error probe: every donated param buffer is gone
    assert all(x.is_deleted() for x in old_leaves)
    with pytest.raises(RuntimeError, match="deleted|donated"):
        _ = old_leaves[0] + 1
    # and the batch (not donated) is untouched
    assert not jax.tree.leaves(batch)[0].is_deleted()


def test_donated_step_matches_non_donated():
    opt, s1, batch = _state_and_batch()
    _, s2, _ = _state_and_batch()
    donated = jax.jit(make_param_avg_step(_loss, opt,
                                          schedules.constant(0.01)),
                      donate_argnums=0)
    plain = jax.jit(make_param_avg_step(_loss, opt,
                                        schedules.constant(0.01)))
    for _ in range(3):
        s1, l1 = donated(s1, batch)
        s2, l2 = plain(s2, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)


def test_donated_alexnet_pallas_step_runs():
    """End-to-end: donated step + fused pallas conv backend both engaged
    (the full tentpole path) still trains."""
    if not _donation_supported():
        pytest.skip("backend ignores buffer donation")
    from repro.configs import ALEXNET_SMOKE
    from repro.models import alexnet
    cfg = ALEXNET_SMOKE
    opt = sgd_momentum()
    state = init_param_avg_state(jax.random.PRNGKey(0),
                                 lambda r: alexnet.init(r, cfg), opt, 1)
    step = jax.jit(make_param_avg_step(
        lambda p, b: alexnet.loss_fn(p, cfg, b["images"], b["labels"],
                                     conv_backend="pallas"),
        opt, schedules.constant(0.01)), donate_argnums=0)
    rng = np.random.default_rng(0)
    sz = cfg.image_size
    batch = reshape_for_replicas(
        {"images": jnp.asarray(rng.normal(size=(4, sz, sz, 3)), jnp.float32),
         "labels": jnp.asarray(rng.integers(0, cfg.n_classes, 4),
                               jnp.int32)}, 1)
    old = jax.tree.leaves(state.params)
    state, l1 = step(state, batch)
    state, l2 = step(state, batch)
    assert all(x.is_deleted() for x in old)
    assert np.isfinite(float(l2)) and float(l2) < float(l1) + 1.0
