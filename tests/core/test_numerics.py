"""NumericsPolicy through the train-step builders (docs/numerics.md).

The acceptance bar, as tests:
  * the default/fp32 policy is INERT — bit-equal params and losses vs a
    step built with no policy at all, on both engines (the golden-trace
    suite holds the same line on real archs);
  * bf16 compute + fp32 master weights + dynamic loss scaling learns,
    and a poisoned (NaN) batch provably SKIPS the update — params
    untouched, scale halved, skip counted — then recovers on the next
    clean step;
  * the dynamic scale grows 2x after ``growth_interval`` clean steps and
    a static scale never moves;
  * master-weights-on-fp32 tracks plain fp32 to float tolerance (the
    bf16 param round-trip through ``apply_updates`` costs 1 ulp);
  * every engine variant honors the skip: vmap, scan, mesh (real
    collectives, subprocess-isolated devices), grad-avg.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _subproc import run_child

from repro.core.steps import (init_grad_avg_state, init_param_avg_state,
                              make_grad_avg_step, make_param_avg_step,
                              reshape_for_replicas)
from repro.numerics import NumericsPolicy, get_policy
from repro.optim.optimizers import for_numerics, get_optimizer


def init_fn(rng):
    k1, _ = jax.random.split(rng)
    return {"w": jax.random.normal(k1, (8, 4), jnp.float32) * 0.1,
            "b": jnp.zeros((4,), jnp.float32)}


def init_bf16(rng):
    return jax.tree.map(lambda p: p.astype(jnp.bfloat16), init_fn(rng))


def loss_fn(params, batch):
    x, y = batch
    logits = x @ params["w"].astype(x.dtype) + params["b"].astype(x.dtype)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


OPT = get_optimizer("sgd_momentum")
SCHED = lambda s: 0.1  # noqa: E731
BF16 = get_policy("bf16")


@pytest.fixture(scope="module")
def batch():
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 8), jnp.float32)
    y = jax.random.randint(jax.random.PRNGKey(2), (8,), 0, 4)
    return x, y


def _poison(batch):
    x, y = batch
    return x.at[0, 0].set(jnp.nan), y


# ------------------------------------------------------------ the policy ---

def test_policy_validation():
    with pytest.raises(ValueError, match="loss_scale"):
        NumericsPolicy(loss_scale="sometimes")
    with pytest.raises(ValueError, match="accum_dtype"):
        NumericsPolicy(accum_dtype="bfloat16")
    with pytest.raises(ValueError, match="kv_cache_dtype"):
        NumericsPolicy(kv_cache_dtype="int4")
    with pytest.raises(TypeError):
        NumericsPolicy(param_dtype="float33")
    with pytest.raises(ValueError, match="preset"):
        get_policy("fp16")


def test_policy_describe_and_default_gate():
    assert NumericsPolicy().describe() == "fp32"
    assert NumericsPolicy().is_training_default
    assert get_policy("fp32") == NumericsPolicy()
    bf = get_policy("bf16")
    assert not bf.is_training_default
    assert "master_fp32" in bf.describe()
    assert "loss_scale=dynamic" in bf.describe()
    # kv dtype is serve-side only: it must NOT disturb the training gate
    assert NumericsPolicy(kv_cache_dtype="int8").is_training_default


# ---------------------------------------------------- fp32 is bit-inert ----

def test_fp32_preset_bit_equal_to_no_policy(batch):
    b = reshape_for_replicas(batch, 2)
    sa = init_param_avg_state(jax.random.PRNGKey(0), init_fn, OPT, 2)
    sb = init_param_avg_state(jax.random.PRNGKey(0), init_fn, OPT, 2,
                              numerics=get_policy("fp32"))
    step_a = jax.jit(make_param_avg_step(loss_fn, OPT, SCHED))
    step_b = jax.jit(make_param_avg_step(loss_fn, OPT, SCHED,
                                         numerics=get_policy("fp32")))
    for _ in range(3):
        sa, la = step_a(sa, b)
        sb, lb = step_b(sb, b)
    assert float(la) == float(lb)
    assert sb.numerics is None
    for ka, kb in zip(jax.tree.leaves(sa.params), jax.tree.leaves(sb.params)):
        np.testing.assert_array_equal(np.asarray(ka), np.asarray(kb))


# ------------------------------------------- bf16 + masters + scaling ------

def test_bf16_master_dynamic_learns(batch):
    b = reshape_for_replicas(batch, 2)
    mopt = for_numerics(OPT, BF16)
    s = init_param_avg_state(jax.random.PRNGKey(0), init_bf16, mopt, 2,
                             numerics=BF16)
    step = jax.jit(make_param_avg_step(loss_fn, mopt, SCHED, numerics=BF16))
    # the fp32 run from the same init — the acceptance bar is the bf16
    # TRACE tracking it within a documented tolerance over 20 steps
    sf = init_param_avg_state(jax.random.PRNGKey(0), init_fn, OPT, 2)
    step_f = jax.jit(make_param_avg_step(loss_fn, OPT, SCHED))
    losses, losses_f = [], []
    for _ in range(20):
        s, loss = step(s, b)
        losses.append(float(loss))
        sf, loss_f = step_f(sf, b)
        losses_f.append(float(loss_f))
    assert s.params["w"].dtype == jnp.bfloat16            # live params bf16
    assert s.opt_state["master"]["w"].dtype == jnp.float32  # masters fp32
    assert losses[-1] < losses[0], losses
    # documented tolerance (docs/numerics.md): the bf16 loss trace stays
    # within 5e-2 of the fp32 one at every step of the 20
    np.testing.assert_allclose(losses, losses_f, atol=5e-2, rtol=5e-2)
    assert int(s.numerics["skipped"]) == 0
    assert float(s.numerics["scale"]) == 2.0 ** 15        # no halving


def test_poisoned_step_skips_halves_and_recovers(batch):
    b = reshape_for_replicas(batch, 2)
    bad = reshape_for_replicas(_poison(batch), 2)
    mopt = for_numerics(OPT, BF16)
    s = init_param_avg_state(jax.random.PRNGKey(0), init_bf16, mopt, 2,
                             numerics=BF16)
    step = jax.jit(make_param_avg_step(loss_fn, mopt, SCHED, numerics=BF16))
    for _ in range(3):
        s, _ = step(s, b)
    before = jax.tree.map(np.asarray, s.params)
    s2, _ = step(s, bad)
    for a, c in zip(jax.tree.leaves(before), jax.tree.leaves(s2.params)):
        np.testing.assert_array_equal(a, np.asarray(c))   # update SKIPPED
    assert int(s2.numerics["skipped"]) == 1
    assert float(s2.numerics["scale"]) == 2.0 ** 14       # halved
    assert int(s2.step) == 4                              # step still counts
    s3, _ = step(s2, b)                                   # clean: recovers
    moved = any(not np.array_equal(a, np.asarray(c)) for a, c in
                zip(jax.tree.leaves(before), jax.tree.leaves(s3.params)))
    assert moved
    assert int(s3.numerics["skipped"]) == 1               # no new skips


def test_dynamic_scale_growth(batch):
    b = reshape_for_replicas(batch, 2)
    pol = NumericsPolicy(param_dtype="bfloat16", master_weights=True,
                         loss_scale="dynamic", growth_interval=3,
                         loss_scale_init=2.0)
    mopt = for_numerics(OPT, pol)
    s = init_param_avg_state(jax.random.PRNGKey(0), init_bf16, mopt, 2,
                             numerics=pol)
    step = jax.jit(make_param_avg_step(loss_fn, mopt, SCHED, numerics=pol))
    for _ in range(3):
        s, _ = step(s, b)
    assert float(s.numerics["scale"]) == 4.0              # doubled once
    assert int(s.numerics["good_steps"]) == 0             # counter reset


def test_static_scale_never_moves(batch):
    b = reshape_for_replicas(batch, 2)
    bad = reshape_for_replicas(_poison(batch), 2)
    pol = NumericsPolicy(param_dtype="bfloat16", master_weights=True,
                         loss_scale="static", loss_scale_init=256.0,
                         growth_interval=1)
    mopt = for_numerics(OPT, pol)
    s = init_param_avg_state(jax.random.PRNGKey(0), init_bf16, mopt, 2,
                             numerics=pol)
    step = jax.jit(make_param_avg_step(loss_fn, mopt, SCHED, numerics=pol))
    for _ in range(3):
        s, _ = step(s, b)
    s, _ = step(s, bad)
    assert float(s.numerics["scale"]) == 256.0            # static stays
    assert int(s.numerics["skipped"]) == 1                # but still skips


def test_master_on_fp32_matches_plain_fp32(batch):
    """Masters over fp32 live params: same trajectory to float tolerance
    (the cast round-trip through apply_updates costs at most 1 ulp)."""
    b = reshape_for_replicas(batch, 2)
    pol = NumericsPolicy(master_weights=True)
    mopt = for_numerics(OPT, pol)
    sm = init_param_avg_state(jax.random.PRNGKey(0), init_fn, mopt, 2,
                              numerics=pol)
    sp = init_param_avg_state(jax.random.PRNGKey(0), init_fn, OPT, 2)
    mstep = jax.jit(make_param_avg_step(loss_fn, mopt, SCHED, numerics=pol))
    pstep = jax.jit(make_param_avg_step(loss_fn, OPT, SCHED))
    for _ in range(5):
        sm, _ = mstep(sm, b)
        sp, _ = pstep(sp, b)
    for a, c in zip(jax.tree.leaves(sm.params), jax.tree.leaves(sp.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=1e-6, atol=1e-7)


# ------------------------------------------------- other engine variants ---

def test_scan_replica_exec_skips(batch):
    b = reshape_for_replicas(batch, 2)
    bad = reshape_for_replicas(_poison(batch), 2)
    mopt = for_numerics(OPT, BF16)
    s = init_param_avg_state(jax.random.PRNGKey(0), init_bf16, mopt, 2,
                             numerics=BF16)
    step = jax.jit(make_param_avg_step(loss_fn, mopt, SCHED, numerics=BF16,
                                       replica_exec="scan"))
    for _ in range(3):
        s, loss = step(s, b)
    assert np.isfinite(float(loss))
    s2, _ = step(s, bad)
    assert int(s2.numerics["skipped"]) == 1
    assert float(s2.numerics["scale"]) == 2.0 ** 14


def test_grad_avg_numerics(batch):
    mopt = for_numerics(OPT, BF16)
    s = init_grad_avg_state(jax.random.PRNGKey(0), init_bf16, mopt,
                            numerics=BF16)
    step = jax.jit(make_grad_avg_step(loss_fn, mopt, SCHED, numerics=BF16))
    losses = []
    for _ in range(20):
        s, loss = step(s, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0]
    s2, _ = step(s, _poison(batch))
    assert int(s2.numerics["skipped"]) == 1


_MESH_CHILD = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.core.steps import (init_param_avg_state, make_mesh_param_avg_step,
                              reshape_for_replicas)
from repro.numerics import get_policy
from repro.optim.optimizers import for_numerics, get_optimizer

def init_fn(rng):
    k1, _ = jax.random.split(rng)
    return {"w": jax.random.normal(k1, (8, 4), jnp.float32) * 0.1,
            "b": jnp.zeros((4,), jnp.float32)}

def init_bf16(rng):
    return jax.tree.map(lambda p: p.astype(jnp.bfloat16), init_fn(rng))

def loss_fn(params, batch):
    x, y = batch
    logits = x @ params["w"].astype(x.dtype) + params["b"].astype(x.dtype)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

R = jax.device_count()
mesh = Mesh(np.array(jax.devices()).reshape(R), ("data",))
opt = get_optimizer("sgd_momentum")
sched = lambda s: 0.1
x = jax.random.normal(jax.random.PRNGKey(1), (8, 8), jnp.float32)
y = jax.random.randint(jax.random.PRNGKey(2), (8,), 0, 4)
batch = reshape_for_replicas((x, y), R)
bad = reshape_for_replicas((x.at[0, 0].set(jnp.nan), y), R)

# fp32 preset bit-equal to no-policy on the collective engine
sa = init_param_avg_state(jax.random.PRNGKey(0), init_fn, opt, R)
sb = init_param_avg_state(jax.random.PRNGKey(0), init_fn, opt, R,
                          numerics=get_policy("fp32"))
step_a = jax.jit(make_mesh_param_avg_step(loss_fn, opt, sched, mesh=mesh,
                                          replica_axes=("data",)))
step_b = jax.jit(make_mesh_param_avg_step(loss_fn, opt, sched, mesh=mesh,
                                          replica_axes=("data",),
                                          numerics=get_policy("fp32")))
for _ in range(3):
    sa, la = step_a(sa, batch)
    sb, lb = step_b(sb, batch)
assert float(la) == float(lb)
for a, b in zip(jax.tree.leaves(sa.params), jax.tree.leaves(sb.params)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

# bf16 + dynamic scaling: learns, then a poisoned batch skips + halves
pol = get_policy("bf16")
mopt = for_numerics(opt, pol)
s = init_param_avg_state(jax.random.PRNGKey(0), init_bf16, mopt, R,
                         numerics=pol)
step = jax.jit(make_mesh_param_avg_step(loss_fn, mopt, sched, mesh=mesh,
                                        replica_axes=("data",),
                                        numerics=pol))
losses = []
for _ in range(20):
    s, l = step(s, batch)
    losses.append(float(l))
assert losses[-1] < losses[0], losses
before = jax.tree.map(np.asarray, s.params)
s2, _ = step(s, bad)
for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(s2.params)):
    np.testing.assert_array_equal(a, np.asarray(b))
assert int(s2.numerics["skipped"]) == 1
assert float(s2.numerics["scale"]) == 2.0 ** 14
print("MESH-NUMERICS-OK")
"""


@pytest.mark.parametrize("devices", [2, 4])
def test_mesh_engine_numerics(devices):
    """The collective engine: fp32 inert + bf16 skip/halve, with the
    finite check pmin-reduced across real device shards."""
    assert "MESH-NUMERICS-OK" in run_child(_MESH_CHILD, devices=devices)
