"""Mesh-native exchange engine vs the axis-0 reference.

The contract the Exchanger API rests on: ``make_mesh_param_avg_step``
(shard_map + real collectives) produces the same trajectory as
``make_param_avg_step`` (leading-axis-R simulation) for every strategy —
params AND momentum (paper footnote 3) — on 1, 2 and 4 host devices, and
each strategy's compiled HLO contains exactly the collective its docstring
promises.  Children run in subprocesses so the forced device count never
leaks into the main test process (dry-run isolation rule).
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.join(os.path.dirname(__file__), "..", "..")


def run_child(code: str, devices: int, timeout=560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


CHILD_PARITY = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import (init_param_avg_state, make_mesh_param_avg_step,
                        make_param_avg_step, reshape_for_replicas)
from repro.launch.mesh import make_replica_mesh
from repro.optim import schedules
from repro.optim.optimizers import sgd_momentum

R = jax.device_count()
mesh = make_replica_mesh(R)

def linear_loss(params, batch):
    pred = batch["x"] @ params["w"] + params["b"]
    return jnp.mean((pred - batch["y"]) ** 2)

init_fn = lambda r: {"w": jax.random.normal(r, (6, 3)) * 0.3,
                     "b": jnp.zeros((3,))}
rng = np.random.default_rng(0)
batches = [{"x": jnp.asarray(rng.normal(size=(4 * R, 6)), jnp.float32),
            "y": jnp.asarray(rng.normal(size=(4 * R, 3)), jnp.float32)}
           for _ in range(5)]
opt = sgd_momentum(momentum=0.9)       # momentum state exchanged (fn. 3)
sch = schedules.constant(0.05)
for strat in ("all_reduce", "ring", "pairwise", "none"):
    key = jax.random.PRNGKey(0)
    s_ref = init_param_avg_state(key, init_fn, opt, R)
    s_mesh = init_param_avg_state(key, init_fn, opt, R)
    ref = jax.jit(make_param_avg_step(linear_loss, opt, sch, strategy=strat))
    msh = jax.jit(make_mesh_param_avg_step(linear_loss, opt, sch, mesh=mesh,
                                           strategy=strat,
                                           replica_axes=("data",)))
    for b in batches:
        rb = reshape_for_replicas(b, R)
        s_ref, l_ref = ref(s_ref, rb)
        s_mesh, l_mesh = msh(s_mesh, rb)
    assert abs(float(l_ref) - float(l_mesh)) < 1e-5, (strat, l_ref, l_mesh)
    for name, tref, tmesh in (("params", s_ref.params, s_mesh.params),
                              ("opt", s_ref.opt_state, s_mesh.opt_state)):
        for a, b in zip(jax.tree.leaves(tref), jax.tree.leaves(tmesh)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=f"{strat}/{name}")
    print(strat, "ok")
print("OK")
"""

CHILD_LOWERING = """
import re, jax, jax.numpy as jnp
from repro.core import (EXPECTED_COLLECTIVE, init_param_avg_state,
                        make_mesh_param_avg_step, reshape_for_replicas)
from repro.launch.mesh import make_replica_mesh
from repro.optim import schedules
from repro.optim.optimizers import sgd_momentum

R = jax.device_count()
mesh = make_replica_mesh(R)
init_fn = lambda r: {"w": jax.random.normal(r, (6, 3))}
loss = lambda p, b: jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)
opt = sgd_momentum()
state = init_param_avg_state(jax.random.PRNGKey(0), init_fn, opt, R)
batch = reshape_for_replicas({"x": jnp.ones((4 * R, 6)),
                              "y": jnp.ones((4 * R, 3))}, R)
for strat in ("all_reduce", "ring", "pairwise"):
    step = jax.jit(make_mesh_param_avg_step(loss, opt,
                                            schedules.constant(0.05),
                                            mesh=mesh, strategy=strat,
                                            replica_axes=("data",)))
    txt = step.lower(state, batch).compile().as_text()
    want = EXPECTED_COLLECTIVE[strat]
    n = len(re.findall(want + r"(?:-start)?\\(", txt))
    assert n > 0, (strat, want)
    # the strategies must lower to DISTINCT schedules: ring/pairwise carry
    # no all-reduce on the weights (the loss pmean is the only all-reduce)
    if strat != "all_reduce":
        n_ar = len(re.findall(r"all-reduce(?:-start)?\\(", txt))
        assert n_ar <= 1, (strat, "weights leaked into all-reduce", n_ar)
    print(strat, "->", want, n)
print("OK")
"""

CHILD_POD_AXES = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import (init_param_avg_state, make_mesh_param_avg_step,
                        make_param_avg_step, reshape_for_replicas)
from repro.launch.mesh import make_replica_mesh
from repro.optim import schedules
from repro.optim.optimizers import sgd_momentum

R = jax.device_count()
mesh = make_replica_mesh(R, pod=2)          # ('pod','data') two-axis mesh
assert mesh.axis_names == ("pod", "data")
init_fn = lambda r: {"w": jax.random.normal(r, (6, 3))}
loss = lambda p, b: jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)
opt = sgd_momentum(momentum=0.9)
sch = schedules.constant(0.05)
rng = np.random.default_rng(1)
batches = [{"x": jnp.asarray(rng.normal(size=(4 * R, 6)), jnp.float32),
            "y": jnp.asarray(rng.normal(size=(4 * R, 3)), jnp.float32)}
           for _ in range(4)]
for strat in ("all_reduce", "ring", "pairwise"):
    key = jax.random.PRNGKey(0)
    s_ref = init_param_avg_state(key, init_fn, opt, R)
    s_mesh = init_param_avg_state(key, init_fn, opt, R)
    ref = jax.jit(make_param_avg_step(loss, opt, sch, strategy=strat))
    msh = jax.jit(make_mesh_param_avg_step(loss, opt, sch, mesh=mesh,
                                           strategy=strat))
    for b in batches:
        rb = reshape_for_replicas(b, R)
        s_ref, _ = ref(s_ref, rb)
        s_mesh, _ = msh(s_mesh, rb)
    for a, b in zip(jax.tree.leaves(s_ref.params),
                    jax.tree.leaves(s_mesh.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5, err_msg=strat)
    print(strat, "ok")
print("OK")
"""

CHILD_SYNC_EVERY = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import (init_param_avg_state, make_mesh_param_avg_step,
                        make_param_avg_step, replica_spread,
                        reshape_for_replicas)
from repro.launch.mesh import make_replica_mesh
from repro.optim import schedules
from repro.optim.optimizers import sgd_momentum

R = jax.device_count()
mesh = make_replica_mesh(R)
init_fn = lambda r: {"w": jax.random.normal(r, (6, 3))}
loss = lambda p, b: jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)
opt = sgd_momentum()
sch = schedules.constant(0.05)
rng = np.random.default_rng(2)
s_ref = init_param_avg_state(jax.random.PRNGKey(0), init_fn, opt, R)
s_mesh = init_param_avg_state(jax.random.PRNGKey(0), init_fn, opt, R)
ref = jax.jit(make_param_avg_step(loss, opt, sch, sync_every=3))
msh = jax.jit(make_mesh_param_avg_step(loss, opt, sch, mesh=mesh,
                                       sync_every=3,
                                       replica_axes=("data",)))
for i in range(6):
    b = {"x": jnp.asarray(rng.normal(size=(4 * R, 6)), jnp.float32),
         "y": jnp.asarray(rng.normal(size=(4 * R, 3)), jnp.float32)}
    rb = reshape_for_replicas(b, R)
    s_ref, _ = ref(s_ref, rb)
    s_mesh, _ = msh(s_mesh, rb)
    sp_r = float(replica_spread(s_ref.params))
    sp_m = float(replica_spread(s_mesh.params))
    assert abs(sp_r - sp_m) < 1e-5, (i, sp_r, sp_m)
    for a, b_ in zip(jax.tree.leaves(s_ref.params),
                     jax.tree.leaves(s_mesh.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-5, atol=1e-5)
print("OK")
"""


@pytest.mark.parametrize("devices", [1, 2, 4])
def test_mesh_engine_matches_reference(devices):
    """Every strategy, params + momentum, on 1/2/4 host devices."""
    out = run_child(CHILD_PARITY, devices=devices)
    assert "OK" in out


@pytest.mark.parametrize("devices", [2, 4])
def test_mesh_engine_lowers_to_promised_collectives(devices):
    """all_reduce -> all-reduce; ring/pairwise -> collective-permute ONLY
    (no hidden all-reduce on the weights)."""
    out = run_child(CHILD_LOWERING, devices=devices)
    assert "OK" in out


def test_mesh_engine_pod_data_axes():
    """Replica index spread over a ('pod','data') two-axis mesh — the
    production layout from launch/mesh.py."""
    out = run_child(CHILD_POD_AXES, devices=4)
    assert "OK" in out


def test_mesh_engine_local_sgd_sync_every():
    """Local SGD (sync_every=3): drift and resync match the reference."""
    out = run_child(CHILD_SYNC_EVERY, devices=4)
    assert "OK" in out
