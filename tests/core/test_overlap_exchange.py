"""The delay=1 one-step-stale overlapped exchange + wire compression.

What this file locks down (ISSUE PR 7):

* ``delay=0`` through ``ExchangeConfig`` is BIT-equal to the pre-PR
  synchronous path (plain strategy string) — reference engine in-process
  at R = 1/2/4, mesh engine in 1/2/4-device subprocesses.
* ``delay=1`` converges on a task the synchronous path converges on,
  within a documented tolerance (one step of staleness, not divergence).
* The delayed exchange does not DOUBLE the collectives: the compiled
  delay=1 program carries exactly as many weight all-reduces as the
  delay=0 program (one collective per exchange interval), including
  under ``sync_every`` gating.
* Compression: bf16 stays within round-trip bounds of the dense
  trajectory; topk with ``topk_frac=1.0`` is bitwise the dense path;
  the top-k error-feedback residual carries exactly what the compressor
  dropped; skipped local-SGD steps leave the compression state
  untouched.
* ``replica_exec="scan"`` (sequential unrolled replicas) matches vmap.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ExchangeConfig, init_param_avg_state,
                        make_param_avg_step, reshape_for_replicas)
from repro.core.param_avg import Exchanger
from repro.optim import schedules
from repro.optim.optimizers import sgd_momentum

REPO = os.path.join(os.path.dirname(__file__), "..", "..")


def run_child(code: str, devices: int, timeout=560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def _linear_loss(params, batch):
    pred = batch["x"] @ params["w"] + params["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


def _init_fn(r):
    return {"w": jax.random.normal(r, (6, 3)) * 0.3, "b": jnp.zeros((3,))}


def _batches(n, R, seed=0):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(6, 3)).astype(np.float32)
    out = []
    for _ in range(n):
        x = rng.normal(size=(4 * R, 6)).astype(np.float32)
        out.append(reshape_for_replicas(
            {"x": jnp.asarray(x), "y": jnp.asarray(x @ w)}, R))
    return out


def _run_steps(exchange, R, n=6, replica_exec="vmap", strategy=None,
               seed=0):
    """Full trajectory under the reference engine; returns (losses, state)."""
    opt = sgd_momentum(momentum=0.9)
    state = init_param_avg_state(jax.random.PRNGKey(0), _init_fn, opt, R,
                                 exchange=exchange)
    step = jax.jit(make_param_avg_step(
        _linear_loss, opt, schedules.constant(0.05),
        strategy=strategy if strategy is not None else exchange,
        replica_exec=replica_exec))
    losses = []
    for b in _batches(n, R, seed):
        state, loss = step(state, b)
        losses.append(float(loss))
    return losses, state


def _assert_tree_equal(a, b, msg=""):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)


# --------------------------------------------------------------- delay=0 --

@pytest.mark.parametrize("R", [1, 2, 4])
def test_delay0_bit_equal_pre_pr_reference(R):
    """ExchangeConfig(delay=0) is the pre-PR path, bit for bit."""
    l_old, s_old = _run_steps(None, R, strategy="all_reduce")
    l_new, s_new = _run_steps(ExchangeConfig(), R)
    assert l_old == l_new, (l_old, l_new)
    _assert_tree_equal(s_old.params, s_new.params, "params")
    _assert_tree_equal(s_old.opt_state, s_new.opt_state, "opt")


CHILD_DELAY0_MESH = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import (ExchangeConfig, init_param_avg_state,
                        make_mesh_param_avg_step, reshape_for_replicas)
from repro.launch.mesh import make_replica_mesh
from repro.optim import schedules
from repro.optim.optimizers import sgd_momentum

R = jax.device_count()
mesh = make_replica_mesh(R)
init_fn = lambda r: {"w": jax.random.normal(r, (6, 3)) * 0.3,
                     "b": jnp.zeros((3,))}
loss = lambda p, b: jnp.mean((b["x"] @ p["w"] + p["b"] - b["y"]) ** 2)
opt = sgd_momentum(momentum=0.9)
sch = schedules.constant(0.05)
rng = np.random.default_rng(0)
s_old = init_param_avg_state(jax.random.PRNGKey(0), init_fn, opt, R)
s_new = init_param_avg_state(jax.random.PRNGKey(0), init_fn, opt, R,
                             exchange=ExchangeConfig())
old = jax.jit(make_mesh_param_avg_step(loss, opt, sch, mesh=mesh,
                                       strategy="all_reduce",
                                       replica_axes=("data",)))
new = jax.jit(make_mesh_param_avg_step(loss, opt, sch, mesh=mesh,
                                       strategy=ExchangeConfig(),
                                       replica_axes=("data",)))
for i in range(5):
    b = reshape_for_replicas(
        {"x": jnp.asarray(rng.normal(size=(4 * R, 6)), jnp.float32),
         "y": jnp.asarray(rng.normal(size=(4 * R, 3)), jnp.float32)}, R)
    s_old, l_old = old(s_old, b)
    s_new, l_new = new(s_new, b)
    assert float(l_old) == float(l_new), (i, l_old, l_new)   # bit-equal
for a, c in zip(jax.tree.leaves(s_old.params), jax.tree.leaves(s_new.params)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
print("OK")
"""


@pytest.mark.parametrize("devices", [1, 2, 4])
def test_delay0_bit_equal_pre_pr_mesh(devices):
    out = run_child(CHILD_DELAY0_MESH, devices=devices)
    assert "OK" in out


# --------------------------------------------------------------- delay=1 --

def test_delay1_converges_within_tolerance():
    """One step of staleness must not break convergence: both traces
    descend, and the delayed final loss lands within 25% (documented
    tolerance, docs/architecture.md) of the synchronous one."""
    R, n = 4, 20
    l_sync, _ = _run_steps(ExchangeConfig(), R, n=n)
    l_stale, _ = _run_steps(ExchangeConfig(delay=1), R, n=n)
    assert l_stale[-1] < 0.5 * l_stale[0], l_stale          # it learns
    assert abs(l_stale[-1] - l_sync[-1]) <= 0.25 * max(l_sync[-1], 1e-3), \
        (l_sync[-1], l_stale[-1])


def test_delay1_first_step_matches_sync():
    """Step 0 has no previous exchange in flight — identical to sync."""
    l_sync, _ = _run_steps(ExchangeConfig(), 4, n=1)
    l_stale, _ = _run_steps(ExchangeConfig(delay=1), 4, n=1)
    assert l_sync == l_stale


CHILD_HLO_COUNT = """
import re, jax, jax.numpy as jnp
from repro.core import (ExchangeConfig, init_param_avg_state,
                        make_mesh_param_avg_step, reshape_for_replicas)
from repro.launch.mesh import make_replica_mesh
from repro.optim import schedules
from repro.optim.optimizers import sgd_momentum

R = jax.device_count()
mesh = make_replica_mesh(R)
init_fn = lambda r: {"w": jax.random.normal(r, (6, 3))}
loss = lambda p, b: jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)
opt = sgd_momentum()
sch = schedules.constant(0.05)
batch = reshape_for_replicas({"x": jnp.ones((4 * R, 6)),
                              "y": jnp.ones((4 * R, 3))}, R)

def n_all_reduce(exch):
    state = init_param_avg_state(jax.random.PRNGKey(0), init_fn, opt, R,
                                 exchange=exch)
    step = jax.jit(make_mesh_param_avg_step(loss, opt, sch, mesh=mesh,
                                            strategy=exch,
                                            replica_axes=("data",)))
    txt = step.lower(state, batch).compile().as_text()
    return len(re.findall(r"all-reduce(?:-start)?\\(", txt))

n0 = n_all_reduce(ExchangeConfig())
n1 = n_all_reduce(ExchangeConfig(delay=1))
# one collective per exchange interval: the overlapped program must not
# carry the exchange twice (once stale + once fresh would double it)
assert n0 == n1, (n0, n1)
n0g = n_all_reduce(ExchangeConfig(sync_every=2))
n1g = n_all_reduce(ExchangeConfig(delay=1, sync_every=2))
assert n0g == n1g, (n0g, n1g)
print("OK", n0, n1, n0g, n1g)
"""


@pytest.mark.parametrize("devices", [2, 4])
def test_delay1_single_collective_per_interval(devices):
    out = run_child(CHILD_HLO_COUNT, devices=devices)
    assert "OK" in out


# ------------------------------------------------------------ compression --

def test_bf16_within_roundtrip_bounds():
    """bf16 wire compression: same descent, losses within bf16 mantissa
    bounds of the dense delayed trajectory at every step."""
    l_dense, s_dense = _run_steps(ExchangeConfig(delay=1), 4, n=8)
    l_bf16, s_bf16 = _run_steps(
        ExchangeConfig(delay=1, compression="bf16"), 4, n=8)
    np.testing.assert_allclose(l_bf16, l_dense, rtol=2e-2, atol=1e-3)
    for a, b in zip(jax.tree.leaves(s_dense.params),
                    jax.tree.leaves(s_bf16.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-2, atol=1e-2)


def test_topk_full_fraction_is_bitwise_dense():
    """topk_frac=1.0 routes through the dense arithmetic — bit-equal."""
    l_dense, s_dense = _run_steps(ExchangeConfig(delay=1), 4, n=8)
    l_full, s_full = _run_steps(
        ExchangeConfig(delay=1, compression="topk", topk_frac=1.0), 4, n=8)
    assert l_dense == l_full
    _assert_tree_equal(s_dense.params, s_full.params)
    # and the residual never accumulates anything
    for leaf in jax.tree.leaves(s_full.exchange["residual"]):
        assert float(np.abs(np.asarray(leaf)).max()) == 0.0


def test_topk_error_feedback_residual_math():
    """average_delta per the spec: d = (x-base)+res; c = top-k(d);
    out = base + mean(c); res' = d - c (nothing is lost, only delayed)."""
    ex = Exchanger("all_reduce", compression="topk", topk_frac=0.5)
    R, n = 2, 4                       # k = 2 of 4
    x = jnp.asarray(np.array([[1.0, -3.0, 0.5, 2.0],
                              [0.25, 1.5, -0.75, -0.5]], np.float32))
    base = jnp.zeros((R, n)) + jnp.asarray([0.5, 0.0, 0.0, 0.0])
    res = jnp.asarray(np.array([[0.1, 0.0, 0.0, 0.0],
                                [0.0, 0.2, 0.0, 0.0]], np.float32))
    out, new_res = ex.average_delta((x,), (base,), (res,))
    out, new_res = out[0], new_res[0]
    d = np.asarray(x) - np.asarray(base) + np.asarray(res)
    # top-2 by |.| per replica: replica 0 -> [-3.0, 2.0]; replica 1 -> [1.7, -0.75]
    kept = np.zeros_like(d)
    for r in range(R):
        idx = np.argsort(-np.abs(d[r]))[:2]
        kept[r, idx] = d[r, idx]
    expect_out = np.asarray(base) + kept.sum(0) / R
    np.testing.assert_allclose(np.asarray(out), expect_out, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(new_res), d - kept, rtol=1e-6)
    # conservation: transmitted + residual == full owed delta
    np.testing.assert_allclose(kept + np.asarray(new_res), d, rtol=1e-6)


def test_topk_trains_and_carries_residual():
    """Sparse exchange still converges; the residual is live (non-zero)."""
    l_topk, s_topk = _run_steps(
        ExchangeConfig(delay=1, compression="topk", topk_frac=0.25), 4,
        n=20)
    assert l_topk[-1] < 0.5 * l_topk[0], l_topk
    total = sum(float(np.abs(np.asarray(leaf)).sum())
                for leaf in jax.tree.leaves(s_topk.exchange["residual"]))
    assert total > 0.0


def test_topk_requires_delay():
    with pytest.raises(ValueError, match="delay=1"):
        ExchangeConfig(compression="topk")
    with pytest.raises(ValueError, match="all-gather"):
        ExchangeConfig(strategy="ring", compression="topk", delay=1)


def test_sync_every_skips_leave_compression_state_untouched():
    """Local-SGD gating composes with the stateful exchange: on skipped
    steps base AND residual must ride through unchanged (a cond that
    half-updated them would corrupt the consensus)."""
    exch = ExchangeConfig(delay=1, compression="topk", topk_frac=0.25,
                          sync_every=2)
    R = 4
    opt = sgd_momentum(momentum=0.9)
    state = init_param_avg_state(jax.random.PRNGKey(0), _init_fn, opt, R,
                                 exchange=exch)
    step = jax.jit(make_param_avg_step(_linear_loss, opt,
                                       schedules.constant(0.05),
                                       strategy=exch))
    prev_aux = state.exchange
    for i, b in enumerate(_batches(6, R)):
        state, _ = step(state, b)
        synced = (i + 1) % 2 == 0
        if not synced:
            _assert_tree_equal(state.exchange, prev_aux,
                               f"aux moved on skipped step {i}")
        prev_aux = state.exchange
    # the sync steps DID move the consensus base
    assert any(float(np.abs(np.asarray(a) - np.asarray(b)).max()) > 0
               for a, b in zip(jax.tree.leaves(prev_aux["base"]),
                               jax.tree.leaves(
                                   init_param_avg_state(
                                       jax.random.PRNGKey(0), _init_fn,
                                       opt, R,
                                       exchange=exch).exchange["base"])))


# -------------------------------------------------------------- scan exec --

@pytest.mark.parametrize("delay", [0, 1])
def test_scan_exec_matches_vmap(delay):
    """Sequential unrolled replicas = batched replicas, same numbers."""
    exch = ExchangeConfig(delay=delay)
    l_vmap, s_vmap = _run_steps(exch, 4, n=5, replica_exec="vmap")
    l_scan, s_scan = _run_steps(exch, 4, n=5, replica_exec="scan")
    np.testing.assert_allclose(l_scan, l_vmap, rtol=1e-6)
    for a, b in zip(jax.tree.leaves(s_vmap.params),
                    jax.tree.leaves(s_scan.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


def test_scan_exec_single_replica():
    l_vmap, _ = _run_steps(ExchangeConfig(), 1, n=3, replica_exec="vmap")
    l_scan, _ = _run_steps(ExchangeConfig(), 1, n=3, replica_exec="scan")
    np.testing.assert_allclose(l_scan, l_vmap, rtol=1e-6)
