"""Shared subprocess isolation for multi-device tests: force the XLA host
device count in a CHILD process so it never leaks into the main test
process (the dry-run isolation rule).  Importable from any tests/ subdir
(the rootdir conftest puts tests/ on sys.path, same as tests/_hyp.py)."""
import os
import subprocess
import sys

REPO = os.path.join(os.path.dirname(__file__), "..")


def run_isolated(cmd_tail, devices: int, timeout=560, check: bool = True):
    """Run ``python <cmd_tail...>`` with ``devices`` forced host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["REPRO_DEVICES"] = str(devices)       # for entrypoints that re-set
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable] + list(cmd_tail), env=env,
                       capture_output=True, text=True, timeout=timeout)
    if check:
        assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r


def run_child(code: str, devices: int = 8, timeout=560) -> str:
    """Run a ``python -c`` snippet; asserts success, returns stdout."""
    return run_isolated(["-c", code], devices, timeout).stdout
