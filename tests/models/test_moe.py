"""MoE routing: capacity semantics, gate normalization, aux-loss bounds."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.configs import ARCHS, reduced
from repro.models import moe


def make_cfg(n_experts=4, top_k=2, cf=None, mlp="swiglu"):
    cfg = reduced(ARCHS["mixtral-8x7b"])
    m = dataclasses.replace(cfg.moe, n_experts=n_experts, top_k=top_k,
                            capacity_factor=cf or float(n_experts))
    return dataclasses.replace(cfg, moe=m, mlp=mlp)


def test_single_expert_equals_dense_mlp():
    """E=1, k=1, no-drop capacity: MoE must equal its one expert's MLP."""
    from repro.models.layers import mlp_apply
    cfg = make_cfg(n_experts=1, top_k=1)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    out, aux = moe.moe_apply(p, cfg, x)
    dense = {"w_in": p["w_in"][0], "w_gate": p["w_gate"][0],
             "w_out": p["w_out"][0]}
    exp = mlp_apply(dense, cfg, x.reshape(32, -1)).reshape(x.shape)
    np.testing.assert_allclose(out, exp, rtol=2e-4, atol=2e-4)


def test_output_is_gate_convex_combination():
    """With k=1, output = gate * expert(x); scaling check vs direct."""
    cfg = make_cfg(n_experts=4, top_k=1)
    p = moe.moe_init(jax.random.PRNGKey(2), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 8, cfg.d_model))
    out, _ = moe.moe_apply(p, cfg, x)
    assert out.shape == x.shape
    assert not bool(jnp.any(jnp.isnan(out)))


def test_capacity_zero_drop_deterministic_batch_order():
    """Permuting tokens permutes outputs identically (no cross-token mixing)
    under no-drop capacity."""
    cfg = make_cfg(n_experts=4, top_k=2)
    p = moe.moe_init(jax.random.PRNGKey(4), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 16, cfg.d_model))
    out, _ = moe.moe_apply(p, cfg, x)
    perm = jnp.array([5, 2, 0, 1, 3, 4, 6, 7, 15, 9, 10, 11, 12, 13, 14, 8])
    out_p, _ = moe.moe_apply(p, cfg, x[:, perm])
    np.testing.assert_allclose(out_p, out[:, perm], rtol=2e-4, atol=2e-4)


def test_tiny_capacity_drops_tokens():
    """capacity_factor << 1 must produce zero output rows for dropped
    tokens, not garbage."""
    cfg = make_cfg(n_experts=4, top_k=1, cf=0.25)
    p = moe.moe_init(jax.random.PRNGKey(6), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(7), (1, 32, cfg.d_model))
    out, _ = moe.moe_apply(p, cfg, x)
    assert not bool(jnp.any(jnp.isnan(out)))
    # at least some tokens were dropped (all-zero rows)
    row_norms = jnp.linalg.norm(out[0], axis=-1)
    assert float(jnp.min(row_norms)) < 1e-6


@settings(max_examples=10, deadline=None)
@given(e=st.sampled_from([2, 4, 8]), k=st.sampled_from([1, 2]),
       seed=st.integers(0, 1000))
def test_aux_loss_bounds(e, k, seed):
    """Switch aux loss in [coef, coef*E]: 1 at perfect balance, E at
    collapse (scaled by coef)."""
    cfg = make_cfg(n_experts=e, top_k=k)
    p = moe.moe_init(jax.random.PRNGKey(seed), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 32, cfg.d_model))
    _, aux = moe.moe_apply(p, cfg, x)
    coef = cfg.moe.router_aux_coef
    assert coef * 0.9 <= float(aux) <= coef * e * 1.01


def test_shared_expert_added():
    cfg = make_cfg(n_experts=2, top_k=1)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, shared_expert=True))
    p = moe.moe_init(jax.random.PRNGKey(8), cfg, jnp.float32)
    assert "shared" in p
    x = jax.random.normal(jax.random.PRNGKey(9), (1, 8, cfg.d_model))
    out, _ = moe.moe_apply(p, cfg, x)
    assert out.shape == x.shape


def test_grads_flow_to_router_and_experts():
    cfg = make_cfg(n_experts=4, top_k=2)
    p = moe.moe_init(jax.random.PRNGKey(10), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(11), (1, 16, cfg.d_model))

    def loss(p):
        out, aux = moe.moe_apply(p, cfg, x)
        return jnp.sum(out ** 2) + aux

    g = jax.grad(loss)(p)
    assert float(jnp.linalg.norm(g["router"])) > 0
    assert float(jnp.linalg.norm(g["w_in"])) > 0
