"""Quantized/low-precision ring KV cache (NumericsPolicy.kv_cache_dtype).

int8 storage keeps per-head-per-slot fp32 scales next to the int8 k/v
leaves; dequantization happens in-kernel for the Pallas decode path and
at the einsum boundary for XLA.  The contract under test:

  * prefill/decode logits under int8 (and bf16) storage track the fp32
    cache within a documented tolerance (atol/rtol 5e-2 end-to-end on a
    reduced real arch — observed ~8e-3);
  * the Pallas decode kernel's in-kernel dequant is PARITY-tight against
    the reference dequant (same quantized operands, atol 2e-4);
  * quant/dequant round-trip error is bounded by the per-head scale;
  * scale leaves ride slot surgery and cache sharding like any other
    cache leaf (path-generic machinery, one regex rule in specs).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, models
from repro.kernels.decode_attention import ops as da_ops
from repro.kernels.decode_attention import ref as da_ref
from repro.models import attention as attn
from repro.numerics import NumericsPolicy


@pytest.fixture(scope="module")
def setup():
    cfg = configs.reduced(configs.get_config("olmo-1b"))
    params = models.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    return cfg, params, toks


def _kv_cfg(cfg, kv):
    return dataclasses.replace(cfg, numerics=NumericsPolicy(kv_cache_dtype=kv))


def _cache_dtypes(cache):
    return {l.dtype for l in jax.tree.leaves(cache)}


def test_int8_cache_layout(setup):
    cfg, params, toks = setup
    _, st = models.prefill(params, _kv_cfg(cfg, "int8"), toks, 32)
    flat = jax.tree_util.tree_flatten_with_path(st.cache)[0]
    names = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path) for path, _ in flat]
    assert any("k_scale" in n for n in names)
    assert any("v_scale" in n for n in names)
    for name, leaf in zip(names, flat):
        if name.endswith("k_scale") or name.endswith("v_scale"):
            assert leaf[1].dtype == jnp.float32
        elif name.endswith("/k") or name.endswith("/v"):
            assert leaf[1].dtype == jnp.int8


def test_quant_dequant_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, 2, 16), jnp.float32)
    q, scale = attn._kv_quant(x)
    assert q.dtype == jnp.int8 and scale.shape == (2, 8, 2)
    back = attn._kv_dequant(q, scale)
    # error bounded by half a quantization bin per element
    bound = np.asarray(scale)[..., None] * 0.5 + 1e-6
    assert (np.abs(np.asarray(back - x)) <= bound).all()


@pytest.mark.parametrize("kv,atol", [("int8", 5e-2), ("bf16", 5e-2)])
def test_prefill_decode_close_to_fp32(setup, kv, atol):
    cfg, params, toks = setup
    lg0, st0 = models.prefill(params, cfg, toks, 32)
    lgq, stq = models.prefill(params, _kv_cfg(cfg, kv), toks, 32)
    np.testing.assert_allclose(np.asarray(lgq), np.asarray(lg0),
                               atol=atol, rtol=atol)
    nt = jnp.asarray([[3], [5]], jnp.int32)
    d0, _ = models.decode_step(params, cfg, st0, nt)
    dq, stq2 = models.decode_step(params, _kv_cfg(cfg, kv), stq, nt)
    np.testing.assert_allclose(np.asarray(dq), np.asarray(d0),
                               atol=atol, rtol=atol)
    # storage dtype survives the step (no silent upcast of the ring)
    want = jnp.dtype(jnp.int8 if kv == "int8" else jnp.bfloat16)
    assert want in _cache_dtypes(stq2.cache)


def test_pallas_int8_decode_parity_with_ref():
    """Same quantized operands through the Pallas kernel's in-kernel
    dequant vs the reference's explicit dequant: parity-tight."""
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    b, w, hkv, g, hd = 2, 40, 2, 2, 32
    q = jax.random.normal(ks[0], (b, hkv, g, hd))
    kf = jax.random.normal(ks[1], (b, w, hkv, hd))
    vf = jax.random.normal(ks[2], (b, w, hkv, hd))
    pos = jnp.asarray([5, 97], jnp.int32)
    kq, ksc = attn._kv_quant(kf)
    vq, vsc = attn._kv_quant(vf)
    o_ref = da_ref.decode_attention_ref(q, kq, vq, pos, window=32,
                                        scale=hd ** -0.5,
                                        k_scale=ksc, v_scale=vsc)
    o_pl = da_ops.decode_attention(q, kq, vq, pos, window=32,
                                   scale=hd ** -0.5, interpret=True,
                                   k_scale=ksc, v_scale=vsc)
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_ref),
                               atol=2e-4, rtol=2e-4)
    # and the quantized path lands near the unquantized fp32 one
    o_fp = da_ref.decode_attention_ref(q, kf, vf, pos, window=32,
                                       scale=hd ** -0.5)
    assert float(jnp.abs(o_ref - o_fp).max()) < 5e-2


def test_slot_surgery_carries_scales(setup):
    cfg, params, toks = setup
    cfg8 = _kv_cfg(cfg, "int8")
    _, st = models.prefill(params, cfg8, toks, 32)
    _, sub = models.prefill(params, cfg8, toks[:1], 32)
    out = models.write_slots(st, sub, jnp.asarray([1]))
    for (p1, a), (p2, b) in zip(
            jax.tree_util.tree_flatten_with_path(st.cache)[0],
            jax.tree_util.tree_flatten_with_path(out.cache)[0]):
        assert a.shape == b.shape and a.dtype == b.dtype, (p1, a, b)


def test_scale_leaves_get_cache_sharding(setup):
    """cache_sharding must co-shard the (B, S, Hkv) scale leaves with the
    k/v leaves they dequantize: heads on 'model', never the capacity
    axis — a scale sharded along the ring would force a gather inside
    every decode tick."""
    from repro.sharding.specs import cache_sharding
    cfg, params, toks = setup
    _, st = models.prefill(params, _kv_cfg(cfg, "int8"), toks, 32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shardings = cache_sharding(st.cache, cfg, mesh)
    flat = jax.tree_util.tree_flatten_with_path(shardings)[0]
    seen = 0
    for path, sh in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        if name.endswith("k_scale") or name.endswith("v_scale"):
            seen += 1
            spec = tuple(sh.spec)
            assert spec[-1] == "model", (name, spec)     # head axis
            assert spec[-2] is None, (name, spec)        # ring axis free
    assert seen > 0
