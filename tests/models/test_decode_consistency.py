"""Decode-vs-full-forward consistency — the core cache-correctness property
for every attention/recurrence family, including ring-buffer wraparound and
prefill-then-continue."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import ARCHS, reduced
from repro.kernels.common import KernelPolicy
from repro.models import encdec, transformer

XLA_ATTN = KernelPolicy(attention="xla")

CASES = ["olmo-1b", "gemma-7b", "minitron-8b", "rwkv6-7b",
         "recurrentgemma-9b", "phi-3-vision-4.2b"]


def _decode_all(cfg, params, toks, cache, start=0):
    outs = []
    for t in range(start, toks.shape[1]):
        lg, cache = transformer.decode_step(params, cfg, cache,
                                            toks[:, t:t + 1], t)
        outs.append(lg[:, 0])
    return jnp.stack(outs, 1), cache


@pytest.mark.parametrize("arch", CASES)
def test_decode_equals_forward(arch, rng):
    cfg = reduced(ARCHS[arch])
    if arch == "recurrentgemma-9b":
        cfg = dataclasses.replace(cfg, n_layers=4)  # 1 superblock + rem
    params = models.init(rng, cfg)
    b, s = 2, 32
    toks = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    cfg = dataclasses.replace(cfg, kernels=XLA_ATTN)
    full, _ = transformer.forward(params, cfg, toks)
    cache = transformer.init_decode_cache(cfg, b, s)
    dec, _ = _decode_all(cfg, params, toks, cache)
    np.testing.assert_allclose(dec, full, rtol=2e-4, atol=2e-4)


def test_moe_decode_equals_forward_nodrop(rng):
    cfg = reduced(ARCHS["mixtral-8x7b"])
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe,
                                     capacity_factor=float(cfg.moe.n_experts)))
    params = models.init(rng, cfg)
    b, s = 2, 32
    toks = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    cfg = dataclasses.replace(cfg, kernels=XLA_ATTN)
    full, _ = transformer.forward(params, cfg, toks)
    cache = transformer.init_decode_cache(cfg, b, s)
    dec, _ = _decode_all(cfg, params, toks, cache)
    np.testing.assert_allclose(dec, full, rtol=2e-4, atol=2e-4)


def test_swa_ring_wraparound(rng):
    """Window smaller than sequence: ring buffer must wrap correctly."""
    cfg = dataclasses.replace(reduced(ARCHS["gemma-7b-swa"]),
                              sliding_window=16)
    params = models.init(rng, cfg)
    b, s = 2, 48
    toks = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    cfg = dataclasses.replace(cfg, kernels=XLA_ATTN)
    full, _ = transformer.forward(params, cfg, toks)
    cache = transformer.init_decode_cache(cfg, b, s)
    assert cache["blocks"][0]["k"].shape[2] == 16   # capacity == window
    dec, _ = _decode_all(cfg, params, toks, cache)
    np.testing.assert_allclose(dec, full, rtol=2e-4, atol=2e-4)


def test_prefill_then_decode(rng):
    cfg = dataclasses.replace(reduced(ARCHS["gemma-7b-swa"]),
                              sliding_window=16)
    params = models.init(rng, cfg)
    b, s, extra = 2, 32, 8
    toks = jax.random.randint(rng, (b, s + extra), 0, cfg.vocab_size)
    cfg = dataclasses.replace(cfg, kernels=XLA_ATTN)
    full, _ = transformer.forward(params, cfg, toks)
    _, _, cache = transformer.forward(params, cfg, toks[:, :s],
                                      return_cache=True)
    for t in range(s, s + extra):
        lg, cache = transformer.decode_step(params, cfg, cache,
                                            toks[:, t:t + 1], t)
        np.testing.assert_allclose(lg[:, 0], full[:, t], rtol=2e-4, atol=2e-4)


def test_prefill_then_decode_rwkv(rng):
    cfg = reduced(ARCHS["rwkv6-7b"])
    params = models.init(rng, cfg)
    b, s, extra = 2, 64, 8
    toks = jax.random.randint(rng, (b, s + extra), 0, cfg.vocab_size)
    cfg = dataclasses.replace(cfg, kernels=XLA_ATTN)
    full, _ = transformer.forward(params, cfg, toks)
    _, _, cache = transformer.forward(params, cfg, toks[:, :s],
                                      return_cache=True)
    for t in range(s, s + extra):
        lg, cache = transformer.decode_step(params, cfg, cache,
                                            toks[:, t:t + 1], t)
        np.testing.assert_allclose(lg[:, 0], full[:, t], rtol=3e-4, atol=3e-4)


def test_encdec_decode_equals_forward(rng):
    cfg = reduced(ARCHS["seamless-m4t-medium"])
    params = models.init(rng, cfg)
    b, s, enc_len = 2, 24, 16
    toks = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    frames = jax.random.normal(rng, (b, enc_len, cfg.d_model))
    full, _ = encdec.forward(params, cfg, frames, toks)
    mem = encdec.encode(params, cfg, frames)
    cache = encdec.init_decode_cache(cfg, b, s, enc_len)
    cache = {"self": cache["self"],
             "cross": encdec.build_cross_cache(params, cfg, mem)}
    outs = []
    for t in range(s):
        lg, cache = encdec.decode_step(params, cfg, cache, toks[:, t:t + 1], t)
        outs.append(lg[:, 0])
    np.testing.assert_allclose(jnp.stack(outs, 1), full, rtol=2e-4, atol=2e-4)
