"""Per-assigned-architecture smoke tests (required deliverable f).

Each instantiates a REDUCED same-family variant (2 layers, d_model<=512,
<=4 experts) and runs one forward + one train step on CPU, asserting output
shapes and no NaNs.  The FULL configs are exercised only via the dry-run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import ALEXNET_SMOKE, ARCHS, ASSIGNED, reduced
from repro.core import (init_param_avg_state, make_param_avg_step,
                        reshape_for_replicas)
from repro.optim import schedules
from repro.optim.optimizers import sgd_momentum

B, S = 2, 64


def make_batch(cfg, rng, b=B, s=S):
    ks = jax.random.split(rng, 3)
    batch = {"tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size),
             "labels": jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(ks[2], (b, s // 4, cfg.d_model))
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            ks[2], (b, cfg.n_image_tokens, cfg.d_model))
        mask = jnp.zeros((b, s), bool).at[:, :cfg.n_image_tokens].set(True)
        batch["image_mask"] = mask
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_and_train_step(arch, rng):
    cfg = reduced(ARCHS[arch])
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    params = models.init(rng, cfg)
    batch = make_batch(cfg, rng)

    logits, aux = models.logits_fn(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert not bool(jnp.isnan(aux))

    # one param-averaging train step over 2 replicas
    opt = sgd_momentum()
    state = init_param_avg_state(
        rng, lambda r: models.init(r, cfg), opt, 2)
    step = jax.jit(make_param_avg_step(
        lambda p, b: models.loss_fn(p, cfg, b), opt,
        schedules.constant(1e-2)))
    state2, loss = step(state, reshape_for_replicas(batch, 2))
    assert np.isfinite(float(loss))
    for a, b_ in zip(jax.tree.leaves(state.params),
                     jax.tree.leaves(state2.params)):
        assert a.shape == b_.shape
    assert not any(bool(jnp.any(jnp.isnan(x.astype(jnp.float32))))
                   for x in jax.tree.leaves(state2.params))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_serve_step(arch, rng):
    """One-token decode with a KV cache (the decode_32k/long_500k path)."""
    cfg = reduced(ARCHS[arch])
    params = models.init(rng, cfg)
    cache = models.init_decode_cache(cfg, B, 32, enc_len=16)
    if cfg.family == "encdec":
        from repro.models import encdec
        mem = encdec.encode(params, cfg,
                            jax.random.normal(rng, (B, 16, cfg.d_model)))
        cache = {"self": cache["self"],
                 "cross": encdec.build_cross_cache(params, cfg, mem)}
    toks = jax.random.randint(rng, (B, 1), 0, cfg.vocab_size)
    logits, new_cache = models.decode_step(params, cfg, cache, toks, 5)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


def test_alexnet_smoke(rng):
    from repro.models import alexnet
    cfg = ALEXNET_SMOKE
    params = alexnet.init(rng, cfg)
    imgs = jax.random.normal(rng, (4, cfg.image_size, cfg.image_size, 3))
    labels = jax.random.randint(rng, (4,), 0, cfg.n_classes)
    logits = alexnet.forward(params, cfg, imgs)
    assert logits.shape == (4, cfg.n_classes)
    loss = alexnet.loss_fn(params, cfg, imgs, labels, train=True,
                           dropout_rng=rng)
    assert np.isfinite(float(loss))
    g = jax.grad(alexnet.loss_fn)(params, cfg, imgs, labels)
    assert not any(bool(jnp.any(jnp.isnan(x))) for x in jax.tree.leaves(g))


def test_remat_matches(rng):
    """Gradient with remat == gradient without (numerics identical)."""
    cfg = reduced(ARCHS["olmo-1b"])
    params = models.init(rng, cfg)
    batch = make_batch(cfg, rng)
    g1 = jax.grad(lambda p: models.loss_fn(p, cfg, batch, remat=False))(params)
    g2 = jax.grad(lambda p: models.loss_fn(p, cfg, batch, remat=True))(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_unroll_matches(rng):
    """UNROLL=True (dry-run aux path) computes the same function."""
    from repro.models import _unroll
    cfg = reduced(ARCHS["recurrentgemma-9b"], n_layers=2)
    params = models.init(rng, cfg)
    batch = make_batch(cfg, rng)
    l1 = models.loss_fn(params, cfg, batch)
    try:
        _unroll.UNROLL = True
        l2 = models.loss_fn(params, cfg, batch)
    finally:
        _unroll.UNROLL = False
    np.testing.assert_allclose(l1, l2, rtol=1e-5, atol=1e-6)
