"""Decode-parity suite for the DecodeState contract (docs/serving.md):
prefill + step-by-step decode must reproduce the full-sequence forward
logits for EVERY model family, on BOTH kernel backends — including the
SWA ring-buffer wraparound, GQA group-sum, and the bucketed (right-
padded, per-row ``length``) prefill the serving engine relies on."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import ARCHS, reduced
from repro.kernels.common import KernelPolicy

TOL = 1e-4

# one representative per family; gemma-7b-swa adds ring wraparound
# (window 16 < seq) and GQA in one case
FAMILY_CASES = {
    "dense": "olmo-1b",
    "dense-swa-gqa": "gemma-7b-swa",
    "moe": "mixtral-8x7b",
    "ssm": "rwkv6-7b",
    "hybrid": "recurrentgemma-9b",
    "encdec": "seamless-m4t-medium",
    "vlm": "phi-3-vision-4.2b",
}
BACKENDS = {
    "xla": KernelPolicy(backend="xla"),
    # CPU hosts run the Pallas kernels (flash prefill + flash-decode) in
    # interpret mode — same code path the compiled backend takes
    "pallas": KernelPolicy(backend="pallas"),
}


def _case_cfg(case, backend):
    cfg = reduced(ARCHS[FAMILY_CASES[case]])
    if case == "dense-swa-gqa":
        cfg = dataclasses.replace(cfg, sliding_window=16, n_kv_heads=2)
        assert cfg.n_kv_heads < cfg.n_heads       # GQA stays on
    if case == "hybrid":
        cfg = dataclasses.replace(cfg, n_layers=4)  # superblock + remainder
    if case == "moe":
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    return dataclasses.replace(cfg, kernels=BACKENDS[backend])


def _batch(cfg, rng, b, s):
    extras = {}
    if cfg.family == "encdec":
        extras["frames"] = jax.random.normal(rng, (b, 16, cfg.d_model))
    if cfg.family == "vlm":
        extras["image_embeds"] = jax.random.normal(
            rng, (b, cfg.n_image_tokens, cfg.d_model))
        extras["image_mask"] = jnp.zeros(
            (b, s), bool).at[:, :cfg.n_image_tokens].set(True)
    return jax.random.randint(rng, (b, s), 0, cfg.vocab_size), extras


def _full_logits(params, cfg, toks, extras):
    if cfg.family == "encdec":
        from repro.models import encdec
        return encdec.forward(params, cfg, extras["frames"], toks)[0]
    from repro.models import transformer
    return transformer.forward(params, cfg, toks, **extras)[0]


@pytest.mark.parametrize("backend", sorted(BACKENDS))
@pytest.mark.parametrize("case", sorted(FAMILY_CASES))
def test_prefill_then_decode_matches_forward(case, backend, rng):
    cfg = _case_cfg(case, backend)
    params = models.init(rng, cfg)
    b, s, split = 2, 32, 20
    if case == "dense-swa-gqa":
        s, split = 48, 24                 # ring (cap 16) wraps twice
    toks, extras = _batch(cfg, rng, b, s)
    full = _full_logits(params, cfg, toks, extras)

    pf_extras = dict(extras)
    if "image_mask" in pf_extras:       # the mask spans the prompt only
        pf_extras["image_mask"] = pf_extras["image_mask"][:, :split]
    logits, st = models.prefill(params, cfg, toks[:, :split], s, **pf_extras)
    np.testing.assert_allclose(logits, full[:, :split], rtol=TOL, atol=TOL)
    assert st.pos.tolist() == [split] * b
    for t in range(split, s):
        lg, st = models.decode_step(params, cfg, st, toks[:, t:t + 1])
        np.testing.assert_allclose(lg[:, 0], full[:, t], rtol=TOL, atol=TOL)
    assert st.pos.tolist() == [s] * b


@pytest.mark.parametrize("backend", sorted(BACKENDS))
@pytest.mark.parametrize("case", ["dense-swa-gqa", "ssm", "hybrid"])
def test_bucketed_prefill_matches_unpadded(case, backend, rng):
    """Right-padded prefill at per-row ``length`` == unpadded prefill:
    ring writes, recurrent carries and positions all mask the padding
    (this is the property the serving engine's buckets stand on)."""
    cfg = _case_cfg(case, backend)
    params = models.init(rng, cfg)
    cap, bucket = 48, 16
    L = [9, 14]
    toks, extras = _batch(cfg, rng, 2, bucket)
    lengths = jnp.asarray(L, jnp.int32)
    lg_pad, st_pad = models.prefill(params, cfg, toks, cap, length=lengths,
                                    **extras)
    # each row's state + last-real-position logits == its unpadded prefill,
    # and stays in lockstep through 4 more greedy decode steps
    refs, curs = [], []
    for i, li in enumerate(L):
        lg_ref, st_ref = models.prefill(params, cfg, toks[i:i + 1, :li],
                                        cap, **_slice_extras(extras, i, li))
        np.testing.assert_allclose(lg_pad[i, li - 1], lg_ref[0, -1],
                                   rtol=TOL, atol=TOL)
        refs.append(st_ref)
        curs.append(jnp.argmax(lg_ref[:, -1:], -1).astype(jnp.int32))
    cur_pad = jnp.concatenate(curs, axis=0)
    for _ in range(4):
        lg_pad2, st_pad = models.decode_step(params, cfg, st_pad, cur_pad)
        nxt = []
        for i in range(len(L)):
            lg_ref2, refs[i] = models.decode_step(params, cfg, refs[i],
                                                  curs[i])
            np.testing.assert_allclose(lg_pad2[i, 0], lg_ref2[0, 0],
                                       rtol=TOL, atol=TOL)
            curs[i] = jnp.argmax(lg_ref2[:, 0], -1).astype(jnp.int32)[:, None]
            nxt.append(curs[i])
        cur_pad = jnp.concatenate(nxt, axis=0)


def _slice_extras(extras, i, li):
    out = {}
    if "frames" in extras:
        out["frames"] = extras["frames"][i:i + 1]
    if "image_embeds" in extras:
        out["image_embeds"] = extras["image_embeds"][i:i + 1]
        out["image_mask"] = extras["image_mask"][i:i + 1, :li]
    return out


def test_vector_pos_decode_matches_scalar(rng):
    """(B,) per-row positions: rows decoding at different depths in one
    call agree with each row decoded alone at its scalar position."""
    cfg = dataclasses.replace(reduced(ARCHS["gemma-7b-swa"]),
                              sliding_window=16,
                              kernels=KernelPolicy(backend="xla"))
    params = models.init(rng, cfg)
    cap = 24
    toks, _ = _batch(cfg, rng, 2, 20)
    # two rows prefilled to different depths via length masking
    L = jnp.asarray([7, 19], jnp.int32)
    _, st = models.prefill(params, cfg, toks, cap, length=L)
    step_tok = jax.random.randint(rng, (2, 1), 0, cfg.vocab_size)
    lg_vec, _ = models.decode_step(params, cfg, st, step_tok)
    for i in range(2):
        li = int(L[i])
        _, st_i = models.prefill(params, cfg, toks[i:i + 1, :li], cap)
        lg_i, _ = models.decode_step(params, cfg, st_i, step_tok[i:i + 1])
        np.testing.assert_allclose(lg_vec[i], lg_i[0], rtol=TOL, atol=TOL)


def test_decode_step_guards_unknown_family(rng):
    cfg = dataclasses.replace(reduced(ARCHS["olmo-1b"]), family="mamba")
    with pytest.raises(NotImplementedError, match="DecodeState contract"):
        models.init_decode_state(cfg, 2, 16)
    with pytest.raises(NotImplementedError, match="mamba"):
        models.decode_step(None, cfg, {"blocks": ()},
                           jnp.zeros((1, 1), jnp.int32), 0)


def test_decode_state_api_shape_contract(rng):
    cfg = reduced(ARCHS["olmo-1b"])
    st = models.init_decode_state(cfg, 3, 16)
    assert st.pos.shape == (3,) and st.pos.dtype == jnp.int32
    # pytree round-trip (jit boundary crossing)
    leaves, treedef = jax.tree_util.tree_flatten(st)
    st2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(st2, models.DecodeState)
    with pytest.raises(ValueError, match="DecodeState.pos"):
        models.decode_step(None, cfg, st, jnp.zeros((3, 1), jnp.int32), 5)
