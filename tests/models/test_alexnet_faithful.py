"""Paper-faithful AlexNet fidelity: the exact Krizhevsky-2012 topology
(grouped conv2/4/5, pool-then-LRN ordering, 60,965,224 parameters) — and
the guarantee that the legacy ``faithful=False`` nets' numerics did not
move when the faithful path landed."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import (ALEXNET, ALEXNET_FAITHFUL, ALEXNET_FAITHFUL_SMOKE,
                           ALEXNET_SMOKE)
from repro.configs.alexnet import AlexNetConfig, ConvSpec
from repro.kernels.common import KernelPolicy
from repro.models import alexnet


def test_faithful_param_count_is_the_canonical_61m():
    """The dual-GPU grouping halves conv2/4/5 fan-in: exactly the
    published 60,965,224 parameters (~61M)."""
    assert ALEXNET_FAITHFUL.n_params() == 60_965_224
    # the ungrouped variant is strictly bigger — grouping is load-bearing
    assert ALEXNET.n_params() > ALEXNET_FAITHFUL.n_params()


def test_faithful_topology():
    groups = [cs.groups for cs in ALEXNET_FAITHFUL.convs]
    assert groups == [1, 2, 1, 2, 2]          # conv2/4/5 split across GPUs
    lrn = [cs.lrn for cs in ALEXNET_FAITHFUL.convs]
    assert lrn == [True, True, False, False, False]
    assert ALEXNET_FAITHFUL.faithful
    assert (ALEXNET_FAITHFUL.lrn_n, ALEXNET_FAITHFUL.lrn_alpha,
            ALEXNET_FAITHFUL.lrn_beta, ALEXNET_FAITHFUL.lrn_k) == \
        (5, 1e-4, 0.75, 2.0)


def test_param_count_matches_init():
    """n_params() vs the actual init tree, grouped and ungrouped."""
    for cfg in (ALEXNET_SMOKE, ALEXNET_FAITHFUL_SMOKE):
        shapes = jax.eval_shape(
            lambda c=cfg: alexnet.init(jax.random.PRNGKey(0), c))
        total = sum(int(np.prod(l.shape))
                    for l in jax.tree.leaves(shapes))
        assert total == cfg.n_params(), cfg.name


def test_grouped_weight_shapes():
    params = jax.eval_shape(
        lambda: alexnet.init(jax.random.PRNGKey(0), ALEXNET_FAITHFUL_SMOKE))
    c_in = ALEXNET_FAITHFUL_SMOKE.in_channels
    for cp, cs in zip(params["convs"], ALEXNET_FAITHFUL_SMOKE.convs):
        assert cp["w"].shape == (cs.kernel, cs.kernel, c_in // cs.groups,
                                 cs.out_channels)
        c_in = cs.out_channels


def test_config_rejects_indivisible_groups():
    with pytest.raises(ValueError, match="groups"):
        AlexNetConfig(name="bad", convs=(
            ConvSpec(16, 3, 1, 1, pool=False, lrn=False, groups=3),))


def _manual_forward(params, cfg, images, lrn_after_pool):
    """Layer loop written out by hand — the ordering oracle."""
    h = images
    for cp, cs in zip(params["convs"], cfg.convs):
        h = alexnet.conv2d(h, cp["w"], cp["b"], cs.stride, cs.padding,
                           "xla", relu=True, groups=cs.groups)
        if cs.lrn and not lrn_after_pool:
            h = alexnet.lrn(h, cfg.lrn_n, cfg.lrn_alpha, cfg.lrn_beta,
                            cfg.lrn_k)
        if cs.pool:
            h = alexnet.maxpool(h)
        if cs.lrn and lrn_after_pool:
            h = alexnet.lrn(h, cfg.lrn_n, cfg.lrn_alpha, cfg.lrn_beta,
                            cfg.lrn_k)
    h = h.reshape(h.shape[0], -1)
    for i, fp in enumerate(params["fcs"]):
        if i > 0:
            h = jax.nn.relu(h)
        h = h @ fp["w"] + fp["b"]
    return h.astype(jnp.float32)


@pytest.mark.parametrize("cfg,after_pool", [
    (ALEXNET_SMOKE, False),             # legacy: LRN BEFORE pool (PR 2)
    (ALEXNET_FAITHFUL_SMOKE, True),     # faithful: pool THEN LRN (Caffe)
], ids=["legacy", "faithful"])
def test_lrn_ordering(cfg, after_pool):
    """The faithful flag switches pool/LRN order and ONLY that — each
    flavour equals the hand-written oracle with the matching order (and
    differs from the other order, so the switch is observable)."""
    params = alexnet.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (2, cfg.image_size, cfg.image_size,
                           cfg.in_channels))
    got = alexnet.forward(params, cfg, x, conv_backend="xla")
    exp = _manual_forward(params, cfg, x, lrn_after_pool=after_pool)
    np.testing.assert_allclose(got, exp, rtol=1e-5, atol=1e-5)
    other = _manual_forward(params, cfg, x, lrn_after_pool=not after_pool)
    assert not np.allclose(got, other, rtol=1e-5, atol=1e-5)


def test_faithful_backends_agree():
    """xla == fused pallas == block-diag im2col on the grouped net."""
    cfg = ALEXNET_FAITHFUL_SMOKE
    params = alexnet.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2),
                          (2, cfg.image_size, cfg.image_size,
                           cfg.in_channels))
    ref = np.asarray(alexnet.forward(params, cfg, x, conv_backend="xla"))
    for backend in ("pallas", "pallas_im2col_ref"):
        got = np.asarray(alexnet.forward(params, cfg, x,
                                         conv_backend=backend))
        np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3,
                                   err_msg=backend)


def test_faithful_trains_a_step():
    """grad through the grouped+LRN net is finite and moves the loss."""
    cfg = ALEXNET_FAITHFUL_SMOKE
    params = alexnet.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, cfg.image_size,
                                                  cfg.image_size,
                                                  cfg.in_channels))
    y = jnp.array([0, 1, 2, 3])

    def loss(p):
        return alexnet.loss_fn(p, cfg, x, y)

    l0, g = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(l0))
    assert all(np.isfinite(np.asarray(leaf)).all()
               for leaf in jax.tree.leaves(g))
    p2 = jax.tree.map(lambda p, d: p - 0.05 * d, params, g)
    assert float(loss(p2)) < float(l0)


def test_models_api_routes_conv_family():
    """models.init/loss_fn/model_inputs serve the conv family so the
    golden-trace loop and serving engine need no special casing."""
    cfg = ALEXNET_FAITHFUL_SMOKE
    spec = models.model_inputs(cfg, 2, 16)
    assert spec["images"][0] == (2, cfg.image_size, cfg.image_size,
                                 cfg.in_channels)
    assert spec["labels"][0] == (2,)
    params = models.init(jax.random.PRNGKey(0), cfg)
    batch = {"images": jnp.zeros(spec["images"][0], spec["images"][1]),
             "labels": jnp.zeros((2,), jnp.int32)}
    loss = models.loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss))


def test_legacy_policy_still_resolves():
    """KernelPolicy routing reaches the new lrn op."""
    cfg = dataclasses.replace(ALEXNET_FAITHFUL_SMOKE,
                              kernels=KernelPolicy(backend="pallas"))
    assert alexnet.resolve_lrn_backend(cfg) == "pallas"
    cfg = dataclasses.replace(ALEXNET_FAITHFUL_SMOKE,
                              kernels=KernelPolicy(backend="xla"))
    assert alexnet.resolve_lrn_backend(cfg) == "xla"
