"""Optional-dependency shim for ``hypothesis``.

Property-based tests import ``given``/``settings``/``st`` from here instead
of from hypothesis directly.  With hypothesis installed (requirements-
dev.txt) they run as real property tests; without it they are skipped
individually while every non-property test in the same module still runs —
the suite must collect cleanly on a bare jax+pytest environment.
"""
__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for ``hypothesis.strategies``: every strategy builder
        returns None — the values are never drawn because ``given`` skips
        the test before it runs."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        return lambda f: f

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed "
                                       "(see requirements-dev.txt)")
