"""Flash-decode kernel (single token vs ring cache): parity with the
slot-arithmetic oracle across ring states, GQA groupings and windows —
plus the policy resolver and the non-differentiability contract."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.kernels.common import KernelPolicy
from repro.kernels.decode_attention import ops, ref
from repro.kernels.decode_attention.decode_attention import decode_blocks
from repro.models.attention import resolve_decode_impl


def _inputs(b, w, hkv, g, hd, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (b, hkv, g, hd)),
            jax.random.normal(ks[1], (b, w, hkv, hd)),
            jax.random.normal(ks[2], (b, w, hkv, hd)))


@pytest.mark.parametrize("b,w,hkv,g,hd,window,pos", [
    (2, 64, 2, 2, 32, None, [0, 63]),       # first token + exactly full
    (2, 64, 1, 4, 32, None, [5, 200]),      # mid-fill + wrapped (GQA 4)
    (1, 40, 2, 1, 32, None, [39]),          # odd capacity (pad path)
    (2, 16, 2, 2, 64, 16, [7, 100]),        # SWA ring at window capacity
    (1, 48, 4, 2, 32, 32, [45]),            # window < capacity
])
def test_decode_kernel_matches_ref(b, w, hkv, g, hd, window, pos):
    q, k, v = _inputs(b, w, hkv, g, hd)
    pos = jnp.asarray(pos, jnp.int32)
    scale = hd ** -0.5
    out = ops.decode_attention(q, k, v, pos, window=window, scale=scale,
                               impl="pallas")
    exp = ref.decode_attention_ref(q, k, v, pos, window=window, scale=scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-4, atol=2e-4)


def test_rows_at_different_depths_disagree_with_lockstep():
    """The per-row mask is real: evaluating row 1 at row 0's position
    changes its output (so a shared-scalar fallback would be WRONG)."""
    q, k, v = _inputs(2, 32, 2, 2, 32)
    o = ref.decode_attention_ref(q, k, v, jnp.asarray([3, 30]), scale=0.2)
    o_lock = ref.decode_attention_ref(q, k, v, jnp.asarray([3, 3]),
                                      scale=0.2)
    np.testing.assert_allclose(o[0], o_lock[0], rtol=1e-6, atol=1e-6)
    assert float(jnp.max(jnp.abs(o[1] - o_lock[1]))) > 1e-3


def test_slot_positions_oracle():
    """Ring slot i holds pos - ((pos - i) mod W): the last W positions."""
    sp = np.asarray(ref.slot_positions(jnp.asarray([2, 7]), 4))
    np.testing.assert_array_equal(sp[0], [0, 1, 2, -1])   # slot 3 unwritten
    np.testing.assert_array_equal(sp[1], [4, 5, 6, 7])    # fully wrapped
    assert sp.max() == 7


def test_registered_not_differentiable():
    from repro.kernels import common
    op = common.get_op("decode_attention")
    assert not op.differentiable
    assert op.tuner is decode_blocks


def test_resolver_follows_policy():
    def cfg(**pol):
        return dataclasses.replace(reduced(ARCHS["olmo-1b"]),
                                   kernels=KernelPolicy(**pol))
    assert resolve_decode_impl(cfg(backend="pallas")) == "pallas"
    assert resolve_decode_impl(cfg(backend="xla")) == "xla"
    assert resolve_decode_impl(cfg(decode_attention="pallas",
                                   backend="xla")) == "pallas"
    assert resolve_decode_impl(cfg(interpret=False)) == "pallas"
    if jax.default_backend() != "tpu":
        assert resolve_decode_impl(cfg()) == "xla"
    with pytest.raises(ValueError, match="unknown decode_attention"):
        resolve_decode_impl(cfg(decode_attention="cudnn"))


def test_tuner_uses_shared_cache():
    from repro.kernels import common
    common.clear_cache()
    assert decode_blocks(64, 32, "float32", interpret=False,
                         autotune=False) == (64,)
    assert common.cache_info()["measured"] == 0
    assert ("decode_attn", 64, 32, "float32") in \
        {k[:4] for k in common._CACHE}
    common.clear_cache()
