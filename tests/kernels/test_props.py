"""Property-based checks of the serving/kernel ARITHMETIC — the pure
index math that parity tests only probe at a handful of points:

* grouped-conv shape algebra (output spatial dims, parameter counts,
  group-major channel layout) vs brute-force oracles,
* the ring-cache slot/validity math (``slot_positions``) vs a literal
  write-loop simulation of the ring.

With hypothesis installed (requirements-dev.txt / CI) these explore the
space; without it the ``@given`` tests skip and the seeded sweeps below
keep the same oracles exercised on every bare-environment run."""
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.configs.alexnet import AlexNetConfig, ConvSpec
from repro.kernels.decode_attention.ref import slot_positions


# ------------------------------------------------------------- oracles ----

def _conv_out_hw(hw, kernel, stride, pad):
    return (hw + 2 * pad - kernel) // stride + 1


def _check_shape_algebra(hw, kernel, stride, pad, cig, npg, groups):
    """feature_hw / n_params vs brute force on a 1-conv net."""
    cin, cout = cig * groups, npg * groups
    out = _conv_out_hw(hw, kernel, stride, pad)
    if out < 1:
        with pytest.raises(ValueError):
            AlexNetConfig(
                name="p", image_size=hw, in_channels=cin, n_classes=3,
                convs=(ConvSpec(cout, kernel, stride, pad, pool=False,
                                lrn=False, groups=groups),),
                fc_dim=4).feature_hw()
        return
    cfg = AlexNetConfig(
        name="p", image_size=hw, in_channels=cin, n_classes=3,
        convs=(ConvSpec(cout, kernel, stride, pad, pool=False, lrn=False,
                        groups=groups),),
        fc_dim=4)
    assert cfg.feature_hw() == out
    # brute-force param count: each output channel sees only its group
    conv_params = kernel * kernel * cig * cout + cout
    fc = out * out * cout * 4 + 4 + 4 * 4 + 4 + 4 * 3 + 3
    assert cfg.n_params() == conv_params + fc


def _check_group_major_layout(cig, npg, groups, kernel=1, hw=3, seed=0):
    """The grouped conv's output channel layout == running each group's
    conv separately and concatenating (group-major Cout) — pinned against
    a per-group numpy loop, which is what makes out-channel sharding and
    the weight slicing in sharding/specs.py correct."""
    import jax
    from repro.kernels.conv2d import ref
    cin, cout = cig * groups, npg * groups
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    x = jax.random.normal(ks[0], (1, hw, hw, cin))
    w = jax.random.normal(ks[1], (kernel, kernel, cig, cout)) * 0.3
    got = np.asarray(ref.conv2d_ref(x, w, 1, 0, groups=groups))
    for g in range(groups):
        xs = x[..., g * cig:(g + 1) * cig]
        wg = w[..., g * npg:(g + 1) * npg]
        exp = np.asarray(ref.conv2d_ref(xs, wg, 1, 0))
        np.testing.assert_allclose(got[..., g * npg:(g + 1) * npg], exp,
                                   rtol=1e-5, atol=1e-5)


def _check_ring_slots(pos, cap):
    """slot_positions vs literally writing positions 0..pos into a ring."""
    ring = -np.ones(cap, np.int64)
    for t in range(pos + 1):
        ring[t % cap] = t
    sp = np.asarray(slot_positions(np.asarray([pos]), cap))[0]
    valid = sp >= 0
    # every valid slot holds exactly what the write loop left there
    np.testing.assert_array_equal(sp[valid], ring[valid])
    # validity == the write loop reached that slot
    np.testing.assert_array_equal(valid, ring >= 0)
    # invariants the decode kernels rely on
    assert (sp <= pos).all()
    assert (sp[valid] > pos - cap).all()            # within the window
    assert (sp[valid] % cap == np.flatnonzero(valid)).all()


def _check_window_mask(pos, cap, window):
    """The sliding-window validity mask == brute force over positions."""
    sp = np.asarray(slot_positions(np.asarray([pos]), cap))[0]
    got = (sp >= 0) & (sp > pos - window)
    exp = np.array([0 <= p and p > pos - window for p in sp])
    np.testing.assert_array_equal(got, exp)


# ------------------------------------------------- hypothesis explorers ----

@settings(max_examples=200, deadline=None)
@given(hw=st.integers(1, 40), kernel=st.integers(1, 11),
       stride=st.integers(1, 4), pad=st.integers(0, 5),
       cig=st.integers(1, 8), npg=st.integers(1, 8),
       groups=st.integers(1, 4))
def test_shape_algebra_property(hw, kernel, stride, pad, cig, npg, groups):
    _check_shape_algebra(hw, kernel, stride, pad, cig, npg, groups)


@settings(max_examples=50, deadline=None)
@given(cig=st.integers(1, 5), npg=st.integers(1, 5),
       groups=st.integers(1, 4), seed=st.integers(0, 7))
def test_group_major_layout_property(cig, npg, groups, seed):
    _check_group_major_layout(cig, npg, groups, seed=seed)


@settings(max_examples=300, deadline=None)
@given(pos=st.integers(0, 300), cap=st.integers(1, 64))
def test_ring_slots_property(pos, cap):
    _check_ring_slots(pos, cap)


@settings(max_examples=200, deadline=None)
@given(pos=st.integers(0, 200), cap=st.integers(1, 48),
       window=st.integers(1, 64))
def test_window_mask_property(pos, cap, window):
    _check_window_mask(pos, cap, window)


# ------------------------------------------ seeded always-on fallbacks ----

def test_shape_algebra_seeded_sweep():
    rs = np.random.default_rng(0)
    for _ in range(60):
        _check_shape_algebra(int(rs.integers(1, 40)),
                             int(rs.integers(1, 11)),
                             int(rs.integers(1, 4)), int(rs.integers(0, 5)),
                             int(rs.integers(1, 8)), int(rs.integers(1, 8)),
                             int(rs.integers(1, 4)))


def test_group_major_layout_seeded_sweep():
    for groups in (1, 2, 3, 4):
        _check_group_major_layout(3, 2, groups)
        _check_group_major_layout(1, 5, groups, seed=groups)


def test_ring_slots_seeded_sweep():
    rs = np.random.default_rng(1)
    for cap in (1, 2, 3, 8, 16, 31):
        for pos in {0, 1, cap - 1, cap, cap + 1, 3 * cap + 2,
                    int(rs.integers(0, 200))}:
            if pos >= 0:
                _check_ring_slots(int(pos), cap)
                _check_window_mask(int(pos), cap, int(rs.integers(1, 64)))
