"""Grouped convolution fidelity: the paper's dual-GPU intra-layer split
(conv2/4/5 of AlexNet) as native ``groups`` in every conv backend.

Parity: fused implicit-GEMM == block-diag im2col ref == lax.conv with
``feature_group_count``, forward AND VJP, on the real AlexNet grouped
geometries.  Structure: a jaxpr walk proves the fused path still never
materializes the (B*OH*OW, K*K*Cin) patch tensor, and that NOTHING inside
the Pallas kernel is sized by the full channel counts — each tile reads
only its group's input slice (the no-cross-group-reads acceptance)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.alexnet import FAITHFUL
from repro.kernels import common
from repro.kernels.conv2d import ops, ref


def _make(b, hw, cin, cout, kernel, groups, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(ks[0], (b, hw, hw, cin))
    w = jax.random.normal(ks[1], (kernel, kernel, cin // groups, cout)) * 0.1
    b_ = jax.random.normal(ks[2], (cout,)) * 0.1
    return x, w, b_


# the faithful net's grouped layers: real channel geometry, spatial dims
# reduced so interpret mode stays fast
GROUPED_ALEXNET = [
    pytest.param(cs.kernel, cs.stride, cs.padding, cin, cs.out_channels,
                 cs.groups, hw, id=f"conv{i + 1}")
    for i, (cs, cin, hw) in enumerate(zip(
        FAITHFUL.convs,
        [FAITHFUL.in_channels] + [c.out_channels
                                  for c in FAITHFUL.convs[:-1]],
        [27, 8, 6, 6, 6]))
    if cs.groups > 1
]


@pytest.mark.parametrize("kernel,stride,pad,cin,cout,groups,hw",
                         GROUPED_ALEXNET)
@pytest.mark.parametrize("impl", ["fused", "im2col_ref"])
def test_grouped_parity_alexnet_layers(kernel, stride, pad, cin, cout,
                                       groups, hw, impl):
    x, w, b = _make(2, hw, cin, cout, kernel, groups)
    fn = ops.conv2d_fused if impl == "fused" else ops.conv2d_im2col
    out = fn(x, w, stride=stride, padding=pad, bias=b, groups=groups)
    exp = ref.conv2d_ref(x, w, stride, pad, groups=groups) + b
    np.testing.assert_allclose(out, exp, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("hw,cin,cout,kernel,stride,pad,groups", [
    (13, 8, 12, 3, 1, 1, 2),
    (9, 6, 9, 3, 1, 1, 3),        # non-pow2 per-group channels
    (11, 16, 16, 5, 2, 2, 4),
    (8, 4, 8, 1, 1, 0, 2),        # 1x1 grouped
    (14, 12, 10, 3, 2, 1, 2),     # cout/g not a lane multiple
])
@pytest.mark.parametrize("impl", ["fused", "im2col_ref"])
def test_grouped_parity_sweep(hw, cin, cout, kernel, stride, pad, groups,
                              impl):
    x, w, _ = _make(2, hw, cin, cout, kernel, groups, seed=1)
    fn = ops.conv2d_fused if impl == "fused" else ops.conv2d_im2col
    out = fn(x, w, stride=stride, padding=pad, groups=groups)
    exp = ref.conv2d_ref(x, w, stride, pad, groups=groups)
    np.testing.assert_allclose(out, exp, rtol=2e-4, atol=2e-4)


def test_grouped_fused_gradients_match_xla():
    """custom_vjp with groups: dx, dw, db through a ReLU epilogue == the
    lax.conv grouped oracle's autodiff."""
    x, w, b = _make(2, 10, 8, 12, 3, 2, seed=2)

    def f_fused(x, w, b):
        return jnp.sum(jnp.sin(ops.conv2d_fused(
            x, w, stride=1, padding=1, bias=b, relu=True, groups=2)))

    def f_xla(x, w, b):
        return jnp.sum(jnp.sin(jnp.maximum(
            ref.conv2d_ref(x, w, 1, 1, groups=2) + b, 0.0)))

    got = jax.grad(f_fused, argnums=(0, 1, 2))(x, w, b)
    exp = jax.grad(f_xla, argnums=(0, 1, 2))(x, w, b)
    for g, e in zip(got, exp):
        np.testing.assert_allclose(g, e, rtol=2e-4, atol=1e-5)


def test_grouped_registered_in_kernel_registry():
    """'conv2d_grouped' rides the KernelOp registry — the registry-driven
    parity loop in test_grad_parity covers it on every run."""
    assert "conv2d_grouped" in common.ops()
    op = common.get_op("conv2d_grouped")
    assert op.differentiable


def test_grouped_rejects_bad_divisibility():
    x = jnp.ones((1, 8, 8, 6))
    with pytest.raises(ValueError):
        ops.conv2d_fused(x, jnp.ones((3, 3, 2, 8)), stride=1, padding=1,
                         groups=2)            # 2*2 != cin 6
    with pytest.raises(ValueError):
        ops.conv2d_fused(x, jnp.ones((3, 3, 3, 9)), stride=1, padding=1,
                         groups=2)            # cout 9 % 2 != 0


# ------------------------------------------------------- structure proofs ----

def _collect_shapes(jaxpr, out):
    for eqn in jaxpr.eqns:
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                out.add(tuple(aval.shape))
        for p in eqn.params.values():
            for sub in (p if isinstance(p, (list, tuple)) else (p,)):
                if isinstance(sub, jax.core.ClosedJaxpr):
                    _collect_shapes(sub.jaxpr, out)
                elif isinstance(sub, jax.core.Jaxpr):
                    _collect_shapes(sub, out)
    return out


def _pallas_inner_shapes(jaxpr, out):
    """Shapes that exist INSIDE pallas_call kernels only."""
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            inner = eqn.params["jaxpr"]
            _collect_shapes(getattr(inner, "jaxpr", inner), out)
        for p in eqn.params.values():
            for sub in (p if isinstance(p, (list, tuple)) else (p,)):
                if isinstance(sub, jax.core.ClosedJaxpr):
                    _pallas_inner_shapes(sub.jaxpr, out)
                elif isinstance(sub, jax.core.Jaxpr):
                    _pallas_inner_shapes(sub, out)
    return out


def test_grouped_fused_never_materializes_im2col():
    """groups > 1 must not fall back to a patch tensor: no intermediate
    with B*OH*OW*K*K*Cin elements anywhere in the fwd jaxpr (the grouped
    im2col ref provably has one — detector sanity)."""
    b_, hw, cin, cout, kernel, groups = 2, 11, 14, 20, 3, 2
    x = jnp.ones((b_, hw, hw, cin))
    w = jnp.ones((kernel, kernel, cin // groups, cout))
    oh = hw  # stride 1, pad 1, k 3
    patch_elems = b_ * oh * oh * kernel * kernel * cin

    fused = jax.make_jaxpr(lambda x, w: ops.conv2d_fused(
        x, w, stride=1, padding=1, groups=groups))(x, w)
    sizes = {int(np.prod(s)) for s in _collect_shapes(fused.jaxpr, set())}
    assert patch_elems not in sizes, \
        "grouped fused path materializes an im2col-sized tensor"

    ref_path = jax.make_jaxpr(lambda x, w: ops.conv2d_im2col(
        x, w, stride=1, padding=1, groups=groups))(x, w)
    ref_sizes = {int(np.prod(s)) for s in _collect_shapes(ref_path.jaxpr,
                                                          set())}
    assert patch_elems in ref_sizes, "detector failed to see ref's patches"


def test_grouped_kernel_never_reads_across_groups():
    """Inside the Pallas kernel no array carries the FULL channel counts:
    every ref is sized by Cin/G and (padded) Cout/G — the block-index
    maps hand each output tile exactly its own group's input slice, so a
    cross-group read is structurally impossible."""
    b_, hw, cin, cout, kernel, groups = 2, 10, 14, 20, 3, 2
    x = jnp.ones((b_, hw, hw, cin))
    w = jnp.ones((kernel, kernel, cin // groups, cout))
    jaxpr = jax.make_jaxpr(lambda x, w: ops.conv2d_fused(
        x, w, stride=1, padding=1, groups=groups, bm=32, bn=16))(x, w)
    inner = _pallas_inner_shapes(jaxpr.jaxpr, set())
    assert inner, "no pallas_call found in the fused path"
    offenders = [s for s in inner if cin in s or cout in s]
    assert not offenders, \
        f"kernel-internal arrays sized by full channels: {offenders}"
