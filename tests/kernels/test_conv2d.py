"""Conv im2col + Pallas MXU matmul vs lax.conv oracle, shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.conv2d import ops, ref
from repro.kernels.conv2d.conv2d import matmul_bias


@pytest.mark.parametrize("m,k,n,bm,bk,bn", [
    (64, 64, 64, 32, 32, 32),
    (100, 70, 50, 32, 32, 32),      # non-multiples: padding path
    (256, 128, 256, 128, 128, 128),
])
@pytest.mark.parametrize("relu", [False, True])
def test_matmul_bias(m, k, n, bm, bk, bn, relu):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(ks[0], (m, k))
    w = jax.random.normal(ks[1], (k, n)) * 0.1
    b = jax.random.normal(ks[2], (n,))
    out = matmul_bias(x, w, b, bm=bm, bk=bk, bn=bn, relu=relu)
    exp = ref.matmul_bias_ref(x, w, b, relu=relu)
    np.testing.assert_allclose(out, exp, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("hw,cin,cout,kernel,stride,pad", [
    (33, 5, 7, 5, 2, 2),
    (27, 3, 16, 11, 4, 0),   # AlexNet conv1 shape family
    (16, 8, 8, 3, 1, 1),
    (14, 4, 6, 1, 1, 0),     # 1x1 conv
])
def test_conv2d_im2col(hw, cin, cout, kernel, stride, pad):
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    x = jax.random.normal(ks[0], (2, hw, hw, cin))
    w = jax.random.normal(ks[1], (kernel, kernel, cin, cout)) * 0.1
    out = ops.conv2d_im2col(x, w, stride=stride, padding=pad)
    exp = ref.conv2d_ref(x, w, stride, pad)
    np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)


def test_conv2d_bias_relu_fused():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    x = jax.random.normal(ks[0], (1, 12, 12, 4))
    w = jax.random.normal(ks[1], (3, 3, 4, 8)) * 0.2
    b = jax.random.normal(ks[2], (8,))
    out = ops.conv2d_im2col(x, w, stride=1, padding=1, bias=b, relu=True)
    exp = jnp.maximum(ref.conv2d_ref(x, w, 1, 1) + b, 0.0)
    np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)


def test_bf16():
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    x = jax.random.normal(ks[0], (1, 16, 16, 4), jnp.bfloat16)
    w = (jax.random.normal(ks[1], (3, 3, 4, 8)) * 0.1).astype(jnp.bfloat16)
    out = ops.conv2d_im2col(x, w, stride=1, padding=1)
    exp = ref.conv2d_ref(x, w, 1, 1)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=3e-2, atol=3e-2)
