"""Conv backend parity: fused implicit-GEMM vs two-stage im2col ref vs
lax.conv oracle, plus gradient parity, the no-HBM-im2col proof, and the
aligned-matmul no-pad guarantee."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.alexnet import CONFIG as ALEXNET_FULL
from repro.kernels.conv2d import ops, ref
from repro.kernels.conv2d.conv2d import matmul_bias


@pytest.mark.parametrize("m,k,n,bm,bk,bn", [
    (64, 64, 64, 32, 32, 32),
    (100, 70, 50, 32, 32, 32),      # non-multiples: padding path
    (256, 128, 256, 128, 128, 128),
])
@pytest.mark.parametrize("relu", [False, True])
def test_matmul_bias(m, k, n, bm, bk, bn, relu):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(ks[0], (m, k))
    w = jax.random.normal(ks[1], (k, n)) * 0.1
    b = jax.random.normal(ks[2], (n,))
    out = matmul_bias(x, w, b, bm=bm, bk=bk, bn=bn, relu=relu)
    exp = ref.matmul_bias_ref(x, w, b, relu=relu)
    np.testing.assert_allclose(out, exp, rtol=1e-5, atol=1e-5)


def test_matmul_bias_autoblocks():
    """bm/bk/bn=None resolve through the tune cache (non-128 M/K/N)."""
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    x = jax.random.normal(ks[0], (150, 93))
    w = jax.random.normal(ks[1], (93, 37)) * 0.1
    b = jax.random.normal(ks[2], (37,))
    out = matmul_bias(x, w, b)
    np.testing.assert_allclose(out, ref.matmul_bias_ref(x, w, b),
                               rtol=1e-5, atol=1e-5)


def _collect_shapes(jaxpr, out):
    for eqn in jaxpr.eqns:
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                out.add(tuple(aval.shape))
        for p in eqn.params.values():
            for sub in (p if isinstance(p, (list, tuple)) else (p,)):
                if isinstance(sub, jax.core.ClosedJaxpr):
                    _collect_shapes(sub.jaxpr, out)
                elif isinstance(sub, jax.core.Jaxpr):
                    _collect_shapes(sub, out)
    return out


def _primitives(jaxpr, out):
    for eqn in jaxpr.eqns:
        out.add(eqn.primitive.name)
        for p in eqn.params.values():
            for sub in (p if isinstance(p, (list, tuple)) else (p,)):
                if isinstance(sub, jax.core.ClosedJaxpr):
                    _primitives(sub.jaxpr, out)
                elif isinstance(sub, jax.core.Jaxpr):
                    _primitives(sub, out)
    return out


def test_matmul_bias_aligned_skips_padding():
    """Block-multiple operands must not pay the jnp.pad HBM copies."""
    x = jnp.ones((128, 64))
    w = jnp.ones((64, 128))
    b = jnp.ones((128,))
    aligned = jax.make_jaxpr(
        lambda x, w, b: matmul_bias(x, w, b, bm=128, bk=64, bn=128))(x, w, b)
    assert "pad" not in _primitives(aligned.jaxpr, set())
    misaligned = jax.make_jaxpr(
        lambda x, w, b: matmul_bias(x, w, b, bm=128, bk=128, bn=128))(
            jnp.ones((100, 70)), jnp.ones((70, 50)), jnp.ones((50,)))
    assert "pad" in _primitives(misaligned.jaxpr, set())


# the five full-AlexNet conv layers: real kernel/stride/padding/channel
# geometry, spatial dims reduced so interpret mode stays fast
ALEXNET_SHAPES = [
    pytest.param(cs.kernel, cs.stride, cs.padding, cin, cs.out_channels,
                 hw, id=f"conv{i + 1}")
    for i, (cs, cin, hw) in enumerate(zip(
        ALEXNET_FULL.convs,
        [ALEXNET_FULL.in_channels] + [c.out_channels
                                      for c in ALEXNET_FULL.convs[:-1]],
        [27, 8, 6, 6, 6]))
]


@pytest.mark.parametrize("kernel,stride,pad,cin,cout,hw", ALEXNET_SHAPES)
def test_conv_backend_parity_alexnet_shapes(kernel, stride, pad, cin, cout,
                                            hw):
    """fused == im2col_ref == lax.conv on every AlexNet layer geometry."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(ks[0], (2, hw, hw, cin))
    w = jax.random.normal(ks[1], (kernel, kernel, cin, cout)) * 0.05
    b = jax.random.normal(ks[2], (cout,)) * 0.1
    exp = np.asarray(ref.conv2d_ref(x, w, stride, pad) + b)
    fused = np.asarray(ops.conv2d_fused(x, w, stride=stride, padding=pad,
                                        bias=b))
    ref2 = np.asarray(ops.conv2d_im2col(x, w, stride=stride, padding=pad,
                                        bias=b))
    np.testing.assert_allclose(fused, exp, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(ref2, exp, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("hw,cin,cout,kernel,stride,pad", [
    (33, 5, 7, 5, 2, 2),
    (27, 3, 16, 11, 4, 0),   # AlexNet conv1 shape family
    (16, 8, 8, 3, 1, 1),
    (14, 4, 6, 1, 1, 0),     # 1x1 conv
    (19, 3, 9, 3, 3, 2),     # odd stride + asymmetric-ish padding
    (21, 6, 5, 5, 3, 1),     # odd stride, non-pow2 channels
])
@pytest.mark.parametrize("impl", ["fused", "im2col_ref"])
def test_conv2d_parity_sweep(hw, cin, cout, kernel, stride, pad, impl):
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    x = jax.random.normal(ks[0], (2, hw, hw, cin))
    w = jax.random.normal(ks[1], (kernel, kernel, cin, cout)) * 0.1
    fn = ops.conv2d_fused if impl == "fused" else ops.conv2d_im2col
    out = fn(x, w, stride=stride, padding=pad)
    exp = ref.conv2d_ref(x, w, stride, pad)
    np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("impl", ["fused", "im2col_ref"])
def test_conv2d_bias_relu_fused(impl):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    x = jax.random.normal(ks[0], (1, 12, 12, 4))
    w = jax.random.normal(ks[1], (3, 3, 4, 8)) * 0.2
    b = jax.random.normal(ks[2], (8,))
    fn = ops.conv2d_fused if impl == "fused" else ops.conv2d_im2col
    out = fn(x, w, stride=1, padding=1, bias=b, relu=True)
    exp = jnp.maximum(ref.conv2d_ref(x, w, 1, 1) + b, 0.0)
    np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("impl", ["fused", "im2col_ref"])
def test_bf16(impl):
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    x = jax.random.normal(ks[0], (1, 16, 16, 4), jnp.bfloat16)
    w = (jax.random.normal(ks[1], (3, 3, 4, 8)) * 0.1).astype(jnp.bfloat16)
    fn = ops.conv2d_fused if impl == "fused" else ops.conv2d_im2col
    out = fn(x, w, stride=1, padding=1)
    exp = ref.conv2d_ref(x, w, 1, 1)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_conv2d_fused_gradients_match_xla():
    """custom_vjp of the fused kernel == autodiff of the lax.conv oracle,
    for dx, dw AND db, through a ReLU epilogue."""
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    x = jax.random.normal(ks[0], (2, 12, 12, 3))
    w = jax.random.normal(ks[1], (3, 3, 3, 8)) * 0.1
    b = jax.random.normal(ks[2], (8,)) * 0.1

    def f_fused(x, w, b):
        return jnp.sum(jnp.sin(ops.conv2d_fused(
            x, w, stride=2, padding=1, bias=b, relu=True)))

    def f_xla(x, w, b):
        return jnp.sum(jnp.sin(jnp.maximum(
            ref.conv2d_ref(x, w, 2, 1) + b, 0.0)))

    got = jax.grad(f_fused, argnums=(0, 1, 2))(x, w, b)
    exp = jax.grad(f_xla, argnums=(0, 1, 2))(x, w, b)
    for g, e in zip(got, exp):
        np.testing.assert_allclose(g, e, rtol=1e-4, atol=1e-5)


def test_matmul_bias_gradients_match_ref():
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    x = jax.random.normal(ks[0], (37, 23))
    w = jax.random.normal(ks[1], (23, 19)) * 0.1
    b = jax.random.normal(ks[2], (19,))

    def f(mm):
        return lambda x, w, b: jnp.sum(jnp.cos(mm(x, w, b, relu=True)))

    got = jax.grad(f(matmul_bias), argnums=(0, 1, 2))(x, w, b)
    exp = jax.grad(f(ref.matmul_bias_ref), argnums=(0, 1, 2))(x, w, b)
    for g, e in zip(got, exp):
        np.testing.assert_allclose(g, e, rtol=1e-4, atol=1e-5)


def test_fused_never_materializes_im2col():
    """The (B*OH*OW, K*K*C) patch tensor must not exist anywhere in the
    fused path's jaxpr — not even under the pallas_call — while the
    two-stage ref path provably does materialize it (detector sanity)."""
    b_, hw, cin, cout, kernel, stride, pad = 2, 11, 7, 11, 3, 2, 1
    x = jnp.ones((b_, hw, hw, cin))
    w = jnp.ones((kernel, kernel, cin, cout))
    oh = (hw + 2 * pad - kernel) // stride + 1
    patch_elems = b_ * oh * oh * kernel * kernel * cin

    fused = jax.make_jaxpr(lambda x, w: ops.conv2d_fused(
        x, w, stride=stride, padding=pad))(x, w)
    fused_sizes = {int(np.prod(s)) for s in _collect_shapes(fused.jaxpr,
                                                            set())}
    assert patch_elems not in fused_sizes, (
        "fused path materializes an im2col-sized tensor")

    ref_path = jax.make_jaxpr(lambda x, w: ops.conv2d_im2col(
        x, w, stride=stride, padding=pad))(x, w)
    ref_sizes = {int(np.prod(s)) for s in _collect_shapes(ref_path.jaxpr,
                                                          set())}
    assert patch_elems in ref_sizes, "detector failed to see ref's patches"


def test_weight_reorder_cached():
    w = jnp.arange(2 * 2 * 3 * 4, dtype=jnp.float32).reshape(2, 2, 3, 4)
    a = ops.reorder_weights(w)
    b = ops.reorder_weights(w)
    assert a is b                       # memoised for the same buffer
    np.testing.assert_array_equal(
        a, w.transpose(2, 0, 1, 3).reshape(12, 4))
    w2 = w + 1
    c = ops.reorder_weights(w2)
    assert c is not a


def test_alexnet_conv_layer_all_backends():
    """models.alexnet.conv2d dispatch: all three backends agree (incl.
    the fused relu epilogue)."""
    from repro.models import alexnet
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    x = jax.random.normal(ks[0], (2, 14, 14, 3))
    w = jax.random.normal(ks[1], (5, 5, 3, 8)) * 0.1
    b = jax.random.normal(ks[2], (8,))
    outs = {be: np.asarray(alexnet.conv2d(x, w, b, 2, 2, be, relu=True))
            for be in ("xla", "pallas", "pallas_im2col_ref")}
    np.testing.assert_allclose(outs["pallas"], outs["xla"],
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(outs["pallas_im2col_ref"], outs["xla"],
                               rtol=1e-4, atol=1e-4)
