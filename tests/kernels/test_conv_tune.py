"""Backend resolution + autotune cache (kernels/conv2d/tune.py)."""
import jax
import pytest

from repro.kernels.conv2d import tune


def test_resolve_interpret_auto(monkeypatch):
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    expect = jax.default_backend() != "tpu"
    assert tune.resolve_interpret(None) is expect
    assert tune.resolve_interpret(True) is True
    assert tune.resolve_interpret(False) is False


def test_resolve_interpret_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert tune.resolve_interpret(None) is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert tune.resolve_interpret(None) is True
    # explicit argument beats the env var
    assert tune.resolve_interpret(False) is False


def test_autotune_measures_once_and_caches():
    tune.clear_cache()
    calls = []

    def measure(cand):
        calls.append(cand)
        return {(8, 8): 3.0, (16, 16): 1.0, (32, 32): 2.0}[cand]

    key = ("test", 1, 2, 3)
    cands = [(8, 8), (16, 16), (32, 32)]
    assert tune.autotune(key, cands, measure) == (16, 16)
    assert len(calls) == 3
    # cache hit: no re-measurement
    assert tune.autotune(key, cands, measure) == (16, 16)
    assert len(calls) == 3
    info = tune.cache_info()
    assert info["entries"] == 1 and info["hits"] == 1
    tune.clear_cache()


def test_autotune_skips_failing_candidates():
    tune.clear_cache()

    def measure(cand):
        if cand == (8,):
            raise RuntimeError("compiler rejected blocking")
        return 1.0

    assert tune.autotune(("k",), [(8,), (16,)], measure) == (16,)
    tune.clear_cache()


def test_blocks_clip_to_problem_shape():
    """Interpret mode: deterministic heuristic blocks, pow2-clipped so tiny
    problems do not pad up to 128**3."""
    tune.clear_cache()
    assert tune.matmul_blocks(100, 70, 50, "float32",
                              interpret=True) == (128, 128, 64)
    assert tune.matmul_blocks(5, 3, 2, "float32",
                              interpret=True) == (8, 8, 8)
    bm, bn = tune.conv_blocks(2, 5, 5, 3, 7, 11, 2, "float32",
                              interpret=True)
    assert bm == 32 and bn == 16      # pow2ceil(25), pow2ceil(11)
    # second call is a pure cache hit
    before = tune.cache_info()["hits"]
    tune.matmul_blocks(100, 70, 50, "float32", interpret=True)
    assert tune.cache_info()["hits"] == before + 1
    tune.clear_cache()


@pytest.mark.parametrize("env,measured", [("0", 0), ("1", 1)])
def test_autotune_env_gate(monkeypatch, env, measured):
    """REPRO_CONV_AUTOTUNE=0 disables measurement even off-interpret; with
    it on, a compiled-backend tune would measure (exercised via the
    public entry point on tiny shapes in interpret=False... too slow on
    CPU, so assert through the gate instead)."""
    tune.clear_cache()
    monkeypatch.setenv("REPRO_CONV_AUTOTUNE", env)
    assert tune._autotune_enabled(interpret=False) is bool(measured)
    assert tune._autotune_enabled(interpret=True) is False
    tune.clear_cache()
